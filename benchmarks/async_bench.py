"""Disaggregated async rollout ↔ train benchmark (DESIGN.md §12).

Three legs over the same tiny trainer:

* **identity** — K=0 under the strict ``"pc"`` interleave must be loss-
  and token-identical to the synchronous trainer (asserted in-bench, the
  §12 determinism contract — a perf number from a wrong loop is worthless);
* **sync** — wall time per ``Trainer.train_step`` (collect + optimize in
  one process, the pre-§12 loop);
* **async** — the buffer is pre-filled by producer ticks, then wall time
  per ``consumer_step`` measures the optimization half alone: the collect
  stage has moved into the producer's failure domain, which is exactly the
  overlap a disaggregated deployment buys.  The consumed staleness
  distribution is recorded alongside.

``async_vs_sync_speedup`` = sync step wall / async consumer-step wall
(> 1 ⇔ collection dominates the step, the regime SPEC-RL targets).
Writes BENCH_async.json.

    PYTHONPATH=src python -m benchmarks.async_bench [--smoke] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import math
import os
import time

import jax
import numpy as np

from repro.core import SpecConfig
from repro.data.dataset import PromptDataset
from repro.data.tokenizer import VOCAB_SIZE
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamWConfig
from repro.rewards.mathgen import MathTaskConfig, generate_problems
from repro.rl.async_loop import AsyncConfig, AsyncTrainer
from repro.rl.trainer import RLConfig, Trainer

from .common import emit

OUT_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_async.json")


def _make_trainer(max_new_tokens: int, variant: str = "spec") -> Trainer:
    cfg = ModelConfig(name="bench", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=VOCAB_SIZE,
                      max_seq_len=128)
    problems = generate_problems(MathTaskConfig(num_problems=16,
                                                max_operand=9))
    ds = PromptDataset(problems, max_prompt_len=10)
    rl = RLConfig(algo="grpo", group_size=2, prompts_per_batch=4,
                  max_new_tokens=max_new_tokens, optim=AdamWConfig(lr=1e-3),
                  max_resample_rounds=1)
    spec = SpecConfig(variant=variant, lenience=math.e ** 0.5,
                      verify_impl="ref")
    return Trainer(cfg, rl, spec, ds, jax.random.PRNGKey(0))


def run(smoke: bool = False, out_path: str = OUT_PATH) -> dict:
    steps = 4 if smoke else 8
    toks = 6 if smoke else 12

    # ---- leg 0: the K=0 determinism contract, asserted in-bench --------
    tr_ref = _make_trainer(toks)
    ref = [tr_ref.train_step() for _ in range(3)]
    at0 = AsyncTrainer(_make_trainer(toks),
                       AsyncConfig(staleness_window=0, buffer_capacity=2,
                                   schedule="pc"))
    got = at0.run(3)
    for ms, ma in zip(ref, got):
        assert ms["loss"] == ma["loss"], \
            f"K=0 identity broken: {ms['loss']} != {ma['loss']}"
    np.testing.assert_array_equal(np.asarray(tr_ref.last_rb.response),
                                  np.asarray(at0.trainer.last_rb.response))
    emit("async/k0_identity", 0.0, f"{len(got)} steps bit-identical")

    # The perf legs run variant="off" (full generation each collect): the
    # disaggregation win is proportional to the collect stage's share of
    # the step, and SPEC-RL reuse at bench scale shrinks that share to
    # noise — "off" is the collection-dominated regime §12 targets.
    # ---- leg 1: synchronous wall per train step ------------------------
    tr_sync = _make_trainer(toks, variant="off")
    tr_sync.train_step()                              # compile warmup
    t0 = time.perf_counter()
    for _ in range(steps):
        tr_sync.train_step()
    t_sync = time.perf_counter() - t0

    # ---- leg 2: async consumer wall off a warm buffer ------------------
    at = AsyncTrainer(_make_trainer(toks, variant="off"),
                      AsyncConfig(staleness_window=steps + 4,
                                  buffer_capacity=steps + 5,
                                  # the timed leg batch-consumes with no
                                  # producer ticks in between, so service
                                  # staleness legitimately runs ahead —
                                  # park the ladder out of the way
                                  hard_staleness_cap=10 * steps,
                                  schedule="pc"))
    at.run(1)                                         # exact-path warmup
    for _ in range(steps + 3):                        # pre-fill: collection
        assert at.producer_tick()                     # happens off-step
    for _ in range(3):                                # warm BOTH optimize
        m = at.consumer_step()                        # branches (the first
        assert m is not None                          # stale one compiles
        if m["staleness"] > 0 and at.is_steps >= 2:   # the IS program)
            break
    metrics = []
    t0 = time.perf_counter()
    for _ in range(steps):
        m = at.consumer_step()
        assert m is not None, "warm buffer starved"
        metrics.append(m)
    t_async = time.perf_counter() - t0

    staleness = [m["staleness"] for m in metrics]
    assert at.reverified == 0, "window sized to keep this leg IS-only"
    assert at.mode == "async", at.mode

    record = {
        "backend": jax.default_backend(),
        "steps": steps, "max_new_tokens": toks,
        "k0_identity": True,                          # asserted above
        "sync": {"time_s": t_sync, "per_step_ms": t_sync / steps * 1e3},
        "async": {
            "time_s": t_async, "per_step_ms": t_async / steps * 1e3,
            "exact_steps": int(at.exact_steps),
            "is_steps": int(at.is_steps),
            "staleness": {"min": float(min(staleness)),
                          "max": float(max(staleness)),
                          "mean": float(np.mean(staleness))},
            **{k: int(v) for k, v in at.buffer.counters().items()},
        },
        # > 1 ⇔ the collect stage dominates the step; disaggregation
        # moves it off the optimizer's critical path
        "async_vs_sync_speedup": t_sync / max(t_async, 1e-9),
    }
    emit("async/sync_step", t_sync / steps * 1e6, f"{steps} steps")
    emit("async/consumer_step", t_async / steps * 1e6,
         f"stale_mean={record['async']['staleness']['mean']:.1f}")
    emit("async/speedup", 0.0,
         f"{record['async_vs_sync_speedup']:.2f}x")
    with open(out_path, "w") as f:
        json.dump(record, f, indent=2)
    emit("async/json", 0.0, out_path)
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fewer steps, smaller generation budget")
    ap.add_argument("--out", default=OUT_PATH)
    args = ap.parse_args()
    run(smoke=args.smoke, out_path=args.out)
