"""Bench-regression guard: compare freshly recorded BENCH_*.json speedup
ratios against the committed baselines and fail on >30% regression.

Baselines live in ``benchmarks/baselines/`` and are recorded in the SAME
``--smoke`` mode CI runs, so ratios compare like-for-like (the repo-root
BENCH_*.json are the full-mode perf-trajectory records — different shapes,
different ratios — and are not what CI regenerates).  Only *speedup-like*
keys are guarded (key name contains ``speedup``, value numeric).  Two
tiers: ratios whose baseline is at least ``--min-baseline`` (default 1.2)
— actual protected speedups — fail on a >``--threshold`` (30%) relative
drop; sub-floor ratios (a ratio at or below ~1.0 in the smoke regime is a
recorded trade-off, not a speedup — e.g. the dispatch-bound one-pass CPU
shapes noted for PR 3, or blocked-vs-naive at smoke cache widths, and its
timing noise is large) are still guarded against *catastrophic* collapse
via the wider ``--floor-threshold`` (60%), so no file is ever a silent
no-op.  A baseline path missing from the fresh record IS a failure — it
means the bench silently stopped recording it.  No jax import — this runs
in seconds on any runner.

    python benchmarks/check_regression.py --fresh-dir bench-artifacts \
        --files BENCH_rollout.json BENCH_decode.json BENCH_serving.json
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys
from typing import Iterator, Tuple


def iter_speedups(obj, path: str = "") -> Iterator[Tuple[str, float]]:
    """Yield (json-path, value) for every numeric key containing 'speedup'."""
    if isinstance(obj, dict):
        for k, v in sorted(obj.items()):
            sub = f"{path}.{k}" if path else str(k)
            if "speedup" in str(k) and isinstance(v, (int, float)):
                yield sub, float(v)
            else:
                yield from iter_speedups(v, sub)
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            yield from iter_speedups(v, f"{path}[{i}]")


def check_file(baseline_path: str, fresh_path: str, threshold: float,
               min_baseline: float, floor_threshold: float
               ) -> Tuple[int, int]:
    """Returns (checked, failed) and prints one line per guarded ratio."""
    with open(baseline_path) as f:
        base = dict(iter_speedups(json.load(f)))
    with open(fresh_path) as f:
        fresh = dict(iter_speedups(json.load(f)))
    name = os.path.basename(baseline_path)
    checked = failed = 0
    for key, bval in base.items():
        fval = fresh.get(key)
        if fval is None:
            print(f"FAIL {name}:{key} missing from fresh record")
            failed += 1
            checked += 1
            continue
        # a NaN/inf or non-positive ratio means the bench divided by zero
        # (or recorded garbage): fail LOUDLY instead of letting float
        # comparison semantics (inf >= inf, 0.0 >= 0.0) silently pass
        bad = [t for t, v in (("baseline", bval), ("fresh", fval))
               if not math.isfinite(v) or v <= 0.0]
        if bad:
            print(f"FAIL {name}:{key} non-finite/non-positive {bad[0]} "
                  f"ratio (baseline {bval!r}, fresh {fval!r})")
            failed += 1
            checked += 1
            continue
        strict = bval >= min_baseline
        tol = threshold if strict else floor_threshold
        tier = "" if strict else \
            f" [sub-{min_baseline:.1f}x baseline, lax tier]"
        checked += 1
        floor = bval * (1.0 - tol)
        status = "ok  " if fval >= floor else "FAIL"
        if fval < floor:
            failed += 1
        print(f"{status} {name}:{key} baseline {bval:.2f}x fresh {fval:.2f}x "
              f"(floor {floor:.2f}x){tier}")
    return checked, failed


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline-dir",
                    default=os.path.join(os.path.dirname(
                        os.path.abspath(__file__)), "baselines"),
                    help="directory holding the committed smoke baselines")
    ap.add_argument("--fresh-dir", default="bench-artifacts",
                    help="directory holding the just-recorded BENCH_*.json")
    ap.add_argument("--files", nargs="+",
                    default=["BENCH_rollout.json", "BENCH_decode.json",
                             "BENCH_serving.json"])
    ap.add_argument("--threshold", type=float, default=0.30,
                    help="max allowed fractional regression of a protected "
                         "(>= min-baseline) speedup ratio")
    ap.add_argument("--min-baseline", type=float, default=1.2,
                    help="baselines below this use the lax floor-threshold "
                         "tier instead of the strict one")
    ap.add_argument("--floor-threshold", type=float, default=0.60,
                    help="max allowed fractional drop of a sub-floor ratio "
                         "(catches collapses without crying wolf on noise)")
    args = ap.parse_args(argv)

    total = failures = 0
    for fn in args.files:
        bpath = os.path.join(args.baseline_dir, fn)
        fpath = os.path.join(args.fresh_dir, fn)
        if not os.path.exists(bpath):
            print(f"FAIL missing committed baseline {bpath}")
            failures += 1
            continue
        if not os.path.exists(fpath):
            print(f"FAIL missing fresh record {fpath} (bench did not run?)")
            failures += 1
            continue
        c, f = check_file(bpath, fpath, args.threshold, args.min_baseline,
                          args.floor_threshold)
        if c == 0:
            print(f"FAIL {fn}: no speedup ratios found to guard")
            failures += 1
        total += c
        failures += f
    print(f"bench-regression guard: {total} ratios checked, "
          f"{failures} failures (threshold {args.threshold:.0%})")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
