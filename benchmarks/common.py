"""Shared benchmark harness: one tiny backbone + identical shapes across all
benchmarks so jit caches are reused; CSV emission helpers.

The benchmarks reproduce the paper's MEASURABLE CLAIMS at CPU scale: token
reduction, speedup vs lenience, variant comparisons, diagnostics, diversity.
Token counts are exact (the paper's own primary efficiency metric);
wall-clock is reported for completeness but CPU timing is not the claim.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.core import SpecConfig
from repro.data.dataset import PromptDataset
from repro.data.tokenizer import VOCAB_SIZE
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamWConfig
from repro.rewards.mathgen import MathTaskConfig, generate_problems
from repro.rl.trainer import RLConfig, Trainer

ROWS: List[str] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def bench_model_cfg() -> ModelConfig:
    return ModelConfig(name="bench", num_layers=2, d_model=64, num_heads=4,
                       num_kv_heads=2, d_ff=128, vocab_size=VOCAB_SIZE,
                       max_seq_len=128)


def bench_dataset(n: int = 12) -> PromptDataset:
    problems = generate_problems(MathTaskConfig(num_problems=n, max_operand=9))
    return PromptDataset(problems, max_prompt_len=10)


def make_trainer(algo: str = "grpo", variant: str = "spec",
                 lenience: float = math.e ** 0.5, seed: int = 0,
                 dataset: Optional[PromptDataset] = None,
                 max_new_tokens: int = 12) -> Trainer:
    cfg = bench_model_cfg()
    ds = dataset or bench_dataset()
    rl = RLConfig(algo=algo, group_size=2, prompts_per_batch=4,
                  max_new_tokens=max_new_tokens, optim=AdamWConfig(lr=5e-4),
                  max_resample_rounds=1)
    spec = SpecConfig(variant=variant, lenience=lenience, verify_impl="ref")
    return Trainer(cfg, rl, spec, ds, jax.random.PRNGKey(seed))


def run_steps(tr: Trainer, n: int) -> Dict[str, float]:
    t0 = time.perf_counter()
    rollout_time = 0.0
    for _ in range(n):
        m = tr.train_step()
        rollout_time += m.get("rollout_time", 0.0) + m.get("verify_time", 0.0) \
            + m.get("assembly_time", 0.0)
    wall = time.perf_counter() - t0
    h = tr.history
    return {
        "tokens": tr.total_generated_tokens,
        "reward_last": float(np.mean([x["reward_mean"] for x in h[-2:]])),
        "wall_s": wall,
        "rollout_s": rollout_time,
        "steps": n,
        "entropy": float(np.mean([x.get("entropy", 0.0) for x in h])),
        "kl": float(np.mean([abs(x.get("approx_kl", 0.0)) for x in h])),
        "clip_frac": float(np.mean([x.get("clip_frac", 0.0) for x in h])),
        "prefix_mean": float(np.mean([x.get("verified_prefix_mean", 0.0)
                                      for x in h])),
        "full_reuse": float(np.mean([x.get("full_reuse_ratio", 0.0)
                                     for x in h])),
    }
