"""Per-token decode-attention microbenchmark: latency vs *live* cache length
for the three decode impls — legacy naive (full-S materialised scores),
length-bounded blocked (while_loop over live chunks) and the split-K Pallas
flash-decode kernel (interpret mode off-TPU).  Writes BENCH_decode.json so
the perf trajectory captures the decode win (DESIGN.md §7).

The point of flash-decode is that cost tracks the *live* extent, not the
allocated width S: a slot-server row 64 tokens into a 1024-slot cache should
pay ~1/16th of full-width attention.  The naive row is flat in `live` by
construction; blocked/flash fall with it.

    PYTHONPATH=src python -m benchmarks.decode_bench [--smoke] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.ops import decode_attention

from .common import emit

OUT_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_decode.json")

B, HQ, HKV, D = 8, 8, 2, 64
S_FULL, S_SMOKE = 1024, 256


def _inputs(S, live, start=0, seed=0):
    """A lockstep decode batch with live slots [start, start + live) in an
    S-slot cache (start > 0 = the dead left padding a one-pass SPEC-RL
    resume sits behind, see DESIGN.md §3/§7)."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, HQ, 1, D))
    k = jax.random.normal(ks[1], (B, HKV, S, D))
    v = jax.random.normal(ks[2], (B, HKV, S, D))
    j = jnp.arange(S, dtype=jnp.int32)
    k_pos = jnp.broadcast_to(
        jnp.where((j >= start) & (j < start + live), j - start, -1), (B, S))
    q_pos = jnp.full((B,), live - 1, jnp.int32)
    lengths = jnp.full((B,), start + live, jnp.int32)
    starts = jnp.full((B,), start, jnp.int32)
    return q, k, v, q_pos, k_pos, lengths, starts


def _time(impl, args, iters):
    out = decode_attention(*args, impl=impl)          # compile
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = decode_attention(*args, impl=impl)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6   # us / decode token


def run(smoke: bool = False, out_path: str = OUT_PATH) -> None:
    S = S_SMOKE if smoke else S_FULL
    lives = [32, 128] if smoke else [32, 64, 128, 256, 512, S_FULL]
    iters = 5 if smoke else 50
    interp_iters = 2 if smoke else 5                  # interpret is host-side
    record = {"backend": jax.default_backend(), "B": B, "Hq": HQ,
              "Hkv": HKV, "D": D, "S": S, "iters": iters, "points": []}
    for live in lives:
        args = _inputs(S, live)
        row = {"live": live,
               "naive_us": _time("naive", args, iters),
               "blocked_us": _time("blocked", args, iters),
               "flash_interpret_us": _time("interpret", args, interp_iters)}
        row["speedup_blocked_vs_naive"] = row["naive_us"] / max(
            row["blocked_us"], 1e-9)
        record["points"].append(row)
        emit("decode_bench/point", row["blocked_us"],
             f"S={S};live={live};naive={row['naive_us']:.1f}us;"
             f"blocked={row['blocked_us']:.1f}us;"
             f"speedup={row['speedup_blocked_vs_naive']:.2f}x")
    short = record["points"][0]
    record["speedup_short_live"] = short["speedup_blocked_vs_naive"]
    # resume-shaped: a short live span sitting behind dead left padding
    # (start bound skips it; naive still scans the full width)
    live, start = lives[0], S - 2 * lives[0]
    args = _inputs(S, live, start=start)
    row = {"live": live, "start": start,
           "naive_us": _time("naive", args, iters),
           "blocked_us": _time("blocked", args, iters)}
    row["speedup_blocked_vs_naive"] = row["naive_us"] / max(
        row["blocked_us"], 1e-9)
    record["resume_shaped"] = row
    emit("decode_bench/resume_shaped", row["blocked_us"],
         f"S={S};start={start};live={live};naive={row['naive_us']:.1f}us;"
         f"blocked={row['blocked_us']:.1f}us;"
         f"speedup={row['speedup_blocked_vs_naive']:.2f}x")
    if not smoke:
        # acceptance: >= 2x over naive at S=1024 with short live lengths
        assert record["speedup_short_live"] >= 2.0, record["speedup_short_live"]
    with open(out_path, "w") as f:
        json.dump(record, f, indent=2)
    emit("decode_bench/json", 0.0, out_path)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small cache, few live points/iters (CI)")
    ap.add_argument("--out", default=OUT_PATH)
    args = ap.parse_args()
    run(smoke=args.smoke, out_path=args.out)
