"""Draft-engine benchmark: tokens-per-forward and wall time of §9 drafted
decoding vs draft-off, at matched sampling params.  Writes BENCH_draft.json.

Greedy decoding keeps the token streams identical (asserted), so the
comparison is pure decode efficiency.  Three arms:

* ``off``     — vanilla ``generate`` (1 token per forward by definition);
* ``self``    — drafting from each row's own prompt ⊕ generated stream
  (whatever repetition the model emits is speculated);
* ``corpus``  — drafting with a sibling trajectory corpus from a previous
  identical-policy pass, the GRPO / SPEC-RL regime where the n-gram index
  locks onto the prior rollout and acceptance approaches 100%.

    PYTHONPATH=src python -m benchmarks.draft_bench [--smoke] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.tokenizer import VOCAB_SIZE
from repro.drafting import DraftConfig
from repro.drafting.engine import drafted_generate
from repro.engine.generate import GenerateConfig, generate
from repro.models import model as M
from repro.models.config import ModelConfig

from .common import emit

OUT_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_draft.json")
PROMPT_LEN = 16


def _setup(B, N, seed=0):
    cfg = ModelConfig(name="bench", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=VOCAB_SIZE,
                      max_seq_len=max(256, PROMPT_LEN + 2 * N))
    params = M.init_lm(jax.random.PRNGKey(seed), cfg)
    gen = GenerateConfig(max_new_tokens=N, temperature=0.0,
                         eos_id=VOCAB_SIZE - 1)
    prompts = jax.random.randint(jax.random.PRNGKey(seed + 1),
                                 (B, PROMPT_LEN), 3, VOCAB_SIZE - 1)
    mask = jnp.ones((B, PROMPT_LEN), bool)
    key = jax.random.PRNGKey(seed + 2)
    return cfg, params, gen, prompts, mask, key


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, time.perf_counter() - t0


def run(smoke: bool = False, out_path: str = OUT_PATH) -> dict:
    B = 4 if smoke else 8
    N = 48 if smoke else 96
    K = 8
    cfg, params, gen, prompts, mask, key = _setup(B, N)
    draft = DraftConfig(kind="ngram", draft_k=K)

    # warmup (compile) then timed arms
    generate(params, cfg, gen, prompts, mask, key)
    van, t_off = _timed(lambda: jax.block_until_ready(
        generate(params, cfg, gen, prompts, mask, key)["tokens"]))
    van_tok = np.asarray(generate(params, cfg, gen, prompts, mask,
                                  key)["tokens"])

    drafted_generate(params, cfg, gen, prompts, mask, key, draft)  # warmup
    slf, t_self = _timed(
        lambda: drafted_generate(params, cfg, gen, prompts, mask, key, draft))

    corpus = [[np.asarray(van_tok[b])] for b in range(B)]
    drafted_generate(params, cfg, gen, prompts, mask, key, draft,
                     corpus=corpus)                                # warmup
    crp, t_corpus = _timed(
        lambda: drafted_generate(params, cfg, gen, prompts, mask, key, draft,
                                 corpus=corpus))

    # greedy identity: drafting must never change the stream
    np.testing.assert_array_equal(np.asarray(slf["tokens"]), van_tok)
    np.testing.assert_array_equal(np.asarray(crp["tokens"]), van_tok)

    tpf_self = slf["stats"].tokens_per_forward
    tpf_corpus = crp["stats"].tokens_per_forward
    record = {
        "backend": jax.default_backend(),
        "batch": B, "prompt_len": PROMPT_LEN, "max_new_tokens": N,
        "draft_k": K,
        "off": {"time_s": t_off, "tokens_per_forward": 1.0},
        "self": {"time_s": t_self, "tokens_per_forward": tpf_self,
                 "accept_rate": slf["stats"].accept_rate,
                 "mean_draft_len": slf["stats"].mean_draft_len},
        "corpus": {"time_s": t_corpus, "tokens_per_forward": tpf_corpus,
                   "accept_rate": crp["stats"].accept_rate,
                   "mean_draft_len": crp["stats"].mean_draft_len},
        # tokens-per-forward ratios are the headline numbers AND exactly
        # reproducible (greedy + fixed seeds => deterministic forward
        # counts), so they are what the regression guard protects; the wall
        # ratio is recorded for the perf trajectory but keyed outside the
        # guard's "speedup" namespace (tiny-CPU wall times are noisy)
        "tokens_per_forward_speedup": tpf_corpus / 1.0,
        "tokens_per_forward_speedup_self": tpf_self / 1.0,
        "wall_ratio_corpus_vs_off": t_off / max(t_corpus, 1e-9),
    }
    emit("draft/off", t_off * 1e6, "tpf=1.00")
    emit("draft/self", t_self * 1e6,
         f"tpf={tpf_self:.2f};acc={slf['stats'].accept_rate:.2f}")
    emit("draft/corpus", t_corpus * 1e6,
         f"tpf={tpf_corpus:.2f};acc={crp['stats'].accept_rate:.2f}")
    emit("draft/speedup", 0.0,
         f"tpf={record['tokens_per_forward_speedup']:.2f}x;"
         f"wall={record['wall_ratio_corpus_vs_off']:.2f}x")
    assert record["tokens_per_forward_speedup"] >= 1.5, \
        f"corpus drafting below 1.5x tokens/forward: {tpf_corpus:.2f}"
    with open(out_path, "w") as f:
        json.dump(record, f, indent=2)
    emit("draft/json", 0.0, out_path)
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="smaller batch and budget")
    ap.add_argument("--out", default=OUT_PATH)
    args = ap.parse_args()
    run(smoke=args.smoke, out_path=args.out)
