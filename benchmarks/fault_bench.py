"""Fault-recovery benchmark: price the §10 hardening against a clean run.

Serves the same request set through the slot engine twice — fault-free, then
under a seeded FaultPlan (nan quarantines + a stalled row tripping its
deadline) — and records the recovery overhead as
``recovery_efficiency_speedup`` = clean_time / faulted_time (≤ 1 by
construction: recovery costs retry admissions, never helps).  A third leg
measures exact kill-and-resume: the engine is killed mid-batch, snapshotted
through checkpoint/io, restored into a fresh engine and drained — the bench
asserts the resumed output is token-identical to the clean run and records
the snapshot/restore cost.  Writes BENCH_faults.json.

    PYTHONPATH=src python -m benchmarks.fault_bench [--smoke] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.io import load_server_state, save_server_state
from repro.data.tokenizer import VOCAB_SIZE
from repro.engine.generate import GenerateConfig
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.serving import (EngineKilled, FaultEvent, FaultPlan, Request,
                           SlotEngine, seeded_plan)

from .common import emit

OUT_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_faults.json")
SLOTS = 4
PROMPT_LEN = 16


def _setup(N, seed=0):
    cfg = ModelConfig(name="bench", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=VOCAB_SIZE,
                      max_seq_len=max(256, PROMPT_LEN + 2 * N))
    params = M.init_lm(jax.random.PRNGKey(seed), cfg)
    gen = GenerateConfig(max_new_tokens=N, eos_id=VOCAB_SIZE - 1)
    return cfg, params, gen


def _requests(n_requests, N, seed=0):
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(seed + 1), (n_requests, PROMPT_LEN), 3,
        VOCAB_SIZE - 1))
    keys = np.asarray(jax.vmap(
        lambda i: jax.random.fold_in(jax.random.PRNGKey(seed + 2), i))(
        jnp.arange(n_requests)))
    return [Request(request_id=i, prompt=prompts[i].astype(np.int32),
                    key=keys[i], max_new_tokens=N, max_retries=3)
            for i in range(n_requests)]


def _engine(cfg, params, gen, **kw):
    return SlotEngine(params, cfg, gen, num_slots=SLOTS,
                      prompt_width=PROMPT_LEN, **kw)


def _serve(cfg, params, gen, reqs, **kw):
    eng = _engine(cfg, params, gen, **kw)
    for r in reqs:
        eng.submit(r)
    t0 = time.perf_counter()
    resps = eng.run()
    return resps, time.perf_counter() - t0, eng.stats()


def run(smoke: bool = False, out_path: str = OUT_PATH) -> dict:
    N = 32 if smoke else 48
    n_requests = 12 if smoke else 24
    cfg, params, gen = _setup(N)

    _serve(cfg, params, gen, _requests(SLOTS, N, seed=7))   # compile warmup

    clean_out, t_clean, clean_st = _serve(cfg, params, gen,
                                          _requests(n_requests, N))

    # seeded chaos: nan quarantines + one stalled row; deadline generous
    # enough that only the stall trips it
    plan = seeded_plan(0, request_ids=range(n_requests), max_step=N,
                       n_nan=2, n_stall=1)
    targeted = plan.targeted_requests()
    fault_out, t_fault, fault_st = _serve(cfg, params, gen,
                                          _requests(n_requests, N),
                                          faults=plan, deadline_steps=8 * N)
    for i in range(n_requests):              # recovery is complete and exact
        assert fault_out[i].finish_reason in ("eos", "budget"), \
            (i, fault_out[i].finish_reason)
        if i not in targeted:
            np.testing.assert_array_equal(fault_out[i].tokens,
                                          clean_out[i].tokens)

    # exact kill-and-resume: die mid-batch, snapshot, restore, drain
    killed = _engine(cfg, params, gen,
                     faults=FaultPlan([FaultEvent("kill", at_step=N)]))
    for r in _requests(n_requests, N):
        killed.submit(r)
    t0 = time.perf_counter()
    try:
        killed.run()
        raise AssertionError("kill fault never fired")
    except EngineKilled:
        pass
    t_partial = time.perf_counter() - t0
    snap = out_path + ".resume_snap"
    t0 = time.perf_counter()
    save_server_state(snap, killed)
    t_save = time.perf_counter() - t0
    resumed = _engine(cfg, params, gen)
    t0 = time.perf_counter()
    load_server_state(snap, resumed)
    t_load = time.perf_counter() - t0
    t0 = time.perf_counter()
    resumed_out = resumed.run()
    t_resume = time.perf_counter() - t0
    for i in range(n_requests):              # §10 token-identity contract
        np.testing.assert_array_equal(resumed_out[i].tokens,
                                      clean_out[i].tokens)
    for ext in (".npz", ".json"):
        os.remove(snap + ext)

    tokens = int(clean_st["generated_tokens"])
    record = {
        "backend": jax.default_backend(),
        "slots": SLOTS, "requests": n_requests, "prompt_len": PROMPT_LEN,
        "max_new_tokens": N,
        "clean": {"time_s": t_clean, "tokens": tokens,
                  "tok_per_s": tokens / max(t_clean, 1e-9)},
        "faulted": {
            "time_s": t_fault,
            "tokens": int(fault_st["generated_tokens"]),
            "injected": int(fault_st["fault_injected"]),
            "nan_events": int(fault_st["fault_nan_events"]),
            "quarantines": int(fault_st["fault_quarantines"]),
            "timeouts": int(fault_st["timeouts"]),
            "retries": int(fault_st["retried_requests"]),
        },
        "kill_resume": {
            "killed_at_step": N,
            "partial_time_s": t_partial,
            "save_ms": t_save * 1e3,
            "load_ms": t_load * 1e3,
            "resume_time_s": t_resume,
            "token_identical": True,         # asserted above
        },
        # ≤ 1 by construction: the guard is that recovery stays CHEAP —
        # a collapse here means retries/quarantines went runaway
        "recovery_efficiency_speedup": t_clean / max(t_fault, 1e-9),
    }
    record["resume_efficiency_speedup"] = t_clean / max(
        t_partial + t_save + t_load + t_resume, 1e-9)
    emit("faults/clean", t_clean * 1e6, f"tok={tokens}")
    emit("faults/faulted", t_fault * 1e6,
         f"retries={record['faulted']['retries']};"
         f"quar={record['faulted']['quarantines']};"
         f"timeouts={record['faulted']['timeouts']}")
    emit("faults/kill_resume", (t_save + t_load) * 1e6,
         f"save_ms={record['kill_resume']['save_ms']:.1f};"
         f"load_ms={record['kill_resume']['load_ms']:.1f}")
    emit("faults/speedup", 0.0,
         f"recovery={record['recovery_efficiency_speedup']:.2f}x;"
         f"resume={record['resume_efficiency_speedup']:.2f}x")
    assert record["faulted"]["retries"] > 0, "the plan injected nothing"
    with open(out_path, "w") as f:
        json.dump(record, f, indent=2)
    emit("faults/json", 0.0, out_path)
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fewer requests, smaller budgets")
    ap.add_argument("--out", default=OUT_PATH)
    args = ap.parse_args()
    run(smoke=args.smoke, out_path=args.out)
