"""Paper Fig. 2: token overlap (ROUGE-1) between consecutive-epoch rollouts —
the redundancy SPEC-RL exploits.  Vanilla GRPO rollouts, same prompts across
epochs."""
from __future__ import annotations

import time

import numpy as np

from repro.core.metrics import batch_overlap, prefix_match_fraction

from .common import bench_dataset, emit, make_trainer

EPOCH_STEPS = 4


def run() -> None:
    ds = bench_dataset(8)
    tr = make_trainer("grpo", "off", dataset=ds, seed=11)
    t0 = time.perf_counter()
    # fixed batch every step == one "epoch" per step over the same prompts
    batch = ds.sample_batch(__import__("random").Random(0), 4, 2)
    prev = None
    overlaps, prefixes = [], []
    for step in range(EPOCH_STEPS):
        _, rb, _, _ = tr._collect(batch)
        cur = [rb.response[i, :rb.length[i]] for i in range(len(rb.length))]
        if prev is not None:
            overlaps.append(batch_overlap(prev, cur))
            prefixes.append(float(np.mean([
                prefix_match_fraction(p, c) for p, c in zip(prev, cur)])))
        prev = cur
        tr.train_step(batch)
    wall = (time.perf_counter() - t0) / EPOCH_STEPS
    emit("fig2/rouge1_overlap", wall * 1e6,
         f"mean={np.mean(overlaps):.3f};per_epoch="
         + "|".join(f"{o:.3f}" for o in overlaps))
    emit("fig2/prefix_match", wall * 1e6,
         f"mean={np.mean(prefixes):.3f}")


if __name__ == "__main__":
    run()
