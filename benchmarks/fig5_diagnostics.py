"""Paper Fig. 5: training-health diagnostics (entropy, approx-KL, clip
fraction) vs lenience — moderate lenience stays in the stable region, l=inf
drifts."""
from __future__ import annotations

import math

from .common import emit, make_trainer, run_steps

STEPS = 5


def run() -> None:
    for name, variant, l in (("baseline", "off", 1.0),
                             ("l=1", "spec", 1.0),
                             ("l=e0.5", "spec", math.e ** 0.5),
                             ("l=inf", "full", float("inf"))):
        r = run_steps(make_trainer("grpo", variant, lenience=l, seed=13),
                      STEPS)
        emit(f"fig5/{name}", r["wall_s"] / STEPS * 1e6,
             f"entropy={r['entropy']:.3f};kl={r['kl']:.5f};"
             f"clip_frac={r['clip_frac']:.5f}")


if __name__ == "__main__":
    run()
