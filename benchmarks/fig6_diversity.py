"""Paper Fig. 6: rollout diversity (Distinct-1 up / Self-BLEU down) —
SPEC-RL preserves batch diversity vs the GRPO baseline."""
from __future__ import annotations

import time

import numpy as np

from repro.core.metrics import distinct_n, self_bleu

from .common import bench_dataset, emit, make_trainer

STEPS = 4


def run() -> None:
    ds = bench_dataset(8)
    batch = ds.sample_batch(__import__("random").Random(1), 4, 2)
    for label, variant in (("baseline", "off"), ("spec_rl", "spec")):
        tr = make_trainer("grpo", variant, dataset=ds, seed=17)
        d1s, sbs = [], []
        t0 = time.perf_counter()
        for _ in range(STEPS):
            _, rb, _, _ = tr._collect(batch)
            rolls = [rb.response[i, :rb.length[i]]
                     for i in range(len(rb.length)) if rb.length[i] > 0]
            if rolls:
                d1s.append(distinct_n(rolls, 1))
                sbs.append(self_bleu(rolls))
            tr.train_step(batch)
        wall = (time.perf_counter() - t0) / STEPS
        emit(f"fig6/{label}", wall * 1e6,
             f"distinct1={np.mean(d1s):.3f};self_bleu={np.mean(sbs):.3f}")


if __name__ == "__main__":
    run()
