"""Kernel microbenchmarks: jit'd oracle paths (CPU wall-time) + interpret-mode
correctness spot checks.  On TPU the pallas impls replace the oracles."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.rwkv6_wkv.ops import wkv
from repro.kernels.spec_verify.ops import spec_verify

from .common import emit


def _time(fn, *args, iters=20, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run() -> None:
    B, T = 64, 1024
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    lp_c = jax.random.normal(ks[0], (B, T)) - 1
    lp_p = jax.random.normal(ks[1], (B, T)) - 1
    u = jax.random.uniform(ks[2], (B, T))
    vl = jax.random.randint(ks[3], (B,), 0, T).astype(jnp.int32)
    us = _time(spec_verify, lp_c, lp_p, u, vl, 0.5, impl="ref")
    emit("kernels/spec_verify_ref", us, f"B={B};T={T}")
    got = spec_verify(lp_c[:4, :256], lp_p[:4, :256], u[:4, :256],
                      jnp.minimum(vl[:4], 256), 0.5, impl="interpret")
    want = spec_verify(lp_c[:4, :256], lp_p[:4, :256], u[:4, :256],
                       jnp.minimum(vl[:4], 256), 0.5, impl="ref")
    assert (np.asarray(got) == np.asarray(want)).all()
    emit("kernels/spec_verify_interpret_check", 0.0, "allclose=True")

    q = jax.random.normal(ks[0], (2, 8, 256, 64))
    k = jax.random.normal(ks[1], (2, 2, 256, 64))
    v = jax.random.normal(ks[2], (2, 2, 256, 64))
    pos = jnp.broadcast_to(jnp.arange(256, dtype=jnp.int32), (2, 256))
    us = _time(flash_attention, q, k, v, pos, pos, impl="ref", iters=5)
    emit("kernels/flash_attention_ref", us, "B2H8T256D64;gqa4x")

    r = jax.random.normal(ks[0], (2, 256, 4, 32))
    kk = jax.random.normal(ks[1], (2, 256, 4, 32))
    vv = jax.random.normal(ks[2], (2, 256, 4, 32))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (2, 256, 4, 32)))
    uu = jax.random.normal(ks[0], (4, 32))
    s0 = jnp.zeros((2, 4, 32, 32))
    us = _time(wkv, r, kk, vv, w, uu, s0, impl="ref", iters=5)
    emit("kernels/rwkv6_wkv_ref", us, "B2T256H4hd32")


if __name__ == "__main__":
    run()
