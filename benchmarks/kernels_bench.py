"""Kernel microbenchmarks: jit'd oracle paths (CPU wall-time) + interpret-mode
correctness spot checks.  On TPU the pallas impls replace the oracles.

    PYTHONPATH=src python -m benchmarks.kernels_bench [--smoke]

--smoke shrinks shapes and iteration counts so CI can run the interpret-mode
checks in seconds."""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.cache_gather.ops import cache_roll
from repro.kernels.cache_gather.ref import cache_roll_ref
from repro.kernels.cache_slot_write.ops import cache_slot_write
from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.rwkv6_wkv.ops import wkv
from repro.kernels.spec_verify.ops import spec_verify

from .common import emit


def _time(fn, *args, iters=20, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run(smoke: bool = False) -> None:
    B, T = (8, 256) if smoke else (64, 1024)
    iters = 3 if smoke else 20
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    lp_c = jax.random.normal(ks[0], (B, T)) - 1
    lp_p = jax.random.normal(ks[1], (B, T)) - 1
    u = jax.random.uniform(ks[2], (B, T))
    vl = jax.random.randint(ks[3], (B,), 0, T).astype(jnp.int32)
    us = _time(spec_verify, lp_c, lp_p, u, vl, 0.5, impl="ref", iters=iters)
    emit("kernels/spec_verify_ref", us, f"B={B};T={T}")
    got = spec_verify(lp_c[:4, :256], lp_p[:4, :256], u[:4, :256],
                      jnp.minimum(vl[:4], 256), 0.5, impl="interpret")
    want = spec_verify(lp_c[:4, :256], lp_p[:4, :256], u[:4, :256],
                       jnp.minimum(vl[:4], 256), 0.5, impl="ref")
    assert (np.asarray(got) == np.asarray(want)).all()
    emit("kernels/spec_verify_interpret_check", 0.0, "allclose=True")

    # cache_gather: the SPEC-RL KV compaction roll (one-pass rollout path)
    R, S, D = (8, 64, 16) if smoke else (64, 512, 64)
    buf = jax.random.normal(ks[0], (R, S, D))
    shift = jax.random.randint(ks[1], (R,), 0, S + 1).astype(jnp.int32)
    us = _time(cache_roll, buf, shift, impl="ref", iters=iters)
    emit("kernels/cache_gather_ref", us, f"R={R};S={S};D={D}")
    got = cache_roll(buf[:4, :32], shift[:4] % 32, impl="interpret")
    want = cache_roll_ref(buf[:4, :32], shift[:4] % 32)
    assert (np.asarray(got) == np.asarray(want)).all()
    emit("kernels/cache_gather_interpret_check", 0.0, "allclose=True")

    # cache_slot_write: serving slot-admission scatter (DESIGN.md §6)
    src = jax.random.normal(ks[2], (R // 2, S, D))
    rows = jax.random.permutation(ks[3], R)[:R // 2].astype(jnp.int32)
    us = _time(cache_slot_write, buf, src, rows, impl="ref", iters=iters)
    emit("kernels/cache_slot_write_ref", us, f"Rd={R};Rs={R // 2};S={S};D={D}")
    got = cache_slot_write(buf[:6, :32], src[:3, :32], rows[:3] % 6,
                           impl="interpret")
    want = cache_slot_write(buf[:6, :32], src[:3, :32], rows[:3] % 6,
                            impl="ref")
    assert (np.asarray(got) == np.asarray(want)).all()
    emit("kernels/cache_slot_write_interpret_check", 0.0, "bit_exact=True")

    # decode_attention: split-K flash-decode with per-row live lengths
    DS = 64 if smoke else 512
    dq = jax.random.normal(ks[0], (4, 8, 1, 32))
    dk = jax.random.normal(ks[1], (4, 2, DS, 32))
    dv = jax.random.normal(ks[2], (4, 2, DS, 32))
    dlen = jnp.array([0, DS // 4, DS // 2, DS], jnp.int32)
    j = jnp.arange(DS, dtype=jnp.int32)
    dkpos = jnp.where(j[None, :] < dlen[:, None], j[None, :], -1)
    dqpos = jnp.maximum(dlen - 1, -1)
    us = _time(decode_attention, dq, dk, dv, dqpos, dkpos, dlen,
               impl="blocked", iters=iters)
    emit("kernels/decode_attention_blocked", us, f"B4Hq8Hkv2S{DS}D32")
    got = decode_attention(dq, dk, dv, dqpos, dkpos, dlen, impl="interpret",
                           block_k=32)
    want = decode_attention_ref(dq, dk, dv, dqpos, dkpos, dlen)
    assert np.allclose(np.asarray(got), np.asarray(want), atol=2e-5), \
        np.abs(np.asarray(got) - np.asarray(want)).max()
    emit("kernels/decode_attention_interpret_check", 0.0, "allclose=True")

    AT = 64 if smoke else 256
    q = jax.random.normal(ks[0], (2, 8, AT, 64))
    k = jax.random.normal(ks[1], (2, 2, AT, 64))
    v = jax.random.normal(ks[2], (2, 2, AT, 64))
    pos = jnp.broadcast_to(jnp.arange(AT, dtype=jnp.int32), (2, AT))
    us = _time(flash_attention, q, k, v, pos, pos, impl="ref", iters=3)
    emit("kernels/flash_attention_ref", us, f"B2H8T{AT}D64;gqa4x")

    WT = 64 if smoke else 256
    r = jax.random.normal(ks[0], (2, WT, 4, 32))
    kk = jax.random.normal(ks[1], (2, WT, 4, 32))
    vv = jax.random.normal(ks[2], (2, WT, 4, 32))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (2, WT, 4, 32)))
    uu = jax.random.normal(ks[0], (4, 32))
    s0 = jnp.zeros((2, 4, 32, 32))
    us = _time(wkv, r, kk, vv, w, uu, s0, impl="ref", iters=3)
    emit("kernels/rwkv6_wkv_ref", us, f"B2T{WT}H4hd32")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes + few iters (CI interpret-mode check)")
    run(smoke=ap.parse_args().smoke)
