"""Token-provenance ledger overhead benchmark (DESIGN.md §14): the slot
engine serves an identical speculative-prefix request set with the ledger
off and on, and the on-arm must stay within 3% wall-clock while recording a
full conserving provenance plane per request.  Writes BENCH_ledger.json.

The arms are interleaved A/B with min-of-k on both sides (same jit caches),
tokens are asserted bit-identical (the §14 zero-overhead contract), the
on-arm's provenance counts are asserted identical across repeats
(attribution is deterministic, not sampled), and the savings-attribution
report built from those counts must satisfy its own conservation law:
baseline - actual == seconds saved, with saved = counts x measured cost.
``ledger_off_vs_on_speedup`` (~1.0 by construction) is the
regression-guarded key.

    PYTHONPATH=src python -m benchmarks.ledger_bench [--smoke] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cache import RolloutCache
from repro.data.tokenizer import VOCAB_SIZE
from repro.engine.generate import GenerateConfig
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.obs.attrib import build_report, measured_token_cost
from repro.obs.ledger import TokenLedger
from repro.serving import Request, SlotEngine

from .common import emit

OUT_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_ledger.json")
SLOTS = 4
PROMPT_LEN = 16
MAX_OVERHEAD_PCT = 3.0


def _setup(N, seed=0):
    cfg = ModelConfig(name="bench", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=VOCAB_SIZE,
                      max_seq_len=max(256, PROMPT_LEN + 2 * N))
    params = M.init_lm(jax.random.PRNGKey(seed), cfg)
    gen = GenerateConfig(max_new_tokens=N, eos_id=VOCAB_SIZE - 1)
    return cfg, params, gen


def _requests(n_requests, N, seed=0):
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(seed + 1), (n_requests, PROMPT_LEN), 3,
        VOCAB_SIZE - 1))
    keys = np.asarray(jax.vmap(
        lambda i: jax.random.fold_in(jax.random.PRNGKey(seed + 2), i))(
        jnp.arange(n_requests)))
    return [Request(request_id=i, prompt=prompts[i].astype(np.int32),
                    key=keys[i], max_new_tokens=N)
            for i in range(n_requests)]


def _spec_requests(n_requests, N, drafts: RolloutCache):
    """The speculative arm's request set: pass-1 outputs as drafts,
    truncated to N//2 so the ledger has reused AND fresh provenance to
    account (full drafts from the same model verify clean end-to-end)."""
    reqs = _requests(n_requests, N)
    vkeys = np.asarray(jax.vmap(
        lambda i: jax.random.fold_in(jax.random.PRNGKey(11), i))(
        jnp.arange(n_requests)))
    for i, r in enumerate(reqs):
        e = drafts.get(r.request_id)
        r.verify_key = vkeys[i]
        half = min(N // 2, len(e.tokens))
        r.draft_tokens = e.tokens[:half]
        r.draft_logprobs = e.logprobs[:half]
        r.draft_eos = False
    return reqs


def _serve(cfg, params, gen, n_requests, N, ledger, drafts):
    eng = SlotEngine(params, cfg, gen, num_slots=SLOTS,
                     prompt_width=PROMPT_LEN, spec_prefix=True,
                     log_lenience=0.0, ledger=ledger)
    for r in _spec_requests(n_requests, N, drafts):
        eng.submit(r)
    t0 = time.perf_counter()
    resps = eng.run()
    dt = time.perf_counter() - t0
    toks = {i: (resps[i].tokens.tolist(), resps[i].n_accepted)
            for i in resps}
    return dt, toks, eng


def run(smoke: bool = False, out_path: str = OUT_PATH) -> dict:
    N = 32 if smoke else 64
    n_requests = 12 if smoke else 32
    repeats = 6 if smoke else 8
    cfg, params, gen = _setup(N)

    # pass 1 (vanilla) builds the drafts every timed arm reuses
    warm = SlotEngine(params, cfg, gen, num_slots=SLOTS,
                      prompt_width=PROMPT_LEN)
    for r in _requests(n_requests, N):
        warm.submit(r)
    drafts = RolloutCache()
    for i, resp in warm.run().items():
        drafts.put(i, resp.tokens, resp.logprobs, resp.length, step=0,
                   eos_id=gen.eos_id)

    _serve(cfg, params, gen, SLOTS, N, None, drafts)      # compile warmup

    t_off, t_on = [], []
    toks_off = toks_on = None
    counts_seen, last_on = [], None

    def _round(k):
        nonlocal toks_off, toks_on, last_on
        for _ in range(k):                                # interleaved A/B
            dt, toks_off, _ = _serve(cfg, params, gen, n_requests, N, None,
                                     drafts)
            t_off.append(dt)
            led = TokenLedger(enabled=True)
            dt, toks_on, eng = _serve(cfg, params, gen, n_requests, N, led,
                                      drafts)
            t_on.append(dt)
            counts_seen.append(led.counts_dict())
            last_on = (led, eng, dt)

    _round(repeats)
    # noisy shared-CPU runners: extend before asserting on one sample
    for _ in range(2):
        if min(t_on) / min(t_off) - 1.0 < MAX_OVERHEAD_PCT / 100.0:
            break
        _round(repeats)

    assert toks_on == toks_off, "ledger-on serving changed the tokens"
    assert all(c == counts_seen[0] for c in counts_seen), \
        "provenance counts differ across identical runs"
    led, eng, dt_last = last_on
    assert led.violations == 0 and led.finalized == n_requests
    counts = led.counts_dict()
    assert counts["reused_prefix"] > 0, "spec arm reused nothing"
    assert counts["fresh"] > 0

    # attribution conservation: saved == counts x cost == baseline - actual
    regd = eng.metrics_registry().as_dict()
    t_tok = measured_token_cost(regd)
    assert t_tok is not None and t_tok > 0
    rep = build_report(led, t_tok, actual_s=dt_last)
    assert abs((rep.baseline_s - rep.actual_s) - rep.total_saved_s) \
        < 1e-9 * max(1.0, rep.baseline_s)
    assert rep.saved_s["spec_prefix"] == \
        counts["reused_prefix"] * t_tok

    best_off, best_on = min(t_off), min(t_on)
    overhead_pct = (best_on / best_off - 1.0) * 100.0
    record = {
        "backend": jax.default_backend(),
        "slots": SLOTS, "requests": n_requests, "max_new_tokens": N,
        "repeats": repeats,
        "ledger_off": {"time_s": best_off, "all_times_s": t_off},
        "ledger_on": {"time_s": best_on, "all_times_s": t_on,
                      "counts": counts,
                      "finalized": led.finalized},
        "attribution": rep.as_dict(),
        "overhead_pct": overhead_pct,
        "ledger_off_vs_on_speedup": best_off / best_on,
    }
    emit("ledger/off", best_off * 1e6, f"reqs={n_requests}")
    emit("ledger/on", best_on * 1e6,
         f"reused={counts['reused_prefix']};overhead={overhead_pct:.2f}%")
    emit("ledger/saved_s", rep.total_saved_s * 1e6,
         f"speedup={rep.as_dict()['attrib.speedup']:.2f}x")
    assert overhead_pct < MAX_OVERHEAD_PCT, \
        f"ledger overhead {overhead_pct:.2f}% exceeds {MAX_OVERHEAD_PCT}%"
    with open(out_path, "w") as f:
        json.dump(record, f, indent=2)
    emit("ledger/json", 0.0, out_path)
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fewer requests, smaller budgets")
    ap.add_argument("--out", default=OUT_PATH)
    args = ap.parse_args()
    run(smoke=args.smoke, out_path=args.out)
