"""Regenerate experiments/dryrun_table.md from experiments/dryrun/*.json."""
import glob, json, os

OUT = os.path.join(os.path.dirname(__file__), "..", "experiments",
                   "dryrun_table.md")


def run():
    rows = []
    for p in sorted(glob.glob(os.path.join(os.path.dirname(OUT), "dryrun",
                                           "*.json"))):
        r = json.load(open(p))
        if r.get("tag", "baseline") != "baseline":
            continue
        rows.append(r)

    def fmt(r):
        if r["status"] == "skipped":
            return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | SKIP | — |"
                    f" — | — | {r['reason'][:58]} |")
        cb = r["collective_bytes_per_device"]
        dom = max(cb, key=cb.get) if cb else "-"
        return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
                f"{r['hbm_gib_per_device']:.1f} | "
                f"{r['dot_flops_per_device']:.2e} | "
                f"{r['collective_bytes_total_per_device']:.2e} ({dom}) | "
                f"compile {r['compile_s']}s |")

    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    lines = [
        "# Dry-run results (baseline; per-device numbers from the "
        "SPMD-partitioned HLO)",
        "",
        "| arch | shape | mesh | status | HBM GiB/dev | HLO FLOPs/dev | "
        "collective B/dev (dominant op) | notes |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rows, key=lambda r: (r["arch"], order[r["shape"]],
                                         r["mesh"])):
        lines.append(fmt(r))
    with open(OUT, "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"wrote {OUT} ({len(rows)} rows)")


def run_comparison():
    """experiments/optimized_table.md: baseline vs optimized per pair."""
    import collections
    base, opt = {}, {}
    for p in glob.glob(os.path.join(os.path.dirname(OUT), "dryrun",
                                    "*.json")):
        r = json.load(open(p))
        if r.get("mesh") != "pod" or r.get("status") != "ok":
            continue
        key = (r["arch"], r["shape"])
        if r.get("tag") == "baseline":
            base[key] = r
        elif r.get("tag") == "optimized":
            opt[key] = r
    lines = [
        "# Baseline vs optimized (single-pod; levers: ZeRO opt sharding, "
        "donation, chunked CE/scoring, blocked attention, KV head-dim "
        "sharding)",
        "",
        "| arch | shape | HBM GiB/dev base → opt | Δ | collective B/dev "
        "base → opt |",
        "|---|---|---|---|---|",
    ]
    for key in sorted(base):
        if key not in opt:
            continue
        b, o = base[key], opt[key]
        hb, ho = b["hbm_gib_per_device"], o["hbm_gib_per_device"]
        cb = b["collective_bytes_total_per_device"]
        co = o["collective_bytes_total_per_device"]
        lines.append(
            f"| {key[0]} | {key[1]} | {hb:.1f} → {ho:.1f} | "
            f"{(1 - ho / hb) * 100:+.0f}% | {cb:.2e} → {co:.2e} |")
    out = os.path.join(os.path.dirname(OUT), "optimized_table.md")
    with open(out, "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"wrote {out}")


if __name__ == "__main__":
    import sys
    run()
    if "--compare" in sys.argv:
        run_comparison()
