"""Mesh weak-scaling benchmark: data-parallel SPEC-RL rollout throughput.

Runs the one-pass speculative rollout (warm draft cache, so verify →
compact → resume all execute) at a fixed per-shard batch over growing
``data`` axis sizes and records tokens/second and scaling efficiency vs the
single-device run into ``BENCH_mesh.json``.  The d = 2 point is additionally
asserted token-identical to the single-device rollout over the same global
batch — the §8 identity contract, re-proven where the numbers are recorded.

Virtual CPU devices (``--xla_force_host_platform_device_count``) share one
physical CPU, so CPU "scaling" mostly measures partitioning overhead; the
shape of the curve (and the recorded collective layout) is what transfers
to real multi-chip meshes.  The env var is set before jax imports — run as
a module, not via an already-jax-initialised interpreter:

    PYTHONPATH=src python -m benchmarks.mesh_bench --smoke --out BENCH_mesh.json
"""
from __future__ import annotations

import argparse
import json
import os
import time

OUT_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_mesh.json")


def _ensure_virtual_devices(n: int) -> None:
    """Append the device-count flag BEFORE jax initialises (a later
    os.environ mutation silently no-ops once the backend exists)."""
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + f" --xla_force_host_platform_device_count={n}").strip()
    os.environ.setdefault("JAX_PLATFORMS", "cpu")


def run(smoke: bool = False, out_path: str = OUT_PATH,
        max_data: int = 8) -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import RolloutCache, SpecConfig, rollout
    from repro.data.tokenizer import VOCAB_SIZE
    from repro.distributed.mesh import MeshConfig, shard_params
    from repro.engine.generate import GenerateConfig
    from repro.models import model as M
    from repro.models.config import ModelConfig

    from .common import emit

    B_shard = 4 if smoke else 8
    P = 16
    N = 24 if smoke else 48
    iters = 2 if smoke else 5
    cfg = ModelConfig(name="mesh-bench", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=VOCAB_SIZE, max_seq_len=max(256, P + 2 * N))
    params = M.init_lm(jax.random.PRNGKey(0), cfg)
    gen = GenerateConfig(max_new_tokens=N, eos_id=VOCAB_SIZE - 1)
    spec = SpecConfig(variant="spec")

    ndev = jax.device_count()
    data_points = [d for d in (1, 2, 4, 8) if d <= min(max_data, ndev)]

    def batch(B, seed=1):
        prompts = jax.random.randint(jax.random.PRNGKey(seed), (B, P), 3,
                                     VOCAB_SIZE - 1)
        mask = jnp.ones((B, P), bool)
        keys = jax.vmap(lambda i: jax.random.fold_in(
            jax.random.PRNGKey(seed + 1), i))(jnp.arange(B))
        return prompts, mask, keys

    def warm_cache(p, B, mesh):
        """Vanilla step 0 fills the draft cache (untimed compile warmup for
        both engine paths rides along)."""
        prompts, mask, keys = batch(B)
        cache = RolloutCache()
        rollout(p, cfg, gen, spec, prompts, mask, list(range(B)), cache,
                jax.vmap(lambda k: jax.random.fold_in(k, 0))(keys), 0,
                mesh=mesh)
        return prompts, mask, keys, cache

    def spec_step(p, B, mesh, prompts, mask, keys, cache, step: int):
        """One warm one-pass speculative step against the evolving cache."""
        return rollout(p, cfg, gen, spec, prompts, mask, list(range(B)),
                       cache,
                       jax.vmap(lambda k: jax.random.fold_in(k, step))(keys),
                       step, mesh=mesh)

    points = []
    base_tok_s = None
    for d in data_points:
        B = B_shard * d
        mesh = MeshConfig(data=d, model=1).build() if d > 1 else None
        p = shard_params(mesh, cfg, params) if mesh is not None else params
        args = warm_cache(p, B, mesh)
        spec_step(p, B, mesh, *args, 1)             # spec-path compile warmup
        # timed region covers ONLY speculative steps — the served tokens
        # (generated + reused) below are produced inside this window
        t0 = time.perf_counter()
        tokens = 0
        for it in range(iters):
            rb = spec_step(p, B, mesh, *args, 2 + it)
            tokens += int(rb.metrics["n_generated"] + rb.metrics["n_reused"])
        dt = time.perf_counter() - t0
        tok_s = tokens / max(dt, 1e-9)
        if base_tok_s is None:
            base_tok_s = tok_s
        pt = {"data": d, "model": 1, "B": B, "time_s": dt, "tokens": tokens,
              "tok_per_s": tok_s, "throughput_vs_1dev": tok_s / base_tok_s,
              "efficiency": tok_s / base_tok_s / d}
        points.append(pt)
        emit(f"mesh/rollout_d{d}", dt * 1e6,
             f"B={B};tok_s={tok_s:.0f};scale={pt['throughput_vs_1dev']:.2f}x")

    # §8 identity: sharded rollout == single-device rollout, same global batch
    identity = False
    if len(data_points) > 1:
        d = data_points[1]
        B = B_shard * d
        mesh = MeshConfig(data=d, model=1).build()
        sp = shard_params(mesh, cfg, params)
        rb_ref = spec_step(params, B, None, *warm_cache(params, B, None), 99)
        rb_mesh = spec_step(sp, B, mesh, *warm_cache(sp, B, mesh), 99)
        np.testing.assert_array_equal(rb_ref.response, rb_mesh.response)
        np.testing.assert_array_equal(rb_ref.length, rb_mesh.length)
        identity = True
        emit("mesh/identity", 0.0, f"d={d};token-identical=True")

    record = {
        "backend": jax.default_backend(),
        "devices": ndev,
        "B_per_shard": B_shard, "P": P, "N": N, "iters": iters,
        "variant": "spec(one-pass)",
        "points": points,
        "identity_checked": identity,
    }
    with open(out_path, "w") as f:
        json.dump(record, f, indent=2)
    emit("mesh/json", 0.0, out_path)
    return record


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="smaller batch/budget (CI lane)")
    ap.add_argument("--out", default=OUT_PATH)
    ap.add_argument("--devices", type=int, default=8,
                    help="virtual device count to request if jax is not "
                         "yet initialised and XLA_FLAGS does not set one")
    ap.add_argument("--max-data", type=int, default=8)
    args = ap.parse_args(argv)
    _ensure_virtual_devices(args.devices)
    run(smoke=args.smoke, out_path=args.out, max_data=args.max_data)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
