"""Observability overhead benchmark (DESIGN.md §11): the slot engine serves
an identical request set untraced and fully traced (sample_rate=1.0,
metrics + per-request span lanes) and the traced arm must stay within 3%
wall-clock.  Writes BENCH_obs.json.

The arms are interleaved A/B and each takes its min-of-k, so the ratio
compares best-case against best-case under the same jit caches; tokens are
asserted bit-identical between arms (the §11 zero-overhead contract, here
measured rather than lowered-HLO-checked).  ``traced_vs_untraced_speedup``
(~1.0 by construction) is the regression-guarded key: a collapse means
instrumentation started doing real work on the hot path.

    PYTHONPATH=src python -m benchmarks.obs_bench [--smoke] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.tokenizer import VOCAB_SIZE
from repro.engine.generate import GenerateConfig
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.obs import Tracer
from repro.obs.export import chrome_trace, prometheus_text
from repro.serving import Request, SlotEngine

from .common import emit

OUT_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_obs.json")
SLOTS = 4
PROMPT_LEN = 16
MAX_OVERHEAD_PCT = 3.0


def _setup(N, seed=0):
    cfg = ModelConfig(name="bench", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=VOCAB_SIZE,
                      max_seq_len=max(256, PROMPT_LEN + 2 * N))
    params = M.init_lm(jax.random.PRNGKey(seed), cfg)
    gen = GenerateConfig(max_new_tokens=N, eos_id=VOCAB_SIZE - 1)
    return cfg, params, gen


def _requests(n_requests, N, seed=0):
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(seed + 1), (n_requests, PROMPT_LEN), 3,
        VOCAB_SIZE - 1))
    keys = np.asarray(jax.vmap(
        lambda i: jax.random.fold_in(jax.random.PRNGKey(seed + 2), i))(
        jnp.arange(n_requests)))
    return [Request(request_id=i, prompt=prompts[i].astype(np.int32),
                    key=keys[i], max_new_tokens=N)
            for i in range(n_requests)]


def _serve(cfg, params, gen, n_requests, N, tracer):
    eng = SlotEngine(params, cfg, gen, num_slots=SLOTS,
                     prompt_width=PROMPT_LEN, tracer=tracer)
    for r in _requests(n_requests, N):
        eng.submit(r)
    t0 = time.perf_counter()
    resps = eng.run()
    dt = time.perf_counter() - t0
    toks = {i: resps[i].tokens.tolist() for i in resps}
    return dt, toks, eng


def run(smoke: bool = False, out_path: str = OUT_PATH) -> dict:
    N = 32 if smoke else 64
    n_requests = 12 if smoke else 32
    # min-of-k of two identically-floored arms: k must be large enough to
    # reach the floor on both sides, or scheduler noise masquerades as
    # overhead at this (~100 ms/run) scale
    repeats = 6 if smoke else 8
    cfg, params, gen = _setup(N)

    _serve(cfg, params, gen, SLOTS, N, None)             # compile warmup

    t_off, t_on = [], []
    toks_off = toks_on = None
    last_traced = None

    def _round(k):
        nonlocal toks_off, toks_on, last_traced
        for _ in range(k):                               # interleaved A/B
            dt, toks_off, _ = _serve(cfg, params, gen, n_requests, N, None)
            t_off.append(dt)
            tracer = Tracer(enabled=True, sample_rate=1.0)
            dt, toks_on, eng = _serve(cfg, params, gen, n_requests, N, tracer)
            t_on.append(dt)
            last_traced = (tracer, eng)

    _round(repeats)
    # noisy shared-CPU runners: if either arm's min hasn't converged the
    # ratio can read a few % high; extend rather than assert on one sample
    for _ in range(2):
        if min(t_on) / min(t_off) - 1.0 < MAX_OVERHEAD_PCT / 100.0:
            break
        _round(repeats)

    assert toks_on == toks_off, "traced serving changed the tokens"
    tracer, eng = last_traced
    n_spans = len(tracer.spans)
    assert any(t.startswith("req/") for t in tracer.tracks())

    t0 = time.perf_counter()
    doc = chrome_trace(tracer)
    text = prometheus_text(eng.metrics_registry())
    t_export = time.perf_counter() - t0

    best_off, best_on = min(t_off), min(t_on)
    overhead_pct = (best_on / best_off - 1.0) * 100.0
    record = {
        "backend": jax.default_backend(),
        "slots": SLOTS, "requests": n_requests, "max_new_tokens": N,
        "repeats": repeats,
        "untraced": {"time_s": best_off, "all_times_s": t_off},
        "traced": {"time_s": best_on, "all_times_s": t_on,
                   "spans": n_spans, "trace_events": len(doc["traceEvents"]),
                   "prom_lines": text.count("\n"),
                   "export_time_s": t_export},
        "overhead_pct": overhead_pct,
        "traced_vs_untraced_speedup": best_off / best_on,
    }
    emit("obs/untraced", best_off * 1e6, f"reqs={n_requests}")
    emit("obs/traced", best_on * 1e6,
         f"spans={n_spans};overhead={overhead_pct:.2f}%")
    emit("obs/export", t_export * 1e6,
         f"events={len(doc['traceEvents'])}")
    assert overhead_pct < MAX_OVERHEAD_PCT, \
        f"traced overhead {overhead_pct:.2f}% exceeds {MAX_OVERHEAD_PCT}%"
    with open(out_path, "w") as f:
        json.dump(record, f, indent=2)
    emit("obs/json", 0.0, out_path)
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fewer requests, smaller budgets")
    ap.add_argument("--out", default=OUT_PATH)
    args = ap.parse_args()
    run(smoke=args.smoke, out_path=args.out)
