"""Paged-KV memory benchmark: block pool + CoW GRPO prompt sharing (§13).

Unlike the wall-clock benches, the paged layout's claims are MEMORY
ACCOUNTING identities, so the guarded ratios are deterministic — exact
block counts from the allocator, not timing:

* ``resident_batch_speedup`` — blocks a dense layout pins for the resident
  GRPO batch (every row owns a full ``cache_len`` stripe) over the paged
  pool's peak occupancy for the same batch.  At fixed HBM this is how many
  MORE resident rows the paged engine can table.
* ``prompt_copies_speedup`` — physical prompt copies per GRPO group: dense
  writes one per sibling (G), paged registers exactly one (the §13
  acceptance invariant, asserted here before it is ratio'd).

Token identity with the dense engine is asserted on the same workload, so
the record can never trade correctness for the ratio.

    PYTHONPATH=src python -m benchmarks.paged_bench [--smoke] [--out PATH]
"""
from __future__ import annotations

import argparse
import copy
import json
import os

import jax
import numpy as np

from repro.data.tokenizer import VOCAB_SIZE
from repro.engine.generate import GenerateConfig
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.serving import Request, make_slot_engine

from .common import emit

OUT_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_paged.json")
BS = 8                              # kv block size


def _setup(P, N):
    cfg = ModelConfig(name="bench", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=VOCAB_SIZE,
                      max_seq_len=max(256, 2 * (P + N)))
    params = M.init_lm(jax.random.PRNGKey(0), cfg)
    gen = GenerateConfig(max_new_tokens=N, temperature=0.7,
                         eos_id=VOCAB_SIZE - 1)
    return cfg, params, gen


def _grpo_requests(groups, siblings, P, N, seed=0):
    """Prompt-heavy GRPO workload: long shared prompts, short rollouts —
    the regime the paper's G-sibling groups put rollout memory in."""
    rng = np.random.RandomState(seed)
    reqs, rid = [], 0
    for g in range(groups):
        L = int(rng.randint(P - BS + 1, P + 1))
        prompt = rng.randint(3, VOCAB_SIZE - 1, size=L).astype(np.int32)
        for _ in range(siblings):
            key = np.asarray(jax.random.PRNGKey(1000 + rid), np.uint32)
            reqs.append(Request(request_id=rid, prompt=prompt.copy(),
                                key=key, max_new_tokens=N, group_id=g))
            rid += 1
    return reqs


def _serve(params, cfg, gen, reqs, num_slots, P):
    eng = make_slot_engine(params, cfg, gen, num_slots=num_slots,
                           prompt_width=P)
    for r in reqs:
        eng.submit(copy.deepcopy(r))
    resps = eng.run()
    return eng, resps


def run(smoke: bool = False, out_path: str = OUT_PATH) -> dict:
    groups = 2 if smoke else 4
    siblings = 8 if smoke else 16
    P = 48 if smoke else 96
    N = 8 if smoke else 16
    num_slots = groups * siblings           # whole batch resident at peak

    cfg, params, gen = _setup(P, N)
    cfg_p = cfg.replace(cache_layout="paged", kv_block_size=BS)
    reqs = _grpo_requests(groups, siblings, P, N)

    eng_d, dense = _serve(params, cfg, gen, reqs, num_slots, P)
    eng_p, paged = _serve(params, cfg_p, gen, reqs, num_slots, P)

    # correctness floor: the record is only worth guarding if paged serving
    # is still token-identical to dense on this exact workload
    assert sorted(paged) == sorted(dense)
    for i in dense:
        np.testing.assert_array_equal(paged[i].tokens, dense[i].tokens)

    a = eng_p.allocator
    nb, pb, bs = eng_p.nb, eng_p._pb, cfg_p.kv_block_size
    # dense pins cache_len (= nb blocks' worth) per resident row; the paged
    # pool's PEAK is what the same batch actually addressed (sink excluded)
    dense_blocks = num_slots * nb
    paged_blocks = int(a.peak_blocks_in_use)
    resident_speedup = dense_blocks / paged_blocks

    # §13 acceptance: exactly ONE physical prompt copy per group was
    # ever registered (every sibling admission counted its saved blocks)
    saved_blocks = a.shared_prompt_bytes_saved // max(eng_p._block_bytes, 1)
    assert saved_blocks == groups * (siblings - 1) * pb, \
        (saved_blocks, groups, siblings, pb)
    prompt_copies_dense = siblings          # one per sibling row
    prompt_copies_paged = 1                 # the registered shared copy
    prompt_speedup = prompt_copies_dense / prompt_copies_paged

    record = {
        "backend": jax.default_backend(),
        "groups": groups, "siblings": siblings, "prompt_len": P,
        "max_new_tokens": N, "kv_block_size": bs,
        "blocks_per_row": nb, "prompt_blocks": pb,
        "dense": {"resident_blocks": dense_blocks,
                  "prompt_copies_per_group": prompt_copies_dense},
        "paged": {"peak_blocks": paged_blocks,
                  "prompt_copies_per_group": prompt_copies_paged,
                  "cow_forks": int(a.cow_forks),
                  "alloc_failures": int(a.alloc_failures),
                  "shared_prompt_bytes_saved":
                      int(a.shared_prompt_bytes_saved)},
        "resident_batch_speedup": resident_speedup,
        "prompt_copies_speedup": prompt_speedup,
    }
    emit("paged/resident_blocks", 0.0,
         f"dense={dense_blocks};paged_peak={paged_blocks};"
         f"speedup={resident_speedup:.2f}x")
    emit("paged/prompt_copies", 0.0,
         f"dense={prompt_copies_dense};paged={prompt_copies_paged};"
         f"speedup={prompt_speedup:.2f}x")
    emit("paged/sharing", 0.0,
         f"cow_forks={a.cow_forks};"
         f"bytes_saved={a.shared_prompt_bytes_saved}")
    assert resident_speedup > 1.2, \
        f"paged layout not saving memory: {resident_speedup:.2f}x"
    with open(out_path, "w") as f:
        json.dump(record, f, indent=2)
    emit("paged/json", 0.0, out_path)
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fewer groups, shorter prompts")
    ap.add_argument("--out", default=OUT_PATH)
    args = ap.parse_args()
    run(smoke=args.smoke, out_path=args.out)
