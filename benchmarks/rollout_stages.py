"""Rollout-stage microbenchmark: verify / compact(prefill) / decode wall-time
split for the one-pass vs two-pass speculative engine paths, plus the
no-second-prefill op-count assertion.  Writes BENCH_rollout.json so future
PRs have a perf trajectory to regress against.

    PYTHONPATH=src python -m benchmarks.rollout_stages [--smoke] [--out PATH]
"""
from __future__ import annotations

import argparse
import copy
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import RolloutCache, SpecConfig, rollout
from repro.data.tokenizer import VOCAB_SIZE
from repro.engine.generate import GenerateConfig
from repro.models import model as M
from repro.models.config import ModelConfig

from .common import emit

SIZES = [(4, 8, 16), (8, 16, 32), (4, 32, 64)]          # (B, P, N)
STAGES = ("verify_time", "compact_time", "decode_time", "assembly_time")
OUT_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_rollout.json")


def _setup(B, P, N, seed=0):
    cfg = ModelConfig(name="bench", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=VOCAB_SIZE,
                      max_seq_len=max(256, P + 2 * N))
    params = M.init_lm(jax.random.PRNGKey(seed), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(seed + 1), (B, P), 3,
                                VOCAB_SIZE)
    mask = jnp.ones((B, P), bool)
    gen = GenerateConfig(max_new_tokens=N, eos_id=VOCAB_SIZE - 1)
    return cfg, params, prompt, mask, gen


def _spec(one_pass: str) -> SpecConfig:
    # lenience < 1 with an unchanged policy gives per-token accept prob == l,
    # i.e. a realistic partial-acceptance mix of reused and regenerated
    # tokens (full acceptance would degenerate decode_time to ~0).
    return SpecConfig(variant="spec", lenience=0.8, verify_impl="ref",
                      one_pass=one_pass, compact_impl="ref")


def _time_path(cfg, params, prompt, mask, gen, cache, one_pass: str,
               iters: int):
    """Mean per-stage seconds over ``iters`` warm rollout steps.

    Each iteration re-verifies the same drafts (fresh cache copy) so the
    accepted-prefix length — and therefore the work — is held constant."""
    ids = list(range(prompt.shape[0]))
    spec = _spec(one_pass)
    acc = {s: 0.0 for s in STAGES}
    reused = generated = 0
    rollout(params, cfg, gen, spec, prompt, mask, ids, copy.deepcopy(cache),
            jax.random.PRNGKey(2), 1)            # jit warmup
    for i in range(iters):
        rb = rollout(params, cfg, gen, spec, prompt, mask, ids,
                     copy.deepcopy(cache), jax.random.PRNGKey(2), 1)
        for s in STAGES:
            acc[s] += rb.metrics[s]
        reused += rb.metrics["n_reused"]
        generated += rb.metrics["n_generated"]
    out = {s: acc[s] / iters for s in STAGES}
    out["total"] = sum(out.values())
    out["n_reused"] = reused / iters
    out["n_generated"] = generated / iters
    return out


def _assert_single_prefill(cfg, params, prompt, mask):
    """Op-count proof that one-pass forwards prompt ⊕ prefix exactly once."""
    ids = list(range(prompt.shape[0]))
    small = GenerateConfig(max_new_tokens=4)
    cache = RolloutCache()
    rollout(params, cfg, small, _spec("off"), prompt, mask, ids, cache,
            jax.random.PRNGKey(0), 0)           # seed drafts
    with jax.disable_jit():
        M.reset_op_counts()
        rollout(params, cfg, small, _spec("on"), prompt, mask, ids,
                copy.deepcopy(cache), jax.random.PRNGKey(2), 1)
        assert M.OP_COUNTS["prefill"] == 1, M.OP_COUNTS
        assert M.OP_COUNTS["forward"] == 0, M.OP_COUNTS
        one = dict(M.OP_COUNTS)
        M.reset_op_counts()
        rollout(params, cfg, small, _spec("off"), prompt, mask, ids,
                copy.deepcopy(cache), jax.random.PRNGKey(2), 1)
        assert M.OP_COUNTS["prefill"] + M.OP_COUNTS["forward"] == 2, M.OP_COUNTS
    emit("rollout_stages/op_count", 0.0,
         f"one_pass_prefill={one['prefill']};one_pass_forward={one['forward']}")


def run(smoke: bool = False, out_path: str = OUT_PATH) -> None:
    sizes = SIZES[:1] if smoke else SIZES
    iters = 2 if smoke else 5
    record = {"backend": jax.default_backend(), "iters": iters, "sizes": []}
    for B, P, N in sizes:
        cfg, params, prompt, mask, gen = _setup(B, P, N)
        cache = RolloutCache()
        rollout(params, cfg, gen, _spec("off"), prompt, mask,
                list(range(B)), cache, jax.random.PRNGKey(0), 0)  # seed drafts
        row = {"B": B, "P": P, "N": N}
        for label, flag in (("one_pass", "on"), ("two_pass", "off")):
            t = _time_path(cfg, params, prompt, mask, gen, cache, flag, iters)
            row[label] = t
            emit(f"rollout_stages/{label}", t["total"] * 1e6,
                 f"B={B};P={P};N={N};" + ";".join(
                     f"{s.replace('_time','')}={t[s]*1e3:.2f}ms"
                     for s in STAGES) + f";reused={t['n_reused']:.1f}")
        row["speedup_total"] = row["two_pass"]["total"] / max(
            row["one_pass"]["total"], 1e-9)
        record["sizes"].append(row)
    _assert_single_prefill(*_setup(*sizes[0])[:4])
    with open(out_path, "w") as f:
        json.dump(record, f, indent=2)
    emit("rollout_stages/json", 0.0, out_path)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="smallest size only, 2 iters")
    ap.add_argument("--out", default=OUT_PATH)
    args = ap.parse_args()
    run(smoke=args.smoke, out_path=args.out)
