"""Roofline analysis (deliverable g).

Reads ``experiments/dryrun/*.json`` and derives, per (arch x shape x mesh):

  compute term    = HLO_FLOPs / (chips x 197 TFLOP/s bf16)
  memory term     = HLO_bytes / (chips x 819 GB/s HBM)
  collective term = collective_bytes / (chips x 50 GB/s/link)

plus MODEL_FLOPS = 6*N*D (6*N_active*D for MoE) and the useful-compute ratio
MODEL_FLOPS / HLO_FLOPs.  Emits the CSV rows and writes
``experiments/roofline.md`` (the §Roofline table)."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.launch.specs import INPUT_SHAPES

try:
    from .common import emit
except ImportError:                      # direct module execution
    def emit(name, us, derived=""):
        print(f"{name},{us:.1f},{derived}")

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")
OUT_MD = os.path.join(os.path.dirname(__file__), "..", "experiments",
                      "roofline.md")


def active_params(arch: str) -> float:
    """N (dense) or N_active (MoE: shared + top-k routed + attn/norm)."""
    cfg = get_config(arch)
    from repro.models import model as M
    struct = jax.eval_shape(lambda: M.init_lm(jax.random.PRNGKey(0), cfg))
    total = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(struct))
    if not cfg.num_experts:
        return float(total)
    # subtract inactive routed experts
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    ff, d = cfg.resolved_moe_d_ff, cfg.d_model
    n_moe_layers = sum(1 for _, moe in cfg.layer_plan() if moe)
    per_expert = 3 * d * ff
    inactive = n_moe_layers * (E - k) * per_expert
    return float(total - inactive)


def model_flops(arch: str, shape_name: str) -> float:
    info = INPUT_SHAPES[shape_name]
    D = info["seq_len"] * info["global_batch"] if info["kind"] != "decode" \
        else info["global_batch"]
    n = active_params(arch)
    mult = 6.0 if info["kind"] == "train" else 2.0   # fwd-only for serving
    return mult * n * D


def dominant(terms: Dict[str, float]) -> str:
    return max(terms, key=terms.get)


def load_results(mesh: str = "pod", tag: Optional[str] = None) -> List[Dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("mesh") != mesh:
            continue
        if tag is not None and r.get("tag", "baseline") != tag:
            continue
        out.append(r)
    return out


def roofline_row(r: Dict) -> Optional[Dict]:
    if r.get("status") != "ok":
        return None
    chips = r["num_devices"]
    flops_dev = r["dot_flops_per_device"]
    bytes_dev = r.get("hlo_bytes_per_device", r["xla_bytes_per_device"])
    coll_dev = r["collective_bytes_total_per_device"]
    t_compute = flops_dev / PEAK_FLOPS_BF16
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    mf = model_flops(r["arch"], r["shape"])
    hlo_total = flops_dev * chips
    return {
        "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
        "tag": r.get("tag", "baseline"),
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant(terms),
        "model_flops": mf, "hlo_flops": hlo_total,
        "useful_ratio": mf / hlo_total if hlo_total else 0.0,
        "hbm_gib": r.get("hbm_gib_per_device", 0.0),
        "fits_hbm": r.get("hbm_gib_per_device", 0.0) <= 16.0,
    }


def run(mesh: str = "pod", tag: str = "baseline") -> List[Dict]:
    rows = []
    for r in load_results(mesh, tag):
        row = roofline_row(r)
        if row is None:
            continue
        rows.append(row)
        emit(f"roofline/{row['arch']}/{row['shape']}",
             max(row["t_compute_s"], row["t_memory_s"],
                 row["t_collective_s"]) * 1e6,
             f"compute={row['t_compute_s']:.3e}s;"
             f"memory={row['t_memory_s']:.3e}s;"
             f"collective={row['t_collective_s']:.3e}s;"
             f"dominant={row['dominant']};"
             f"useful={row['useful_ratio']:.2f};"
             f"hbm={row['hbm_gib']:.1f}GiB")
    if tag == "baseline":
        write_md(rows)
    return rows


def write_md(rows: List[Dict]) -> None:
    lines = [
        "# Roofline (single-pod 16x16 = 256 chips, TPU v5e: "
        "197 TF bf16 / 819 GB/s HBM / 50 GB/s ICI)",
        "",
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "MODEL/HLO flops | HBM GiB/dev | fits 16G |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3e} | "
            f"{r['t_memory_s']:.3e} | {r['t_collective_s']:.3e} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['hbm_gib']:.1f} | {'Y' if r['fits_hbm'] else 'N'} |")
    os.makedirs(os.path.dirname(OUT_MD), exist_ok=True)
    with open(OUT_MD, "w") as f:
        f.write("\n".join(lines) + "\n")


if __name__ == "__main__":
    import sys
    run(mesh=sys.argv[1] if len(sys.argv) > 1 else "pod")
