"""Benchmark orchestrator: one benchmark per paper table/figure + kernels +
roofline.  Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--only table1,roofline]
"""
from __future__ import annotations

import argparse
import time

SUITES = ["table1", "table2", "table3", "table4", "fig2", "fig5", "fig6",
          "kernels", "rollout", "roofline"]


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--only", default=None,
                   help="comma-separated subset of: " + ",".join(SUITES))
    args = p.parse_args()
    selected = args.only.split(",") if args.only else SUITES

    from . import (fig2_overlap, fig5_diagnostics, fig6_diversity,
                   kernels_bench, rollout_stages, roofline, table1_main,
                   table2_variants, table3_lenience, table4_breakdown)
    mods = {
        "table1": table1_main, "table2": table2_variants,
        "table3": table3_lenience, "table4": table4_breakdown,
        "fig2": fig2_overlap, "fig5": fig5_diagnostics,
        "fig6": fig6_diversity, "kernels": kernels_bench,
        "rollout": rollout_stages, "roofline": roofline,
    }
    print("name,us_per_call,derived")
    t0 = time.time()
    for name in selected:
        mod = mods[name]
        print(f"# --- {name} ({mod.__doc__.splitlines()[0].strip()})",
              flush=True)
        mod.run()
    print(f"# total {time.time() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
