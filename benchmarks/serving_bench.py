"""Serving benchmark: fixed-batch decode vs the continuous-batching slot
scheduler (vanilla and speculative-prefix admission) on a long-tailed
response-length distribution.  Writes BENCH_serving.json.

Fixed-batch decode runs each 8-request batch to its *slowest* row, so the
long tail idles every short row; the slot scheduler backfills freed slots
immediately.  Tokens are identical between the two engines (same
per-request PRNG keys — asserted), so the comparison is pure scheduling.

    PYTHONPATH=src python -m benchmarks.serving_bench [--smoke] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import random
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cache import RolloutCache
from repro.data.tokenizer import VOCAB_SIZE
from repro.engine.generate import GenerateConfig
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.serving import Request, SlotEngine

from .common import emit

OUT_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_serving.json")
SLOTS = 8
PROMPT_LEN = 16
# long tail: most rows short, 1-in-10 runs the full budget
TAIL_FRACTIONS = (0.125, 0.25, 0.5, 1.0)
TAIL_WEIGHTS = (0.5, 0.25, 0.15, 0.1)


def _setup(N, seed=0):
    cfg = ModelConfig(name="bench", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=VOCAB_SIZE,
                      max_seq_len=max(256, PROMPT_LEN + 2 * N))
    params = M.init_lm(jax.random.PRNGKey(seed), cfg)
    gen = GenerateConfig(max_new_tokens=N, eos_id=VOCAB_SIZE - 1)
    return cfg, params, gen


def _requests(n_requests, N, seed=0):
    rng = random.Random(seed)
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(seed + 1), (n_requests, PROMPT_LEN), 3,
        VOCAB_SIZE - 1))
    keys = np.asarray(jax.vmap(
        lambda i: jax.random.fold_in(jax.random.PRNGKey(seed + 2), i))(
        jnp.arange(n_requests)))
    vkeys = np.asarray(jax.vmap(
        lambda i: jax.random.fold_in(jax.random.PRNGKey(seed + 3), i))(
        jnp.arange(n_requests)))
    reqs = []
    for i in range(n_requests):
        budget = max(1, int(N * rng.choices(TAIL_FRACTIONS, TAIL_WEIGHTS)[0]))
        reqs.append(Request(request_id=i, prompt=prompts[i].astype(np.int32),
                            key=keys[i], verify_key=vkeys[i],
                            max_new_tokens=budget))
    return reqs


def _run_fixed(cfg, params, gen, reqs):
    """Fixed-batch baseline: SLOTS-sized batches decoded to the slowest row."""
    from repro.engine.generate import generate
    outs, n_gen = {}, 0
    for lo in range(0, len(reqs), SLOTS):
        chunk = reqs[lo:lo + SLOTS]
        toks = np.stack([r.prompt for r in chunk])
        mask = np.ones_like(toks, bool)
        out = generate(params, cfg, gen, jnp.asarray(toks), jnp.asarray(mask),
                       jnp.asarray(np.stack([r.key for r in chunk])),
                       row_budget=jnp.asarray([r.max_new_tokens
                                               for r in chunk], jnp.int32))
        jax.block_until_ready(out["tokens"])
        for j, r in enumerate(chunk):
            outs[r.request_id] = np.asarray(
                out["tokens"][j, :int(out["length"][j])])
        n_gen += int(out["n_generated"])
    return outs, n_gen


def _run_slots(cfg, params, gen, reqs, drafts=None):
    engine = SlotEngine(params, cfg, gen, num_slots=SLOTS,
                        prompt_width=PROMPT_LEN, spec_prefix=drafts is not None,
                        log_lenience=0.0)
    for r in reqs:
        if drafts is not None:
            e = drafts.get(r.request_id)
            r.draft_tokens, r.draft_logprobs = e.tokens, e.logprobs
            r.draft_eos = e.ends_with_eos
        engine.submit(r)
    resps = engine.run()
    outs = {i: resps[i].tokens for i in resps}
    return outs, engine.stats(), resps


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, time.perf_counter() - t0


def run(smoke: bool = False, out_path: str = OUT_PATH) -> dict:
    N = 48 if smoke else 64
    n_requests = 24 if smoke else 64
    cfg, params, gen = _setup(N)

    reqs = _requests(n_requests, N)
    _run_fixed(cfg, params, gen, reqs[:SLOTS])          # compile warmup
    _run_slots(cfg, params, gen, _requests(SLOTS, N, seed=7))

    (fixed_out, n_gen_fixed), t_fixed = _timed(
        lambda: _run_fixed(cfg, params, gen, reqs))
    (slot_out, sched, _), t_slots = _timed(
        lambda: _run_slots(cfg, params, gen, _requests(n_requests, N)))

    # same per-request keys => identical tokens; the comparison is scheduling
    for i in range(n_requests):
        np.testing.assert_array_equal(slot_out[i], fixed_out[i])
    n_gen_slots = int(sched["generated_tokens"])
    assert n_gen_slots == n_gen_fixed, (n_gen_slots, n_gen_fixed)

    # speculative-prefix admission: drafts from a previous (identical-policy)
    # pass, so verification accepts nearly everything
    drafts = RolloutCache()
    _, _, warm_resps = _run_slots(cfg, params, gen, _requests(n_requests, N))
    for i, resp in warm_resps.items():
        drafts.put(i, resp.tokens, resp.logprobs, resp.length, step=0,
                   eos_id=gen.eos_id)
    _run_slots(cfg, params, gen, _requests(SLOTS, N), drafts=drafts)  # warmup
    (spec_out, spec_sched, _), t_spec = _timed(
        lambda: _run_slots(cfg, params, gen, _requests(n_requests, N),
                           drafts=drafts))

    served_spec = int(spec_sched["generated_tokens"]
                      + spec_sched["reused_tokens"])
    record = {
        "backend": jax.default_backend(),
        "slots": SLOTS, "requests": n_requests, "prompt_len": PROMPT_LEN,
        "max_new_tokens": N,
        "tail": {"fractions": TAIL_FRACTIONS, "weights": TAIL_WEIGHTS},
        "fixed": {"time_s": t_fixed, "tokens": n_gen_fixed,
                  "tok_per_s": n_gen_fixed / max(t_fixed, 1e-9)},
        "slots_sched": {"time_s": t_slots, "tokens": n_gen_slots,
                        "tok_per_s": n_gen_slots / max(t_slots, 1e-9),
                        "occupancy": sched["occupancy"],
                        "engine_steps": sched["engine_steps"]},
        "slots_spec": {"time_s": t_spec, "generated": int(
            spec_sched["generated_tokens"]),
            "reused": int(spec_sched["reused_tokens"]),
            "served_tok_per_s": served_spec / max(t_spec, 1e-9),
            "occupancy": spec_sched["occupancy"]},
    }
    record["speedup_slots_vs_fixed"] = (record["slots_sched"]["tok_per_s"]
                                        / record["fixed"]["tok_per_s"])
    record["speedup_spec_vs_fixed"] = (record["slots_spec"]["served_tok_per_s"]
                                       / record["fixed"]["tok_per_s"])
    emit("serving/fixed", t_fixed * 1e6,
         f"tok={n_gen_fixed};tok_s={record['fixed']['tok_per_s']:.0f}")
    emit("serving/slots", t_slots * 1e6,
         f"tok={n_gen_slots};tok_s={record['slots_sched']['tok_per_s']:.0f};"
         f"occ={sched['occupancy']:.2f}")
    emit("serving/slots_spec", t_spec * 1e6,
         f"served={served_spec};tok_s="
         f"{record['slots_spec']['served_tok_per_s']:.0f}")
    emit("serving/speedup", 0.0,
         f"slots={record['speedup_slots_vs_fixed']:.2f}x;"
         f"spec={record['speedup_spec_vs_fixed']:.2f}x")
    assert record["speedup_slots_vs_fixed"] >= 1.5, \
        f"slot scheduler below 1.5x: {record['speedup_slots_vs_fixed']:.2f}"
    with open(out_path, "w") as f:
        json.dump(record, f, indent=2)
    emit("serving/json", 0.0, out_path)
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fewer requests, smaller budgets")
    ap.add_argument("--out", default=OUT_PATH)
    args = ap.parse_args()
    run(smoke=args.smoke, out_path=args.out)
