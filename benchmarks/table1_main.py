"""Paper Table 1: rollout efficiency (generated tokens, speedup) and reward
across GRPO / PPO / DAPO, vanilla vs +SPEC-RL."""
from __future__ import annotations

from .common import emit, make_trainer, run_steps

STEPS = 5


def run() -> None:
    for algo in ("grpo", "ppo", "dapo"):
        base = run_steps(make_trainer(algo, "off", seed=3), STEPS)
        spec = run_steps(make_trainer(algo, "spec", seed=3), STEPS)
        speed_tok = base["tokens"] / max(spec["tokens"], 1)
        speed_wall = base["rollout_s"] / max(spec["rollout_s"], 1e-9)
        emit(f"table1/{algo}/vanilla",
             base["rollout_s"] / STEPS * 1e6,
             f"tokens={base['tokens']};reward={base['reward_last']:.3f};"
             f"speedup=1.00x")
        emit(f"table1/{algo}/spec_rl",
             spec["rollout_s"] / STEPS * 1e6,
             f"tokens={spec['tokens']};reward={spec['reward_last']:.3f};"
             f"token_speedup={speed_tok:.2f}x;wall_speedup={speed_wall:.2f}x")


if __name__ == "__main__":
    run()
