"""Paper Table 2: SPEC-RL vs Random Reuse vs Delayed Reuse (GRPO)."""
from __future__ import annotations

from .common import emit, make_trainer, run_steps

STEPS = 5


def run() -> None:
    base = run_steps(make_trainer("grpo", "off", seed=5), STEPS)
    for variant in ("spec", "random", "delayed", "full"):
        r = run_steps(make_trainer("grpo", variant, seed=5), STEPS)
        speed = base["tokens"] / max(r["tokens"], 1)
        emit(f"table2/{variant}", r["rollout_s"] / STEPS * 1e6,
             f"tokens={r['tokens']};token_speedup={speed:.2f}x;"
             f"reward={r['reward_last']:.3f};prefix={r['prefix_mean']:.1f}")
    emit("table2/vanilla", base["rollout_s"] / STEPS * 1e6,
         f"tokens={base['tokens']};token_speedup=1.00x;"
         f"reward={base['reward_last']:.3f}")


if __name__ == "__main__":
    run()
