"""Paper Table 3 / Fig. 4: lenience ablation — speedup rises monotonically
with lenience; l=1 is vanilla speculative decoding, l=inf is full reuse."""
from __future__ import annotations

import math

from .common import emit, make_trainer, run_steps

STEPS = 5
LENIENCES = [("l=1", 1.0), ("l=e0.2", math.e ** 0.2),
             ("l=e0.5", math.e ** 0.5), ("l=e0.8", math.e ** 0.8),
             ("l=e2.0", math.e ** 2.0), ("l=inf", float("inf"))]


def run() -> None:
    base = run_steps(make_trainer("grpo", "off", seed=7), STEPS)
    emit("table3/vanilla", base["rollout_s"] / STEPS * 1e6,
         f"tokens={base['tokens']};speedup=1.00x")
    prev_tokens = None
    for name, l in LENIENCES:
        variant = "full" if math.isinf(l) else "spec"
        r = run_steps(make_trainer("grpo", variant, lenience=l, seed=7), STEPS)
        speed = base["tokens"] / max(r["tokens"], 1)
        emit(f"table3/{name}", r["rollout_s"] / STEPS * 1e6,
             f"tokens={r['tokens']};token_speedup={speed:.2f}x;"
             f"reward={r['reward_last']:.3f};prefix={r['prefix_mean']:.1f};"
             f"full_reuse={r['full_reuse']:.2f}")


if __name__ == "__main__":
    run()
