"""Paper Table 4: per-stage time breakdown — verification + assembly overhead
vs rollout savings (verl stage order).  The rollout stage is split into the
engine's explicit sub-stages: verify (fused verify+prefill on the one-pass
path), compact (cache_gather / left_align) and decode."""
from __future__ import annotations

import numpy as np

from .common import emit, make_trainer

STEPS = 5
STAGES = ["verify_time", "compact_time", "decode_time", "assembly_time",
          "reward_time", "old_logprob_time", "ref_time", "values_time",
          "adv_time", "update_critic_time", "update_actor_time"]


def run() -> None:
    for label, variant in (("vanilla", "off"), ("spec_rl", "spec")):
        tr = make_trainer("grpo", variant, seed=9)
        for _ in range(STEPS):
            tr.train_step()
        h = tr.history[2:]          # skip compile-heavy steps: cold-start
                                    # generate + first speculative step
        parts = []
        total = 0.0
        for s in STAGES:
            v = float(np.mean([x.get(s, 0.0) for x in h]))
            total += v
            if v > 0:
                parts.append(f"{s.replace('_time','')}={v*1e3:.1f}ms")
        emit(f"table4/{label}", total * 1e6, ";".join(parts))


if __name__ == "__main__":
    run()
