"""Lenience ablation (paper Table 3 / Fig. 4 at CPU scale).

Sweeps l over the paper's grid, reporting generated tokens, token-level
speedup vs vanilla, verified-prefix length, and reward — the tradeoff curve
that motivates moderate lenience.

    PYTHONPATH=src python examples/lenience_ablation.py --steps 6
"""
import argparse
import math

import jax

from repro.core import SpecConfig
from repro.data.dataset import PromptDataset
from repro.data.tokenizer import VOCAB_SIZE
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamWConfig
from repro.rewards.mathgen import MathTaskConfig, generate_problems
from repro.rl.trainer import RLConfig, Trainer

GRID = [("vanilla", None), ("l=1", 1.0), ("l=e^0.2", math.e ** 0.2),
        ("l=e^0.5", math.e ** 0.5), ("l=e^0.8", math.e ** 0.8),
        ("l=e^2.0", math.e ** 2.0), ("l=inf", float("inf"))]


def run_one(lenience, steps, seed=0):
    model = ModelConfig(name="abl", num_layers=2, d_model=96, num_heads=4,
                        num_kv_heads=2, d_ff=192, vocab_size=VOCAB_SIZE,
                        max_seq_len=128)
    problems = generate_problems(MathTaskConfig(num_problems=12,
                                                max_operand=9))
    ds = PromptDataset(problems, max_prompt_len=10)
    rl = RLConfig(algo="grpo", group_size=4, prompts_per_batch=4,
                  max_new_tokens=10, optim=AdamWConfig(lr=1e-3))
    if lenience is None:
        spec = SpecConfig(variant="off")
    elif math.isinf(lenience):
        spec = SpecConfig(variant="full")
    else:
        spec = SpecConfig(variant="spec", lenience=lenience,
                          verify_impl="ref")
    tr = Trainer(model, rl, spec, ds, jax.random.PRNGKey(seed))
    rewards, prefixes = [], []
    for _ in range(steps):
        m = tr.train_step()
        rewards.append(m["reward_mean"])
        prefixes.append(m.get("verified_prefix_mean", 0.0))
    return dict(tokens=tr.total_generated_tokens,
                reward=sum(rewards[-3:]) / 3,
                prefix=sum(prefixes) / len(prefixes))


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=6)
    args = p.parse_args()

    base_tokens = None
    print(f"{'setting':>9} {'tokens':>8} {'speedup':>8} {'prefix':>7} "
          f"{'reward':>7}")
    for name, l in GRID:
        r = run_one(l, args.steps)
        if base_tokens is None:
            base_tokens = r["tokens"]
        speed = base_tokens / max(r["tokens"], 1)
        print(f"{name:>9} {r['tokens']:8d} {speed:7.2f}x {r['prefix']:7.2f} "
              f"{r['reward']:7.3f}")


if __name__ == "__main__":
    main()
