"""Quickstart: GRPO + SPEC-RL on the synthetic verifiable-math task.

Trains a tiny model for a handful of steps and prints the paper's headline
metrics per step: generated tokens (the efficiency metric), verified-prefix
length, full-reuse ratio, reward.

    PYTHONPATH=src python examples/quickstart.py
"""
import math

import jax

from repro.core import SpecConfig
from repro.data.dataset import PromptDataset
from repro.data.tokenizer import VOCAB_SIZE
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamWConfig
from repro.rewards.mathgen import MathTaskConfig, generate_problems
from repro.rl.trainer import RLConfig, Trainer


def main():
    model = ModelConfig(name="quickstart", num_layers=2, d_model=96,
                        num_heads=4, num_kv_heads=2, d_ff=192,
                        vocab_size=VOCAB_SIZE, max_seq_len=128)
    problems = generate_problems(MathTaskConfig(num_problems=12,
                                                max_operand=9))
    dataset = PromptDataset(problems, max_prompt_len=10)
    rl = RLConfig(algo="grpo", group_size=4, prompts_per_batch=4,
                  max_new_tokens=10, optim=AdamWConfig(lr=1e-3))
    spec = SpecConfig(variant="spec", lenience=math.e ** 0.5,
                      verify_impl="ref")

    trainer = Trainer(model, rl, spec, dataset, jax.random.PRNGKey(0))
    print(f"{'step':>4} {'reward':>7} {'gen_tok':>8} {'reused':>7} "
          f"{'prefix':>7} {'full_reuse':>10}")
    for _ in range(8):
        m = trainer.train_step()
        print(f"{m['step']:4.0f} {m['reward_mean']:7.3f} "
              f"{m.get('n_generated', 0):8.0f} {m.get('n_reused', 0):7.0f} "
              f"{m.get('verified_prefix_mean', 0):7.2f} "
              f"{m.get('full_reuse_ratio', 0):10.2f}")
    print(f"\ntotal generated tokens: {trainer.total_generated_tokens}"
          f" (vanilla would regenerate everything each step)")
    print("cache:", trainer.cache.stats())


if __name__ == "__main__":
    main()
