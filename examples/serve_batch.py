"""Serving demo: batched requests against the engine — prefill + decode with
per-row early stopping, plus a speculative *re-serve* pass that reuses a
previous response as the draft (the SPEC-RL mechanism applied to serving:
answer regeneration after a small model update).

    PYTHONPATH=src python examples/serve_batch.py
"""
import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.verify import verify_drafts
from repro.data.dataset import PromptDataset
from repro.data.tokenizer import VOCAB_SIZE, decode
from repro.engine.generate import GenerateConfig, generate
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.rewards.mathgen import MathTaskConfig, generate_problems


def main():
    cfg = ModelConfig(name="serve", num_layers=2, d_model=96, num_heads=4,
                      num_kv_heads=2, d_ff=192, vocab_size=VOCAB_SIZE,
                      max_seq_len=128)
    params = M.init_lm(jax.random.PRNGKey(0), cfg)

    problems = generate_problems(MathTaskConfig(num_problems=8, max_operand=9))
    ds = PromptDataset(problems, max_prompt_len=10)
    batch = ds.sample_batch(__import__("random").Random(0), 8, 1)
    prompts = jnp.asarray(batch.tokens)
    mask = jnp.asarray(batch.mask)
    gen = GenerateConfig(max_new_tokens=16, temperature=1.0)

    t0 = time.time()
    out = generate(params, cfg, gen, prompts, mask, jax.random.PRNGKey(1))
    jax.block_until_ready(out["tokens"])
    t_first = time.time() - t0
    print(f"batched serve: {int(out['n_generated'])} tokens "
          f"in {t_first:.2f}s")
    for i in range(4):
        txt = decode(np.asarray(out["tokens"][i, :out["length"][i]]))
        print(f"  [{batch.problem_ids[i]}] "
              f"{problems[batch.problem_ids[i]].prompt_text!r} -> {txt!r}")

    # simulate a small policy update, then re-serve speculatively
    updated = jax.tree.map(
        lambda x: x + 0.01 * jax.random.normal(jax.random.PRNGKey(2),
                                               x.shape).astype(x.dtype),
        params)
    t0 = time.time()
    ver = verify_drafts(updated, cfg, prompts, mask, out["tokens"],
                        out["logprobs"], out["length"], jax.random.PRNGKey(3),
                        math.log(math.e ** 0.5), impl="ref")
    n = ver["n"]
    jax.block_until_ready(n)
    reused = int(n.sum())
    total = int(out["length"].sum())
    print(f"\nspeculative re-serve after update: verified prefix "
          f"{reused}/{total} tokens ({100 * reused / max(total, 1):.0f}% "
          f"reused) in {time.time() - t0:.2f}s verification")
    print("per-request verified prefix:", np.asarray(n).tolist())


if __name__ == "__main__":
    main()
