"""End-to-end driver (deliverable b): train a model with RLVR + SPEC-RL for a
few hundred steps, with checkpointing, eval, and a vanilla-baseline
comparison mode.

Default is a CPU-budget model; ``--model 100m`` selects a ~100M-parameter
qwen3-style backbone (the assignment's e2e scale — practical on accelerators,
slow but runnable on CPU).

    PYTHONPATH=src python examples/train_spec_rl.py --steps 200
    PYTHONPATH=src python examples/train_spec_rl.py --variant off   # baseline
    PYTHONPATH=src python examples/train_spec_rl.py --model 100m --steps 300
"""
import argparse
import json
import math
import os
import time

import jax
import numpy as np

from repro.checkpoint.io import save_pytree, save_rollout_cache
from repro.core import SpecConfig
from repro.data.dataset import PromptDataset
from repro.data.tokenizer import VOCAB_SIZE, decode
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamWConfig
from repro.rewards.mathgen import MathTaskConfig, generate_problems
from repro.rewards.verifier import batch_rewards
from repro.rl.trainer import RLConfig, Trainer

MODELS = {
    "tiny": ModelConfig(name="tiny", num_layers=2, d_model=96, num_heads=4,
                        num_kv_heads=2, d_ff=192, vocab_size=VOCAB_SIZE,
                        max_seq_len=128),
    "20m": ModelConfig(name="20m", num_layers=6, d_model=384, num_heads=6,
                       num_kv_heads=2, d_ff=1152, vocab_size=VOCAB_SIZE,
                       qk_norm=True, max_seq_len=256),
    "100m": ModelConfig(name="100m", num_layers=12, d_model=768, num_heads=12,
                        num_kv_heads=4, d_ff=2304, vocab_size=VOCAB_SIZE,
                        qk_norm=True, max_seq_len=512),
}


def evaluate(trainer: Trainer, n_prompts: int = 16) -> float:
    """Greedy eval on held-out problems (exact-match accuracy)."""
    from repro.engine.generate import GenerateConfig, generate
    problems = generate_problems(MathTaskConfig(num_problems=n_prompts,
                                                max_operand=9, seed=999))
    ds = PromptDataset(problems, max_prompt_len=10)
    batch = ds.sample_batch(__import__("random").Random(0), n_prompts, 1)
    gen = GenerateConfig(max_new_tokens=trainer.rl.max_new_tokens,
                         temperature=0.0)
    out = generate(trainer.params, trainer.cfg, gen,
                   jax.numpy.asarray(batch.tokens),
                   jax.numpy.asarray(batch.mask), jax.random.PRNGKey(0))
    r = batch_rewards(np.asarray(out["tokens"]), np.asarray(out["length"]),
                      batch.answers)
    return float(r.mean())


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--model", choices=sorted(MODELS), default="tiny")
    p.add_argument("--algo", choices=["grpo", "ppo", "dapo"], default="grpo")
    p.add_argument("--variant", choices=["spec", "off", "random", "delayed",
                                         "full"], default="spec")
    p.add_argument("--lenience", type=float, default=math.e ** 0.5)
    p.add_argument("--steps", type=int, default=60)
    p.add_argument("--problems", type=int, default=32)
    p.add_argument("--lr", type=float, default=1e-3)
    p.add_argument("--max-new-tokens", type=int, default=10)
    p.add_argument("--eval-every", type=int, default=20)
    p.add_argument("--out", default="runs/train_spec_rl")
    args = p.parse_args()

    model = MODELS[args.model]
    problems = generate_problems(MathTaskConfig(num_problems=args.problems,
                                                max_operand=9))
    dataset = PromptDataset(problems, max_prompt_len=10)
    rl = RLConfig(algo=args.algo, group_size=4, prompts_per_batch=8,
                  max_new_tokens=args.max_new_tokens,
                  optim=AdamWConfig(lr=args.lr))
    spec = SpecConfig(variant=args.variant, lenience=args.lenience,
                      verify_impl="ref")
    trainer = Trainer(model, rl, spec, dataset, jax.random.PRNGKey(0))

    os.makedirs(args.out, exist_ok=True)
    t0 = time.time()
    for i in range(args.steps):
        m = trainer.train_step()
        if i % 10 == 0:
            print(f"step {m['step']:4.0f} reward={m['reward_mean']:.3f} "
                  f"gen_tok={m.get('n_generated', 0):6.0f} "
                  f"reuse={m.get('n_reused', 0):6.0f} "
                  f"kl={m.get('approx_kl', 0):+.4f} "
                  f"ent={m.get('entropy', 0):.2f}", flush=True)
        if args.eval_every and (i + 1) % args.eval_every == 0:
            acc = evaluate(trainer)
            print(f"  eval@{i+1}: exact-match={acc:.3f}")

    acc = evaluate(trainer)
    wall = time.time() - t0
    print(f"\nfinal eval={acc:.3f}; total generated tokens="
          f"{trainer.total_generated_tokens}; wall={wall:.1f}s")
    save_pytree(os.path.join(args.out, "policy"), trainer.params,
                {"steps": args.steps, "algo": args.algo,
                 "variant": args.variant})
    save_rollout_cache(os.path.join(args.out, "rollouts"), trainer.cache)
    with open(os.path.join(args.out, "history.json"), "w") as f:
        json.dump(trainer.history, f, indent=1)
    print(f"checkpoint + history written to {args.out}/")


if __name__ == "__main__":
    main()
