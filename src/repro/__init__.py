"""SPEC-RL reproduction package.

One process-wide numerical contract is pinned here, at the root import
every ``repro.*`` module shares, so it can never depend on WHICH submodule
a given entry point happens to import:

Partitionable threefry makes PRNG bit generation a pure function of
(key, shape) regardless of how operands are sharded.  The legacy default
derives bits from a device-layout-dependent global iota, so the same
sampling call would return DIFFERENT tokens once its inputs carry a
NamedSharding — silently breaking the token-identity contract between
sharded and single-device rollouts (DESIGN.md §8, asserted in
tests/distributed/test_mesh_rollout.py).  Flipping it uniformly at the
package root also keeps single-device token streams identical across every
entry point (engine-only scripts, serving, trainer, benches) instead of
varying with the import graph.
"""
import jax

jax.config.update("jax_threefry_partitionable", True)
