"""Pytree checkpointing: npz arrays + json tree structure (no orbax here).

Saves/restores arbitrary nested dict/list pytrees of jnp/np arrays — policy
params, optimizer state, critic, the SPEC-RL rollout cache (so resumed
training keeps its reuse warm instead of paying a fresh cold-start epoch)
and the slot server's exact serving state (DESIGN.md §10 kill-and-resume).

Crash safety (§10): every file is written to a temp name in the same
directory and moved into place with ``os.replace`` — a reader never sees a
half-written checkpoint.  A checkpoint directory additionally keeps a
``latest`` pointer file, updated *last* (write_latest), so a crash between
"new checkpoint fully on disk" and "pointer moved" leaves the previous
checkpoint live — the pointer flip is the commit point.
"""
from __future__ import annotations

import json
import os
from collections import deque
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cache import CacheEntry, RolloutCache

LATEST = "latest"                    # pointer file name inside a ckpt dir


def _flatten(tree, prefix="", out=None):
    out = out if out is not None else {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            _flatten(tree[k], f"{prefix}/{k}", out)
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            _flatten(v, f"{prefix}/#{i}", out)
    else:
        out[prefix] = np.asarray(tree)
    return out


def _structure(tree):
    if isinstance(tree, dict):
        return {"__kind__": "dict",
                "items": {k: _structure(v) for k, v in tree.items()}}
    if isinstance(tree, (list, tuple)):
        return {"__kind__": "list" if isinstance(tree, list) else "tuple",
                "items": [_structure(v) for v in tree]}
    return {"__kind__": "leaf"}


def _rebuild(struct, flat, prefix=""):
    kind = struct["__kind__"]
    if kind == "dict":
        return {k: _rebuild(v, flat, f"{prefix}/{k}")
                for k, v in struct["items"].items()}
    if kind in ("list", "tuple"):
        seq = [_rebuild(v, flat, f"{prefix}/#{i}")
               for i, v in enumerate(struct["items"])]
        return seq if kind == "list" else tuple(seq)
    return jnp.asarray(flat[prefix])


# ------------------------------------------------------------ atomic writes

def _fsync_dir(path: str) -> None:
    """fsync the directory holding ``path``: ``os.replace`` makes the new
    name visible, but the *rename itself* is only durable once the parent
    directory's entry is flushed — without this a power cut after replace
    can resurrect the old file (POSIX).  Best-effort on filesystems that
    refuse O_RDONLY directory handles."""
    d = os.path.dirname(path) or "."
    try:
        fd = os.open(d, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def _atomic_write_npz(path: str, blob: Dict[str, np.ndarray]) -> None:
    """np.savez to ``path`` via temp-file + os.replace (same filesystem)."""
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **blob)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    _fsync_dir(path)


def _atomic_write_text(path: str, text: str) -> None:
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        f.write(text)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    _fsync_dir(path)


def write_latest(ckpt_dir: str, name: str) -> None:
    """Flip the ``latest`` pointer to checkpoint ``name`` — the commit
    point of a checkpoint: call it only after every file of ``name`` is
    fully on disk.  Atomic, so a crash leaves either pointer intact."""
    os.makedirs(ckpt_dir, exist_ok=True)
    _atomic_write_text(os.path.join(ckpt_dir, LATEST), name + "\n")


def read_latest(ckpt_dir: str) -> Optional[str]:
    """Name of the last committed checkpoint in ``ckpt_dir`` (None if no
    checkpoint was ever committed).

    Validated: the pointer must reference files that actually exist.  A
    crash (or a pre-dir-fsync power cut) can leave ``latest`` naming a
    checkpoint whose files never became durable; a reader must fall back
    to "no checkpoint" rather than hand callers a name that raises
    FileNotFoundError downstream."""
    p = os.path.join(ckpt_dir, LATEST)
    if not os.path.exists(p):
        return None
    with open(p) as f:
        name = f.read().strip()
    if not name:
        return None
    try:
        entries = os.listdir(ckpt_dir)
    except OSError:
        return None
    if not any(e == name or e.startswith(name + ".") for e in entries):
        return None
    return name


# ---------------------------------------------------------------- pytrees

def save_pytree(path: str, tree, metadata: Optional[Dict[str, Any]] = None) -> None:
    """Write ``path``.npz + ``path``.json, each atomically.

    The json (structure + metadata) is written LAST — loaders open it
    first, so a crash mid-save leaves either the complete previous pair or
    a dangling .npz that no json references yet.
    """
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    _atomic_write_npz(path + ".npz", flat)
    _atomic_write_text(path + ".json", json.dumps(
        {"structure": _structure(tree), "metadata": metadata or {}}))


def load_pytree(path: str) -> Tuple[Any, Dict[str, Any]]:
    with open(path + ".json") as f:
        meta = json.load(f)
    with np.load(path + ".npz") as z:
        flat = {k: z[k] for k in z.files}
    return _rebuild(meta["structure"], flat), meta["metadata"]


# ----------------------------------------------------------- rollout cache

def save_rollout_cache(path: str, cache: RolloutCache) -> None:
    """Persist a RolloutCache *losslessly*: entries, LRU recency order,
    sibling-group registration, eviction bound and hit/miss counters all
    round-trip — a restored trainer sees the same reuse behaviour AND the
    same eviction pressure it would have seen uninterrupted."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    blob = {}
    index = {}
    for pid, q in cache._store.items():          # iteration order = LRU order
        index[str(pid)] = len(q)
        for j, e in enumerate(q):
            blob[f"t/{pid}/{j}"] = e.tokens
            blob[f"l/{pid}/{j}"] = e.logprobs
            blob[f"m/{pid}/{j}"] = np.array([e.step, int(e.ends_with_eos)])
    meta = {
        "index": index,
        "order": [int(pid) for pid in cache._store],   # LRU, oldest first
        "history": cache.history,
        "max_prompts": cache.max_prompts,
        "group_size": cache.group_size,
        "group_of": {str(pid): int(gid)
                     for pid, gid in cache._group_of.items()},
        "counters": {"puts": cache.puts, "hits": cache.hits,
                     "misses": cache.misses, "evictions": cache.evictions},
    }
    _atomic_write_npz(path + ".cache.npz", blob)
    _atomic_write_text(path + ".cache.json", json.dumps(meta))


def load_rollout_cache(path: str) -> RolloutCache:
    with open(path + ".cache.json") as f:
        meta = json.load(f)
    cache = RolloutCache(history=meta["history"],
                         max_prompts=meta.get("max_prompts"),
                         group_size=meta.get("group_size", 0))
    with np.load(path + ".cache.npz") as z:
        # rebuild the store directly (not via put(): that would bump the
        # puts counter, re-derive groups and re-run eviction) in saved LRU
        # order — insertion order of the OrderedDict IS its recency order
        order = meta.get("order") or [int(p) for p in meta["index"]]
        for pid in order:
            n = meta["index"][str(pid)]
            q = deque(maxlen=cache.history)
            for j in range(n):
                step, eos = z[f"m/{pid}/{j}"]
                q.append(CacheEntry(z[f"t/{pid}/{j}"], z[f"l/{pid}/{j}"],
                                    int(step), bool(eos)))
            cache._store[pid] = q
    for pid_s, gid in meta.get("group_of", {}).items():
        pid = int(pid_s)
        cache._group_of[pid] = int(gid)
        cache._groups.setdefault(int(gid), set()).add(pid)
    for k, v in meta.get("counters", {}).items():
        setattr(cache, k, int(v))
    return cache


# ---------------------------------------------- §10 serving state snapshots

def save_server_state(path: str, server,
                      metadata: Optional[Dict[str, Any]] = None) -> None:
    """Snapshot a SlotEngine / MeshSlotServer for exact kill-and-resume.

    ``server.state_dict()`` is an all-array pytree by construction, so the
    generic atomic pytree writer carries it; restore into a freshly
    constructed (same shapes / same params) server via
    ``load_server_state``.  Tokens produced after the restore are identical
    to an uninterrupted run (tests/serving/test_kill_resume.py).
    """
    save_pytree(path, server.state_dict(),
                metadata={**(metadata or {}), "kind": "server_state"})


def load_server_state(path: str, server) -> Dict[str, Any]:
    """Restore ``server`` in place from a ``save_server_state`` snapshot;
    returns the snapshot's metadata."""
    tree, meta = load_pytree(path)
    server.load_state_dict(tree)
    return meta
