"""Pytree checkpointing: npz arrays + json tree structure (no orbax here).

Saves/restores arbitrary nested dict/list pytrees of jnp/np arrays — policy
params, optimizer state, critic, and the SPEC-RL rollout cache (so resumed
training keeps its reuse warm instead of paying a fresh cold-start epoch).
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cache import CacheEntry, RolloutCache


def _flatten(tree, prefix="", out=None):
    out = out if out is not None else {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            _flatten(tree[k], f"{prefix}/{k}", out)
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            _flatten(v, f"{prefix}/#{i}", out)
    else:
        out[prefix] = np.asarray(tree)
    return out


def _structure(tree):
    if isinstance(tree, dict):
        return {"__kind__": "dict",
                "items": {k: _structure(v) for k, v in tree.items()}}
    if isinstance(tree, (list, tuple)):
        return {"__kind__": "list" if isinstance(tree, list) else "tuple",
                "items": [_structure(v) for v in tree]}
    return {"__kind__": "leaf"}


def _rebuild(struct, flat, prefix=""):
    kind = struct["__kind__"]
    if kind == "dict":
        return {k: _rebuild(v, flat, f"{prefix}/{k}")
                for k, v in struct["items"].items()}
    if kind in ("list", "tuple"):
        seq = [_rebuild(v, flat, f"{prefix}/#{i}")
               for i, v in enumerate(struct["items"])]
        return seq if kind == "list" else tuple(seq)
    return jnp.asarray(flat[prefix])


def save_pytree(path: str, tree, metadata: Optional[Dict[str, Any]] = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    np.savez(path + ".npz", **{k: v for k, v in flat.items()})
    with open(path + ".json", "w") as f:
        json.dump({"structure": _structure(tree), "metadata": metadata or {}}, f)


def load_pytree(path: str) -> Tuple[Any, Dict[str, Any]]:
    with open(path + ".json") as f:
        meta = json.load(f)
    with np.load(path + ".npz") as z:
        flat = {k: z[k] for k in z.files}
    return _rebuild(meta["structure"], flat), meta["metadata"]


def save_rollout_cache(path: str, cache: RolloutCache) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    blob = {}
    index = {}
    for pid, q in cache._store.items():
        index[str(pid)] = len(q)
        for j, e in enumerate(q):
            blob[f"t/{pid}/{j}"] = e.tokens
            blob[f"l/{pid}/{j}"] = e.logprobs
            blob[f"m/{pid}/{j}"] = np.array([e.step, int(e.ends_with_eos)])
    np.savez(path + ".cache.npz", **blob)
    with open(path + ".cache.json", "w") as f:
        json.dump({"index": index, "history": cache.history}, f)


def load_rollout_cache(path: str) -> RolloutCache:
    with open(path + ".cache.json") as f:
        meta = json.load(f)
    cache = RolloutCache(history=meta["history"])
    with np.load(path + ".cache.npz") as z:
        for pid_s, n in meta["index"].items():
            pid = int(pid_s)
            for j in range(n):
                step, eos = z[f"m/{pid}/{j}"]
                toks = z[f"t/{pid}/{j}"]
                q = cache._store.setdefault(pid, __import__("collections").deque(
                    maxlen=cache.history))
                q.append(CacheEntry(toks, z[f"l/{pid}/{j}"], int(step), bool(eos)))
    return cache
