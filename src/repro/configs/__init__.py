"""Architecture registry: the 10 assigned architectures + the paper's own
backbone, selectable via ``--arch <id>``."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

# arch id -> module name
ARCH_IDS = {
    "granite-34b": "granite_34b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "qwen3-0.6b": "qwen3_0p6b",
    "jamba-v0.1-52b": "jamba_v0p1_52b",
    "pixtral-12b": "pixtral_12b",
    "qwen1.5-110b": "qwen1p5_110b",
    "rwkv6-3b": "rwkv6_3b",
    "mixtral-8x22b": "mixtral_8x22b",
    "whisper-tiny": "whisper_tiny",
    "deepseek-7b": "deepseek_7b",
    # the paper's own backbone (not part of the assigned 10)
    "qwen3-1.7b": "qwen3_1p7b",
}

ASSIGNED = [k for k in ARCH_IDS if k != "qwen3-1.7b"]


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch '{arch_id}'; known: {sorted(ARCH_IDS)}")
    mod = importlib.import_module(f"repro.configs.{ARCH_IDS[arch_id]}")
    cfg = mod.config()
    cfg.validate()
    return cfg


def list_configs() -> List[str]:
    return sorted(ARCH_IDS)


def all_configs() -> Dict[str, ModelConfig]:
    return {k: get_config(k) for k in ARCH_IDS}
