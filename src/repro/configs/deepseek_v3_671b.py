"""deepseek-v3-671b [moe]: 61L, d_model 7168, 128 heads (MLA), MoE 256
routed experts top-8 + 1 shared (expert d_ff 2048, dense d_ff 18432 on the
first 3 layers), vocab 129280, MTP head [arXiv:2412.19437]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b", arch_type="moe", source="arXiv:2412.19437",
        num_layers=61, d_model=7168, num_heads=128, num_kv_heads=128,
        d_ff=18432, vocab_size=129280, max_seq_len=8192,
        attention_kind="mla", q_lora_rank=1536, kv_lora_rank=512,
        qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
        num_experts=256, num_experts_per_tok=8, num_shared_experts=1,
        moe_d_ff=2048, first_dense_layers=3, moe_every=1,
        moe_impl="dispatch", mtp=True,
        rope_theta=10_000.0,
        dtype="bfloat16", param_dtype="bfloat16",
    )
