"""granite-34b [dense]: 88L, d_model 6144, 48 heads MQA (kv=1), d_ff 24576,
vocab 49152 — llama-architecture code model [arXiv:2405.04324]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-34b", arch_type="dense", source="arXiv:2405.04324",
        num_layers=88, d_model=6144, num_heads=48, num_kv_heads=1,
        d_ff=24576, vocab_size=49152, max_seq_len=8192,
        rope_theta=10_000.0, act="gelu", ffn_kind="mlp",
        dtype="bfloat16", param_dtype="bfloat16",
    )
