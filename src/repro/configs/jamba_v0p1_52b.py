"""jamba-v0.1-52b [hybrid]: 32L, d_model 4096, Mamba:attention 7:1
(one attention layer per 8, at offset 4), GQA kv=8, d_ff 14336, MoE 16
experts top-2 on every second layer, vocab 65536 [arXiv:2403.19887]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b", arch_type="hybrid", source="arXiv:2403.19887",
        num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
        d_ff=14336, vocab_size=65536, max_seq_len=262144,
        block_kind="mamba", attn_period=8, attn_offset=4,
        num_experts=16, num_experts_per_tok=2, moe_every=2,
        moe_impl="dispatch",
        mamba_d_state=16, mamba_d_conv=4, mamba_expand=2,
        rope_theta=10_000.0,
        dtype="bfloat16", param_dtype="bfloat16",
    )
