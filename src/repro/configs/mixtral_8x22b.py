"""mixtral-8x22b [moe]: 56L, d_model 6144, 48 heads GQA kv=8, d_ff 16384,
8 experts top-2 on every layer, sliding-window attention (W=4096),
vocab 32768 [arXiv:2401.04088]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b", arch_type="moe", source="arXiv:2401.04088",
        num_layers=56, d_model=6144, num_heads=48, num_kv_heads=8,
        d_ff=16384, vocab_size=32768, max_seq_len=65536,
        num_experts=8, num_experts_per_tok=2, moe_every=1,
        moe_impl="dispatch", sliding_window=4096,
        rope_theta=1_000_000.0,
        dtype="bfloat16", param_dtype="bfloat16",
    )
