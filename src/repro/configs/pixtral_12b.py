"""pixtral-12b [vlm]: language backbone 40L, d_model 5120, 32 heads GQA kv=8,
head_dim 128, d_ff 14336, vocab 131072; vision patches come from the STUB
frontend as precomputed prefix embeddings [hf:mistralai/Pixtral-12B-2409]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="pixtral-12b", arch_type="vlm", source="hf:mistralai/Pixtral-12B-2409",
        num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8,
        head_dim=128, d_ff=14336, vocab_size=131072, max_seq_len=131072,
        rope_theta=1_000_000_000.0,
        frontend="vision", num_prefix_embeddings=256,
        dtype="bfloat16", param_dtype="bfloat16",
    )
