"""qwen1.5-110b [dense]: 80L, d_model 8192, 64 heads GQA kv=8, d_ff 49152,
vocab 152064, QKV bias [hf:Qwen/Qwen1.5-0.5B family]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-110b", arch_type="dense", source="hf:Qwen/Qwen1.5-0.5B",
        num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
        d_ff=49152, vocab_size=152064, max_seq_len=32768,
        qkv_bias=True, rope_theta=1_000_000.0,
        dtype="bfloat16", param_dtype="bfloat16",
    )
