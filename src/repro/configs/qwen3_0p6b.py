"""qwen3-0.6b [dense]: 28L, d_model 1024, 16 heads GQA kv=8, head_dim 128,
d_ff 3072, vocab 151936, qk-norm [hf:Qwen/Qwen3-8B family]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-0.6b", arch_type="dense", source="hf:Qwen/Qwen3-8B",
        num_layers=28, d_model=1024, num_heads=16, num_kv_heads=8,
        head_dim=128, d_ff=3072, vocab_size=151936, max_seq_len=32768,
        qk_norm=True, rope_theta=1_000_000.0, tie_embeddings=True,
        dtype="bfloat16", param_dtype="bfloat16",
    )
