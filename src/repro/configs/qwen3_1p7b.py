"""qwen3-1.7b [dense]: the paper's own backbone family (Qwen3-1.7B-Base):
28L, d_model 2048, 16 heads GQA kv=8, head_dim 128, d_ff 6144,
vocab 151936, qk-norm [arXiv:2505.09388; paper §4.1]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-1.7b", arch_type="dense", source="arXiv:2505.09388",
        num_layers=28, d_model=2048, num_heads=16, num_kv_heads=8,
        head_dim=128, d_ff=6144, vocab_size=151936, max_seq_len=32768,
        qk_norm=True, rope_theta=1_000_000.0,
        dtype="bfloat16", param_dtype="bfloat16",
    )
