"""rwkv6-3b [ssm] "Finch": 32L, d_model 2560 (40 heads x 64), attention-free
data-dependent-decay linear recurrence, channel-mix d_ff 8960, vocab 65536
[arXiv:2404.05892]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b", arch_type="ssm", source="arXiv:2404.05892",
        num_layers=32, d_model=2560, num_heads=0, num_kv_heads=0,
        d_ff=8960, vocab_size=65536, max_seq_len=1048576,
        block_kind="rwkv", rwkv_head_dim=64, rwkv_lora_rank=64,
        dtype="bfloat16", param_dtype="bfloat16",
    )
