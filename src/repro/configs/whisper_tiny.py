"""whisper-tiny [audio]: encoder-decoder, 4+4L, d_model 384, 6 heads,
d_ff 1536, vocab 51865; the mel+conv frontend is a STUB supplying 1500
frame embeddings; decoder uses learned positions [arXiv:2212.04356]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny", arch_type="audio", source="arXiv:2212.04356",
        num_layers=4, d_model=384, num_heads=6, num_kv_heads=6,
        d_ff=1536, vocab_size=51865, max_seq_len=448,
        encoder_layers=4, encoder_frames=1500, cross_attention=True,
        frontend="audio", pos_embed="learned", act="gelu", ffn_kind="mlp",
        dtype="bfloat16", param_dtype="bfloat16",
    )
