"""SPEC-RL core: the paper's contribution.

- cache: previous-epoch rollout store (tokens + behaviour log-probs)
- verify: draft-and-verify pass (Algorithm 1) over cached rollouts —
  scoring-only (two-pass) or fused with the engine prefill (one-pass)
- spec_rollout: orchestrator — verify, compact, resume, assemble,
  refresh cache (engine paths in DESIGN.md §3)
- lenience: fixed/warmup/adaptive lenience schedules
- metrics: overlap / diversity / diagnostic metrics from the paper
"""
from .cache import RolloutCache
from .lenience import make_schedule
from .spec_rollout import RolloutBatch, SpecConfig, rollout
