"""Exponential backoff with deterministic jitter (DESIGN.md §12).

One retry policy shared by every layer that talks across a failure
domain: the async trainer's versioned weight publication
(serving/rollout_service.py) and the slot engine's reclaim→resubmit
path (serving/engine_loop.py, ``retry_backoff=``).  The schedule is a
pure function of (config, attempt) — no wall clock, no global RNG — so
tests and the deterministic async scheduler can replay it exactly, and
the same config can express delays in seconds (weight sync) or in
engine steps (slot retries).

``retry`` takes an injectable ``sleep`` so production code sleeps for
real while tests pass a recorder and pay nothing.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, List, Optional, Tuple, Type


class RetriesExhausted(RuntimeError):
    """Raised by ``retry`` when every attempt failed; ``__cause__`` is the
    last underlying exception."""


def _unit(seed: int, i: int) -> float:
    """Deterministic uniform in [0, 1) from (seed, attempt) — integer hash
    mix (no process-global RNG, no PYTHONHASHSEED sensitivity)."""
    x = (seed * 1000003 + i * 2654435761 + 0x9E3779B9) & 0xFFFFFFFF
    x ^= x >> 16
    x = (x * 0x45D9F3B) & 0xFFFFFFFF
    x ^= x >> 16
    x = (x * 0x45D9F3B) & 0xFFFFFFFF
    x ^= x >> 16
    return x / 2.0 ** 32


@dataclass(frozen=True)
class BackoffConfig:
    """Exponential schedule: attempt ``i`` waits
    ``min(max_delay, base * factor**i)``, optionally jittered by a
    deterministic ±``jitter`` fraction keyed on (seed, i)."""
    base: float = 0.05
    factor: float = 2.0
    max_delay: float = 2.0
    max_attempts: int = 5
    jitter: float = 0.0          # 0 = none; 0.1 = ±10%, deterministic
    seed: int = 0

    def delay(self, attempt: int) -> float:
        d = min(self.max_delay, self.base * self.factor ** max(0, attempt))
        if self.jitter > 0.0:
            d *= 1.0 + self.jitter * (2.0 * _unit(self.seed, attempt) - 1.0)
        return max(0.0, d)

    def schedule(self) -> List[float]:
        """The full inter-attempt delay sequence (len = max_attempts - 1)."""
        return [self.delay(i) for i in range(max(0, self.max_attempts - 1))]


def retry(fn: Callable[[], object], cfg: BackoffConfig, *,
          sleep: Optional[Callable[[float], None]] = None,
          retry_on: Tuple[Type[BaseException], ...] = (Exception,),
          on_retry: Optional[Callable[[int, BaseException, float], None]] = None,
          describe: str = "operation"):
    """Run ``fn`` up to ``cfg.max_attempts`` times with the backoff
    schedule between attempts.

    ``sleep`` is injectable (defaults to ``time.sleep``); ``on_retry``
    fires before each sleep with (attempt_index, exception, delay) — the
    hook the callers use to count retries in the obs registry.  Raises
    ``RetriesExhausted`` (chained to the last failure) when the budget
    runs out.
    """
    do_sleep = time.sleep if sleep is None else sleep
    last: Optional[BaseException] = None
    for attempt in range(max(1, cfg.max_attempts)):
        try:
            return fn()
        except retry_on as e:                       # noqa: PERF203
            last = e
            if attempt + 1 >= max(1, cfg.max_attempts):
                break
            d = cfg.delay(attempt)
            if on_retry is not None:
                on_retry(attempt, e, d)
            do_sleep(d)
    raise RetriesExhausted(
        f"{describe}: {max(1, cfg.max_attempts)} attempts failed") from last
