"""SPEC-RL rollout cache (paper §3.2).

Host-side store of the most recent rollout (tokens + behaviour log-probs)
per prompt.  A short history ring per prompt supports the *Delayed Reuse*
ablation (drafts from ``lag`` epochs/visits ago).  The cache is refreshed
immediately after every step for the prompts that were rolled — the paper's
"immediate cache-updating strategy" (Table 2 shows why it matters).

Sibling groups (DESIGN.md §9): GRPO rolls ``G`` responses per problem, and
the dataset assigns slot ``g`` of problem ``p`` the cache key
``p * G + g`` — so the cache doubles as the draft-engine's n-gram corpus:
``siblings(prompt_id)`` returns the other group members' latest rollouts,
a highly-correlated draft source for the continuation past the verified
prefix.  Group membership is registered on ``put`` and unregistered on
eviction, so LRU pressure never leaves a group pointing at evicted entries.
"""
from __future__ import annotations

from collections import OrderedDict, deque
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Set

import numpy as np


@dataclass
class CacheEntry:
    tokens: np.ndarray        # (L,) int32 response tokens (no pads)
    logprobs: np.ndarray      # (L,) float32 behaviour log-probs
    step: int                 # training step that produced it
    ends_with_eos: bool


class RolloutCache:
    """Maps prompt_id -> recent rollouts (most recent last).

    ``max_prompts`` bounds host memory: millions of distinct prompt_ids must
    not grow the store without limit, so when set, the least-recently-used
    prompt (by put *or* hit) is evicted on overflow.  An eviction only costs
    a cold-start rollout for that prompt on its next visit — SPEC-RL stays
    correct, it just loses the reuse speedup there — and ``stats()`` reports
    the eviction counter so the trainer can see the pressure.

    ``group_size`` enables sibling lookups: prompt_id ``p*G + g`` belongs to
    group ``p`` (the dataset's cache-key contract).  Pass an explicit
    ``group`` to ``put`` for non-contiguous schemes.
    """

    def __init__(self, history: int = 4, max_prompts: Optional[int] = None,
                 group_size: int = 0):
        self.history = max(2, history)
        assert max_prompts is None or max_prompts > 0, max_prompts
        assert group_size >= 0, group_size
        self.max_prompts = max_prompts
        self.group_size = group_size
        self._store: "OrderedDict[int, deque]" = OrderedDict()
        self._groups: Dict[int, Set[int]] = {}     # group id -> member pids
        self._group_of: Dict[int, int] = {}        # pid -> group id
        self.puts = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._store)

    def _default_group(self, pid: int) -> Optional[int]:
        return pid // self.group_size if self.group_size > 0 else None

    def _unlink_group(self, pid: int) -> None:
        gid = self._group_of.pop(pid, None)
        if gid is None:
            return
        members = self._groups.get(gid)
        if members is not None:
            members.discard(pid)
            if not members:
                del self._groups[gid]

    def put(self, prompt_id: int, tokens: np.ndarray, logprobs: np.ndarray,
            length: int, step: int, eos_id: int = 2,
            group: Optional[int] = None) -> None:
        tokens = np.asarray(tokens[:length], np.int32)
        logprobs = np.asarray(logprobs[:length], np.float32)
        ends = bool(length > 0 and tokens[-1] == eos_id)
        pid = int(prompt_id)
        q = self._store.get(pid)
        if q is None:
            q = self._store[pid] = deque(maxlen=self.history)
        else:
            self._store.move_to_end(pid)
        q.append(CacheEntry(tokens, logprobs, step, ends))
        gid = group if group is not None else self._default_group(pid)
        if gid is not None and self._group_of.get(pid) != gid:
            self._unlink_group(pid)
            self._group_of[pid] = gid
            self._groups.setdefault(gid, set()).add(pid)
        self.puts += 1
        while self.max_prompts is not None and len(self._store) > self.max_prompts:
            evicted, _ = self._store.popitem(last=False)  # least recently used
            self._unlink_group(evicted)
            self.evictions += 1

    def get(self, prompt_id: int, lag: int = 1) -> Optional[CacheEntry]:
        """lag=1: most recent rollout; lag=2: one before it (Delayed Reuse)."""
        q = self._store.get(int(prompt_id))
        if not q or len(q) < lag:
            self.misses += 1
            return None
        self.hits += 1
        self._store.move_to_end(int(prompt_id))      # LRU touch
        return q[-lag]

    def siblings(self, prompt_id: int, lag: int = 1) -> List[CacheEntry]:
        """Latest rollouts of the other members of ``prompt_id``'s group.

        The draft-engine corpus lookup (DESIGN.md §9).  Does NOT touch LRU
        recency and does not count as hits/misses — reading a sibling for
        n-gram material should not keep it alive over prompts that are
        actually being rolled.  Every returned entry is backed by the
        store (eviction unregisters members, so nothing dangles).
        """
        pid = int(prompt_id)
        gid = self._group_of.get(pid)
        if gid is None:
            gid = self._default_group(pid)
        if gid is None:
            return []
        members = self._groups.get(gid, set())
        out = []
        for other in sorted(members):
            if other == pid:
                continue
            q = self._store.get(other)
            assert q is not None, f"dangling sibling {other} in group {gid}"
            if len(q) >= lag:
                out.append(q[-lag])
        return out

    def batch_get(self, prompt_ids: Sequence[int], max_len: int, lag: int = 1
                  ) -> Dict[str, np.ndarray]:
        """Right-padded draft batch for verification.

        Returns dict with draft_tokens (B, max_len) int32, draft_logprobs
        (B, max_len) f32, draft_len (B,) int32 (0 = no draft),
        draft_eos (B,) bool.
        """
        B = len(prompt_ids)
        toks = np.zeros((B, max_len), np.int32)
        lps = np.zeros((B, max_len), np.float32)
        lens = np.zeros((B,), np.int32)
        eos = np.zeros((B,), bool)
        for i, pid in enumerate(prompt_ids):
            e = self.get(pid, lag)
            if e is None:
                continue
            L = min(len(e.tokens), max_len)
            toks[i, :L] = e.tokens[:L]
            lps[i, :L] = e.logprobs[:L]
            lens[i] = L
            eos[i] = e.ends_with_eos and L == len(e.tokens)
        return {"draft_tokens": toks, "draft_logprobs": lps,
                "draft_len": lens, "draft_eos": eos}

    def batch_siblings(self, prompt_ids: Sequence[int], lag: int = 1
                       ) -> List[List[np.ndarray]]:
        """Per-row n-gram corpora: each row's own latest rollout (when
        cached) plus its siblings' token arrays."""
        out: List[List[np.ndarray]] = []
        for pid in prompt_ids:
            corpus = []
            q = self._store.get(int(pid))
            if q and len(q) >= lag:
                corpus.append(q[-lag].tokens)
            corpus.extend(e.tokens for e in self.siblings(pid, lag))
            out.append(corpus)
        return out

    def batch_put(self, prompt_ids: Sequence[int], tokens: np.ndarray,
                  logprobs: np.ndarray, lengths: np.ndarray, step: int,
                  eos_id: int = 2) -> None:
        for i, pid in enumerate(prompt_ids):
            self.put(pid, tokens[i], logprobs[i], int(lengths[i]), step, eos_id)

    def stats(self) -> Dict[str, float]:
        total = self.hits + self.misses
        return {"size": len(self._store), "puts": self.puts,
                "hit_rate": self.hits / total if total else 0.0,
                "evictions": self.evictions,
                "groups": len(self._groups),
                "max_prompts": self.max_prompts or 0}
