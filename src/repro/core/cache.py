"""SPEC-RL rollout cache (paper §3.2).

Host-side store of the most recent rollout (tokens + behaviour log-probs)
per prompt.  A short history ring per prompt supports the *Delayed Reuse*
ablation (drafts from ``lag`` epochs/visits ago).  The cache is refreshed
immediately after every step for the prompts that were rolled — the paper's
"immediate cache-updating strategy" (Table 2 shows why it matters).
"""
from __future__ import annotations

from collections import OrderedDict, deque
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclass
class CacheEntry:
    tokens: np.ndarray        # (L,) int32 response tokens (no pads)
    logprobs: np.ndarray      # (L,) float32 behaviour log-probs
    step: int                 # training step that produced it
    ends_with_eos: bool


class RolloutCache:
    """Maps prompt_id -> recent rollouts (most recent last).

    ``max_prompts`` bounds host memory: millions of distinct prompt_ids must
    not grow the store without limit, so when set, the least-recently-used
    prompt (by put *or* hit) is evicted on overflow.  An eviction only costs
    a cold-start rollout for that prompt on its next visit — SPEC-RL stays
    correct, it just loses the reuse speedup there — and ``stats()`` reports
    the eviction counter so the trainer can see the pressure.
    """

    def __init__(self, history: int = 4, max_prompts: Optional[int] = None):
        self.history = max(2, history)
        assert max_prompts is None or max_prompts > 0, max_prompts
        self.max_prompts = max_prompts
        self._store: "OrderedDict[int, deque]" = OrderedDict()
        self.puts = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._store)

    def put(self, prompt_id: int, tokens: np.ndarray, logprobs: np.ndarray,
            length: int, step: int, eos_id: int = 2) -> None:
        tokens = np.asarray(tokens[:length], np.int32)
        logprobs = np.asarray(logprobs[:length], np.float32)
        ends = bool(length > 0 and tokens[-1] == eos_id)
        pid = int(prompt_id)
        q = self._store.get(pid)
        if q is None:
            q = self._store[pid] = deque(maxlen=self.history)
        else:
            self._store.move_to_end(pid)
        q.append(CacheEntry(tokens, logprobs, step, ends))
        self.puts += 1
        while self.max_prompts is not None and len(self._store) > self.max_prompts:
            self._store.popitem(last=False)          # least recently used
            self.evictions += 1

    def get(self, prompt_id: int, lag: int = 1) -> Optional[CacheEntry]:
        """lag=1: most recent rollout; lag=2: one before it (Delayed Reuse)."""
        q = self._store.get(int(prompt_id))
        if not q or len(q) < lag:
            self.misses += 1
            return None
        self.hits += 1
        self._store.move_to_end(int(prompt_id))      # LRU touch
        return q[-lag]

    def batch_get(self, prompt_ids: Sequence[int], max_len: int, lag: int = 1
                  ) -> Dict[str, np.ndarray]:
        """Right-padded draft batch for verification.

        Returns dict with draft_tokens (B, max_len) int32, draft_logprobs
        (B, max_len) f32, draft_len (B,) int32 (0 = no draft),
        draft_eos (B,) bool.
        """
        B = len(prompt_ids)
        toks = np.zeros((B, max_len), np.int32)
        lps = np.zeros((B, max_len), np.float32)
        lens = np.zeros((B,), np.int32)
        eos = np.zeros((B,), bool)
        for i, pid in enumerate(prompt_ids):
            e = self.get(pid, lag)
            if e is None:
                continue
            L = min(len(e.tokens), max_len)
            toks[i, :L] = e.tokens[:L]
            lps[i, :L] = e.logprobs[:L]
            lens[i] = L
            eos[i] = e.ends_with_eos and L == len(e.tokens)
        return {"draft_tokens": toks, "draft_logprobs": lps,
                "draft_len": lens, "draft_eos": eos}

    def batch_put(self, prompt_ids: Sequence[int], tokens: np.ndarray,
                  logprobs: np.ndarray, lengths: np.ndarray, step: int,
                  eos_id: int = 2) -> None:
        for i, pid in enumerate(prompt_ids):
            self.put(pid, tokens[i], logprobs[i], int(lengths[i]), step, eos_id)

    def stats(self) -> Dict[str, float]:
        total = self.hits + self.misses
        return {"size": len(self._store), "puts": self.puts,
                "hit_rate": self.hits / total if total else 0.0,
                "evictions": self.evictions,
                "max_prompts": self.max_prompts or 0}
