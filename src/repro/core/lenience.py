"""Lenience schedules.

The paper uses a fixed ℓ (grid-searched per algorithm: e^0.5 GRPO, e^0.3 PPO,
e^0.15 DAPO) and names adaptive scheduling as future work.  Beyond-paper we
add two controllers:

- ``LinearWarmupLenience``: ℓ ramps from 1 (exact speculative decoding) to
  the target over the first W steps — early training has the largest policy
  gap (paper Fig. 4c), so starting strict avoids early off-policy drift.
- ``AdaptiveLenience``: integral controller steering the *observed KL
  divergence* (or clip fraction) to a budget by moving log ℓ; keeps the
  diagnostics of Fig. 5 inside the stable region automatically.
"""
from __future__ import annotations

import math
from dataclasses import dataclass


class FixedLenience:
    def __init__(self, lenience: float):
        self.lenience = lenience

    def __call__(self, step: int) -> float:
        return self.lenience

    def update(self, observed: float) -> None:  # no-op
        pass


class LinearWarmupLenience:
    def __init__(self, target: float, warmup_steps: int):
        self.target = target
        self.warmup = max(1, warmup_steps)

    def __call__(self, step: int) -> float:
        frac = min(1.0, step / self.warmup)
        return math.exp(frac * math.log(self.target))

    def update(self, observed: float) -> None:
        pass


class AdaptiveLenience:
    """Integral controller: log ℓ += gain * (budget - observed).

    ``observed`` is a per-step diagnostic (KL divergence to the rollout
    distribution, or clip fraction).  When the rollouts drift too far
    off-policy the lenience shrinks toward exactness; when fully on-policy it
    grows to harvest more reuse.
    """

    def __init__(self, init: float = 1.0, budget: float = 0.05,
                 gain: float = 2.0, lo: float = 1.0, hi: float = math.e ** 2):
        self.log_l = math.log(init)
        self.budget = budget
        self.gain = gain
        self.lo, self.hi = math.log(lo), math.log(hi)

    def __call__(self, step: int) -> float:
        return math.exp(self.log_l)

    def update(self, observed: float) -> None:
        self.log_l += self.gain * (self.budget - observed)
        self.log_l = min(max(self.log_l, self.lo), self.hi)


def make_schedule(kind: str, **kw):
    return {"fixed": FixedLenience, "warmup": LinearWarmupLenience,
            "adaptive": AdaptiveLenience}[kind](**kw)
