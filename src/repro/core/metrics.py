"""Rollout diagnostics and diversity metrics from the paper, plus the
draft-engine telemetry accumulator.

- ROUGE-1 token overlap between consecutive-epoch rollouts (Fig. 2)
- Distinct-1 (Li et al. 2016) and Self-BLEU (Zhu et al. 2018) (Fig. 6)
- policy entropy / KL / clip-fraction summaries (Fig. 5) are computed in the
  RL trainer and aggregated here.
- ``DraftStats`` (DESIGN.md §9): acceptance / draft-length / tokens-per-
  forward counters shared by the drafted decode loops, the serving slot
  engine and the trainer step logs.
- ``FaultStats`` (DESIGN.md §10): recovery-event counters — timeouts,
  retries, sheds, quarantines, degradations — shared by the slot engine,
  the mesh server's gathered view and the trainer watchdog logs.
"""
from __future__ import annotations

import math
from collections import Counter
from dataclasses import dataclass
from typing import Dict, List, Sequence

import numpy as np


@dataclass
class DraftStats:
    """Draft-and-verify telemetry (DESIGN.md §9).

    Counters accumulate over decode forwards; the derived ratios are the
    three numbers that characterise a drafted decode run:

    * ``accept_rate``       — accepted / proposed draft tokens (the
      rejection-sampling yield; the DraftController's steering signal);
    * ``mean_draft_len``    — proposed draft tokens per drafting forward
      (how deep the controller is speculating);
    * ``tokens_per_forward``— emitted tokens per model forward, the
      end-to-end speedup lever (1.0 = vanilla decode; up to draft_k + 1).
    """
    forwards: int = 0          # decode forwards (drafted or not)
    draft_forwards: int = 0    # forwards that verified >= 1 draft token
    proposed: int = 0          # draft tokens verified
    accepted: int = 0          # draft tokens accepted by rejection sampling
    emitted: int = 0           # tokens actually kept (stored) by decode

    def add_step(self, forwards: int, proposed: int, accepted: int,
                 emitted: int, draft_forwards: int = 0) -> None:
        self.forwards += int(forwards)
        self.draft_forwards += int(draft_forwards)
        self.proposed += int(proposed)
        self.accepted += int(accepted)
        self.emitted += int(emitted)

    @property
    def accept_rate(self) -> float:
        return self.accepted / self.proposed if self.proposed else 0.0

    @property
    def mean_draft_len(self) -> float:
        return self.proposed / self.draft_forwards if self.draft_forwards \
            else 0.0

    @property
    def tokens_per_forward(self) -> float:
        return self.emitted / self.forwards if self.forwards else 0.0

    def as_dict(self, prefix: str = "") -> Dict[str, float]:
        return {
            f"{prefix}accept_rate": self.accept_rate,
            f"{prefix}mean_draft_len": self.mean_draft_len,
            f"{prefix}tokens_per_forward": self.tokens_per_forward,
            f"{prefix}draft_proposed": float(self.proposed),
            f"{prefix}draft_accepted": float(self.accepted),
            f"{prefix}decode_forwards": float(self.forwards),
            f"{prefix}decode_emitted": float(self.emitted),
            f"{prefix}draft_forwards": float(self.draft_forwards),
        }


@dataclass
class FaultStats:
    """Failure / recovery telemetry (DESIGN.md §10).

    Every recovery action the serving layer can take is a counter here, so
    "did the degradation ladder fire?" is always answerable from ``stats()``
    instead of from log archaeology.  The schema is uniform across engines
    (zeros when a path never fired), which lets ``MeshSlotServer.stats()``
    sum shards field-by-field and the trainer log the same keys.
    """
    injected: int = 0          # fault-plan events actually applied
    timeouts: int = 0          # deadline expiries -> slot reclamation
    retries: int = 0           # reclaimed requests re-admitted
    sheds: int = 0             # requests dropped by queue backpressure
    rejected: int = 0          # new submissions refused (reject-new policy)
    nan_events: int = 0        # non-finite logit rows caught by the guard
    quarantines: int = 0       # rows pulled out of the decode batch
    draft_errors: int = 0      # draft-source exceptions caught
    draft_disabled: int = 0    # rows whose drafting was switched off
    impl_fallbacks: int = 0    # decode_impl ladder steps (pallas->...->naive)
    failed: int = 0            # requests finished with a failure reason

    FIELDS = ("injected", "timeouts", "retries", "sheds", "rejected",
              "nan_events", "quarantines", "draft_errors", "draft_disabled",
              "impl_fallbacks", "failed")

    def add(self, **counts: int) -> None:
        for k, v in counts.items():
            assert k in self.FIELDS, k
            setattr(self, k, getattr(self, k) + int(v))

    def merge(self, other: "FaultStats") -> None:
        for k in self.FIELDS:
            setattr(self, k, getattr(self, k) + getattr(other, k))

    def as_dict(self, prefix: str = "fault_") -> Dict[str, float]:
        return {f"{prefix}{k}": float(getattr(self, k)) for k in self.FIELDS}

    @classmethod
    def from_dict(cls, d: Dict[str, float], prefix: str = "fault_"
                  ) -> "FaultStats":
        return cls(**{k: int(d.get(f"{prefix}{k}", 0)) for k in cls.FIELDS})


def rouge1_overlap(a: Sequence[int], b: Sequence[int]) -> float:
    """Unigram F1 overlap between two token sequences (Fig. 2 metric)."""
    if len(a) == 0 or len(b) == 0:
        return 0.0
    ca, cb = Counter(a), Counter(b)
    inter = sum((ca & cb).values())
    p = inter / max(len(b), 1)
    r = inter / max(len(a), 1)
    return 2 * p * r / (p + r) if (p + r) else 0.0


def batch_overlap(prev: List[np.ndarray], curr: List[np.ndarray]) -> float:
    vals = [rouge1_overlap(p.tolist(), c.tolist()) for p, c in zip(prev, curr)]
    return float(np.mean(vals)) if vals else 0.0


def prefix_match_fraction(prev: np.ndarray, curr: np.ndarray) -> float:
    """Longest-common-prefix fraction — the redundancy SPEC-RL exploits."""
    L = min(len(prev), len(curr))
    if L == 0:
        return 0.0
    neq = prev[:L] != curr[:L]
    lcp = int(np.argmax(neq)) if neq.any() else L
    return lcp / max(len(curr), 1)


def distinct_n(rollouts: List[np.ndarray], n: int = 1) -> float:
    """#unique n-grams / #n-grams across the batch (Distinct-1 for n=1)."""
    grams = set()
    total = 0
    for r in rollouts:
        toks = r.tolist()
        for i in range(len(toks) - n + 1):
            grams.add(tuple(toks[i:i + n]))
            total += 1
    return len(grams) / total if total else 0.0


def _ngram_counts(toks: List[int], n: int) -> Counter:
    return Counter(tuple(toks[i:i + n]) for i in range(len(toks) - n + 1))


def _bleu(cand: List[int], refs: List[List[int]], max_n: int = 4) -> float:
    if not cand:
        return 0.0
    logs = []
    for n in range(1, max_n + 1):
        cc = _ngram_counts(cand, n)
        if not cc:
            break
        best = Counter()
        for r in refs:
            rc = _ngram_counts(r, n)
            for g, c in rc.items():
                best[g] = max(best[g], c)
        match = sum(min(c, best[g]) for g, c in cc.items())
        total = sum(cc.values())
        logs.append(math.log(max(match, 1e-9) / total))
    if not logs:
        return 0.0
    score = math.exp(sum(logs) / len(logs))
    ref_len = min(len(r) for r in refs) if refs else 1
    bp = 1.0 if len(cand) >= ref_len else math.exp(1 - ref_len / max(len(cand), 1))
    return bp * score


def self_bleu(rollouts: List[np.ndarray], max_n: int = 4,
              sample: int = 16) -> float:
    """Mean BLEU of each rollout against the others (lower = more diverse)."""
    seqs = [r.tolist() for r in rollouts if len(r) > 0][:sample]
    if len(seqs) < 2:
        return 0.0
    vals = []
    for i, cand in enumerate(seqs):
        refs = seqs[:i] + seqs[i + 1:]
        vals.append(_bleu(cand, refs, max_n))
    return float(np.mean(vals))


def summarize(history: List[Dict[str, float]], keys: Sequence[str],
              percentiles: bool = False) -> Dict[str, float]:
    """Per-key mean over a metrics history; with ``percentiles=True`` each
    key additionally reports ``{k}_min/_max/_p50/_p95/_p99`` via the §11
    log-bucketed histogram helper (so long-run summaries see the tail, not
    just the mean — the watchdog's stall detector reads the same p95)."""
    from repro.obs import extend_summary
    out = {}
    for k in keys:
        vals = [h[k] for h in history if k in h]
        if not vals:
            continue
        out[k] = float(np.mean(vals))
        if percentiles:
            for suffix, v in extend_summary(vals).items():
                out[f"{k}_{suffix}"] = v
    return out
