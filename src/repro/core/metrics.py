"""Rollout diagnostics and diversity metrics from the paper.

- ROUGE-1 token overlap between consecutive-epoch rollouts (Fig. 2)
- Distinct-1 (Li et al. 2016) and Self-BLEU (Zhu et al. 2018) (Fig. 6)
- policy entropy / KL / clip-fraction summaries (Fig. 5) are computed in the
  RL trainer and aggregated here.
"""
from __future__ import annotations

import math
from collections import Counter
from typing import Dict, List, Sequence

import numpy as np


def rouge1_overlap(a: Sequence[int], b: Sequence[int]) -> float:
    """Unigram F1 overlap between two token sequences (Fig. 2 metric)."""
    if len(a) == 0 or len(b) == 0:
        return 0.0
    ca, cb = Counter(a), Counter(b)
    inter = sum((ca & cb).values())
    p = inter / max(len(b), 1)
    r = inter / max(len(a), 1)
    return 2 * p * r / (p + r) if (p + r) else 0.0


def batch_overlap(prev: List[np.ndarray], curr: List[np.ndarray]) -> float:
    vals = [rouge1_overlap(p.tolist(), c.tolist()) for p, c in zip(prev, curr)]
    return float(np.mean(vals)) if vals else 0.0


def prefix_match_fraction(prev: np.ndarray, curr: np.ndarray) -> float:
    """Longest-common-prefix fraction — the redundancy SPEC-RL exploits."""
    L = min(len(prev), len(curr))
    if L == 0:
        return 0.0
    neq = prev[:L] != curr[:L]
    lcp = int(np.argmax(neq)) if neq.any() else L
    return lcp / max(len(curr), 1)


def distinct_n(rollouts: List[np.ndarray], n: int = 1) -> float:
    """#unique n-grams / #n-grams across the batch (Distinct-1 for n=1)."""
    grams = set()
    total = 0
    for r in rollouts:
        toks = r.tolist()
        for i in range(len(toks) - n + 1):
            grams.add(tuple(toks[i:i + n]))
            total += 1
    return len(grams) / total if total else 0.0


def _ngram_counts(toks: List[int], n: int) -> Counter:
    return Counter(tuple(toks[i:i + n]) for i in range(len(toks) - n + 1))


def _bleu(cand: List[int], refs: List[List[int]], max_n: int = 4) -> float:
    if not cand:
        return 0.0
    logs = []
    for n in range(1, max_n + 1):
        cc = _ngram_counts(cand, n)
        if not cc:
            break
        best = Counter()
        for r in refs:
            rc = _ngram_counts(r, n)
            for g, c in rc.items():
                best[g] = max(best[g], c)
        match = sum(min(c, best[g]) for g, c in cc.items())
        total = sum(cc.values())
        logs.append(math.log(max(match, 1e-9) / total))
    if not logs:
        return 0.0
    score = math.exp(sum(logs) / len(logs))
    ref_len = min(len(r) for r in refs) if refs else 1
    bp = 1.0 if len(cand) >= ref_len else math.exp(1 - ref_len / max(len(cand), 1))
    return bp * score


def self_bleu(rollouts: List[np.ndarray], max_n: int = 4,
              sample: int = 16) -> float:
    """Mean BLEU of each rollout against the others (lower = more diverse)."""
    seqs = [r.tolist() for r in rollouts if len(r) > 0][:sample]
    if len(seqs) < 2:
        return 0.0
    vals = []
    for i, cand in enumerate(seqs):
        refs = seqs[:i] + seqs[i + 1:]
        vals.append(_bleu(cand, refs, max_n))
    return float(np.mean(vals))


def summarize(history: List[Dict[str, float]], keys: Sequence[str]) -> Dict[str, float]:
    out = {}
    for k in keys:
        vals = [h[k] for h in history if k in h]
        if vals:
            out[k] = float(np.mean(vals))
    return out
