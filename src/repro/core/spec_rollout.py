"""SPEC-RL speculative rollout orchestrator (paper §3, Algorithm 1 + §3.2).

Per training step, for each prompt in the batch:

1. retrieve the cached previous rollout as a *draft* (cold start ⇒ empty),
2. verify all drafts in ONE packed forward of the current policy,
3. keep the verified prefix ``y_prev[:n]``,
4. resume generation for every row in ONE packed decode,
5. assemble ``y = y_prev[:n] ⊕ y_cont`` and refresh the cache immediately.

Continuation runs on one of two engine paths (DESIGN.md §3):

* **one-pass** (default for ``spec``/``delayed`` on attention trunks): the
  verification forward is a *prefilling* one (verify_and_prefill), its KV
  caches are compacted to the accepted region by the cache_gather kernel
  (model.realign_decode_cache), and decoding resumes straight from the
  compacted cache (engine.resume_from_cache).  Prompt ⊕ accepted prefix is
  forwarded exactly once per step — no second prefill.
* **two-pass** (fallback for recurrent trunks / ``random`` / ``full`` and
  the ``one_pass='off'`` escape hatch): score-then-re-prefill, where
  ``left_align`` packs prompt ⊕ prefix (the paper's padding trick) and
  ``generate`` prefills it again.  Sample-for-sample identical to one-pass
  under the same PRNG key (tested).

Variants (paper Table 2 / §4.3): ``spec`` (the method), ``random`` (uniform
rejection position, stale behaviour log-probs, no verification pass),
``delayed`` (drafts from two visits ago), ``full`` (ℓ→∞: reuse everything),
``off`` (vanilla RLVR).
"""
from __future__ import annotations

import functools
import math
import time
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.drafting.controller import DraftConfig
from repro.engine.generate import (GenerateConfig, generate,
                                   resume_from_cache)
from repro.engine.sampling import split_key
from repro.models import model as M
from repro.models.config import ModelConfig

from .cache import RolloutCache
from .verify import verify_and_prefill, verify_drafts

VARIANTS = ("off", "spec", "random", "delayed", "full")


@dataclass(frozen=True)
class SpecConfig:
    variant: str = "spec"
    lenience: float = math.e ** 0.5     # paper default for GRPO
    cache_history: int = 4
    verify_impl: str = "auto"           # kernels.spec_verify impl selector
    one_pass: str = "auto"              # 'auto' | 'on' | 'off' — fused
                                        # verify→compact→resume engine path
    compact_impl: str = "auto"          # kernels.cache_gather impl selector
    backfill: str = "none"              # 'none' | 'slots' — continuous-
                                        # batching rollout (DESIGN.md §6):
                                        # finished rows immediately pick up
                                        # pending prompts via the serving
                                        # slot scheduler
    backfill_slots: int = 0             # decode-batch size for 'slots'
                                        # (0 -> half the prompt batch)
    cache_max_prompts: Optional[int] = None  # RolloutCache LRU bound
    draft: DraftConfig = DraftConfig()  # §9 continuation draft engine:
                                        # n-gram/sibling drafts + multi-token
                                        # verify inside the decode loop
                                        # (kind='off' = vanilla decoding)

    @property
    def cache_lag(self) -> int:
        return 2 if self.variant == "delayed" else 1

    @property
    def log_lenience(self) -> float:
        return math.log(self.lenience) if math.isfinite(self.lenience) else 1e9


@dataclass
class RolloutBatch:
    """Uniform output consumed by the RL trainer, whatever the variant."""
    prompt: np.ndarray            # (B, P) left-padded
    prompt_mask: np.ndarray       # (B, P)
    response: np.ndarray          # (B, N) right-padded
    response_mask: np.ndarray     # (B, N)
    behaviour_logprobs: np.ndarray  # (B, N) log-probs under the behaviour dist
    length: np.ndarray            # (B,)
    metrics: Dict[str, float] = field(default_factory=dict)


@functools.partial(jax.jit, static_argnames=("impl",))
def left_align(tokens, mask, impl: str = "gather"):
    """Shift each row so its last valid token sits in the last column.

    Requires the columns after the last valid one to be padding (true for
    [left-padded prompt | right-padded prefix] layouts).

    impl='gather' (default) lowers to ONE take_along_axis gather with
    modular source indices — the per-row dynamic roll lowers poorly on
    TPU.  impl='roll' is the legacy vmap'd per-row jnp.roll, kept as the
    fallback used by the non-spec variants (random / full ablations) and
    as the oracle for the gather path (bit-identical by construction).
    """
    W = tokens.shape[1]
    idx = jnp.arange(W, dtype=jnp.int32)[None, :]
    end = jnp.max(jnp.where(mask, idx + 1, 0), axis=1)      # (B,)
    shift = W - end
    if impl == "roll":
        roll = jax.vmap(lambda t, s: jnp.roll(t, s, axis=0))
        return roll(tokens, shift), roll(mask, shift)
    src = (idx - shift[:, None]) % W
    return (jnp.take_along_axis(tokens, src, axis=1),
            jnp.take_along_axis(mask, src, axis=1))


@functools.partial(jax.jit, static_argnames=("pad_id",))
def assemble(draft_tokens, prefix_lp, n, cont_tokens, cont_lp, cont_len,
             *, pad_id: int = 0):
    """y = draft[:n] ⊕ continuation, right-padded to N columns.

    prefix_lp: (B, N) behaviour log-probs to use for the reused prefix.
    Returns (tokens, lp, mask, length).
    """
    B, N = draft_tokens.shape
    j = jnp.arange(N, dtype=jnp.int32)[None, :]
    in_prefix = j < n[:, None]
    total = n + cont_len
    in_resp = j < total[:, None]

    gather = jnp.clip(j - n[:, None], 0, N - 1)
    cont_tok_shift = jnp.take_along_axis(cont_tokens, gather, axis=1)
    cont_lp_shift = jnp.take_along_axis(cont_lp, gather, axis=1)

    tokens = jnp.where(in_prefix, draft_tokens,
                       jnp.where(in_resp, cont_tok_shift, pad_id))
    lp = jnp.where(in_prefix, prefix_lp, jnp.where(in_resp, cont_lp_shift, 0.0))
    return tokens, lp, in_resp, total


def _vanilla(params, cfg, gen, prompts, prompt_mask, key, model_kwargs,
             mesh=None):
    out = generate(params, cfg, gen, prompts, prompt_mask, key, mesh=mesh,
                   **model_kwargs)
    return out


def use_drafting(cfg: ModelConfig, spec: SpecConfig, model_kwargs) -> bool:
    """Whether the §9 drafted decode loop replaces the vanilla while_loop.

    Needs rewindable per-slot KV state (attention-only trunk, no modality
    extras — model.supports_drafting); recurrent trunks and the random/full
    ablations (whose continuations ride the legacy two-pass path) decode
    vanilla."""
    return spec.draft.enabled and M.supports_drafting(cfg, model_kwargs)


def _emit_rollout_obs(spec, metrics, t0, stages, n=None):
    """§11 per-epoch rollout telemetry: stage spans on the 'rollout' lane
    plus registry histograms/counters for the paper's headline diagnostics
    (reuse length, acceptance, lenience).  Pure host side — the stage
    endpoints reuse the perf_counter stamps the metrics dict already took
    at existing block_until_ready boundaries, so with the default
    NULL_TRACER and an idle registry this adds no syncs and no clock reads
    beyond a few dict ops."""
    from repro.obs import get_registry, get_tracer
    tr = get_tracer()
    reg = get_registry()
    step = int(metrics.get("step", 0))
    t_end = max((ts + dur) for _, ts, dur in stages)
    if tr.enabled:
        tr.complete("rollout", "rollout", t0, t_end, cat="rollout",
                    step=step, n_reused=metrics.get("n_reused", 0),
                    accept_rate=metrics.get("accept_rate", 0.0))
        for name, ts, dur in stages:
            tr.complete(name, "rollout", ts, ts + dur, cat="rollout",
                        step=step)
    for name, ts, dur in stages:
        reg.observe(f"rollout.{name}_s", dur)
    reg.observe("rollout.step_s", t_end - t0)
    reg.observe("rollout.accept_rate", metrics.get("accept_rate", 0.0))
    reg.set("rollout.lenience", float(spec.lenience)
            if math.isfinite(spec.lenience) else 0.0)
    reg.set("rollout.step", float(step), agg="max")
    reg.inc("rollout.generated_tokens", metrics.get("n_generated", 0))
    reg.inc("rollout.reused_tokens", metrics.get("n_reused", 0))
    if n is not None:
        for v in np.asarray(n).reshape(-1):
            reg.observe("rollout.reuse_len", float(v))


def _draft_metrics(stats=None) -> Dict[str, float]:
    """Rollout-metric view of a DraftStats (zeros when drafting is off).

    ``accept_rate`` is already taken by SPEC-RL prefix verification, so the
    draft-engine ratios ride a ``draft_`` prefix; ``tokens_per_forward`` is
    the headline decode-efficiency number (1.0 = vanilla)."""
    from repro.core.metrics import DraftStats
    st = stats or DraftStats()
    return {"draft_accept_rate": st.accept_rate,
            "draft_mean_len": st.mean_draft_len,
            "tokens_per_forward": st.tokens_per_forward if st.forwards
            else 1.0,
            "decode_forwards": float(st.forwards)}


def _ledger_rows(led, B: int, prompt_mask):
    """Reserve + begin one §14 provenance row per batch row.

    Host-side only: reads the prompt mask (already materialised by every
    caller path) and touches no device code, so the lowered programs are
    byte-identical ledger on/off.  Returns (row_ids, prompt_lens)."""
    p_np = np.asarray(prompt_mask).sum(axis=1).astype(np.int64)
    base = led.reserve(B)
    rows = [base + b for b in range(B)]
    for b in range(B):
        led.begin_row(rows[b], int(p_np[b]))
    return rows, p_np


def use_one_pass(cfg: ModelConfig, spec: SpecConfig, model_kwargs) -> bool:
    """Whether the fused verify→compact→resume path applies.

    Needs per-slot KV state in every layer (attention-only trunk) and no
    vision prefix (whose extra cache slots the compactor does not model).
    """
    if spec.variant not in ("spec", "delayed") or spec.one_pass == "off":
        return False
    ok = (M.supports_cache_realign(cfg)
          and model_kwargs.get("prefix_embeds") is None)
    if spec.one_pass == "on" and not ok:
        raise ValueError("one_pass='on' requires an attention-only trunk "
                         "and no prefix_embeds")
    return ok


def rollout(params, cfg: ModelConfig, gen: GenerateConfig, spec: SpecConfig,
            prompts, prompt_mask, prompt_ids: Sequence[int],
            cache: Optional[RolloutCache], key, step: int, mesh=None,
            **model_kwargs) -> RolloutBatch:
    """One rollout step for a prompt batch.  Host-level: the cache is host
    memory; verification / compaction / generation / assembly are jit'd
    device calls.

    ``key`` may be (2,) — the classic batched PRNG stream — or (B, 2)
    per-request keys, which make every row's tokens independent of batch
    grouping (the contract the slot-backfill mode relies on).  With
    ``spec.backfill == 'slots'`` the whole step is drained through the
    serving slot scheduler instead of the fixed decode batch: rows that
    finish early immediately pick up pending prompts (DESIGN.md §6).

    ``mesh``: optional live Mesh (DESIGN.md §8).  Batch rows are placed over
    the data axes, params are expected pre-sharded by the caller, and every
    device stage — verify, compact, resume/generate — runs the same SPMD
    program, so the output is token-identical to the single-device path.
    """
    assert spec.variant in VARIANTS, spec.variant
    if spec.backfill == "slots":
        from repro.serving.rl_adapter import rollout_via_slots
        return rollout_via_slots(params, cfg, gen, spec, prompts, prompt_mask,
                                 prompt_ids, cache, key, step, mesh=mesh,
                                 **model_kwargs)
    assert spec.backfill == "none", spec.backfill
    if mesh is not None:
        from repro.distributed.mesh import shard_batch
        prompts, prompt_mask = shard_batch(mesh, (jnp.asarray(prompts),
                                                  jnp.asarray(prompt_mask)))
        if jnp.ndim(key) == 2:
            key = shard_batch(mesh, key)
    B, P = prompts.shape
    N = gen.max_new_tokens
    t0 = time.perf_counter()
    metrics: Dict[str, float] = {"step": step}
    from repro.obs import get_ledger
    from repro.obs.ledger import FRESH, REUSED_PREFIX
    led = get_ledger()

    use_cache = spec.variant != "off" and cache is not None
    drafts = cache.batch_get(prompt_ids, N, spec.cache_lag) if use_cache else None
    have_drafts = use_cache and int(drafts["draft_len"].sum()) > 0

    drafting = use_drafting(cfg, spec, model_kwargs)

    if not have_drafts:
        key, sub = split_key(key)
        rows = p_np = None
        if led.enabled:
            rows, p_np = _ledger_rows(led, B, prompt_mask)
        if drafting:
            from repro.drafting import drafted_generate
            corpus = cache.batch_siblings(prompt_ids, spec.cache_lag) \
                if use_cache else None
            # bind the rollout's rows so _DraftLoop's per-macro-step
            # provenance appends land on them instead of fresh rows
            if rows is not None:
                led.bind(rows)
            try:
                out = drafted_generate(params, cfg, gen, prompts, prompt_mask,
                                       sub, spec.draft, corpus=corpus,
                                       verify_impl=spec.verify_impl, mesh=mesh)
            finally:
                if rows is not None:
                    led.unbind()
        else:
            out = _vanilla(params, cfg, gen, prompts, prompt_mask, sub,
                           model_kwargs, mesh=mesh)
        resp, lp, length = out["tokens"], out["logprobs"], out["length"]
        resp_mask = jnp.arange(N)[None, :] < length[:, None]
        rollout_time = time.perf_counter() - t0
        metrics.update(
            n_generated=int(out["n_generated"]), n_reused=0,
            verified_prefix_mean=0.0, full_reuse_ratio=0.0,
            accept_rate=0.0, draft_coverage=0.0,
            verify_time=0.0, rollout_time=rollout_time,
            assembly_time=0.0, compact_time=0.0, decode_time=rollout_time,
            one_pass=0.0, prefill_passes=1.0,
            **_draft_metrics(out.get("stats")))
        _emit_rollout_obs(spec, metrics, t0,
                          [("generate", t0, rollout_time)])
        _update_cache(cache, prompt_ids, resp, lp, length, step, gen.eos_id)
        if rows is not None:
            len_np = np.asarray(length)
            for b in range(B):
                if not drafting:   # drafted rows were filled by _DraftLoop
                    led.append(rows[b], FRESH, int(len_np[b]))
                led.finalize(rows[b], int(p_np[b]) + int(len_np[b]))
        return RolloutBatch(
            prompt=np.asarray(prompts), prompt_mask=np.asarray(prompt_mask),
            response=np.asarray(resp), response_mask=np.asarray(resp_mask),
            behaviour_logprobs=np.asarray(lp), length=np.asarray(length),
            metrics=metrics)

    draft_tokens = jnp.asarray(drafts["draft_tokens"])
    draft_lp = jnp.asarray(drafts["draft_logprobs"])
    draft_len = jnp.asarray(drafts["draft_len"])
    draft_eos = jnp.asarray(drafts["draft_eos"])
    if mesh is not None:
        from repro.distributed.mesh import shard_batch
        draft_tokens, draft_lp, draft_len, draft_eos = shard_batch(
            mesh, (draft_tokens, draft_lp, draft_len, draft_eos))
    one_pass = use_one_pass(cfg, spec, model_kwargs)
    led_rows = led_p = None
    if led.enabled:
        led_rows, led_p = _ledger_rows(led, B, prompt_mask)

    tv0 = time.perf_counter()
    if one_pass:
        # ---- fused path: ONE forward over prompt ⊕ draft -----------------
        key, sub = split_key(key)
        ver = verify_and_prefill(params, cfg, prompts, prompt_mask,
                                 draft_tokens, draft_lp, draft_len, sub,
                                 spec.log_lenience, temperature=gen.temperature,
                                 top_p=gen.top_p, impl=spec.verify_impl,
                                 mesh=mesh, **model_kwargs)
        n = ver["n"]
        prefix_lp = ver["lp_curr"]
        accept_rate = float(ver["accept_rate"])
        jax.block_until_ready(n)
        verify_time = time.perf_counter() - tv0

        # compact the caches to [prompt | draft[:n]], left-aligned at W
        W = P + N
        tc0 = time.perf_counter()
        p_len = jnp.sum(prompt_mask, axis=1).astype(jnp.int32)
        caches = M.realign_decode_cache(cfg, ver["caches"],
                                        (N - n).astype(jnp.int32),
                                        p_len + n, W, impl=spec.compact_impl,
                                        mesh=mesh)
        jax.block_until_ready(jax.tree.leaves(caches)[0])
        compact_time = time.perf_counter() - tc0

        # resume decoding from the compacted cache — zero redundant prefill
        full_reuse = (n == draft_len) & draft_eos
        td0 = time.perf_counter()
        key, sub = split_key(key)
        if drafting:
            # §9: draft the continuation too — the n-gram index is seeded
            # with prompt ⊕ accepted prefix and the sibling corpus, so the
            # decode loop keeps speculating past the verified prefix
            from repro.drafting import drafted_resume
            n_np = np.asarray(n)
            mask_np = np.asarray(prompt_mask)
            prompts_np = np.asarray(prompts)
            dt_np = np.asarray(draft_tokens)
            contexts = [np.concatenate([prompts_np[b][mask_np[b]],
                                        dt_np[b, :int(n_np[b])]])
                        for b in range(B)]
            corpus = cache.batch_siblings(prompt_ids, spec.cache_lag)
            # §14: the verified prefix is reused provenance; bind the rows
            # so the drafted continuation extends them in place
            if led_rows is not None:
                for b in range(B):
                    led.append(led_rows[b], REUSED_PREFIX, int(n_np[b]))
                led.bind(led_rows)
            try:
                cont = drafted_resume(params, cfg, gen, caches,
                                      ver["seed_logits"], p_len + n, W, sub,
                                      spec.draft, contexts, corpus=corpus,
                                      initial_done=full_reuse,
                                      row_budget=N - n,
                                      verify_impl=spec.verify_impl, mesh=mesh)
            finally:
                if led_rows is not None:
                    led.unbind()
        else:
            cont = resume_from_cache(params, cfg, gen, caches,
                                     ver["seed_logits"], p_len + n, W, sub,
                                     initial_done=full_reuse,
                                     row_budget=N - n, mesh=mesh,
                                     **model_kwargs)
        jax.block_until_ready(cont["tokens"])
        decode_time = time.perf_counter() - td0
        rollout_time = compact_time + decode_time
        prefill_passes = 1.0
    else:
        # ---- two-pass path: rejection positions then re-prefill ----------
        if spec.variant in ("spec", "delayed"):
            key, sub = split_key(key)
            ver = verify_drafts(params, cfg, prompts, prompt_mask, draft_tokens,
                                draft_lp, draft_len, sub, spec.log_lenience,
                                temperature=gen.temperature, top_p=gen.top_p,
                                impl=spec.verify_impl, mesh=mesh,
                                **model_kwargs)
            n = ver["n"]
            prefix_lp = ver["lp_curr"]      # current-policy probs (exact)
            accept_rate = float(ver["accept_rate"])
            prefill_passes = 2.0            # score fwd + continuation prefill
        elif spec.variant == "random":
            key, sub = split_key(key)
            frac = (jax.vmap(lambda k: jax.random.uniform(k))(sub)
                    if jnp.ndim(sub) == 2 else jax.random.uniform(sub, (B,)))
            n = jnp.floor(frac * (draft_len + 1)).astype(jnp.int32)
            n = jnp.minimum(n, draft_len)
            prefix_lp = draft_lp            # stale behaviour probs (biased)
            accept_rate = float(jnp.where(draft_len.sum() > 0,
                                          n.sum() / jnp.maximum(draft_len.sum(), 1),
                                          0.0))
            prefill_passes = 1.0
        else:  # full
            n = draft_len
            prefix_lp = draft_lp
            accept_rate = 1.0
            prefill_passes = 1.0
        jax.block_until_ready(n)
        verify_time = time.perf_counter() - tv0

        full_reuse = (n == draft_len) & draft_eos
        tc0 = time.perf_counter()
        j = jnp.arange(N, dtype=jnp.int32)[None, :]
        prefix_mask = j < n[:, None]
        combined = jnp.concatenate(
            [prompts, jnp.where(prefix_mask, draft_tokens, gen.pad_id)], axis=1)
        combined_mask = jnp.concatenate([prompt_mask, prefix_mask], axis=1)
        align_impl = "gather" if spec.variant in ("spec", "delayed") else "roll"
        aligned_tokens, aligned_mask = left_align(combined, combined_mask,
                                                  impl=align_impl)
        jax.block_until_ready(aligned_tokens)
        compact_time = time.perf_counter() - tc0

        td0 = time.perf_counter()
        key, sub = split_key(key)
        cont = generate(params, cfg, gen, aligned_tokens, aligned_mask, sub,
                        initial_done=full_reuse, row_budget=N - n, mesh=mesh,
                        **model_kwargs)
        jax.block_until_ready(cont["tokens"])
        decode_time = time.perf_counter() - td0
        rollout_time = compact_time + decode_time

    # ---- assembly ----------------------------------------------------------
    ta0 = time.perf_counter()
    resp, lp, resp_mask, length = assemble(
        draft_tokens, prefix_lp, n, cont["tokens"], cont["logprobs"],
        cont["length"], pad_id=gen.pad_id)
    jax.block_until_ready(resp)
    assembly_time = time.perf_counter() - ta0

    _update_cache(cache, prompt_ids, resp, lp, length, step, gen.eos_id)

    if led_rows is not None:
        drafted_cont = one_pass and drafting
        n_fin = np.asarray(n)
        len_fin = np.asarray(length)
        for b in range(B):
            if not drafted_cont:   # drafted rows were extended by _DraftLoop
                led.append(led_rows[b], REUSED_PREFIX, int(n_fin[b]))
                led.append(led_rows[b], FRESH,
                           int(len_fin[b]) - int(n_fin[b]))
            led.finalize(led_rows[b], int(led_p[b]) + int(len_fin[b]))

    metrics.update(
        n_generated=int(cont["n_generated"]),
        n_reused=int(n.sum()),
        verified_prefix_mean=float(n.mean()),
        full_reuse_ratio=float(full_reuse.mean()),
        accept_rate=accept_rate,
        draft_coverage=float((draft_len > 0).mean()),
        verify_time=verify_time, rollout_time=rollout_time,
        assembly_time=assembly_time, compact_time=compact_time,
        decode_time=decode_time, one_pass=float(one_pass),
        prefill_passes=prefill_passes,
        **_draft_metrics(cont.get("stats") if isinstance(cont, dict)
                         else None))
    _emit_rollout_obs(spec, metrics, t0,
                      [("verify", tv0, verify_time),
                       ("compact", tc0, compact_time),
                       ("decode", td0, decode_time),
                       ("assembly", ta0, assembly_time)],
                      n=np.asarray(n))
    return RolloutBatch(
        prompt=np.asarray(prompts), prompt_mask=np.asarray(prompt_mask),
        response=np.asarray(resp), response_mask=np.asarray(resp_mask),
        behaviour_logprobs=np.asarray(lp), length=np.asarray(length),
        metrics=metrics)


def _update_cache(cache: Optional[RolloutCache], prompt_ids, resp, lp, length,
                  step, eos_id):
    if cache is None:
        return
    cache.batch_put(prompt_ids, np.asarray(resp), np.asarray(lp),
                    np.asarray(length), step, eos_id)
