"""SPEC-RL draft verification (Algorithm 1, the jit'd device side).

One teacher-forced forward of the current policy over prompt ⊕ draft yields
``p_curr``; the fused accept/first-reject reduction (Pallas kernel on TPU,
its oracle elsewhere) yields the rejection position ``n`` per row.

Two flavours:

* ``verify_drafts``      — scoring-only (discards activations); feeds the
  legacy two-pass path and non-cache callers.
* ``verify_and_prefill`` — *prefilling* verification: the same forward runs
  through ``M.prefill`` so the KV caches come out populated, alongside the
  per-row seed logits at the last accepted token.  Combined with
  model.realign_decode_cache + engine.resume_from_cache this makes the whole
  speculative step a single pass over prompt ⊕ draft (DESIGN.md §3).
"""
from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp

from repro.distributed.shard_wrap import sharded_spec_verify
from repro.engine.generate import positions_from_mask, score
from repro.engine.sampling import logprobs_of
from repro.kernels.spec_verify.ops import spec_verify
from repro.models import model as M
from repro.models.config import ModelConfig


def _accept_uniforms(key, B: int, N: int) -> jnp.ndarray:
    """Per-token acceptance uniforms u (B, N).

    key: (2,) — one stream for the whole batch — or (B, 2) per-row keys,
    where row b's uniforms depend only on its own key.  Per-row streams make
    the rejection index a per-request quantity, invariant to how requests
    are grouped into verification batches (serving spec-prefix admission,
    DESIGN.md §6)."""
    if jnp.ndim(key) == 2:
        return jax.vmap(lambda k: jax.random.uniform(k, (N,)))(key)
    return jax.random.uniform(key, (B, N))


@functools.partial(jax.jit, static_argnames=("cfg", "temperature", "top_p",
                                             "impl", "mesh"))
def verify_drafts(params, cfg: ModelConfig, prompt, prompt_mask,
                  draft_tokens, draft_logprobs, draft_len, key,
                  log_lenience, *, temperature: float = 1.0,
                  top_p: float = 1.0, impl: str = "auto", mesh=None,
                  **model_kwargs) -> Dict[str, jnp.ndarray]:
    """prompt: (B, P) left-padded; draft_*: (B, N) right-padded.

    Returns:
      n            (B,) first-rejection position in [0, draft_len]
      lp_curr      (B, N) current-policy log-probs of draft tokens
      accept_rate  ()    fraction of draft tokens accepted
    """
    B, P = prompt.shape
    N = draft_tokens.shape[1]
    didx = jnp.arange(N, dtype=jnp.int32)[None, :]
    draft_mask = didx < draft_len[:, None]

    full = jnp.concatenate([prompt, jnp.where(draft_mask, draft_tokens, 0)], axis=1)
    mask = jnp.concatenate([prompt_mask, draft_mask], axis=1)
    sc = score(params, cfg, full, mask, temperature=temperature, top_p=top_p,
               **model_kwargs)
    lp_curr = sc["logprobs"][:, P:]                       # (B, N)

    u = _accept_uniforms(key, B, N)
    n = _spec_verify(mesh, lp_curr, draft_logprobs, u, draft_len,
                     log_lenience, impl)

    total = jnp.maximum(draft_len.sum(), 1)
    accept_rate = n.sum() / total
    return {"n": n, "lp_curr": lp_curr, "accept_rate": accept_rate}


def _spec_verify(mesh, lp_curr, draft_logprobs, u, draft_len, log_lenience,
                 impl):
    """Dispatch the accept/first-reject kernel, via §8 shard_map on a mesh."""
    if mesh is not None:
        return sharded_spec_verify(mesh, lp_curr, draft_logprobs, u,
                                   draft_len, log_lenience, impl=impl)
    return spec_verify(lp_curr, draft_logprobs, u, draft_len, log_lenience,
                       impl=impl)


@functools.partial(jax.jit, static_argnames=("cfg", "temperature", "top_p",
                                             "impl", "mesh"))
def verify_and_prefill(params, cfg: ModelConfig, prompt, prompt_mask,
                       draft_tokens, draft_logprobs, draft_len, key,
                       log_lenience, *, temperature: float = 1.0,
                       top_p: float = 1.0, impl: str = "auto", mesh=None,
                       **model_kwargs) -> Dict[str, jnp.ndarray]:
    """Fused verification + engine prefill over [prompt | draft] (one pass).

    Same inputs and verification semantics as ``verify_drafts`` (identical
    token/mask/position layout and PRNG stream, so ``n`` and ``lp_curr``
    agree with the two-pass path), but the forward also populates decode
    caches sized W + N (W = P + N) so continuation never re-prefills.

    Extra returns on top of verify_drafts':
      caches       populated KV caches, slots [0, W) = [prompt | draft]
      seed_logits  (B, V) logits at the last accepted token (index P+n-1;
                   the last prompt token when n == 0) — the continuation's
                   first sampling distribution.
    """
    B, P = prompt.shape
    N = draft_tokens.shape[1]
    W = P + N
    didx = jnp.arange(N, dtype=jnp.int32)[None, :]
    draft_mask = didx < draft_len[:, None]

    full = jnp.concatenate([prompt, jnp.where(draft_mask, draft_tokens, 0)], axis=1)
    mask = jnp.concatenate([prompt_mask, draft_mask], axis=1)
    positions = positions_from_mask(mask)
    extras = {k: model_kwargs.get(k) for k in
              ("encoder_out", "encoder_positions")}
    caches = M.init_cache(cfg, B, W + N)
    if mesh is not None:
        from repro.distributed.mesh import constrain_caches
        caches = constrain_caches(cfg, caches, mesh)
    logits, caches = M.prefill(params, cfg, full, positions, caches, **extras)

    # same token-logprob extraction as engine.score (logits[t] -> token t+1)
    lp_next = logprobs_of(logits[:, :-1], full[:, 1:], temperature, top_p)
    lp = jnp.concatenate([jnp.zeros_like(lp_next[:, :1]), lp_next], axis=1)
    valid = mask & jnp.concatenate([jnp.zeros_like(mask[:, :1]), mask[:, :-1]],
                                   axis=1)
    lp_curr = jnp.where(valid, lp, 0.0)[:, P:]            # (B, N)

    u = _accept_uniforms(key, B, N)
    n = _spec_verify(mesh, lp_curr, draft_logprobs, u, draft_len,
                     log_lenience, impl)

    seed_idx = P + n.astype(jnp.int32) - 1                # n==0 -> last prompt tok
    seed_logits = jnp.take_along_axis(
        logits, seed_idx[:, None, None], axis=1)[:, 0]

    total = jnp.maximum(draft_len.sum(), 1)
    return {"n": n, "lp_curr": lp_curr, "accept_rate": n.sum() / total,
            "caches": caches, "seed_logits": seed_logits}


# §14 recompile sentinel enrollment (obs/alerts.py): both verify entry
# points — the two-pass scorer and the fused one-pass admission program
from repro.obs.alerts import register_jit_entry  # noqa: E402

register_jit_entry("verify_drafts", verify_drafts)
register_jit_entry("verify_and_prefill", verify_and_prefill)
