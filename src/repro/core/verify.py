"""SPEC-RL draft verification (Algorithm 1, the jit'd device side).

One teacher-forced forward of the current policy over prompt ⊕ draft yields
``p_curr``; the fused accept/first-reject reduction (Pallas kernel on TPU,
its oracle elsewhere) yields the rejection position ``n`` per row.
"""
from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp

from repro.engine.generate import positions_from_mask, score
from repro.kernels.spec_verify.ops import spec_verify
from repro.models.config import ModelConfig


@functools.partial(jax.jit, static_argnames=("cfg", "temperature", "top_p",
                                             "impl"))
def verify_drafts(params, cfg: ModelConfig, prompt, prompt_mask,
                  draft_tokens, draft_logprobs, draft_len, key,
                  log_lenience, *, temperature: float = 1.0,
                  top_p: float = 1.0, impl: str = "auto",
                  **model_kwargs) -> Dict[str, jnp.ndarray]:
    """prompt: (B, P) left-padded; draft_*: (B, N) right-padded.

    Returns:
      n            (B,) first-rejection position in [0, draft_len]
      lp_curr      (B, N) current-policy log-probs of draft tokens
      accept_rate  ()    fraction of draft tokens accepted
    """
    B, P = prompt.shape
    N = draft_tokens.shape[1]
    didx = jnp.arange(N, dtype=jnp.int32)[None, :]
    draft_mask = didx < draft_len[:, None]

    full = jnp.concatenate([prompt, jnp.where(draft_mask, draft_tokens, 0)], axis=1)
    mask = jnp.concatenate([prompt_mask, draft_mask], axis=1)
    sc = score(params, cfg, full, mask, temperature=temperature, top_p=top_p,
               **model_kwargs)
    lp_curr = sc["logprobs"][:, P:]                       # (B, N)

    u = jax.random.uniform(key, (B, N))
    n = spec_verify(lp_curr, draft_logprobs, u, draft_len, log_lenience,
                    impl=impl)

    total = jnp.maximum(draft_len.sum(), 1)
    accept_rate = n.sum() / total
    return {"n": n, "lp_curr": lp_curr, "accept_rate": accept_rate}
