"""Prompt dataset + epoch iterator with group (rollout-N) expansion.

The paper trains for tens of epochs over a small curated set — exactly the
regime where consecutive-epoch rollouts overlap.  ``PromptDataset`` yields
batches of (prompt row, cache key); each prompt is repeated ``group_size``
times and slot ``g`` of prompt ``p`` gets the stable cache key
``p * group_size + g`` so SPEC-RL reuses the previous epoch's rollout of the
*same slot*.
"""
from __future__ import annotations

import random
from dataclasses import dataclass
from typing import Iterator, List, Sequence, Tuple

import numpy as np

from repro.rewards.mathgen import Problem
from .tokenizer import BOS_ID, PAD_ID, encode


@dataclass
class PromptBatch:
    tokens: np.ndarray        # (B, P) left-padded int32
    mask: np.ndarray          # (B, P) bool
    cache_keys: List[int]     # (B,) stable SPEC-RL cache ids
    answers: List[int]        # (B,)
    problem_ids: List[int]    # (B,)
    epoch: int


class PromptDataset:
    def __init__(self, problems: Sequence[Problem], max_prompt_len: int = 32,
                 seed: int = 0):
        self.problems = list(problems)
        self.max_prompt_len = max_prompt_len
        self.seed = seed
        self._encoded = [encode(p.prompt_text)[:max_prompt_len]
                         for p in self.problems]

    def __len__(self) -> int:
        return len(self.problems)

    def _pack(self, idxs: List[int], group_size: int, epoch: int) -> PromptBatch:
        rows, keys, answers, pids = [], [], [], []
        for i in idxs:
            for g in range(group_size):
                rows.append(self._encoded[i])
                keys.append(i * group_size + g)
                answers.append(self.problems[i].answer)
                pids.append(self.problems[i].problem_id)
        P = self.max_prompt_len
        B = len(rows)
        toks = np.full((B, P), PAD_ID, np.int32)
        mask = np.zeros((B, P), bool)
        for r, ids in enumerate(rows):
            L = len(ids)
            toks[r, P - L:] = ids          # left padding
            mask[r, P - L:] = True
        return PromptBatch(toks, mask, keys, answers, pids, epoch)

    def epochs(self, prompts_per_batch: int, group_size: int,
               num_epochs: int, shuffle: bool = True
               ) -> Iterator[PromptBatch]:
        """Yields batches; each epoch visits every prompt once."""
        n = len(self.problems)
        for epoch in range(num_epochs):
            order = list(range(n))
            if shuffle:
                random.Random(self.seed + epoch).shuffle(order)
            for s in range(0, n - prompts_per_batch + 1, prompts_per_batch):
                yield self._pack(order[s:s + prompts_per_batch],
                                 group_size, epoch)

    def sample_batch(self, rng: random.Random, prompts_per_batch: int,
                     group_size: int, epoch: int = 0) -> PromptBatch:
        idxs = rng.sample(range(len(self.problems)),
                          min(prompts_per_batch, len(self.problems)))
        return self._pack(idxs, group_size, epoch)
