"""Character-level tokenizer for the synthetic verifiable-math task.

Fixed special ids: pad=0, bos=1, eos=2.  Vocabulary covers digits, operators
and a small alphabet so prompts like ``"17+25="`` and CoT-ish responses like
``"17+25=42"`` round-trip exactly.
"""
from __future__ import annotations

from typing import List

PAD_ID = 0
BOS_ID = 1
EOS_ID = 2

_CHARS = "0123456789+-*/=()., ?abcdefghijklmnopqrstuvwxyz"
_CHAR_TO_ID = {c: i + 3 for i, c in enumerate(_CHARS)}
_ID_TO_CHAR = {i + 3: c for i, c in enumerate(_CHARS)}

VOCAB_SIZE = 3 + len(_CHARS)


def encode(text: str, add_bos: bool = True, add_eos: bool = False) -> List[int]:
    ids = [BOS_ID] if add_bos else []
    ids += [_CHAR_TO_ID[c] for c in text.lower() if c in _CHAR_TO_ID]
    if add_eos:
        ids.append(EOS_ID)
    return ids


def decode(ids, stop_at_eos: bool = True) -> str:
    out = []
    for i in ids:
        i = int(i)
        if i == EOS_ID and stop_at_eos:
            break
        if i in (PAD_ID, BOS_ID):
            continue
        out.append(_ID_TO_CHAR.get(i, ""))
    return "".join(out)
