"""Runtime mesh execution for the SPEC-RL loop (DESIGN.md §8).

``launch/`` owns the *static* side of distribution — partition rules,
ShapeDtypeStruct dry-runs, HLO analysis.  This module owns the *runtime*
side: a ``MeshConfig`` the launchers plumb into the trainer / rollout /
serving stack, plus the helpers that place live arrays on the mesh:

* params / optimizer moments via the ``param_spec`` rules,
* batch rows over the ``data`` axis,
* decode caches batch-over-``data`` and KV-heads-over-``model``.

Everything degrades to single-device execution: ``MeshConfig.build()``
returns ``None`` when the mesh is trivial (1×1) or the host exposes too few
devices (unless ``require``), and every helper accepts ``mesh=None`` as a
no-op.  Meshes may also lack an axis entirely (the per-data-shard serving
submeshes carry only ``model``), so all axis lookups are presence-checked.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig

from .sharding import params_pspecs

# NOTE: the partitionable-threefry flag this module's identity contract
# relies on is pinned in repro/__init__.py — uniformly for every repro
# entry point, not as a side effect of importing mesh support.


@dataclass(frozen=True)
class MeshConfig:
    """Axis sizes for the runtime (data, model) mesh.

    ``build()`` materialises the mesh over the first ``data * model`` host
    devices; a trivial (1, 1) config — or too few devices with
    ``require=False`` — yields ``None``, the single-device fallback every
    consumer treats as "run exactly the unsharded path".
    """
    data: int = 1
    model: int = 1
    require: bool = False

    @property
    def size(self) -> int:
        return self.data * self.model

    def build(self) -> Optional[Mesh]:
        if self.size <= 1:
            return None
        if jax.device_count() < self.size:
            if self.require:
                raise RuntimeError(
                    f"MeshConfig({self.data}x{self.model}) needs {self.size} "
                    f"devices, found {jax.device_count()} (set "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=N for "
                    "virtual CPU devices)")
            return None
        return jax.make_mesh((self.data, self.model), ("data", "model"))


# ------------------------------------------------------------------ axis info


def data_size(mesh: Optional[Mesh]) -> int:
    if mesh is None:
        return 1
    out = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            out *= mesh.shape[a]
    return out


def model_size(mesh: Optional[Mesh]) -> int:
    if mesh is None or "model" not in mesh.axis_names:
        return 1
    return mesh.shape["model"]


def _data_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def data_submeshes(mesh: Mesh):
    """One model-only submesh per ``data``-shard row of the device grid.

    The per-shard serving schedulers (DESIGN.md §8) each run on one of
    these: disjoint devices, ``model`` axis only.  A mesh without a data
    axis is its own (single) submesh.
    """
    import numpy as np
    if "data" not in mesh.axis_names or mesh.shape["data"] <= 1:
        return [mesh]
    axis = mesh.axis_names.index("data")
    devs = np.moveaxis(np.asarray(mesh.devices), axis, 0)
    names = tuple(a for a in mesh.axis_names if a != "data")
    if not names:
        devs = devs.reshape(devs.shape[0], 1)
        names = ("model",)
    return [Mesh(d, names) for d in devs]


def batch_pspec(mesh: Mesh, ndim: int, batch: int) -> P:
    """Leading-dim partition over the data axes; replicate when indivisible."""
    axes = _data_axes(mesh)
    dsz = data_size(mesh)
    if not axes or dsz <= 1 or batch % dsz != 0 or batch < dsz:
        return P(*([None] * ndim))
    first = axes if len(axes) > 1 else axes[0]
    return P(first, *([None] * (ndim - 1)))


# ------------------------------------------------------------------ placement


def replicate(mesh: Optional[Mesh], tree):
    if mesh is None:
        return tree
    return jax.device_put(tree, NamedSharding(mesh, P()))


def host_fetch(tree):
    """Gather a (possibly sharded) pytree to host numpy arrays.

    The §12 weight-publication path uses this when the sync channel must
    carry a self-contained copy across failure domains (a transport that
    serialises, or a producer on another host) — by default WeightSync
    hands the live device arrays through untouched, which keeps the K=0
    identity contract and the sharding layout intact."""
    return jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)


def shard_batch(mesh: Optional[Mesh], tree):
    """device_put every leaf with its leading dim over the data axes."""
    if mesh is None:
        return tree
    return jax.tree.map(
        lambda x: jax.device_put(x, NamedSharding(
            mesh, batch_pspec(mesh, jnp.ndim(x), jnp.shape(x)[0]
                              if jnp.ndim(x) else 1))), tree)


def param_shardings(mesh: Mesh, cfg: ModelConfig, params):
    pspecs = params_pspecs(cfg, params, model_size(mesh))
    return jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                        is_leaf=lambda x: isinstance(x, P))


def shard_params(mesh: Optional[Mesh], cfg: ModelConfig, params):
    """Place a params pytree per the ``param_spec`` partition rules."""
    if mesh is None:
        return params
    return jax.device_put(params, param_shardings(mesh, cfg, params))


def shard_opt_state(mesh: Optional[Mesh], cfg: ModelConfig, params, opt_state):
    """AdamW moments follow the param layout; ``step`` is replicated."""
    if mesh is None:
        return opt_state
    sh = param_shardings(mesh, cfg, params)
    return {"mu": jax.device_put(opt_state["mu"], sh),
            "nu": jax.device_put(opt_state["nu"], sh),
            "step": jax.device_put(opt_state["step"], NamedSharding(mesh, P()))}


# ------------------------------------------------------------------ KV caches


def _cache_leaf_pspec(shape, cfg: ModelConfig, mesh: Mesh,
                      kv_heads: bool) -> P:
    """Partition for one trunk-cache leaf (leading axis = scan run)."""
    b_ax = None
    if len(shape) >= 2:
        dsz = data_size(mesh)
        axes = _data_axes(mesh)
        if axes and dsz > 1 and shape[1] % dsz == 0 and shape[1] >= dsz:
            b_ax = axes if len(axes) > 1 else axes[0]
    spec = [None, b_ax] + [None] * (len(shape) - 2)
    if kv_heads:
        msz = model_size(mesh)
        if msz > 1 and shape[2] % msz == 0 and shape[2] >= msz:
            spec[2] = "model"
    return P(*spec)


def decode_cache_pspecs(cfg: ModelConfig, caches, mesh: Mesh, *,
                        batch: bool = True):
    """Same-structure pytree of PartitionSpecs for a trunk decode cache.

    Batch (axis 1, after the scan-run axis) shards over ``data``; the KV head
    axis of attention ``k``/``v`` buffers shards over ``model`` when the head
    count divides (uneven heads — MQA/GQA with few KV heads — replicate,
    mirroring ``param_spec``'s kv rule).  MLA latents (``ckv``/``krope``)
    and recurrent state shard on batch only.  ``batch=False`` suppresses the
    data-axis entry — the serving slot engine keeps its persistent decode
    batch whole per data shard (one scheduler per shard, DESIGN.md §8) and
    shards only the KV head axis.
    """
    out = []
    for run in caches:
        new_run = {}
        for group, sub in run.items():
            paged = "table" in sub
            new_sub = {}
            for name, leaf in sub.items():
                kv_heads = group == "self" and name in ("k", "v") \
                    and leaf.ndim == 5
                if paged:
                    # §13 paged layout: pool axis 1 is the GLOBAL block
                    # pool — rows of DIFFERENT slots interleave there, so
                    # it must never shard like a batch axis.  Replicate
                    # everything except the GQA pool head axis (axis 2,
                    # same slot as dense), which shards over ``model``.
                    spec = [None] * leaf.ndim
                    if kv_heads:
                        msz = model_size(mesh)
                        if msz > 1 and leaf.shape[2] % msz == 0:
                            spec[2] = "model"
                    new_sub[name] = P(*spec)
                    continue
                spec = _cache_leaf_pspec(leaf.shape, cfg, mesh, kv_heads)
                if not batch and len(spec) > 1:
                    spec = P(spec[0], None, *spec[2:])
                new_sub[name] = spec
            new_run[group] = new_sub
        out.append(new_run)
    return out


def cache_shardings(cfg: ModelConfig, caches, mesh: Mesh, *,
                    batch: bool = True):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        decode_cache_pspecs(cfg, caches, mesh, batch=batch),
                        is_leaf=lambda x: isinstance(x, P))


def constrain_caches(cfg: ModelConfig, caches, mesh: Optional[Mesh], *,
                     batch: bool = True):
    """``with_sharding_constraint`` every cache leaf (jit-traceable)."""
    if mesh is None:
        return caches
    return jax.tree.map(jax.lax.with_sharding_constraint, caches,
                        cache_shardings(cfg, caches, mesh, batch=batch))


def shard_caches(cfg: ModelConfig, caches, mesh: Optional[Mesh], *,
                 batch: bool = True):
    """Eager placement of a live cache pytree (serving persistent caches)."""
    if mesh is None:
        return caches
    return jax.device_put(caches, cache_shardings(cfg, caches, mesh,
                                                  batch=batch))
