"""shard_map boundaries around the Pallas kernels (DESIGN.md §8).

GSPMD partitions plain jnp code automatically, but a ``pl.pallas_call`` is a
black box to the partitioner: under a mesh it must be wrapped in
``shard_map`` so each device runs the kernel on its *local* block with a
static per-shard shape (grids, BlockSpecs and scalar-prefetch lengths are
shape-derived).  This module is the single place those wrappers live:

* ``sharded_decode_attention`` — batch over ``data``, query/KV heads over
  ``model`` (head sharding only when both head counts divide; uneven-head
  GQA/MQA replicates heads, mirroring ``param_spec``'s kv rule);
* ``sharded_spec_verify``     — batch over ``data``;
* ``shard_map_call``          — generic helper for the cache-surgery kernels
  (``cache_gather`` rolls shard batch rows, ``cache_slot_write`` shards the
  KV head axis with slot indices replicated — see models/model.py).

Every wrapper degrades: when the mesh lacks the relevant axis or a dimension
does not divide, it falls back to the unwrapped (GSPMD- or single-device-)
call, so callers thread ``mesh`` unconditionally.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def batch_axis_name(mesh: Mesh):
    """The data axes as a PartitionSpec entry (None when absent)."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names
                 and mesh.shape[a] > 1)
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


def _axis_size(mesh: Mesh, ax) -> int:
    if ax is None:
        return 1
    axes = ax if isinstance(ax, tuple) else (ax,)
    out = 1
    for a in axes:
        out *= mesh.shape[a]
    return out


def batch_shardable(mesh: Optional[Mesh], batch: int) -> bool:
    if mesh is None:
        return False
    ax = batch_axis_name(mesh)
    d = _axis_size(mesh, ax)
    return ax is not None and d > 1 and batch % d == 0 and batch >= d


def model_axis(mesh: Mesh, *dims: int):
    """'model' when present and every ``dim`` divides it, else None."""
    if "model" not in mesh.axis_names or mesh.shape["model"] <= 1:
        return None
    m = mesh.shape["model"]
    if all(d % m == 0 and d >= m for d in dims):
        return "model"
    return None


def shard_map_call(mesh: Mesh, fn, in_specs, out_specs, *args):
    """One-shot shard_map application (per-shard shapes stay static)."""
    return shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)(*args)


# ------------------------------------------------------------ decode attention


def sharded_decode_attention(mesh: Optional[Mesh], q, k, v, q_pos, k_pos,
                             lengths, starts, *, window: int = 0,
                             impl: str = "auto", block_k: int = 128):
    """Mesh-partitioned flash-decode attention.

    q: (B, Hq, T, Dk) (T == 1 classic decode, k+1 draft-verify block);
    k: (B, Hkv, S, Dk); v: (B, Hkv, S, Dv); q_pos: (B,) or (B, T);
    k_pos: (B, S); lengths/starts: (B,) int32 (must be materialised — no
    None — so the shard_map arg tree is static).  Batch shards over the
    data axes, heads over ``model`` when both Hq and Hkv divide it.
    """
    from repro.kernels.decode_attention.ops import decode_attention
    B, Hq = q.shape[0], q.shape[1]
    Hkv = k.shape[1]

    d_ax = batch_axis_name(mesh) if batch_shardable(mesh, B) else None
    h_ax = model_axis(mesh, Hq, Hkv) if mesh is not None else None
    if d_ax is None and h_ax is None:
        return decode_attention(q, k, v, q_pos, k_pos, lengths, starts,
                                window=window, impl=impl, block_k=block_k)

    def inner(q, k, v, qp, kp, ln, st):
        return decode_attention(q, k, v, qp, kp, ln, st, window=window,
                                impl=impl, block_k=block_k)

    head4 = P(d_ax, h_ax, None, None)
    rows = P(d_ax)
    qp_spec = rows if q_pos.ndim == 1 else P(d_ax, None)
    return shard_map_call(
        mesh, inner,
        (head4, head4, head4, qp_spec, P(d_ax, None), rows, rows),
        head4, q, k, v, q_pos, k_pos, lengths, starts)


# ------------------------------------------------------------------ spec verify


def sharded_spec_verify(mesh: Optional[Mesh], lp_curr, lp_prev, u, valid_len,
                        log_lenience, *, impl: str = "auto"):
    """Mesh-partitioned accept/first-reject reduction (batch over data)."""
    from repro.kernels.spec_verify.ops import spec_verify
    B = lp_curr.shape[0]
    if not batch_shardable(mesh, B):
        return spec_verify(lp_curr, lp_prev, u, valid_len, log_lenience,
                           impl=impl)
    d_ax = batch_axis_name(mesh)
    r2, r1 = P(d_ax, None), P(d_ax)

    def inner(lc, lp, uu, vl, ll):
        return spec_verify(lc, lp, uu, vl, ll, impl=impl)

    return shard_map_call(
        mesh, inner, (r2, r2, r2, r1, P()), r1,
        lp_curr, lp_prev, u, valid_len,
        jnp.asarray(log_lenience, jnp.float32))
