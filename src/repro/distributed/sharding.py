"""Path-based parameter partition rules -> PartitionSpecs.

Tensor parallelism on the ``model`` axis, data parallelism on
``(pod, data)``.  Rules are matched on the trailing components of the
flattened parameter path; stacked (scanned) trunk parameters get a leading
``None`` axis automatically.

Key decisions (see DESIGN.md §4):
- GQA kv projections shard on `model` only when kv_heads divide the axis;
  MQA/GQA with few kv heads replicates kv (standard practice).
- MoE experts use expert parallelism when num_experts % model == 0
  (deepseek-v3 256e, jamba 16e), else per-expert tensor parallelism
  (mixtral 8e on a 16-way axis).
- Optimizer moments are additionally sharded over `data` on their first
  sharded-free dimension (ZeRO-style) via ``zero_shard_spec`` — this is a
  beyond-paper lever exercised in §Perf.
"""
from __future__ import annotations

import re
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(f"#{p.idx}")
        else:
            parts.append(str(p))
    return "/".join(parts)


def _divisible(n: int, size: int) -> bool:
    return size > 0 and n % size == 0


def param_spec(path_str: str, shape: Tuple[int, ...], cfg: ModelConfig,
               model_size: int) -> P:
    """PartitionSpec for one parameter (without any leading scan axis)."""
    s = path_str
    ndim = len(shape)

    def col():   # (in, out) -> shard out
        return P(None, "model") if _divisible(shape[-1], model_size) else P()

    def row():   # (in, out) -> shard in
        return P("model", None) if _divisible(shape[-2], model_size) else P()

    # ---- embeddings / heads -------------------------------------------------
    if re.search(r"(^|/)embed$", s):
        return P("model", None) if _divisible(shape[0], model_size) else P()
    if "lm_head" in s and s.endswith("kernel"):
        return col()
    if "pos_table" in s:
        return P()
    if "value_head" in s:
        return P()

    # ---- attention ----------------------------------------------------------
    if re.search(r"attn/w[q]|wq_b", s) and s.endswith("kernel"):
        return col()
    if re.search(r"attn/w[kv]/kernel", s):
        kv_dim_ok = _divisible(cfg.num_kv_heads, model_size)
        return P(None, "model") if kv_dim_ok else P()
    if re.search(r"attn/w[kv]/bias", s):
        kv_dim_ok = _divisible(cfg.num_kv_heads, model_size)
        return P("model") if kv_dim_ok else P()
    if s.endswith("wq/bias"):
        return P("model") if _divisible(shape[-1], model_size) else P()
    if s.endswith("wo/kernel"):
        return row()
    if "wq_a" in s and s.endswith("kernel"):
        return col()
    if "wkv_a" in s:   # keep the MLA latent whole per device
        return P()
    if "wkv_b" in s and s.endswith("kernel"):
        return col()

    # ---- MoE ------------------------------------------------------------------
    if s.endswith("moe/router/kernel"):
        return P()
    if re.search(r"moe/w_(gate|up)$", s):           # (E, d, ff)
        if _divisible(shape[0], model_size):
            return P("model", None, None)           # expert parallel
        return P(None, None, "model") if _divisible(shape[-1], model_size) else P()
    if s.endswith("moe/w_down"):                    # (E, ff, d)
        if _divisible(shape[0], model_size):
            return P("model", None, None)
        return P(None, "model", None) if _divisible(shape[-2], model_size) else P()

    # ---- dense FFN (mlp / shared expert / rwkv channel-mix) -----------------
    if re.search(r"w_(gate|up)/kernel$", s) or s.endswith("channel_mix/wk/kernel"):
        return col()
    if s.endswith("w_down/kernel") or s.endswith("channel_mix/wv/kernel"):
        return row()
    if s.endswith("channel_mix/wr/kernel"):
        return col() if False else P()              # output gates full-d: replicate

    # ---- mamba -----------------------------------------------------------------
    if s.endswith("in_proj/kernel"):
        return col()
    if s.endswith("conv_w"):
        return P(None, "model") if _divisible(shape[-1], model_size) else P()
    if s.endswith("conv_b") or re.search(r"mamba/D$", s):
        return P("model") if _divisible(shape[-1], model_size) else P()
    if s.endswith("x_proj/kernel"):
        return row()
    if s.endswith("dt_proj/kernel"):
        return col()
    if re.search(r"A_log$", s):
        return P("model", None) if _divisible(shape[-2], model_size) else P()
    if s.endswith("out_proj/kernel"):
        return row()

    # ---- rwkv time mix -----------------------------------------------------------
    if re.search(r"time_mix/w[rkvg]/kernel$", s):
        return col()
    if s.endswith("time_mix/wo/kernel"):
        return row()

    # default: replicate (norms, small vectors, loras, router bias, ...)
    return P()


def shift_for_scan(spec: P) -> P:
    return P(None, *spec)


def params_pspecs(cfg: ModelConfig, params_shapes, model_size: int):
    """Build a pytree of PartitionSpecs mirroring ``params_shapes``.

    ``params_shapes`` is any pytree whose leaves expose ``.shape`` (arrays or
    ShapeDtypeStructs).  Trunk entries (under 'trunk' or 'encoder/trunk' or
    'mtp') with a stacked layer axis get the leading None.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shapes)
    specs = []
    for path, leaf in flat:
        ps = _path_str(path)
        stacked = "trunk" in ps and "#" in ps
        shape = leaf.shape
        base_shape = shape[1:] if stacked else shape
        spec = param_spec(ps, base_shape, cfg, model_size)
        if stacked:
            spec = shift_for_scan(spec)
        if len(spec) > len(shape):
            spec = P()
        specs.append(spec)
    return jax.tree_util.tree_unflatten(treedef, specs)


def zero_shard_spec(spec: P, shape: Tuple[int, ...], data_axes=("data",),
                    data_size: int = 16) -> P:
    """ZeRO-style optimizer-moment sharding: put the (pod,)data axes on the
    first dimension the param spec leaves unsharded and that divides."""
    parts = list(spec) + [None] * (len(shape) - len(spec))
    for i, (cur, dim) in enumerate(zip(parts, shape)):
        if cur is None and dim >= data_size and dim % data_size == 0:
            parts[i] = tuple(data_axes) if len(data_axes) > 1 else data_axes[0]
            break
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def make_shardings(mesh: Mesh, pspecs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_spec(mesh: Mesh, ndim: int, batch_size: int) -> P:
    axes = batch_axes(mesh)
    import numpy as np
    total = int(np.prod([mesh.shape[a] for a in axes]))
    if batch_size % total != 0 or batch_size < total:
        return P(*([None] * ndim))              # tiny batch: replicate
    first = axes if len(axes) > 1 else axes[0]
    return P(first, *([None] * (ndim - 1)))
