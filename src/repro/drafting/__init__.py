"""Draft engine: speculative continuation beyond the SPEC-RL prefix.

SPEC-RL only speculates on the *reused prefix* — once the verified prefix
diverges, every continuation token costs a full decode step.  This package
extends draft-and-verify into the continuation itself (DESIGN.md §9):

* ``NGramDraftSource``  — k-token proposals from a suffix hash map over
  the row's own prompt ⊕ generated stream plus its GRPO sibling
  trajectories (``RolloutCache.siblings``);
* ``DraftController``   — per-row adaptive draft length from a running
  acceptance-rate EMA (the ``core/lenience.py`` controller pattern);
* ``draft_step``        — the jit'd (k+1)-token verify forward with
  rejection-sampling acceptance (``kernels/spec_verify``) over the
  multi-token flash-decode path (``kernels/decode_attention``);
* ``drafted_generate`` / ``drafted_resume`` — host-driven decode loops
  mirroring ``engine/generate.generate`` / ``resume_from_cache``.

Greedy decoding is bit-exact against the vanilla loops; temperature /
top-p sampling is distribution-correct per token (tested both ways).
"""
from .controller import DraftConfig, DraftController
from .ngram import NGramDraftSource

__all__ = ["DraftConfig", "DraftController", "NGramDraftSource",
           "draft_step", "drafted_generate", "drafted_resume"]

_LAZY = {"draft_step": "step", "drafted_generate": "engine",
         "drafted_resume": "engine"}


def __getattr__(name):
    # engine/step pull in the model stack; loading them lazily lets
    # core.spec_rollout import DraftConfig without an import cycle
    if name in _LAZY:
        import importlib
        return getattr(importlib.import_module(f".{_LAZY[name]}", __name__),
                       name)
    raise AttributeError(name)
