"""Draft configuration and the per-row adaptive draft-length controller.

``DraftController`` follows the ``core/lenience.py`` controller pattern —
a small host-side object with a query method and an ``update`` fed by the
observed signal.  Here the signal is the per-row *running acceptance rate*
of drafted tokens, and the control variable is how many tokens to draft on
the row's next forward.  The lever is real because the decode loops
compile the verify block at the power-of-two cover of the widest live
proposal (``step.block_width``): rows whose drafts keep being rejected
fall back toward plain single-token decoding (k -> k_min, a (B, 2) block)
instead of paying a full (B, draft_k + 1) forward for tokens that never
land, while rows whose sibling / history drafts track the policy
speculate deeper (k -> draft_k).

The schedule uses the classic speculative-decoding yield argument: with
per-token acceptance probability r, the expected number of accepted tokens
of an unbounded draft is r / (1 - r), so the controller drafts
``floor(r / (1 - r)) + 1`` tokens, clipped to [k_min, draft_k].
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class DraftConfig:
    """Draft-engine knobs (host-side; the jit'd step only sees draft_k).

    kind: 'off' disables drafting; 'ngram' proposes from the suffix hash
    map over the row's own prompt ⊕ generated stream plus its sibling
    trajectories (drafting/ngram.py).
    """
    kind: str = "off"            # 'off' | 'ngram'
    draft_k: int = 8             # max drafted tokens per forward
    min_ngram: int = 1           # shortest suffix n-gram to match on
    max_ngram: int = 3           # longest (tried first; most specific wins)
    use_siblings: bool = True    # index GRPO sibling trajectories too
    adaptive: bool = True        # per-row draft length from acceptance rate
    accept_ema: float = 0.7      # EMA decay of the running acceptance rate
    accept_init: float = 0.5     # optimistic prior: start at draft_len ~ 2
    k_min: int = 0               # floor (0 = allow falling back to vanilla)

    @property
    def enabled(self) -> bool:
        return self.kind != "off"

    def validate(self) -> None:
        assert self.kind in ("off", "ngram"), self.kind
        assert 1 <= self.min_ngram <= self.max_ngram, \
            (self.min_ngram, self.max_ngram)
        assert 0 < self.draft_k, self.draft_k
        assert 0 <= self.k_min <= self.draft_k, (self.k_min, self.draft_k)
        assert 0.0 <= self.accept_ema < 1.0, self.accept_ema


class DraftController:
    """Per-row draft length from a running acceptance-rate EMA."""

    def __init__(self, cfg: DraftConfig, rows: int):
        cfg.validate()
        self.cfg = cfg
        self.rate = np.full(rows, cfg.accept_init, np.float64)

    def reset(self, row: int) -> None:
        """Forget a slot's history (serving slot reuse)."""
        self.rate[row] = self.cfg.accept_init

    def draft_len(self, row: int) -> int:
        """How many tokens to draft for ``row``'s next forward."""
        if not self.cfg.adaptive:
            return self.cfg.draft_k
        r = min(float(self.rate[row]), 0.98)
        opt = math.floor(r / (1.0 - r)) + 1
        return max(self.cfg.k_min, min(self.cfg.draft_k, opt))

    def update(self, row: int, proposed: int, accepted: int) -> None:
        """Fold one verify outcome into the row's acceptance EMA.

        ``accepted`` is the raw rejection-sampling acceptance count (before
        eos/budget truncation — those say nothing about draft quality)."""
        if proposed <= 0:
            return
        e = self.cfg.accept_ema
        self.rate[row] = e * self.rate[row] + (1 - e) * (accepted / proposed)
