"""Host-driven drafted decode loops: the §9 counterparts of
``engine/generate.generate`` and ``resume_from_cache``.

The vanilla decode loop is one jit'd ``lax.while_loop``; drafting needs the
host in the loop (the n-gram proposal is a hash-map lookup), so these
functions run the same stages as their vanilla twins but step through the
jit'd ``drafting.step.draft_step`` macro-step, proposing between steps:

    prefill (jit)  ->  [propose (host) -> draft_step (jit)]*  ->  pack

Contracts mirrored from the vanilla loops:

* same output dict (``tokens``/``logprobs``/``length``/``n_generated``),
  plus a ``stats`` DraftStats;
* same greedy token stream: under temperature <= 0 acceptance is exactly
  "draft == argmax" and correction is argmax, so the emitted stream is the
  vanilla greedy stream whatever the proposals were (asserted in
  tests/drafting/);
* same per-token *marginal* distribution under temperature / top-p — the
  rejection-sampling guarantee (chi-squared-tested), though the PRNG
  draws divide differently so sampled streams are not bit-equal;
* caches end byte-equivalent over the live region (rejected slots are
  invalidated and overwritten), so SPEC-RL's next-epoch verification sees
  the same layout either way.
"""
from __future__ import annotations

import functools
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.metrics import DraftStats
from repro.engine.generate import GenerateConfig, positions_from_mask
from repro.engine.sampling import sample, split_key
from repro.models import model as M
from repro.models.config import ModelConfig

from .controller import DraftConfig, DraftController
from .ngram import NGramDraftSource


@functools.partial(jax.jit, static_argnames=("cfg", "gen", "extra", "mesh"))
def _prefill_seed(params, cfg: ModelConfig, gen: GenerateConfig, prompt,
                  prompt_mask, key, *, extra: int, mesh=None):
    """``generate``'s prefill stage with ``extra`` spare cache slots, plus
    the seed sample — the same key-split order as ``_decode_loop``."""
    B, P = prompt.shape
    positions = positions_from_mask(prompt_mask)
    caches = M.init_cache(cfg, B, P + gen.max_new_tokens + extra)
    if mesh is not None:
        from repro.distributed.mesh import constrain_caches
        caches = constrain_caches(cfg, caches, mesh)
    logits, caches = M.prefill(params, cfg, prompt, positions, caches)
    key, sub = split_key(key)
    tok0, lp0 = sample(sub, logits[:, -1], gen.temperature, gen.top_p)
    next_pos = prompt_mask.sum(axis=1).astype(jnp.int32)
    return {"caches": caches, "tok0": tok0, "lp0": lp0,
            "next_pos": next_pos, "key": key}


@functools.partial(jax.jit, static_argnames=("cfg", "gen", "extra", "mesh"))
def _pad_seed(params, cfg: ModelConfig, gen: GenerateConfig, caches,
              seed_logits, key, *, extra: int, mesh=None):
    """``resume_from_cache``'s entry: pad the compacted caches with draft
    headroom and seed-sample with the vanilla key-split order."""
    caches = M.pad_cache(cfg, caches, extra)
    if mesh is not None:
        from repro.distributed.mesh import constrain_caches
        caches = constrain_caches(cfg, caches, mesh)
    key, sub = split_key(key)
    tok0, lp0 = sample(sub, seed_logits, gen.temperature, gen.top_p)
    return {"caches": caches, "tok0": tok0, "lp0": lp0, "key": key}


class _DraftLoop:
    """Shared host loop: state vectors + propose/step/harvest plumbing."""

    def __init__(self, params, cfg: ModelConfig, gen: GenerateConfig,
                 draft: DraftConfig, caches, tok0, lp0, next_pos, key,
                 write_idx, initial_done, row_budget, contexts,
                 corpus, verify_impl: str, mesh):
        from .step import draft_step
        self._step = draft_step
        B = int(np.asarray(next_pos).shape[0])
        N = gen.max_new_tokens
        self.params, self.cfg, self.gen, self.mesh = params, cfg, gen, mesh
        self.K = draft.draft_k
        self.verify_impl = verify_impl
        self.caches = caches
        self.cur_tok = tok0
        self.cur_lp = lp0
        self.key = key
        self.next_pos = jnp.asarray(next_pos, jnp.int32)
        self.write_idx = jnp.asarray(write_idx, jnp.int32)
        budget = jnp.full((B,), N, jnp.int32) if row_budget is None else \
            jnp.asarray(row_budget, jnp.int32)
        done0 = jnp.zeros((B,), bool) if initial_done is None else \
            jnp.asarray(initial_done)
        self.done = done0 | (budget <= 0)
        self.budget = budget
        self.count = jnp.zeros((B,), jnp.int32)
        self.source = NGramDraftSource(draft, B)
        self.controller = DraftController(draft, B)
        for b in range(B):
            self.source.reset(b, contexts[b],
                              corpus[b] if corpus is not None else None)
        self.acc_tok: List[List[np.ndarray]] = [[] for _ in range(B)]
        self.acc_lp: List[List[np.ndarray]] = [[] for _ in range(B)]
        self.stats = DraftStats()
        self.B, self.N = B, N
        # §14 provenance: when the caller bound ledger rows (spec_rollout's
        # one-pass continuation extends the rollout's own rows), append to
        # those; otherwise reserve fresh rows and lay each row's context
        # down as its prompt plane.  Host-side only — the jit'd step above
        # is untouched, so lowered HLO is identical ledger on/off.
        from repro.obs import get_ledger
        self.ledger = led = get_ledger()
        self._rows: List = [None] * B
        self._carry_bonus = np.zeros(B, bool)
        if led.enabled:
            bound = [led.bound_row(b) for b in range(B)]
            if all(r is not None for r in bound):
                self._rows = bound
            else:
                base = led.reserve(B)
                self._rows = [base + b for b in range(B)]
                for b in range(B):
                    led.begin_row(self._rows[b], len(contexts[b]))

    def run(self) -> Dict[str, jnp.ndarray]:
        # §11: the global tracer draws one span per draft macro-step on the
        # 'draft' lane (proposal + forward + harvest — the harvest's
        # np.asarray is the loop's existing host sync, so the end stamp
        # adds no new blocking); the acceptance time series rides the span
        # args.  Clock reads are guarded on tr.enabled — a NULL_TRACER run
        # takes none.
        from repro.obs import get_decision_log, get_registry, get_tracer
        from repro.obs.ledger import SOURCE_NGRAM, categorize_draft_block
        tr = get_tracer()
        reg = get_registry()
        led = self.ledger
        dec = get_decision_log()
        macro_step = 0
        while True:
            done_np = np.asarray(self.done)
            if done_np.all():
                break
            t0 = (tr.now() if tr.enabled else
                  time.perf_counter() if dec.enabled else 0.0)
            cur_np = np.asarray(self.cur_tok)
            dt = np.zeros((self.B, self.K), np.int32)
            dl = np.zeros((self.B,), np.int32)
            feats: Dict[int, Dict[str, float]] = {}
            if dec.enabled:
                cur_lp_np = np.asarray(self.cur_lp)
                pos_np = np.asarray(self.next_pos)
            for b in range(self.B):
                if done_np[b]:
                    continue
                k_b = self.controller.draft_len(b)
                d = self.source.propose(b, k_b, pending=int(cur_np[b]))
                dt[b, :len(d)] = d
                dl[b] = len(d)
                if dec.enabled:
                    # §14 decision features, captured pre-step (surprisal
                    # is -logp of the pending carry token — the host-side
                    # stand-in for next-token entropy; the fixed-batch
                    # loop has no queue or pool, so those columns are 0)
                    feats[b] = {
                        "surprisal": -float(cur_lp_np[b]),
                        "position": float(pos_np[b]),
                        "accept_ema": float(self.controller.rate[b]),
                        "draft_k": float(len(d)),
                        "draft_source": SOURCE_NGRAM,
                        "slot_age": float(macro_step),
                    }
            # compile the block at the power-of-two cover of the widest
            # live proposal — adaptive draft lengths narrow the forward
            # (drafting/step.py:block_width); acceptance draws stay at
            # u_width = draft_k so streams are bucket-invariant
            from .step import block_width
            K_step = block_width(int(dl.max()), self.K)
            out = self._step(
                self.params, self.cfg, self.gen, self.caches, self.cur_tok,
                self.cur_lp, self.done, self.count, self.budget,
                self.next_pos, self.write_idx, self.key,
                jnp.asarray(dt[:, :K_step]), jnp.asarray(dl), K=K_step,
                u_width=self.K, verify_impl=self.verify_impl,
                mesh=self.mesh)
            self.caches = out["caches"]
            for name in ("cur_tok", "cur_lp", "done", "count", "next_pos",
                         "write_idx"):
                setattr(self, name, out[name])
            self.key = out["keys"]
            toks = np.asarray(out["tokens"])
            lps = np.asarray(out["logprobs"])
            emitted = np.asarray(out["emitted"])
            accepted = np.asarray(out["accepted"])
            proposed = np.asarray(out["proposed"])
            t1 = (tr.now() if tr.enabled else
                  time.perf_counter() if dec.enabled else 0.0)
            for b in range(self.B):
                mb = int(emitted[b])
                if mb:
                    self.acc_tok[b].append(toks[b, :mb])
                    self.acc_lp[b].append(lps[b, :mb])
                    self.source.extend(b, toks[b, :mb])
                    if led.enabled:
                        for cat, nrun in categorize_draft_block(
                                mb, bool(self._carry_bonus[b])):
                            led.append(self._rows[b], cat, nrun)
                self._carry_bonus[b] = bool(
                    proposed[b] > 0 and accepted[b] == proposed[b])
                self.controller.update(b, int(proposed[b]), int(accepted[b]))
            if dec.enabled and feats:
                step_ms = (t1 - t0) * 1e3
                for b, f in feats.items():
                    prop, acc = int(proposed[b]), int(accepted[b])
                    mb = int(emitted[b])
                    dec.record(self._rows[b] if self._rows[b] is not None
                               else b, macro_step, f, {
                                   "proposed": prop, "accepted": acc,
                                   "bonus": 1.0 if (prop > 0 and acc == prop
                                                    and mb > acc) else 0.0,
                                   "emitted": mb, "step_ms": step_ms})
            # per-ROW forward counting: one batched forward serves `live`
            # rows, so tokens_per_forward is a per-row quantity with 1.0 as
            # the vanilla baseline (a live vanilla row emits exactly one
            # token per forward it participates in)
            n_prop, n_acc = int(proposed.sum()), int(accepted.sum())
            self.stats.add_step(forwards=int((~done_np).sum()),
                                proposed=n_prop, accepted=n_acc,
                                emitted=int(emitted.sum()),
                                draft_forwards=int((dl > 0).sum()))
            reg.observe("draft.proposed_per_step", n_prop)
            reg.observe("draft.accepted_per_step", n_acc)
            if tr.enabled:
                tr.complete("draft_step", "draft", t0, tr.now(), cat="draft",
                            step=macro_step, live=int((~done_np).sum()),
                            proposed=n_prop, accepted=n_acc,
                            emitted=int(emitted.sum()))
            macro_step += 1
        return self._pack()

    def _pack(self) -> Dict[str, jnp.ndarray]:
        tokens = np.full((self.B, self.N), self.gen.pad_id, np.int32)
        lps = np.zeros((self.B, self.N), np.float32)
        length = np.zeros((self.B,), np.int32)
        for b in range(self.B):
            row = np.concatenate(self.acc_tok[b]) if self.acc_tok[b] else \
                np.zeros(0, np.int32)
            lp_row = np.concatenate(self.acc_lp[b]) if self.acc_lp[b] else \
                np.zeros(0, np.float32)
            L = min(len(row), self.N)
            tokens[b, :L] = row[:L]
            lps[b, :L] = lp_row[:L]
            length[b] = L
        return {"tokens": jnp.asarray(tokens), "logprobs": jnp.asarray(lps),
                "length": jnp.asarray(length),
                "n_generated": jnp.asarray(length.sum()),
                "stats": self.stats}


def drafted_generate(params, cfg: ModelConfig, gen: GenerateConfig, prompt,
                     prompt_mask, key, draft: DraftConfig, *,
                     corpus: Optional[Sequence[Sequence[np.ndarray]]] = None,
                     initial_done=None, row_budget=None,
                     verify_impl: str = "auto", mesh=None
                     ) -> Dict[str, jnp.ndarray]:
    """``generate`` with the drafted decode loop (same output contract,
    plus ``stats``).  ``corpus[b]`` optionally holds row b's sibling /
    previous-rollout trajectories for the n-gram index."""
    assert M.supports_drafting(cfg), "drafting needs an attention-only trunk"
    B, P = prompt.shape
    pre = _prefill_seed(params, cfg, gen, jnp.asarray(prompt),
                        jnp.asarray(prompt_mask), key, extra=draft.draft_k,
                        mesh=mesh)
    mask_np = np.asarray(prompt_mask)
    prompt_np = np.asarray(prompt)
    contexts = [prompt_np[b][mask_np[b]] for b in range(B)]
    loop = _DraftLoop(params, cfg, gen, draft, pre["caches"], pre["tok0"],
                      pre["lp0"], pre["next_pos"], pre["key"],
                      np.full((B,), P, np.int32), initial_done, row_budget,
                      contexts, corpus, verify_impl, mesh)
    return loop.run()


def drafted_resume(params, cfg: ModelConfig, gen: GenerateConfig, caches,
                   seed_logits, next_pos, write_offset: int, key,
                   draft: DraftConfig, contexts: Sequence[Sequence[int]], *,
                   corpus: Optional[Sequence[Sequence[np.ndarray]]] = None,
                   initial_done=None, row_budget=None,
                   verify_impl: str = "auto", mesh=None
                   ) -> Dict[str, jnp.ndarray]:
    """``resume_from_cache`` with the drafted decode loop — the one-pass
    SPEC-RL continuation drafts past the verified prefix (DESIGN.md §9).

    ``contexts[b]`` must hold row b's prompt ⊕ accepted-prefix tokens (the
    n-gram index needs the token values; the caches only hold K/V)."""
    assert M.supports_drafting(cfg), "drafting needs an attention-only trunk"
    B = seed_logits.shape[0]
    pre = _pad_seed(params, cfg, gen, caches, seed_logits, key,
                    extra=draft.draft_k, mesh=mesh)
    loop = _DraftLoop(params, cfg, gen, draft, pre["caches"], pre["tok0"],
                      pre["lp0"], next_pos, pre["key"],
                      np.full((B,), write_offset, np.int32), initial_done,
                      row_budget, contexts, corpus, verify_impl, mesh)
    return loop.run()
