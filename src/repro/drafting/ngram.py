"""N-gram draft source: suffix hash map over each row's own token stream
plus its GRPO sibling trajectories.

Why this works for RL rollouts: the G sibling rollouts of a GRPO group are
sampled from the same policy on the same prompt, and consecutive-epoch
rollouts of the same prompt overlap heavily (the redundancy SPEC-RL's
prefix reuse exploits, paper Fig. 2).  Both corpora sit in ``RolloutCache``
already — so after the verified prefix diverges, the *continuation* can
still be speculated nearly for free: match the row's current suffix
against its own history and its siblings, and propose the tokens that
followed the match last time.

Mechanics (host-side, O(1) per lookup):

* every indexed sequence registers, for each position p and each gram
  length m in [min_ngram, max_ngram], the mapping
  ``tuple(seq[p-m:p]) -> (seq_ref, p)`` — "this m-gram was last seen
  continuing at position p of seq_ref".  Later registrations win, so the
  row's own stream (indexed incrementally as tokens are emitted) shadows
  the sibling corpus, and recent occurrences shadow old ones.
* a proposal looks up the stream's current suffix (including the pending
  just-sampled-but-not-yet-stored token), longest gram first, and copies
  up to k continuation tokens from the match site.

Proposals are **deterministic** functions of the row's context — a point
mass q = δ(draft) — which is what makes the §9 rejection-sampling
acceptance exact: the residual distribution is p with the draft token
masked out (engine/sampling.residual_sample).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .controller import DraftConfig

# seq_ref -1 means "the row's own stream"; >= 0 indexes the sibling corpus
SELF = -1


class NGramDraftSource:
    """Per-row suffix hash maps with incremental own-stream indexing."""

    def __init__(self, cfg: DraftConfig, rows: int):
        cfg.validate()
        self.cfg = cfg
        self._stream: List[List[int]] = [[] for _ in range(rows)]
        self._corpus: List[List[np.ndarray]] = [[] for _ in range(rows)]
        self._index: List[Dict[Tuple[int, ...], Tuple[int, int]]] = \
            [{} for _ in range(rows)]

    @property
    def rows(self) -> int:
        return len(self._stream)

    # ------------------------------------------------------------- indexing

    def _register(self, row: int, seq: Sequence[int], seq_ref: int,
                  start: int) -> None:
        """Index grams ending just before each position p >= max(start, 1)."""
        idx = self._index[row]
        lo, hi = self.cfg.min_ngram, self.cfg.max_ngram
        for p in range(max(start, 1), len(seq)):
            for m in range(lo, min(hi, p) + 1):
                idx[tuple(seq[p - m:p])] = (seq_ref, p)

    def reset(self, row: int, context: Sequence[int],
              corpus: Optional[Sequence[np.ndarray]] = None) -> None:
        """(Re)seed a row: context = prompt ⊕ already-kept tokens; corpus =
        sibling / previous-rollout trajectories (indexed first, so the
        row's own stream shadows them on gram collisions)."""
        self._stream[row] = [int(t) for t in context]
        self._corpus[row] = []
        self._index[row] = {}
        if corpus and self.cfg.use_siblings:
            for seq in corpus:
                seq = np.asarray(seq, np.int32)
                if len(seq) == 0:
                    continue
                sid = len(self._corpus[row])
                self._corpus[row].append(seq)
                self._register(row, [int(t) for t in seq], sid, 1)
        self._register(row, self._stream[row], SELF, 1)

    def extend(self, row: int, tokens: Sequence[int]) -> None:
        """Append newly kept tokens to the row's stream and index them."""
        if len(tokens) == 0:
            return
        start = len(self._stream[row])
        self._stream[row].extend(int(t) for t in tokens)
        self._register(row, self._stream[row], SELF, start)

    # ------------------------------------------------------------- proposal

    def propose(self, row: int, k: int,
                pending: Optional[int] = None) -> np.ndarray:
        """Up to ``k`` draft tokens continuing the row's current suffix.

        ``pending`` is the just-sampled token that will start the next
        decode block — the suffix must end with it even though it is not
        in the stream yet.  Returns an empty array on no match.
        """
        if k <= 0:
            return np.zeros(0, np.int32)
        stream = self._stream[row]
        # only the trailing max_ngram tokens are ever matched on — slice
        # instead of copying the whole stream in the decode hot loop
        tail = stream[-self.cfg.max_ngram:]
        if pending is not None:
            tail = tail + [int(pending)]
        idx = self._index[row]
        for m in range(min(self.cfg.max_ngram, len(tail)),
                       self.cfg.min_ngram - 1, -1):
            hit = idx.get(tuple(tail[-m:]))
            if hit is None:
                continue
            ref, p = hit
            if ref == SELF:
                cont = stream[p:p + k]
            else:
                cont = self._corpus[row][ref][p:p + k]
            if len(cont):
                return np.asarray(cont, np.int32)
        return np.zeros(0, np.int32)
