"""The jit'd draft-verify decode step shared by every drafted decode loop.

One macro-step replaces up to ``K + 1`` single-token decode steps with ONE
forward of a (K+1)-token block — [current token | K drafted tokens] — and
turns the drafts into kept output via rejection sampling:

1. **forward**: the block is written into the per-row cache slots
   [write_idx, write_idx + K] and attends through the short-multi-token
   flash-decode path (models/attention._decode_shaped) over each row's live
   bounds.  Draft padding and done rows carry position -1 (masked).
2. **verify**: draft token i is scored by the logits at block column i;
   acceptance reuses the ``kernels/spec_verify`` accept/first-reject
   reduction with ``lp_prev = 0`` (the n-gram proposal is a point mass) and
   zero lenience — accept g_i iff u_i <= p(g_i).  Under greedy
   (temperature <= 0) the log-ratio is built from the argmax directly
   (0 on match, -inf otherwise) with a constant u, so acceptance is exactly
   "draft == argmax" — bit-exact vanilla greedy, no float thresholds.
3. **accept / truncate**: vanilla ``_decode_loop`` done-semantics are
   replayed over the stored candidates [cur_tok | accepted drafts]: stop at
   the first eos or budget exhaustion.  Cache slots written beyond the kept
   tokens are invalidated (pos = -1); the next block overwrites them, so
   the cache is byte-equivalent (live region) to single-token decoding.
4. **correct**: the next carry token is sampled at block column n — from
   the residual distribution (draft masked out) on rejection, from the
   plain distribution on full acceptance (the "bonus" token) — via
   ``sampling.residual_sample``, whose emitted marginal is exactly p.

Per-row accepts advance per-row write offsets unevenly — the same
(write_idx, budget, count) machinery the serving slot engine already
carries, which is why this one device program serves ``drafted_generate``,
``drafted_resume`` AND the slot engine's draft chunks (DESIGN.md §9).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.engine.generate import GenerateConfig
from repro.engine.sampling import logprobs_of, residual_sample, split_key
from repro.models import model as M
from repro.models.config import ModelConfig

NEG_INF = -1e30


def _uniforms(key, B: int, K: int):
    """(B, K) acceptance uniforms from a (2,) or (B, 2) key."""
    if jnp.ndim(key) == 2:
        return jax.vmap(lambda k: jax.random.uniform(k, (K,)))(key)
    return jax.random.uniform(key, (B, K))


def _spec_verify(mesh, lp_curr, lp_prev, u, valid_len, impl):
    if mesh is not None:
        from repro.distributed.shard_wrap import sharded_spec_verify
        return sharded_spec_verify(mesh, lp_curr, lp_prev, u, valid_len,
                                   0.0, impl=impl)
    from repro.kernels.spec_verify.ops import spec_verify
    return spec_verify(lp_curr, lp_prev, u, valid_len, 0.0, impl=impl)


def _invalidate_slots(caches, lo, hi):
    """pos = -1 on cache slots j with lo[b] <= j < hi[b] (rejected drafts)."""
    out = []
    for run in caches:
        sc = dict(run["self"])
        pos = sc["pos"]                               # (run, B, S)
        S = pos.shape[-1]
        j = jnp.arange(S, dtype=jnp.int32)[None, :]
        kill = (j >= lo[:, None]) & (j < hi[:, None])  # (B, S)
        sc["pos"] = jnp.where(kill[None], -1, pos)
        out.append({"self": sc})
    return out


def block_width(max_proposed: int, k_max: int) -> int:
    """The static draft width to compile this macro-step at: the power-of-
    two cover of the widest live proposal, capped at the engine's draft_k.

    The block forward is statically (K + 1) tokens wide whatever gets
    accepted, so proposing less only pays off if the compiled width
    shrinks with it — bucketing to powers of two keeps the number of jit
    variants at log2(draft_k) while letting the DraftController's
    adaptive lengths genuinely narrow the forward."""
    w = 1 << max(0, int(max_proposed) - 1).bit_length()
    return max(1, min(w, k_max))


@functools.partial(jax.jit, static_argnames=("cfg", "gen", "K", "u_width",
                                             "verify_impl", "mesh"))
def draft_step(params, cfg: ModelConfig, gen: GenerateConfig, caches,
               cur_tok, cur_lp, done, count, budget, next_pos, write_idx,
               keys, draft_tokens, draft_len, *, K: int, u_width: int = 0,
               verify_impl: str = "auto", mesh=None):
    """One draft-verify macro-step for all B rows.

    cur_tok/cur_lp: (B,) carry token (sampled, not yet stored) and its
    behaviour log-prob; done/count/budget/next_pos: (B,) vanilla decode
    state (count counts STORED tokens, budget caps them); write_idx: (B,)
    per-row first free cache slot; keys: (2,) or (B, 2) PRNG;
    draft_tokens: (B, K) right-padded proposals; draft_len: (B,) int32.

    The caller must have allocated enough spare cache slots past the last
    token it will keep (model.pad_cache with the engine's draft_k >= K) —
    the block write is statically K + 1 wide whatever gets accepted.

    ``u_width`` (0 = K) fixes the width of the acceptance-uniform draw
    independently of the compiled block width: engines that bucket K per
    macro-step (``block_width``) pass their full draft_k here, so a row's
    acceptance draws — and therefore its sampled stream — do not depend on
    how wide its co-batched rows made the bucket (the same grouping-
    invariance contract the §6 slot engine rides).

    Returns dict with the advanced state plus:
      tokens/logprobs (B, K+1)  kept tokens this step, left-packed, padded
      emitted          (B,)     how many of those columns are real
      accepted         (B,)     raw rejection-sampling accepts (telemetry /
                                the DraftController signal)
      proposed         (B,)     drafts actually verified (0 for done rows)
    """
    assert K >= 1, K
    B = cur_tok.shape[0]
    bidx = jnp.arange(K + 1, dtype=jnp.int32)[None, :]
    eff_len = jnp.where(done, 0, draft_len.astype(jnp.int32))

    # ---- block forward: [cur_tok | drafts], one write + one attention ----
    tok_store = jnp.where(done, gen.pad_id, cur_tok)
    block = jnp.concatenate(
        [tok_store[:, None],
         jnp.where(jnp.arange(K, dtype=jnp.int32)[None, :] < eff_len[:, None],
                   draft_tokens, gen.pad_id)], axis=1)          # (B, K+1)
    valid = (~done[:, None]) & (bidx <= eff_len[:, None])
    pos_block = jnp.where(valid, next_pos[:, None] + bidx, -1)
    logits, caches = M.decode_step(
        params, cfg, block, pos_block, caches, write_idx,
        kv_length=write_idx + 1 + K, kv_start=write_idx - next_pos,
        mesh=mesh)                                              # (B, K+1, V)

    # ---- verify: block column i scores draft i -------------------------
    lp_draft = logprobs_of(logits[:, :K], draft_tokens,
                           gen.temperature, gen.top_p)          # (B, K)
    if gen.temperature <= 0.0:
        # greedy: accept iff draft == argmax, expressed as an exact log-
        # ratio (0 / -inf) against a constant uniform — keys stay unused,
        # mirroring sample()'s greedy branch
        am = jnp.argmax(logits[:, :K], axis=-1).astype(jnp.int32)
        lp_acc = jnp.where(am == draft_tokens, 0.0, NEG_INF)
        u = jnp.full((B, K), 0.5, jnp.float32)
    else:
        lp_acc = lp_draft
        keys, sub = split_key(keys)
        u = _uniforms(sub, B, max(u_width, K))[:, :K]
    n = _spec_verify(mesh, lp_acc, jnp.zeros_like(lp_acc), u, eff_len,
                     verify_impl)                               # (B,)

    # ---- accept/truncate: replay vanilla done-semantics over the kept
    # candidates [cur_tok | draft[:n]] ----------------------------------
    avail = jnp.where(done, 0, 1 + n)
    is_stop = (block == gen.eos_id) | \
        ((count[:, None] + bidx + 1) >= budget[:, None])
    stop_in = is_stop & (bidx < avail[:, None])
    any_stop = stop_in.any(axis=1)
    first_stop = jnp.argmax(stop_in, axis=1).astype(jnp.int32)
    m = jnp.where(done, 0, jnp.where(any_stop, first_stop + 1, avail))
    done_next = done | any_stop

    lp_block = jnp.concatenate([cur_lp[:, None], lp_draft], axis=1)
    emit = bidx < m[:, None]
    toks_out = jnp.where(emit, block, gen.pad_id)
    lps_out = jnp.where(emit, lp_block, 0.0)

    # invalidate written-but-rejected slots; next block overwrites them
    caches = _invalidate_slots(caches, write_idx + m, write_idx + K + 1)

    # ---- correction / bonus sample at block column n -------------------
    nxt_logits = jnp.take_along_axis(
        logits, n[:, None, None].astype(jnp.int32), axis=1)[:, 0]
    rejected = n < eff_len
    rej_tok = jnp.take_along_axis(
        draft_tokens, jnp.clip(n, 0, K - 1)[:, None], axis=1)[:, 0]
    keys, sub = split_key(keys)
    nxt, nlp = residual_sample(sub, nxt_logits, rej_tok, rejected,
                               gen.temperature, gen.top_p)

    return {
        "caches": caches,
        "cur_tok": jnp.where(done_next, cur_tok, nxt),
        "cur_lp": jnp.where(done_next, cur_lp, nlp),
        "done": done_next,
        "count": count + m,
        "next_pos": next_pos + m,
        "write_idx": write_idx + m,
        "keys": keys,
        "tokens": toks_out,
        "logprobs": lps_out,
        "emitted": m,
        "accepted": jnp.minimum(n, eff_len),
        "proposed": eff_len,
    }


# §14 recompile sentinel enrollment (obs/alerts.py): draft_step is shared
# by every drafted loop, so its cache size counts compiles for all of them
from repro.obs.alerts import register_jit_entry  # noqa: E402

register_jit_entry("draft_step", draft_step)
