"""Batched autoregressive generation and teacher-forced scoring.

The engine is built from two explicit, composable stages (see DESIGN.md §3):

* **prefill** — one forward over the (left-padded) prompt that populates the
  dense KV caches and yields the seed logits for the first sampled token;
* **decode** — a single ``lax.while_loop`` with per-row done flags that
  extends the caches one token at a time.

``generate`` = prefill ∘ decode and serves vanilla rollouts as well as the
legacy two-pass SPEC-RL continuation (caller concatenates prompt ⊕ verified
prefix into the "prompt").  ``resume_from_cache`` is the decode stage alone:
it starts the while_loop from an already-populated cache, per-row start
positions and seed logits, which is how the one-pass speculative path
continues straight out of verification with zero redundant prefill.
Left-padded batches, dense caches — the TPU-idiomatic replacement for vLLM's
continuous batching (see DESIGN.md §3).

Observability (DESIGN.md §11): ``generate`` and ``resume_from_cache`` are
themselves ``jax.jit`` programs, so the §11 tracer deliberately does NOT
reach inside them — host-side tracer calls traced into the jit graph would
either fail or bake ops into the compiled program, violating the
zero-overhead contract.  Their timings are spanned at the call sites
(core/spec_rollout emits the 'decode'/'generate' stage spans around its
existing ``block_until_ready`` boundaries), and the §9 drafted loops —
which ARE host-driven — carry their own per-macro-step spans.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ModelConfig

from .sampling import entropy_of, logprobs_of, sample, split_key

PAD = 0


def positions_from_mask(mask) -> jnp.ndarray:
    """mask: (B, T) bool -> positions (B, T) int32, -1 where invalid."""
    pos = jnp.cumsum(mask.astype(jnp.int32), axis=1) - 1
    return jnp.where(mask, pos, -1)


@dataclass(frozen=True)
class GenerateConfig:
    max_new_tokens: int = 64
    temperature: float = 1.0
    top_p: float = 1.0
    eos_id: int = 2
    pad_id: int = PAD


def _model_extras(model_kwargs):
    return {k: model_kwargs.get(k) for k in
            ("encoder_out", "encoder_positions")}


@functools.partial(jax.jit, static_argnames=("cfg", "gen", "mesh"))
def generate(params, cfg: ModelConfig, gen: GenerateConfig, prompt, prompt_mask,
             key, initial_done=None, row_budget=None, mesh=None,
             **model_kwargs) -> Dict[str, jnp.ndarray]:
    """prompt: (B, P) int32 left-padded; prompt_mask: (B, P) bool.

    initial_done: optional (B,) bool — rows that must not decode at all
    (SPEC-RL full-reuse rows).  row_budget: optional (B,) int32 — per-row max
    generated tokens (SPEC-RL continuation budget = max_resp - prefix_len).
    mesh: optional live Mesh (static) — the KV caches are constrained
    batch-over-data / heads-over-model and decode attention runs inside the
    §8 shard_map boundary; with sharded params/inputs the whole program
    compiles SPMD.  ``None`` is the single-device path, bit-for-bit the
    pre-mesh behaviour.

    Returns dict with:
      tokens     (B, N) generated tokens (pad after eos)
      logprobs   (B, N) behaviour log-probs of generated tokens
      length     (B,)   #generated tokens per row (including eos)
      n_generated ()    total generated tokens (the paper's "Tokens" metric)
    """
    B, P = prompt.shape
    N = gen.max_new_tokens
    positions = positions_from_mask(prompt_mask)
    extras = _model_extras(model_kwargs)
    prefix_embeds = model_kwargs.get("prefix_embeds")

    cache_len = P + N + (prefix_embeds.shape[1] if prefix_embeds is not None else 0)
    caches = M.init_cache(cfg, B, cache_len)
    if mesh is not None:
        from repro.distributed.mesh import constrain_caches
        caches = constrain_caches(cfg, caches, mesh)

    if prefix_embeds is not None:
        Pv = prefix_embeds.shape[1]
        vis_pos = jnp.broadcast_to(jnp.arange(Pv, dtype=jnp.int32), (B, Pv))
        positions_full = jnp.concatenate([vis_pos, jnp.where(
            positions >= 0, positions + Pv, -1)], axis=1)
        logits, caches = M.prefill(params, cfg, prompt, positions_full, caches,
                                   prefix_embeds=prefix_embeds, **extras)
        pos_offset = Pv
        write_offset = P + Pv
        # vision slots [0, Pv) are live ahead of the prompt's left padding,
        # so the context is not contiguous from a single start slot
        kv_start = None
    else:
        logits, caches = M.prefill(params, cfg, prompt, positions, caches, **extras)
        pos_offset = 0
        write_offset = P
        kv_start = P - prompt_mask.sum(axis=1).astype(jnp.int32)

    next_pos = prompt_mask.sum(axis=1).astype(jnp.int32) + pos_offset  # (B,)
    return _decode_loop(params, cfg, gen, caches, logits[:, -1], next_pos,
                        write_offset, key, initial_done, row_budget, extras,
                        kv_start=kv_start, mesh=mesh)


def _decode_loop(params, cfg: ModelConfig, gen: GenerateConfig, caches,
                 seed_logits, next_pos, write_offset, key,
                 initial_done, row_budget, extras,
                 kv_start=None, mesh=None) -> Dict[str, jnp.ndarray]:
    """The decode stage: sample from ``seed_logits`` then run the while_loop.

    caches: populated KV caches whose slots [0, write_offset) hold the
    context; seed_logits: (B, V) logits of the first token to sample;
    next_pos: (B,) position value of that first token.  Key-split order is
    identical whether entered via ``generate`` or ``resume_from_cache`` so
    the two-pass and one-pass SPEC-RL paths are sample-for-sample exact.

    ``key`` may be (2,) (batched sampling) or (B, 2) per-row keys; with
    per-row keys row b's token stream depends only on its own key, which is
    the invariant the serving slot scheduler's step loop mirrors split for
    split (see serving/engine_loop.py and DESIGN.md §6).
    """
    B = seed_logits.shape[0]
    N = gen.max_new_tokens
    key, sub = split_key(key)
    tok0, lp0 = sample(sub, seed_logits, gen.temperature, gen.top_p)

    tokens_buf = jnp.full((B, N), gen.pad_id, jnp.int32)
    lp_buf = jnp.zeros((B, N), jnp.float32)

    def cond(state):
        step, done, *_ = state
        return (step < N) & ~jnp.all(done)

    def body(state):
        (step, done, cur_tok, cur_lp, next_pos, caches, tokens_buf, lp_buf,
         count, key) = state
        tok_store = jnp.where(done, gen.pad_id, cur_tok)
        lp_store = jnp.where(done, 0.0, cur_lp)
        tokens_buf = jax.lax.dynamic_update_index_in_dim(
            tokens_buf, tok_store, step, axis=1)
        lp_buf = jax.lax.dynamic_update_index_in_dim(lp_buf, lp_store, step, axis=1)
        count = count + (~done).astype(jnp.int32)
        done_next = done | (cur_tok == gen.eos_id) | (count >= budget)

        # live cache extent: [kv_start, write_offset + step] — the dead
        # left padding in front of the context and the unwritten tail are
        # both skipped by the flash-decode kernel
        logits, caches = M.decode_step(
            params, cfg, tok_store[:, None],
            jnp.where(done[:, None], -1, next_pos[:, None]),
            caches, write_offset + step,
            kv_length=write_offset + 1 + step, kv_start=kv_start,
            mesh=mesh, **extras)
        key, sub = split_key(key)
        nxt, nlp = sample(sub, logits[:, 0], gen.temperature, gen.top_p)
        return (step + 1, done_next, nxt, nlp, next_pos + 1, caches,
                tokens_buf, lp_buf, count, key)

    done0 = jnp.zeros((B,), bool) if initial_done is None else initial_done
    budget = jnp.full((B,), N, jnp.int32) if row_budget is None else \
        row_budget.astype(jnp.int32)
    done0 = done0 | (budget <= 0)
    state = (jnp.array(0), done0, tok0, lp0, next_pos, caches,
             tokens_buf, lp_buf, jnp.zeros((B,), jnp.int32), key)
    final = jax.lax.while_loop(cond, body, state)
    _, _, _, _, _, _, tokens_buf, lp_buf, length, _ = final
    return {
        "tokens": tokens_buf,
        "logprobs": lp_buf,
        "length": length,
        "n_generated": length.sum(),
    }


@functools.partial(jax.jit, static_argnames=("cfg", "gen", "write_offset",
                                             "mesh"))
def resume_from_cache(params, cfg: ModelConfig, gen: GenerateConfig, caches,
                      seed_logits, next_pos, write_offset: int, key,
                      initial_done=None, row_budget=None, mesh=None,
                      **model_kwargs) -> Dict[str, jnp.ndarray]:
    """Continue decoding from an existing cache — the one-pass SPEC-RL entry.

    caches: decode caches whose slots [0, write_offset) already hold
    [left-aligned prompt ⊕ accepted prefix] (see model.realign_decode_cache);
    seed_logits: (B, V) logits of the last accepted (or last prompt) token;
    next_pos: (B,) int32 = prompt_len + n, the position the first continued
    token will occupy.  Returns the same dict as ``generate``.

    Bit-compatible with ``generate`` on the left-aligned layout: feeding the
    same PRNG key to either entry point yields the same key-split sequence,
    so continuation tokens/logprobs agree sample-for-sample.
    """
    extras = _model_extras(model_kwargs)
    next_pos = next_pos.astype(jnp.int32)
    if mesh is not None:
        from repro.distributed.mesh import constrain_caches
        caches = constrain_caches(cfg, caches, mesh)
    # compacted layout (§3): row b's context is contiguous in
    # [write_offset - next_pos[b], write_offset) — a short accepted prefix
    # decodes over its live extent, not the allocated verify width
    return _decode_loop(params, cfg, gen, caches, seed_logits,
                        next_pos, write_offset, key,
                        initial_done, row_budget, extras,
                        kv_start=write_offset - next_pos, mesh=mesh)


@functools.partial(jax.jit, static_argnames=("cfg", "temperature", "top_p",
                                             "return_entropy"))
def score(params, cfg: ModelConfig, tokens, mask, *, temperature: float = 1.0,
          top_p: float = 1.0, return_entropy: bool = False, **model_kwargs):
    """Teacher-forced scoring: log-prob of every token given its prefix.

    tokens: (B, L) left-padded full sequences; mask: (B, L) bool validity.
    Returns dict with ``logprobs`` (B, L) — entry t is the log-prob of
    tokens[:, t] under the sampling distribution given tokens[:, :t]
    (0 where mask is False or t is the first valid token), and optionally
    ``entropy`` (B, L).

    This single pass is SPEC-RL's *verification* forward (p_curr over the
    draft) and doubles as the PPO old-log-prob computation.
    """
    extras = _model_extras(model_kwargs)
    positions = positions_from_mask(mask)
    logits, _ = M.forward(params, cfg, tokens, positions,
                          prefix_embeds=model_kwargs.get("prefix_embeds"),
                          **extras)
    # logits[:, t] predicts tokens[:, t+1]
    lp_next = logprobs_of(logits[:, :-1], tokens[:, 1:], temperature, top_p)
    lp = jnp.concatenate([jnp.zeros_like(lp_next[:, :1]), lp_next], axis=1)
    # valid only where both target and its predecessor are valid
    valid = mask & jnp.concatenate([jnp.zeros_like(mask[:, :1]), mask[:, :-1]],
                                   axis=1)
    out = {"logprobs": jnp.where(valid, lp, 0.0), "valid": valid}
    if return_entropy:
        ent = entropy_of(logits[:, :-1], temperature)
        ent = jnp.concatenate([jnp.zeros_like(ent[:, :1]), ent], axis=1)
        out["entropy"] = jnp.where(valid, ent, 0.0)
    return out
