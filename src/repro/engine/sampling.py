"""Token sampling: temperature + nucleus (top-p), returning the log-prob of
the sampled token under the *actual* sampling distribution.

SPEC-RL correctness requires the cached behaviour log-probs ``p_prev`` to be
the true probabilities the rollout engine sampled from — i.e. *after*
temperature and top-p renormalisation — so that the acceptance ratio
q/p in Eq. (2) is exact.  ``sample`` therefore returns that log-prob.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def adjust_logits(logits, temperature: float = 1.0, top_p: float = 1.0):
    """Return renormalised log-probs of the sampling distribution.

    logits: (..., V) float32.
    """
    if temperature != 1.0:
        logits = logits / jnp.maximum(temperature, 1e-6)
    logp = jax.nn.log_softmax(logits, axis=-1)
    if top_p < 1.0:
        sorted_lp = jnp.sort(logp, axis=-1)[..., ::-1]
        cum = jnp.cumsum(jnp.exp(sorted_lp), axis=-1)
        # keep the smallest set whose mass >= top_p (always keep argmax)
        keep_sorted = (cum - jnp.exp(sorted_lp)) < top_p
        # threshold log-prob: smallest kept log-prob
        thresh = jnp.min(jnp.where(keep_sorted, sorted_lp, jnp.inf),
                         axis=-1, keepdims=True)
        logp = jnp.where(logp >= thresh, logp, NEG_INF)
        logp = jax.nn.log_softmax(logp, axis=-1)
    return logp


def sample(key, logits, temperature: float = 1.0, top_p: float = 1.0):
    """Sample one token per row.

    logits: (B, V).  Returns (token (B,) int32, logprob (B,) float32) where
    logprob is under the temperature/top-p-adjusted distribution.
    """
    logp = adjust_logits(logits.astype(jnp.float32), temperature, top_p)
    if temperature <= 0.0:
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return tok, jnp.zeros(tok.shape, jnp.float32)
    tok = jax.random.categorical(key, logp, axis=-1).astype(jnp.int32)
    lp = jnp.take_along_axis(logp, tok[..., None], axis=-1)[..., 0]
    return tok, lp


def logprobs_of(logits, tokens, temperature: float = 1.0, top_p: float = 1.0):
    """Log-prob of given tokens under the adjusted distribution.

    logits: (..., V); tokens: (...). Returns (...) float32.
    """
    logp = adjust_logits(logits.astype(jnp.float32), temperature, top_p)
    return jnp.take_along_axis(logp, tokens[..., None].astype(jnp.int32),
                               axis=-1)[..., 0]


def entropy_of(logits, temperature: float = 1.0):
    logp = adjust_logits(logits.astype(jnp.float32), temperature, 1.0)
    return -jnp.sum(jnp.exp(logp) * logp, axis=-1)
