"""Token sampling: temperature + nucleus (top-p), returning the log-prob of
the sampled token under the *actual* sampling distribution.

SPEC-RL correctness requires the cached behaviour log-probs ``p_prev`` to be
the true probabilities the rollout engine sampled from — i.e. *after*
temperature and top-p renormalisation — so that the acceptance ratio
q/p in Eq. (2) is exact.  ``sample`` therefore returns that log-prob.

Per-request PRNG streams
------------------------
Every sampling entry point accepts either one PRNG key of shape (2,) —
classic batched sampling, where a row's draw depends on its batch index —
or per-row keys of shape (B, 2), where row b is sampled from its own key.
Per-row keys make a row's token stream a function of (its key, its tokens)
alone, independent of batch size, batch position and co-batched rows.  That
invariance is what lets the serving slot scheduler (DESIGN.md §6) re-batch
requests freely while staying token-identical to fixed-batch ``generate``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def split_key(key):
    """``jax.random.split`` lifted over an optional per-row key batch.

    key: (2,) or (B, 2) uint32.  Returns (carry, sub) with key's shape; the
    (2,) case is exactly ``jax.random.split(key)``, so callers migrating to
    per-row keys keep their scalar-key PRNG streams bit-identical.
    """
    if jnp.ndim(key) == 2:
        ks = jax.vmap(jax.random.split)(key)          # (B, 2, 2)
        return ks[:, 0], ks[:, 1]
    k1, k2 = jax.random.split(key)
    return k1, k2


def adjust_logits(logits, temperature: float = 1.0, top_p: float = 1.0):
    """Return renormalised log-probs of the sampling distribution.

    logits: (..., V) float32.
    """
    if temperature != 1.0:
        logits = logits / jnp.maximum(temperature, 1e-6)
    logp = jax.nn.log_softmax(logits, axis=-1)
    if top_p < 1.0:
        sorted_lp = jnp.sort(logp, axis=-1)[..., ::-1]
        cum = jnp.cumsum(jnp.exp(sorted_lp), axis=-1)
        # keep the smallest set whose mass >= top_p (always keep argmax)
        keep_sorted = (cum - jnp.exp(sorted_lp)) < top_p
        # threshold log-prob: smallest kept log-prob
        thresh = jnp.min(jnp.where(keep_sorted, sorted_lp, jnp.inf),
                         axis=-1, keepdims=True)
        logp = jnp.where(logp >= thresh, logp, NEG_INF)
        logp = jax.nn.log_softmax(logp, axis=-1)
    return logp


def sample(key, logits, temperature: float = 1.0, top_p: float = 1.0):
    """Sample one token per row.

    logits: (B, V); key: (2,) for batched sampling or (B, 2) for per-row
    streams (see module docstring).  Returns (token (B,) int32, logprob
    (B,) float32) where logprob is under the temperature/top-p-adjusted
    distribution.
    """
    logp = adjust_logits(logits.astype(jnp.float32), temperature, top_p)
    if temperature <= 0.0:
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return tok, jnp.zeros(tok.shape, jnp.float32)
    if jnp.ndim(key) == 2:
        tok = jax.vmap(
            lambda k, lp: jax.random.categorical(k, lp))(key, logp)
        tok = tok.astype(jnp.int32)
    else:
        tok = jax.random.categorical(key, logp, axis=-1).astype(jnp.int32)
    lp = jnp.take_along_axis(logp, tok[..., None], axis=-1)[..., 0]
    return tok, lp


def residual_sample(key, logits, banned_tok, banned_mask,
                    temperature: float = 1.0, top_p: float = 1.0):
    """Sample from the adjusted distribution with one token excluded.

    The rejection-sampling correction step of draft-verify decoding
    (DESIGN.md §9): an n-gram draft is a *point mass* q = δ(g), so the
    residual distribution norm(max(p - q, 0)) is exactly p with g masked
    out and renormalised.  Where ``banned_mask`` is False (full-accept
    bonus token) this is plain ``sample``.

    logits: (B, V); banned_tok: (B,) int32; banned_mask: (B,) bool.
    Returns (token (B,) int32, logprob (B,) float32) — the log-prob is
    taken under the UNMASKED adjusted distribution, because the emitted
    token's marginal probability (accept-path ⊕ reject-path combined) is
    exactly p(token), which is what behaviour log-probs must record.

    temperature <= 0 is greedy: argmax of the raw logits, identical to
    ``sample`` (a greedy rejection implies draft != argmax, so the ban
    never intersects the argmax).
    """
    logp = adjust_logits(logits.astype(jnp.float32), temperature, top_p)
    if temperature <= 0.0:
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return tok, jnp.zeros(tok.shape, jnp.float32)
    V = logits.shape[-1]
    ban = banned_mask[:, None] & (jnp.arange(V, dtype=jnp.int32)[None, :]
                                  == banned_tok[:, None])
    masked = jax.nn.log_softmax(jnp.where(ban, NEG_INF, logp), axis=-1)
    if jnp.ndim(key) == 2:
        tok = jax.vmap(
            lambda k, lp: jax.random.categorical(k, lp))(key, masked)
        tok = tok.astype(jnp.int32)
    else:
        tok = jax.random.categorical(key, masked, axis=-1).astype(jnp.int32)
    lp = jnp.take_along_axis(logp, tok[..., None], axis=-1)[..., 0]
    return tok, lp


def logprobs_of(logits, tokens, temperature: float = 1.0, top_p: float = 1.0):
    """Log-prob of given tokens under the adjusted distribution.

    logits: (..., V); tokens: (...). Returns (...) float32.
    """
    logp = adjust_logits(logits.astype(jnp.float32), temperature, top_p)
    return jnp.take_along_axis(logp, tokens[..., None].astype(jnp.int32),
                               axis=-1)[..., 0]


def entropy_of(logits, temperature: float = 1.0):
    logp = adjust_logits(logits.astype(jnp.float32), temperature, 1.0)
    return -jnp.sum(jnp.exp(logp) * logp, axis=-1)
