"""Pallas TPU kernels for SPEC-RL hot spots.

Each kernel subpackage ships kernel.py (pl.pallas_call + BlockSpec tiling),
ops.py (jit'd public wrapper) and ref.py (pure-jnp oracle used by tests).
"""
