"""Pallas-TPU kernel for SPEC-RL KV-cache compaction (cache_gather).

After the fused verify+prefill forward, each row's accepted context
[left-padded prompt | draft[:n]] already sits *contiguously* in the cache at
slots [P - p_len, P + n).  Left-aligning it to the decode layout is therefore
a per-row circular shift along the sequence axis — not an arbitrary gather —
so the whole compaction is one fused dynamic-roll per (row, head) with a
single HBM read and write per cache buffer, replacing the old host-visible
``left_align`` + second prefill round trip.

Grid: one program per flattened (run, batch, head) row.  The per-row shift
arrives via scalar prefetch (SMEM) so it is available before the block DMA.
The roll is realised as a dynamic slice of the sequence-doubled block, whose
semantics (out[j] = x[(j - shift) mod S]) are stable across backends and
interpret mode; wrapped-in slots carry stale K/V but their cache positions
are rewritten to -1 by the caller, and position-masked attention never reads
them (see DESIGN.md §3).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _roll_kernel(shift_ref, in_ref, out_ref, *, seq_len: int):
    r = pl.program_id(0)
    s = shift_ref[r]
    x = in_ref[0]                                    # (S, D)
    doubled = jnp.concatenate([x, x], axis=0)        # (2S, D)
    out_ref[0] = jax.lax.dynamic_slice_in_dim(doubled, seq_len - s, seq_len,
                                              axis=0)


def cache_roll_pallas(buf, shift, *, interpret: bool = False):
    """buf: (R, S, D); shift: (R,) int32 in [0, S].

    Returns out with out[r, j] = buf[r, (j - shift[r]) mod S].
    """
    R, S, D = buf.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(R,),
        in_specs=[pl.BlockSpec((1, S, D), lambda r, shift_ref: (r, 0, 0))],
        out_specs=pl.BlockSpec((1, S, D), lambda r, shift_ref: (r, 0, 0)),
    )
    return pl.pallas_call(
        functools.partial(_roll_kernel, seq_len=S),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(buf.shape, buf.dtype),
        interpret=interpret,
    )(shift.astype(jnp.int32), buf)


def _gather_kernel(tab_ref, pool_ref, out_ref):
    del tab_ref  # consumed by the index map, not the body
    out_ref[0, 0] = pool_ref[0]


def paged_gather_pallas(pool, table, *, interpret: bool = False):
    """Paged-cache gather: materialise logical rows from a block pool.

    pool: (NB, X, D) physical blocks (X = bs, or Hkv*bs with heads folded
    into the sublane dim); table: (R, nb) int32 block ids.  Returns
    (R, nb, X, D) with out[r, i] = pool[table[r, i]].

    The table rides scalar prefetch so each program's block DMA is
    redirected at *index-map* time — the same machinery the paged decode
    kernel uses — and the kernel body is a pure VMEM copy (the compaction
    counterpart of cache_roll for the §13 layout).
    """
    NB, X, D = pool.shape
    R, nb = table.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(R, nb),
        in_specs=[pl.BlockSpec((1, X, D),
                               lambda r, i, tab_ref: (tab_ref[r, i], 0, 0))],
        out_specs=pl.BlockSpec((1, 1, X, D),
                               lambda r, i, tab_ref: (r, i, 0, 0)),
    )
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((R, nb, X, D), pool.dtype),
        interpret=interpret,
    )(table.astype(jnp.int32), pool)
