"""Jit'd public wrapper for the cache_gather compaction kernel.

``cache_roll`` right-rotates each (S, D) row of a flattened KV-cache buffer
by a per-row shift — the primitive behind model.realign_decode_cache, which
left-aligns verified [prompt | draft[:n]] context for cache-resumed decoding.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import cache_roll_pallas, paged_gather_pallas
from .ref import cache_roll_ref


@functools.partial(jax.jit, static_argnames=("impl",))
def cache_roll(buf, shift, *, impl: str = "auto"):
    """buf: (R, S, D); shift: (R,) int32 in [0, S].

    Returns out[r, j] = buf[r, (j - shift[r]) mod S].
    impl: 'auto' (pallas on TPU, ref elsewhere) | 'pallas' | 'interpret' | 'ref'.
    """
    assert buf.ndim == 3, buf.shape
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return cache_roll_ref(buf, shift)
    return cache_roll_pallas(buf, shift, interpret=(impl == "interpret"))


@functools.partial(jax.jit, static_argnames=("impl",))
def paged_gather(pool, table, *, impl: str = "auto"):
    """pool: (NB, X, D); table: (R, nb) int32 in [0, NB).

    Returns (R, nb, X, D) with out[r, i] = pool[table[r, i]] — the paged
    counterpart of this module's compaction primitive (DESIGN.md §13): it
    materialises the dense logical view of a block pool, which the paged
    realign path rolls with ``cache_roll`` before re-paging.
    impl: 'auto' (pallas on TPU, ref elsewhere) | 'pallas' | 'interpret' | 'ref'.
    """
    assert pool.ndim == 3 and table.ndim == 2, (pool.shape, table.shape)
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        R, nb = table.shape
        return jnp.take(pool, table.reshape(-1), axis=0).reshape(
            R, nb, *pool.shape[1:])
    return paged_gather_pallas(pool, table, interpret=(impl == "interpret"))
