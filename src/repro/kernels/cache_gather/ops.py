"""Jit'd public wrapper for the cache_gather compaction kernel.

``cache_roll`` right-rotates each (S, D) row of a flattened KV-cache buffer
by a per-row shift — the primitive behind model.realign_decode_cache, which
left-aligns verified [prompt | draft[:n]] context for cache-resumed decoding.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import cache_roll_pallas
from .ref import cache_roll_ref


@functools.partial(jax.jit, static_argnames=("impl",))
def cache_roll(buf, shift, *, impl: str = "auto"):
    """buf: (R, S, D); shift: (R,) int32 in [0, S].

    Returns out[r, j] = buf[r, (j - shift[r]) mod S].
    impl: 'auto' (pallas on TPU, ref elsewhere) | 'pallas' | 'interpret' | 'ref'.
    """
    assert buf.ndim == 3, buf.shape
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return cache_roll_ref(buf, shift)
    return cache_roll_pallas(buf, shift, interpret=(impl == "interpret"))
