"""jnp oracle for cache_gather: per-row circular right-shift along axis -2."""
from __future__ import annotations

import jax.numpy as jnp


def cache_roll_ref(buf, shift):
    """buf: (R, S, D); shift: (R,) int32.

    out[r, j] = buf[r, (j - shift[r]) mod S] — a single take_along_axis
    gather (the same closed form the Pallas kernel realises as a dynamic
    slice of the sequence-doubled block).
    """
    S = buf.shape[1]
    j = jnp.arange(S, dtype=jnp.int32)[None, :]
    src = (j - shift[:, None].astype(jnp.int32)) % S
    return jnp.take_along_axis(buf, src[:, :, None], axis=1)
