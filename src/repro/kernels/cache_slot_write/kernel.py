"""Pallas-TPU kernel for serving slot admission (cache_slot_write).

The continuous-batching engine (DESIGN.md §6) keeps one persistent dense KV
cache of B slots.  When a slot frees, the next request's freshly prefilled
cache row must replace the old row *in place* — a batched scatter along the
flattened (run, batch, head) row axis, the write-side dual of cache_gather's
per-row roll and sharing its (R, S, D) layout.

Rather than scattering source rows (which would leave unwritten output
blocks undefined without buffer aliasing), the kernel walks every
*destination* row and pulls: the per-row source index arrives via scalar
prefetch (SMEM), the input BlockSpec index map redirects the DMA to either
the selected source row or the old destination row, and the body writes a
select of the two.  One HBM read + write per cache row, no aliasing
requirement, stable semantics in interpret mode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _slot_write_kernel(idx_ref, src_ref, dst_ref, out_ref):
    d = pl.program_id(0)
    out_ref[0] = jnp.where(idx_ref[d] >= 0, src_ref[0], dst_ref[0])


def cache_slot_write_pallas(dst, src, src_for_dst, *, interpret: bool = False):
    """dst: (Rd, S, D); src: (Rs, S, D); src_for_dst: (Rd,) int32.

    Returns out with out[d] = src[src_for_dst[d]] where src_for_dst[d] >= 0
    and out[d] = dst[d] elsewhere.
    """
    Rd, S, D = dst.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(Rd,),
        in_specs=[
            # source block: redirected per destination row (clamped for the
            # keep case, whose DMA result is discarded by the select)
            pl.BlockSpec((1, S, D),
                         lambda d, idx_ref: (jnp.maximum(idx_ref[d], 0), 0, 0)),
            pl.BlockSpec((1, S, D), lambda d, idx_ref: (d, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, S, D), lambda d, idx_ref: (d, 0, 0)),
    )
    return pl.pallas_call(
        _slot_write_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(dst.shape, dst.dtype),
        interpret=interpret,
    )(src_for_dst.astype(jnp.int32), src.astype(dst.dtype), dst)
