"""Jit'd public wrapper for the cache_slot_write admission kernel.

``cache_slot_write`` replaces selected rows of a flattened KV-cache buffer
with freshly prefilled source rows — the primitive behind
model.write_cache_slots, which admits new requests into the persistent
serving batch by in-place slot replacement (DESIGN.md §6).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import cache_slot_write_pallas
from .ref import cache_slot_write_ref


def _invert_rows(dst_rows, n_dst: int, n_src: int):
    """dst_rows: (Rs,) -> src_for_dst: (Rd,) with -1 for untouched rows.

    Deterministic on duplicates: the LAST source row targeting a
    destination wins (the admission path only ever duplicates identical
    rows, but the contract should not depend on scatter ordering).
    scatter-max over source indices IS last-wins — "last" = highest index —
    and stays O(Rd + Rs) on the admission hot path.
    """
    return jnp.full((n_dst,), -1, jnp.int32).at[dst_rows].max(
        jnp.arange(n_src, dtype=jnp.int32), mode="drop")


@functools.partial(jax.jit, static_argnames=("impl",))
def cache_slot_write(dst, src, dst_rows, *, impl: str = "auto"):
    """dst: (Rd, S, D); src: (Rs, S, D); dst_rows: (Rs,) int32 in [0, Rd).

    Returns out with out[dst_rows[i]] = src[i] and every other destination
    row unchanged.  Duplicate dst_rows: the last source row wins.
    impl: 'auto' (pallas on TPU, ref elsewhere) | 'pallas' | 'interpret' | 'ref'.
    """
    assert dst.ndim == 3 and src.ndim == 3, (dst.shape, src.shape)
    assert dst.shape[1:] == src.shape[1:], (dst.shape, src.shape)
    src_for_dst = _invert_rows(dst_rows.astype(jnp.int32), dst.shape[0],
                               src.shape[0])
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return cache_slot_write_ref(dst, src, src_for_dst)
    return cache_slot_write_pallas(dst, src, src_for_dst,
                                   interpret=(impl == "interpret"))
