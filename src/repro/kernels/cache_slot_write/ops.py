"""Jit'd public wrapper for the cache_slot_write admission kernel.

``cache_slot_write`` replaces selected rows of a flattened KV-cache buffer
with freshly prefilled source rows — the primitive behind
model.write_cache_slots, which admits new requests into the persistent
serving batch by in-place slot replacement (DESIGN.md §6).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import cache_slot_write_pallas
from .ref import cache_slot_write_ref


def _invert_rows(dst_rows, n_dst: int, n_src: int):
    """dst_rows: (Rs,) -> src_for_dst: (Rd,) with -1 for untouched rows.

    Deterministic on duplicates: the LAST source row targeting a
    destination wins (the admission path only ever duplicates identical
    rows, but the contract should not depend on scatter ordering).
    scatter-max over source indices IS last-wins — "last" = highest index —
    and stays O(Rd + Rs) on the admission hot path.
    """
    return jnp.full((n_dst,), -1, jnp.int32).at[dst_rows].max(
        jnp.arange(n_src, dtype=jnp.int32), mode="drop")


@functools.partial(jax.jit, static_argnames=("impl",))
def cache_slot_write(dst, src, dst_rows, *, impl: str = "auto"):
    """dst: (Rd, S, D); src: (Rs, S, D); dst_rows: (Rs,) int32 in [0, Rd).

    Returns out with out[dst_rows[i]] = src[i] and every other destination
    row unchanged.  Duplicate dst_rows: the last source row wins.
    impl: 'auto' (pallas on TPU, ref elsewhere) | 'pallas' | 'interpret' | 'ref'.
    """
    assert dst.ndim == 3 and src.ndim == 3, (dst.shape, src.shape)
    assert dst.shape[1:] == src.shape[1:], (dst.shape, src.shape)
    src_for_dst = _invert_rows(dst_rows.astype(jnp.int32), dst.shape[0],
                               src.shape[0])
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return cache_slot_write_ref(dst, src, src_for_dst)
    return cache_slot_write_pallas(dst, src, src_for_dst,
                                   interpret=(impl == "interpret"))


@functools.partial(jax.jit, static_argnames=("impl",))
def paged_slot_write(pool, src, tables, *, impl: str = "auto"):
    """Paged admission counterpart of ``cache_slot_write`` (DESIGN.md §13).

    pool: (run, NB, Hkv, bs, D) or (run, NB, bs, D) physical block pool;
    src: (run, R, Hkv, S, D) / (run, R, S, D) dense admitted rows with
    S == nb * bs; tables: (run, R, nb) int32 — the block-table rows of the
    admitted slots.  Each dense source row is cut into nb logical blocks
    and scattered to the physical blocks its table references; every other
    pool block is untouched.

    The scatter itself reuses the ``cache_slot_write`` dest-walking kernel:
    physical blocks are flattened to (run*NB, Hkv*bs, D) rows and the
    block ids become destination-row indices, so the Pallas path gets the
    same redirect-the-DMA schedule admission already uses for dense slots.
    """
    run_len, NB = pool.shape[:2]
    bs, D = pool.shape[-2], pool.shape[-1]
    nb = tables.shape[-1]
    R = tables.shape[1]
    assert tables.shape == (run_len, R, nb), tables.shape
    if pool.ndim == 5:
        Hkv = pool.shape[2]
        blocks = (src.reshape(run_len, R, Hkv, nb, bs, D)
                  .transpose(0, 1, 3, 2, 4, 5)
                  .reshape(run_len * R * nb, Hkv * bs, D))
        flat_pool = pool.reshape(run_len * NB, Hkv * bs, D)
    else:
        blocks = src.reshape(run_len * R * nb, bs, D)
        flat_pool = pool.reshape(run_len * NB, bs, D)
    r0 = jnp.arange(run_len, dtype=jnp.int32)[:, None, None]
    rows = (r0 * NB + tables.astype(jnp.int32)).reshape(-1)
    out = cache_slot_write(flat_pool, blocks.astype(pool.dtype), rows,
                           impl=impl)
    return out.reshape(pool.shape)
