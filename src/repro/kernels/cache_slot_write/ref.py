"""jnp oracle for cache_slot_write: batched row scatter with keep-mask.

The serving slot scheduler admits freshly prefilled requests into a
persistent decode batch by replacing whole cache rows in place (DESIGN.md
§6).  The closed form is a select over the destination rows: row ``d`` takes
source row ``src_for_dst[d]`` when that index is >= 0 and keeps its old
contents otherwise — the same inverse-map formulation the Pallas kernel
realises block-by-block.
"""
from __future__ import annotations

import jax.numpy as jnp


def cache_slot_write_ref(dst, src, src_for_dst):
    """dst: (Rd, S, D); src: (Rs, S, D); src_for_dst: (Rd,) int32.

    out[d] = src[src_for_dst[d]] if src_for_dst[d] >= 0 else dst[d].
    """
    take = jnp.clip(src_for_dst.astype(jnp.int32), 0, src.shape[0] - 1)
    keep = (src_for_dst < 0)[:, None, None]
    return jnp.where(keep, dst, src[take].astype(dst.dtype))
