"""Pallas-TPU flash-decode: split-K short-query GQA/MQA attention with
per-row cache-length early exit (DESIGN.md §7, §9).

The prefill-shaped flash kernel is degenerate at decode time: a T=1 query
gives ``block_q = 1`` — a single-row MXU tile — and every token pays
attention over the full allocated cache width S even when most slots are
empty.  This kernel is specialised for the decode shape instead:

* **Head×query packing.**  The ``G = Hq / Hkv`` query heads that share one
  KV head, times the T block queries (T == 1 for classic decode, k + 1 for
  a draft-verify block), are packed into the MXU *sublane* dimension, so
  each KV tile is consumed by one ``(G·T, Dk) × (Dk, block_k)`` matmul
  rather than G·T single-row tiles, and each KV block is fetched exactly
  once per group.

* **Split-K.**  The grid is ``(B, Hkv, S / block_k)`` — cache slots are
  *split* across programs.  Each program emits an online-softmax partial
  (row max ``m``, row sum ``l``, unnormalised accumulator ``acc``) for its
  slot range; a cheap second-stage jnp combine (`_combine`) merges the
  partials with the standard logsumexp rescaling.  Splits are independent,
  so there is no sequential scratch carry and the (tiny-T) grid parallelism
  lost to small ``block_q`` is recovered across the split axis.

* **Per-row early exit.**  Per-row live bounds arrive via scalar prefetch:
  ``lengths`` (write offset + block width — essential for the serving slot
  engine, whose rows sit at different decode depths) and ``starts`` (the
  first live slot — the §3 compacted layout right-aligns context at the
  verify width, so a short accepted prefix has a dead left-pad region in
  front of it).  A split whose slot range falls outside
  [starts[b], lengths[b]) redirects its K/V/k_pos block DMAs to block 0
  (already resident — no HBM traffic) and skips the matmul entirely,
  writing the softmax-neutral partial (m=-inf, l=0, acc=0).

* **Query-block contract.**  Query positions arrive as two scalars per
  row — ``q_pos0[b]`` (position of query 0) and ``q_len[b]`` (number of
  valid queries) — so query t sits at position ``q_pos0 + t`` when
  ``t < q_len`` and is fully masked (exact-zero output) otherwise.  This
  matches the decode layouts that reach the kernel: a done row has
  ``q_len == 0``; a draft block proposes a valid prefix of its T columns.
  The ops wrapper derives both from the (B, T) position array; arbitrary
  non-contiguous query positions belong on the ref/blocked paths.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, start_ref, qpos0_ref, qlen_ref, kpos_ref, q_ref,
                   k_ref, v_ref, m_ref, l_ref, acc_ref, *, scale: float,
                   window: int, block_k: int, T: int):
    b = pl.program_id(0)
    s_i = pl.program_id(2)
    start = s_i * block_k
    live = (start < len_ref[b]) & (start + block_k > start_ref[b])

    @pl.when(jnp.logical_not(live))
    def _dead():
        # softmax-neutral partial: ignored by the combine stage
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(live)
    def _live():
        q = q_ref[0, 0].astype(jnp.float32)              # (G*T, Dk)
        k = k_ref[0, 0].astype(jnp.float32)              # (bk, Dk)
        v = v_ref[0, 0].astype(jnp.float32)              # (bk, Dv)
        GT = q.shape[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kpos = kpos_ref[0].astype(jnp.int32)[None, :]    # (1, bk)
        # sublane row r = g*T + t: query t of group g, at position qpos0 + t
        t_idx = jax.lax.broadcasted_iota(jnp.int32, (GT, block_k), 0) % T
        qpos = qpos0_ref[b] + t_idx
        mask = (kpos >= 0) & (kpos <= qpos) & (t_idx < qlen_ref[b])
        if window > 0:
            mask &= (qpos - kpos) < window
        j = start + jax.lax.broadcasted_iota(jnp.int32, (GT, block_k), 1)
        mask &= (j < len_ref[b]) & (j >= start_ref[b])
        s = jnp.where(mask, s, NEG_INF)
        m = jnp.max(s, axis=1, keepdims=True)            # (G*T, 1)
        p = jnp.where(mask, jnp.exp(s - m), 0.0)
        m_ref[0, 0, 0] = m[:, 0]
        l_ref[0, 0, 0] = jnp.sum(p, axis=1)
        acc_ref[0, 0, 0] = jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)    # (G*T, Dv)


def _combine(m, l, acc):
    """Second-stage split-K merge over axis 2 (the split axis).

    m, l: (B, Hkv, nsplit, G*T); acc: (B, Hkv, nsplit, G*T, Dv).
    Standard logsumexp rescale; fully-masked rows (every split neutral)
    come out exactly zero."""
    m_glob = jnp.max(m, axis=2)                          # (B, Hkv, G*T)
    coef = jnp.exp(m - m_glob[:, :, None, :])
    l_tot = jnp.sum(coef * l, axis=2)                    # (B, Hkv, G*T)
    acc_tot = jnp.sum(coef[..., None] * acc, axis=2)     # (B, Hkv, G*T, Dv)
    return acc_tot / jnp.where(l_tot > 0, l_tot, 1.0)[..., None]


def _paged_kernel(len_ref, start_ref, qpos0_ref, qlen_ref, table_ref,
                  kpos_ref, q_ref, k_ref, v_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, window: int, block_k: int, T: int):
    # identical math to the dense kernel — the block table only redirects
    # the K/V DMAs (see the index maps in paged_decode_attention_pallas)
    del table_ref
    _decode_kernel(len_ref, start_ref, qpos0_ref, qlen_ref, kpos_ref, q_ref,
                   k_ref, v_ref, m_ref, l_ref, acc_ref, scale=scale,
                   window=window, block_k=block_k, T=T)


def paged_decode_attention_pallas(q, k_pool, v_pool, table, q_pos0, q_len,
                                  k_pos, lengths, starts, *, window: int = 0,
                                  interpret: bool = False):
    """Flash-decode over a paged KV cache (DESIGN.md §13).

    Same split-K schedule and kernel body as ``decode_attention_pallas``,
    but K/V live in a physical block pool — ``k_pool``: (NB, Hkv, bs, Dk),
    ``v_pool``: (NB, Hkv, bs, Dv) — and each row's logical cache is defined
    by ``table``: (B, nb) int32 block ids.  The split axis of the grid *is*
    the logical block axis (``block_k == bs``), so the per-split K/V index
    maps simply translate split ``s`` through the prefetched table:
    ``table[b, s]``.  Dead splits (outside [starts, lengths)) redirect to
    physical block 0 — the allocator's pinned sink — exactly as the dense
    kernel redirects to its own block 0.  ``k_pos`` stays dense (B, S =
    nb*bs), so masking is untouched: outputs are bit-identical to running
    the dense kernel on the gathered cache.
    """
    B, Hq, T, Dk = q.shape
    NB, Hkv, bs, _ = k_pool.shape
    Dv = v_pool.shape[-1]
    nb = table.shape[1]
    S = nb * bs
    assert k_pos.shape == (B, S), (k_pos.shape, (B, S))
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, T, Dk).reshape(B, Hkv, G * T, Dk)
    scale = 1.0 / (Dk ** 0.5)

    def _live_split(s, len_ref, start_ref, b):
        return (s * bs < len_ref[b]) & ((s + 1) * bs > start_ref[b])

    def _kv_block(b, h, s, len_ref, start_ref, qp_ref, ql_ref, table_ref):
        # live split s of row b reads physical block table[b, s]; dead
        # splits re-fetch the sink (block 0) instead of streaming recycled
        # blocks (same-block DMA is elided)
        live = _live_split(s, len_ref, start_ref, b)
        return (jnp.where(live, table_ref[b, s], 0), h, 0, 0)

    def _kpos_block(b, h, s, len_ref, start_ref, *_):
        return (b, jnp.where(_live_split(s, len_ref, start_ref, b), s, 0))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=5,
        grid=(B, Hkv, nb),
        in_specs=[
            pl.BlockSpec((1, bs), _kpos_block),
            pl.BlockSpec((1, 1, G * T, Dk), lambda b, h, s, *_: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bs, Dk), _kv_block),
            pl.BlockSpec((1, 1, bs, Dv), _kv_block),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, G * T), lambda b, h, s, *_: (b, h, s, 0)),
            pl.BlockSpec((1, 1, 1, G * T), lambda b, h, s, *_: (b, h, s, 0)),
            pl.BlockSpec((1, 1, 1, G * T, Dv),
                         lambda b, h, s, *_: (b, h, s, 0, 0)),
        ],
    )
    m, l, acc = pl.pallas_call(
        functools.partial(_paged_kernel, scale=scale, window=window,
                          block_k=bs, T=T),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, Hkv, nb, G * T), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, nb, G * T), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, nb, G * T, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(lengths.astype(jnp.int32), starts.astype(jnp.int32),
      q_pos0.astype(jnp.int32), q_len.astype(jnp.int32),
      table.astype(jnp.int32), k_pos, qg, k_pool, v_pool)
    out = _combine(m, l, acc)                            # (B, Hkv, G*T, Dv)
    return out.reshape(B, Hkv, G, T, Dv).reshape(B, Hq, T, Dv)


def decode_attention_pallas(q, k, v, q_pos0, q_len, k_pos, lengths, starts, *,
                            window: int = 0, block_k: int = 128,
                            interpret: bool = False):
    """q: (B, Hq, T, Dk); k: (B, Hkv, S, Dk); v: (B, Hkv, S, Dv);
    q_pos0/q_len: (B,) int32 query-block descriptors (query t lives at
    position q_pos0 + t iff t < q_len); k_pos: (B, S) int32;
    lengths/starts: (B,) int32 live bounds (slot j live iff
    starts[b] <= j < lengths[b]).

    Returns (B, Hq, T, Dv) float32.  Dk and Dv may differ (MLA)."""
    B, Hq, T, Dk = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = Hq // Hkv
    block_k = min(block_k, S)
    pad_s = (-S) % block_k
    if pad_s:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_s), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_s), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad_s)), constant_values=-1)
    Sp = k.shape[2]
    nsplit = Sp // block_k
    # pack (G, T) into the sublane dim: row g*T + t
    qg = q.reshape(B, Hkv, G, T, Dk).reshape(B, Hkv, G * T, Dk)
    scale = 1.0 / (Dk ** 0.5)

    def _live_split(s, len_ref, start_ref, b):
        return (s * block_k < len_ref[b]) & ((s + 1) * block_k > start_ref[b])

    def _kv_block(b, h, s, len_ref, start_ref, *_):
        # early exit: dead splits re-fetch block 0 instead of streaming the
        # dead left-pad / empty tail (same-block DMA is elided)
        return (b, h, jnp.where(_live_split(s, len_ref, start_ref, b), s, 0),
                0)

    def _kpos_block(b, h, s, len_ref, start_ref, *_):
        return (b, jnp.where(_live_split(s, len_ref, start_ref, b), s, 0))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(B, Hkv, nsplit),
        in_specs=[
            pl.BlockSpec((1, block_k), _kpos_block),
            pl.BlockSpec((1, 1, G * T, Dk), lambda b, h, s, *_: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, Dk), _kv_block),
            pl.BlockSpec((1, 1, block_k, Dv), _kv_block),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, G * T), lambda b, h, s, *_: (b, h, s, 0)),
            pl.BlockSpec((1, 1, 1, G * T), lambda b, h, s, *_: (b, h, s, 0)),
            pl.BlockSpec((1, 1, 1, G * T, Dv),
                         lambda b, h, s, *_: (b, h, s, 0, 0)),
        ],
    )
    m, l, acc = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, window=window,
                          block_k=block_k, T=T),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, Hkv, nsplit, G * T), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, nsplit, G * T), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, nsplit, G * T, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(lengths.astype(jnp.int32), starts.astype(jnp.int32),
      q_pos0.astype(jnp.int32), q_len.astype(jnp.int32), k_pos, qg, k, v)
    out = _combine(m, l, acc)                            # (B, Hkv, G*T, Dv)
    return out.reshape(B, Hkv, G, T, Dv).reshape(B, Hq, T, Dv)
