"""Jit'd public wrapper for flash-decode attention.

``decode_attention`` is the short-query decode dual of
``kernels/flash_attention``: every decode step in ``generate``,
``resume_from_cache`` and the serving slot engine routes here (see
models/attention.py), as does the k+1-token draft-verify block of the
drafting engine (DESIGN.md §9).  ``lengths`` carries each row's live cache
extent (write offset + block width) and ``starts`` its first live slot
(dead left-padding in front of a compacted / left-padded context), letting
the blocked path iterate only live chunks and the Pallas kernel early-exit
per row.

``q_pos`` may be (B,) / (B, 1) (classic single-token decode) or (B, T) for
a T-token block.  The Pallas path additionally requires the block layout
every decode caller produces: per row, a valid prefix of queries at
consecutive positions (q_pos[b, t] == q_pos[b, 0] + t for t < q_len, -1
after) — the wrapper derives the (q_pos0, q_len) scalars the kernel
prefetches.  The ref/blocked oracles accept arbitrary per-query positions.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import decode_attention_pallas, paged_decode_attention_pallas
from .ref import decode_attention_blocked, decode_attention_ref

# Below this cache width a single naive score pass beats the blocked
# while_loop's bookkeeping (one block_k=128 chunk covers it anyway).
NAIVE_MAX_S = 128


@functools.partial(jax.jit, static_argnames=("window", "impl", "block_k"))
def decode_attention(q, k, v, q_pos, k_pos, lengths=None, starts=None, *,
                     window: int = 0, impl: str = "auto",
                     block_k: int = 128):
    """Short-query decode attention over a dense cache.

    q: (B, Hq, T, Dk) with small T (1 = classic decode, k+1 = draft-verify
    block); k: (B, Hkv, S, Dk); v: (B, Hkv, S, Dv) (Dk may differ from Dv —
    MLA); q_pos: (B,), (B, 1) or (B, T); k_pos: (B, S); lengths/starts:
    optional (B,) int32 live bounds — slot j of row b is attended only when
    starts[b] <= j < lengths[b] (None = [0, S)).  Returns (B, Hq, T, Dv)
    float32.

    impl: 'auto' (pallas on TPU; elsewhere naive for S <= NAIVE_MAX_S,
    length-bounded blocked beyond) | 'pallas' | 'interpret' | 'blocked' |
    'naive'.
    """
    if impl == "auto":
        if jax.default_backend() == "tpu":
            impl = "pallas"
        elif k.shape[2] <= NAIVE_MAX_S:
            impl = "naive"
        else:
            impl = "blocked"
    if impl == "naive":
        return decode_attention_ref(q, k, v, q_pos, k_pos, lengths, starts,
                                    window=window)
    if impl == "blocked":
        return decode_attention_blocked(q, k, v, q_pos, k_pos, lengths,
                                        starts, window=window,
                                        block_k=block_k)
    B, _, T = q.shape[:3]
    S = k.shape[2]
    if lengths is None:
        lengths = jnp.full((B,), S, jnp.int32)
    lengths = jnp.minimum(lengths.reshape(B).astype(jnp.int32), S)
    if starts is None:
        starts = jnp.zeros((B,), jnp.int32)
    starts = jnp.clip(starts.reshape(B).astype(jnp.int32), 0, S)
    q_pos = q_pos.reshape(B, -1).astype(jnp.int32)
    if q_pos.shape != (B, T):
        # same rejection as ref._norm_inputs: a (B,)/(B, 1) position for a
        # T > 1 block would silently mean different things per impl
        raise ValueError(f"q_pos {q_pos.shape} must be (B, T)={B, T} for "
                         f"T > 1 query blocks")
    # valid-prefix query-block contract (see module docstring)
    q_pos0 = q_pos[:, 0]
    q_len = jnp.sum((q_pos >= 0).astype(jnp.int32), axis=1)
    return decode_attention_pallas(q, k, v, q_pos0, q_len, k_pos,
                                   lengths, starts, window=window,
                                   block_k=block_k,
                                   interpret=(impl == "interpret"))


def gather_paged_kv(pool, table):
    """Materialise the logical dense view of a paged K/V pool.

    pool: (NB, Hkv, bs, D) (GQA) or (NB, bs, D) (MLA latents); table:
    (B, nb) int32.  Returns (B, Hkv, nb*bs, D) / (B, nb*bs, D) — the exact
    array a dense cache would hold at the same positions, which is what
    makes every dense attention path (naive / blocked / mesh shard_map) a
    valid paged fallback.  Under jit the gather is dead-code-eliminated
    whenever the paged kernel path is taken instead.
    """
    B, nb = table.shape
    g = jnp.take(pool, table.reshape(-1), axis=0)
    if pool.ndim == 4:
        NB, Hkv, bs, D = pool.shape
        return (g.reshape(B, nb, Hkv, bs, D).transpose(0, 2, 1, 3, 4)
                .reshape(B, Hkv, nb * bs, D))
    NB, bs, D = pool.shape
    return g.reshape(B, nb * bs, D)


@functools.partial(jax.jit, static_argnames=("window", "impl"))
def paged_decode_attention(q, k_pool, v_pool, table, q_pos, k_pos,
                           lengths=None, starts=None, *, window: int = 0,
                           impl: str = "auto"):
    """Short-query decode attention over a paged cache (DESIGN.md §13).

    q: (B, Hq, T, Dk); k_pool/v_pool: (NB, Hkv, bs, D) physical block
    pools; table: (B, nb) int32 block table (logical slot j of row b lives
    at ``pool[table[b, j // bs], :, j % bs]``); k_pos: (B, nb*bs) dense
    positions; lengths/starts as in ``decode_attention``.

    impl: 'pallas' | 'interpret' run the paged flash kernel (split axis ==
    block axis, table-redirected DMAs); 'naive' | 'blocked' | 'auto'-on-CPU
    gather the pool to its dense view and defer to ``decode_attention`` —
    bit-identical by construction, and the oracle the kernel is tested
    against.
    """
    B, _, T = q.shape[:3]
    bs = k_pool.shape[-2]
    S = table.shape[1] * bs
    if k_pos.shape[1] < S:
        # logical width short of the block-rounded physical width: the
        # rounding slack is empty by construction, so pad with -1 (masked)
        k_pos = jnp.pad(k_pos, ((0, 0), (0, S - k_pos.shape[1])),
                        constant_values=-1)
    if impl == "auto":
        if jax.default_backend() == "tpu":
            impl = "pallas"
        else:
            impl = "naive" if S <= NAIVE_MAX_S else "blocked"
    if impl in ("naive", "blocked"):
        k = gather_paged_kv(k_pool, table)
        v = gather_paged_kv(v_pool, table)
        return decode_attention(q, k, v, q_pos, k_pos, lengths, starts,
                                window=window, impl=impl, block_k=bs)
    if lengths is None:
        lengths = jnp.full((B,), S, jnp.int32)
    lengths = jnp.minimum(lengths.reshape(B).astype(jnp.int32), S)
    if starts is None:
        starts = jnp.zeros((B,), jnp.int32)
    starts = jnp.clip(starts.reshape(B).astype(jnp.int32), 0, S)
    q_pos = q_pos.reshape(B, -1).astype(jnp.int32)
    if q_pos.shape != (B, T):
        raise ValueError(f"q_pos {q_pos.shape} must be (B, T)={B, T} for "
                         f"T > 1 query blocks")
    q_pos0 = q_pos[:, 0]
    q_len = jnp.sum((q_pos >= 0).astype(jnp.int32), axis=1)
    return paged_decode_attention_pallas(
        q, k_pool, v_pool, table, q_pos0, q_len, k_pos, lengths, starts,
        window=window, interpret=(impl == "interpret"))
