"""Jit'd public wrapper for flash-decode attention.

``decode_attention`` is the T==1 decode dual of
``kernels/flash_attention``: every decode step in ``generate``,
``resume_from_cache`` and the serving slot engine routes here (see
models/attention.py).  ``lengths`` carries each row's live cache extent
(write offset + 1) and ``starts`` its first live slot (dead left-padding
in front of a compacted / left-padded context), letting the blocked path
iterate only live chunks and the Pallas kernel early-exit per row.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import decode_attention_pallas
from .ref import decode_attention_blocked, decode_attention_ref

# Below this cache width a single naive score pass beats the blocked
# while_loop's bookkeeping (one block_k=128 chunk covers it anyway).
NAIVE_MAX_S = 128


@functools.partial(jax.jit, static_argnames=("window", "impl", "block_k"))
def decode_attention(q, k, v, q_pos, k_pos, lengths=None, starts=None, *,
                     window: int = 0, impl: str = "auto",
                     block_k: int = 128):
    """Single-token decode attention over a dense cache.

    q: (B, Hq, 1, Dk); k: (B, Hkv, S, Dk); v: (B, Hkv, S, Dv) (Dk may differ
    from Dv — MLA); q_pos: (B,) or (B, 1); k_pos: (B, S); lengths/starts:
    optional (B,) int32 live bounds — slot j of row b is attended only when
    starts[b] <= j < lengths[b] (None = [0, S)).  Returns (B, Hq, 1, Dv)
    float32.

    impl: 'auto' (pallas on TPU; elsewhere naive for S <= NAIVE_MAX_S,
    length-bounded blocked beyond) | 'pallas' | 'interpret' | 'blocked' |
    'naive'.
    """
    if impl == "auto":
        if jax.default_backend() == "tpu":
            impl = "pallas"
        elif k.shape[2] <= NAIVE_MAX_S:
            impl = "naive"
        else:
            impl = "blocked"
    if impl == "naive":
        return decode_attention_ref(q, k, v, q_pos, k_pos, lengths, starts,
                                    window=window)
    if impl == "blocked":
        return decode_attention_blocked(q, k, v, q_pos, k_pos, lengths,
                                        starts, window=window,
                                        block_k=block_k)
    B = q.shape[0]
    S = k.shape[2]
    if lengths is None:
        lengths = jnp.full((B,), S, jnp.int32)
    lengths = jnp.minimum(lengths.reshape(B).astype(jnp.int32), S)
    if starts is None:
        starts = jnp.zeros((B,), jnp.int32)
    starts = jnp.clip(starts.reshape(B).astype(jnp.int32), 0, S)
    return decode_attention_pallas(q, k, v, q_pos.reshape(B), k_pos,
                                   lengths, starts, window=window,
                                   block_k=block_k,
                                   interpret=(impl == "interpret"))
