"""Pure-jnp oracles for short-query decode attention.

Two reference implementations with identical semantics:

``decode_attention_ref``     the naive oracle — materialises the full
    (B, Hkv, G, T, S) score tensor.  At T == 1 it is term-for-term the
    decode slice of ``repro.models.attention.dot_product_attention`` (same
    einsum, same masking, same fully-masked-row zeroing), so routing decode
    through it is bit-identical to the legacy naive decode path.

``decode_attention_blocked`` the length-bounded flash path — a
    ``lax.while_loop`` over KV chunks that stops at the last *live* chunk
    (ceil(max(lengths) / block_k)), so per-token decode work is
    proportional to the deepest live cache row instead of the allocated
    cache width S.  This is the CPU/dry-run default for decode-shaped
    calls (see models/attention.py and DESIGN.md §7); the Pallas kernel
    additionally early-exits per *row*.

The query axis T is 1 for classic decode and ``k + 1`` for a draft-verify
block (DESIGN.md §9): the current token plus k drafted continuation tokens
forwarded together, each attending causally over the per-row live cache
bounds (the block's own K/V are already written into the cache, so
within-block causality is ordinary position masking).

Masking contract (shared with the kernel): key slot j of row b contributes
to query t iff ``k_pos[b, j] >= 0`` and ``k_pos[b, j] <= q_pos[b, t]`` and
(window) ``q_pos[b, t] - k_pos[b, j] < window`` and
``starts[b] <= j < lengths[b]``.  ``starts``/``lengths`` are performance
bounds — callers derive them from the cache layout (first live slot /
write offset + block width), so every slot outside [starts, lengths)
already carries ``pos == -1`` — but both are also enforced as masks so
ref/blocked/pallas agree even on adversarial inputs.  A query with
``q_pos == -1`` (done row / draft padding) comes out exactly zero.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _norm_inputs(q, q_pos, lengths, starts, S):
    """q: (B, Hq, T, D) unchanged; q_pos: (B,)/(B, 1) at T == 1, else
    strictly (B, T) — a (B,) position for a T > 1 block is ambiguous (the
    Pallas kernel's consecutive-position contract vs same-position
    broadcast), so every impl rejects it rather than diverging."""
    assert q.ndim == 4, f"decode attention wants (B, Hq, T, D); got {q.shape}"
    B, _, T = q.shape[:3]
    q_pos = q_pos.reshape(B, -1)
    if q_pos.shape != (B, T):
        raise ValueError(f"q_pos {q_pos.shape} must be (B, T)={B, T} for "
                         f"T > 1 query blocks")
    if lengths is None:
        lengths = jnp.full((B,), S, jnp.int32)
    lengths = jnp.minimum(lengths.reshape(B).astype(jnp.int32), S)
    if starts is None:
        starts = jnp.zeros((B,), jnp.int32)
    starts = jnp.clip(starts.reshape(B).astype(jnp.int32), 0, S)
    return q_pos, lengths, starts


def decode_attention_ref(q, k, v, q_pos, k_pos, lengths=None, starts=None, *,
                         window: int = 0):
    """q: (B, Hq, T, Dk); k: (B, Hkv, S, Dk); v: (B, Hkv, S, Dv);
    q_pos: (B,), (B, 1) or (B, T); k_pos: (B, S); lengths/starts: optional
    (B,) int32 live bounds (slot j live iff starts[b] <= j < lengths[b]).

    Returns (B, Hq, T, Dv) float32."""
    B, Hq, T = q.shape[:3]
    Hkv, S, Dk = k.shape[1], k.shape[2], k.shape[3]
    q_pos, lengths, starts = _norm_inputs(q, q_pos, lengths, starts, S)
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, T, Dk)
    scale = 1.0 / jnp.sqrt(jnp.asarray(Dk, jnp.float32))
    scores = jnp.einsum("bhgtd,bhsd->bhgts", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    kp = k_pos[:, None, None, None, :]
    qp = q_pos[:, None, None, :, None]
    mask = (kp >= 0) & (kp <= qp)
    if window > 0:
        mask &= (qp - kp) < window
    j = jnp.arange(S, dtype=jnp.int32)[None, None, None, None, :]
    mask &= j < lengths[:, None, None, None, None]
    mask &= j >= starts[:, None, None, None, None]
    scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    any_valid = jnp.any(mask, axis=-1, keepdims=True)
    w = jnp.where(any_valid, w, 0.0)
    out = jnp.einsum("bhgts,bhsd->bhgtd", w, v.astype(jnp.float32))
    return out.reshape(B, Hq, T, v.shape[-1])


def decode_attention_blocked(q, k, v, q_pos, k_pos, lengths=None, starts=None,
                             *, window: int = 0, block_k: int = 128):
    """Length-bounded online-softmax decode (same signature/result as ref).

    A ``while_loop`` over KV chunks runs from chunk min(starts) // block_k
    to ceil(max(lengths) / block_k) — real work savings even under jit,
    since both trip bounds are dynamic.  Peak score memory is
    (B, Hkv, G, T, block_k)."""
    B, Hq, T = q.shape[:3]
    Hkv, S, Dk = k.shape[1], k.shape[2], k.shape[3]
    Dv = v.shape[-1]
    q_pos, lengths, starts = _norm_inputs(q, q_pos, lengths, starts, S)
    G = Hq // Hkv
    block_k = min(block_k, S)
    pad = (-S) % block_k
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-1)
    qg = q.reshape(B, Hkv, G, T, Dk).astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(jnp.asarray(Dk, jnp.float32))
    c0 = jnp.min(starts) // block_k
    n_live = (jnp.max(lengths) + block_k - 1) // block_k
    jidx = jnp.arange(block_k, dtype=jnp.int32)

    def cond(carry):
        return carry[0] < n_live

    def body(carry):
        c, m, l, acc = carry
        start = c * block_k
        k_b = jax.lax.dynamic_slice_in_dim(k, start, block_k, axis=2)
        v_b = jax.lax.dynamic_slice_in_dim(v, start, block_k, axis=2)
        p_b = jax.lax.dynamic_slice_in_dim(k_pos, start, block_k, axis=1)
        s = jnp.einsum("bhgtd,bhsd->bhgts", qg,
                       k_b.astype(jnp.float32)) * scale
        kp = p_b[:, None, :]                                  # (B, 1, bk)
        qp = q_pos[:, :, None]                                # (B, T, 1)
        mask = (kp >= 0) & (kp <= qp)                         # (B, T, bk)
        if window > 0:
            mask &= (qp - kp) < window
        j = (start + jidx)[None, None, :]
        mask &= (j < lengths[:, None, None]) & (j >= starts[:, None, None])
        maskb = mask[:, None, None, :, :]                     # (B,1,1,T,bk)
        s = jnp.where(maskb, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.where(maskb, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m - m_new)
        l = corr * l + jnp.sum(p, axis=-1, keepdims=True)
        acc = corr * acc + jnp.einsum("bhgts,bhsd->bhgtd", p,
                                      v_b.astype(jnp.float32))
        return c + 1, m_new, l, acc

    init = (c0.astype(jnp.int32),
            jnp.full((B, Hkv, G, T, 1), NEG_INF, jnp.float32),
            jnp.zeros((B, Hkv, G, T, 1), jnp.float32),
            jnp.zeros((B, Hkv, G, T, Dv), jnp.float32))
    _, m, l, acc = jax.lax.while_loop(cond, body, init)
    out = acc / jnp.where(l > 0, l, 1.0)
    return out.reshape(B, Hq, T, Dv)
