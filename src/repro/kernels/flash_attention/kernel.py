"""Pallas-TPU flash attention (causal, GQA, optional sliding window,
position-based padding masks).

This is the hot spot of SPEC-RL's *verification* pass (a prefill-shaped
teacher-forced forward over the draft) and of prefill generally.

Tiling: grid = (batch, q_heads, q_tiles, kv_tiles), kv innermost.  Online
softmax state (row max `m`, row sum `l`, output accumulator) lives in VMEM
scratch sized (block_q, head_dim) — chosen so q/k/v tiles plus accumulators
fit comfortably in 16 MB VMEM with MXU-aligned (multiple-of-128) tiles at
production sizes.  GQA is expressed in the k/v BlockSpec index maps
(`h // group`), so kv tiles are fetched once per q-head group member without
materialising repeated heads.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(qpos_ref, kpos_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, scale: float, window: int,
                  causal: bool):
    kv_i = pl.program_id(3)

    @pl.when(kv_i == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                 # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)                 # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)                 # (bk, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qpos = qpos_ref[0].astype(jnp.int32)[:, None]       # (bq, 1)
    kpos = kpos_ref[0].astype(jnp.int32)[None, :]       # (1, bk)
    mask = kpos >= 0
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                 # (bq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = corr * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = corr * acc_scr[...] + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(kv_i == pl.num_programs(3) - 1)
    def _finish():
        l = l_scr[...]
        o = acc_scr[...] / jnp.where(l > 0, l, 1.0)
        o_ref[0, 0] = o.astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, q_pos, k_pos, *, causal: bool = True,
                           window: int = 0, block_q: int = 128,
                           block_k: int = 128, interpret: bool = False):
    """q: (B, Hq, T, D); k/v: (B, Hkv, S, D); q_pos: (B, T); k_pos: (B, S).

    Returns (B, Hq, T, D) float32 attention output.
    """
    B, Hq, T, D = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    group = Hq // Hkv
    block_q = min(block_q, T)
    block_k = min(block_k, S)
    pad_t = (-T) % block_q
    pad_s = (-S) % block_k
    if pad_t:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_t), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad_t)), constant_values=-1)
    if pad_s:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_s), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_s), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad_s)), constant_values=-1)
    Tp, Sp = q.shape[2], k.shape[2]

    grid = (B, Hq, Tp // block_q, Sp // block_k)
    scale = 1.0 / (D ** 0.5)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, window=window,
                          causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q), lambda b, h, t, s: (b, t)),
            pl.BlockSpec((1, block_k), lambda b, h, t, s: (b, s)),
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, t, s: (b, h, t, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, t, s, g=group: (b, h // g, s, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, t, s, g=group: (b, h // g, s, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, t, s: (b, h, t, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Tp, D), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q_pos, k_pos, q, k, v)
    return out[:, :, :T, :]
