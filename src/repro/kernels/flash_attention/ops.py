"""Jit'd public wrapper for flash attention."""
from __future__ import annotations

import functools

import jax

from .kernel import flash_attention_pallas
from .ref import flash_attention_ref


@functools.partial(jax.jit, static_argnames=("causal", "window", "impl",
                                             "block_q", "block_k"))
def flash_attention(q, k, v, q_pos, k_pos, *, causal: bool = True,
                    window: int = 0, impl: str = "auto",
                    block_q: int = 128, block_k: int = 128):
    """impl: 'auto' (pallas on TPU, ref elsewhere) | 'pallas' | 'interpret' | 'ref'."""
    # T == 1 would give a degenerate block_q=1 (single-MXU-row) schedule;
    # decode-shaped calls belong to kernels/decode_attention instead.
    assert q.shape[2] > 1, (
        "flash_attention is the prefill/verify kernel; single-token decode "
        f"(T={q.shape[2]}) must route to kernels/decode_attention")
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return flash_attention_ref(q, k, v, q_pos, k_pos, causal=causal,
                                   window=window)
    return flash_attention_pallas(q, k, v, q_pos, k_pos, causal=causal,
                                  window=window, block_q=block_q,
                                  block_k=block_k,
                                  interpret=(impl == "interpret"))
