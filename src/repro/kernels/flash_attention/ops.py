"""Jit'd public wrapper for flash attention."""
from __future__ import annotations

import functools

import jax

from .kernel import flash_attention_pallas
from .ref import flash_attention_ref


@functools.partial(jax.jit, static_argnames=("causal", "window", "impl",
                                             "block_q", "block_k"))
def flash_attention(q, k, v, q_pos, k_pos, *, causal: bool = True,
                    window: int = 0, impl: str = "auto",
                    block_q: int = 128, block_k: int = 128):
    """impl: 'auto' (pallas on TPU, ref elsewhere) | 'pallas' | 'interpret' | 'ref'."""
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return flash_attention_ref(q, k, v, q_pos, k_pos, causal=causal,
                                   window=window)
    return flash_attention_pallas(q, k, v, q_pos, k_pos, causal=causal,
                                  window=window, block_q=block_q,
                                  block_k=block_k,
                                  interpret=(impl == "interpret"))
