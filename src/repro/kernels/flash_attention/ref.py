"""Pure-jnp oracle for flash attention: masked softmax attention with GQA,
causal + sliding-window + padding masks (same semantics as
repro.models.attention.dot_product_attention)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, q_pos, k_pos, *, causal: bool = True,
                        window: int = 0):
    B, Hq, T, D = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, T, D).astype(jnp.float32)
    scale = 1.0 / (D ** 0.5)
    s = jnp.einsum("bhgtd,bhsd->bhgts", qg, k.astype(jnp.float32)) * scale
    mask = k_pos[:, None, None, None, :] >= 0
    if causal:
        mask &= k_pos[:, None, None, None, :] <= q_pos[:, None, None, :, None]
    if window > 0:
        mask &= (q_pos[:, None, None, :, None]
                 - k_pos[:, None, None, None, :]) < window
    s = jnp.where(mask, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    w = jnp.where(jnp.any(mask, axis=-1, keepdims=True), w, 0.0)
    out = jnp.einsum("bhgts,bhsd->bhgtd", w, v.astype(jnp.float32))
    return out.reshape(B, Hq, T, D)
