"""Pallas-TPU kernel for the RWKV6 (Finch) time-mix recurrence.

Per (batch, head) with data-dependent per-channel decay ``w_t``::

    y_t = r_t @ S_{t-1} + (r_t * u * k_t).sum() * v_t
    S_t = w_t[:, None] * S_{t-1} + k_t[:, None] * v_t[None, :]

TPU adaptation of the CUDA wkv kernels: grid walks (batch*heads) x time
tiles sequentially; the (head_dim, head_dim) state is carried in a VMEM
scratch accumulator across time tiles, so HBM traffic is one read of
r/k/v/w and one write of y — the state never leaves VMEM until the final
tile writes it out for decode-cache handoff.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sout_ref,
                s_scr, *, block_t: int):
    t_i = pl.program_id(1)

    @pl.when(t_i == 0)
    def _init():
        s_scr[...] = s0_ref[0].astype(jnp.float32)

    u = u_ref[0].astype(jnp.float32)                    # (hd,)

    def body(i, _):
        r = r_ref[0, i, :].astype(jnp.float32)          # (hd,)
        k = k_ref[0, i, :].astype(jnp.float32)
        v = v_ref[0, i, :].astype(jnp.float32)
        w = w_ref[0, i, :].astype(jnp.float32)
        s = s_scr[...]                                  # (hd, hd)
        bonus = jnp.sum(r * u * k)
        y = r @ s + bonus * v                           # (hd,)
        y_ref[0, i, :] = y.astype(y_ref.dtype)
        s_scr[...] = w[:, None] * s + k[:, None] * v[None, :]
        return 0

    jax.lax.fori_loop(0, block_t, body, 0)

    @pl.when(t_i == pl.num_programs(1) - 1)
    def _finish():
        sout_ref[0] = s_scr[...].astype(sout_ref.dtype)


def wkv_pallas(r, k, v, w, u, s0, *, block_t: int = 256,
               interpret: bool = False):
    """r/k/v/w: (BH, T, hd) float32; u: (H, hd); s0: (BH, hd, hd) f32.

    Returns (y (BH, T, hd) f32, s_final (BH, hd, hd) f32).
    BH = batch * heads; row bh maps to head bh % H for the bonus vector.
    """
    BH, T, hd = r.shape
    H = u.shape[0]
    block_t = min(block_t, T)
    pad_t = (-T) % block_t
    if pad_t:
        # pads: w=1 (no decay), k=0 (no update) -> state unchanged
        r = jnp.pad(r, ((0, 0), (0, pad_t), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad_t), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_t), (0, 0)))
        w = jnp.pad(w, ((0, 0), (0, pad_t), (0, 0)), constant_values=1.0)
    Tp = r.shape[1]

    grid = (BH, Tp // block_t)
    y, s_final = pl.pallas_call(
        functools.partial(_wkv_kernel, block_t=block_t),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_t, hd), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, block_t, hd), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, block_t, hd), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, block_t, hd), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, hd), lambda b, t, H=H: (b % H, 0)),
            pl.BlockSpec((1, hd, hd), lambda b, t: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_t, hd), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, hd, hd), lambda b, t: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, Tp, hd), jnp.float32),
            jax.ShapeDtypeStruct((BH, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, s0)
    return y[:, :T, :], s_final
