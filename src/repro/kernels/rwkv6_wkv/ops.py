"""Jit'd public wrapper for the RWKV6 wkv recurrence.

Accepts model-layout tensors (B, T, H, hd) and returns the same layout, so
`repro.models.rwkv` can call it directly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import wkv_pallas
from .ref import wkv_ref


@functools.partial(jax.jit, static_argnames=("impl", "block_t"))
def wkv(r, k, v, w, u, s0, *, impl: str = "auto", block_t: int = 256):
    """r/k/v/w: (B, T, H, hd); u: (H, hd); s0: (B, H, hd, hd).

    Returns (y (B, T, H, hd) f32, s_final (B, H, hd, hd) f32).
    impl: 'auto' (pallas on TPU, ref elsewhere) | 'pallas' | 'interpret' | 'ref'.
    """
    B, T, H, hd = r.shape
    to_bh = lambda x: x.transpose(0, 2, 1, 3).reshape(B * H, T, hd)
    rb, kb, vb, wb = (to_bh(t.astype(jnp.float32)) for t in (r, k, v, w))
    s0b = s0.reshape(B * H, hd, hd).astype(jnp.float32)

    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        yb, sb = wkv_ref(rb, kb, vb, wb, u, s0b)
    else:
        yb, sb = wkv_pallas(rb, kb, vb, wb, u, s0b, block_t=block_t,
                            interpret=(impl == "interpret"))
    y = yb.reshape(B, H, T, hd).transpose(0, 2, 1, 3)
    return y, sb.reshape(B, H, hd, hd)
