"""Pure-jnp oracle for the RWKV6 wkv recurrence (lax.scan over time)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv_ref(r, k, v, w, u, s0):
    """r/k/v/w: (BH, T, hd); u: (H, hd); s0: (BH, hd, hd).

    Returns (y (BH, T, hd), s_final).  Row bh uses bonus u[bh % H].
    """
    BH, T, hd = r.shape
    H = u.shape[0]
    u_rows = jnp.tile(u, (BH // H, 1)) if BH % H == 0 else u[jnp.arange(BH) % H]
    u_rows = u[jnp.arange(BH) % H]                      # (BH, hd)

    def step(s, inp):
        r_t, k_t, v_t, w_t = inp                        # (BH, hd)
        bonus = jnp.sum(r_t * u_rows * k_t, axis=-1, keepdims=True)  # (BH,1)
        y = jnp.einsum("bk,bkv->bv", r_t, s) + bonus * v_t
        s = w_t[..., :, None] * s + k_t[..., :, None] * v_t[..., None, :]
        return s, y

    xs = tuple(jnp.moveaxis(t.astype(jnp.float32), 1, 0) for t in (r, k, v, w))
    s_final, ys = jax.lax.scan(step, s0.astype(jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 1), s_final
