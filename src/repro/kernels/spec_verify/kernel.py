"""Pallas-TPU kernel for SPEC-RL speculative verification (Algorithm 1).

Fuses the per-token acceptance test
``u_i <= min(1, lenience * p_curr_i / p_prev_i)`` with the
first-rejection-index reduction into a single pass over the two log-prob
streams: one HBM read per operand, a running min-index accumulator that
lives in the output block (revisited across sequence tiles), no
materialised intermediates.

Grid: (batch_tiles, seq_tiles); seq tiles iterate innermost so the output
block (BB, 1) accumulates a running minimum.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INT_MAX = jnp.iinfo(jnp.int32).max


def _verify_kernel(logl_ref, lp_curr_ref, lp_prev_ref, u_ref, valid_ref,
                   out_ref, *, block_t: int):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, INT_MAX)

    diff = (lp_curr_ref[...] - lp_prev_ref[...]).astype(jnp.float32)
    log_alpha = jnp.minimum(diff + logl_ref[0, 0], 0.0)
    alpha = jnp.exp(log_alpha)                         # (BB, BT), <= 1
    reject = u_ref[...] > alpha

    gidx = t * block_t + jax.lax.broadcasted_iota(jnp.int32, reject.shape, 1)
    in_draft = gidx < valid_ref[...]                   # valid (BB, 1) broadcast
    idx = jnp.where(reject & in_draft, gidx, INT_MAX)
    block_min = jnp.min(idx, axis=1, keepdims=True)    # (BB, 1)
    out_ref[...] = jnp.minimum(out_ref[...], block_min)


def spec_verify_pallas(lp_curr, lp_prev, u, valid_len, log_lenience, *,
                       block_b: int = 8, block_t: int = 512,
                       interpret: bool = False):
    """Returns (B,) int32: first rejected index, or INT_MAX when none.

    lp_curr / lp_prev / u: (B, T) float; valid_len: (B,) int32;
    log_lenience: scalar (traced ok).
    """
    B, T = lp_curr.shape
    block_b = min(block_b, B)
    block_t = min(block_t, T)
    pad_b = (-B) % block_b
    pad_t = (-T) % block_t
    if pad_b or pad_t:
        pad2 = lambda x: jnp.pad(x, ((0, pad_b), (0, pad_t)))
        lp_curr, lp_prev = pad2(lp_curr), pad2(lp_prev)
        u = jnp.pad(u, ((0, pad_b), (0, pad_t)), constant_values=0.0)
        valid_len = jnp.pad(valid_len, (0, pad_b))
    Bp, Tp = lp_curr.shape

    logl = jnp.full((1, 1), log_lenience, jnp.float32)
    valid2 = valid_len.astype(jnp.int32)[:, None]

    grid = (Bp // block_b, Tp // block_t)
    out = pl.pallas_call(
        functools.partial(_verify_kernel, block_t=block_t),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, t: (0, 0)),
            pl.BlockSpec((block_b, block_t), lambda b, t: (b, t)),
            pl.BlockSpec((block_b, block_t), lambda b, t: (b, t)),
            pl.BlockSpec((block_b, block_t), lambda b, t: (b, t)),
            pl.BlockSpec((block_b, 1), lambda b, t: (b, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, 1), lambda b, t: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((Bp, 1), jnp.int32),
        interpret=interpret,
    )(logl, lp_curr, lp_prev, u, valid2)
    return out[:B, 0]
