"""Jit'd public wrapper for the spec_verify kernel.

``spec_verify`` returns, per batch row, the first rejected draft position
``n`` ∈ [0, valid_len] (== valid_len ⇒ full acceptance).  On CPU it runs the
kernel in interpret mode unless ``use_ref`` short-circuits to the oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import INT_MAX, spec_verify_pallas
from .ref import spec_verify_ref


@functools.partial(jax.jit, static_argnames=("impl", "block_b", "block_t"))
def spec_verify(lp_curr, lp_prev, u, valid_len, log_lenience, *,
                impl: str = "auto", block_b: int = 8, block_t: int = 512):
    """impl: 'auto' (pallas on TPU, ref elsewhere) | 'pallas' | 'interpret' | 'ref'."""
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return spec_verify_ref(lp_curr, lp_prev, u, valid_len, log_lenience)
    raw = spec_verify_pallas(lp_curr, lp_prev, u, valid_len, log_lenience,
                             block_b=block_b, block_t=block_t,
                             interpret=(impl == "interpret"))
    return jnp.minimum(raw, valid_len.astype(jnp.int32))
