"""Pure-jnp oracle for speculative verification (Algorithm 1, lines 2-8)."""
from __future__ import annotations

import jax.numpy as jnp


def spec_verify_ref(lp_curr, lp_prev, u, valid_len, log_lenience):
    """First rejection position per row.

    Acceptance: u_i <= min(1, l * p_curr / p_prev), evaluated in log space.
    Positions >= valid_len are not part of the draft.  Returns (B,) int32 in
    [0, valid_len]: == valid_len means every draft token was accepted.
    """
    B, T = lp_curr.shape
    log_alpha = jnp.minimum(lp_curr.astype(jnp.float32)
                            - lp_prev.astype(jnp.float32) + log_lenience, 0.0)
    alpha = jnp.exp(log_alpha)
    gidx = jnp.arange(T, dtype=jnp.int32)[None, :]
    reject = (u > alpha) & (gidx < valid_len[:, None])
    any_rej = reject.any(axis=1)
    first = jnp.argmax(reject, axis=1).astype(jnp.int32)
    return jnp.where(any_rej, first, valid_len.astype(jnp.int32))
