"""HLO analysis for the roofline report.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified
empirically), which under-counts scanned layer stacks by ~num_layers.  This
module parses the post-SPMD HLO text instead:

- builds a per-computation symbol table (op name -> shape),
- propagates execution multipliers through the call graph (while bodies get
  their ``known_trip_count`` from backend_config, falling back to the
  largest integer constant in the paired condition computation),
- counts dot/convolution FLOPs x multiplier  -> per-device HLO FLOPs,
- sums collective operand bytes x multiplier -> per-device collective bytes
  (per type: all-reduce / all-gather / reduce-scatter / all-to-all /
  collective-permute).

Shapes in the partitioned module are PER-DEVICE; callers multiply by chip
count for global numbers.
"""
from __future__ import annotations

import json
import math
import re
from collections import defaultdict
from typing import Any, Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(")
_HDR_RE = re.compile(r"^\s*(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_CALL_RE = re.compile(r"(?:condition|body|to_apply|calls)=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\s*\{"n":\s*"(\d+)"')

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def shape_bytes(type_str: str) -> int:
    """Bytes of a (possibly tuple) HLO type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def shape_elems(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0
    n = 1
    if m.group(2):
        for d in m.group(2).split(","):
            n *= int(d)
    return n


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


class Computation:
    def __init__(self, name: str):
        self.name = name
        self.ops: List[Dict[str, Any]] = []
        self.symbols: Dict[str, str] = {}     # op name -> type string
        self.calls: List[Tuple[str, str, Optional[int]]] = []  # (kind, callee, trip)
        self.max_const = 1


def parse_hlo(txt: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry_name = None
    for line in txt.splitlines():
        hdr = _HDR_RE.match(line)
        if hdr and ("->" in line):
            cur = Computation(hdr.group(2))
            comps[cur.name] = cur
            if hdr.group(1):
                entry_name = cur.name
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            continue
        d = _DEF_RE.match(line)
        if not d:
            continue
        name, type_str, opcode = d.group(1), d.group(2), d.group(3)
        cur.symbols[name] = type_str
        mconst = re.search(r"constant\((\d+)\)", line)
        if mconst and "s32[]" in type_str:
            cur.max_const = max(cur.max_const, int(mconst.group(1)))
        op = {"name": name, "type": type_str, "opcode": opcode, "line": line}
        cur.ops.append(op)
        if opcode == "while":
            trip = None
            mt = _TRIP_RE.search(line)
            if mt:
                trip = int(mt.group(1))
            mb = re.search(r"body=%?([\w.\-]+)", line)
            mc = re.search(r"condition=%?([\w.\-]+)", line)
            if mb:
                cur.calls.append(("while_body", mb.group(1), trip))
            if mc:
                cur.calls.append(("while_cond", mc.group(1), trip))
        elif opcode == "conditional":
            for m in _CALL_RE.finditer(line):
                cur.calls.append(("while_body", m.group(1), 1))  # control edge
        else:
            # fusion / reduce / sort comparators etc: internal computations
            for m in _CALL_RE.finditer(line):
                cur.calls.append(("fused", m.group(1), None))
    if entry_name:
        comps["__entry__"] = comps[entry_name]
    return comps


def _multipliers(comps: Dict[str, Computation]) -> Dict[str, float]:
    entry = comps.get("__entry__")
    mult: Dict[str, float] = defaultdict(float)
    if entry is None:
        return {k: 1.0 for k in comps}
    mult[entry.name] = 1.0
    # propagate breadth-first; graphs are DAGs of computations
    frontier = [entry.name]
    seen_edges = set()
    while frontier:
        cname = frontier.pop()
        c = comps.get(cname)
        if c is None:
            continue
        for kind, callee, trip in c.calls:
            edge = (cname, callee, kind)
            if edge in seen_edges:
                continue
            seen_edges.add(edge)
            w = 1.0
            if kind.startswith("while"):
                if trip is None:
                    cond = next((cl for k2, cl, _ in c.calls
                                 if k2 == "while_cond"), None)
                    trip = comps[cond].max_const if cond in comps else 1
                w = max(1, trip)
            mult[callee] += mult[cname] * w
            frontier.append(callee)
    return dict(mult)


def _control_set(comps: Dict[str, Computation]) -> set:
    """Computations reachable from ENTRY via control edges only (ENTRY,
    while bodies/conds, conditional branches) — the ones whose ops
    materialise buffers (fusion internals do not)."""
    entry = comps.get("__entry__")
    if entry is None:
        return set(comps)
    ctl = {entry.name}
    frontier = [entry.name]
    while frontier:
        c = comps.get(frontier.pop())
        if c is None:
            continue
        for kind, callee, _ in c.calls:
            if kind.startswith("while") and callee not in ctl:
                ctl.add(callee)
                frontier.append(callee)
    return ctl


def _dot_flops(op: Dict[str, Any], symbols: Dict[str, str]) -> float:
    """2 * prod(output dims) * prod(contracted lhs dims)."""
    out_elems = shape_elems(op["type"])
    line = op["line"]
    mo = re.search(r"dot\(([^)]*)\)", line)
    if not mo:
        return 0.0
    operands = [o.strip().lstrip("%") for o in mo.group(1).split(",")]
    mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    contracted = 1
    if mc and operands:
        lhs_type = symbols.get(operands[0], "")
        dims = _shape_dims(lhs_type)
        for idx in (int(i) for i in mc.group(1).split(",") if i):
            if idx < len(dims):
                contracted *= dims[idx]
    return 2.0 * out_elems * contracted


def _operand_group(rhs: str, opcode: str) -> Optional[str]:
    """The balanced paren group right after the opcode — NOT the first paren
    group on the rhs, which for tuple-result ops is the result type and for
    TPU tiled layouts is the tiling annotation ``T(8,128)``."""
    i = rhs.find(opcode + "(")
    if i < 0:
        return None
    start = i + len(opcode) + 1
    depth = 1
    for j in range(start, len(rhs)):
        if rhs[j] == "(":
            depth += 1
        elif rhs[j] == ")":
            depth -= 1
            if depth == 0:
                return rhs[start:j]
    return None


def _operand_bytes(op: Dict[str, Any], symbols: Dict[str, str]) -> int:
    # Newer XLA prints typed operands ("f32[64,128]{1,0} %call.42"), older
    # prints bare "%call.42" — take the inline type when present, else the
    # symbol table.
    group = _operand_group(op["line"].split("=", 1)[1], op["opcode"])
    if not group:
        return 0
    total = 0
    for typ, name in re.findall(
            r"(?:([a-z]\w*\[[^\]]*\](?:\{[^}]*\})?)\s+)?%([\w.\-]+)",
            group):
        if typ:
            total += shape_bytes(typ)
        elif name in symbols:
            total += shape_bytes(symbols[name])
    return total


def analyze_hlo_text(txt: str) -> Dict[str, Any]:
    comps = parse_hlo(txt)
    mult = _multipliers(comps)
    control = _control_set(comps)
    flops = 0.0
    hlo_bytes = 0.0
    coll_bytes: Dict[str, float] = defaultdict(float)
    coll_count: Dict[str, int] = defaultdict(int)
    for name, c in comps.items():
        if name == "__entry__":
            continue
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        for op in c.ops:
            oc = op["opcode"]
            if oc == "dot":
                flops += m * _dot_flops(op, c.symbols)
            elif oc in ("convolution",):
                flops += m * 2.0 * shape_elems(op["type"])  # rough
            if name in control and oc not in ("parameter", "constant",
                                              "get-tuple-element", "tuple",
                                              "bitcast"):
                # post-fusion top-level op: one write of its output plus
                # reads of its operands approximates HBM traffic
                hlo_bytes += m * (shape_bytes(op["type"])
                                  + _operand_bytes(op, c.symbols))
            if oc in COLLECTIVES or any(oc.startswith(p) for p in COLLECTIVES):
                base = oc
                for p in COLLECTIVES:
                    if oc.startswith(p):
                        base = p
                        break
                b = _operand_bytes(op, c.symbols)
                coll_bytes[base] += m * b
                coll_count[base] += 1
    return {
        "dot_flops_per_device": flops,
        "hlo_bytes_per_device": hlo_bytes,
        "collective_bytes_per_device": dict(coll_bytes),
        "collective_bytes_total_per_device": float(sum(coll_bytes.values())),
        "collective_op_counts": dict(coll_count),
        "num_computations": len(comps) - 1,
    }


def analyze_compiled(compiled, num_devices: int) -> Dict[str, Any]:
    """Full report: XLA cost/memory analysis + our HLO-parse corrections."""
    out: Dict[str, Any] = {}
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):   # older jax: one dict per device
        ca = ca[0] if ca else {}
    out["xla_flops_per_device"] = float(ca.get("flops", 0.0))
    out["xla_bytes_per_device"] = float(ca.get("bytes accessed", 0.0))
    ma = compiled.memory_analysis()
    out["memory"] = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "code_bytes": int(ma.generated_code_size_in_bytes),
    }
    out["memory"]["resident_bytes"] = (
        out["memory"]["argument_bytes"] + out["memory"]["output_bytes"]
        + out["memory"]["temp_bytes"] - out["memory"]["alias_bytes"])
    txt = compiled.as_text()
    out.update(analyze_hlo_text(txt))
    out["num_devices"] = num_devices
    return out


# ------------------------------------------------------------------- CLI
# §14 speculation economics: offline analysis over the artifacts a
# --trace-dir / --decision-log run leaves behind.


def _load_metrics_jsonl(path: str) -> Dict[str, float]:
    """The flat registry view from an ``events.jsonl`` dump (its final
    ``metrics`` record; later records win if several were appended)."""
    metrics: Dict[str, float] = {}
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            if rec.get("type") == "metrics":
                metrics.update(rec["metrics"])
    return metrics


def main(argv=None):
    import argparse
    p = argparse.ArgumentParser(
        description="offline analysis over §11/§14 run artifacts")
    sub = p.add_subparsers(dest="cmd", required=True)
    pa = sub.add_parser(
        "attrib",
        help="savings attribution: provenance counts x measured decode "
             "cost -> seconds saved per mechanism (run with --ledger and "
             "--trace-dir to produce the input)")
    pa.add_argument("events", help="events.jsonl written by --trace-dir")
    pa.add_argument("--actual-s", type=float, default=None,
                    help="measured wall clock of the run; anchors the "
                         "baseline = actual + saved counterfactual")
    pa.add_argument("--token-s", type=float, default=None,
                    help="override the measured decode s/token")
    pa.add_argument("--prompt-token-s", type=float, default=None,
                    help="prefill s/token for shared-prompt pricing "
                         "(defaults to the decode cost)")
    pa.add_argument("--json", default="",
                    help="also write the report dict as JSON here")
    pd = sub.add_parser(
        "decisions",
        help="decision-dataset summary: shard count, per-column stats of "
             "a --decision-log directory")
    pd.add_argument("dir", help="directory of decisions-*.npz shards")
    args = p.parse_args(argv)

    if args.cmd == "attrib":
        from repro.obs.attrib import build_report, measured_token_cost
        from repro.obs.ledger import CATEGORY_NAMES
        m = _load_metrics_jsonl(args.events)
        counts = {name: int(m.get(f"ledger.tokens_{name}", 0))
                  for name in CATEGORY_NAMES}
        if not any(counts.values()):
            raise SystemExit(f"{args.events}: no ledger.tokens_* metrics "
                             "— produce it with --ledger --trace-dir")
        t_tok = args.token_s or measured_token_cost(m)
        if t_tok is None:
            raise SystemExit("no decode-cost metrics in the dump; "
                             "pass --token-s explicitly")
        rep = build_report(counts, t_tok,
                           t_prompt_token_s=args.prompt_token_s,
                           actual_s=args.actual_s)
        print(rep.summary())
        if args.json:
            with open(args.json, "w") as f:
                json.dump(rep.as_dict(), f, indent=2, sort_keys=True)
            print(f"report: {args.json}")
        return 0

    # decisions
    from repro.obs.ledger import load_dataset
    ds = load_dataset(args.dir)
    feats, outs = ds["features"], ds["outcomes"]
    print(f"{feats.shape[0]} decision records "
          f"({len(set(ds['row'].tolist()))} rows, "
          f"schema v{int(ds['schema_version'])})")
    for label, names, arr in (("features", ds["feature_names"], feats),
                              ("outcomes", ds["outcome_names"], outs)):
        print(label + ":")
        for j, name in enumerate(names):
            col = arr[:, j]
            print(f"  {str(name):14s} mean={col.mean():10.4f} "
                  f"min={col.min():10.4f} max={col.max():10.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
