import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run (deliverable e).

For every (architecture x input shape x mesh): build the production mesh,
attach shardings to ShapeDtypeStruct stand-ins (no allocation), lower the
step function, ``.compile()`` it, and record memory / cost / collective
analysis to ``experiments/dryrun/<arch>__<shape>__<mesh>.json``.

The two mandatory lines above run BEFORE any jax import so 512 placeholder
host devices exist when jax initialises.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all            # every combo
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh pod  # single-pod only
"""
import argparse
import json
import sys
import time
import traceback

import jax
import numpy as np

from repro.configs import ARCH_IDS, ASSIGNED, get_config
from repro.launch import analysis
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import INPUT_SHAPES, input_specs, shape_applicable
from repro.launch.steps import make_serve_step, make_train_step, make_verify_step
from repro.optim import adamw

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def prepare(cfg, shape_name, mesh, *, zero_opt=False, remat=None,
            ce_impl="naive", microbatch=1, dp_only=False, attn_impl=None,
            accum_dtype="float32", kv_shard_hd=False, moe_impl=None,
            moe_groups=None, scan_chunk=None):
    info = INPUT_SHAPES[shape_name]
    if remat is None:
        remat = "full" if info["kind"] == "train" else "none"
    cfg = cfg.replace(remat=remat)
    if attn_impl:
        cfg = cfg.replace(attn_impl=attn_impl)
    if moe_impl:
        cfg = cfg.replace(moe_impl=moe_impl)
    if moe_groups is not None:
        cfg = cfg.replace(moe_groups=moe_groups)
    if scan_chunk is not None:
        cfg = cfg.replace(scan_chunk=scan_chunk)
    spec = input_specs(cfg, shape_name, mesh, zero_opt=zero_opt,
                       dp_only=dp_only, kv_shard_hd=kv_shard_hd)
    if spec["step"] == "train":
        grad_specs = None
        if zero_opt and microbatch > 1:
            grad_specs = jax.tree.map(lambda s: s.sharding,
                                      spec["opt"]["mu"])
        fn = make_train_step(cfg, adamw.AdamWConfig(), ce_impl=ce_impl,
                             microbatch=microbatch, accum_dtype=accum_dtype,
                             grad_specs=grad_specs)
        args = (spec["params"], spec["opt"]) + spec["args"]
    elif spec["step"] == "verify":
        fn = make_verify_step(cfg, score_impl=ce_impl)
        args = (spec["params"],) + spec["args"]
    else:
        fn = make_serve_step(cfg)
        args = (spec["params"],) + spec["args"]
    return fn, args, spec


def run_one(arch: str, shape_name: str, mesh_kind: str, *, zero_opt=False,
            remat=None, save=True, tag="baseline", ce_impl="naive",
            microbatch=1, dp_only=False, attn_impl=None, donate=False,
            accum_dtype="float32", kv_shard_hd=False, moe_impl=None,
            moe_groups=None, scan_chunk=None):
    cfg = get_config(arch)
    ok, reason = shape_applicable(cfg, shape_name)
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
              "tag": tag, "zero_opt": zero_opt, "ce_impl": ce_impl}
    if not ok:
        result.update(status="skipped", reason=reason)
        _save(result, save)
        return result

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    t0 = time.perf_counter()
    try:
        fn, args, spec = prepare(cfg, shape_name, mesh, zero_opt=zero_opt,
                                 remat=remat, ce_impl=ce_impl,
                                 microbatch=microbatch, dp_only=dp_only,
                                 attn_impl=attn_impl, accum_dtype=accum_dtype,
                                 kv_shard_hd=kv_shard_hd, moe_impl=moe_impl,
                                 moe_groups=moe_groups, scan_chunk=scan_chunk)
        donate_args = (0, 1) if (donate and spec["step"] == "train") else ()
        with mesh:
            lowered = jax.jit(fn, donate_argnums=donate_args).lower(
                *args, **spec["extras"])
            t_lower = time.perf_counter() - t0
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0 - t_lower
            rep = analysis.analyze_compiled(compiled, mesh.size)
        result.update(**rep)
        result.update(status="ok", lower_s=round(t_lower, 1),
                      compile_s=round(t_compile, 1),
                      tokens_per_step=spec["tokens_per_step"],
                      num_devices=mesh.size)
        # convenience: per-device HBM GiB
        result["hbm_gib_per_device"] = round(
            rep["memory"]["resident_bytes"] / 2**30, 3)
    except Exception as e:  # record failures — they are bugs to fix
        result.update(status="error", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-4000:])
    _save(result, save)
    return result


def _save(result, save):
    if not save:
        return
    os.makedirs(OUT_DIR, exist_ok=True)
    name = f"{result['arch']}__{result['shape']}__{result['mesh']}"
    if result.get("tag", "baseline") != "baseline":
        name += f"__{result['tag']}"
    with open(os.path.join(OUT_DIR, name + ".json"), "w") as f:
        json.dump(result, f, indent=1, default=float)


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", choices=sorted(ARCH_IDS), default=None)
    p.add_argument("--shape", choices=sorted(INPUT_SHAPES), default=None)
    p.add_argument("--mesh", choices=["pod", "multipod", "both"], default="pod")
    p.add_argument("--all", action="store_true")
    p.add_argument("--zero-opt", action="store_true",
                   help="ZeRO-shard optimizer moments over the data axes")
    p.add_argument("--remat", choices=["none", "full", "dots"], default=None)
    p.add_argument("--tag", default="baseline")
    p.add_argument("--ce", choices=["naive", "chunked"], default="naive")
    p.add_argument("--microbatch", type=int, default=1)
    p.add_argument("--dp-only", action="store_true",
                   help="pure data parallelism (batch over all axes)")
    p.add_argument("--attn", choices=["naive", "blocked"], default=None)
    p.add_argument("--donate", action="store_true",
                   help="donate params/opt buffers (in-place update)")
    p.add_argument("--accum-dtype", default="float32",
                   choices=["float32", "bfloat16"])
    p.add_argument("--kv-shard-hd", action="store_true",
                   help="shard the KV-cache head_dim over `model` when kv "
                        "heads alone do not divide the axis (decode)")
    p.add_argument("--moe", choices=["dense", "dispatch", "sort"],
                   default=None)
    p.add_argument("--moe-groups", type=int, default=None)
    p.add_argument("--scan-chunk", type=int, default=None)
    args = p.parse_args(argv)

    # explicit --arch/--shape always narrow the sweep, even with --all
    archs = [args.arch] if args.arch else ASSIGNED
    shapes = [args.shape] if args.shape else sorted(INPUT_SHAPES)
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mk in meshes:
                r = run_one(arch, shape, mk, zero_opt=args.zero_opt,
                            remat=args.remat, tag=args.tag, ce_impl=args.ce,
                            microbatch=args.microbatch, dp_only=args.dp_only,
                            attn_impl=args.attn, donate=args.donate,
                            accum_dtype=args.accum_dtype,
                            kv_shard_hd=args.kv_shard_hd, moe_impl=args.moe,
                            moe_groups=args.moe_groups,
                            scan_chunk=args.scan_chunk)
                status = r["status"]
                extra = ""
                if status == "ok":
                    extra = (f"hbm/dev={r['hbm_gib_per_device']}GiB "
                             f"flops/dev={r['dot_flops_per_device']:.3e} "
                             f"coll/dev={r['collective_bytes_total_per_device']:.3e}B "
                             f"compile={r['compile_s']}s")
                elif status == "error":
                    failures += 1
                    extra = r["error"][:200]
                else:
                    extra = r["reason"][:80]
                print(f"[{status:7s}] {arch:18s} {shape:12s} {mk:8s} {extra}",
                      flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
