"""Production meshes (assignment): single pod (16, 16) = 256 chips with axes
(data, model); multi-pod (2, 16, 16) = 512 chips with axes (pod, data,
model).  A FUNCTION, not a module constant — importing this module never
touches jax device state.

The *runtime* mesh — the one the trainer / rollout / serving stack actually
executes on — is configured with ``repro.distributed.mesh.MeshConfig``
(re-exported here), which falls back to single-device when the host cannot
fit the axes (DESIGN.md §8).
"""
from __future__ import annotations

import jax

from repro.distributed.mesh import MeshConfig  # noqa: F401  (re-export)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(model: int = 2, data: int = 2):
    """Tiny mesh for unit tests (requires >= model*data host devices)."""
    return jax.make_mesh((data, model), ("data", "model"))


# TPU v5e hardware constants used by the roofline analysis (benchmarks/roofline.py)
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link
