"""Serving launcher: batched generation with any --arch (reduced variant on
CPU), one prefill + decode loop per request batch.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b --smoke
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.data.dataset import PromptDataset
from repro.data.tokenizer import VOCAB_SIZE, decode
from repro.engine.generate import GenerateConfig, generate
from repro.models import model as M
from repro.rewards.mathgen import MathTaskConfig, generate_problems


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", choices=sorted(ARCH_IDS), default="qwen3-0.6b")
    p.add_argument("--smoke", action="store_true", default=True)
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--max-new-tokens", type=int, default=12)
    args = p.parse_args(argv)

    cfg = get_config(args.arch).reduced(vocab_size=max(VOCAB_SIZE, 64))
    if cfg.vocab_size < VOCAB_SIZE:
        cfg = cfg.replace(vocab_size=VOCAB_SIZE)
    params = M.init_lm(jax.random.PRNGKey(0), cfg)

    problems = generate_problems(MathTaskConfig(num_problems=args.batch))
    ds = PromptDataset(problems, max_prompt_len=10)
    batch = ds.sample_batch(__import__("random").Random(0), args.batch, 1)
    gen = GenerateConfig(max_new_tokens=args.max_new_tokens)

    kw = {}
    if cfg.encoder_layers:
        frames = jax.random.normal(jax.random.PRNGKey(1),
                                   (args.batch, cfg.encoder_frames,
                                    cfg.d_model))
        enc, pos = M.encode(params, cfg, frames)
        kw = {"encoder_out": enc, "encoder_positions": pos}
    if cfg.num_prefix_embeddings:
        kw["prefix_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2),
            (args.batch, cfg.num_prefix_embeddings, cfg.d_model))

    t0 = time.time()
    out = generate(params, cfg, gen, jnp.asarray(batch.tokens),
                   jnp.asarray(batch.mask), jax.random.PRNGKey(3), **kw)
    jax.block_until_ready(out["tokens"])
    dt = time.time() - t0
    print(f"arch={cfg.name}: served {args.batch} requests, "
          f"{int(out['n_generated'])} tokens in {dt:.2f}s")
    for i in range(min(args.batch, 4)):
        txt = decode(np.asarray(out["tokens"][i, :out["length"][i]]))
        print(f"  req{i}: {txt!r}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
