"""Serving launcher: continuous-batching slot server with a request arrival
stream, speculative-prefix admission, and latency/throughput stats
(DESIGN.md §6).  Falls back to one-shot fixed-batch generation for trunks
the slot engine does not cover (recurrent state, encoder/vision extras).

    PYTHONPATH=src python -m repro.launch.serve --smoke
    PYTHONPATH=src python -m repro.launch.serve --no-smoke --arch qwen3-1.7b \
        --requests 64 --slots 8 --spec-prefix
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m repro.launch.serve --smoke \
        --mesh-data 2 --mesh-model 2      # one scheduler per data shard (§8)
"""
from __future__ import annotations

import argparse
import random
import signal
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.core.cache import RolloutCache
from repro.data.dataset import PromptDataset
from repro.data.tokenizer import VOCAB_SIZE, decode
from repro.distributed.mesh import MeshConfig, data_size, shard_params
from repro.engine.generate import GenerateConfig, generate
from repro.models import model as M
from repro.rewards.mathgen import MathTaskConfig, generate_problems
from repro.serving import Request, make_slot_engine

# long-tailed per-request budgets (fractions of --max-new-tokens): most
# requests are short, a few run to the full budget — the regime where
# fixed-batch decode idles on its stragglers
TAIL_FRACTIONS = (0.25, 0.25, 0.5, 1.0)
TAIL_WEIGHTS = (0.5, 0.25, 0.15, 0.1)


def build_requests(ds: PromptDataset, rng: random.Random, n_requests: int,
                   max_new_tokens: int, key) -> list:
    batch = ds.sample_batch(rng, n_requests, 1)
    keys = np.asarray(jax.vmap(
        lambda i: jax.random.fold_in(key, i))(jnp.arange(n_requests)))
    reqs = []
    for i in range(n_requests):
        p_len = int(batch.mask[i].sum())
        budget = max(1, int(max_new_tokens *
                            rng.choices(TAIL_FRACTIONS, TAIL_WEIGHTS)[0]))
        reqs.append(Request(
            request_id=i, prompt=batch.tokens[i, -p_len:].astype(np.int32),
            key=keys[i], max_new_tokens=budget))
    return reqs


def _model_extras(params, cfg, batch: int, seed: int = 1):
    """Stub modality conditioning for encoder / vision trunks (the same
    placeholder inputs the engine tests use)."""
    kw = {}
    if cfg.encoder_layers:
        frames = jax.random.normal(jax.random.PRNGKey(seed),
                                   (batch, cfg.encoder_frames, cfg.d_model))
        enc, pos = M.encode(params, cfg, frames)
        kw = {"encoder_out": enc, "encoder_positions": pos}
    if cfg.num_prefix_embeddings:
        kw["prefix_embeds"] = jax.random.normal(
            jax.random.PRNGKey(seed + 1),
            (batch, cfg.num_prefix_embeddings, cfg.d_model))
    return kw


def serve_fixed(params, cfg, gen, reqs, prompt_width, slots):
    """Fixed-batch baseline: decode ``slots``-sized batches to the slowest
    row (legacy serve.py behaviour).  Returns (tokens dict, n_generated)."""
    outs, total = {}, 0
    for lo in range(0, len(reqs), slots):
        chunk = reqs[lo:lo + slots]
        B = len(chunk)
        toks = np.zeros((B, prompt_width), np.int32)
        mask = np.zeros((B, prompt_width), bool)
        for j, r in enumerate(chunk):
            toks[j, prompt_width - len(r.prompt):] = r.prompt
            mask[j, prompt_width - len(r.prompt):] = True
        keys = jnp.asarray(np.stack([r.key for r in chunk]))
        budget = jnp.asarray([r.max_new_tokens for r in chunk], jnp.int32)
        out = generate(params, cfg, gen, jnp.asarray(toks), jnp.asarray(mask),
                       keys, row_budget=budget,
                       **_model_extras(params, cfg, B))
        jax.block_until_ready(out["tokens"])
        for j, r in enumerate(chunk):
            L = int(out["length"][j])
            outs[r.request_id] = np.asarray(out["tokens"][j, :L])
        total += int(out["n_generated"])
    return outs, total


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", choices=sorted(ARCH_IDS), default="qwen3-0.6b")
    p.add_argument("--smoke", action=argparse.BooleanOptionalAction,
                   default=True,
                   help="tiny reduced run (default); --no-smoke serves the "
                        "full request/token budget")
    p.add_argument("--engine", choices=["auto", "slots", "fixed"],
                   default="auto")
    p.add_argument("--slots", type=int, default=4,
                   help="decode-batch slots (also the fixed-batch size)")
    p.add_argument("--requests", type=int, default=None)
    p.add_argument("--max-new-tokens", type=int, default=None)
    p.add_argument("--prompt-len", type=int, default=10)
    p.add_argument("--arrival-every", type=int, default=0,
                   help="stagger arrivals: one request every K engine steps "
                        "(0 = all queued up front)")
    p.add_argument("--spec-prefix", action="store_true",
                   help="serve every request twice: the first pass's output "
                        "becomes the second pass's speculative prefix")
    p.add_argument("--draft", type=int, default=0, metavar="K",
                   help="continuation draft engine (§9): draft up to K "
                        "tokens per decode forward from n-gram matches over "
                        "each request's own stream (and, with --spec-prefix, "
                        "its first-pass trajectory as corpus); 0 = off")
    p.add_argument("--mesh-data", type=int, default=1,
                   help="data shards — one slot scheduler per shard (§8)")
    p.add_argument("--mesh-model", type=int, default=1,
                   help="model-parallel axis size per shard")
    p.add_argument("--require-mesh", action="store_true",
                   help="fail instead of silently serving single-device "
                        "when the host has fewer devices than the mesh")
    p.add_argument("--deadline-steps", type=int, default=0,
                   help="§10 per-request decode-step deadline (0 = none): "
                        "expired requests are reclaimed and retried once")
    p.add_argument("--max-queue", type=int, default=0,
                   help="§10 bounded admission queue (0 = unbounded)")
    p.add_argument("--overflow", choices=["reject", "shed-oldest"],
                   default="reject",
                   help="backpressure policy when the queue is full")
    p.add_argument("--ledger", action="store_true",
                   help="§14 token-provenance ledger: account every emitted "
                        "token to its mechanism (reused prefix / accepted "
                        "draft / bonus / fresh / retry / shared block) and "
                        "print the savings-attribution report after the run")
    p.add_argument("--decision-log", default="", metavar="DIR",
                   help="§14 decision-record logging: one (features, "
                        "outcomes) record per draft decision, sharded as "
                        "JSONL + NPZ under DIR (obs.ledger.load_dataset "
                        "reloads them as a training-ready bundle)")
    p.add_argument("--assert-compile-stable", action="store_true",
                   help="§14 recompile sentinel: replay the identical "
                        "request set on a fresh engine after the run and "
                        "fail if any registered jit entry compiles again "
                        "(steady-state compile stability)")
    p.add_argument("--trace-dir", default="",
                   help="§11 observatory: write trace.json (Chrome trace, "
                        "load at ui.perfetto.dev), events.jsonl and "
                        "metrics.prom here after the run")
    p.add_argument("--trace-sample-rate", type=float, default=1.0,
                   help="fraction of requests given their own trace lane "
                        "(deterministic per-request hash)")
    p.add_argument("--metrics", type=int, default=0, metavar="PORT",
                   help="serve Prometheus text exposition on "
                        "http://localhost:PORT/metrics during the run "
                        "(0 = off)")
    p.add_argument("--state-path", default="",
                   help="on SIGTERM/Ctrl-C, snapshot the exact server state "
                        "here (checkpoint/io.save_server_state) for "
                        "kill-and-resume; empty = drain without snapshot")
    p.add_argument("--cache-layout", choices=["dense", "paged"],
                   default="dense",
                   help="§13 KV cache layout: 'paged' serves over a block "
                        "pool with CoW GRPO prompt sharing (token-identical "
                        "to dense; resident batch at fixed HBM grows by the "
                        "per-row block-rounding margin)")
    p.add_argument("--kv-block-size", type=int, default=0,
                   help="paged KV block size in tokens (0 = config default)")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    n_requests = args.requests or (8 if args.smoke else 64)
    max_new = args.max_new_tokens or (12 if args.smoke else 64)

    cfg = get_config(args.arch).reduced(vocab_size=max(VOCAB_SIZE, 64))
    if cfg.vocab_size < VOCAB_SIZE:
        cfg = cfg.replace(vocab_size=VOCAB_SIZE)
    if args.cache_layout != cfg.cache_layout:
        cfg = cfg.replace(cache_layout=args.cache_layout)
    if args.kv_block_size > 0:
        cfg = cfg.replace(kv_block_size=args.kv_block_size)
    params = M.init_lm(jax.random.PRNGKey(args.seed), cfg)
    gen = GenerateConfig(max_new_tokens=max_new)
    mesh = MeshConfig(data=args.mesh_data, model=args.mesh_model,
                      require=args.require_mesh).build()
    if mesh is not None and data_size(mesh) <= 1:
        # model-only mesh: shard params here; the slot engine head-shards
        # its caches from the same mesh
        params = shard_params(mesh, cfg, params)

    draft = None
    if args.draft > 0:
        from repro.drafting import DraftConfig
        draft = DraftConfig(kind="ngram", draft_k=args.draft)

    # §11: one explicit tracer for the MAIN serving engine only (the
    # spec-prefix warm pass below builds its cache untraced, keeping the
    # trace about the speculative serve itself)
    tracer = None
    if args.trace_dir:
        from repro.obs import Tracer
        tracer = Tracer(enabled=True, sample_rate=args.trace_sample_rate)

    # §14: the ledger is handed ONLY to the main (traced) engine — the
    # spec-prefix warm pass and the compile-stability replay run without it
    # so the attribution report is about the speculative serve itself
    ledger = None
    if args.ledger:
        from repro.obs.ledger import TokenLedger
        ledger = TokenLedger(enabled=True)

    def make_engine(spec_prefix: bool, traced: bool = False):
        return make_slot_engine(params, cfg, gen, mesh=mesh,
                                num_slots=args.slots,
                                prompt_width=args.prompt_len,
                                spec_prefix=spec_prefix, log_lenience=0.0,
                                draft=draft,
                                deadline_steps=args.deadline_steps or None,
                                max_queue=args.max_queue or None,
                                overflow=args.overflow,
                                tracer=tracer if traced else None,
                                ledger=ledger if traced else None)

    rng = random.Random(args.seed)
    problems = generate_problems(MathTaskConfig(num_problems=n_requests))
    ds = PromptDataset(problems, max_prompt_len=args.prompt_len)
    reqs = build_requests(ds, rng, n_requests, max_new,
                          jax.random.PRNGKey(args.seed + 3))

    engine_kind = args.engine
    if engine_kind == "auto":
        engine_kind = "slots" if M.supports_slot_serving(cfg) else "fixed"
    if engine_kind == "slots" and not M.supports_slot_serving(cfg):
        raise SystemExit(f"--engine slots unsupported for arch {cfg.name} "
                         "(recurrent trunk or modality extras)")
    if engine_kind == "fixed" and (args.spec_prefix or args.arrival_every
                                   or args.draft):
        raise SystemExit(
            f"--spec-prefix/--arrival-every/--draft need the slot engine, "
            f"but engine resolved to 'fixed' for arch {cfg.name}; drop the "
            "flags or pick a slot-capable --arch")

    t0 = time.time()
    if engine_kind == "fixed":
        outs, n_gen = serve_fixed(params, cfg, gen, reqs, args.prompt_len,
                                  args.slots)
        dt = time.time() - t0
        print(f"arch={cfg.name} engine=fixed: served {n_requests} requests, "
              f"{n_gen} tokens in {dt:.2f}s ({n_gen / max(dt, 1e-9):.0f} tok/s)")
        for i in range(min(n_requests, 4)):
            print(f"  req{i}: {decode(outs[i])!r}")
        return 0

    drafts = None

    def _attach_spec(reqs_):
        vkeys = np.asarray(jax.vmap(
            lambda i: jax.random.fold_in(jax.random.PRNGKey(args.seed + 11), i)
        )(jnp.arange(n_requests)))
        for i, r in enumerate(reqs_):
            e = drafts.get(r.request_id)
            r.verify_key = vkeys[i]
            r.draft_tokens, r.draft_logprobs = e.tokens, e.logprobs
            r.draft_eos = e.ends_with_eos
            if draft is not None:
                # first-pass trajectory doubles as the §9 n-gram corpus
                r.ngram_corpus = [e.tokens]

    if args.spec_prefix:
        # pass 1 (vanilla) builds the draft cache; pass 2 below serves with
        # speculative-prefix admission against the same policy
        warm = make_engine(spec_prefix=False)
        for r in reqs:
            warm.submit(Request(request_id=r.request_id, prompt=r.prompt,
                                key=r.key, max_new_tokens=r.max_new_tokens))
        warm_resp = warm.run()
        drafts = RolloutCache()
        for i, r in enumerate(reqs):
            resp = warm_resp[r.request_id]
            drafts.put(r.request_id, resp.tokens, resp.logprobs, resp.length,
                       step=0, eos_id=gen.eos_id)
        _attach_spec(reqs)
        t0 = time.time()

    if args.decision_log:
        # the global decision log is configured AFTER the warm pass so the
        # dataset holds only the speculative serve's decisions
        from repro.obs import configure
        from repro.obs.ledger import DecisionLog
        configure(decisions=DecisionLog(args.decision_log, enabled=True))

    engine = make_engine(spec_prefix=args.spec_prefix, traced=True)

    metrics_srv = None
    if args.metrics:
        from repro.obs.export import start_metrics_server
        metrics_srv = start_metrics_server(engine.metrics_registry,
                                           args.metrics)
        print(f"metrics: http://localhost:{args.metrics}/metrics")

    # §10 graceful shutdown: SIGTERM folds into KeyboardInterrupt, and an
    # interrupted serve stops at a chunk boundary (run() only yields control
    # between chunks, where host state is consistent), snapshots the exact
    # server state for kill-and-resume, and still prints final stats
    def _sigterm(signum, frame):
        raise KeyboardInterrupt

    signal.signal(signal.SIGTERM, _sigterm)
    interrupted = False
    try:
        if args.arrival_every > 0:
            arrivals = [(i * args.arrival_every, r)
                        for i, r in enumerate(reqs)]
            resps = engine.run(arrivals=arrivals)
        else:
            for r in reqs:
                engine.submit(r)
            resps = engine.run()
    except KeyboardInterrupt:
        interrupted = True
        resps = engine.responses
        if args.state_path:
            from repro.checkpoint.io import save_server_state
            save_server_state(args.state_path, engine,
                              metadata={"arch": cfg.name,
                                        "requests": n_requests})
            print(f"\ninterrupted: server state -> {args.state_path} "
                  "(resume via checkpoint/io.load_server_state)")
        else:
            print("\ninterrupted: draining without snapshot "
                  "(--state-path to keep serving state)")
    dt = time.time() - t0
    if metrics_srv is not None:
        metrics_srv.shutdown()
    if args.decision_log:
        from repro.obs import get_decision_log
        dec = get_decision_log()
        dec.flush()
        print(f"decisions: {dec.records_total} records -> "
              f"{args.decision_log} (obs.ledger.load_dataset to reload)")
    report = None
    if args.ledger:
        # §14: provenance counts x measured decode cost -> seconds saved
        # per mechanism; the actual wall clock anchors the counterfactual
        from repro.obs.attrib import build_report, measured_token_cost
        regd = engine.metrics_registry().as_dict()
        n_all = max(1, int(ledger.category_counts().sum()))
        t_tok = measured_token_cost(regd) or dt / n_all
        report = build_report(ledger, t_tok, actual_s=dt)
        print(report.summary())
    if args.trace_dir:
        import os
        from repro.obs import export as obs_export
        os.makedirs(args.trace_dir, exist_ok=True)
        reg = engine.metrics_registry()
        counters = None
        if report is not None:
            report.to_registry(reg)    # attribution joins /metrics + prom
            counters = report.counter_events(dt)
        obs_export.write_chrome_trace(
            os.path.join(args.trace_dir, "trace.json"), tracer,
            counters=counters)
        obs_export.write_jsonl(
            os.path.join(args.trace_dir, "events.jsonl"), tracer, reg)
        obs_export.write_prometheus(
            os.path.join(args.trace_dir, "metrics.prom"), reg)
        print(f"trace: {args.trace_dir}/trace.json (load at "
              f"ui.perfetto.dev), events.jsonl, metrics.prom")
    s = engine.stats()
    n_gen = int(s["generated_tokens"])
    shards = int(s.get("num_shards", 1))
    served = len(resps)
    print(f"arch={cfg.name} engine=slots(spec={args.spec_prefix}, "
          f"shards={shards}){' [interrupted]' if interrupted else ''}: served "
          f"{served}/{n_requests} requests, {n_gen} generated "
          f"(+{int(s['reused_tokens'])} reused) tokens in {dt:.2f}s "
          f"({(n_gen + int(s['reused_tokens'])) / max(dt, 1e-9):.0f} tok/s)")
    print(f"  occupancy={s['occupancy']:.2f} engine_steps={int(s['engine_steps'])} "
          f"admissions={int(s['admitted'])} "
          f"mean_queue_wait={s['mean_queue_wait'] * 1e3:.1f}ms "
          f"mean_serve={s['mean_serve_time'] * 1e3:.1f}ms")
    recov = {k: int(s[k]) for k in ("timeouts", "retried_requests",
                                    "shed_requests", "fault_quarantines",
                                    "fault_impl_fallbacks") if s.get(k)}
    if recov:
        print(f"  recovery: {recov}")
    if draft is not None:
        print(f"  draft: tok/fwd={s['tokens_per_forward']:.2f} "
              f"accept={s['accept_rate']:.2f} "
              f"mean_len={s['mean_draft_len']:.2f} "
              f"forwards={int(s['decode_forwards'])}")
    for i in range(min(n_requests, 4)):
        r = resps.get(i)
        if r is None:
            print(f"  req{i} [in-flight at interrupt]")
            continue
        full = np.concatenate([
            np.asarray(reqs[i].draft_tokens[:r.n_accepted], np.int32)
            if r.n_accepted else np.zeros(0, np.int32), r.tokens])
        print(f"  req{i} [{r.finish_reason}]: {decode(full)!r}")

    if args.assert_compile_stable and not interrupted:
        # §14 recompile sentinel: an identical request stream on a fresh
        # engine must hit only already-compiled signatures — any jit cache
        # growth here is a compile in steady state (the recompile_steady_
        # state alert's offline twin)
        from repro.obs.alerts import compile_counts
        baseline = dict(compile_counts())
        reqs2 = build_requests(ds, random.Random(args.seed), n_requests,
                               max_new, jax.random.PRNGKey(args.seed + 3))
        if args.spec_prefix:
            _attach_spec(reqs2)
        replay = make_engine(spec_prefix=args.spec_prefix)
        if args.arrival_every > 0:
            replay.run(arrivals=[(i * args.arrival_every, r)
                                 for i, r in enumerate(reqs2)])
        else:
            for r in reqs2:
                replay.submit(r)
            replay.run()
        grew = {k: (baseline.get(k, 0), v)
                for k, v in compile_counts().items()
                if v != baseline.get(k, 0)}
        if grew:
            raise SystemExit("compile instability: jit cache growth on "
                             f"identical replay: {grew}")
        print(f"compile-stability: {sum(baseline.values())} compiles total, "
              "0 new on identical replay")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
