"""Input ShapeDtypeStruct stand-ins + shardings per (arch, input-shape).

No device allocation: params/caches come from ``jax.eval_shape`` over the
real constructors, inputs are ShapeDtypeStructs with NamedShardings
attached.  ``input_specs(cfg, shape_name, mesh)`` returns everything
``dryrun.py`` needs to lower a step function.
"""
from __future__ import annotations

import dataclasses
import functools
import re
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.sharding import (_path_str, batch_axes, batch_spec,
                                        params_pspecs, zero_shard_spec)
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.optim import adamw

# The four assigned input shapes.
INPUT_SHAPES: Dict[str, Dict[str, int]] = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}


def sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype), sharding=sharding)


def _ns(mesh, spec):
    return NamedSharding(mesh, spec)


def shape_applicable(cfg: ModelConfig, shape_name: str) -> Tuple[bool, str]:
    """Whether (arch, shape) runs; reason when skipped (DESIGN.md §7)."""
    info = INPUT_SHAPES[shape_name]
    if shape_name == "long_500k" and not cfg.subquadratic:
        return False, ("full-attention arch: 500k decode needs sub-quadratic "
                       "attention (SSM/hybrid/SWA only)")
    return True, ""


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


# ------------------------------------------------------------------ params


def params_struct(cfg: ModelConfig):
    return jax.eval_shape(
        lambda: M.init_lm(jax.random.PRNGKey(0), cfg))


def opt_state_struct(params_sds):
    f32 = lambda s: sds(s.shape, jnp.float32)
    return {"mu": jax.tree.map(f32, params_sds),
            "nu": jax.tree.map(f32, params_sds),
            "step": sds((), jnp.int32)}


def sharded_params_struct(cfg: ModelConfig, mesh: Mesh, *,
                          zero_opt: bool = False, dp_only: bool = False):
    """(params_sds, opt_sds) with shardings attached."""
    pstruct = params_struct(cfg)
    pspecs = params_pspecs(cfg, pstruct,
                           1 if dp_only else mesh.shape["model"])
    params_sds = jax.tree.map(
        lambda s, sp: sds(s.shape, s.dtype, _ns(mesh, sp)), pstruct, pspecs)

    daxes = batch_axes(mesh)
    dsize = int(np.prod([mesh.shape[a] for a in daxes]))

    def moment(s, sp):
        spec = sp
        if zero_opt:
            spec = zero_shard_spec(sp, s.shape, daxes, dsize)
        return sds(s.shape, jnp.float32, _ns(mesh, spec))

    opt_sds = {"mu": jax.tree.map(moment, pstruct, pspecs),
               "nu": jax.tree.map(moment, pstruct, pspecs),
               "step": sds((), jnp.int32, _ns(mesh, P()))}
    return params_sds, opt_sds, pspecs


# ------------------------------------------------------------------ caches


def cache_pspec(path_str: str, shape, cfg: ModelConfig, mesh: Mesh,
                batch: int, seq_shard: bool, kv_shard_hd: bool = False) -> P:
    """PartitionSpec for a decode-cache leaf (leading axis = scan run)."""
    model = mesh.shape["model"]
    baxes = batch_axes(mesh)
    dsize = int(np.prod([mesh.shape[a] for a in baxes]))
    b_ax = (baxes if len(baxes) > 1 else baxes[0]) if batch % dsize == 0 and \
        batch >= dsize else None
    if seq_shard:
        b_ax = None          # long_500k: the data axis shards the seq dim
    s = path_str

    if re.search(r"/(k|v)$", s):            # (run, B, Hkv, S, hd)
        kv_ok = cfg.num_kv_heads % model == 0 and cfg.num_kv_heads >= model
        seq_ax = "data" if (seq_shard and "data" in mesh.axis_names) else None
        if not kv_ok and kv_shard_hd and cfg.resolved_head_dim % model == 0:
            # GQA decode with few KV heads: shard head_dim instead — pays a
            # small score all-reduce but divides the dominant KV bytes 16x
            return P(None, b_ax, None, seq_ax, "model")
        return P(None, b_ax, "model" if kv_ok else None, seq_ax, None)
    if s.endswith("/ckv") or s.endswith("/krope"):   # (run, B, S, r)
        seq_ax = "data" if (seq_shard and "data" in mesh.axis_names) else None
        return P(None, b_ax, seq_ax, None)
    if s.endswith("/pos"):                  # (run, B, S)
        seq_ax = "data" if (seq_shard and "data" in mesh.axis_names) else None
        return P(None, b_ax, seq_ax)
    if s.endswith("/conv"):                 # (run, B, dc-1, di)
        di_ok = cfg.mamba_d_inner % model == 0
        return P(None, b_ax, None, "model" if di_ok else None)
    if s.endswith("/ssm"):                  # (run, B, di, ds)
        di_ok = cfg.mamba_d_inner % model == 0
        return P(None, b_ax, "model" if di_ok else None, None)
    if s.endswith("/wkv"):                  # (run, B, H, hd, hd)
        h_ok = cfg.rwkv_num_heads % model == 0
        return P(None, b_ax, "model" if h_ok else None, None, None)
    if s.endswith("/shift_t") or s.endswith("/shift_c"):  # (run, B, d)
        return P(None, b_ax, None)
    return P()


def cache_struct(cfg: ModelConfig, mesh: Mesh, batch: int, max_len: int, *,
                 seq_shard: bool = False, kv_shard_hd: bool = False):
    struct = jax.eval_shape(lambda: M.init_cache(cfg, batch, max_len))
    flat, tdef = jax.tree_util.tree_flatten_with_path(struct)
    out = []
    for path, leaf in flat:
        spec = cache_pspec(_path_str(path), leaf.shape, cfg, mesh, batch,
                           seq_shard, kv_shard_hd)
        if len(spec) != len(leaf.shape):
            spec = P()
        out.append(sds(leaf.shape, leaf.dtype, _ns(mesh, spec)))
    return jax.tree_util.tree_unflatten(tdef, out)


# ------------------------------------------------------------------ inputs


def input_specs(cfg: ModelConfig, shape_name: str, mesh: Mesh, *,
                zero_opt: bool = False, dp_only: bool = False,
                kv_shard_hd: bool = False) -> Dict[str, Any]:
    """Everything needed to lower the step for (cfg, shape, mesh).

    Returns dict: step ('train'|'verify'|'serve'), args (tuple of SDS),
    kwargs (extras), params/opt structs.
    """
    ok, reason = shape_applicable(cfg, shape_name)
    assert ok, f"{cfg.name} x {shape_name}: {reason}"
    info = INPUT_SHAPES[shape_name]
    S, B = info["seq_len"], info["global_batch"]
    dt = _dtype(cfg)
    if dp_only:
        # pure data parallelism: batch over every mesh axis, params replicated
        axes = tuple(mesh.axis_names)
        total = mesh.size
        def _bs(ndim):
            if B % total == 0 and B >= total:
                return P(axes, *([None] * (ndim - 1)))
            return P(*([None] * ndim))
        bspec1, bspec2 = _bs(1), _bs(2)
        bspec3 = _bs(3)
    else:
        bspec1 = batch_spec(mesh, 1, B)
        bspec2 = batch_spec(mesh, 2, B)
        bspec3 = batch_spec(mesh, 3, B)

    params_sds, opt_sds, pspecs = sharded_params_struct(
        cfg, mesh, zero_opt=zero_opt, dp_only=dp_only)
    extras: Dict[str, Any] = {}
    if cfg.encoder_layers:
        # encoder output from the stub frontend path (B, F, d)
        extras["encoder_out"] = sds((B, cfg.encoder_frames, cfg.d_model), dt,
                                    _ns(mesh, bspec3))
        extras["encoder_positions"] = sds((B, cfg.encoder_frames), jnp.int32,
                                          _ns(mesh, bspec2))

    if info["kind"] == "train":
        T = S
        args: Dict[str, Any] = {}
        if cfg.num_prefix_embeddings:
            Pv = cfg.num_prefix_embeddings
            T = S - Pv
            extras["prefix_embeds"] = sds((B, Pv, cfg.d_model), dt,
                                          _ns(mesh, bspec3))
            pos = sds((B, S), jnp.int32, _ns(mesh, bspec2))
        else:
            pos = sds((B, T), jnp.int32, _ns(mesh, bspec2))
        tokens = sds((B, T), jnp.int32, _ns(mesh, bspec2))
        return dict(step="train", params=params_sds, opt=opt_sds,
                    args=(tokens, pos), extras=extras, pspecs=pspecs,
                    tokens_per_step=B * S)

    if info["kind"] == "prefill":
        tokens = sds((B, S), jnp.int32, _ns(mesh, bspec2))
        pos = sds((B, S), jnp.int32, _ns(mesh, bspec2))
        dlp = sds((B, S), jnp.float32, _ns(mesh, bspec2))
        u = sds((B, S), jnp.float32, _ns(mesh, bspec2))
        dlen = sds((B,), jnp.int32, _ns(mesh, bspec1))
        ll = sds((), jnp.float32, _ns(mesh, P()))
        return dict(step="verify", params=params_sds, opt=None,
                    args=(tokens, pos, dlp, u, dlen, ll), extras=extras,
                    pspecs=pspecs, tokens_per_step=B * S)

    # decode: ONE new token against a seq_len-deep cache
    seq_shard = (B == 1)                    # long_500k: shard KV seq on data
    cache_len = min(S, cfg.sliding_window) if (
        cfg.sliding_window and shape_name == "long_500k") else S
    caches = cache_struct(cfg, mesh, B, cache_len, seq_shard=seq_shard,
                          kv_shard_hd=kv_shard_hd)
    token = sds((B, 1), jnp.int32, _ns(mesh, bspec2))
    pos = sds((B, 1), jnp.int32, _ns(mesh, bspec2))
    start = sds((), jnp.int32, _ns(mesh, P()))
    return dict(step="serve", params=params_sds, opt=None,
                args=(token, pos, caches, start), extras=extras,
                pspecs=pspecs, tokens_per_step=B)
