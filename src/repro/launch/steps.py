"""Step functions lowered by the dry-run and used by the drivers.

- ``train_step``: forward + CE loss (+ MoE aux) + backward + AdamW update.
- ``verify_step``: teacher-forced log-probs over a full batch — the
  prefill-shaped SPEC-RL *verification* pass (prefill_32k shape).
- ``serve_step``: ONE new token against a KV/SSM cache (decode shapes).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.engine.sampling import logprobs_of
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.optim import adamw


def _ce_naive(params, cfg, logits, tokens, positions):
    logp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    mask = (positions[..., -tokens.shape[1]:][:, 1:] >= 0).astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def _ce_chunked(params, cfg, hidden, tokens, positions, chunk: int = 1024):
    """Unembedding-chunked cross entropy: never materialises (B, T, V).

    The lm-head matmul + logsumexp + target gather run per T-chunk inside a
    rematerialised scan, so peak memory is (B, chunk, V) instead of
    (B, T, V) — the classic fix for vocab-dominated training memory.
    """
    from repro.models.model import _logits
    B, T, d = hidden.shape
    tgt = jnp.concatenate([tokens[:, 1:], jnp.zeros_like(tokens[:, :1])], 1)
    pos_t = positions[..., -T:]
    mask = jnp.concatenate([(pos_t[:, 1:] >= 0), jnp.zeros_like(
        pos_t[:, :1], bool)], axis=1).astype(jnp.float32)
    chunk = min(chunk, T)
    while T % chunk:
        chunk -= 1
    nch = T // chunk
    h_c = jnp.moveaxis(hidden.reshape(B, nch, chunk, d), 1, 0)
    t_c = jnp.moveaxis(tgt.reshape(B, nch, chunk), 1, 0)
    m_c = jnp.moveaxis(mask.reshape(B, nch, chunk), 1, 0)

    @jax.checkpoint
    def body(carry, xs):
        h, t, m = xs
        logits = _logits(params, cfg, h)                    # (B, chunk, V) f32
        lse = jax.nn.logsumexp(logits, axis=-1)
        tl = jnp.take_along_axis(logits, t[..., None], axis=-1)[..., 0]
        return carry + ((lse - tl) * m).sum(), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (h_c, t_c, m_c))
    return total / jnp.maximum(mask.sum(), 1.0)


def make_train_step(cfg: ModelConfig, ocfg: adamw.AdamWConfig, *,
                    ce_impl: str = "naive", ce_chunk: int = 1024,
                    microbatch: int = 1, accum_dtype: str = "float32",
                    grad_specs=None):
    def loss_fn(params, tokens, positions, extras):
        if ce_impl == "chunked":
            _, aux = M.forward(params, cfg, tokens, positions,
                               return_hidden=True, compute_logits=False,
                               **extras)
            loss = _ce_chunked(params, cfg, aux["hidden"], tokens, positions,
                               ce_chunk)
        else:
            logits, aux = M.forward(params, cfg, tokens, positions, **extras)
            loss = _ce_naive(params, cfg, logits, tokens, positions)
        if "moe_lb_loss" in aux:
            loss = loss + cfg.router_aux_coef * aux["moe_lb_loss"] \
                + cfg.router_z_coef * aux["moe_z_loss"]
        return loss

    def train_step(params, opt_state, tokens, positions, **extras):
        if microbatch > 1:
            # gradient accumulation: activation residuals live for ONE
            # microbatch at a time (B/microbatch rows), grads accumulate
            def split(x):
                return x.reshape(microbatch, x.shape[0] // microbatch,
                                 *x.shape[1:])
            xs = jax.tree.map(split, (tokens, positions, extras))

            adt = jnp.dtype(accum_dtype)

            def mb_body(g_acc, xs_mb):
                t_mb, p_mb, e_mb = xs_mb
                loss, g = jax.value_and_grad(loss_fn)(params, t_mb, p_mb,
                                                      e_mb)
                if grad_specs is not None:
                    # keep per-microbatch grads sharded like the (ZeRO)
                    # optimizer moments: GSPMD lowers the psum to
                    # reduce-scatter instead of a full all-reduce
                    g = jax.lax.with_sharding_constraint(g, grad_specs)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(adt), g_acc, g)
                return g_acc, loss

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, adt), params)
            if grad_specs is not None:
                g0 = jax.lax.with_sharding_constraint(g0, grad_specs)
            grads, losses = jax.lax.scan(mb_body, g0, xs)
            grads = jax.tree.map(lambda g: g / microbatch, grads)
            loss = losses.mean()
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, tokens,
                                                      positions, extras)
        params, opt_state, info = adamw.update(ocfg, params, grads, opt_state)
        return params, opt_state, loss, info["grad_norm"]

    return train_step


def _score_chunked(params, cfg, hidden, tokens, chunk: int = 1024):
    """Chunked log-prob extraction: the (B, T, V) logits tensor is never
    materialised — lm-head matmul + log-softmax + gather run per T-chunk
    (mirrors _ce_chunked; §Perf iteration C for the verification pass)."""
    from repro.models.model import _logits
    B, T, d = hidden.shape
    tgt = jnp.concatenate([tokens[:, 1:], jnp.zeros_like(tokens[:, :1])], 1)
    chunk = min(chunk, T)
    while T % chunk:
        chunk -= 1
    nch = T // chunk
    h_c = jnp.moveaxis(hidden.reshape(B, nch, chunk, d), 1, 0)
    t_c = jnp.moveaxis(tgt.reshape(B, nch, chunk), 1, 0)

    def body(_, xs):
        h, t = xs
        logits = _logits(params, cfg, h)
        lse = jax.nn.logsumexp(logits, axis=-1)
        tl = jnp.take_along_axis(logits, t[..., None], axis=-1)[..., 0]
        return 0, tl - lse

    _, lps = jax.lax.scan(body, 0, (h_c, t_c))       # (nch, B, chunk)
    lp_next = jnp.moveaxis(lps, 0, 1).reshape(B, T)  # lp of token t+1 at t
    return jnp.concatenate([jnp.zeros_like(lp_next[:, :1]),
                            lp_next[:, :-1]], axis=1)


def make_verify_step(cfg: ModelConfig, *, score_impl: str = "naive",
                     score_chunk: int = 1024):
    """SPEC-RL verification at scale: one scoring pass over prompt⊕draft."""
    def verify_step(params, tokens, positions, draft_logprobs, u, draft_len,
                    log_lenience, **extras):
        if score_impl == "chunked":
            _, aux = M.forward(params, cfg, tokens, positions,
                               return_hidden=True, compute_logits=False,
                               **extras)
            lp = _score_chunked(params, cfg, aux["hidden"], tokens,
                                score_chunk)
        else:
            logits, _ = M.forward(params, cfg, tokens, positions, **extras)
            lp = logprobs_of(logits[:, :-1], tokens[:, 1:])
            lp = jnp.concatenate([jnp.zeros_like(lp[:, :1]), lp], axis=1)
        # fused accept/first-reject (oracle impl lowers everywhere)
        from repro.kernels.spec_verify.ref import spec_verify_ref
        n = spec_verify_ref(lp, draft_logprobs, u, draft_len, log_lenience)
        return n, lp

    return verify_step


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, token, position, caches, cache_start, **extras):
        logits, caches = M.decode_step(params, cfg, token, position, caches,
                                       cache_start, **extras)
        return logits, caches

    return serve_step
