"""Distributed training launcher.

On real hardware this runs the RLVR trainer with parameters laid out by the
partition rules over the production mesh; ``--mesh-data/--mesh-model`` build
the runtime mesh (DESIGN.md §8) and the whole rollout → verify → train loop
executes SPMD on it.  A (1, 1) mesh — or too few devices — falls back to
single-device execution, token-identical by the §8 contract.  On a CPU
container virtual devices come from
``XLA_FLAGS=--xla_force_host_platform_device_count=N``.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
        --smoke --steps 4          # reduced variant, CPU, single device
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m repro.launch.train --smoke --steps 4 \
        --mesh-data 4 --mesh-model 2
"""
from __future__ import annotations

import argparse
import math

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.core import SpecConfig
from repro.data.dataset import PromptDataset
from repro.drafting import DraftConfig
from repro.data.tokenizer import VOCAB_SIZE
from repro.distributed.mesh import MeshConfig
from repro.optim.adamw import AdamWConfig
from repro.rewards.mathgen import MathTaskConfig, generate_problems
from repro.rl.trainer import RLConfig, Trainer


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", choices=sorted(ARCH_IDS), default="qwen3-1.7b")
    p.add_argument("--algo", choices=["grpo", "ppo", "dapo"], default="grpo")
    p.add_argument("--variant", default="spec",
                   choices=["spec", "off", "random", "delayed", "full"])
    p.add_argument("--lenience", type=float, default=math.e ** 0.5)
    p.add_argument("--steps", type=int, default=10)
    p.add_argument("--smoke", action="store_true",
                   help="reduced config (CPU-sized) of the same family")
    p.add_argument("--group-size", type=int, default=4)
    p.add_argument("--prompts-per-batch", type=int, default=4)
    p.add_argument("--max-new-tokens", type=int, default=10)
    p.add_argument("--lr", type=float, default=5e-7)
    p.add_argument("--mesh-data", type=int, default=1,
                   help="data-parallel axis size (1 = off)")
    p.add_argument("--mesh-model", type=int, default=1,
                   help="model-parallel axis size (1 = off)")
    p.add_argument("--require-mesh", action="store_true",
                   help="fail instead of falling back when the host has "
                        "fewer devices than the mesh needs")
    p.add_argument("--draft", type=int, default=0, metavar="K",
                   help="continuation draft engine (§9): draft up to K "
                        "tokens per decode forward from n-gram/sibling "
                        "matches (0 = off)")
    p.add_argument("--draft-fixed", action="store_true",
                   help="disable the adaptive per-row draft length "
                        "controller (always draft K)")
    p.add_argument("--async", dest="async_mode", action="store_true",
                   help="§12 disaggregated mode: continuous rollout service "
                        "feeding a bounded trajectory buffer, consumed by "
                        "the trainer under a bounded staleness window")
    p.add_argument("--staleness-window", type=int, default=1, metavar="K",
                   help="async: accept trajectories <= K policy versions "
                        "old with truncated-IS correction; older ones are "
                        "re-verified through the SPEC-RL draft path (K=0 "
                        "is token-identical to the synchronous trainer)")
    p.add_argument("--buffer-capacity", type=int, default=8,
                   help="async: trajectory buffer bound (shed-oldest past "
                        "it, producer throttles at the high watermark)")
    p.add_argument("--publish-every", type=int, default=1,
                   help="async: publish weights every N optimizer steps")
    p.add_argument("--async-schedule", default="pc",
                   help="async: deterministic producer/consumer interleave "
                        "pattern, e.g. 'pc' or 'ppcc'")
    p.add_argument("--watchdog-dir", default="",
                   help="enable the §10 trainer watchdog: snapshot to this "
                        "directory on healthy steps, restore-last-good and "
                        "skip the batch on non-finite loss / stalled rollout")
    p.add_argument("--watchdog-every", type=int, default=10,
                   help="healthy-step snapshot cadence (steps)")
    p.add_argument("--watchdog-max-collect-time", type=float,
                   default=float("inf"),
                   help="rollout stall threshold in seconds")
    p.add_argument("--ledger", action="store_true",
                   help="§14 token-provenance ledger: account every rollout "
                        "token to its mechanism and print the savings-"
                        "attribution report after the run")
    p.add_argument("--decision-log", default="", metavar="DIR",
                   help="§14 decision-record logging: shard draft-decision "
                        "(features, outcomes) records under DIR — the "
                        "learned draft-length controller's dataset")
    p.add_argument("--alerts", action="store_true",
                   help="§14 metric alert rules: evaluate the default "
                        "threshold/trend rules on every step's metrics; "
                        "events trace on the 'alerts' lane and feed the "
                        "watchdog counters when --watchdog-dir rides along")
    p.add_argument("--trace-dir", default="",
                   help="§11 observatory: write trace.json (Chrome trace, "
                        "load at ui.perfetto.dev), events.jsonl and "
                        "metrics.prom here after the run")
    p.add_argument("--trace-sample-rate", type=float, default=1.0,
                   help="fraction of slot-served requests given their own "
                        "trace lane (deterministic per-request hash)")
    p.add_argument("--metrics", type=int, default=0, metavar="PORT",
                   help="serve Prometheus text exposition on "
                        "http://localhost:PORT/metrics during the run "
                        "(0 = off)")
    args = p.parse_args(argv)

    # §11: install the process-global tracer/registry BEFORE the trainer is
    # built so the rollout, drafting and trainer stage hooks all land in it
    tracer = None
    if args.trace_dir or args.metrics:
        from repro.obs import MetricsRegistry, Tracer, configure
        tracer = Tracer(enabled=bool(args.trace_dir),
                        sample_rate=args.trace_sample_rate)
        configure(tracer=tracer, registry=MetricsRegistry())
    # §14: the ledger/decision log are process-global like the tracer — the
    # rollout, drafting loop and slot adapter all record through obs.get_*
    ledger = None
    if args.ledger:
        from repro.obs import configure
        from repro.obs.ledger import TokenLedger
        ledger = TokenLedger(enabled=True)
        configure(ledger=ledger)
    if args.decision_log:
        from repro.obs import configure
        from repro.obs.ledger import DecisionLog
        configure(decisions=DecisionLog(args.decision_log, enabled=True))

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced(vocab_size=max(VOCAB_SIZE, 64))
    if cfg.vocab_size < VOCAB_SIZE:
        cfg = cfg.replace(vocab_size=VOCAB_SIZE)

    problems = generate_problems(MathTaskConfig(num_problems=16,
                                                max_operand=9))
    ds = PromptDataset(problems, max_prompt_len=10)
    rl = RLConfig(algo=args.algo, group_size=args.group_size,
                  prompts_per_batch=args.prompts_per_batch,
                  max_new_tokens=args.max_new_tokens,
                  optim=AdamWConfig(lr=args.lr))
    draft = DraftConfig(kind="ngram", draft_k=args.draft,
                        adaptive=not args.draft_fixed) if args.draft > 0 \
        else DraftConfig()
    spec = SpecConfig(variant=args.variant, lenience=args.lenience,
                      verify_impl="auto", draft=draft)
    mesh_cfg = MeshConfig(data=args.mesh_data, model=args.mesh_model,
                          require=args.require_mesh)
    watchdog = None
    if args.watchdog_dir:
        from repro.rl.watchdog import TrainWatchdog, WatchdogConfig
        watchdog = TrainWatchdog(WatchdogConfig(
            checkpoint_dir=args.watchdog_dir,
            snapshot_every=args.watchdog_every,
            max_collect_time=args.watchdog_max_collect_time))
    alerts = None
    if args.alerts:
        from repro.obs import get_tracer
        from repro.obs.alerts import AlertManager
        alerts = AlertManager(tracer=tracer if tracer is not None
                              else get_tracer())
    tr = Trainer(cfg, rl, spec, ds, jax.random.PRNGKey(0), mesh=mesh_cfg,
                 watchdog=watchdog, alerts=alerts)
    metrics_srv = None
    if args.metrics:
        from repro.obs import get_registry
        from repro.obs.export import start_metrics_server
        metrics_srv = start_metrics_server(get_registry, args.metrics)
        print(f"metrics: http://localhost:{args.metrics}/metrics")
    mesh_desc = (f"{args.mesh_data}x{args.mesh_model}" if tr.mesh is not None
                 else "off")
    print(f"arch={cfg.name} devices={jax.device_count()} mesh={mesh_desc} "
          f"params={sum(x.size for x in jax.tree.leaves(tr.params)) / 1e6:.1f}M")
    def _step_line(m):
        line = (f"step {m['step']:3.0f} reward={m['reward_mean']:.3f} "
                f"gen_tok={m.get('n_generated', 0):6.0f} "
                f"reused={m.get('n_reused', 0):6.0f}")
        if args.draft > 0:
            line += (f" tok/fwd={m.get('tokens_per_forward', 1.0):.2f} "
                     f"draft_acc={m.get('draft_accept_rate', 0.0):.2f} "
                     f"draft_len={m.get('draft_mean_len', 0.0):.2f}")
        return line

    import time as _time
    t_run0 = _time.time()
    if args.async_mode:
        from repro.rl.async_loop import AsyncConfig, AsyncTrainer
        at = AsyncTrainer(tr, AsyncConfig(
            staleness_window=args.staleness_window,
            buffer_capacity=args.buffer_capacity,
            publish_every=args.publish_every,
            schedule=args.async_schedule))
        print(f"async: K={args.staleness_window} "
              f"buffer={args.buffer_capacity} "
              f"schedule={args.async_schedule!r}")
        sched, i, done, idle = args.async_schedule, 0, 0, 0
        while done < args.steps and idle < 10000:
            role = sched[i % len(sched)]
            i += 1
            if role == "p":
                at.producer_tick()
                continue
            m = at.consumer_step()
            if m is None:
                idle += 1
                continue
            idle, done = 0, done + 1
            print(_step_line(m) +
                  f" staleness={m.get('staleness', 0.0):.0f} "
                  f"mode={m.get('async_mode_level', 0.0):.0f}", flush=True)
        for k, v in sorted(at.counters().items()):
            print(f"async {k}={v:.0f}")
    else:
        for _ in range(args.steps):
            print(_step_line(tr.train_step()), flush=True)
    t_run = _time.time() - t_run0
    if metrics_srv is not None:
        metrics_srv.shutdown()
    if args.decision_log:
        from repro.obs import get_decision_log
        dec = get_decision_log()
        dec.flush()
        print(f"decisions: {dec.records_total} records -> "
              f"{args.decision_log} (obs.ledger.load_dataset to reload)")
    if alerts is not None:
        fired = {k: v for k, v in alerts.as_dict().items() if v}
        print(f"alerts: {fired or 'none fired'}")
    report = None
    if args.ledger:
        from repro.obs import get_registry
        from repro.obs.attrib import build_report, measured_token_cost
        regd = get_registry().as_dict()
        n_all = max(1, int(ledger.category_counts().sum()))
        t_tok = measured_token_cost(regd) or t_run / n_all
        report = build_report(ledger, t_tok, actual_s=t_run)
        print(report.summary())
    if args.trace_dir:
        import os
        from repro.obs import export as obs_export, get_registry
        os.makedirs(args.trace_dir, exist_ok=True)
        reg = get_registry()
        counters = None
        if report is not None:
            report.to_registry(reg)
            counters = report.counter_events(t_run)
        obs_export.write_chrome_trace(
            os.path.join(args.trace_dir, "trace.json"), tracer,
            counters=counters)
        obs_export.write_jsonl(
            os.path.join(args.trace_dir, "events.jsonl"), tracer, reg)
        obs_export.write_prometheus(
            os.path.join(args.trace_dir, "metrics.prom"), reg)
        print(f"trace: {args.trace_dir}/trace.json (load at "
              f"ui.perfetto.dev), events.jsonl, metrics.prom")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
