"""Attention: GQA/MQA/MHA with qk-norm, qkv-bias, RoPE, sliding window,
cross-attention, and DeepSeek-V3 MLA (multi-head latent attention).

Position-based masking
----------------------
Every token carries an explicit integer position; padding slots carry -1.
A query at position ``pq`` may attend to a key at position ``pk`` iff::

    pk >= 0  and  pk <= pq          (causal)
    and pq - pk < window            (if sliding window > 0)

This one rule serves training, left-padded prefill and single-token decode,
so prefill+decode is provably equivalent to a full forward (tested).

KV caches come in two layouts (``cfg.cache_layout``, DESIGN.md §13):

* **dense** (default): ``(B, Hkv, S, D)`` buffers plus a ``pos`` array
  (B, S) holding each slot's position (-1 = empty).
* **paged**: physical block pools ``(NB, Hkv, bs, D)`` plus an int32 block
  ``table`` (B, nb) mapping logical block → physical block (logical slot j
  of row b lives at ``pool[table[b, j // bs], :, j % bs]``).  ``pos`` stays
  dense, so position-based masking — and therefore every output — is
  untouched by the layout; physical block 0 is a reserved garbage sink
  (serving/block_table.py).  Both layouts stay statically shaped, which is
  what XLA/TPU wants; paging only redirects which tiles the decode kernel
  DMAs.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (apply_dense, apply_rmsnorm, apply_rope, make_dense,
                     make_rmsnorm, split_keys)

NEG_INF = -1e30


@functools.lru_cache(maxsize=None)
def _default_backend() -> str:
    """Backend probe, hoisted out of the per-layer hot path (the answer
    cannot change within a process)."""
    return jax.default_backend()


# ------------------------------------------------------------------ core math


def dot_product_attention(q, k, v, q_pos, k_pos, *, window: int = 0,
                          causal: bool = True, impl: str = "naive",
                          block_k: int = 1024) -> jnp.ndarray:
    """Grouped-query attention with position-based masking.

    q: (B, Hq, T, D); k/v: (B, Hkv, S, D); q_pos: (B, T); k_pos: (B, S).
    impl='blocked' streams KV chunks through an online softmax (flash
    attention expressed in XLA) so the (T, S) score matrix is never
    materialised — the pure-JAX analogue of kernels/flash_attention, used
    when the Pallas kernel is unavailable (dry-run / CPU).
    """
    if impl == "blocked" and k.shape[2] > block_k:
        return _blocked_attention(q, k, v, q_pos, k_pos, window=window,
                                  causal=causal, block_k=block_k)
    B, Hq, T, D = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, T, D)
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    scores = jnp.einsum("bhgtd,bhsd->bhgts", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    mask = k_pos[:, None, None, None, :] >= 0
    if causal:
        mask &= k_pos[:, None, None, None, :] <= q_pos[:, None, None, :, None]
    if window > 0:
        mask &= (q_pos[:, None, None, :, None] - k_pos[:, None, None, None, :]) < window
    # Rows whose query is padding produce garbage that is masked downstream.
    scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    # Fully-masked rows: softmax of all -inf -> uniform garbage; zero them.
    any_valid = jnp.any(mask, axis=-1, keepdims=True)
    w = jnp.where(any_valid, w, 0.0)
    out = jnp.einsum("bhgts,bhsd->bhgtd", w, v.astype(jnp.float32))
    return out.reshape(B, Hq, T, v.shape[-1])


def _blocked_attention(q, k, v, q_pos, k_pos, *, window: int, causal: bool,
                       block_k: int) -> jnp.ndarray:
    """Online-softmax attention over KV chunks (peak memory ~ (T, block_k))."""
    B, Hq, T, D = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = Hq // Hkv
    pad = (-S) % block_k
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-1)
    nch = k.shape[2] // block_k
    qg = q.reshape(B, Hkv, G, T, D).astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    kc = jnp.moveaxis(k.reshape(B, Hkv, nch, block_k, D), 2, 0)
    vc = jnp.moveaxis(v.reshape(B, Hkv, nch, block_k, Dv), 2, 0)
    pc = jnp.moveaxis(k_pos.reshape(B, nch, block_k), 1, 0)

    def body(carry, xs):
        m, l, acc = carry                                   # (B,Hkv,G,T,1/Dv)
        k_b, v_b, p_b = xs
        s = jnp.einsum("bhgtd,bhsd->bhgts", qg,
                       k_b.astype(jnp.float32)) * scale
        mask = p_b[:, None, None, None, :] >= 0
        if causal:
            mask &= p_b[:, None, None, None, :] <= \
                q_pos[:, None, None, :, None]
        if window > 0:
            mask &= (q_pos[:, None, None, :, None]
                     - p_b[:, None, None, None, :]) < window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m - m_new)
        l = corr * l + jnp.sum(p, axis=-1, keepdims=True)
        acc = corr * acc + jnp.einsum("bhgts,bhsd->bhgtd", p,
                                      v_b.astype(jnp.float32))
        return (m_new, l, acc), None

    init = (jnp.full((B, Hkv, G, T, 1), NEG_INF, jnp.float32),
            jnp.zeros((B, Hkv, G, T, 1), jnp.float32),
            jnp.zeros((B, Hkv, G, T, Dv), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(body, init, (kc, vc, pc))
    out = acc / jnp.where(l > 0, l, 1.0)
    return out.reshape(B, Hq, T, Dv)


# largest query block the decode-shaped path accepts (k + 1 for draft
# blocks); bigger cached-T calls take the prefill-style full-S paths
DECODE_BLOCK_MAX_T = 64


def _decode_shaped(cache, kv_x, causal, T: int, kv_length) -> bool:
    """Whether a cached call routes to the flash-decode op: single-token
    decode always; a short multi-token block (the §9 draft-verify forward)
    only when the caller threads its per-row live bounds explicitly."""
    if cache is None or kv_x is not None or not causal:
        return False
    return T == 1 or (kv_length is not None and T <= DECODE_BLOCK_MAX_T)


def _decode_attention(cfg: ModelConfig, q, k, v, q_pos, kv_pos, *,
                      window: int, cache_start, kv_length, kv_start,
                      use_pallas: bool, mesh=None, paged=None) -> jnp.ndarray:
    """Route a decode-shaped (short-T, cached) call to the flash-decode op.

    ``kv_length`` is the per-row live cache extent.  When the caller does
    not thread it explicitly it is derived from ``cache_start``: the T
    decode tokens were just written at slots [cache_start, cache_start+T),
    so every slot at or beyond ``cache_start + T`` is empty (pos == -1) and
    can be skipped.  ``kv_start`` is the per-row first live slot (the dead
    left-padding in front of a left-padded / compacted context); only
    callers that know their layout is contiguous from that slot may thread
    it — None means start at 0, which is always safe.

    ``mesh`` routes the call through the shard_map boundary (DESIGN.md §8):
    each device runs the kernel on its local (batch, head) block with a
    static per-shard shape instead of leaving a Pallas black box to GSPMD.
    """
    B, _, T = q.shape[:3]
    if kv_length is None:
        kv_length = jnp.asarray(cache_start, jnp.int32) + T
    lengths = jnp.broadcast_to(
        jnp.asarray(kv_length, jnp.int32).reshape(-1), (B,))
    starts = None if kv_start is None else jnp.broadcast_to(
        jnp.asarray(kv_start, jnp.int32).reshape(-1), (B,))
    if window > 0 and starts is not None:
        # contiguous layout (the kv_start contract): slot j holds position
        # j - start, so keys at or below start + q_pos - window are outside
        # the sliding window of the EARLIEST query (t=0) — tighten the start
        # bound to skip their blocks entirely (they were already
        # window-masked; this changes no output)
        qp = q_pos[:, 0].astype(jnp.int32)
        starts = jnp.maximum(starts, starts + qp - window + 1)
    impl = cfg.decode_impl
    if impl == "auto" and use_pallas:
        impl = "pallas" if _default_backend() == "tpu" else "interpret"
    # remaining "auto" resolves in the op: pallas on TPU, else naive for
    # tiny caches / length-bounded blocked beyond (DESIGN.md §7)
    if mesh is not None:
        # paged + mesh reuses the dense shard_map path on the gathered
        # logical view the caller already built (k/v here) — the gather is
        # a per-shard-local permutation once pools stay unsharded on batch
        from repro.distributed.shard_wrap import sharded_decode_attention
        if starts is None:
            starts = jnp.zeros((B,), jnp.int32)
        return sharded_decode_attention(
            mesh, q, k.astype(q.dtype), v.astype(q.dtype), q_pos,
            kv_pos, lengths, starts, window=window, impl=impl)
    if paged is not None and impl in ("pallas", "interpret"):
        # the paged flash kernel consumes the block pools directly (the
        # gathered k/v above become dead code under jit)
        from repro.kernels.decode_attention.ops import paged_decode_attention
        k_pool, v_pool, table = paged
        return paged_decode_attention(
            q, k_pool.astype(q.dtype), v_pool.astype(q.dtype), table,
            q_pos, kv_pos, lengths, starts, window=window, impl=impl)
    from repro.kernels.decode_attention.ops import decode_attention
    return decode_attention(q, k.astype(q.dtype), v.astype(q.dtype),
                            q_pos, kv_pos, lengths, starts,
                            window=window, impl=impl)


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> dict:
    hd = cfg.resolved_head_dim
    if cfg.cache_layout == "paged":
        return init_paged_kv_cache(cfg, batch, max_len, dtype)
    if cfg.attention_kind == "mla":
        return {
            "ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
            "krope": jnp.zeros((batch, max_len, cfg.qk_rope_head_dim), dtype),
            "pos": jnp.full((batch, max_len), -1, jnp.int32),
        }
    return {
        "k": jnp.zeros((batch, cfg.num_kv_heads, max_len, hd), dtype),
        "v": jnp.zeros((batch, cfg.num_kv_heads, max_len, hd), dtype),
        "pos": jnp.full((batch, max_len), -1, jnp.int32),
    }


def init_paged_kv_cache(cfg: ModelConfig, batch: int, max_len: int, dtype,
                        *, num_blocks: Optional[int] = None,
                        table=None) -> dict:
    """Paged layer cache (DESIGN.md §13).

    The *logical* width stays exactly ``max_len`` (the ``pos`` array is
    byte-identical to the dense layout's, and every gather slices the
    block-rounded physical view back to it — which is what makes paged
    outputs bit-exact against dense, not merely close); only the physical
    pools are rounded up to whole blocks.

    Without ``table``, each row owns a contiguous identity stripe of the
    pool — the zero-bookkeeping layout the pure-functional paths
    (``generate``, one-pass resume, drafted decode) use, exercising the
    same paged read/write machinery as the allocator-managed serving
    engine.  ``num_blocks``/``table`` let the serving engine supply its own
    pool size (with the block-0 sink) and allocator-issued tables.
    """
    bs = cfg.kv_block_size
    nb = -(-max_len // bs)                   # physical blocks per row
    if table is None:
        table = (jnp.arange(batch * nb, dtype=jnp.int32).reshape(batch, nb))
        if num_blocks is None:
            num_blocks = batch * nb
    else:
        table = jnp.asarray(table, jnp.int32)
        assert table.shape == (batch, nb), (table.shape, (batch, nb))
        assert num_blocks is not None
    if cfg.attention_kind == "mla":
        return {
            "ckv": jnp.zeros((num_blocks, bs, cfg.kv_lora_rank), dtype),
            "krope": jnp.zeros((num_blocks, bs, cfg.qk_rope_head_dim), dtype),
            "pos": jnp.full((batch, max_len), -1, jnp.int32),
            "table": table,
        }
    hd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((num_blocks, cfg.num_kv_heads, bs, hd), dtype),
        "v": jnp.zeros((num_blocks, cfg.num_kv_heads, bs, hd), dtype),
        "pos": jnp.full((batch, max_len), -1, jnp.int32),
        "table": table,
    }


def _cache_write(buf, update, start, axis: int = -2):
    """Write ``update`` (length T) into ``buf`` at slot ``start`` on ``axis``.

    start: scalar — one slot for the whole batch (prefill / lockstep decode)
    — or (B,) int32 — per-row slots, required by the serving slot scheduler
    whose slots sit at different decode depths (DESIGN.md §6).  The per-row
    form is a vmap'd dynamic_update_slice (a scatter), writing the same
    values at the same indices as the scalar form does row by row.
    """
    update = update.astype(buf.dtype)
    if jnp.ndim(start) == 0:
        return jax.lax.dynamic_update_slice_in_dim(buf, update, start, axis)
    return jax.vmap(
        lambda b, u, s: jax.lax.dynamic_update_slice_in_dim(b, u, s, axis)
    )(buf, update, start.astype(jnp.int32))


def _paged_write(pool, update, start, table, s_logical: int):
    """Paged counterpart of ``_cache_write``: scatter a T-token update into
    the physical block pool through the row's block table.

    pool: (NB, Hkv, bs, D) or (NB, bs, D); update: (B, Hkv, T, D) /
    (B, T, D); start: scalar or (B,) int32; s_logical: the logical cache
    width (the ``pos`` array's, which may be short of ``nb * bs`` by the
    block-rounding slack).  Slot mapping matches the dense DUS semantics
    exactly — the effective start is clamped to ``s_logical - T`` so the
    whole window fits, and token t lands at logical slot ``start + t``
    (physical ``pool[table[b, (start+t) // bs], ..., (start+t) % bs]``).

    Two regimes: a large block-aligned update (prefill) scatters whole
    blocks; a short update (decode step / draft block, T <=
    DECODE_BLOCK_MAX_T) scatters per token.  Both are plain jnp scatters —
    the layout transform is memory-bound and XLA-friendly; only the
    attention *read* has a Pallas kernel.
    """
    update = update.astype(pool.dtype)
    bs = pool.shape[-2]
    B, nb = table.shape
    S = s_logical                     # clamp like dense DUS at this width
    gqa = pool.ndim == 4
    T = update.shape[2] if gqa else update.shape[1]
    start = jnp.asarray(start, jnp.int32)
    s0 = jnp.clip(jnp.broadcast_to(start.reshape(-1), (B,)), 0, S - T)
    if jnp.ndim(start) == 0 and T >= bs:
        # block-aligned prefill: the only scalar-start large-T callers write
        # at slot 0 (prefill / verify_and_prefill), so start % bs == 0
        # holds.  A ragged tail is zero-padded to a whole block — the extra
        # slots stay pos == -1 (masked) until a later decode write claims
        # them.
        pad = (-T) % bs
        if pad:
            width = [(0, 0)] * update.ndim
            width[2 if gqa else 1] = (0, pad)
            update = jnp.pad(update, width)
        nbw = (T + pad) // bs
        b0 = s0 // bs                                       # (B,)
        rows = jnp.arange(B)
        if gqa:
            chunks = update.reshape(B, update.shape[1], nbw, bs, -1)
            for i in range(nbw):
                blk = table[rows, b0 + i]
                pool = pool.at[blk].set(chunks[:, :, i])
        else:
            chunks = update.reshape(B, nbw, bs, -1)
            for i in range(nbw):
                blk = table[rows, b0 + i]
                pool = pool.at[blk].set(chunks[:, i])
        return pool
    rows = jnp.arange(B)
    for t in range(T):
        idx = s0 + t
        blk = table[rows, idx // bs]
        off = idx % bs
        if gqa:
            pool = pool.at[blk, :, off].set(update[:, :, t])
        else:
            pool = pool.at[blk, off].set(update[:, t])
    return pool


def _paged_gather(pool, table, s_logical: int):
    """Dense logical view of a paged pool, sliced to the logical width:
    (B, Hkv, s_logical, D) / (B, s_logical, D) — shape-identical (and
    value-identical) to the dense cache buffer, so every downstream fp op
    runs bit-exactly the dense program.  Read-side fallback for the
    non-kernel attention paths; DCE'd by XLA when the paged Pallas kernel
    consumes the pools directly."""
    B, nb = table.shape
    g = jnp.take(pool, table.reshape(-1), axis=0)
    if pool.ndim == 4:
        NB, Hkv, bs, D = pool.shape
        return (g.reshape(B, nb, Hkv, bs, D).transpose(0, 2, 1, 3, 4)
                .reshape(B, Hkv, nb * bs, D)[:, :, :s_logical])
    NB, bs, D = pool.shape
    return g.reshape(B, nb * bs, D)[:, :s_logical]


# ------------------------------------------------------------------ GQA layer


def make_gqa(key, cfg: ModelConfig, dtype):
    hd = cfg.resolved_head_dim
    ks = split_keys(key, 4)
    p = {
        "wq": make_dense(ks[0], cfg.d_model, cfg.num_heads * hd, cfg.qkv_bias, dtype),
        "wk": make_dense(ks[1], cfg.d_model, cfg.num_kv_heads * hd, cfg.qkv_bias, dtype),
        "wv": make_dense(ks[2], cfg.d_model, cfg.num_kv_heads * hd, cfg.qkv_bias, dtype),
        "wo": make_dense(ks[3], cfg.num_heads * hd, cfg.d_model, False, dtype,
                         scale=1.0 / (cfg.num_heads * hd) ** 0.5),
    }
    if cfg.qk_norm:
        p["q_norm"] = make_rmsnorm(hd, dtype)
        p["k_norm"] = make_rmsnorm(hd, dtype)
    return p


def apply_gqa(p, cfg: ModelConfig, x, positions, *, cache=None, cache_start=None,
              causal=True, kv_x=None, kv_positions=None,
              use_pallas: bool = False, kv_length=None, kv_start=None,
              mesh=None):
    """GQA attention.

    x: (B, T, d).  With ``cache`` given, writes K/V at ``cache_start`` and
    attends over the whole cache (decode / incremental prefill).  With
    ``kv_x`` given, performs cross-attention (no causal mask, no rope on kv
    unless positions supplied).  ``kv_length`` (scalar or (B,) int32) bounds
    the live cache extent for decode-shaped calls (T == 1 with cache): those
    are dispatched to the flash-decode kernel / length-bounded blocked path
    instead of full-S attention.
    """
    B, T, _ = x.shape
    hd = cfg.resolved_head_dim
    q = apply_dense(p["wq"], x).reshape(B, T, cfg.num_heads, hd).transpose(0, 2, 1, 3)
    src = kv_x if kv_x is not None else x
    S = src.shape[1]
    k = apply_dense(p["wk"], src).reshape(B, S, cfg.num_kv_heads, hd).transpose(0, 2, 1, 3)
    v = apply_dense(p["wv"], src).reshape(B, S, cfg.num_kv_heads, hd).transpose(0, 2, 1, 3)

    if cfg.qk_norm:
        q = apply_rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = apply_rmsnorm(p["k_norm"], k, cfg.norm_eps)

    if kv_x is None:
        kv_pos = positions
        if cfg.pos_embed == "rope":
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, kv_pos, cfg.rope_theta)
    else:
        kv_pos = kv_positions
        # cross-attention: no rope (whisper style learned enc positions)

    new_cache = None
    paged = None
    if cache is not None:
        if "table" in cache:
            table = cache["table"]
            S_log = cache["pos"].shape[-1]
            k_pool = _paged_write(cache["k"], k, cache_start, table, S_log)
            v_pool = _paged_write(cache["v"], v, cache_start, table, S_log)
            pos_all = _cache_write(cache["pos"], kv_pos.astype(jnp.int32),
                                   cache_start, axis=-1)
            new_cache = {"k": k_pool, "v": v_pool, "pos": pos_all,
                         "table": table}
            paged = (k_pool, v_pool, table)
            # dense logical view for the non-kernel paths; DCE'd when the
            # paged kernel consumes the pools directly
            k, v, kv_pos = (_paged_gather(k_pool, table, S_log),
                            _paged_gather(v_pool, table, S_log), pos_all)
        else:
            k_all = _cache_write(cache["k"], k, cache_start)
            v_all = _cache_write(cache["v"], v, cache_start)
            pos_all = _cache_write(cache["pos"], kv_pos.astype(jnp.int32),
                                   cache_start, axis=-1)
            new_cache = {"k": k_all, "v": v_all, "pos": pos_all}
            k, v, kv_pos = k_all, v_all, pos_all

    if _decode_shaped(cache, kv_x, causal, T, kv_length):
        # short-query decode (single token, or a k+1 draft-verify block):
        # flash-decode kernel with split-K and per-row cache-length early
        # exit (or the length-bounded blocked fallback)
        out = _decode_attention(cfg, q, k, v, positions, kv_pos,
                                window=cfg.sliding_window,
                                cache_start=cache_start, kv_length=kv_length,
                                kv_start=kv_start, use_pallas=use_pallas,
                                mesh=mesh, paged=paged)
    elif use_pallas and kv_x is None and T > 1:
        # Pallas flash kernel (TPU; interpret mode in tests).  Same schedule
        # as _blocked_attention but with MXU-aligned VMEM tiles.  The decode
        # dispatch above guarantees the prefill kernel never sees the
        # degenerate block_q=1 shape.
        from repro.kernels.flash_attention.ops import flash_attention
        impl = "pallas" if _default_backend() == "tpu" else "interpret"
        out = flash_attention(q, k.astype(q.dtype), v.astype(q.dtype),
                              positions, kv_pos, causal=causal,
                              window=cfg.sliding_window, impl=impl,
                              block_q=min(128, q.shape[2]),
                              block_k=min(128, k.shape[2]))
    else:
        out = dot_product_attention(q, k.astype(q.dtype), v.astype(q.dtype),
                                    positions, kv_pos,
                                    window=cfg.sliding_window, causal=causal,
                                    impl=cfg.attn_impl)
    out = out.transpose(0, 2, 1, 3).reshape(B, T, cfg.num_heads * hd)
    return apply_dense(p["wo"], out.astype(x.dtype)), new_cache


# ------------------------------------------------------------------ MLA layer


def make_mla(key, cfg: ModelConfig, dtype):
    """DeepSeek-V3 multi-head latent attention.

    q path:  d -> q_lora -> norm -> H*(nope+rope)
    kv path: d -> (kv_lora + shared k_rope); kv_lora -> norm -> H*(nope + v)
    Cache stores only the compressed latent + shared rope key.
    """
    ks = split_keys(key, 6)
    H = cfg.num_heads
    qd = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    p = {
        "wkv_a": make_dense(ks[2], cfg.d_model,
                            cfg.kv_lora_rank + cfg.qk_rope_head_dim, False, dtype),
        "kv_norm": make_rmsnorm(cfg.kv_lora_rank, dtype),
        "wkv_b": make_dense(ks[3], cfg.kv_lora_rank,
                            H * (cfg.qk_nope_head_dim + cfg.v_head_dim), False, dtype),
        "wo": make_dense(ks[4], H * cfg.v_head_dim, cfg.d_model, False, dtype),
    }
    if cfg.q_lora_rank:
        p["wq_a"] = make_dense(ks[0], cfg.d_model, cfg.q_lora_rank, False, dtype)
        p["q_norm"] = make_rmsnorm(cfg.q_lora_rank, dtype)
        p["wq_b"] = make_dense(ks[1], cfg.q_lora_rank, H * qd, False, dtype)
    else:
        p["wq"] = make_dense(ks[0], cfg.d_model, H * qd, False, dtype)
    return p


def apply_mla(p, cfg: ModelConfig, x, positions, *, cache=None, cache_start=None,
              causal=True, kv_length=None, kv_start=None, mesh=None):
    B, T, _ = x.shape
    H = cfg.num_heads
    nd, rd, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim

    if cfg.q_lora_rank:
        q = apply_dense(p["wq_b"], apply_rmsnorm(p["q_norm"],
                                                 apply_dense(p["wq_a"], x), cfg.norm_eps))
    else:
        q = apply_dense(p["wq"], x)
    q = q.reshape(B, T, H, nd + rd).transpose(0, 2, 1, 3)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv_a = apply_dense(p["wkv_a"], x)
    ckv, k_rope = kv_a[..., :cfg.kv_lora_rank], kv_a[..., cfg.kv_lora_rank:]
    ckv = apply_rmsnorm(p["kv_norm"], ckv, cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, None, :, :], positions, cfg.rope_theta)  # (B,1,T,rd)

    kv_pos = positions
    new_cache = None
    if cache is not None:
        if "table" in cache:
            # paged MLA: latents live in block pools; reads always go
            # through the dense gather (decompression needs the full
            # logical view anyway, DESIGN.md §7)
            table = cache["table"]
            S_log = cache["pos"].shape[-1]
            ckv_pool = _paged_write(cache["ckv"], ckv, cache_start, table,
                                    S_log)
            krope_pool = _paged_write(cache["krope"], k_rope[:, 0],
                                      cache_start, table, S_log)
            pos_all = _cache_write(cache["pos"], positions.astype(jnp.int32),
                                   cache_start, axis=-1)
            new_cache = {"ckv": ckv_pool, "krope": krope_pool,
                         "pos": pos_all, "table": table}
            ckv = _paged_gather(ckv_pool, table, S_log)
            k_rope = _paged_gather(krope_pool, table, S_log)[:, None]
            kv_pos = pos_all
        else:
            ckv_all = _cache_write(cache["ckv"], ckv, cache_start, axis=-2)
            krope_all = _cache_write(cache["krope"], k_rope[:, 0],
                                     cache_start, axis=-2)
            pos_all = _cache_write(cache["pos"], positions.astype(jnp.int32),
                                   cache_start, axis=-1)
            new_cache = {"ckv": ckv_all, "krope": krope_all, "pos": pos_all}
            ckv, k_rope, kv_pos = ckv_all, krope_all[:, None], pos_all

    # decompress latent -> per-head K_nope and V
    kv = apply_dense(p["wkv_b"], ckv.astype(x.dtype))
    S = kv.shape[1]
    kv = kv.reshape(B, S, H, nd + vd).transpose(0, 2, 1, 3)
    k_nope, v = kv[..., :nd], kv[..., nd:]
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope.astype(x.dtype),
                                                  (B, H, S, rd))], axis=-1)
    qfull = jnp.concatenate([q_nope, q_rope], axis=-1)

    if _decode_shaped(cache, None, causal, T, kv_length):
        # MLA decode: after latent decompression this is MHA (G = 1) with
        # distinct Dk/Dv head dims — shapes the flash-decode kernel and its
        # length-bounded blocked fallback both support (T > 1 packs the
        # draft block into the sublane dim, §9).
        out = _decode_attention(cfg, qfull, k, v, positions, kv_pos,
                                window=0, cache_start=cache_start,
                                kv_length=kv_length, kv_start=kv_start,
                                use_pallas=False, mesh=mesh)
    else:
        out = dot_product_attention(qfull, k, v, positions, kv_pos,
                                    window=0, causal=causal,
                                    impl=cfg.attn_impl)
    out = out.transpose(0, 2, 1, 3).reshape(B, T, H * vd)
    return apply_dense(p["wo"], out.astype(x.dtype)), new_cache


# ------------------------------------------------------------------ dispatch


def make_attention(key, cfg: ModelConfig, dtype):
    if cfg.attention_kind == "mla":
        return make_mla(key, cfg, dtype)
    return make_gqa(key, cfg, dtype)


def apply_attention(p, cfg: ModelConfig, x, positions, **kw):
    if cfg.attention_kind == "mla":
        kw.pop("kv_x", None), kw.pop("kv_positions", None)
        # MLA prefill stays on the jnp path (mixed head dims defeat the
        # prefill flash tiling); decode routes to the flash-decode op, which
        # handles Dk != Dv, inside apply_mla.
        kw.pop("use_pallas", None)
        return apply_mla(p, cfg, x, positions, **kw)
    return apply_gqa(p, cfg, x, positions, **kw)
