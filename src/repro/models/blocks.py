"""Decoder blocks and the layer-stack assembler.

A block is (kind, is_moe, cross) where kind ∈ {attn, mamba, rwkv}.  Layers
with identical signatures are *stacked* and executed with ``jax.lax.scan`` so
the lowered HLO stays small even for 88-layer trunks; heterogeneous trunks
(jamba) become a short python loop over signature runs, each run scanned.

Caches mirror the run structure: ``cache[run_idx]`` is a pytree whose leaves
have a leading ``run_len`` axis, scanned alongside the parameters.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import apply_attention, init_kv_cache, make_attention
from .config import ATTN, MAMBA, RWKV, ModelConfig
from .layers import (apply_layernorm, apply_rmsnorm, make_layernorm,
                     make_rmsnorm, split_keys)
from .mamba import apply_mamba, init_mamba_cache, make_mamba
from .moe import apply_moe, apply_ffn, make_ffn, make_moe
from .rwkv import (apply_rwkv_channel_mix, apply_rwkv_time_mix,
                   init_rwkv_cache, make_rwkv_channel_mix, make_rwkv_time_mix)

BlockSig = Tuple[str, bool, bool]  # (kind, is_moe, cross_attention)


def block_signatures(cfg: ModelConfig) -> List[BlockSig]:
    return [(kind, moe, cfg.cross_attention)
            for kind, moe in cfg.layer_plan()]


def signature_runs(cfg: ModelConfig) -> List[Tuple[BlockSig, int]]:
    """Consecutive runs of identical block signatures: [(sig, run_len), ...]."""
    runs: List[Tuple[BlockSig, int]] = []
    for sig in block_signatures(cfg):
        if runs and runs[-1][0] == sig:
            runs[-1] = (sig, runs[-1][1] + 1)
        else:
            runs.append((sig, 1))
    return runs


# ------------------------------------------------------------------ single block


def make_block(key, cfg: ModelConfig, sig: BlockSig, dtype):
    kind, is_moe, cross = sig
    ks = split_keys(key, 6)
    norm = make_layernorm if kind == RWKV else make_rmsnorm
    p: Dict[str, Any] = {"norm1": norm(cfg.d_model, dtype),
                         "norm2": norm(cfg.d_model, dtype)}
    if kind == ATTN:
        p["attn"] = make_attention(ks[0], cfg, dtype)
    elif kind == MAMBA:
        p["mamba"] = make_mamba(ks[0], cfg, dtype)
    elif kind == RWKV:
        p["time_mix"] = make_rwkv_time_mix(ks[0], cfg, dtype)
    if cross:
        p["norm_ca"] = norm(cfg.d_model, dtype)
        p["cross_attn"] = make_attention(ks[1], cfg.replace(qk_norm=False), dtype)
    if kind == RWKV:
        p["channel_mix"] = make_rwkv_channel_mix(ks[2], cfg, dtype)
    elif is_moe:
        p["moe"] = make_moe(ks[2], cfg, dtype)
    else:
        p["mlp"] = make_ffn(ks[2], cfg.d_model, cfg.d_ff, dtype, cfg.ffn_kind)
    return p


def init_block_cache(cfg: ModelConfig, sig: BlockSig, batch: int, max_len: int, dtype):
    kind, _, cross = sig
    cache: Dict[str, Any] = {}
    if kind == ATTN:
        cache["self"] = init_kv_cache(cfg, batch, max_len, dtype)
    elif kind == MAMBA:
        cache["mamba"] = init_mamba_cache(cfg, batch, dtype)
    elif kind == RWKV:
        cache["rwkv"] = init_rwkv_cache(cfg, batch, dtype)
    # cross-attn K/V are recomputed from encoder_out each call (cheap for the
    # stubbed frontend lengths) — no cross cache entries needed.
    return cache


def apply_block(p, cfg: ModelConfig, sig: BlockSig, x, positions, *,
                cache=None, cache_start=None, encoder_out=None,
                encoder_positions=None, use_pallas: bool = False,
                causal: bool = True, kv_length=None, kv_start=None,
                mesh=None):
    kind, is_moe, cross = sig
    norm = apply_layernorm if kind == RWKV else functools.partial(
        apply_rmsnorm, eps=cfg.norm_eps)
    aux: Dict[str, jnp.ndarray] = {}
    new_cache: Dict[str, Any] = {}

    h = norm(p["norm1"], x)
    if kind == ATTN:
        out, c = apply_attention(p["attn"], cfg, h, positions,
                                 cache=None if cache is None else cache["self"],
                                 cache_start=cache_start, causal=causal,
                                 use_pallas=use_pallas, kv_length=kv_length,
                                 kv_start=kv_start, mesh=mesh)
        if c is not None:
            new_cache["self"] = c
    elif kind == MAMBA:
        out, c = apply_mamba(p["mamba"], cfg, h, positions,
                             cache=None if cache is None else cache["mamba"])
        if c is not None:
            new_cache["mamba"] = c
    else:  # RWKV time mix
        out, c = apply_rwkv_time_mix(p["time_mix"], cfg, h, positions,
                                     cache=None if cache is None else cache["rwkv"],
                                     use_pallas=use_pallas)
        if c is not None:
            new_cache["rwkv"] = dict(c)
    x = x + out

    if cross:
        h = norm(p["norm_ca"], x)
        out, _ = apply_attention(p["cross_attn"], cfg, h, positions,
                                 kv_x=encoder_out, kv_positions=encoder_positions,
                                 causal=False)
        x = x + out

    h = norm(p["norm2"], x)
    if kind == RWKV:
        out, c = apply_rwkv_channel_mix(p["channel_mix"], cfg, h, positions,
                                        cache=None if cache is None else cache["rwkv"])
        if c is not None:
            new_cache["rwkv"].update(c)
    elif is_moe:
        out, moe_aux = apply_moe(p["moe"], cfg, h)
        aux.update(moe_aux)
    else:
        out = apply_ffn(p["mlp"], h, cfg.act)
    x = x + out
    return x, (new_cache if cache is not None else None), aux


# ------------------------------------------------------------------ layer stack


def make_trunk(key, cfg: ModelConfig, dtype):
    """Returns params: list (one entry per run) of stacked block params."""
    runs = signature_runs(cfg)
    keys = split_keys(key, len(runs))
    trunk = []
    for (sig, run_len), k in zip(runs, keys):
        layer_keys = split_keys(k, run_len)
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs),
                               *[make_block(lk, cfg, sig, dtype) for lk in layer_keys])
        trunk.append(stacked)
    return trunk


def init_trunk_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    caches = []
    for sig, run_len in signature_runs(cfg):
        one = init_block_cache(cfg, sig, batch, max_len, dtype)
        caches.append(jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (run_len,) + x.shape).copy(), one))
    return caches


def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return fn


def apply_trunk(trunk_params, cfg: ModelConfig, x, positions, *,
                caches=None, cache_start=None, encoder_out=None,
                encoder_positions=None, use_pallas: bool = False,
                causal: bool = True, kv_length=None, kv_start=None,
                mesh=None):
    """Run all layers.  Returns (x, new_caches, aux_mean)."""
    runs = signature_runs(cfg)
    new_caches = [] if caches is not None else None
    aux_sums: Dict[str, jnp.ndarray] = {}
    aux_counts: Dict[str, int] = {}

    for run_idx, (sig, run_len) in enumerate(runs):
        params = trunk_params[run_idx]
        cache = caches[run_idx] if caches is not None else None

        def body(carry, xs):
            h = carry
            if cache is not None:
                layer_p, layer_c = xs
            else:
                layer_p, layer_c = xs, None
            h, new_c, aux = apply_block(
                layer_p, cfg, sig, h, positions,
                cache=layer_c, cache_start=cache_start,
                encoder_out=encoder_out, encoder_positions=encoder_positions,
                use_pallas=use_pallas, causal=causal, kv_length=kv_length,
                kv_start=kv_start, mesh=mesh)
            outs = (new_c, aux) if cache is not None else aux
            return h, outs

        body = _maybe_remat(body, cfg)
        xs = (params, cache) if cache is not None else params
        x, outs = jax.lax.scan(body, x, xs)
        if cache is not None:
            stacked_c, auxs = outs
            new_caches.append(stacked_c)
        else:
            auxs = outs
        for k, v in auxs.items():           # v: (run_len, ...) from scan ys
            aux_sums[k] = aux_sums.get(k, 0.0) + jnp.sum(v, axis=0)
            aux_counts[k] = aux_counts.get(k, 0) + run_len

    aux_mean = {k: aux_sums[k] / aux_counts[k] for k in aux_sums}
    return x, new_caches, aux_mean
