"""Unified model configuration covering every assigned architecture family.

One frozen dataclass describes dense / GQA / MLA / MoE / Mamba / RWKV6 /
hybrid / encoder-decoder models.  Per-architecture instances live in
``repro/configs/<id>.py``; reduced smoke variants are derived with
``ModelConfig.reduced()``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# Block kinds a layer may take.
ATTN = "attn"
MAMBA = "mamba"
RWKV = "rwkv"

VALID_BLOCKS = (ATTN, MAMBA, RWKV)


@dataclass(frozen=True)
class ModelConfig:
    # -- identity ----------------------------------------------------------
    name: str = "model"
    arch_type: str = "dense"          # dense|moe|hybrid|ssm|vlm|audio
    source: str = ""                  # citation (paper / model card)

    # -- trunk -------------------------------------------------------------
    num_layers: int = 2
    d_model: int = 128
    num_heads: int = 2                # query heads (0 for attention-free)
    num_kv_heads: int = 2
    head_dim: int = 0                 # 0 -> d_model // num_heads
    d_ff: int = 512
    vocab_size: int = 256
    max_seq_len: int = 8192
    norm_eps: float = 1e-6
    act: str = "silu"                 # silu|gelu
    ffn_kind: str = "swiglu"          # swiglu (3-matrix) | mlp (2-matrix, granite/whisper)

    # -- attention flavour --------------------------------------------------
    attention_kind: str = "gqa"       # gqa|mla
    qk_norm: bool = False             # qwen3
    qkv_bias: bool = False            # qwen1.5
    rope_theta: float = 1_000_000.0
    pos_embed: str = "rope"           # rope|learned (whisper decoder)
    sliding_window: int = 0           # 0 = full attention; >0 = SWA (mixtral)
    attn_impl: str = "naive"          # naive (materialised scores) | blocked (online-softmax XLA flash)
    decode_impl: str = "auto"         # T==1 decode attention: auto (pallas on TPU;
                                      # naive for tiny caches, length-bounded blocked
                                      # beyond) | naive | blocked | pallas | interpret

    # -- KV cache layout (DESIGN.md §13) -------------------------------------
    cache_layout: str = "dense"       # dense (contiguous (B, S) slabs) | paged
                                      # (block-table pools, CoW prompt sharing)
    kv_block_size: int = 32           # paged: KV slots per physical block

    # -- MLA (deepseek-v3) ---------------------------------------------------
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # -- MoE -----------------------------------------------------------------
    num_experts: int = 0              # 0 = dense FFN everywhere
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0       # deepseek-v3: 1 shared expert
    moe_d_ff: int = 0                 # expert hidden dim (defaults to d_ff)
    first_dense_layers: int = 0       # deepseek-v3: first 3 layers dense FFN
    moe_every: int = 1                # jamba: MoE on every 2nd layer
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    router_z_coef: float = 1e-4
    moe_impl: str = "dense"           # dense (exact) | dispatch (GShard einsum) | sort (argsort gather/scatter)
    moe_groups: int = 0               # dispatch groups (0 = one per sequence)

    # -- hybrid / SSM layout -------------------------------------------------
    block_kind: str = ATTN            # default block type for all layers
    attn_period: int = 0              # jamba: attention once per `period` layers
    attn_offset: int = 0              # position of the attn layer in the period

    # -- mamba ---------------------------------------------------------------
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    mamba_dt_rank: int = 0            # 0 -> ceil(d_model/16)
    scan_chunk: int = 64              # recurrent-scan remat chunk (mamba/rwkv)

    # -- rwkv6 ---------------------------------------------------------------
    rwkv_head_dim: int = 64
    rwkv_lora_rank: int = 32

    # -- encoder-decoder (whisper) --------------------------------------------
    encoder_layers: int = 0
    encoder_frames: int = 0           # stubbed frontend output length
    cross_attention: bool = False

    # -- modality frontend stub ------------------------------------------------
    frontend: str = ""                # ''|'audio'|'vision'
    num_prefix_embeddings: int = 0    # vision patch embeddings prepended

    # -- extras ----------------------------------------------------------------
    tie_embeddings: bool = False
    mtp: bool = False                 # deepseek-v3 multi-token prediction head
    logit_softcap: float = 0.0

    # -- numerics ----------------------------------------------------------------
    dtype: str = "float32"            # activation dtype
    param_dtype: str = "float32"
    remat: str = "none"               # none|full|dots  (activation ckpt policy)

    # ------------------------------------------------------------------ utils
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.num_heads:
            return self.d_model // self.num_heads
        return 0

    @property
    def mamba_d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    @property
    def resolved_dt_rank(self) -> int:
        return self.mamba_dt_rank or -(-self.d_model // 16)

    @property
    def rwkv_num_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    @property
    def resolved_moe_d_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    def block_kind_for_layer(self, i: int) -> str:
        """Which block type layer ``i`` uses (jamba interleave etc.)."""
        if self.attn_period > 0:
            return ATTN if (i % self.attn_period) == self.attn_offset else self.block_kind
        return self.block_kind

    def is_moe_layer(self, i: int) -> bool:
        if self.num_experts <= 0:
            return False
        if i < self.first_dense_layers:
            return False
        return ((i - self.first_dense_layers) % self.moe_every) == 0

    def layer_plan(self) -> Tuple[Tuple[str, bool], ...]:
        """Per-layer (block_kind, is_moe) tuples for the decoder trunk."""
        return tuple(
            (self.block_kind_for_layer(i), self.is_moe_layer(i))
            for i in range(self.num_layers)
        )

    @property
    def has_decode_path(self) -> bool:
        return True  # all assigned archs have a decoder

    @property
    def subquadratic(self) -> bool:
        """True when a 500k-token decode is feasible (SSM / hybrid / SWA)."""
        plan = self.layer_plan()
        for kind, _ in plan:
            if kind == ATTN and self.sliding_window == 0 and self.attn_period == 0:
                return False
        # hybrids with a few full-attention layers qualify (KV is seq-sharded)
        return True

    def validate(self) -> None:
        assert self.block_kind in VALID_BLOCKS, self.block_kind
        assert self.decode_impl in ("auto", "naive", "blocked", "pallas",
                                    "interpret"), self.decode_impl
        assert self.cache_layout in ("dense", "paged"), self.cache_layout
        assert self.kv_block_size > 0, self.kv_block_size
        if self.num_heads:
            assert self.num_heads % max(self.num_kv_heads, 1) == 0, (
                f"{self.name}: num_heads {self.num_heads} not divisible by "
                f"kv heads {self.num_kv_heads}")
        if self.attention_kind == "mla":
            assert self.kv_lora_rank > 0 and self.qk_rope_head_dim > 0
        if self.num_experts:
            assert 0 < self.num_experts_per_tok <= self.num_experts
        if self.cross_attention:
            assert self.encoder_layers > 0

    def reduced(self, **overrides) -> "ModelConfig":
        """Smoke-test variant of the same family: tiny but shape-faithful."""
        changes = dict(
            name=self.name + "-smoke",
            num_layers=min(self.num_layers, 2),
            d_model=min(self.d_model, 256),
            vocab_size=min(self.vocab_size, 512),
            max_seq_len=min(self.max_seq_len, 256),
            dtype="float32", param_dtype="float32",
            moe_impl="dense", remat="none",
        )
        if self.num_heads:
            heads = min(self.num_heads, 4)
            kv = min(self.num_kv_heads, heads)
            while heads % kv:
                kv -= 1
            changes.update(num_heads=heads, num_kv_heads=kv, head_dim=0)
        changes["d_ff"] = min(self.d_ff, 512)
        if self.num_experts:
            e = min(self.num_experts, 4)
            changes.update(
                num_experts=e,
                num_experts_per_tok=min(self.num_experts_per_tok, 2, e),
                moe_d_ff=min(self.resolved_moe_d_ff, 256),
                first_dense_layers=min(self.first_dense_layers, 1),
            )
        if self.attention_kind == "mla":
            changes.update(
                q_lora_rank=min(self.q_lora_rank, 64) or 0,
                kv_lora_rank=min(self.kv_lora_rank, 64),
                qk_nope_head_dim=min(self.qk_nope_head_dim, 32),
                qk_rope_head_dim=min(self.qk_rope_head_dim, 16),
                v_head_dim=min(self.v_head_dim, 32),
            )
        if self.block_kind == RWKV or self.arch_type == "ssm":
            changes["rwkv_head_dim"] = min(self.rwkv_head_dim, 32)
            changes["d_model"] = 128  # divisible by rwkv head dim
        if self.attn_period:
            changes["num_layers"] = self.attn_period  # keep one full period
            changes["attn_offset"] = min(self.attn_offset, self.attn_period - 1)
        if self.encoder_layers:
            changes.update(encoder_layers=min(self.encoder_layers, 2),
                           encoder_frames=min(self.encoder_frames or 64, 64))
        if self.sliding_window:
            changes["sliding_window"] = min(self.sliding_window, 64)
        if self.num_prefix_embeddings:
            changes["num_prefix_embeddings"] = min(self.num_prefix_embeddings, 16)
        changes.update(overrides)
        cfg = dataclasses.replace(self, **changes)
        cfg.validate()
        return cfg

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)
