"""Primitive layers: inits, norms, dense, rotary embeddings, activations.

Parameters are plain nested dicts of ``jnp.ndarray`` (pytrees).  Sharding is
applied externally by path-based partition rules
(:mod:`repro.distributed.sharding`), so inits stay mesh-agnostic.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

# --------------------------------------------------------------------------- init


def dense_init(key, in_dim: int, out_dim: int, dtype=jnp.float32, scale: float | None = None):
    """Truncated-normal fan-in init (matches common LLM practice)."""
    scale = scale if scale is not None else 1.0 / math.sqrt(in_dim)
    return (jax.random.truncated_normal(key, -2.0, 2.0, (in_dim, out_dim)) * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, dim)) * 0.02).astype(dtype)


def make_dense(key, in_dim: int, out_dim: int, bias: bool = False, dtype=jnp.float32,
               scale: float | None = None):
    p = {"kernel": dense_init(key, in_dim, out_dim, dtype, scale)}
    if bias:
        p["bias"] = jnp.zeros((out_dim,), dtype)
    return p


def apply_dense(p, x):
    y = x @ p["kernel"].astype(x.dtype)
    if "bias" in p:
        y = y + p["bias"].astype(x.dtype)
    return y


# --------------------------------------------------------------------------- norms


def make_rmsnorm(dim: int, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype)}


def apply_rmsnorm(p, x, eps: float = 1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


def make_layernorm(dim: int, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def apply_layernorm(p, x, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(dt)


# --------------------------------------------------------------------------- act


def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


# --------------------------------------------------------------------------- rope


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding.

    x: (B, H, T, D) with even D; positions: (B, T) int32 (may contain -1 for
    padding rows — rotation there is irrelevant because those positions are
    masked out of attention).
    """
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                       # (d/2,)
    ang = positions.astype(jnp.float32)[:, None, :, None] * freqs  # (B,1,T,d/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------- misc


def softcap(logits: jnp.ndarray, cap: float) -> jnp.ndarray:
    if not cap:
        return logits
    return cap * jnp.tanh(logits / cap)


def split_keys(key, n: int):
    return list(jax.random.split(key, n))
