"""Mamba (S6) block, as interleaved inside Jamba.

Training / prefill use a `lax.scan` over time with masked updates so that
left-padded positions leave the SSM state untouched (dt is forced to zero and
conv inputs are zeroed at invalid positions).  Decode keeps a constant-size
cache: the last ``d_conv-1`` conv inputs and the (d_inner, d_state) SSM state.

TPU adaptation: the recurrence is a sequential scan (time-major) whose state
lives in registers/VMEM; there is no CUDA-style parallel selective-scan here —
on TPU the sequential scan with fused elementwise updates is the idiomatic
form (see also kernels/rwkv6_wkv for the Pallas treatment of this pattern).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import apply_dense, apply_rmsnorm, make_dense, make_rmsnorm, split_keys


def make_mamba(key, cfg: ModelConfig, dtype):
    d, di, ds = cfg.d_model, cfg.mamba_d_inner, cfg.mamba_d_state
    dtr, dc = cfg.resolved_dt_rank, cfg.mamba_d_conv
    ks = split_keys(key, 6)
    p = {
        "in_proj": make_dense(ks[0], d, 2 * di, False, dtype),
        "conv_w": (jax.random.normal(ks[1], (dc, di)) / math.sqrt(dc)).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": make_dense(ks[2], di, dtr + 2 * ds, False, dtype),
        "dt_proj": make_dense(ks[3], dtr, di, True, dtype),
        "A_log": jnp.log(jnp.broadcast_to(jnp.arange(1, ds + 1, dtype=jnp.float32),
                                          (di, ds))).astype(dtype),
        "D": jnp.ones((di,), dtype),
        "out_proj": make_dense(ks[4], di, d, False, dtype, scale=1.0 / math.sqrt(di)),
        # Jamba normalises dt/B/C before the scan.
        "dt_norm": make_rmsnorm(dtr, dtype),
        "b_norm": make_rmsnorm(ds, dtype),
        "c_norm": make_rmsnorm(ds, dtype),
    }
    return p


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype):
    di, ds, dc = cfg.mamba_d_inner, cfg.mamba_d_state, cfg.mamba_d_conv
    return {
        "conv": jnp.zeros((batch, dc - 1, di), dtype),
        "ssm": jnp.zeros((batch, di, ds), jnp.float32),
    }


def _ssm_inputs(p, cfg: ModelConfig, xc, valid):
    """Shared projection math.  xc: post-conv activations (..., di)."""
    dtr, ds = cfg.resolved_dt_rank, cfg.mamba_d_state
    proj = apply_dense(p["x_proj"], xc)
    dt, Bc, Cc = jnp.split(proj, [dtr, dtr + ds], axis=-1)
    dt = apply_rmsnorm(p["dt_norm"], dt, cfg.norm_eps)
    Bc = apply_rmsnorm(p["b_norm"], Bc, cfg.norm_eps).astype(jnp.float32)
    Cc = apply_rmsnorm(p["c_norm"], Cc, cfg.norm_eps).astype(jnp.float32)
    dt = jax.nn.softplus(apply_dense(p["dt_proj"], dt).astype(jnp.float32))
    dt = dt * valid[..., None].astype(jnp.float32)     # pads: no state update
    return dt, Bc, Cc


def apply_mamba(p, cfg: ModelConfig, x, positions, *, cache=None):
    """x: (B, T, d); positions: (B, T) with -1 for padding.

    Returns (y, new_cache) — new_cache is None unless ``cache`` was given,
    in which case T must be 1 (decode) or the cache is rebuilt from the full
    sequence (prefill-with-cache).
    """
    B, T, d = x.shape
    di, ds, dc = cfg.mamba_d_inner, cfg.mamba_d_state, cfg.mamba_d_conv
    valid = positions >= 0

    xz = apply_dense(p["in_proj"], x)
    xin, z = jnp.split(xz, 2, axis=-1)
    xin = xin * valid[..., None].astype(xin.dtype)

    # causal depthwise conv
    if cache is not None and T == 1:
        hist = jnp.concatenate([cache["conv"].astype(xin.dtype), xin], axis=1)  # (B,dc,di)
        xc = jnp.einsum("bcd,cd->bd", hist, p["conv_w"].astype(xin.dtype))[:, None]
        new_conv = hist[:, 1:]
    else:
        pad = jnp.zeros((B, dc - 1, di), xin.dtype)
        hist = jnp.concatenate([pad, xin], axis=1)              # (B, T+dc-1, di)
        windows = jnp.stack([hist[:, i:i + T, :] for i in range(dc)], axis=-1)
        xc = jnp.einsum("btdc,cd->btd", windows, p["conv_w"].astype(xin.dtype))
        new_conv = hist[:, T:] if dc > 1 else jnp.zeros((B, 0, di), xin.dtype)
    xc = jax.nn.silu(xc + p["conv_b"].astype(xc.dtype))

    dt, Bc, Cc = _ssm_inputs(p, cfg, xc, valid)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                  # (di, ds)
    u = xc.astype(jnp.float32)

    s0 = cache["ssm"] if cache is not None else jnp.zeros((B, di, ds), jnp.float32)

    def step(s, inp):
        # discretise per step: dA_t (B,di,ds) never materialises over T
        dt_t, B_t, C_t, u_t = inp
        dA_t = jnp.exp(dt_t[..., None] * A)
        s = dA_t * s + (dt_t * u_t)[..., None] * B_t[..., None, :]
        y = jnp.einsum("bds,bs->bd", s, C_t)
        return s, y

    def tmajor(t):
        return jnp.moveaxis(t, 1, 0)

    xs = (tmajor(dt), tmajor(Bc), tmajor(Cc), tmajor(u))
    chunk = min(cfg.scan_chunk, T)
    if T > chunk and T % chunk == 0:
        # chunked + rematerialised: only chunk-boundary states are saved for
        # the backward pass (the standard memory fix for selective scans —
        # without it training residuals are T x (B, di, ds)).
        nch = T // chunk

        @jax.checkpoint
        def chunk_body(s, xs_c):
            return jax.lax.scan(step, s, xs_c)

        xs_c = jax.tree.map(lambda a: a.reshape(nch, chunk, *a.shape[1:]), xs)
        s_final, ys = jax.lax.scan(chunk_body, s0, xs_c)
        ys = ys.reshape(T, *ys.shape[2:])
    else:
        s_final, ys = jax.lax.scan(step, s0, xs)
    y = jnp.moveaxis(ys, 0, 1)                                     # (B,T,di)
    y = y + u * p["D"].astype(jnp.float32)
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = apply_dense(p["out_proj"], y)

    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype), "ssm": s_final}
    return out, new_cache
