"""Top-level language model: embeddings, trunk, head, optional encoder
(whisper), optional MTP head (deepseek-v3), modality-frontend hooks.

Three entry points (all pure functions over a params pytree):

``forward``      training / scoring: full-sequence logits, no cache.
``prefill``      builds decode caches from a (left-padded) prompt.
``decode_step``  one token against the caches.

Frontends (audio frames / vision patches) are STUBS per the assignment: the
engine supplies precomputed embeddings of shape (B, P, d_model); here they are
simply placed in front of the token embeddings (vision) or consumed by the
encoder (audio).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .blocks import (apply_trunk, init_trunk_cache, make_trunk,
                     signature_runs)
from .config import ModelConfig
from .layers import (apply_dense, apply_rmsnorm, embed_init, make_dense,
                     make_rmsnorm, softcap, split_keys)
from .moe import apply_ffn


def _dt(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


# Forward-pass op counters (host-side).  Incremented at python level, so under
# jit they count *traces*; wrap a region in ``jax.disable_jit()`` to count the
# actual forwards executed — that is how the one-pass SPEC-RL benchmark/tests
# assert "prompt ⊕ accepted prefix is forwarded exactly once per step".
OP_COUNTS = {"forward": 0, "prefill": 0, "decode_step": 0}


def reset_op_counts() -> None:
    for k in OP_COUNTS:
        OP_COUNTS[k] = 0


def init_lm(key, cfg: ModelConfig) -> Dict[str, Any]:
    cfg.validate()
    dtype = _dt(cfg)
    ks = split_keys(key, 8)
    params: Dict[str, Any] = {
        "embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model, dtype),
        "trunk": make_trunk(ks[1], cfg, dtype),
        "final_norm": make_rmsnorm(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = make_dense(ks[2], cfg.d_model, cfg.vocab_size,
                                       False, dtype)
    if cfg.pos_embed == "learned":
        params["pos_table"] = embed_init(ks[3], cfg.max_seq_len, cfg.d_model, dtype)
    if cfg.encoder_layers:
        enc_cfg = cfg.replace(num_layers=cfg.encoder_layers, cross_attention=False,
                              num_experts=0, block_kind="attn", attn_period=0)
        params["encoder"] = {
            "trunk": make_trunk(ks[4], enc_cfg, dtype),
            "final_norm": make_rmsnorm(cfg.d_model, dtype),
        }
    if cfg.mtp:
        from .blocks import make_block
        params["mtp"] = {
            "proj": make_dense(ks[5], 2 * cfg.d_model, cfg.d_model, False, dtype),
            "block": make_block(ks[6], cfg, ("attn", False, False), dtype),
            "norm": make_rmsnorm(cfg.d_model, dtype),
        }
    return params


def count_params(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


def _embed(params, cfg: ModelConfig, tokens, positions):
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    if cfg.pos_embed == "learned":
        pos = jnp.clip(positions, 0, cfg.max_seq_len - 1)
        x = x + params["pos_table"][pos].astype(x.dtype)
    valid = (positions >= 0)[..., None]
    return jnp.where(valid, x, 0.0)


def _logits(params, cfg: ModelConfig, x):
    if cfg.tie_embeddings:
        logits = x @ params["embed"].astype(x.dtype).T
    else:
        logits = apply_dense(params["lm_head"], x)
    return softcap(logits.astype(jnp.float32), cfg.logit_softcap)


def encode(params, cfg: ModelConfig, frames) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Whisper-style encoder over stub frame embeddings (B, F, d_model).

    Returns (encoder_out, encoder_positions)."""
    enc_cfg = cfg.replace(num_layers=cfg.encoder_layers, cross_attention=False,
                          num_experts=0, block_kind="attn", attn_period=0)
    B, F, _ = frames.shape
    pos = jnp.broadcast_to(jnp.arange(F, dtype=jnp.int32), (B, F))
    x, _, _ = apply_trunk(params["encoder"]["trunk"], enc_cfg,
                          frames.astype(jnp.dtype(cfg.dtype)), pos, causal=False)
    x = apply_rmsnorm(params["encoder"]["final_norm"], x, cfg.norm_eps)
    return x, pos


def forward(params, cfg: ModelConfig, tokens, positions, *,
            encoder_out=None, encoder_positions=None, prefix_embeds=None,
            use_pallas: bool = False, return_hidden: bool = False,
            return_mtp: bool = False, compute_logits: bool = True):
    """Full-sequence teacher-forced forward.

    tokens: (B, T) int32; positions: (B, T) with -1 on padding.
    prefix_embeds: optional (B, P, d_model) — vision patches; caller's
    positions must already cover P + T (pass positions for the FULL sequence).
    Returns (logits over token slots only, aux dict).
    """
    OP_COUNTS["forward"] += 1
    x = _embed(params, cfg, tokens, positions if prefix_embeds is None
               else positions[:, prefix_embeds.shape[1]:])
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    x, _, aux = apply_trunk(params["trunk"], cfg, x, positions,
                            encoder_out=encoder_out,
                            encoder_positions=encoder_positions,
                            use_pallas=use_pallas)
    x = apply_rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if prefix_embeds is not None:
        x = x[:, prefix_embeds.shape[1]:]
    logits = _logits(params, cfg, x) if compute_logits else None
    if return_hidden:
        aux["hidden"] = x
    if cfg.mtp and return_mtp:
        aux["mtp_logits"] = _mtp_logits(params, cfg, x, tokens, positions if
                                        prefix_embeds is None else
                                        positions[:, prefix_embeds.shape[1]:])
    return logits, aux


def _mtp_logits(params, cfg: ModelConfig, hidden, tokens, positions):
    """DeepSeek-V3 multi-token prediction: predict t+2 from (h_t, emb_{t+1})."""
    from .blocks import apply_block
    emb_next = jnp.concatenate(
        [params["embed"][tokens[:, 1:]],
         jnp.zeros_like(params["embed"][tokens[:, :1]])], axis=1).astype(hidden.dtype)
    h = apply_dense(params["mtp"]["proj"],
                    jnp.concatenate([apply_rmsnorm(params["mtp"]["norm"], hidden,
                                                   cfg.norm_eps), emb_next], axis=-1))
    h, _, _ = apply_block(params["mtp"]["block"], cfg, ("attn", False, False),
                          h, positions)
    return _logits(params, cfg, h)


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    return init_trunk_cache(cfg, batch, max_len, jnp.dtype(cfg.dtype))


def _paged_run_gather(sc, impl: str = "auto"):
    """Dense logical K/V view of one paged cache run.

    sc: run dict with pools (run, NB, Hkv, bs, D) / (run, NB, bs, r) and
    ``table`` (run, B, nb).  Returns {name: (run, B[, H], S, D)} for the
    K/V leaves with S = the logical (``pos``) width — exactly the buffers a
    dense cache would hold, which every dense cache op expects.  Routed
    through the ``paged_gather`` kernel op with heads folded into the block
    rows (the same flattening cache_roll uses)."""
    from repro.kernels.cache_gather.ops import paged_gather
    table = sc["table"]
    run_len, B, nb = table.shape
    S_log = sc["pos"].shape[-1]
    out = {}
    for name in ("k", "v", "ckv", "krope"):
        if name not in sc:
            continue
        pool = sc[name]
        NB = pool.shape[1]
        bs, D = pool.shape[-2], pool.shape[-1]
        r0 = jnp.arange(run_len, dtype=jnp.int32)[:, None, None]
        tab = (r0 * NB + table.astype(jnp.int32)).reshape(run_len * B, nb)
        if pool.ndim == 5:                       # GQA: (run, NB, Hkv, bs, D)
            Hkv = pool.shape[2]
            g = paged_gather(pool.reshape(run_len * NB, Hkv * bs, D), tab,
                             impl=impl)
            g = (g.reshape(run_len, B, nb, Hkv, bs, D)
                 .transpose(0, 1, 3, 2, 4, 5)
                 .reshape(run_len, B, Hkv, nb * bs, D)[..., :S_log, :])
        else:                                    # MLA: (run, NB, bs, r)
            g = paged_gather(pool.reshape(run_len * NB, bs, D), tab,
                             impl=impl)
            g = g.reshape(run_len, B, nb * bs, D)[..., :S_log, :]
        out[name] = g
    return out


def _pad_to_blocks(buf, nb: int, bs: int):
    """Zero-pad a dense logical buffer (..., S, D) to the block-rounded
    width nb*bs so it cuts into whole blocks for re-paging."""
    S = buf.shape[-2]
    if S == nb * bs:
        return buf
    pad = [(0, 0)] * buf.ndim
    pad[-2] = (0, nb * bs - S)
    return jnp.pad(buf, pad)


def supports_cache_realign(cfg: ModelConfig) -> bool:
    """Cache compaction needs every trunk layer to hold per-slot KV state.

    Recurrent blocks (mamba / rwkv) carry a single running state that cannot
    be rewound past rejected draft tokens, so they take the two-pass path."""
    from .config import ATTN
    return all(kind == ATTN for kind, _ in cfg.layer_plan())


def _roll_rows(buf, shift, impl):
    """Right-rotate ``buf`` (..., S, D) along axis -2, per-batch shift.

    buf: (run, B, S, D) or (run, B, H, S, D); shift: (B,) int32."""
    from repro.kernels.cache_gather.ops import cache_roll
    lead = buf.shape[:-2]                        # (run, B[, H])
    reps = 1
    for d in lead:
        reps *= d
    per_b = reps // (lead[0] * lead[1])          # heads folded after batch
    shift_r = jnp.tile(jnp.repeat(shift.astype(jnp.int32), per_b), lead[0])
    flat = buf.reshape((reps,) + buf.shape[-2:])
    return cache_roll(flat, shift_r, impl=impl).reshape(buf.shape)


def realign_decode_cache(cfg: ModelConfig, caches, shift, valid_len,
                         width: int, *, impl: str = "auto", mesh=None):
    """Compact verify-prefill caches to the left-aligned decode layout.

    After ``prefill`` over [left-padded prompt | right-padded draft] of width
    ``width``, row b's accepted context (p_len + n = ``valid_len[b]`` tokens)
    occupies the contiguous slot range [P - p_len, P + n).  Rotating the
    sequence axis right by ``shift[b] = width - (P + n[b])`` lands it at
    [width - valid_len, width) — exactly the layout ``prefill`` over the
    left-aligned tokens would have produced — and slot positions are
    rewritten in closed form (slots outside the valid range become -1, so
    position-masked attention ignores whatever K/V the rotation wrapped in).

    caches: trunk cache list (attention-only, see supports_cache_realign);
    shift / valid_len: (B,) int32; width: python int (the prefilled width).
    Returns the realigned cache pytree, ready for ``resume_from_cache`` with
    write_offset = width.

    Under a ``mesh`` the per-buffer roll runs inside a shard_map boundary
    over the batch (data) axis — each device rolls its local cache rows with
    a static per-shard shape — and the output is constrained back to the
    decode-cache layout (DESIGN.md §8).
    """
    assert supports_cache_realign(cfg), "realign needs attention-only trunks"
    roll = _roll_rows
    if mesh is not None:
        from repro.distributed.shard_wrap import (batch_axis_name,
                                                  batch_shardable,
                                                  shard_map_call)
        from jax.sharding import PartitionSpec as P

        def roll(buf, shift_, impl_):
            if not batch_shardable(mesh, buf.shape[1]):
                return _roll_rows(buf, shift_, impl_)
            d = batch_axis_name(mesh)
            bspec = P(None, d, *([None] * (buf.ndim - 2)))
            return shard_map_call(
                mesh, functools.partial(_roll_rows, impl=impl_),
                (bspec, P(d)), bspec, buf, shift_)

    new_caches = []
    for run in caches:
        sc = run["self"]
        S = sc["pos"].shape[-1]
        run_len, B = sc["pos"].shape[0], sc["pos"].shape[1]
        j = jnp.arange(S, dtype=jnp.int32)[None, :]
        start = (width - valid_len.astype(jnp.int32))[:, None]
        pos_row = jnp.where((j >= start) & (j < width), j - start, -1)
        new_sc = {"pos": jnp.broadcast_to(pos_row[None], (run_len, B, S))}
        if "table" in sc:
            # paged compaction (§13): gather pools to the dense logical
            # view, roll it exactly like the dense path, re-page through
            # the unchanged tables.  Only exclusively-owned tables reach
            # this path (the one-pass rollout's identity stripes) — CoW
            # sharing exists only behind the serving engine, whose
            # admission compacts densely before paging in.
            from repro.kernels.cache_slot_write.ops import paged_slot_write
            nb = sc["table"].shape[-1]
            bs = (sc["k"] if "k" in sc else sc["ckv"]).shape[-2]
            dense = _paged_run_gather(sc, impl)
            for name, buf in dense.items():
                rolled = _pad_to_blocks(roll(buf, shift, impl), nb, bs)
                new_sc[name] = paged_slot_write(sc[name], rolled,
                                                sc["table"], impl=impl)
            new_sc["table"] = sc["table"]
        else:
            for name in ("k", "v", "ckv", "krope"):
                if name in sc:
                    new_sc[name] = roll(sc[name], shift, impl)
        new_caches.append({"self": new_sc})
    if mesh is not None:
        from repro.distributed.mesh import constrain_caches
        new_caches = constrain_caches(cfg, new_caches, mesh)
    return new_caches


def supports_drafting(cfg: ModelConfig, model_kwargs=None) -> bool:
    """Whether the §9 draft-verify decode loop applies.

    A rejected draft token must leave no trace: attention trunks discard it
    by invalidating its cache slot (pos = -1) and overwriting on the next
    block, but recurrent blocks (mamba / rwkv) fold every forwarded token
    into a running state that cannot be rewound.  Modality extras are not
    threaded through the drafted host loop, so the gate matches slot
    serving's."""
    return supports_slot_serving(cfg, model_kwargs)


def pad_cache(cfg: ModelConfig, caches, extra: int):
    """Append ``extra`` empty slots to every cache buffer's sequence axis.

    The drafted decode loop writes a static (k + 1)-token block at the
    per-row write offset each macro-step, so its last step can touch up to
    ``draft_k`` slots beyond the final kept token; without headroom the
    dynamic_update_slice would clamp backwards onto live slots.  New slots
    carry pos == -1 (empty) and zero K/V — exactly what ``init_cache``
    would have allocated at the larger width.
    """
    if extra <= 0:
        return caches
    assert supports_cache_realign(cfg), "pad_cache needs attention trunks"
    new_caches = []
    for run in caches:
        sc = run["self"]
        if "table" in sc:
            new_caches.append({"self": _pad_paged_run(sc, extra)})
            continue
        new_sc = {"pos": jnp.pad(sc["pos"], ((0, 0), (0, 0), (0, extra)),
                                 constant_values=-1)}
        for name in ("k", "v", "ckv", "krope"):
            if name in sc:
                buf = sc[name]
                pad = [(0, 0)] * buf.ndim
                pad[-2] = (0, extra)
                new_sc[name] = jnp.pad(buf, pad)
        new_caches.append({"self": new_sc})
    return new_caches


def _pad_paged_run(sc, extra: int):
    """Paged ``pad_cache``: grow every row's logical width by ``extra``.

    The logical (``pos``) width grows by exactly ``extra`` — matching the
    dense path bit-for-bit — while the physical pool only moves in whole
    blocks: the block-rounding slack is consumed first, and any remainder
    appends fresh zero blocks to the pool tail and extends each table row
    with an identity stripe of them (exclusively owned — padding is only
    used by the fixed-batch drafted loop, never on CoW-shared serving
    rows)."""
    table = sc["table"]
    run_len, B, nb = table.shape
    pos = sc["pos"]
    S = pos.shape[-1]
    ref = sc["k"] if "k" in sc else sc["ckv"]
    bs = ref.shape[-2]
    nb_new = -(-(S + extra) // bs)
    add = nb_new - nb
    new_sc = {"pos": jnp.pad(pos, ((0, 0), (0, 0), (0, extra)),
                             constant_values=-1)}
    if add == 0:
        for name in ("k", "v", "ckv", "krope"):
            if name in sc:
                new_sc[name] = sc[name]
        new_sc["table"] = table
        return new_sc
    NB = ref.shape[1]
    fresh = (NB + jnp.arange(B * add, dtype=jnp.int32).reshape(B, add))
    new_sc["table"] = jnp.concatenate(
        [table, jnp.broadcast_to(fresh[None], (run_len, B, add))], axis=-1)
    for name in ("k", "v", "ckv", "krope"):
        if name in sc:
            buf = sc[name]
            pad = [(0, 0)] * buf.ndim
            pad[1] = (0, B * add)
            new_sc[name] = jnp.pad(buf, pad)
    return new_sc


def supports_slot_serving(cfg: ModelConfig, model_kwargs=None) -> bool:
    """Whether the continuous-batching slot engine (DESIGN.md §6) applies.

    Needs per-slot KV state (attention-only trunk, same constraint as cache
    realignment) and none of the modality extras the persistent decode batch
    does not carry (encoder memory / vision prefix)."""
    kw = model_kwargs or {}
    return (supports_cache_realign(cfg)
            and not cfg.encoder_layers
            and not cfg.num_prefix_embeddings
            and kw.get("encoder_out") is None
            and kw.get("prefix_embeds") is None)


def write_cache_slots(cfg: ModelConfig, dst_caches, src_caches, slots, *,
                      impl: str = "auto", mesh=None):
    """Admit prefilled rows into the persistent serving batch, in place.

    dst_caches: trunk caches over B slots; src_caches: same structure over R
    admitted rows (same sequence length); slots: (R,) int32 destination slot
    per source row.  Every leaf's row ``slots[i]`` along the batch axis is
    replaced by source row ``i`` via the cache_slot_write batched scatter
    (Pallas on TPU) on the flattened (run, batch[, head]) rows — the same
    layout cache_gather rolls.  Duplicate slots must carry identical rows
    (the admission path pads partial groups by duplicating a real row).

    pos arrays ride a plain jnp scatter (they are tiny and int32).
    Returns the updated cache pytree; untouched slots are bit-identical.

    Under a ``mesh`` with a KV-head-sharded cache the scatter runs inside a
    shard_map boundary over the head axis: slot indices are *batch* indices
    and therefore replicated, so each model shard rewrites its local head
    slice independently (DESIGN.md §8).
    """
    from repro.kernels.cache_slot_write.ops import cache_slot_write
    assert supports_cache_realign(cfg), "slot serving needs attention trunks"
    slots = slots.astype(jnp.int32)
    if any("table" in run["self"] for run in dst_caches):
        return _write_cache_slots_paged(dst_caches, src_caches, slots,
                                        impl=impl)

    def scatter(d, s, slots_):
        run_len, B = d.shape[0], d.shape[1]
        R = s.shape[1]
        per = 1                                      # heads folded after batch
        for sz in d.shape[2:-2]:
            per *= sz
        r0 = jnp.arange(run_len, dtype=jnp.int32)[:, None, None]
        h = jnp.arange(per, dtype=jnp.int32)[None, None, :]
        rows = ((r0 * B + slots_[None, :, None]) * per + h).reshape(-1)
        flat = cache_slot_write(
            d.reshape((run_len * B * per,) + d.shape[-2:]),
            s.reshape((run_len * R * per,) + s.shape[-2:]),
            rows, impl=impl)
        return flat.reshape(d.shape)

    new_caches = []
    for dst_run, src_run in zip(dst_caches, src_caches):
        dsc, ssc = dst_run["self"], src_run["self"]
        new_sc = {"pos": dsc["pos"].at[:, slots].set(ssc["pos"])}
        for name in ("k", "v", "ckv", "krope"):
            if name not in dsc:
                continue
            d, s = dsc[name], ssc[name]
            h_ax = None
            if mesh is not None and d.ndim == 5:
                from repro.distributed.shard_wrap import model_axis
                h_ax = model_axis(mesh, d.shape[2])
            if h_ax is not None:
                from repro.distributed.shard_wrap import shard_map_call
                from jax.sharding import PartitionSpec as P
                hspec = P(None, None, h_ax, None, None)
                new_sc[name] = shard_map_call(
                    mesh, scatter, (hspec, hspec, P()), hspec, d, s, slots)
            else:
                new_sc[name] = scatter(d, s, slots)
        new_caches.append({"self": new_sc})
    return new_caches


def _write_cache_slots_paged(dst_caches, src_caches, slots, *,
                             impl: str = "auto"):
    """Admit dense prefilled rows into a *paged* persistent cache (§13).

    The admission forward runs on small throwaway dense caches (identical
    device programs to the dense engine — that is what makes paged serving
    trivially token-identical); this scatter re-pages each admitted row
    into the blocks its table references via ``paged_slot_write``.  The
    addressed rows must be exclusively owned — the paged engine admits
    leaders with freshly allocated full-width tables and never routes
    CoW-sharing followers through here.

    A dense source narrower than the paged logical width is padded with
    empty slots (pos == -1); K/V is zero-padded to the block-rounded
    physical width so the scatter lands on whole blocks.
    """
    from repro.kernels.cache_slot_write.ops import paged_slot_write
    new_caches = []
    for dst_run, src_run in zip(dst_caches, src_caches):
        dsc, ssc = dst_run["self"], src_run["self"]
        S_paged = dsc["pos"].shape[-1]
        S_src = ssc["pos"].shape[-1]
        assert S_src <= S_paged, (S_src, S_paged)
        nb = dsc["table"].shape[-1]
        bs = (dsc["k"] if "k" in dsc else dsc["ckv"]).shape[-2]
        src_pos = ssc["pos"]
        if S_src < S_paged:
            src_pos = jnp.pad(src_pos, ((0, 0), (0, 0), (0, S_paged - S_src)),
                              constant_values=-1)
        new_sc = {"pos": dsc["pos"].at[:, slots].set(src_pos),
                  "table": dsc["table"]}
        table = dsc["table"][:, slots]               # (run, R, nb)
        for name in ("k", "v", "ckv", "krope"):
            if name not in dsc:
                continue
            new_sc[name] = paged_slot_write(
                dsc[name], _pad_to_blocks(ssc[name], nb, bs), table,
                impl=impl)
        new_caches.append({"self": new_sc})
    return new_caches


def prefill(params, cfg: ModelConfig, tokens, positions, caches, *,
            encoder_out=None, encoder_positions=None, prefix_embeds=None,
            use_pallas: bool = False):
    """Run the prompt through the model, filling caches at slots [0, T).

    Returns (logits (B, T, V), new_caches)."""
    OP_COUNTS["prefill"] += 1
    x = _embed(params, cfg, tokens, positions if prefix_embeds is None
               else positions[:, prefix_embeds.shape[1]:])
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    x, caches, _ = apply_trunk(params["trunk"], cfg, x, positions,
                               caches=caches, cache_start=0,
                               encoder_out=encoder_out,
                               encoder_positions=encoder_positions,
                               use_pallas=use_pallas)
    x = apply_rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if prefix_embeds is not None:
        x = x[:, prefix_embeds.shape[1]:]
    return _logits(params, cfg, x), caches


def decode_step(params, cfg: ModelConfig, token, position, caches, cache_start, *,
                encoder_out=None, encoder_positions=None,
                use_pallas: bool = False, kv_length=None, kv_start=None,
                mesh=None):
    """One decode step over a short token block.

    token: (B, T) with small T — 1 for classic decode, k + 1 for a §9
    draft-verify block; position: (B, T) (-1 marks done rows / draft
    padding); cache_start: first slot to write — scalar int32 (lockstep
    decode) or (B,) int32 per-row slots (serving slot scheduler / drafted
    loops, where each row sits at its own decode depth).  The T tokens are
    written at slots [cache_start, cache_start + T) before attending, so
    within-block causality is ordinary position masking.
    kv_length: optional per-row live cache extent (scalar or (B,) int32);
    attention beyond it is skipped by the flash-decode kernel.  Defaults to
    ``cache_start + T`` — the just-written block ends the live range.
    Multi-token blocks MUST thread it (the decode dispatch requires it,
    models/attention._decode_shaped).
    kv_start: optional per-row first live slot; pass only when the context
    is contiguous from that slot (left-padded prompt / compacted layout,
    no vision prefix) so the kernel can also skip the dead left padding.
    mesh: optional live Mesh — decode attention then runs inside the §8
    shard_map boundary (batch over data, KV heads over model).
    Returns (logits (B, 1, V), new_caches)."""
    OP_COUNTS["decode_step"] += 1
    x = _embed(params, cfg, token, position)
    x, caches, _ = apply_trunk(params["trunk"], cfg, x, position,
                               caches=caches, cache_start=cache_start,
                               encoder_out=encoder_out,
                               encoder_positions=encoder_positions,
                               use_pallas=use_pallas, kv_length=kv_length,
                               kv_start=kv_start, mesh=mesh)
    x = apply_rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return _logits(params, cfg, x), caches
