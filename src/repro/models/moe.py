"""Feed-forward layers: dense SwiGLU FFN and mixture-of-experts.

Three MoE execution strategies:

``dense``     every expert runs on every token, outputs combined with router
              weights.  Exact (no token dropping); used for smoke tests and
              small models.
``dispatch``  GShard-style grouped one-hot dispatch einsum with a capacity
              limit.  The battle-tested TPU formulation: tokens stay sharded
              on (pod, data), experts shard on `model` (expert parallelism),
              and the dispatch einsums carry the all-to-all.  Production
              default — EXPERIMENTS.md §Perf round 4 shows why.
``sort``      argsort-by-expert gather/scatter.  FLOP-honest (no dispatch
              matmuls) but GSPMD cannot shard the scatter (it replicates the
              token buffer) — kept for single-device use and as the
              measured-and-refuted §Perf round-4 hypothesis; the TPU fix is
              megablox/ragged kernels.

Aux losses (load-balance + router z-loss) are returned to the caller and
added to the RL/pretrain objective.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import activation, apply_dense, make_dense, split_keys


# ------------------------------------------------------------------ dense FFN


def make_ffn(key, d: int, ff: int, dtype, kind: str = "swiglu"):
    ks = split_keys(key, 3)
    p = {
        "w_up": make_dense(ks[1], d, ff, False, dtype),
        "w_down": make_dense(ks[2], ff, d, False, dtype, scale=1.0 / math.sqrt(ff)),
    }
    if kind == "swiglu":
        p["w_gate"] = make_dense(ks[0], d, ff, False, dtype)
    return p


def apply_ffn(p, x, act_name: str = "silu"):
    act = activation(act_name)
    if "w_gate" in p:   # swiglu
        return apply_dense(p["w_down"],
                           act(apply_dense(p["w_gate"], x)) * apply_dense(p["w_up"], x))
    return apply_dense(p["w_down"], act(apply_dense(p["w_up"], x)))


# ------------------------------------------------------------------ MoE


def make_moe(key, cfg: ModelConfig, dtype):
    E, d, ff = cfg.num_experts, cfg.d_model, cfg.resolved_moe_d_ff
    ks = split_keys(key, 5)

    def stack(k, ins, outs, scale=None):
        keys = jax.random.split(k, E)
        return jnp.stack([make_dense(kk, ins, outs, False, dtype, scale)["kernel"]
                          for kk in keys])

    p = {
        "router": make_dense(ks[0], d, E, False, dtype),
        "w_gate": stack(ks[1], d, ff),
        "w_up": stack(ks[2], d, ff),
        "w_down": stack(ks[3], ff, d, 1.0 / math.sqrt(ff)),
    }
    if cfg.num_shared_experts:
        p["shared"] = make_ffn(ks[4], d, ff * cfg.num_shared_experts, dtype)
    return p


def _router(p, cfg: ModelConfig, xf):
    """xf: (N, d) -> (weights (N,k), idx (N,k), aux dict)."""
    logits = (xf @ p["router"]["kernel"].astype(xf.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, cfg.num_experts_per_tok)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)

    # Switch-style load-balance loss + z-loss.
    E = cfg.num_experts
    me = jnp.mean(probs, axis=0)                                   # (E,)
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)             # (N,k,E)
    ce = jnp.mean(onehot.sum(1), axis=0) / cfg.num_experts_per_tok  # frac routed
    lb = E * jnp.sum(me * ce)
    z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    aux = {"moe_lb_loss": lb, "moe_z_loss": z,
           "moe_expert_frac": ce}
    return weights, idx, aux


def _experts_batched(p, xe, act_name):
    """xe: (E, C, d) -> (E, C, d) through per-expert SwiGLU."""
    act = activation(act_name)
    h = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"].astype(xe.dtype))
    u = jnp.einsum("ecd,edf->ecf", xe, p["w_up"].astype(xe.dtype))
    return jnp.einsum("ecf,efd->ecd", act(h) * u, p["w_down"].astype(xe.dtype))


def _apply_moe_dense(p, cfg: ModelConfig, x):
    B, T, d = x.shape
    xf = x.reshape(-1, d)
    weights, idx, aux = _router(p, cfg, xf)
    act = activation(cfg.act)
    # all experts on all tokens: (E, N, d)
    h = jnp.einsum("nd,edf->enf", xf, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("nd,edf->enf", xf, p["w_up"].astype(x.dtype))
    ye = jnp.einsum("enf,efd->end", act(h) * u, p["w_down"].astype(x.dtype))
    onehot = jax.nn.one_hot(idx, cfg.num_experts, dtype=x.dtype)   # (N,k,E)
    combine = jnp.einsum("nke,nk->en", onehot, weights.astype(x.dtype))
    y = jnp.einsum("end,en->nd", ye, combine)
    return y.reshape(B, T, d), aux


def _apply_moe_dispatch(p, cfg: ModelConfig, x):
    """GShard grouped dispatch.  Groups = batch rows (tokens of one sequence)."""
    B, T, d = x.shape
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    xf = x.reshape(-1, d)
    weights, idx, aux = _router(p, cfg, xf)

    G = cfg.moe_groups or B                # default: one group per sequence
    G = min(G, B * T)
    while (B * T) % G:
        G -= 1
    n = (B * T) // G                       # tokens per group
    cap = max(1, int(math.ceil(k * n / E * cfg.capacity_factor)))
    cap = min(cap, k * n)
    idx_g = idx.reshape(G, n, k)
    w_g = weights.reshape(G, n, k).astype(x.dtype)
    x_g = xf.reshape(G, n, d)

    onehot = jax.nn.one_hot(idx_g, E, dtype=jnp.int32)             # (G,n,k,E)
    flat = onehot.reshape(G, n * k, E)
    pos = jnp.cumsum(flat, axis=1) - flat                          # rank within expert
    pos = pos.reshape(G, n, k, E)
    in_cap = pos < cap
    disp = (onehot * in_cap).astype(x.dtype)                       # keep mask
    pos_oh = jax.nn.one_hot(jnp.sum(pos * onehot, -1), cap, dtype=x.dtype)  # (G,n,k,cap)
    # dispatch tensor (G, n, k, E, cap) contracted immediately
    dispatch = jnp.einsum("gnke,gnkc->gnkec", disp, pos_oh)
    xe = jnp.einsum("gnkec,gnd->gecd", dispatch, x_g)              # (G,E,cap,d)
    ye = jax.vmap(lambda xg: _experts_batched(p, xg, cfg.act))(xe)  # (G,E,cap,d)
    combine = jnp.einsum("gnkec,gnk->gnkec", dispatch, w_g)
    y = jnp.einsum("gnkec,gecd->gnd", combine, ye)
    dropped = 1.0 - jnp.mean(jnp.sum(disp, axis=(2, 3)) > 0)
    aux["moe_drop_frac"] = dropped.astype(jnp.float32)
    return y.reshape(B, T, d), aux


def _apply_moe_sort(p, cfg: ModelConfig, x):
    """Sort-based dispatch: argsort tokens by expert, scatter into a
    (E, cap, d) buffer, batched expert matmuls, gather back.

    Unlike the GShard one-hot einsum this moves data with gather/scatter
    instead of matmuls, so HLO FLOPs ≈ active expert FLOPs (the dispatch
    einsum inflates compute by up to 10x at deepseek-v3 scale — §Perf).
    """
    B, T, d = x.shape
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    xf = x.reshape(-1, d)
    N = xf.shape[0]
    weights, idx, aux = _router(p, cfg, xf)

    cap = max(1, int(math.ceil(k * N / E * cfg.capacity_factor)))
    cap = min(cap, k * N)
    eid = idx.reshape(-1)                                  # (N*k,)
    tok = jnp.arange(N * k, dtype=jnp.int32) // k
    order = jnp.argsort(eid, stable=True)
    eid_s, tok_s = eid[order], tok[order]
    counts = jnp.bincount(eid, length=E)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(N * k, dtype=jnp.int32) - starts[eid_s]
    keep = rank < cap
    rank_c = jnp.minimum(rank, cap - 1)

    buf = jnp.zeros((E, cap, d), x.dtype)
    buf = buf.at[eid_s, rank_c].set(
        jnp.where(keep[:, None], xf[tok_s], 0.0), mode="drop")
    ye = _experts_batched(p, buf, cfg.act)                 # (E, cap, d)
    rows = ye[eid_s, rank_c] * keep[:, None].astype(x.dtype)
    w_s = weights.reshape(-1)[order].astype(x.dtype)
    y = jnp.zeros((N, d), x.dtype).at[tok_s].add(rows * w_s[:, None])
    aux["moe_drop_frac"] = (1.0 - keep.mean().astype(jnp.float32))
    return y.reshape(B, T, d), aux


def apply_moe(p, cfg: ModelConfig, x):
    if cfg.moe_impl == "dispatch":
        y, aux = _apply_moe_dispatch(p, cfg, x)
    elif cfg.moe_impl == "sort":
        y, aux = _apply_moe_sort(p, cfg, x)
    else:
        y, aux = _apply_moe_dense(p, cfg, x)
    if "shared" in p:
        y = y + apply_ffn(p["shared"], x, cfg.act)
    return y, aux
