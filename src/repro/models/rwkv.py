"""RWKV6 ("Finch") block: data-dependent decay time-mix + channel-mix.

Per head (k-dim = v-dim = head_dim), with data-dependent per-channel decay
``w_t`` and bonus ``u``::

    y_t = r_t · (S_{t-1} + diag(u) k_t v_tᵀ)
    S_t = diag(w_t) S_{t-1} + k_t v_tᵀ

Token-shift uses the RWKV6 "ddlerp": a low-rank data-dependent interpolation
between x_t and x_{t-1} per projection stream.

Padding: embeddings at invalid positions are zeroed by the trunk, and k is
masked / w forced to 1 there, so the state is untouched by pads.

The time-mix recurrence has a Pallas kernel (`repro.kernels.rwkv6_wkv`) used
when ``use_pallas`` is enabled; the jnp scan here is the reference path.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import apply_dense, make_dense, split_keys

STREAMS = ("r", "k", "v", "w", "g")


def make_rwkv_time_mix(key, cfg: ModelConfig, dtype):
    d, rank = cfg.d_model, cfg.rwkv_lora_rank
    H, hd = cfg.rwkv_num_heads, cfg.rwkv_head_dim
    ks = split_keys(key, 12)
    p = {
        "mu_base": jnp.zeros((d,), dtype),
        "mu": jnp.zeros((len(STREAMS), d), dtype),
        "lora_a": make_dense(ks[0], d, len(STREAMS) * rank, False, dtype),
        "lora_b": (jax.random.normal(ks[1], (len(STREAMS), rank, d)) * 0.01).astype(dtype),
        "wr": make_dense(ks[2], d, d, False, dtype),
        "wk": make_dense(ks[3], d, d, False, dtype),
        "wv": make_dense(ks[4], d, d, False, dtype),
        "wg": make_dense(ks[5], d, d, False, dtype),
        "wo": make_dense(ks[6], d, d, False, dtype, scale=1.0 / math.sqrt(d)),
        # decay: w = exp(-exp(w0 + lora_w(x)))
        "w0": jnp.full((d,), -6.0, dtype),
        "w_lora_a": make_dense(ks[7], d, rank, False, dtype),
        "w_lora_b": (jax.random.normal(ks[8], (rank, d)) * 0.01).astype(dtype),
        "u": (jax.random.normal(ks[9], (d,)) * 0.1).astype(dtype),
        "ln_x_scale": jnp.ones((H, hd), dtype),
        "ln_x_bias": jnp.zeros((H, hd), dtype),
    }
    return p


def make_rwkv_channel_mix(key, cfg: ModelConfig, dtype):
    d, ff = cfg.d_model, cfg.d_ff
    ks = split_keys(key, 3)
    return {
        "mu_k": jnp.full((d,), 0.5, dtype),
        "mu_r": jnp.full((d,), 0.5, dtype),
        "wk": make_dense(ks[0], d, ff, False, dtype),
        "wv": make_dense(ks[1], ff, d, False, dtype, scale=1.0 / math.sqrt(ff)),
        "wr": make_dense(ks[2], d, d, False, dtype),
    }


def init_rwkv_cache(cfg: ModelConfig, batch: int, dtype):
    H, hd = cfg.rwkv_num_heads, cfg.rwkv_head_dim
    return {
        "shift_t": jnp.zeros((batch, cfg.d_model), dtype),
        "shift_c": jnp.zeros((batch, cfg.d_model), dtype),
        "wkv": jnp.zeros((batch, H, hd, hd), jnp.float32),
    }


def _token_shift(x, x_prev_row):
    """(B,T,d) -> previous-token tensor; first slot uses x_prev_row (B,d)."""
    return jnp.concatenate([x_prev_row[:, None, :], x[:, :-1, :]], axis=1)


def _ddlerp(p, x, xprev):
    """RWKV6 data-dependent token-shift for the 5 streams."""
    xx = xprev - x
    base = x + xx * p["mu_base"].astype(x.dtype)
    lora = jnp.tanh(apply_dense(p["lora_a"], base))
    B, T, _ = x.shape
    rank = p["lora_b"].shape[1]
    lora = lora.reshape(B, T, len(STREAMS), rank)
    dmu = jnp.einsum("btsr,srd->btsd", lora, p["lora_b"].astype(x.dtype))
    mixed = []
    for i, _ in enumerate(STREAMS):
        m = p["mu"][i].astype(x.dtype) + dmu[:, :, i, :]
        mixed.append(x + xx * m)
    return mixed  # list of (B,T,d) for r,k,v,w,g


def _group_norm(p, y, eps):
    """y: (B,T,H,hd) per-head layer norm."""
    mu = jnp.mean(y, axis=-1, keepdims=True)
    var = jnp.var(y, axis=-1, keepdims=True)
    yn = (y - mu) * jax.lax.rsqrt(var + eps)
    return yn * p["ln_x_scale"].astype(y.dtype) + p["ln_x_bias"].astype(y.dtype)


def wkv_scan(r, k, v, w, u, s0, chunk: int = 64):
    """Reference jnp recurrence.

    r,k,v,w: (B, T, H, hd) float32; u: (H, hd); s0: (B, H, hd, hd).
    Returns y (B,T,H,hd), s_final.  For long T the scan is chunked with
    rematerialisation so training residuals hold only chunk-boundary states
    (T x (B,H,hd,hd) otherwise).
    """
    T = r.shape[1]

    def step(s, inp):
        r_t, k_t, v_t, w_t = inp                                  # (B,H,hd)
        kv = k_t[..., :, None] * v_t[..., None, :]                # (B,H,hd,hd)
        y = jnp.einsum("bhk,bhkv->bhv", r_t, s + u[None, :, :, None] * kv)
        s = w_t[..., :, None] * s + kv
        return s, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    if T > chunk and T % chunk == 0:
        nch = T // chunk

        @jax.checkpoint
        def chunk_body(s, xs_c):
            return jax.lax.scan(step, s, xs_c)

        xs_c = jax.tree.map(lambda a: a.reshape(nch, chunk, *a.shape[1:]), xs)
        s_final, ys = jax.lax.scan(chunk_body, s0, xs_c)
        ys = ys.reshape(T, *ys.shape[2:])
    else:
        s_final, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1), s_final


def apply_rwkv_time_mix(p, cfg: ModelConfig, x, positions, *, cache=None,
                        use_pallas: bool = False):
    B, T, d = x.shape
    H, hd = cfg.rwkv_num_heads, cfg.rwkv_head_dim
    valid = (positions >= 0)[..., None].astype(jnp.float32)

    xprev_row = cache["shift_t"].astype(x.dtype) if cache is not None else \
        jnp.zeros((B, d), x.dtype)
    xprev = _token_shift(x, xprev_row)
    xr, xk, xv, xw, xg = _ddlerp(p, x, xprev)

    r = apply_dense(p["wr"], xr).astype(jnp.float32)
    k = apply_dense(p["wk"], xk).astype(jnp.float32) * valid
    v = apply_dense(p["wv"], xv).astype(jnp.float32)
    g = jax.nn.silu(apply_dense(p["wg"], xg))

    logw = p["w0"].astype(jnp.float32) + \
        (jnp.tanh(apply_dense(p["w_lora_a"], xw)).astype(jnp.float32)
         @ p["w_lora_b"].astype(jnp.float32))
    w = jnp.exp(-jnp.exp(logw))                                    # (B,T,d) in (0,1)
    w = jnp.where(valid > 0, w, 1.0)                               # pads: no decay

    shp = (B, T, H, hd)
    r_, k_, v_, w_ = (t.reshape(shp) for t in (r, k, v, w))
    u = p["u"].astype(jnp.float32).reshape(H, hd)
    s0 = cache["wkv"] if cache is not None else jnp.zeros((B, H, hd, hd), jnp.float32)

    if use_pallas:
        from repro.kernels.rwkv6_wkv import ops as wkv_ops
        y, s_final = wkv_ops.wkv(r_, k_, v_, w_, u, s0)
    else:
        y, s_final = wkv_scan(r_, k_, v_, w_, u, s0, cfg.scan_chunk)

    y = _group_norm(p, y.astype(x.dtype), cfg.norm_eps).reshape(B, T, d)
    out = apply_dense(p["wo"], y * g)

    new_cache = None
    if cache is not None:
        new_cache = {"shift_t": x[:, -1, :].astype(cache["shift_t"].dtype),
                     "wkv": s_final}
    return out, new_cache


def apply_rwkv_channel_mix(p, cfg: ModelConfig, x, positions, *, cache=None):
    B, T, d = x.shape
    xprev_row = cache["shift_c"].astype(x.dtype) if cache is not None else \
        jnp.zeros((B, d), x.dtype)
    xprev = _token_shift(x, xprev_row)
    xx = xprev - x
    xk = x + xx * p["mu_k"].astype(x.dtype)
    xr = x + xx * p["mu_r"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(apply_dense(p["wk"], xk)))
    kv = apply_dense(p["wv"], k)
    out = jax.nn.sigmoid(apply_dense(p["wr"], xr)) * kv
    new_cache = None
    if cache is not None:
        new_cache = {"shift_c": x[:, -1, :].astype(cache["shift_c"].dtype)}
    return out, new_cache
