"""Rollout observatory: span tracing + unified metrics (DESIGN.md §11).

Components that are constructed explicitly (SlotEngine, MeshSlotServer)
take a ``tracer=`` kwarg; code deep in the loop (spec_rollout, the drafted
decode loop, the trainer) reads the process-global tracer/registry below,
which launch scripts set once via ``configure`` before building anything.
The defaults (``NULL_TRACER``, an idle registry) satisfy the zero-overhead
contract: every recording call early-returns.
"""
from .trace import NULL_TRACER, Event, Span, Tracer
from .registry import (Counter, Gauge, Histogram, MetricsRegistry, Ratio,
                       extend_summary)
from . import export  # noqa: F401  (re-exported submodule)

_TRACER: Tracer = NULL_TRACER
_REGISTRY: MetricsRegistry = MetricsRegistry()


def get_tracer() -> Tracer:
    return _TRACER


def get_registry() -> MetricsRegistry:
    return _REGISTRY


def configure(tracer: Tracer = None,
              registry: MetricsRegistry = None) -> None:
    """Install a process-global tracer and/or registry (launch scripts)."""
    global _TRACER, _REGISTRY
    if tracer is not None:
        _TRACER = tracer
    if registry is not None:
        _REGISTRY = registry


def reset() -> None:
    """Back to the inert defaults (tests)."""
    global _TRACER, _REGISTRY
    _TRACER = NULL_TRACER
    _REGISTRY = MetricsRegistry()


__all__ = ["Tracer", "Span", "Event", "NULL_TRACER",
           "MetricsRegistry", "Counter", "Gauge", "Histogram", "Ratio",
           "extend_summary", "export",
           "get_tracer", "get_registry", "configure", "reset"]
