"""Rollout observatory: span tracing + unified metrics (DESIGN.md §11).

Components that are constructed explicitly (SlotEngine, MeshSlotServer)
take a ``tracer=`` kwarg; code deep in the loop (spec_rollout, the drafted
decode loop, the trainer) reads the process-global tracer/registry below,
which launch scripts set once via ``configure`` before building anything.
The defaults (``NULL_TRACER``, an idle registry) satisfy the zero-overhead
contract: every recording call early-returns.
"""
from .trace import NULL_TRACER, Event, Span, Tracer
from .registry import (Counter, Gauge, Histogram, MetricsRegistry, Ratio,
                       extend_summary)
from .ledger import (NULL_DECISION_LOG, NULL_LEDGER, DecisionLog,
                     LedgerError, TokenLedger)
from . import export  # noqa: F401  (re-exported submodule)

_TRACER: Tracer = NULL_TRACER
_REGISTRY: MetricsRegistry = MetricsRegistry()
_LEDGER: TokenLedger = NULL_LEDGER
_DECISIONS: DecisionLog = NULL_DECISION_LOG


def get_tracer() -> Tracer:
    return _TRACER


def get_registry() -> MetricsRegistry:
    return _REGISTRY


def get_ledger() -> TokenLedger:
    return _LEDGER


def get_decision_log() -> DecisionLog:
    return _DECISIONS


def configure(tracer: Tracer = None,
              registry: MetricsRegistry = None,
              ledger: TokenLedger = None,
              decisions: DecisionLog = None) -> None:
    """Install process-global observability sinks (launch scripts)."""
    global _TRACER, _REGISTRY, _LEDGER, _DECISIONS
    if tracer is not None:
        _TRACER = tracer
    if registry is not None:
        _REGISTRY = registry
    if ledger is not None:
        _LEDGER = ledger
    if decisions is not None:
        _DECISIONS = decisions


def reset() -> None:
    """Back to the inert defaults (tests)."""
    global _TRACER, _REGISTRY, _LEDGER, _DECISIONS
    _TRACER = NULL_TRACER
    _REGISTRY = MetricsRegistry()
    _LEDGER = NULL_LEDGER
    _DECISIONS = NULL_DECISION_LOG


__all__ = ["Tracer", "Span", "Event", "NULL_TRACER",
           "MetricsRegistry", "Counter", "Gauge", "Histogram", "Ratio",
           "extend_summary", "export",
           "TokenLedger", "LedgerError", "NULL_LEDGER",
           "DecisionLog", "NULL_DECISION_LOG",
           "get_tracer", "get_registry", "get_ledger", "get_decision_log",
           "configure", "reset"]
