"""Metric alert rules + recompile sentinel + memory gauges (DESIGN.md §14).

The §11 registry records everything and judges nothing: a draft-acceptance
collapse or a steady-state recompile storm is invisible until a bench
regresses.  ``AlertManager`` closes that gap with declarative rules
evaluated over registry dumps each training step:

- ``below`` / ``above``: the metric crossed a threshold after ``warmup``
  observations (collapse detectors);
- ``trend_up`` / ``trend_down``: the metric moved monotonically-on-average
  across a sliding ``window`` by more than ``threshold`` (leak/storm
  detectors — pool exhaustion, staleness rise, recompiles).

Firing is edge-triggered: a rule raises one typed ``AlertEvent`` when its
predicate first becomes true and re-arms only after it clears, so a
persistent condition does not spam the trace.  Events land as instants on
the tracer's ``alerts`` track (visible in the Chrome timeline next to the
spans that caused them) and, optionally, route into the §10
``TrainWatchdog`` via ``note_alert`` so the degradation ladder can react.

Recompile sentinel: every jit'd entry point in this repo is a
module-level ``jax.jit`` wrapper, so its internal cache size *is* the
cumulative per-signature compile count for the process.
``register_jit_entry`` enrolls an entry once (import time);
``record_compile_gauges`` snapshots ``compiles.<name>`` gauges into a
registry, which the ``recompile_steady_state`` trend rule then watches.
A healthy engine compiles during warmup and never again — any upward
trend after that is a shape leak.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Union

from .registry import MetricsRegistry
from .trace import Tracer

SEV_WARN = "warn"
SEV_CRIT = "crit"

_KINDS = ("below", "above", "trend_up", "trend_down")


@dataclass(frozen=True)
class AlertRule:
    """One declarative predicate over a registry metric."""
    name: str                    # rule id (unique within a manager)
    metric: str                  # registry/as_dict key to watch
    kind: str                    # below | above | trend_up | trend_down
    threshold: float
    warmup: int = 0              # observations ignored before arming
    window: int = 8              # trend window (samples)
    severity: str = SEV_WARN
    message: str = ""

    def __post_init__(self):
        assert self.kind in _KINDS, self.kind


@dataclass
class AlertEvent:
    """A rule firing: what tripped, on which value, at which step."""
    rule: str
    metric: str
    value: float
    threshold: float
    step: int
    severity: str = SEV_WARN
    message: str = ""

    def as_args(self) -> Dict[str, Any]:
        return {"rule": self.rule, "metric": self.metric,
                "value": self.value, "threshold": self.threshold,
                "severity": self.severity, "message": self.message}


def default_rules() -> List[AlertRule]:
    """The standing rule set for a SPEC-RL training run.  Rules whose
    metric never appears (e.g. paged gauges on a dense engine) are
    silently inert."""
    return [
        AlertRule("draft_accept_collapse", "accept_rate", "below", 0.05,
                  warmup=5, severity=SEV_WARN,
                  message="draft acceptance collapsed — §9 drafts are "
                          "burning verify forwards for nothing"),
        AlertRule("reuse_collapse", "reuse_rate", "below", 0.05,
                  warmup=5, severity=SEV_WARN,
                  message="SPEC-RL prefix reuse collapsed — policy has "
                          "drifted past the cached rollouts"),
        AlertRule("pool_alloc_failures", "paged_alloc_failures", "above",
                  0.0, severity=SEV_CRIT,
                  message="paged KV pool exhausted — admissions shed"),
        AlertRule("pool_exhaustion_trend", "paged_blocks_in_use",
                  "trend_up", 0.0, warmup=4, window=8,
                  message="live block watermark rising — pool heading "
                          "for exhaustion"),
        AlertRule("staleness_rise", "async.staleness", "trend_up", 0.0,
                  warmup=4, window=8,
                  message="rollout staleness rising — trainer is "
                          "outrunning the producer"),
        AlertRule("recompile_steady_state", "compiles.total", "trend_up",
                  0.0, warmup=4, window=4, severity=SEV_CRIT,
                  message="jit recompiles in steady state — a shape is "
                          "leaking into traced code"),
    ]


DEFAULT_RULES = default_rules()


class AlertManager:
    """Evaluate rules against successive registry dumps.

    ``evaluate`` takes either a ``MetricsRegistry`` or a flat
    ``as_dict()``-style mapping, appends each watched metric to its rule's
    history, and returns the events that fired this step (already emitted
    to the tracer / watchdog).
    """

    def __init__(self, rules: Optional[Sequence[AlertRule]] = None,
                 tracer: Optional[Tracer] = None, watchdog=None):
        self.rules = list(DEFAULT_RULES if rules is None else rules)
        ids = [r.name for r in self.rules]
        assert len(ids) == len(set(ids)), f"duplicate rule ids: {ids}"
        self.tracer = tracer
        self.watchdog = watchdog
        self._hist: Dict[str, deque] = {
            r.name: deque(maxlen=max(2, r.window)) for r in self.rules}
        self._seen: Dict[str, int] = {r.name: 0 for r in self.rules}
        self._active: set = set()
        self.events: List[AlertEvent] = []

    # ------------------------------------------------------------ predicate

    @staticmethod
    def _tripped(rule: AlertRule, hist: deque) -> bool:
        v = hist[-1]
        if rule.kind == "below":
            return v < rule.threshold
        if rule.kind == "above":
            return v > rule.threshold
        if len(hist) < max(2, rule.window):
            return False
        delta = hist[-1] - hist[0]
        return delta > rule.threshold if rule.kind == "trend_up" \
            else delta < -rule.threshold

    def evaluate(self, metrics: Union[MetricsRegistry, Dict[str, float]],
                 step: int = 0) -> List[AlertEvent]:
        flat = metrics.as_dict() if isinstance(metrics, MetricsRegistry) \
            else metrics
        fired: List[AlertEvent] = []
        for rule in self.rules:
            val = flat.get(rule.metric)
            if not isinstance(val, (int, float)):
                continue                       # metric absent: rule inert
            self._seen[rule.name] += 1
            if self._seen[rule.name] <= rule.warmup:
                continue        # warmup samples never enter the window —
                                # compile/pool growth during warmup must not
                                # pre-charge the trend detectors
            hist = self._hist[rule.name]
            hist.append(float(val))
            if self._tripped(rule, hist):
                if rule.name not in self._active:   # edge-trigger
                    self._active.add(rule.name)
                    ev = AlertEvent(rule=rule.name, metric=rule.metric,
                                    value=float(val),
                                    threshold=rule.threshold, step=step,
                                    severity=rule.severity,
                                    message=rule.message)
                    fired.append(ev)
            else:
                self._active.discard(rule.name)     # cleared: re-arm
        for ev in fired:
            self._emit(ev)
        self.events.extend(fired)
        return fired

    def _emit(self, ev: AlertEvent) -> None:
        if self.tracer is not None and self.tracer.enabled:
            self.tracer.event(f"alert/{ev.rule}", "alerts",
                              cat=ev.severity, **ev.as_args())
        if self.watchdog is not None and \
                hasattr(self.watchdog, "note_alert"):
            self.watchdog.note_alert(ev)

    def as_dict(self, prefix: str = "alerts_") -> Dict[str, float]:
        out = {f"{prefix}fired": float(len(self.events)),
               f"{prefix}active": float(len(self._active))}
        for ev in self.events[-8:]:
            out.setdefault(f"{prefix}last_{ev.rule}", float(ev.step))
        return out


# --------------------------------------------------------- recompile sentinel

#: name → jit-wrapped callable, enrolled at import time by the modules that
#: own the entry points (engine_loop, drafting/step, core/verify)
_JIT_ENTRIES: Dict[str, Callable] = {}


def jit_cache_size(fn) -> Optional[int]:
    """Cumulative per-signature compile count of a ``jax.jit`` wrapper, or
    None when this jax build doesn't expose the probe."""
    probe = getattr(fn, "_cache_size", None)
    if probe is None:
        return None
    try:
        return int(probe())
    except Exception:
        return None


def register_jit_entry(name: str, fn) -> None:
    """Enroll a module-level jit entry point for the sentinel.  Idempotent
    by name; harmless when the probe is unavailable."""
    _JIT_ENTRIES[name] = fn


def compile_counts() -> Dict[str, int]:
    """Current compile count per enrolled entry (probe-less entries skipped)."""
    out: Dict[str, int] = {}
    for name, fn in _JIT_ENTRIES.items():
        n = jit_cache_size(fn)
        if n is not None:
            out[name] = n
    return out


def record_compile_gauges(reg: MetricsRegistry) -> None:
    """Snapshot ``compiles.<name>`` gauges plus the ``compiles.total`` the
    recompile rule watches.  agg="max": on a mesh every shard sees the same
    process-global jit caches, so the merge must not double-count."""
    counts = compile_counts()
    if not counts:
        return
    for name, n in counts.items():
        reg.set(f"compiles.{name}", float(n), agg="max")
    reg.set("compiles.total", float(sum(counts.values())), agg="max")


def record_device_memory(reg: MetricsRegistry) -> None:
    """Live/peak device-memory gauges when the backend reports them
    (``memory_stats()`` is None on CPU — gauges simply don't appear)."""
    try:
        import jax
        dev = jax.local_devices()[0]
        ms = dev.memory_stats() if hasattr(dev, "memory_stats") else None
    except Exception:
        return
    if not ms:
        return
    for src, dst in (("bytes_in_use", "device.bytes_in_use"),
                     ("peak_bytes_in_use", "device.peak_bytes_in_use"),
                     ("bytes_limit", "device.bytes_limit")):
        if src in ms:
            reg.set(dst, float(ms[src]),
                    agg="max" if "peak" in src or "limit" in src else "last")
