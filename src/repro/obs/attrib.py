"""Savings attribution: provenance counts × per-token cost (DESIGN.md §14).

The ledger says which mechanism produced each token; this module prices
them.  Every token in a SAVINGS category displaced work a vanilla run
would have done — a sequential decode step for reused/accepted/stitched
tokens, a prefill token's share for a CoW-shared prompt block — so

    saved_s[mechanism] = tokens[mechanism] × unit_cost_s

with the unit costs *measured*, not assumed: callers pass the decode
per-token seconds observed on the same run (e.g. the registry's
``decode.chunk_ms`` histogram mean over the chunk width, or a calibration
loop in benchmarks/ledger_bench.py).  DRAFT_BONUS is free-but-not-saved:
the bonus token rides a verify forward that was already paid for, so it
appears in the report as produced tokens with zero displaced cost.

The report is exported three ways, all built on §11 primitives:
``to_registry`` (→ ``as_dict``/Prometheus via the normal path), and
``counter_events`` → Chrome-trace "C"-phase counter tracks so the
about://tracing timeline shows stacked seconds-saved per mechanism
alongside the spans that earned them.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Union

import numpy as np

from .ledger import (CATEGORY_NAMES, DRAFT_ACCEPTED, DRAFT_BONUS, FRESH,
                     NUM_CATEGORIES, PROMPT, QUARANTINE_CLAMPED,
                     REUSED_PREFIX, RETRY_STITCHED, SHARED_PROMPT_BLOCK,
                     TokenLedger)
from .registry import MetricsRegistry

#: mechanism → provenance categories it is credited for
MECHANISMS: Dict[str, tuple] = {
    "spec_prefix": (REUSED_PREFIX,),            # SPEC-RL cached-rollout reuse
    "draft": (DRAFT_ACCEPTED,),                 # §9 n-gram continuation drafts
    "retry_reverify": (RETRY_STITCHED, QUARANTINE_CLAMPED),  # §10 recovery
    "shared_prompt": (SHARED_PROMPT_BLOCK,),    # §13 CoW prompt blocks
}

#: categories priced at prefill (not decode) unit cost
_PREFILL_PRICED = frozenset((SHARED_PROMPT_BLOCK,))


@dataclass
class AttributionReport:
    """Per-mechanism seconds-saved for one epoch/run."""
    counts: Dict[str, int]                 # category name → token count
    saved_s: Dict[str, float]              # mechanism → attributed seconds
    t_token_s: float                       # measured decode s/token
    t_prompt_token_s: float                # measured prefill s/token
    total_tokens: int = 0
    fresh_tokens: int = 0
    bonus_tokens: int = 0
    actual_s: Optional[float] = None       # measured rollout wall-clock
    epoch: Optional[int] = None
    extra: Dict[str, float] = field(default_factory=dict)

    @property
    def total_saved_s(self) -> float:
        return float(sum(self.saved_s.values()))

    @property
    def baseline_s(self) -> Optional[float]:
        """Implied vanilla wall-clock: measured actual + attributed saved.
        Cross-checked against a real baseline run in ledger_bench.py."""
        if self.actual_s is None:
            return None
        return self.actual_s + self.total_saved_s

    def as_dict(self) -> Dict[str, float]:
        out: Dict[str, float] = {
            "attrib.t_token_s": self.t_token_s,
            "attrib.t_prompt_token_s": self.t_prompt_token_s,
            "attrib.total_tokens": float(self.total_tokens),
            "attrib.fresh_tokens": float(self.fresh_tokens),
            "attrib.bonus_tokens": float(self.bonus_tokens),
            "attrib.total_saved_s": self.total_saved_s,
        }
        for name, n in self.counts.items():
            out[f"attrib.tokens.{name}"] = float(n)
        for mech, s in self.saved_s.items():
            out[f"attrib.saved_s.{mech}"] = float(s)
        if self.actual_s is not None:
            out["attrib.actual_s"] = float(self.actual_s)
            out["attrib.baseline_s"] = float(self.baseline_s)
            out["attrib.speedup"] = (self.baseline_s / self.actual_s
                                     if self.actual_s > 0 else 1.0)
        out.update({f"attrib.{k}": float(v) for k, v in self.extra.items()})
        return out

    # ------------------------------------------------------------- exports

    def to_registry(self, reg: MetricsRegistry) -> MetricsRegistry:
        """Counters for token tallies, gauges for rates/seconds — the §11
        registry then carries attribution through as_dict/Prometheus/merge
        like any other metric."""
        for name, n in self.counts.items():
            if n:
                reg.inc(f"attrib.tokens.{name}", int(n))
        for mech, s in self.saved_s.items():
            reg.set(f"attrib.saved_s.{mech}", float(s))
        reg.set("attrib.total_saved_s", self.total_saved_s)
        reg.set("attrib.t_token_s", self.t_token_s)
        if self.actual_s is not None:
            reg.set("attrib.speedup",
                    self.baseline_s / self.actual_s if self.actual_s > 0
                    else 1.0)
        return reg

    def counter_events(self, ts_s: float = 0.0,
                       track: str = "attrib") -> List[dict]:
        """Chrome-trace counter samples ("C" phase, stacked series) for
        export.chrome_trace(..., counters=...)."""
        return [
            {"name": "tokens_by_provenance", "track": track, "ts": ts_s,
             "values": {n: float(c) for n, c in self.counts.items() if c}},
            {"name": "saved_seconds", "track": track, "ts": ts_s,
             "values": {m: float(s) for m, s in self.saved_s.items()}},
        ]

    def summary(self) -> str:
        """Human-readable table (the analysis CLI prints this)."""
        lines = ["speculation economics"
                 + (f" — epoch {self.epoch}" if self.epoch is not None
                    else ""),
                 f"  decode unit cost   {self.t_token_s * 1e3:9.4f} ms/tok"
                 f"   prefill {self.t_prompt_token_s * 1e3:.4f} ms/tok",
                 f"  {'mechanism':<16}{'tokens':>10}{'saved_s':>12}"]
        for mech, cats in MECHANISMS.items():
            n = sum(self.counts.get(CATEGORY_NAMES[c], 0) for c in cats)
            lines.append(f"  {mech:<16}{n:>10}{self.saved_s[mech]:>12.4f}")
        lines.append(f"  {'fresh (paid)':<16}{self.fresh_tokens:>10}"
                     f"{'—':>12}")
        lines.append(f"  {'bonus (free)':<16}{self.bonus_tokens:>10}"
                     f"{'—':>12}")
        lines.append(f"  total saved {self.total_saved_s:.4f}s")
        if self.actual_s is not None:
            lines.append(f"  actual {self.actual_s:.4f}s  implied baseline "
                         f"{self.baseline_s:.4f}s  speedup "
                         f"{self.baseline_s / max(self.actual_s, 1e-12):.2f}x")
        return "\n".join(lines)


def _counts_array(source: Union[TokenLedger, Dict[str, int],
                                np.ndarray]) -> np.ndarray:
    if isinstance(source, TokenLedger):
        return source.category_counts()
    if isinstance(source, dict):
        out = np.zeros(NUM_CATEGORIES, np.int64)
        for i, name in enumerate(CATEGORY_NAMES):
            out[i] = int(source.get(name, 0))
        return out
    arr = np.asarray(source, np.int64)
    assert arr.shape == (NUM_CATEGORIES,), arr.shape
    return arr


def build_report(source: Union[TokenLedger, Dict[str, int], np.ndarray],
                 t_token_s: float,
                 t_prompt_token_s: Optional[float] = None,
                 actual_s: Optional[float] = None,
                 epoch: Optional[int] = None) -> AttributionReport:
    """Price a provenance tally.

    ``source`` is a live ledger, a ``counts_dict()``, or a raw bincount.
    ``t_token_s`` is the measured sequential decode cost per token;
    ``t_prompt_token_s`` the prefill cost per token (defaults to the decode
    cost — dense prefill amortizes far better, so this overstates shared-
    prompt savings unless measured; pass the real number when you have it).
    """
    c = _counts_array(source)
    if t_prompt_token_s is None:
        t_prompt_token_s = float(t_token_s)
    counts = {name: int(c[i]) for i, name in enumerate(CATEGORY_NAMES)}
    saved: Dict[str, float] = {}
    for mech, cats in MECHANISMS.items():
        s = 0.0
        for cat in cats:
            unit = t_prompt_token_s if cat in _PREFILL_PRICED else t_token_s
            s += float(c[cat]) * unit
        saved[mech] = s
    return AttributionReport(
        counts=counts, saved_s=saved, t_token_s=float(t_token_s),
        t_prompt_token_s=float(t_prompt_token_s),
        total_tokens=int(c.sum()),
        fresh_tokens=int(c[FRESH] + c[PROMPT]),
        bonus_tokens=int(c[DRAFT_BONUS]),
        actual_s=actual_s, epoch=epoch)


def measured_token_cost(reg_dict: Dict[str, float]) -> Optional[float]:
    """Decode s/token from a registry dump: the ``serve.token_ms``
    histogram mean (recorded per chunk by both the vanilla and drafted
    decode paths), falling back to the rollout decode-stage totals
    (decode seconds / generated tokens) for trainer runs that never touch
    the slot engine.  None when the run recorded neither."""
    mean_ms = reg_dict.get("serve.token_ms_mean")
    cnt = reg_dict.get("serve.token_ms_count", 0)
    if mean_ms is not None and cnt:
        return float(mean_ms) / 1e3
    dec_s = reg_dict.get("rollout.decode_s_sum", 0.0)
    gen = reg_dict.get("rollout.generated_tokens", 0.0)
    if dec_s and gen:
        return float(dec_s) / float(gen)
    return None
