"""Export sinks for the rollout observatory (DESIGN.md §11).

Three sinks, all fed from the same ``Tracer``/``MetricsRegistry`` state so
every surface shares one namespace:

* ``chrome_trace`` — Chrome trace-event JSON, loadable in Perfetto
  (https://ui.perfetto.dev) or chrome://tracing.  Each tracer becomes a
  process (pid), each track a thread (tid) — request lanes (``req/<id>``)
  show queued → admit → decode chunks → retry/quarantine → request; engine
  and trainer lanes show the stage breakdown.
* ``write_jsonl`` — one JSON object per span/event plus a final metrics
  record: the structured log the ROADMAP's learned draft controller trains
  on.
* ``prometheus_text`` / ``start_metrics_server`` — Prometheus text
  exposition (stdlib-only HTTP handler, opt-in via ``serve.py --metrics``).

All output is deterministic given a fake clock (sorted keys, stable lane
ordering) so tests pin golden files.
"""
from __future__ import annotations

import json
import re
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Callable, Dict, List, Union

from .registry import Counter, Gauge, Histogram, MetricsRegistry, bucket_edge
from .trace import Tracer

_US = 1e6      # tracer clocks are seconds; Chrome traces are microseconds


def _track_sort_key(track: str):
    """Engine/stage lanes first, request lanes ordered by numeric id."""
    if track.rsplit("/", 1)[-1].isdigit():
        head, _, tail = track.rpartition("/")
        return (1, head, int(tail))
    return (0, track, 0)


def chrome_trace(tracers: Union[Tracer, Dict[str, Tracer]],
                 counters: List[Dict] = None) -> Dict:
    """Build a Chrome trace-event object from one or more tracers.

    ``tracers`` may be a single Tracer or ``{process_name: Tracer}`` (one
    process per mesh shard / component).  ``counters`` adds "C"-phase
    counter samples (stacked series tracks, e.g. the §14 seconds-saved
    attribution): each ``{"name", "track", "ts", "values": {series: v}}``
    becomes a counter track on the first process."""
    if isinstance(tracers, Tracer):
        tracers = {"repro": tracers}
    events: List[Dict] = []
    counter_tracks = sorted({c["track"] for c in (counters or [])})
    for pid, (pname, tr) in enumerate(tracers.items()):
        events.append({"ph": "M", "pid": pid, "tid": 0,
                       "name": "process_name", "args": {"name": pname}})
        tracks = sorted(tr.tracks(), key=_track_sort_key)
        if pid == 0:
            tracks = tracks + [t for t in counter_tracks if t not in tracks]
        tids = {t: i for i, t in enumerate(tracks)}
        if pid == 0:
            for c in counters or []:
                events.append({"ph": "C", "pid": 0, "tid": tids[c["track"]],
                               "name": c["name"], "ts": c["ts"] * _US,
                               "args": {k: float(v)
                                        for k, v in c["values"].items()}})
        for track, tid in tids.items():
            events.append({"ph": "M", "pid": pid, "tid": tid,
                           "name": "thread_name", "args": {"name": track}})
            events.append({"ph": "M", "pid": pid, "tid": tid,
                           "name": "thread_sort_index",
                           "args": {"sort_index": tid}})
        for sp in tr.spans:
            if sp.t1 is None:
                continue
            events.append({"ph": "X", "pid": pid, "tid": tids[sp.track],
                           "name": sp.name, "cat": sp.cat or "span",
                           "ts": sp.t0 * _US,
                           "dur": max(0.0, sp.dur) * _US,
                           "args": dict(sp.args)})
        for ev in tr.events:
            events.append({"ph": "i", "pid": pid, "tid": tids[ev.track],
                           "name": ev.name, "cat": ev.cat or "event",
                           "ts": ev.ts * _US, "s": "t",
                           "args": dict(ev.args)})
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(path, tracers, counters: List[Dict] = None) -> None:
    with open(path, "w") as f:
        json.dump(chrome_trace(tracers, counters=counters), f,
                  sort_keys=True)


def write_jsonl(path, tracers, registry: MetricsRegistry = None) -> None:
    """Structured event log: one record per span/event in (t0, track) order
    per tracer, then one final ``metrics`` record with the registry view."""
    if isinstance(tracers, Tracer):
        tracers = {"repro": tracers}
    with open(path, "w") as f:
        for pname, tr in tracers.items():
            recs = [{"type": "span", "proc": pname, "track": sp.track,
                     "name": sp.name, "cat": sp.cat, "t0": sp.t0,
                     "t1": sp.t1, "dur": sp.dur, "args": dict(sp.args)}
                    for sp in tr.spans if sp.t1 is not None]
            recs += [{"type": "event", "proc": pname, "track": ev.track,
                      "name": ev.name, "cat": ev.cat, "ts": ev.ts,
                      "args": dict(ev.args)} for ev in tr.events]
            recs.sort(key=lambda r: (r.get("t0", r.get("ts", 0.0)),
                                     r["track"], r["name"]))
            for r in recs:
                f.write(json.dumps(r, sort_keys=True) + "\n")
        if registry is not None:
            f.write(json.dumps({"type": "metrics",
                                "metrics": registry.as_dict()},
                               sort_keys=True) + "\n")


# ------------------------------------------------------------- prometheus

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")


def _prom_name(namespace: str, name: str) -> str:
    return _NAME_RE.sub("_", f"{namespace}_{name}")


def _fmt(v: float) -> str:
    if v != v:
        return "NaN"
    if v in (float("inf"), float("-inf")):
        return "+Inf" if v > 0 else "-Inf"
    return repr(float(v))


def prometheus_text(registry: MetricsRegistry, namespace: str = "repro") -> str:
    """Prometheus text exposition format v0.0.4 (stdlib only).

    Counters get the ``_total`` suffix; histograms emit cumulative
    ``_bucket{le=...}`` series ending in ``+Inf`` plus ``_sum``/``_count``;
    gauges and derived ratios are exposed as gauges."""
    lines: List[str] = []
    for name in sorted(registry.names()):
        m = registry.get(name)
        pn = _prom_name(namespace, name)
        if isinstance(m, Counter):
            lines.append(f"# TYPE {pn}_total counter")
            lines.append(f"{pn}_total {_fmt(m.v)}")
        elif isinstance(m, Gauge):
            lines.append(f"# TYPE {pn} gauge")
            lines.append(f"{pn} {_fmt(m.v)}")
        elif isinstance(m, Histogram):
            lines.append(f"# TYPE {pn} histogram")
            cum = 0
            for idx in sorted(m.buckets):
                cum += m.buckets[idx]
                lines.append(f'{pn}_bucket{{le="{_fmt(bucket_edge(idx))}"}}'
                             f" {cum}")
            lines.append(f'{pn}_bucket{{le="+Inf"}} {m.count}')
            lines.append(f"{pn}_sum {_fmt(m.total)}")
            lines.append(f"{pn}_count {m.count}")
        else:                                   # Ratio → derived gauge
            lines.append(f"# TYPE {pn} gauge")
            lines.append(f"{pn} {_fmt(registry.as_dict().get(name, 0.0))}")
    return "\n".join(lines) + "\n"


def write_prometheus(path, registry: MetricsRegistry,
                     namespace: str = "repro") -> None:
    with open(path, "w") as f:
        f.write(prometheus_text(registry, namespace))


def start_metrics_server(provider: Callable[[], MetricsRegistry],
                         port: int, namespace: str = "repro"):
    """Serve ``GET /metrics`` on a daemon thread; returns the HTTPServer
    (call ``.shutdown()`` to stop).  ``provider`` is called per scrape so
    the exposition always reflects live counters."""

    class Handler(BaseHTTPRequestHandler):
        def do_GET(self):                            # noqa: N802 (stdlib API)
            if self.path.rstrip("/") not in ("", "/metrics"):
                self.send_response(404)
                self.end_headers()
                return
            body = prometheus_text(provider(), namespace).encode("utf-8")
            self.send_response(200)
            self.send_header("Content-Type",
                             "text/plain; version=0.0.4; charset=utf-8")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *a):                   # quiet by default
            pass

    srv = ThreadingHTTPServer(("", port), Handler)
    thread = threading.Thread(target=srv.serve_forever, daemon=True)
    thread.start()
    return srv
