"""Token-provenance ledger + decision-record logger (DESIGN.md §14).

SPEC-RL's value claim is "tokens we did not regenerate", but the aggregate
counters (``reuse_len``, ``accept_rate``) cannot say, for a given token,
*which* mechanism produced it.  The ledger answers that: every emitted
sequence gets a per-token uint8 **provenance plane** — one category byte
per position — built host-side by the same loops that already assemble the
tokens (core/spec_rollout.py, drafting/engine.py, serving/engine_loop.py,
serving/paged_engine.py), and audited by a conservation invariant: the
category counts of a finalized row sum exactly to its sequence length,
with no position left ``UNSET``.

Zero-overhead contract (the §11 hard rule, extended to §14): the ledger is
**host-side only** — no category ever enters a jit'd program, so lowered
StableHLO is byte-identical with the ledger on, off, or absent, and tokens
are bit-identical (tests/obs/test_ledger_zero_overhead.py).  Every
recording method early-returns on ``enabled=False``, and instrumented code
guards non-trivial argument construction behind ``ledger.enabled``.

The ``DecisionLog`` is the companion record stream the ROADMAP's learned
draft-length controller is blocked on: one record per (row, macro-step) of
a drafted decode — decision-time features (surprisal of the pending token,
position, acceptance EMA, chosen draft length, source, queue depth, slot
age, pool pressure) joined to outcomes (proposed/accepted/bonus/emitted
tokens, step wall-clock from the stamps the loop already takes) — written
as schema-versioned JSONL + NPZ shards that ``load_dataset`` reassembles
into aligned feature/outcome arrays.

Note on the entropy feature: full next-token logits never reach the host
in the decode loops (that round-trip is exactly what §11 forbids), so the
recorded feature is the **surprisal** of the pending token, ``-logprob``
of the last emitted sample — already host-resident in ``cur_lp``.  It is
the standard single-sample estimator of the same uncertainty signal.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

# ---------------------------------------------------------------- categories

UNSET = 0                 # position not yet attributed (never in a final row)
PROMPT = 1                # caller-supplied prompt token, dense prefill
REUSED_PREFIX = 2         # SPEC-RL verified prefix (cached rollout, accepted)
DRAFT_ACCEPTED = 3        # §9 continuation draft token accepted by verify
DRAFT_BONUS = 4           # free token after a fully-accepted draft block
FRESH = 5                 # vanilla decode / rejection-correction sample
RETRY_STITCHED = 6        # §10 partial output re-verified after timeout/stall
QUARANTINE_CLAMPED = 7    # §10 partial output re-verified after quarantine
SHARED_PROMPT_BLOCK = 8   # §13 CoW follower prompt (prefilled once, mapped)

NUM_CATEGORIES = 9
CATEGORY_NAMES = ("unset", "prompt", "reused_prefix", "draft_accepted",
                  "draft_bonus", "fresh", "retry_stitched",
                  "quarantine_clamped", "shared_prompt_block")

#: categories that represent *work avoided* vs a vanilla decode of the same
#: sequence — the attribution report (obs/attrib.py) prices exactly these
SAVINGS_CATEGORIES = (REUSED_PREFIX, DRAFT_ACCEPTED, RETRY_STITCHED,
                      QUARANTINE_CLAMPED, SHARED_PROMPT_BLOCK)


class LedgerError(ValueError):
    """Conservation-invariant violation: a finalized row does not exactly
    partition its sequence (wrong length, or an UNSET position)."""


class TokenLedger:
    """Per-row provenance planes, keyed by an arbitrary hashable row id.

    Rows grow by appends in emission order: ``begin_row`` lays down the
    prompt plane, the decode loops append one byte per emitted token.  The
    serving engine keys rows by ``request_id``; batch loops (spec_rollout,
    the fixed-batch drafted loop) key rows from ``reserve``'s monotonic id
    space, or from an explicit ``bind`` so a nested component (the drafted
    continuation inside a one-pass rollout) extends the caller's rows
    instead of opening parallel ones.
    """

    def __init__(self, enabled: bool = True):
        self.enabled = bool(enabled)
        self._rows: Dict[Any, bytearray] = {}
        self._retry_cat: Dict[Any, int] = {}
        self._bound: List[Sequence[Any]] = []
        self._next_row = 0
        self.finalized = 0
        self.violations = 0

    # ------------------------------------------------------------ row space

    def reserve(self, n: int) -> int:
        """Claim ``n`` fresh integer row ids; returns the first."""
        base = self._next_row
        self._next_row += int(n)
        return base

    def bind(self, row_ids: Sequence[Any]) -> None:
        """Push an explicit loop-row → ledger-row mapping for a nested
        component (see drafting/engine.py)."""
        if not self.enabled:
            return
        self._bound.append(list(row_ids))

    def unbind(self) -> None:
        if not self.enabled:
            return
        self._bound.pop()

    def bound_row(self, b: int) -> Optional[Any]:
        """The ledger row the caller bound for loop row ``b`` (None when no
        binding is active — the component owns its own rows)."""
        if not self._bound:
            return None
        return self._bound[-1][b]

    # ------------------------------------------------------------ recording

    def begin_row(self, rid: Any, prompt_len: int = 0,
                  prompt_cat: int = PROMPT) -> None:
        """Open (or re-open, on retry re-admission) the plane for ``rid``
        with ``prompt_len`` bytes of the prompt category."""
        if not self.enabled:
            return
        self._rows[rid] = bytearray([prompt_cat]) * int(prompt_len) \
            if prompt_len else bytearray()

    def append(self, rid: Any, cat: int, n: int = 1) -> None:
        """Extend ``rid``'s plane with ``n`` tokens of category ``cat``."""
        if not self.enabled or n <= 0:
            return
        row = self._rows.get(rid)
        if row is None:
            row = self._rows[rid] = bytearray()
        row.extend(bytes([cat]) * int(n))

    def drop_last(self, rid: Any, n: int) -> None:
        """Roll back the last ``n`` positions (§10 poisoned-tail drop)."""
        if not self.enabled or n <= 0:
            return
        row = self._rows.get(rid)
        if row is not None:
            del row[len(row) - min(n, len(row)):]

    def truncate(self, rid: Any, length: int) -> None:
        """Clamp ``rid``'s plane to ``length`` (the pack-to-N clamp)."""
        if not self.enabled:
            return
        row = self._rows.get(rid)
        if row is not None and len(row) > length:
            del row[length:]

    # ----------------------------------------------------- §10 retry memory

    def note_retry(self, rid: Any, reason: str) -> None:
        """Remember why ``rid`` left its slot: its re-verified partial
        output re-enters the plane as RETRY_STITCHED (timeout / stall /
        shed) or QUARANTINE_CLAMPED (non-finite logits)."""
        if not self.enabled:
            return
        self._retry_cat[rid] = QUARANTINE_CLAMPED \
            if reason == "quarantine" else RETRY_STITCHED

    def retry_category(self, rid: Any) -> int:
        return self._retry_cat.get(rid, RETRY_STITCHED)

    def clear_retry(self, rid: Any) -> None:
        if not self.enabled:
            return
        self._retry_cat.pop(rid, None)

    # ----------------------------------------------------------- inspection

    def has_row(self, rid: Any) -> bool:
        """Whether a plane was begun for ``rid``.  Kill-and-resume does not
        persist the ledger (by design — it is telemetry, not engine state),
        so a restored engine skips finalizing rows it never saw begin."""
        return rid in self._rows

    def row(self, rid: Any) -> np.ndarray:
        """The provenance plane for ``rid`` as a uint8 array (a copy)."""
        return np.frombuffer(bytes(self._rows.get(rid, b"")), np.uint8)

    def rows(self) -> Dict[Any, np.ndarray]:
        return {rid: self.row(rid) for rid in self._rows}

    def finalize(self, rid: Any, expected_len: int) -> np.ndarray:
        """Close a row and enforce the conservation invariant: category
        counts sum to ``expected_len`` and nothing is UNSET.  Raises
        ``LedgerError`` on violation (the ledger is an *audit*; a silent
        wrong plane is worse than none)."""
        if not self.enabled:
            return np.zeros(0, np.uint8)
        plane = self.row(rid)
        if len(plane) != int(expected_len) or \
                (len(plane) and int(plane.min()) == UNSET):
            self.violations += 1
            cts = dict(zip(CATEGORY_NAMES, np.bincount(
                plane, minlength=NUM_CATEGORIES).tolist()))
            raise LedgerError(
                f"provenance row {rid!r}: {len(plane)} attributed positions "
                f"vs sequence length {int(expected_len)} (counts={cts})")
        self.finalized += 1
        return plane

    def category_counts(self) -> np.ndarray:
        """(NUM_CATEGORIES,) int64 token tallies over all live rows."""
        out = np.zeros(NUM_CATEGORIES, np.int64)
        for row in self._rows.values():
            if row:
                out += np.bincount(np.frombuffer(bytes(row), np.uint8),
                                   minlength=NUM_CATEGORIES)
        return out

    def counts_dict(self) -> Dict[str, int]:
        c = self.category_counts()
        return {name: int(c[i]) for i, name in enumerate(CATEGORY_NAMES)}

    def clear(self) -> None:
        self._rows.clear()
        self._retry_cat.clear()
        self._bound.clear()
        self._next_row = 0
        self.finalized = 0
        self.violations = 0


#: Shared disabled ledger — the default everywhere provenance is threaded.
NULL_LEDGER = TokenLedger(enabled=False)


def categorize_draft_block(emitted: int,
                           carry_bonus: bool) -> List[Tuple[int, int]]:
    """Provenance of one drafted macro-step's emission, as (cat, n) runs.

    ``drafting.step.draft_step`` emits ``[carry | accepted drafts]``: the
    first token is the PREVIOUS step's correction/seed sample — a *bonus*
    token when that step fully accepted its proposal (its verify forward
    produced the sample for free), a fresh sample otherwise — and the
    remaining ``emitted - 1`` tokens are this step's accepted drafts.
    Callers track ``carry_bonus`` per row across steps (False at admission:
    the seed sample is priced as fresh).
    """
    m = int(emitted)
    if m <= 0:
        return []
    runs: List[Tuple[int, int]] = [
        (DRAFT_BONUS if carry_bonus else FRESH, 1)]
    if m > 1:
        runs.append((DRAFT_ACCEPTED, m - 1))
    return runs


# ------------------------------------------------------------ decision log

DECISION_SCHEMA_VERSION = 1
DECISION_FEATURES = ("surprisal", "position", "accept_ema", "draft_k",
                     "draft_source", "queue_depth", "slot_age",
                     "pool_pressure")
DECISION_OUTCOMES = ("proposed", "accepted", "bonus", "emitted", "step_ms")

# draft_source encoding (feature column stays numeric for the NPZ bundle)
SOURCE_NONE = 0.0
SOURCE_NGRAM = 1.0
SOURCE_CACHE = 2.0


class DecisionLog:
    """Schema-versioned (row, macro-step) decision records.

    In-memory until ``flush`` (or until ``shard_rows`` accumulate with an
    ``out_dir`` set, which auto-rotates a shard).  Each shard is written
    twice from the same records: ``decisions-NNNNN.jsonl`` (one JSON object
    per record, human-greppable) and ``decisions-NNNNN.npz`` (the
    training-ready arrays).  ``load_dataset`` reassembles every NPZ shard
    in a directory into one aligned feature/outcome bundle.
    """

    def __init__(self, out_dir: Optional[str] = None, enabled: bool = True,
                 shard_rows: int = 4096):
        self.enabled = bool(enabled)
        self.out_dir = out_dir
        self.shard_rows = int(shard_rows)
        self._recs: List[Tuple[Any, int, Tuple[float, ...],
                               Tuple[float, ...]]] = []
        self.shards_written = 0
        self.records_total = 0

    def record(self, row: Any, step: int, features: Dict[str, float],
               outcomes: Dict[str, float]) -> None:
        """Append one decision record.  Missing columns default to 0.0 so
        callers only pass what their layer can see (the dense engine has no
        pool pressure; the fixed-batch loop has no queue)."""
        if not self.enabled:
            return
        f = tuple(float(features.get(k, 0.0)) for k in DECISION_FEATURES)
        o = tuple(float(outcomes.get(k, 0.0)) for k in DECISION_OUTCOMES)
        self._recs.append((row, int(step), f, o))
        self.records_total += 1
        if self.out_dir is not None and len(self._recs) >= self.shard_rows:
            self._write_shard()

    def __len__(self) -> int:
        return len(self._recs)

    # -------------------------------------------------------------- output

    def _write_shard(self) -> None:
        recs, self._recs = self._recs, []
        tag = f"decisions-{self.shards_written:05d}"
        os.makedirs(self.out_dir, exist_ok=True)
        with open(os.path.join(self.out_dir, tag + ".jsonl"), "w") as fh:
            for row, step, f, o in recs:
                fh.write(json.dumps(
                    {"v": DECISION_SCHEMA_VERSION, "row": str(row),
                     "step": step,
                     "features": dict(zip(DECISION_FEATURES, f)),
                     "outcomes": dict(zip(DECISION_OUTCOMES, o))},
                    sort_keys=True) + "\n")
        np.savez(
            os.path.join(self.out_dir, tag + ".npz"),
            schema_version=np.int64(DECISION_SCHEMA_VERSION),
            feature_names=np.asarray(DECISION_FEATURES),
            outcome_names=np.asarray(DECISION_OUTCOMES),
            row=np.asarray([str(r) for r, _, _, _ in recs]),
            step=np.asarray([s for _, s, _, _ in recs], np.int64),
            features=np.asarray([f for _, _, f, _ in recs],
                                np.float32).reshape(len(recs),
                                                    len(DECISION_FEATURES)),
            outcomes=np.asarray([o for _, _, _, o in recs],
                                np.float32).reshape(len(recs),
                                                    len(DECISION_OUTCOMES)))
        self.shards_written += 1

    def flush(self) -> int:
        """Write any buffered records as a final shard; returns the number
        of shards on disk.  No-op without an ``out_dir``."""
        if not self.enabled or self.out_dir is None:
            return self.shards_written
        if self._recs:
            self._write_shard()
        return self.shards_written

    def clear(self) -> None:
        self._recs.clear()
        self.shards_written = 0
        self.records_total = 0


#: Shared disabled decision log — the default everywhere records are taken.
NULL_DECISION_LOG = DecisionLog(enabled=False)


def load_dataset(out_dir: str) -> Dict[str, np.ndarray]:
    """Reassemble every NPZ decision shard in ``out_dir`` into one bundle:
    ``features`` (N, F) float32 aligned with ``outcomes`` (N, O) float32,
    plus ``row``/``step`` identity columns and the schema names.  Raises on
    a schema-version or column-name mismatch — the learned controller must
    never silently train on a drifted layout."""
    shards = sorted(f for f in os.listdir(out_dir)
                    if f.startswith("decisions-") and f.endswith(".npz"))
    if not shards:
        raise FileNotFoundError(f"no decision shards under {out_dir}")
    feats, outs, rows, steps = [], [], [], []
    for name in shards:
        with np.load(os.path.join(out_dir, name), allow_pickle=False) as z:
            v = int(z["schema_version"])
            if v != DECISION_SCHEMA_VERSION:
                raise ValueError(f"{name}: schema v{v}, "
                                 f"expected v{DECISION_SCHEMA_VERSION}")
            if tuple(z["feature_names"]) != DECISION_FEATURES or \
                    tuple(z["outcome_names"]) != DECISION_OUTCOMES:
                raise ValueError(f"{name}: column names drifted")
            feats.append(z["features"])
            outs.append(z["outcomes"])
            rows.append(z["row"])
            steps.append(z["step"])
    return {"schema_version": DECISION_SCHEMA_VERSION,
            "feature_names": DECISION_FEATURES,
            "outcome_names": DECISION_OUTCOMES,
            "features": np.concatenate(feats, axis=0),
            "outcomes": np.concatenate(outs, axis=0),
            "row": np.concatenate(rows, axis=0),
            "step": np.concatenate(steps, axis=0)}
