"""Unified metrics registry (DESIGN.md §11): typed counters / gauges /
log-bucketed histograms / derived ratios behind one namespaced schema.

The metric *type* carries the merge semantics, which is what fixes the
mesh-stats schema drift (§8/§11): ``MeshSlotServer.stats()`` used to
hand-list which keys to sum and which to weight, so a new engine counter
could silently vanish from the gathered view.  Here every shard exports a
``MetricsRegistry`` and ``merge`` combines the *union* of names:

* ``Counter``   — summed;
* ``Gauge``     — combined by its declared ``agg`` (max / min / sum / last);
* ``Histogram`` — merged bucket-wise (associative and commutative, tested);
* ``Ratio``     — never merged directly: it names its numerator/denominator
  counters and re-derives after *they* merge (sum-of-parts, not
  mean-of-means — idle shards no longer dilute busy ones).

Histograms are log-bucketed (bucket edges grow by ``2**0.25`` ≈ 19%), so
p50/p95/p99 are exact to one bucket's relative width at any scale, merging
is exact (buckets align by construction), and state is O(#occupied
buckets).  ``state_dict``/``load_state_dict`` round-trip through the
checkpoint/io all-array pytree writer, so kill-and-resume keeps monotonic
counters and latency history (§10 discipline).
"""
from __future__ import annotations

import math
from typing import Dict, Iterable, List, Sequence

import numpy as np

# bucket edges: (_BASE ** i, _BASE ** (i+1)] — four buckets per octave
_BASE = 2.0 ** 0.25
_LOG_BASE = math.log(_BASE)
_ZERO_IDX = -(10 ** 9)            # the v <= 0 bucket (upper edge 0)

_AGGS = ("last", "max", "min", "sum")


def bucket_index(v: float) -> int:
    if v <= 0.0:
        return _ZERO_IDX
    # +1e-9: keep exact powers of _BASE in their own bucket under fp round
    return int(math.floor(math.log(v) / _LOG_BASE + 1e-9))


def bucket_edge(idx: int) -> float:
    """Upper edge of bucket ``idx`` (inclusive)."""
    return 0.0 if idx == _ZERO_IDX else _BASE ** (idx + 1)


class Counter:
    kind = "counter"
    __slots__ = ("v",)

    def __init__(self, v: float = 0.0):
        self.v = float(v)

    def add(self, x: float) -> None:
        self.v += float(x)

    def combine(self, other: "Counter") -> None:
        self.v += other.v


class Gauge:
    kind = "gauge"
    __slots__ = ("v", "agg")

    def __init__(self, v: float = 0.0, agg: str = "last"):
        assert agg in _AGGS, agg
        self.v = float(v)
        self.agg = agg

    def set(self, x: float) -> None:
        self.v = float(x)

    def combine(self, other: "Gauge") -> None:
        if self.agg == "max":
            self.v = max(self.v, other.v)
        elif self.agg == "min":
            self.v = min(self.v, other.v)
        elif self.agg == "sum":
            self.v += other.v
        else:                                    # "last": newest wins
            self.v = other.v


class Histogram:
    """Log-bucketed histogram with exact min/max/sum and bucket-merge."""
    kind = "histogram"
    __slots__ = ("buckets", "count", "total", "vmin", "vmax")

    def __init__(self):
        self.buckets: Dict[int, int] = {}
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def record(self, v: float) -> None:
        v = float(v)
        idx = bucket_index(v)
        self.buckets[idx] = self.buckets.get(idx, 0) + 1
        self.count += 1
        self.total += v
        self.vmin = min(self.vmin, v)
        self.vmax = max(self.vmax, v)

    def combine(self, other: "Histogram") -> None:
        for idx, c in other.buckets.items():
            self.buckets[idx] = self.buckets.get(idx, 0) + c
        self.count += other.count
        self.total += other.total
        self.vmin = min(self.vmin, other.vmin)
        self.vmax = max(self.vmax, other.vmax)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        """q in [0, 100].  Returns the upper edge of the bucket holding the
        q-th sample, clamped to the exact observed [vmin, vmax] — so the
        relative error is at most one bucket width (~19%)."""
        if not self.count:
            return 0.0
        target = max(1, math.ceil(q / 100.0 * self.count))
        cum = 0
        for idx in sorted(self.buckets):
            cum += self.buckets[idx]
            if cum >= target:
                return float(min(self.vmax, max(self.vmin, bucket_edge(idx))))
        return float(self.vmax)

    def summary(self) -> Dict[str, float]:
        empty = not self.count
        return {"count": float(self.count), "sum": float(self.total),
                "mean": self.mean,
                "min": 0.0 if empty else float(self.vmin),
                "max": 0.0 if empty else float(self.vmax),
                "p50": self.percentile(50), "p95": self.percentile(95),
                "p99": self.percentile(99)}

    @classmethod
    def from_values(cls, values: Iterable[float]) -> "Histogram":
        h = cls()
        for v in values:
            h.record(v)
        return h


class Ratio:
    """A derived metric: ``num_name / den_name`` over sibling counters.

    Holds no state of its own — ``value`` re-reads the (possibly merged)
    counters, so the mesh-gathered ratio is always sum(num)/sum(den)."""
    kind = "ratio"
    __slots__ = ("num", "den", "scale")

    def __init__(self, num: str, den: str, scale: float = 1.0):
        self.num = num
        self.den = den
        self.scale = float(scale)

    def combine(self, other: "Ratio") -> None:
        assert (self.num, self.den) == (other.num, other.den), \
            (self.num, self.den, other.num, other.den)


class MetricsRegistry:
    """Named, typed metrics with type-driven cross-shard merge."""

    def __init__(self):
        self._m: Dict[str, object] = {}

    # ------------------------------------------------------------- accessors

    def _get(self, name: str, cls, *args, **kw):
        m = self._m.get(name)
        if m is None:
            assert "/" not in name, \
                f"metric name {name!r} may not contain '/' (pytree separator)"
            m = cls(*args, **kw)
            self._m[name] = m
        assert isinstance(m, cls), (name, type(m).__name__, cls.__name__)
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str, agg: str = "last") -> Gauge:
        return self._get(name, Gauge, 0.0, agg)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def ratio(self, name: str, num: str, den: str,
              scale: float = 1.0) -> Ratio:
        return self._get(name, Ratio, num, den, scale)

    # ------------------------------------------------------------ shorthands

    def inc(self, name: str, v: float = 1.0) -> None:
        self.counter(name).add(v)

    def set(self, name: str, v: float, agg: str = "last") -> None:
        self.gauge(name, agg).set(v)

    def observe(self, name: str, v: float) -> None:
        self.histogram(name).record(v)

    @classmethod
    def from_flat(cls, d: Dict[str, float]) -> "MetricsRegistry":
        """Lift a flat float dict into a registry of gauges — the audit
        gate every step-log surface (trainer, async loop) passes its
        metrics through so the namespace stays one ``as_dict`` schema."""
        reg = cls()
        for k, v in d.items():
            reg.set(k, float(v))
        return reg

    def names(self) -> List[str]:
        return list(self._m)

    def get(self, name: str):
        return self._m.get(name)

    # ----------------------------------------------------------------- merge

    def merge(self, other: "MetricsRegistry") -> "MetricsRegistry":
        """Fold ``other`` in, type-driven, over the UNION of names — a
        metric present on any shard is present in the merged view."""
        for name, m in other._m.items():
            mine = self._m.get(name)
            if mine is None:
                self._m[name] = _copy_metric(m)
            else:
                assert mine.kind == m.kind, (name, mine.kind, m.kind)
                mine.combine(m)
        return self

    @classmethod
    def merged(cls, regs: Sequence["MetricsRegistry"]) -> "MetricsRegistry":
        out = cls()
        for r in regs:
            out.merge(r)
        return out

    # ------------------------------------------------------------- flat view

    def as_dict(self) -> Dict[str, float]:
        """The audited flat namespace (DESIGN.md §11 table): counters and
        gauges by name, ratios re-derived from their counters, histograms
        expanded to ``name_{count,sum,mean,min,max,p50,p95,p99}``."""
        out: Dict[str, float] = {}
        for name, m in self._m.items():
            if isinstance(m, (Counter, Gauge)):
                out[name] = float(m.v)
            elif isinstance(m, Ratio):
                num = self._m.get(m.num)
                den = self._m.get(m.den)
                n = float(num.v) if isinstance(num, (Counter, Gauge)) else 0.0
                d = float(den.v) if isinstance(den, (Counter, Gauge)) else 0.0
                out[name] = m.scale * n / d if d else 0.0
            else:
                for k, v in m.summary().items():
                    out[f"{name}_{k}"] = v
        return out

    # -------------------------------------------- exact state (§10 resume)

    def state_dict(self) -> Dict:
        """All-array pytree (checkpoint/io compatible — string metadata is
        encoded as uint8 so ``jnp.asarray`` round-trips every leaf)."""
        st: Dict = {}
        for name, m in self._m.items():
            if isinstance(m, Counter):
                st[name] = {"kind": np.int64(0), "v": np.float64(m.v)}
            elif isinstance(m, Gauge):
                st[name] = {"kind": np.int64(1), "v": np.float64(m.v),
                            "agg": np.int64(_AGGS.index(m.agg))}
            elif isinstance(m, Histogram):
                idx = np.asarray(sorted(m.buckets), np.int64)
                cnt = np.asarray([m.buckets[i] for i in sorted(m.buckets)],
                                 np.int64)
                st[name] = {"kind": np.int64(2), "idx": idx, "cnt": cnt,
                            "count": np.int64(m.count),
                            "total": np.float64(m.total),
                            "vmin": np.float64(m.vmin if m.count else 0.0),
                            "vmax": np.float64(m.vmax if m.count else 0.0)}
            else:
                st[name] = {"kind": np.int64(3), "scale": np.float64(m.scale),
                            "num": _enc(m.num), "den": _enc(m.den)}
        return st

    def load_state_dict(self, state: Dict) -> None:
        self._m.clear()
        for name, s in state.items():
            kind = int(s["kind"])
            if kind == 0:
                self._m[name] = Counter(float(s["v"]))
            elif kind == 1:
                g = Gauge(float(s["v"]), _AGGS[int(s["agg"])])
                self._m[name] = g
            elif kind == 2:
                h = Histogram()
                idx = np.asarray(s["idx"], np.int64)
                cnt = np.asarray(s["cnt"], np.int64)
                h.buckets = {int(i): int(c) for i, c in zip(idx, cnt)}
                h.count = int(s["count"])
                h.total = float(s["total"])
                h.vmin = float(s["vmin"]) if h.count else math.inf
                h.vmax = float(s["vmax"]) if h.count else -math.inf
                self._m[name] = h
            else:
                self._m[name] = Ratio(_dec(s["num"]), _dec(s["den"]),
                                      float(s["scale"]))


def _enc(name: str) -> np.ndarray:
    return np.frombuffer(name.encode("utf-8"), np.uint8).copy()


def _dec(arr) -> str:
    return bytes(np.asarray(arr, np.uint8).tolist()).decode("utf-8")


def _copy_metric(m):
    if isinstance(m, Counter):
        return Counter(m.v)
    if isinstance(m, Gauge):
        return Gauge(m.v, m.agg)
    if isinstance(m, Ratio):
        return Ratio(m.num, m.den, m.scale)
    h = Histogram()
    h.combine(m)
    return h


def extend_summary(values: Sequence[float]) -> Dict[str, float]:
    """min/max/p50/p95/p99 of ``values`` via the histogram helper — the
    ``core.metrics.summarize`` percentile backend."""
    h = Histogram.from_values(values)
    s = h.summary()
    return {k: s[k] for k in ("min", "max", "p50", "p95", "p99")}
