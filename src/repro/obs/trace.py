"""Span tracer for the rollout observatory (DESIGN.md §11).

A ``Tracer`` records *completed* spans (named intervals on a named track)
and typed instant events into bounded ring buffers.  Tracks become Perfetto
lanes in the Chrome-trace export (obs/export.py): the serving engine emits
one lane per engine plus one per sampled request; the trainer and the
SPEC-RL rollout emit stage lanes.

Zero-overhead contract (the §11 hard rule, enforced by
tests/obs/test_zero_overhead.py):

* tracing is **host-side only** — nothing here is ever traced into a jit'd
  program, so the compiled HLO is identical with tracing on, off, or absent;
* timestamps are taken only at boundaries where the host is *already*
  synchronous (the engine's chunk boundaries, the trainer's stage
  ``block_until_ready`` points, the drafted loop's per-step harvest) — a
  disabled tracer adds **no host syncs** to any hot loop;
* every recording method early-returns on ``enabled=False`` before touching
  the clock, and instrumented code guards arg construction behind
  ``tracer.enabled`` — clean runs stay bit-identical (PR 6 discipline).

The clock is injected (``clock=``) so tests drive a fake monotonic clock and
golden-file exports are deterministic.  ``sample_rate`` keeps per-request
lanes bounded under load: request r is traced iff ``sampled(r)``, a
deterministic hash — the same request samples identically on every shard.
"""
from __future__ import annotations

import time
from collections import deque
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional


@dataclass
class Span:
    """One completed (or still-open) named interval on a track."""
    name: str
    track: str
    cat: str
    t0: float
    t1: Optional[float] = None
    depth: int = 0
    args: Dict = field(default_factory=dict)

    @property
    def dur(self) -> float:
        return (self.t1 - self.t0) if self.t1 is not None else 0.0


@dataclass
class Event:
    """An instant event (a point, not an interval) on a track."""
    name: str
    track: str
    cat: str
    ts: float
    args: Dict = field(default_factory=dict)


# Knuth multiplicative hash — deterministic request sampling, identical on
# every shard/process (no PRNG state, no host randomness in the hot loop)
_HASH_MULT = 2654435761


class Tracer:
    """Bounded-ring span/event recorder with an injected monotonic clock."""

    def __init__(self, enabled: bool = True,
                 clock: Optional[Callable[[], float]] = None,
                 capacity: int = 65536, sample_rate: float = 1.0):
        assert capacity > 0, capacity
        self.enabled = bool(enabled)
        self.capacity = int(capacity)
        self.sample_rate = float(sample_rate)
        self._clock = clock if clock is not None else time.perf_counter
        self.spans: deque = deque(maxlen=self.capacity)
        self.events: deque = deque(maxlen=self.capacity)
        self.dropped_spans = 0          # ring evictions (bounded memory)
        self.dropped_events = 0
        self._open: Dict[int, Span] = {}
        self._depth: Dict[str, int] = {}
        self._next = 0

    # ------------------------------------------------------------- recording

    def now(self) -> float:
        return self._clock()

    def sampled(self, request_id: int) -> bool:
        """Deterministic per-request sampling decision (shard-invariant)."""
        if not self.enabled:
            return False
        if self.sample_rate >= 1.0:
            return True
        if self.sample_rate <= 0.0:
            return False
        h = (int(request_id) * _HASH_MULT) & 0xFFFFFFFF
        return h / 2.0 ** 32 < self.sample_rate

    def begin(self, name: str, track: str = "main", cat: str = "",
              **args) -> int:
        """Open a span; returns a handle for ``end``.  −1 when disabled."""
        if not self.enabled:
            return -1
        h = self._next
        self._next += 1
        d = self._depth.get(track, 0)
        self._depth[track] = d + 1
        self._open[h] = Span(name, track, cat, self._clock(), None, d,
                             dict(args))
        return h

    def end(self, handle: int, **args) -> None:
        if not self.enabled or handle < 0:
            return
        sp = self._open.pop(handle, None)
        if sp is None:
            return
        self._depth[sp.track] = max(0, self._depth.get(sp.track, 1) - 1)
        sp.t1 = self._clock()
        if args:
            sp.args.update(args)
        self._push_span(sp)

    @contextmanager
    def span(self, name: str, track: str = "main", cat: str = "", **args):
        """Lexically scoped span (the common case in tests and the trainer)."""
        if not self.enabled:
            yield
            return
        h = self.begin(name, track, cat, **args)
        try:
            yield
        finally:
            self.end(h)

    def complete(self, name: str, track: str, t0: float, t1: float,
                 cat: str = "", **args) -> None:
        """Record a span with explicit endpoints — the engine path.

        Instrumented code re-uses the ``perf_counter`` readings it already
        takes for its time accounting, so tracing never adds a clock call
        (let alone a sync) to a hot loop; retroactive spans (a request's
        whole lifecycle, emitted at finish) are only expressible this way.
        """
        if not self.enabled:
            return
        self._push_span(Span(name, track, cat, t0, t1,
                             self._depth.get(track, 0), dict(args)))

    def event(self, name: str, track: str = "main", cat: str = "",
              ts: Optional[float] = None, **args) -> None:
        if not self.enabled:
            return
        if len(self.events) == self.capacity:
            self.dropped_events += 1
        self.events.append(Event(name, track, cat,
                                 self._clock() if ts is None else ts,
                                 dict(args)))

    # ------------------------------------------------------------ inspection

    def _push_span(self, sp: Span) -> None:
        if len(self.spans) == self.capacity:
            self.dropped_spans += 1
        self.spans.append(sp)

    def tracks(self):
        seen = []
        for sp in self.spans:
            if sp.track not in seen:
                seen.append(sp.track)
        for ev in self.events:
            if ev.track not in seen:
                seen.append(ev.track)
        return seen

    def clear(self) -> None:
        self.spans.clear()
        self.events.clear()
        self._open.clear()
        self._depth.clear()
        self.dropped_spans = self.dropped_events = 0


#: Shared disabled tracer — the default everywhere instrumentation is
#: threaded.  All recording methods early-return; ``sampled`` is False.
NULL_TRACER = Tracer(enabled=False, capacity=1)
