from . import adamw
