"""AdamW with global-norm clipping and LR schedules (pure JAX; the paper
trains the actor with AdamW lr 5e-7, wd 0.01, clip 1.0 — Appendix A.1)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 5e-7
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    schedule: str = "constant"       # constant|cosine|warmup_cosine
    total_steps: int = 1000
    warmup_steps: int = 0


def lr_at(cfg: AdamWConfig, step) -> jnp.ndarray:
    step = jnp.asarray(step, jnp.float32)
    lr = jnp.asarray(cfg.lr, jnp.float32)
    if cfg.schedule == "constant":
        return lr
    warm = jnp.where(cfg.warmup_steps > 0,
                     jnp.minimum(1.0, step / jnp.maximum(cfg.warmup_steps, 1)),
                     1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    if cfg.schedule == "cosine":
        return lr * cos
    return lr * warm * cos


def init(params) -> Dict[str, Any]:
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"mu": zeros, "nu": jax.tree.map(jnp.zeros_like, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(cfg: AdamWConfig, params, grads, state) -> Tuple[Any, Dict[str, Any],
                                                            Dict[str, jnp.ndarray]]:
    """Returns (new_params, new_state, info)."""
    gnorm = global_norm(grads)
    scale = jnp.where(gnorm > cfg.clip_norm, cfg.clip_norm / (gnorm + 1e-9), 1.0)
    grads = jax.tree.map(lambda g: g * scale, grads)

    step = state["step"] + 1
    lr = lr_at(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g32
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g32)
        mh = m / b1c
        vh = v / b2c
        step_ = lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                      + cfg.weight_decay * p.astype(jnp.float32))
        return (p.astype(jnp.float32) - step_).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["mu"])
    flat_v = jax.tree.leaves(state["nu"])
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        np_, nm, nv = upd(p, g, m, v)
        new_p.append(np_), new_m.append(nm), new_v.append(nv)
    new_params = jax.tree.unflatten(tdef, new_p)
    new_state = {"mu": jax.tree.unflatten(tdef, new_m),
                 "nu": jax.tree.unflatten(tdef, new_v), "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
