"""Synthetic verifiable-math task generator.

Stands in for DeepMath-6K / SimpleRL-8K: prompts are small arithmetic
expressions ("17+25="), ground truth is the integer result, and the reward is
the same +1/0 exact-match rule the paper uses (math-verify style).  Task
difficulty (operand range, #terms) is configurable so tiny models can learn
within a few hundred steps.
"""
from __future__ import annotations

import random
from dataclasses import dataclass
from typing import List, Tuple


@dataclass(frozen=True)
class MathTaskConfig:
    num_problems: int = 256
    min_operand: int = 0
    max_operand: int = 20
    max_terms: int = 2
    ops: str = "+-"
    seed: int = 0


@dataclass(frozen=True)
class Problem:
    prompt_text: str
    answer: int
    problem_id: int


def generate_problems(cfg: MathTaskConfig) -> List[Problem]:
    rng = random.Random(cfg.seed)
    problems = []
    seen = set()
    while len(problems) < cfg.num_problems:
        n_terms = rng.randint(2, max(2, cfg.max_terms))
        terms = [rng.randint(cfg.min_operand, cfg.max_operand)
                 for _ in range(n_terms)]
        ops = [rng.choice(cfg.ops) for _ in range(n_terms - 1)]
        expr = str(terms[0])
        for o, t in zip(ops, terms[1:]):
            expr += o + str(t)
        if expr in seen:
            continue
        seen.add(expr)
        answer = eval(expr)  # trusted: generated from digits/ops only
        problems.append(Problem(expr + "=", int(answer), len(problems)))
    return problems
