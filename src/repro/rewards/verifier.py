"""Rule-based verifiable reward (math-verify style, Appendix A.1).

+1 if the final integer in the decoded response matches the ground truth,
0 otherwise.  Deterministic, tamper-resistant, no format shaping — matching
the paper's reward design.
"""
from __future__ import annotations

import re
from typing import List, Optional, Sequence

import numpy as np

from repro.data.tokenizer import decode

_INT_RE = re.compile(r"-?\d+")


def extract_answer(text: str) -> Optional[int]:
    """Last integer in the response (simplified 'boxed or numeric answer')."""
    matches = _INT_RE.findall(text)
    if not matches:
        return None
    try:
        return int(matches[-1])
    except ValueError:
        return None


def verify_text(response: str, answer: int) -> float:
    got = extract_answer(response)
    return 1.0 if got is not None and got == answer else 0.0


def verify_tokens(tokens: Sequence[int], answer: int) -> float:
    return verify_text(decode(tokens), answer)


def batch_rewards(responses: np.ndarray, lengths: np.ndarray,
                  answers: Sequence[int]) -> np.ndarray:
    """responses: (B, N) token ids; lengths: (B,).  Returns (B,) float32."""
    out = np.zeros((len(answers),), np.float32)
    for i, ans in enumerate(answers):
        out[i] = verify_tokens(responses[i, :int(lengths[i])], ans)
    return out
