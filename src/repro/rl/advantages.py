"""Advantage estimators: GAE (PPO), group-relative (GRPO), DAPO.

All return token-level advantages (B, N) masked by the response mask.
The task is bandit-like (single terminal verifiable reward), mirroring the
paper's RLVR setting.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def group_relative_advantages(rewards, group_size: int, *, use_std: bool = True,
                              eps: float = 1e-6):
    """GRPO: z-score within each group of ``group_size`` rollouts.

    rewards: (B,) with B = num_prompts * group_size, groups contiguous.
    Returns (B,) scalar advantages (broadcast over tokens by the caller).
    """
    B = rewards.shape[0]
    g = rewards.reshape(B // group_size, group_size)
    mean = g.mean(axis=1, keepdims=True)
    adv = g - mean
    if use_std:
        adv = adv / (g.std(axis=1, keepdims=True) + eps)
    return adv.reshape(B)


def gae_advantages(rewards_tok, values, mask, *, gamma: float = 1.0,
                   lam: float = 0.95):
    """PPO GAE over token sequences.

    rewards_tok: (B, N) per-token rewards (terminal reward at last valid
    token); values: (B, N) critic estimates; mask: (B, N) response validity.
    Returns (advantages (B, N), returns (B, N)).
    """
    B, N = rewards_tok.shape
    m = mask.astype(jnp.float32)
    v = values * m
    # v_{t+1}: next valid value, 0 beyond the end
    v_next = jnp.concatenate([v[:, 1:], jnp.zeros_like(v[:, :1])], axis=1)
    delta = (rewards_tok + gamma * v_next - v) * m

    def step(carry, x):
        d_t, m_t = x
        carry = d_t + gamma * lam * m_t * carry
        return carry, carry

    # scan right-to-left: advantage_t = delta_t + gamma*lam*advantage_{t+1}
    d_rev = jnp.moveaxis(delta[:, ::-1], 1, 0)
    # mask of "next token exists": shift mask left then reverse
    m_next = jnp.concatenate([m[:, 1:], jnp.zeros_like(m[:, :1])], axis=1)
    m_rev = jnp.moveaxis(m_next[:, ::-1], 1, 0)
    _, adv_rev = jax.lax.scan(step, jnp.zeros((B,), jnp.float32),
                              (d_rev, m_rev))
    adv = jnp.moveaxis(adv_rev, 0, 1)[:, ::-1] * m
    returns = adv + v
    return adv, returns


def terminal_reward_to_tokens(rewards, lengths, N: int):
    """Place the scalar reward at the last generated token: (B,) -> (B, N)."""
    B = rewards.shape[0]
    j = jnp.arange(N, dtype=jnp.int32)[None, :]
    last = jnp.maximum(lengths - 1, 0)[:, None]
    return jnp.where(j == last, rewards[:, None], 0.0)


def whiten(adv, mask, eps: float = 1e-6):
    m = mask.astype(jnp.float32)
    count = jnp.maximum(m.sum(), 1.0)
    mean = (adv * m).sum() / count
    var = ((adv - mean) ** 2 * m).sum() / count
    return (adv - mean) * m / jnp.sqrt(var + eps)
