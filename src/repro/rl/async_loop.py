"""Async trainer (DESIGN.md §12): the consumer half of the disaggregated
rollout ↔ train seam, under a bounded staleness window.

Topology: a ``serving.RolloutService`` produces version-tagged
trajectories into a bounded ``TrajBuffer``; this loop consumes them and
runs the optimization half of the trainer (``Trainer.optimize``).  Per
consumed trajectory, staleness = consumer policy version − the version it
was sampled under:

* **0**            — the exact synchronous computation (no correction);
* **1 … K**        — truncated importance weights
                     w = min(ρ̄, exp(lp_now − lp_behaviour)) folded into
                     the advantages (``Trainer.optimize(behaviour_lp=…)``);
* **> K**          — NOT dropped: the stale response is primed into a
                     throwaway RolloutCache and re-verified through the
                     existing one-pass verify_and_prefill →
                     realign_decode_cache → resume_from_cache path — reuse
                     the still-agreeing prefix, regenerate the divergent
                     tail, re-reward, train on-policy.  SPEC-RL's own
                     mechanism is what makes asynchrony safe.

Graceful degradation mirrors PR 6's ``_IMPL_LADDER``: when the *service*
staleness (consumer version − served version, i.e. how far weight
publication has fallen behind) exceeds ``hard_staleness_cap``, the loop
walks one rung down ``_MODE_LADDER`` per step:

    async  →  reverify (re-verify every trajectory)  →  sync (collect
    in-process, the pre-§12 loop)

Failure-domain isolation: a producer ``kill`` fault surfaces as
``EngineKilled`` at a tick boundary — the consumer catches it, counts a
restart and keeps training; a failed weight sync leaves the service on
its last good version while the staleness gauge rises.  Everything is
counted in the obs registry (staleness histogram, buffer occupancy, sync
retries, degradation level) and the whole pair checkpoints through
``checkpoint/io`` for exact kill-and-resume.

Determinism contract (tested): with window K=0, publish_every=1 and the
strict ``"pc"`` schedule, producer and consumer replay the synchronous
trainer's RNG streams in lockstep — token- and loss-identical to
``Trainer.train_step``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.io import (load_pytree, load_rollout_cache, read_latest,
                                 save_pytree, save_rollout_cache,
                                 write_latest)
from repro.core import RolloutCache, rollout
from repro.core.spec_rollout import RolloutBatch
from repro.rewards.verifier import batch_rewards
from repro.serving.faults import EngineKilled, FaultPlan
from repro.serving.rollout_service import RolloutService, WeightSync

from .traj_buffer import TrajBuffer, Trajectory

# one-way degradation ladder (§10 pattern): async consumption → re-verify
# every trajectory → fully synchronous in-process collection
_MODE_LADDER = {"async": "reverify", "reverify": "sync", "sync": None}
_MODES = ("async", "reverify", "sync")


@dataclass(frozen=True)
class AsyncConfig:
    staleness_window: int = 1        # K: max versions corrected by IS
    is_clip: float = 2.0             # truncated-IS cap ρ̄
    buffer_capacity: int = 8
    high_watermark: Optional[int] = None   # None → capacity (shed-only)
    hard_staleness_cap: int = 4      # service staleness → walk the ladder
    publish_every: int = 1           # optimizer steps between publications
    schedule: str = "pc"             # deterministic p(roducer)/c(onsumer)
                                     # interleave, repeated
    reverify_seed: int = 7321        # PRNG stream for re-verification
    max_idle_ticks: int = 10000      # run() no-progress safety valve


class AsyncTrainer:
    """Drives a (RolloutService, TrajBuffer, Trainer) triple under the
    deterministic step-interleaved schedule."""

    def __init__(self, trainer, acfg: AsyncConfig = AsyncConfig(),
                 faults: Optional[FaultPlan] = None,
                 sync: Optional[WeightSync] = None,
                 buffer: Optional[TrajBuffer] = None):
        self.trainer = trainer
        self.acfg = acfg
        self.collector = trainer.collector       # SHARED with the trainer:
        # one sampling RNG, one PRNG stream, one SPEC-RL cache — the K=0
        # identity contract depends on there being exactly one of each
        self.buffer = buffer if buffer is not None else TrajBuffer(
            acfg.buffer_capacity, acfg.high_watermark)
        self.sync = sync if sync is not None else WeightSync()
        self.service = RolloutService(self.collector, self.buffer, self.sync,
                                      faults=faults)
        self.version = 0                         # consumer policy version
        self.mode = "async"
        self.degradations = 0
        self.exact_steps = 0                     # staleness == 0
        self.is_steps = 0                        # 1 <= staleness <= K
        self.reverified = 0                      # staleness > K or mode
        self.sync_steps = 0                      # ladder bottom
        self.producer_restarts = 0
        self.starved_ticks = 0
        self._wait_ticks = 0
        self._wait_t0: Optional[float] = None
        self._reverify_key = jax.random.PRNGKey(acfg.reverify_seed)
        # bootstrap deployment: the service starts on the trainer's initial
        # params as version 0 (a direct install, not a sync — there is no
        # failure domain to cross yet)
        self.service.install(trainer.params, self.version)

    # -------------------------------------------------------------- ladder

    @property
    def mode_level(self) -> int:
        return _MODES.index(self.mode)

    def _degrade(self, reason: str) -> None:
        nxt = _MODE_LADDER[self.mode]
        if nxt is None:
            return
        from repro.obs import get_registry, get_tracer
        prev, self.mode = self.mode, nxt
        self.degradations += 1
        reg = get_registry()
        reg.inc("async.degradations")
        reg.set("async.degradation_level", float(self.mode_level), agg="max")
        get_tracer().event("async_degrade", "trainer", cat="fault",
                           frm=prev, to=nxt, reason=reason,
                           step=self.trainer.step_idx)

    def _maybe_degrade(self) -> int:
        """Check the service-staleness hard cap; walk ONE rung per step
        while above it (mirrors the engine's per-incident ladder walk)."""
        from repro.obs import get_registry
        lag = max(0, self.version - max(0, self.service.version))
        get_registry().set("async.service_staleness", float(lag))
        if lag > self.acfg.hard_staleness_cap:
            self._degrade(f"service staleness {lag} > "
                          f"cap {self.acfg.hard_staleness_cap}")
        return lag

    # ------------------------------------------------------------ producer

    def producer_tick(self) -> bool:
        """One service tick inside its own failure domain: a 'kill' fault
        dies here, is counted, and the producer restarts — the trainer
        never goes down with it."""
        try:
            return self.service.tick()
        except EngineKilled:
            from repro.obs import get_registry, get_tracer
            self.producer_restarts += 1
            get_registry().inc("async.producer_restarts")
            get_tracer().event("producer_restart", "trainer", cat="fault",
                               tick=self.service.ticks)
            self.service.recover()
            return False

    # ------------------------------------------------------------ consumer

    def _reverify(self, traj: Trajectory
                  ) -> Tuple[RolloutBatch, np.ndarray, Dict[str, float]]:
        """Over-stale trajectory → SPEC-RL draft: prime a throwaway cache
        with the stale response and roll it under the CURRENT params — the
        one-pass verify→compact→resume path reuses the still-agreeing
        prefix and regenerates only the divergent tail; then re-reward."""
        c = self.collector
        tmp = RolloutCache(history=2, group_size=c.rl.group_size)
        rb0 = traj.rb
        tmp.batch_put(traj.batch.cache_keys, rb0.response,
                      rb0.behaviour_logprobs, rb0.length,
                      step=max(0, traj.version), eos_id=c.gen.eos_id)
        self._reverify_key, sub = jax.random.split(self._reverify_key)
        t0 = time.perf_counter()
        rb = rollout(self.trainer.params, c.cfg, c.gen, c.spec,
                     jnp.asarray(traj.batch.tokens),
                     jnp.asarray(traj.batch.mask), traj.batch.cache_keys,
                     tmp, sub, self.version, mesh=c.mesh)
        rewards = batch_rewards(rb.response, rb.length, traj.batch.answers)
        times = dict(rb.metrics)
        times["collect_time"] = time.perf_counter() - t0
        return rb, rewards, times

    def _after_optimize(self) -> None:
        """Version bump + (possibly failing) weight publication."""
        from repro.obs import get_registry
        self.version += 1
        if self.version % max(1, self.acfg.publish_every) == 0:
            self.sync.publish(self.trainer.params, self.version)
        get_registry().set("async.published_version",
                           float(self.sync.version))
        get_registry().set("async.policy_version", float(self.version))

    def consumer_step(self) -> Optional[Dict[str, float]]:
        """One optimization step off the buffer.  None = starved (the
        schedule's next producer tick will feed it)."""
        from repro.obs import get_registry
        reg = get_registry()
        lag = self._maybe_degrade()

        if self.mode == "sync":
            # ladder bottom: in-process collection, the pre-§12 loop
            m = self.trainer.train_step()
            self.sync_steps += 1
            m["async_mode_level"] = float(self.mode_level)
            m["service_staleness"] = float(lag)
            self._after_optimize()
            return m

        traj = self.buffer.get()
        if traj is None:
            self.starved_ticks += 1
            self._wait_ticks += 1
            if self._wait_t0 is None:
                self._wait_t0 = time.perf_counter()
            reg.inc("async.consumer_starved_ticks")
            return None
        wait_s = (time.perf_counter() - self._wait_t0
                  if self._wait_t0 is not None else 0.0)
        wait_ticks, self._wait_ticks, self._wait_t0 = self._wait_ticks, 0, None

        staleness = max(0, self.version - max(0, traj.version))
        reg.observe("async.traj_staleness", float(staleness))
        extra = {
            "staleness": float(staleness),
            "traj_version": float(traj.version),
            "policy_version": float(self.version),
            "service_staleness": float(lag),
            "service_wait_ticks": float(wait_ticks),
            "service_wait_s": float(wait_s),
            "async_mode_level": float(self.mode_level),
            "sync_retries": float(self.sync.retries),
            "sync_failures": float(self.sync.failures),
            "producer_restarts": float(self.producer_restarts),
            **self.buffer.counters(),
        }

        K = self.acfg.staleness_window
        if self.mode == "reverify" or staleness > K:
            rb, rewards, times = self._reverify(traj)
            self.reverified += 1
            reg.inc("async.reverified")
            extra["reverified"] = 1.0
            m = self.trainer.optimize(rb, rewards, times,
                                      extra_metrics=extra)
        elif staleness > 0:
            self.is_steps += 1
            reg.inc("async.is_corrected")
            m = self.trainer.optimize(
                traj.rb, traj.rewards, dict(traj.rb.metrics),
                behaviour_lp=traj.rb.behaviour_logprobs,
                is_clip=self.acfg.is_clip, extra_metrics=extra)
        else:
            self.exact_steps += 1
            m = self.trainer.optimize(traj.rb, traj.rewards,
                                      dict(traj.rb.metrics),
                                      extra_metrics=extra)
        self._after_optimize()
        return m

    # ----------------------------------------------------------- scheduler

    def run(self, num_steps: int, schedule: Optional[str] = None
            ) -> List[Dict[str, float]]:
        """Drive the deterministic step-interleaved schedule until
        ``num_steps`` consumer steps completed.  The schedule string is a
        cycle over 'p' (producer tick) and 'c' (consumer step) — the test
        scheduler of the §12 determinism contract."""
        sched = schedule if schedule is not None else self.acfg.schedule
        assert sched and set(sched) <= {"p", "c"}, sched
        out: List[Dict[str, float]] = []
        idle = 0
        i = 0
        while len(out) < num_steps:
            ch = sched[i % len(sched)]
            i += 1
            progressed = False
            if ch == "p":
                progressed = self.producer_tick()
            else:
                m = self.consumer_step()
                if m is not None:
                    out.append(m)
                    progressed = True
            idle = 0 if progressed else idle + 1
            if idle > self.acfg.max_idle_ticks:
                raise RuntimeError(
                    f"async loop stalled: {idle} ticks without progress "
                    f"(mode={self.mode}, buffer={len(self.buffer)})")
        return out

    # ------------------------------------------------------------- counters

    def counters(self) -> Dict[str, float]:
        return {"async_version": float(self.version),
                "async_mode_level": float(self.mode_level),
                "async_degradations": float(self.degradations),
                "async_exact_steps": float(self.exact_steps),
                "async_is_steps": float(self.is_steps),
                "async_reverified": float(self.reverified),
                "async_sync_steps": float(self.sync_steps),
                "async_producer_restarts": float(self.producer_restarts),
                "async_starved_ticks": float(self.starved_ticks),
                **self.buffer.counters(),
                **self.service.counters()}

    # -------------------------------------------- §10 exact kill-and-resume

    def state_dict(self) -> Dict:
        tr = self.trainer
        st: Dict = {
            "trainer": {
                "params": tr.params,
                "opt_state": tr.opt_state,
                "key": tr.key,
                "scalars": {
                    "step_idx": np.int64(tr.step_idx),
                    "gen_steps": np.int64(tr.gen_steps),
                    "total_generated_tokens":
                        np.int64(tr.total_generated_tokens),
                },
            },
            "service": self.service.state_dict(),
            "sync": self.sync.state_dict(),
            "buffer": self.buffer.state_dict(),
            "reverify_key": np.asarray(self._reverify_key),
            "scalars": {
                "version": np.int64(self.version),
                "mode": np.int64(self.mode_level),
                "degradations": np.int64(self.degradations),
                "exact_steps": np.int64(self.exact_steps),
                "is_steps": np.int64(self.is_steps),
                "reverified": np.int64(self.reverified),
                "sync_steps": np.int64(self.sync_steps),
                "producer_restarts": np.int64(self.producer_restarts),
                "starved_ticks": np.int64(self.starved_ticks),
            },
        }
        if tr.critic_params is not None:
            st["trainer"]["critic_params"] = tr.critic_params
            st["trainer"]["critic_opt_state"] = tr.critic_opt_state
        return st

    def load_state_dict(self, st: Dict) -> None:
        from repro.distributed.mesh import shard_opt_state, shard_params
        tr = self.trainer
        t = st["trainer"]
        tr.params = shard_params(tr.mesh, tr.cfg, t["params"])
        tr.opt_state = shard_opt_state(tr.mesh, tr.cfg, tr.params,
                                       t["opt_state"])
        tr.key = jnp.asarray(t["key"])
        tr.step_idx = int(t["scalars"]["step_idx"])
        tr.gen_steps = int(t["scalars"]["gen_steps"])
        tr.total_generated_tokens = \
            int(t["scalars"]["total_generated_tokens"])
        if "critic_params" in t and tr.critic_params is not None:
            tr.critic_params = shard_params(tr.mesh, tr.critic_cfg,
                                            t["critic_params"])
            tr.critic_opt_state = shard_opt_state(
                tr.mesh, tr.critic_cfg, tr.critic_params,
                t["critic_opt_state"])
        self.service.load_state_dict(st["service"])
        self.sync.load_state_dict(st["sync"])
        self.buffer.load_state_dict(st["buffer"])
        self._reverify_key = jnp.asarray(st["reverify_key"])
        sc = st["scalars"]
        self.version = int(sc["version"])
        self.mode = _MODES[int(sc["mode"])]
        self.degradations = int(sc["degradations"])
        self.exact_steps = int(sc["exact_steps"])
        self.is_steps = int(sc["is_steps"])
        self.reverified = int(sc["reverified"])
        self.sync_steps = int(sc["sync_steps"])
        self.producer_restarts = int(sc["producer_restarts"])
        self.starved_ticks = int(sc["starved_ticks"])

    def save(self, ckpt_dir: str, name: Optional[str] = None) -> str:
        """Checkpoint the whole async pair — trainer core, service (incl.
        served weights + version), weight-sync channel, buffer contents,
        mode/version scalars, SPEC-RL cache — committed by the ``latest``
        pointer flip, exactly like the watchdog's snapshots."""
        import os
        name = name or f"async_{self.trainer.step_idx:06d}"
        path = os.path.join(ckpt_dir, name)
        save_pytree(path, self.state_dict(),
                    metadata={"step": self.trainer.step_idx,
                              "kind": "async_pair"})
        save_rollout_cache(path, self.collector.cache)
        write_latest(ckpt_dir, name)
        return name

    def restore(self, ckpt_dir: str) -> bool:
        """Restore the pair from the last committed checkpoint; False if
        none exists (a fresh start, not an error)."""
        import os
        name = read_latest(ckpt_dir)
        if name is None:
            return False
        path = os.path.join(ckpt_dir, name)
        tree, _meta = load_pytree(path)
        self.load_state_dict(tree)
        self.collector.cache = load_rollout_cache(path)
        return True
