"""PPO critic: same trunk family as the policy with a scalar value head."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.engine.generate import positions_from_mask
from repro.models.blocks import apply_trunk, make_trunk
from repro.models.config import ModelConfig
from repro.models.layers import (apply_dense, apply_rmsnorm, embed_init,
                                 make_dense, make_rmsnorm, split_keys)


def init_critic(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.param_dtype)
    ks = split_keys(key, 4)
    return {
        "embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model, dtype),
        "trunk": make_trunk(ks[1], cfg, dtype),
        "final_norm": make_rmsnorm(cfg.d_model, dtype),
        "value_head": make_dense(ks[2], cfg.d_model, 1, True, dtype),
    }


def forward_values(params, cfg: ModelConfig, tokens, mask):
    """tokens: (B, L); mask: (B, L).  Returns (B, L) value estimates."""
    positions = positions_from_mask(mask)
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    x = jnp.where(mask[..., None], x, 0.0)
    x, _, _ = apply_trunk(params["trunk"], cfg, x, positions)
    x = apply_rmsnorm(params["final_norm"], x, cfg.norm_eps)
    v = apply_dense(params["value_head"], x)[..., 0].astype(jnp.float32)
    return jnp.where(mask, v, 0.0)
