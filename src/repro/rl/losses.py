"""Clipped-surrogate policy loss, critic loss, KL penalty, diagnostics.

Covers GRPO / PPO (clip 0.2, c=3) and DAPO (asymmetric clip high=0.28,
c=10, token-level aggregation) per Appendix A.1.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class PolicyLossConfig:
    clip_low: float = 0.2
    clip_high: float = 0.2
    clip_c: float = 3.0               # dual-clip constant (DAPO c=10)
    agg: str = "seq"                  # seq (GRPO/PPO) | token (DAPO)
    kl_coef: float = 0.0              # GRPO: 1e-4 vs reference policy
    entropy_coef: float = 0.0


def masked_mean(x, mask, axis=None, eps: float = 1e-8):
    m = mask.astype(jnp.float32)
    return (x * m).sum(axis) / jnp.maximum(m.sum(axis), eps)


def policy_loss(lp_new, lp_old, advantages, mask, cfg: PolicyLossConfig
                ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """PPO-style clipped surrogate.

    lp_new/lp_old: (B, N) token log-probs; advantages: (B, N); mask: (B, N).
    """
    ratio = jnp.exp(lp_new - lp_old)
    clipped = jnp.clip(ratio, 1.0 - cfg.clip_low, 1.0 + cfg.clip_high)
    s1 = ratio * advantages
    s2 = clipped * advantages
    surrogate = jnp.minimum(s1, s2)
    # dual clip (large negative advantage protection)
    surrogate = jnp.where(advantages < 0,
                          jnp.maximum(surrogate, cfg.clip_c * advantages),
                          surrogate)
    if cfg.agg == "token":
        loss = -masked_mean(surrogate, mask)
    else:  # per-sequence mean, then batch mean
        seq = masked_mean(surrogate, mask, axis=1)
        loss = -seq.mean()
    clip_frac = masked_mean(
        (jnp.abs(ratio - 1.0) > jnp.minimum(cfg.clip_low, cfg.clip_high))
        .astype(jnp.float32), mask)
    approx_kl = masked_mean(lp_old - lp_new, mask)      # E[log p_old/p_new]
    return loss, {"clip_frac": clip_frac, "approx_kl": approx_kl,
                  "ratio_mean": masked_mean(ratio, mask)}


def kl_to_reference(lp_new, lp_ref, mask):
    """k3 estimator of KL(pi || ref): exp(r) - r - 1, r = lp_ref - lp_new."""
    r = lp_ref - lp_new
    return masked_mean(jnp.exp(r) - r - 1.0, mask)


def value_loss(values, returns, old_values, mask, clip: float = 0.2):
    v_clip = old_values + jnp.clip(values - old_values, -clip, clip)
    l1 = jnp.square(values - returns)
    l2 = jnp.square(v_clip - returns)
    return 0.5 * masked_mean(jnp.maximum(l1, l2), mask)


def entropy_bonus(entropy, mask):
    return masked_mean(entropy, mask)
