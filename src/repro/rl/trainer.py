"""RLVR trainer: GRPO / PPO / DAPO with SPEC-RL as a drop-in rollout stage.

Pipeline per step (mirrors veRL's stage order, Table 4 of the paper):
  [verification] -> [rollout] -> [assembly]   (repro.core.rollout)
  -> reward -> old-log-probs -> (values) -> adv
  -> (update-critic) -> update-actor

SPEC-RL touches ONLY the first three stages; everything downstream is the
standard algorithm — that is the paper's central compatibility claim, and the
trainer enforces it structurally (the rollout variant is a constructor
argument the update path never sees).
"""
from __future__ import annotations

import functools
import math
import random
import time
from dataclasses import dataclass, field, replace
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import RolloutCache, SpecConfig, rollout
from repro.core.lenience import FixedLenience
from repro.core.spec_rollout import RolloutBatch
from repro.data.dataset import PromptBatch, PromptDataset
from repro.data.tokenizer import EOS_ID, PAD_ID
from repro.distributed.mesh import (MeshConfig, shard_batch, shard_opt_state,
                                    shard_params)
from repro.engine.generate import GenerateConfig, positions_from_mask, score
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.rewards.verifier import batch_rewards

from .advantages import (gae_advantages, group_relative_advantages,
                         terminal_reward_to_tokens, whiten)
from .critic import forward_values, init_critic
from .losses import (PolicyLossConfig, entropy_bonus, kl_to_reference,
                     masked_mean, policy_loss, value_loss)


@dataclass(frozen=True)
class RLConfig:
    algo: str = "grpo"                # grpo|ppo|dapo
    group_size: int = 4
    prompts_per_batch: int = 8
    max_new_tokens: int = 32
    temperature: float = 1.0
    top_p: float = 1.0
    optim: adamw.AdamWConfig = adamw.AdamWConfig(lr=5e-7)
    critic_optim: adamw.AdamWConfig = adamw.AdamWConfig(lr=1e-5)
    gamma: float = 1.0
    gae_lambda: float = 0.95
    whiten_adv: bool = False
    dynamic_sampling: bool = True     # DAPO only
    max_resample_rounds: int = 3
    entropy_coef: float = 0.0

    def policy_cfg(self) -> PolicyLossConfig:
        if self.algo == "dapo":
            return PolicyLossConfig(clip_low=0.2, clip_high=0.28, clip_c=10.0,
                                    agg="token", kl_coef=0.0,
                                    entropy_coef=self.entropy_coef)
        if self.algo == "grpo":
            return PolicyLossConfig(clip_low=0.2, clip_high=0.2, clip_c=3.0,
                                    agg="seq", kl_coef=1e-4,
                                    entropy_coef=self.entropy_coef)
        return PolicyLossConfig(clip_low=0.2, clip_high=0.2, clip_c=3.0,
                                agg="seq", kl_coef=0.0,
                                entropy_coef=self.entropy_coef)


# ------------------------------------------------------------------ jit steps


@functools.partial(jax.jit, static_argnames=("cfg", "resp_start",
                                             "temperature", "top_p"))
def _old_logprobs(params, cfg, full_tokens, full_mask, resp_start: int,
                  temperature: float, top_p: float):
    sc = score(params, cfg, full_tokens, full_mask, temperature=temperature,
               top_p=top_p, return_entropy=True)
    return (sc["logprobs"][:, resp_start:], sc["entropy"][:, resp_start:])


def _actor_loss_fn(params, cfg, pcfg: PolicyLossConfig, full_tokens, full_mask,
                   resp_start, lp_old, advantages, resp_mask, ref_lp,
                   temperature, top_p, moe_lb_coef, moe_z_coef):
    from repro.engine.sampling import entropy_of, logprobs_of
    positions = positions_from_mask(full_mask)
    logits, aux = M.forward(params, cfg, full_tokens, positions)
    lp_next = logprobs_of(logits[:, :-1], full_tokens[:, 1:], temperature, top_p)
    lp_all = jnp.concatenate([jnp.zeros_like(lp_next[:, :1]), lp_next], axis=1)
    ent_next = entropy_of(logits[:, :-1], temperature)
    ent_all = jnp.concatenate([jnp.zeros_like(ent_next[:, :1]), ent_next], axis=1)
    lp_new = lp_all[:, resp_start:]
    ent = ent_all[:, resp_start:]
    loss, info = policy_loss(lp_new, lp_old, advantages, resp_mask, pcfg)
    if pcfg.kl_coef > 0.0:
        kl = kl_to_reference(lp_new, ref_lp, resp_mask)
        loss = loss + pcfg.kl_coef * kl
        info["kl_ref"] = kl
    if pcfg.entropy_coef > 0.0:
        loss = loss - pcfg.entropy_coef * entropy_bonus(ent, resp_mask)
    if "moe_lb_loss" in aux:  # MoE aux losses (if the arch has them)
        loss = loss + moe_lb_coef * aux["moe_lb_loss"] + \
            moe_z_coef * aux["moe_z_loss"]
        info["moe_lb_loss"] = aux["moe_lb_loss"]
    info["entropy"] = masked_mean(ent, resp_mask)
    return loss, info


@functools.partial(jax.jit, static_argnames=("cfg", "pcfg", "ocfg", "resp_start",
                                             "temperature", "top_p"))
def _update_actor(params, opt_state, cfg, pcfg, ocfg, full_tokens, full_mask,
                  resp_start, lp_old, advantages, resp_mask, ref_lp,
                  temperature, top_p):
    (loss, info), grads = jax.value_and_grad(_actor_loss_fn, has_aux=True)(
        params, cfg, pcfg, full_tokens, full_mask, resp_start, lp_old,
        advantages, resp_mask, ref_lp, temperature, top_p,
        cfg.router_aux_coef, cfg.router_z_coef)
    params, opt_state, oinfo = adamw.update(ocfg, params, grads, opt_state)
    info.update(oinfo)
    info["loss"] = loss
    return params, opt_state, info


@functools.partial(jax.jit, static_argnames=("cfg", "ocfg", "resp_start"))
def _update_critic(cparams, copt_state, cfg, ocfg, full_tokens, full_mask,
                   resp_start, returns, old_values, resp_mask):
    def loss_fn(p):
        v = forward_values(p, cfg, full_tokens, full_mask)[:, resp_start:]
        return value_loss(v, returns, old_values, resp_mask)

    loss, grads = jax.value_and_grad(loss_fn)(cparams)
    cparams, copt_state, oinfo = adamw.update(ocfg, cparams, grads, copt_state)
    return cparams, copt_state, {"critic_loss": loss, **oinfo}


# ------------------------------------------------------------------ collector


class Collector:
    """The collection half of the RL loop (DESIGN.md §12): dataset
    sampling, the SPEC-RL rollout cache, the lenience schedule, the
    collection PRNG stream and the DAPO dynamic-sampling resample loop —
    everything ``train_step`` needs to turn params into a rewarded batch,
    and nothing it needs to *update* them.

    The synchronous ``Trainer`` drives it in-process; the async rollout
    service (serving/rollout_service.py) drives the *same object* from the
    producer side of the disaggregated seam.  Both topologies therefore
    share one definition of a collect step — same sampling RNG, same PRNG
    split order, same cache — which is what makes the K=0 deterministic
    schedule token-identical to the synchronous path (the §12 determinism
    contract)."""

    def __init__(self, model_cfg: ModelConfig, rl: RLConfig, spec: SpecConfig,
                 dataset: PromptDataset, key, lenience_schedule=None,
                 mesh=None, tracer=None):
        self.cfg = model_cfg
        self.rl = rl
        self.spec = spec
        # lenience schedule (fixed / warmup / adaptive); adaptive closes the
        # paper's future-work item by steering |approx_kl| to a budget
        self.lenience_schedule = lenience_schedule or FixedLenience(
            spec.lenience)
        self.dataset = dataset
        self.mesh = mesh
        self.key = key
        # group_size makes the cache sibling-aware: the dataset keys slot g
        # of prompt p as p*G + g, so the §9 draft engine can index a row's
        # GRPO siblings as its n-gram corpus (cache.siblings)
        self.cache = RolloutCache(history=spec.cache_history,
                                  max_prompts=spec.cache_max_prompts,
                                  group_size=rl.group_size)
        self.gen = GenerateConfig(max_new_tokens=rl.max_new_tokens,
                                  temperature=rl.temperature, top_p=rl.top_p,
                                  eos_id=EOS_ID, pad_id=PAD_ID)
        self.gen_steps = 0            # DAPO: generation steps consumed
        self.total_generated_tokens = 0
        self._py_rng = random.Random(1234)
        from repro.obs import get_tracer
        self.tracer = tracer if tracer is not None else get_tracer()

    # ---------------------------------------------------------------- §11

    def _stage(self, name: str, t0: float, times: Dict[str, float],
               key: str, step: int) -> float:
        """Close a collect stage: record its duration under ``key``, emit a
        'trainer'-lane span and a train.* histogram sample."""
        from repro.obs import get_registry
        t1 = time.perf_counter()
        times[key] = t1 - t0
        if self.tracer.enabled:
            self.tracer.complete(name, "trainer", t0, t1, cat="train",
                                 step=step)
        get_registry().observe(f"train.{name}_s", t1 - t0)
        return t1

    # -------------------------------------------------------------- rollout

    def sample(self, epoch: int,
               batch: Optional[PromptBatch] = None) -> PromptBatch:
        """Epoch-keyed batch draw from the shared python RNG stream (the
        stream both topologies replay in lockstep)."""
        if batch is not None:
            return batch
        return self.dataset.sample_batch(self._py_rng,
                                         self.rl.prompts_per_batch,
                                         self.rl.group_size, epoch=epoch)

    def rollout_once(self, params, batch: PromptBatch,
                     epoch: int) -> RolloutBatch:
        self.key, sub = jax.random.split(self.key)
        cur_l = float(self.lenience_schedule(epoch))
        if cur_l != self.spec.lenience and self.spec.variant == "spec":
            self.spec = replace(self.spec, lenience=cur_l)
        rb = rollout(params, self.cfg, self.gen, self.spec,
                     jnp.asarray(batch.tokens), jnp.asarray(batch.mask),
                     batch.cache_keys, self.cache, sub, epoch,
                     mesh=self.mesh)
        self.gen_steps += 1
        self.total_generated_tokens += rb.metrics["n_generated"]
        return rb

    def collect(self, params, batch: PromptBatch, epoch: int
                ) -> Tuple[PromptBatch, RolloutBatch, np.ndarray,
                           Dict[str, float]]:
        """Rollout + reward (+ DAPO dynamic sampling) under ``params``."""
        t0 = time.perf_counter()
        rb = self.rollout_once(params, batch, epoch)
        t_reward0 = time.perf_counter()
        rewards = batch_rewards(rb.response, rb.length, batch.answers)
        rtimes: Dict[str, float] = {}
        self._stage("reward", t_reward0, rtimes, "reward_time", epoch)
        reward_time = rtimes["reward_time"]

        if self.rl.algo == "dapo" and self.rl.dynamic_sampling:
            G = self.rl.group_size
            for _ in range(self.rl.max_resample_rounds):
                g = rewards.reshape(-1, G)
                degenerate = (g.std(axis=1) == 0.0)
                if not degenerate.any():
                    break
                # resample the degenerate prompt groups with fresh rollouts
                keep = ~degenerate
                idxs = np.where(degenerate)[0]
                sub_batch = _subset_batch(batch, idxs, G)
                rb2 = self.rollout_once(params, sub_batch, epoch)
                r2 = batch_rewards(rb2.response, rb2.length, sub_batch.answers)
                rb = _merge_rollouts(rb, rb2, idxs, G)
                rewards = rewards.copy()
                for j, gi in enumerate(idxs):
                    rewards[gi * G:(gi + 1) * G] = r2[j * G:(j + 1) * G]

        stage_times = dict(rb.metrics)
        stage_times["reward_time"] = reward_time
        self._stage("collect", t0, stage_times, "collect_time", epoch)
        return batch, rb, rewards, stage_times


# ------------------------------------------------------------------ trainer


class Trainer:
    def __init__(self, model_cfg: ModelConfig, rl: RLConfig, spec: SpecConfig,
                 dataset: PromptDataset, key,
                 critic_cfg: Optional[ModelConfig] = None,
                 lenience_schedule=None, mesh=None, watchdog=None,
                 tracer=None, alerts=None):
        self.cfg = model_cfg
        self.rl = rl
        # mesh (DESIGN.md §8): a MeshConfig (or prebuilt Mesh) shards params
        # and optimizer moments by the param_spec rules and batch rows over
        # the data axes; rollout AND the update steps then compile SPMD on
        # one mesh with no host round-trips between stages.  ``None`` (or a
        # config that does not fit the host's devices) is the single-device
        # path, token-identical by the §8 contract.
        if isinstance(mesh, MeshConfig):
            mesh = mesh.build()
        self.mesh = mesh
        k1, k2, k3, coll_key = jax.random.split(key, 4)
        # §12: collection state lives in the Collector — the synchronous
        # path drives it here, the async rollout service drives the same
        # object from the producer side
        self.collector = Collector(model_cfg, rl, spec, dataset, coll_key,
                                   lenience_schedule=lenience_schedule,
                                   mesh=mesh, tracer=tracer)
        self.params = shard_params(mesh, model_cfg, M.init_lm(k1, model_cfg))
        self.opt_state = shard_opt_state(mesh, model_cfg, self.params,
                                         adamw.init(self.params))
        self.pcfg = rl.policy_cfg()
        self.ref_params = shard_params(
            mesh, model_cfg, jax.tree.map(jnp.copy, self.params)) \
            if self.pcfg.kl_coef > 0 else None
        self.critic_cfg = critic_cfg or model_cfg
        if rl.algo == "ppo":
            self.critic_params = shard_params(
                mesh, self.critic_cfg, init_critic(k2, self.critic_cfg))
            self.critic_opt_state = shard_opt_state(
                mesh, self.critic_cfg, self.critic_params,
                adamw.init(self.critic_params))
        else:
            self.critic_params = None
        self.step_idx = 0
        self.history: List[Dict[str, float]] = []
        # §10 watchdog (rl/watchdog.py): snapshots on healthy steps,
        # restore-last-good + skip-the-batch on non-finite loss or a
        # stalled rollout stage.  None = no monitoring (the default).
        self.watchdog = watchdog
        # §14 alerts (obs/alerts.py): an AlertManager evaluated on every
        # step's flat metrics; events trace on the 'alerts' lane and, when
        # a watchdog rides along, feed its degradation counters.
        self.alerts = alerts
        if alerts is not None and alerts.watchdog is None:
            alerts.watchdog = watchdog
        # §11 observatory: stage spans land on the 'trainer' lane; stage
        # latencies feed train.* histograms in the global registry.  The
        # default NULL_TRACER records nothing and every stamp below reuses
        # a perf_counter reading the times dict already takes.
        from repro.obs import get_tracer
        self.tracer = tracer if tracer is not None else get_tracer()
        self.last_rb: Optional[RolloutBatch] = None

    # ------------------------------------------- collection-state delegation
    # The watchdog snapshot/restore path, tests and benches address
    # collection state through the trainer (tr.cache, tr.key, ...); the
    # state itself lives in the Collector so the async topology can share
    # it.  Plain delegating properties keep both views one object.

    @property
    def spec(self) -> SpecConfig:
        return self.collector.spec

    @spec.setter
    def spec(self, v) -> None:
        self.collector.spec = v

    @property
    def dataset(self) -> PromptDataset:
        return self.collector.dataset

    @property
    def gen(self) -> GenerateConfig:
        return self.collector.gen

    @property
    def lenience_schedule(self):
        return self.collector.lenience_schedule

    @property
    def cache(self) -> RolloutCache:
        return self.collector.cache

    @cache.setter
    def cache(self, v) -> None:
        self.collector.cache = v

    @property
    def key(self):
        return self.collector.key

    @key.setter
    def key(self, v) -> None:
        self.collector.key = v

    @property
    def gen_steps(self) -> int:
        return self.collector.gen_steps

    @gen_steps.setter
    def gen_steps(self, v) -> None:
        self.collector.gen_steps = v

    @property
    def total_generated_tokens(self):
        return self.collector.total_generated_tokens

    @total_generated_tokens.setter
    def total_generated_tokens(self, v) -> None:
        self.collector.total_generated_tokens = v

    @property
    def _py_rng(self) -> random.Random:
        return self.collector._py_rng

    # ---------------------------------------------------------------- §11

    def _stage(self, name: str, t0: float, times: Dict[str, float],
               key: str) -> float:
        """Close a trainer stage: record its duration under ``key``, emit a
        'trainer'-lane span and a train.* histogram sample.  Returns the end
        stamp (= the next stage's natural start)."""
        from repro.obs import get_registry
        t1 = time.perf_counter()
        times[key] = t1 - t0
        if self.tracer.enabled:
            self.tracer.complete(name, "trainer", t0, t1, cat="train",
                                 step=self.step_idx)
        get_registry().observe(f"train.{name}_s", t1 - t0)
        return t1

    # -------------------------------------------------------------- rollout

    def _collect(self, batch: PromptBatch) -> Tuple[PromptBatch, RolloutBatch,
                                                    np.ndarray, Dict[str, float]]:
        """Rollout + reward (+ DAPO dynamic sampling) — the in-process
        (synchronous) drive of the shared Collector."""
        return self.collector.collect(self.params, batch, self.step_idx)

    # -------------------------------------------------------------- training
    def train_step(self, batch: Optional[PromptBatch] = None) -> Dict[str, float]:
        batch = self.collector.sample(self.step_idx, batch)
        t_step0 = time.perf_counter()
        batch, rb, rewards, times = self._collect(batch)
        return self.optimize(rb, rewards, times, t_step0=t_step0)

    def optimize(self, rb: RolloutBatch, rewards: np.ndarray,
                 times: Dict[str, float], *, behaviour_lp=None,
                 is_clip: Optional[float] = None,
                 extra_metrics: Optional[Dict[str, float]] = None,
                 t_step0: Optional[float] = None) -> Dict[str, float]:
        """The optimization half of ``train_step``: old-logprobs → (ref) →
        advantages → (critic) → actor update, on an already-collected and
        already-rewarded rollout.

        The synchronous path calls it back-to-back with ``_collect``; the
        async consumer (rl/async_loop.py) calls it on buffered
        trajectories.  ``behaviour_lp`` (with cap ``is_clip``) switches on
        the §12 truncated-importance-weight correction for trajectories up
        to K versions stale; ``None`` — the synchronous default — leaves
        the update bit-identical to the pre-split trainer."""
        if t_step0 is None:
            t_step0 = time.perf_counter()
        self.last_rb = rb
        B, P = rb.prompt.shape
        N = rb.response.shape[1]

        full_tokens = jnp.asarray(np.concatenate([rb.prompt, rb.response], 1))
        full_mask = jnp.asarray(np.concatenate([rb.prompt_mask,
                                                rb.response_mask], 1))
        resp_mask = jnp.asarray(rb.response_mask)
        lengths = jnp.asarray(rb.length)
        rew = jnp.asarray(rewards)
        if self.mesh is not None:
            # batch rows over the data axes: old-logprob / value / update
            # steps compile SPMD against the sharded params — rollout and
            # train run on the same mesh with no host re-layout between
            full_tokens, full_mask, resp_mask, lengths, rew = shard_batch(
                self.mesh, (full_tokens, full_mask, resp_mask, lengths, rew))

        # ---- old log-probs (veRL stage; ratio == 1 at the first epoch) ----
        t0 = time.perf_counter()
        lp_old, ent_old = _old_logprobs(self.params, self.cfg, full_tokens,
                                        full_mask, P, self.rl.temperature,
                                        self.rl.top_p)
        lp_old = jax.block_until_ready(lp_old)
        self._stage("old_logprob", t0, times, "old_logprob_time")

        ref_lp = jnp.zeros_like(lp_old)
        if self.ref_params is not None:
            t0 = time.perf_counter()
            ref_lp, _ = _old_logprobs(self.ref_params, self.cfg, full_tokens,
                                      full_mask, P, self.rl.temperature,
                                      self.rl.top_p)
            self._stage("ref", t0, times, "ref_time")

        # ---- advantages ----------------------------------------------------
        t0 = time.perf_counter()
        old_values = returns = None
        if self.rl.algo == "ppo":
            tv = time.perf_counter()
            values = forward_values(self.critic_params, self.critic_cfg,
                                    full_tokens, full_mask)[:, P:]
            self._stage("values", tv, times, "values_time")
            rew_tok = terminal_reward_to_tokens(rew, lengths, N)
            adv, returns = gae_advantages(rew_tok, values, resp_mask,
                                          gamma=self.rl.gamma,
                                          lam=self.rl.gae_lambda)
            old_values = values
            if self.rl.whiten_adv:
                adv = whiten(adv, resp_mask)
        else:
            scalar_adv = group_relative_advantages(rew, self.rl.group_size)
            adv = scalar_adv[:, None] * resp_mask.astype(jnp.float32)
        if behaviour_lp is not None:
            # §12 bounded-staleness correction: the trajectory was sampled
            # under an older policy, so the PPO ratio's anchor (lp_old,
            # scored under the *current* params) is off-policy relative to
            # the behaviour distribution.  Truncated per-token importance
            # weights w = min(ρ̄, exp(lp_now − lp_behaviour)) fold into the
            # advantages — losses.policy_loss sees its standard inputs, so
            # the paper's compatibility claim extends across the async seam.
            blp = jnp.asarray(behaviour_lp)
            if self.mesh is not None:
                blp = shard_batch(self.mesh, blp)
            cap = float(is_clip) if is_clip is not None else 2.0
            w = jnp.minimum(cap, jnp.exp(lp_old - blp)) \
                * resp_mask.astype(jnp.float32)
            adv = adv * w
            times["is_weight_mean"] = float(masked_mean(w, resp_mask))
        self._stage("adv", t0, times, "adv_time")

        # ---- updates -------------------------------------------------------
        if self.rl.algo == "ppo":
            t0 = time.perf_counter()
            self.critic_params, self.critic_opt_state, cinfo = _update_critic(
                self.critic_params, self.critic_opt_state, self.critic_cfg,
                self.rl.critic_optim, full_tokens, full_mask, P, returns,
                old_values, resp_mask)
            self._stage("update_critic", t0, times, "update_critic_time")
        else:
            cinfo = {}

        t0 = time.perf_counter()
        self.params, self.opt_state, info = _update_actor(
            self.params, self.opt_state, self.cfg, self.pcfg, self.rl.optim,
            full_tokens, full_mask, P, lp_old, adv, resp_mask, ref_lp,
            self.rl.temperature, self.rl.top_p)
        jax.block_until_ready(info["loss"])
        t_end = self._stage("update_actor", t0, times, "update_actor_time")
        from repro.obs import get_registry
        get_registry().observe("train.train_step_s", t_end - t_step0)
        if self.tracer.enabled:
            # whole-step span encloses the stage spans on the same lane
            self.tracer.complete("train_step", "trainer", t_step0, t_end,
                                 cat="train", step=self.step_idx)

        self.lenience_schedule.update(abs(float(info.get("approx_kl", 0.0))))
        metrics = {
            "step": self.step_idx,
            "lenience": float(self.spec.lenience),
            "reward_mean": float(rewards.mean()),
            "response_len_mean": float(np.asarray(rb.length).mean()),
            "total_generated_tokens": self.total_generated_tokens,
            "gen_steps": self.gen_steps,
            **{k: float(v) for k, v in info.items()},
            **{k: float(v) for k, v in cinfo.items()},
            **{k: float(v) for k, v in times.items() if isinstance(v, (int, float))},
        }
        if extra_metrics:
            # async-loop provenance (staleness, buffer counters, mode) joins
            # the flat namespace BEFORE the watchdog sees the step
            metrics.update({k: float(v) for k, v in extra_metrics.items()})
        # §11 schema fix: the step log is routed through a MetricsRegistry
        # so the trainer shares the audited flat-float namespace with
        # SlotEngine.stats()/MeshSlotServer.stats() (one as_dict view, no
        # ad-hoc key drift between surfaces)
        from repro.obs import (MetricsRegistry, get_decision_log, get_ledger)
        led = get_ledger()
        if led.enabled:
            # §14: cumulative provenance counts join the step log — the
            # savings-attribution report divides exactly these numbers —
            # and mirror into the global registry so the events.jsonl dump
            # feeds `launch.analysis attrib` offline
            from repro.obs import get_registry
            greg = get_registry()
            for cname, nv in led.counts_dict().items():
                metrics[f"ledger_tokens_{cname}"] = float(nv)
                greg.set(f"ledger.tokens_{cname}", float(nv), agg="max")
            metrics["ledger_finalized"] = float(led.finalized)
            metrics["ledger_violations"] = float(led.violations)
        metrics = MetricsRegistry.from_flat(metrics).as_dict()
        if self.alerts is not None:
            # evaluated on the flat step metrics BEFORE the watchdog so a
            # critical alert's counters are visible to the same step log
            self.alerts.evaluate(metrics, self.step_idx)
            metrics.update(self.alerts.as_dict())
        if self.watchdog is not None:
            # may restore params/opt_state/cache to the last snapshot (the
            # poisoned update is undone; step_idx still advances below, so
            # the bad batch is skipped, not replayed) — and always folds
            # its counters into the step metrics
            self.watchdog.after_step(self, metrics)
        dec = get_decision_log()
        if dec.enabled:
            # decision shards hit disk once per train step, not per record
            dec.flush()
        self.history.append(metrics)
        self.step_idx += 1
        return metrics

    def train(self, num_steps: int, log_every: int = 10,
              callback=None) -> List[Dict[str, float]]:
        for _ in range(num_steps):
            m = self.train_step()
            if callback and (m["step"] % log_every == 0):
                callback(m)
        return self.history


# ------------------------------------------------------------------ helpers


def _subset_batch(batch: PromptBatch, group_idxs: np.ndarray, G: int
                  ) -> PromptBatch:
    rows = np.concatenate([np.arange(g * G, (g + 1) * G) for g in group_idxs])
    return PromptBatch(
        tokens=batch.tokens[rows], mask=batch.mask[rows],
        cache_keys=[batch.cache_keys[r] for r in rows],
        answers=[batch.answers[r] for r in rows],
        problem_ids=[batch.problem_ids[r] for r in rows],
        epoch=batch.epoch)


def _merge_rollouts(rb: RolloutBatch, rb2: RolloutBatch, group_idxs: np.ndarray,
                    G: int) -> RolloutBatch:
    rows = np.concatenate([np.arange(g * G, (g + 1) * G) for g in group_idxs])
    out = RolloutBatch(
        prompt=rb.prompt.copy(), prompt_mask=rb.prompt_mask.copy(),
        response=rb.response.copy(), response_mask=rb.response_mask.copy(),
        behaviour_logprobs=rb.behaviour_logprobs.copy(),
        length=rb.length.copy(), metrics=dict(rb.metrics))
    out.response[rows] = rb2.response
    out.response_mask[rows] = rb2.response_mask
    out.behaviour_logprobs[rows] = rb2.behaviour_logprobs
    out.length[rows] = rb2.length
    for k in ("n_generated", "n_reused"):
        out.metrics[k] = rb.metrics.get(k, 0) + rb2.metrics.get(k, 0)
    return out
