"""Bounded, watermarked trajectory buffer (DESIGN.md §12).

The seam between the rollout service (producer) and the async trainer
(consumer).  Capacity is hard-bounded with two levels of backpressure:

* at the **high watermark** the producer throttles — ``should_throttle``
  turns true and the service skips its tick (counted, never silent);
* at **capacity** a forced ``put`` sheds the *oldest* trajectory — stale
  data is the cheapest to lose, because anything still in the buffer can
  be re-verified, and anything shed is simply regenerated fresher.

Every trajectory is tagged with the policy version it was sampled under
(the staleness bookkeeping the consumer's K-window runs on) and a
per-producer sequence number; version tags must be monotone per producer
(asserted — a producer that time-travels is a bug, not a load condition).

Counters reconcile by construction (property-tested):

    submitted == consumed + shed + occupancy

``state_dict``/``load_state_dict`` round-trip the full buffer — entries,
order, tags and counters — through the checkpoint/io all-array pytree
writer, so kill-and-resume of the async pair restores the exact seam
state (§10 discipline).
"""
from __future__ import annotations

import json
from collections import deque
from dataclasses import dataclass
from typing import Deque, Dict, Optional

import numpy as np

from repro.core.spec_rollout import RolloutBatch
from repro.data.dataset import PromptBatch


def _enc(s: str) -> np.ndarray:
    return np.frombuffer(s.encode("utf-8"), np.uint8).copy()


def _dec(arr) -> str:
    return bytes(np.asarray(arr, np.uint8).tolist()).decode("utf-8")


@dataclass
class Trajectory:
    """One collected batch: the prompts it came from, the rollout, its
    rewards, and the provenance tags the async consumer schedules by."""
    batch: PromptBatch
    rb: RolloutBatch
    rewards: np.ndarray
    version: int                  # policy version it was sampled under
    producer: int = 0
    seq: int = 0                  # buffer-assigned, monotone

    # -------------------------------------------------- exact serialization

    def to_state(self) -> Dict:
        b, rb = self.batch, self.rb
        return {
            "tags": {"version": np.int64(self.version),
                     "producer": np.int64(self.producer),
                     "seq": np.int64(self.seq)},
            "rewards": np.asarray(self.rewards, np.float32),
            "batch": {"tokens": np.asarray(b.tokens, np.int32),
                      "mask": np.asarray(b.mask, bool),
                      "cache_keys": np.asarray(b.cache_keys, np.int32),
                      "answers": np.asarray(b.answers, np.int32),
                      "problem_ids": np.asarray(b.problem_ids, np.int32),
                      "epoch": np.int64(b.epoch)},
            "rb": {"prompt": np.asarray(rb.prompt, np.int32),
                   "prompt_mask": np.asarray(rb.prompt_mask, bool),
                   "response": np.asarray(rb.response, np.int32),
                   "response_mask": np.asarray(rb.response_mask, bool),
                   "behaviour_logprobs":
                       np.asarray(rb.behaviour_logprobs, np.float32),
                   "length": np.asarray(rb.length, np.int32),
                   # float metrics ride as encoded json (uint8 leaf): keys
                   # vary per variant and the pytree writer wants arrays
                   "metrics": _enc(json.dumps(
                       {k: float(v) for k, v in rb.metrics.items()},
                       sort_keys=True))},
        }

    @classmethod
    def from_state(cls, st: Dict) -> "Trajectory":
        b, r = st["batch"], st["rb"]
        batch = PromptBatch(
            tokens=np.asarray(b["tokens"], np.int32),
            mask=np.asarray(b["mask"], bool),
            cache_keys=[int(x) for x in np.asarray(b["cache_keys"])],
            answers=[int(x) for x in np.asarray(b["answers"])],
            problem_ids=[int(x) for x in np.asarray(b["problem_ids"])],
            epoch=int(b["epoch"]))
        rb = RolloutBatch(
            prompt=np.asarray(r["prompt"], np.int32),
            prompt_mask=np.asarray(r["prompt_mask"], bool),
            response=np.asarray(r["response"], np.int32),
            response_mask=np.asarray(r["response_mask"], bool),
            behaviour_logprobs=np.asarray(r["behaviour_logprobs"],
                                          np.float32),
            length=np.asarray(r["length"], np.int32),
            metrics=json.loads(_dec(r["metrics"])))
        return cls(batch=batch, rb=rb,
                   rewards=np.asarray(st["rewards"], np.float32),
                   version=int(st["tags"]["version"]),
                   producer=int(st["tags"]["producer"]),
                   seq=int(st["tags"]["seq"]))


class TrajBuffer:
    """FIFO of ``Trajectory`` with watermark backpressure and shed-oldest
    overflow (all counted)."""

    def __init__(self, capacity: int = 8,
                 high_watermark: Optional[int] = None):
        assert capacity >= 1, capacity
        self.capacity = int(capacity)
        hw = capacity if high_watermark is None else int(high_watermark)
        assert 1 <= hw <= capacity, (hw, capacity)
        self.high_watermark = hw
        self._q: Deque[Trajectory] = deque()
        self.submitted = 0
        self.consumed = 0
        self.shed = 0
        self.throttled = 0
        self.occupancy_peak = 0
        self._seq = 0
        self._last_version: Dict[int, int] = {}   # per-producer monotonicity

    # ------------------------------------------------------------- queries

    def __len__(self) -> int:
        return len(self._q)

    @property
    def occupancy(self) -> int:
        return len(self._q)

    def should_throttle(self) -> bool:
        """Producer-side gate: true at/above the high watermark.  The
        caller counts the skipped tick via ``note_throttled``."""
        return len(self._q) >= self.high_watermark

    def note_throttled(self) -> None:
        self.throttled += 1
        self._emit_obs()

    # -------------------------------------------------------------- moves

    def put(self, traj: Trajectory) -> Optional[Trajectory]:
        """Append; returns the shed trajectory if capacity forced one out.

        A forced put past a full buffer sheds the OLDEST entry — the
        staleness ordering makes that the principled victim."""
        last = self._last_version.get(traj.producer)
        assert last is None or traj.version >= last, \
            f"producer {traj.producer} version went backwards: " \
            f"{last} -> {traj.version}"
        self._last_version[traj.producer] = traj.version
        shed = None
        if len(self._q) >= self.capacity:
            shed = self._q.popleft()
            self.shed += 1
        traj.seq = self._seq
        self._seq += 1
        self._q.append(traj)
        self.submitted += 1
        self.occupancy_peak = max(self.occupancy_peak, len(self._q))
        self._emit_obs()
        return shed

    def get(self) -> Optional[Trajectory]:
        """Pop the oldest trajectory (None when starved)."""
        if not self._q:
            return None
        t = self._q.popleft()
        self.consumed += 1
        self._emit_obs()
        return t

    def peek_version(self) -> Optional[int]:
        return self._q[0].version if self._q else None

    # ----------------------------------------------------------------- obs

    def _emit_obs(self) -> None:
        from repro.obs import get_registry
        reg = get_registry()
        reg.set("async.buffer_occupancy", float(len(self._q)))
        reg.set("async.buffer_occupancy_peak", float(self.occupancy_peak),
                agg="max")

    def counters(self, prefix: str = "buffer_") -> Dict[str, float]:
        return {f"{prefix}submitted": float(self.submitted),
                f"{prefix}consumed": float(self.consumed),
                f"{prefix}shed": float(self.shed),
                f"{prefix}throttled": float(self.throttled),
                f"{prefix}occupancy": float(len(self._q)),
                f"{prefix}occupancy_peak": float(self.occupancy_peak)}

    def check_invariants(self) -> None:
        assert len(self._q) <= self.capacity
        assert self.submitted == self.consumed + self.shed + len(self._q), \
            self.counters()

    # -------------------------------------------- exact state (§10 resume)

    def state_dict(self) -> Dict:
        ents = {str(i): t.to_state() for i, t in enumerate(self._q)}
        prods = sorted(self._last_version)
        return {
            "entries": ents,
            "scalars": {
                "capacity": np.int64(self.capacity),
                "high_watermark": np.int64(self.high_watermark),
                "submitted": np.int64(self.submitted),
                "consumed": np.int64(self.consumed),
                "shed": np.int64(self.shed),
                "throttled": np.int64(self.throttled),
                "occupancy_peak": np.int64(self.occupancy_peak),
                "seq": np.int64(self._seq),
            },
            "producers": np.asarray(prods, np.int64).reshape(-1),
            "producer_versions": np.asarray(
                [self._last_version[p] for p in prods], np.int64).reshape(-1),
        }

    def load_state_dict(self, st: Dict) -> None:
        sc = st["scalars"]
        self.capacity = int(sc["capacity"])
        self.high_watermark = int(sc["high_watermark"])
        self.submitted = int(sc["submitted"])
        self.consumed = int(sc["consumed"])
        self.shed = int(sc["shed"])
        self.throttled = int(sc["throttled"])
        self.occupancy_peak = int(sc["occupancy_peak"])
        self._seq = int(sc["seq"])
        self._q = deque(Trajectory.from_state(st["entries"][k])
                        for k in sorted(st["entries"], key=int))
        self._last_version = {
            int(p): int(v) for p, v in zip(np.asarray(st["producers"]),
                                           np.asarray(st["producer_versions"]))}
        self.check_invariants()
