"""Trainer watchdog: restore-last-good on poisoned steps (DESIGN.md §10).

The serving layer degrades gracefully (quarantine / retry / shed), but the
*trainer* has its own failure modes the engine cannot see: a non-finite
loss (one poisoned batch can NaN the params through the update), or a
rollout stage that stalls far past its normal duration.  The watchdog
wraps ``train_step`` output:

* on a healthy step, it snapshots trainer state (params, optimizer
  moments, PRNG key, critic, rollout cache, step counters) on a fixed
  cadence through ``checkpoint/io`` — atomic files, ``latest`` pointer
  flipped last, so a crash mid-snapshot keeps the previous one live;
* on a poisoned step (non-finite loss/reward, or ``collect_time`` above
  the stall threshold), it restores the last snapshot and deliberately
  does NOT roll the step counter back — the dataset's epoch-keyed
  sampling moves on, so the poisoned batch is skipped rather than
  replayed into the same failure.

Stall detection is adaptive as well as absolute (§11): beyond the fixed
``max_collect_time`` ceiling, a step is stalled when its collect time
exceeds ``stall_p95_mult`` × the p95 of the run's own healthy collect
times (a log-bucketed ``obs.Histogram``; armed only once
``stall_min_samples`` healthy steps have been seen, so short tests and
cold-start compile steps never trip it).

Counters (snapshots / restores / skips) ride the step metrics dict, next
to the serving layer's fault_ counters — recovery is observable from the
training log, not from log archaeology.
"""
from __future__ import annotations

import math
import os
from dataclasses import dataclass
from typing import Dict, Optional

import jax.numpy as jnp
import numpy as np

from repro.checkpoint.io import (load_pytree, load_rollout_cache, read_latest,
                                 save_pytree, save_rollout_cache,
                                 write_latest)


@dataclass(frozen=True)
class WatchdogConfig:
    checkpoint_dir: str                      # where snapshots live
    snapshot_every: int = 10                 # healthy-step snapshot cadence
    max_collect_time: float = float("inf")   # rollout-stall threshold (s)
    max_restores: int = 3                    # give up (raise) past this
    stall_p95_mult: float = 10.0             # adaptive: > mult * p95 = stall
    stall_min_samples: int = 8               # healthy samples to arm p95
    # §12 async topology: the collect stage lives in the rollout service's
    # failure domain, so a stalled *service* shows up here not as a long
    # collect_time but as the consumer waiting on fresh trajectories
    # (``service_wait_s``) or as an unbounded staleness gauge
    # (``service_staleness``).  Both route into the same restore-last-good
    # verdict as an in-process stall.
    max_service_wait: float = float("inf")   # fresh-trajectory wait cap (s)
    max_service_staleness: float = float("inf")  # staleness-gauge hard cap


class TrainWatchdog:
    """Attachable step monitor for ``rl.trainer.Trainer``."""

    def __init__(self, cfg: WatchdogConfig):
        assert cfg.checkpoint_dir, "watchdog needs a checkpoint_dir"
        self.cfg = cfg
        self.snapshots = 0
        self.restores = 0
        self.nonfinite_steps = 0
        self.stalled_steps = 0
        self.service_stalled_steps = 0
        self.skipped_no_snapshot = 0
        self.alert_events = 0                # §14 alert routing (obs/alerts)
        self.crit_alert_events = 0
        self.last_alert = ""
        from repro.obs import Histogram
        self._collect_hist = Histogram()     # healthy collect times (§11)
        self._wait_hist = Histogram()        # healthy trajectory waits (§12)

    # ------------------------------------------------------------- plumbing

    def note_alert(self, event) -> None:
        """§14 alert sink: an ``obs.alerts.AlertEvent`` fired on the step
        metrics.  Alerts are advisory — they count toward the step log (the
        degradation ladder and operators read the counters) but do not by
        themselves trigger a restore; the poison checks above stay the only
        rollback authority."""
        self.alert_events += 1
        if getattr(event, "severity", "") == "crit":
            self.crit_alert_events += 1
        self.last_alert = getattr(event, "rule", "")

    def _path(self, name: str) -> str:
        return os.path.join(self.cfg.checkpoint_dir, name)

    def snapshot(self, trainer) -> str:
        """Persist everything a restore needs; commit via the pointer."""
        state = {
            "params": trainer.params,
            "opt_state": trainer.opt_state,
            "key": trainer.key,
            "scalars": {
                "step_idx": np.int64(trainer.step_idx),
                "gen_steps": np.int64(trainer.gen_steps),
                "total_generated_tokens":
                    np.int64(trainer.total_generated_tokens),
            },
        }
        if trainer.critic_params is not None:
            state["critic_params"] = trainer.critic_params
            state["critic_opt_state"] = trainer.critic_opt_state
        name = f"watchdog_{trainer.step_idx:06d}"
        save_pytree(self._path(name), state,
                    metadata={"step": trainer.step_idx})
        save_rollout_cache(self._path(name), trainer.cache)
        write_latest(self.cfg.checkpoint_dir, name)   # the commit point
        self.snapshots += 1
        return name

    def restore(self, trainer) -> bool:
        """Reset trainer state to the last committed snapshot (params,
        moments, key, cache, counters) — step_idx deliberately NOT rolled
        back, so the poisoned batch is skipped.  False if no snapshot."""
        name = read_latest(self.cfg.checkpoint_dir)
        if name is None:
            return False
        from repro.distributed.mesh import shard_opt_state, shard_params
        tree, _ = load_pytree(self._path(name))
        trainer.params = shard_params(trainer.mesh, trainer.cfg,
                                      tree["params"])
        trainer.opt_state = shard_opt_state(trainer.mesh, trainer.cfg,
                                            trainer.params,
                                            tree["opt_state"])
        trainer.key = jnp.asarray(tree["key"])
        if "critic_params" in tree and trainer.critic_params is not None:
            trainer.critic_params = shard_params(
                trainer.mesh, trainer.critic_cfg, tree["critic_params"])
            trainer.critic_opt_state = shard_opt_state(
                trainer.mesh, trainer.critic_cfg, trainer.critic_params,
                tree["critic_opt_state"])
        trainer.cache = load_rollout_cache(self._path(name))
        trainer.gen_steps = int(tree["scalars"]["gen_steps"])
        trainer.total_generated_tokens = \
            int(tree["scalars"]["total_generated_tokens"])
        self.restores += 1
        return True

    # ------------------------------------------------------------ step hook

    def _poisoned(self, metrics: Dict[str, float]) -> Optional[str]:
        for k in ("loss", "reward_mean", "critic_loss"):
            v = metrics.get(k)
            if v is not None and not math.isfinite(float(v)):
                return "nonfinite"
        ct = metrics.get("collect_time", 0.0)
        if ct > self.cfg.max_collect_time:
            return "stall"
        # adaptive threshold: the run's own p95 rollout time (not a single
        # step) decides what "far past normal" means; p95 > 0 guards the
        # all-zero-history case
        if self._collect_hist.count >= self.cfg.stall_min_samples:
            p95 = self._collect_hist.percentile(95)
            if p95 > 0 and ct > self.cfg.stall_p95_mult * p95:
                return "stall"
        # §12: stalled rollout *service* — the async consumer had to wait
        # far past its normal fresh-trajectory cadence (absolute cap, or
        # adaptive p95 × mult over the run's own healthy waits), or the
        # staleness gauge blew past its hard cap.  Same verdict, same
        # restore-last-good recovery as an in-process collect stall.
        wt = metrics.get("service_wait_s", 0.0)
        if wt > self.cfg.max_service_wait:
            return "service_stall"
        if self._wait_hist.count >= self.cfg.stall_min_samples:
            p95 = self._wait_hist.percentile(95)
            if p95 > 0 and wt > self.cfg.stall_p95_mult * p95:
                return "service_stall"
        if metrics.get("service_staleness", 0.0) > \
                self.cfg.max_service_staleness:
            return "service_stall"
        return None

    def after_step(self, trainer, metrics: Dict[str, float]) -> None:
        """Call once per train_step with the step's metrics dict (mutated
        in place with watchdog counters and the recovery verdict)."""
        why = self._poisoned(metrics)
        if why is None:
            ct = float(metrics.get("collect_time", 0.0))
            if ct > 0:
                self._collect_hist.record(ct)    # healthy samples only
            wt = float(metrics.get("service_wait_s", 0.0))
            if wt > 0:
                self._wait_hist.record(wt)
            if self.snapshots == 0 or \
                    trainer.step_idx % max(1, self.cfg.snapshot_every) == 0:
                self.snapshot(trainer)
        else:
            if why == "nonfinite":
                self.nonfinite_steps += 1
            elif why == "service_stall":
                self.service_stalled_steps += 1
            else:
                self.stalled_steps += 1
            if self.restores >= self.cfg.max_restores:
                raise RuntimeError(
                    f"watchdog: {why} step and restore budget "
                    f"({self.cfg.max_restores}) exhausted")
            if self.restore(trainer):
                metrics["watchdog_restored"] = 1.0
                from repro.obs import get_tracer
                get_tracer().event("watchdog_restore", "trainer",
                                   cat="fault", reason=why,
                                   step=trainer.step_idx)
            else:
                # nothing to restore yet — record the skip; the poisoned
                # update stands but the batch still advances past
                self.skipped_no_snapshot += 1
        metrics.update(self.as_dict())

    def as_dict(self, prefix: str = "watchdog_") -> Dict[str, float]:
        return {f"{prefix}snapshots": float(self.snapshots),
                f"{prefix}restores": float(self.restores),
                f"{prefix}nonfinite_steps": float(self.nonfinite_steps),
                f"{prefix}stalled_steps": float(self.stalled_steps),
                f"{prefix}service_stalled_steps":
                    float(self.service_stalled_steps),
                f"{prefix}skipped_no_snapshot":
                    float(self.skipped_no_snapshot),
                f"{prefix}alert_events": float(self.alert_events),
                f"{prefix}crit_alert_events": float(self.crit_alert_events),
                f"{prefix}collect_p95": self._collect_hist.percentile(95),
                f"{prefix}service_wait_p95": self._wait_hist.percentile(95)}
