"""Continuous-batching rollout server (DESIGN.md §6).

A slot-based serving layer between the engine and its two consumers:

- request:     request/response dataclasses, QUEUED → PREFILLING →
               DECODING → DONE lifecycle
- scheduler:   admission queue, slot free-list, occupancy metrics
- engine_loop: persistent decode batch over dense caches with in-place slot
               replacement (cache_slot_write kernel) and speculative-prefix
               admission (verify_and_prefill + cache_gather)
- rl_adapter:  drains an RL training batch through the scheduler —
               ``rollout(..., spec.backfill='slots')`` straggler backfill
- mesh_server: one scheduler per data shard over model-only submeshes with
               shard-local admission and a gathered metrics view (§8)
- block_table: §13 paged-KV host bookkeeping — refcounted BlockAllocator
               over a fixed pool of KV blocks (free-list, CoW forks,
               conservation invariants, exact state round-trip)
- paged_engine: the SlotEngine over a paged block pool — dense admission
               re-paged at the slot write, copy-on-write GRPO prompt
               sharing (one prefill + one physical prompt copy per group),
               pool-pressure admission capping and load shedding
- faults:      deterministic fault injection (§10) — seeded FaultPlans the
               engine consults at chunk boundaries; with the hardening in
               request/scheduler/engine_loop (deadlines, bounded retry,
               backpressure, quarantine, exact kill-and-resume)
- rollout_service: the §12 async producer — drives the shared trainer
               Collector continuously, tags trajectories with the policy
               version, feeds the bounded traj_buffer under backpressure;
               WeightSync is its versioned, retrying (core/backoff)
               weight-publication channel
"""
from .block_table import BlockAllocator, PoolExhausted, identity_table
from .engine_loop import SlotEngine
from .faults import EngineKilled, FaultEvent, FaultPlan, seeded_plan
from .mesh_server import MeshSlotServer, make_slot_engine
from .paged_engine import PagedSlotEngine
from .request import Request, Response
from .rollout_service import RolloutService, SyncFailed, WeightSync
from .scheduler import SlotScheduler
