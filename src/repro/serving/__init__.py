"""Continuous-batching rollout server (DESIGN.md §6).

A slot-based serving layer between the engine and its two consumers:

- request:     request/response dataclasses, QUEUED → PREFILLING →
               DECODING → DONE lifecycle
- scheduler:   admission queue, slot free-list, occupancy metrics
- engine_loop: persistent decode batch over dense caches with in-place slot
               replacement (cache_slot_write kernel) and speculative-prefix
               admission (verify_and_prefill + cache_gather)
- rl_adapter:  drains an RL training batch through the scheduler —
               ``rollout(..., spec.backfill='slots')`` straggler backfill
"""
from .engine_loop import SlotEngine
from .request import Request, Response
from .scheduler import SlotScheduler
