"""Block allocator for the paged KV cache (DESIGN.md §13).

The paged layout replaces each row's dense ``(S, D)`` cache stripe with a
pool of fixed-size KV blocks plus a per-row *block table* mapping logical
block index → physical block id.  This module owns the host-side pool
bookkeeping: a LIFO free list, per-block refcounts, and the copy-on-write
(CoW) primitives the slot engine uses to share one physical prompt copy
across the G sibling rollouts of a GRPO group.

Conventions:

* **Block 0 is the sink.**  It is never allocated and its refcount is
  pinned; every unmapped block-table entry points at it.  Clamped writes
  from idle / finished rows and the dead-split DMA redirect in the decode
  kernel both land there, so recycled blocks can never be corrupted by a
  stale table.  Sink contents are garbage by construction and always masked
  (the dense ``pos`` array still gates attention with ``pos == -1``).

* **Refcounts implement CoW.**  ``share`` bumps a block's refcount (a
  follower mapping its group leader's prompt blocks); ``fork`` is the
  write-path dual — called when a row is about to write into a block it
  does not own exclusively, it allocates a fresh block, drops one ref on
  the shared one, and reports the (old, new) pair so the engine can issue
  the device copy.

* **Conservation.**  ``free + in_use + 1 (sink) == num_blocks`` always;
  ``check()`` asserts it and the hypothesis suite drives it.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np


class PoolExhausted(RuntimeError):
    """Raised by ``alloc`` when the free list cannot cover a request."""


class BlockAllocator:
    """Free-list + refcount bookkeeping for one physical KV block pool.

    Pure host-side numpy/python — the device never sees this object, only
    the int32 block tables it hands out.
    """

    SINK = 0  # reserved garbage block; never allocated, never freed

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 2:
            raise ValueError(f"pool needs >= 2 blocks (1 sink), got {num_blocks}")
        self.num_blocks = int(num_blocks)
        self.block_size = int(block_size)
        # LIFO free list over blocks 1..num_blocks-1 (0 is the sink)
        self._free: List[int] = list(range(self.num_blocks - 1, 0, -1))
        self.refcount = np.zeros(self.num_blocks, dtype=np.int32)
        self.refcount[self.SINK] = 1  # pinned
        # §11 counters (monotonic except blocks_in_use / peak gauge pair)
        self.cow_forks = 0
        self.alloc_failures = 0
        self.shared_prompt_bytes_saved = 0
        self.peak_blocks_in_use = 0

    # ------------------------------------------------------------- queries

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def blocks_in_use(self) -> int:
        return self.num_blocks - 1 - len(self._free)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def check(self) -> None:
        """Assert the conservation + refcount invariants."""
        assert self.blocks_in_use + self.free_blocks + 1 == self.num_blocks
        assert self.refcount[self.SINK] >= 1
        live = np.flatnonzero(self.refcount[1:]) + 1
        assert len(live) == self.blocks_in_use, (live, self.blocks_in_use)
        assert not set(live.tolist()) & set(self._free)

    # ---------------------------------------------------------- lifecycle

    def alloc(self, n: int = 1) -> List[int]:
        """Pop ``n`` fresh blocks (refcount 1 each); all-or-nothing."""
        if n > len(self._free):
            self.alloc_failures += 1
            raise PoolExhausted(
                f"need {n} blocks, {len(self._free)} free of {self.num_blocks}")
        out = [self._free.pop() for _ in range(n)]
        self.refcount[out] += 1
        self.peak_blocks_in_use = max(self.peak_blocks_in_use, self.blocks_in_use)
        return out

    def share(self, block: int) -> int:
        """Add a reference to an allocated block (CoW prompt sharing)."""
        assert block != self.SINK and self.refcount[block] > 0, block
        self.refcount[block] += 1
        return block

    def free(self, block: int) -> None:
        """Drop one reference; the block returns to the pool at zero."""
        if block == self.SINK:
            return
        assert self.refcount[block] > 0, f"double free of block {block}"
        self.refcount[block] -= 1
        if self.refcount[block] == 0:
            self._free.append(block)

    def free_table(self, table) -> None:
        """Drop one reference per non-sink entry of a row's block table."""
        for b in np.asarray(table).reshape(-1).tolist():
            self.free(int(b))

    def fork(self, block: int) -> int:
        """CoW fork: exclusive copy target for a shared ``block``.

        Allocates a fresh block, transfers this row's reference off the
        shared one, and returns the new id.  The caller owns issuing the
        device-side ``pool[new] = pool[old]`` copy.  Raises ``PoolExhausted``
        (allocator state unchanged) when the pool is dry.
        """
        assert block != self.SINK and self.refcount[block] > 1, (
            f"fork of exclusively-owned block {block}")
        new = self.alloc(1)[0]
        self.free(block)
        self.cow_forks += 1
        return new

    # ------------------------------------------------------------- metrics

    def stats(self) -> Dict[str, int]:
        return {
            "num_blocks": self.num_blocks,
            "block_size": self.block_size,
            "blocks_in_use": self.blocks_in_use,
            "peak_blocks_in_use": self.peak_blocks_in_use,
            "free_blocks": self.free_blocks,
            "cow_forks": self.cow_forks,
            "alloc_failures": self.alloc_failures,
            "shared_prompt_bytes_saved": self.shared_prompt_bytes_saved,
        }

    # ------------------------------------------------- §10 kill-and-resume

    def state_dict(self) -> Dict[str, object]:
        return {
            "num_blocks": self.num_blocks,
            "block_size": self.block_size,
            "free": np.asarray(self._free, dtype=np.int32),
            "refcount": self.refcount.copy(),
            "counters": np.asarray(
                [self.cow_forks, self.alloc_failures,
                 self.shared_prompt_bytes_saved, self.peak_blocks_in_use],
                dtype=np.int64),
        }

    def load_state_dict(self, state: Dict[str, object]) -> None:
        assert int(state["num_blocks"]) == self.num_blocks
        assert int(state["block_size"]) == self.block_size
        self._free = [int(b) for b in np.asarray(state["free"]).tolist()]
        self.refcount = np.asarray(state["refcount"], dtype=np.int32).copy()
        c = np.asarray(state["counters"])
        self.cow_forks = int(c[0])
        self.alloc_failures = int(c[1])
        self.shared_prompt_bytes_saved = int(c[2])
        self.peak_blocks_in_use = int(c[3])
        self.check()


def identity_table(batch: int, blocks_per_row: int,
                   offset: int = 0) -> np.ndarray:
    """Static row-major table: row b owns blocks [b*nb, (b+1)*nb).

    The pure-functional paths (``generate``, one-pass resume, drafted
    fixed-batch decode) have no allocator — each row simply owns a
    contiguous stripe of the pool, which exercises the full paged
    read/write machinery with zero host bookkeeping.  ``offset`` shifts
    past reserved blocks (the serving engine's sink).
    """
    return (offset + np.arange(batch * blocks_per_row, dtype=np.int32)
            .reshape(batch, blocks_per_row))
