"""Persistent continuous-batching decode loop over slot-replaced caches.

The engine keeps ONE decode batch of ``num_slots`` rows alive — over dense
``(B, Hkv, S, D)`` cache slabs by default (in-place slot replacement, §6),
or over a paged block pool when built as the ``PagedSlotEngine`` subclass
(serving/paged_engine.py, DESIGN.md §13).  Whenever a row emits
EOS or exhausts its per-slot budget, the next queued request is prefilled —
optionally through ``verify_and_prefill`` so a cached SPEC-RL draft becomes
its speculative prefix — and written into the freed slot by the
``cache_slot_write`` batched-scatter kernel.  No other row notices: the
decode batch never drains to its slowest member.

Three jit'd device programs, all statically shaped:

* ``_admit_vanilla``  — prefill a padded admission group + seed sample;
* ``_admit_spec``     — fused verify+prefill over [prompt | draft], compact
  to the accepted prefix (cache_gather), seed sample at the last accepted
  token — speculative-prefix admission;
* ``_decode_chunk``   — ``chunk_steps`` decode steps for all B slots with
  per-row write offsets (each slot sits at its own depth), per-row PRNG
  streams and per-row budgets.  Its body is term-for-term the body of
  ``engine/generate._decode_loop``, which is what makes slot-scheduled
  output token-identical to fixed-batch ``generate`` (tested).

Host side: numpy state vectors + the SlotScheduler; admission groups are
padded to ``num_slots`` rows by duplicating a real admitted row (duplicate
slot writes carry identical bytes), so every jit sees one shape.

Fault tolerance (DESIGN.md §10): the engine never lets one bad row take the
batch down.  A non-finite-logit guard inside ``_decode_chunk`` quarantines
the offending row in-chunk (its garbage token is never stored; every other
row decodes on); per-request deadlines bound how long a straggler may hold
a slot; a reclaimed request retries through speculative-prefix admission —
its already-generated tokens become the retry's draft and are *verified*,
not regenerated; draft-source exceptions disable drafting for the row,
never crash the server; repeated quarantines walk the decode-impl ladder
(pallas → blocked → naive).  All of it is counted in ``stats()``, injected
deterministically by a ``FaultPlan`` (serving/faults.py), and the whole
engine state round-trips through ``state_dict``/``load_state_dict`` for
exact kill-and-resume (checkpoint/io.save_server_state).
"""
from __future__ import annotations

import functools
import math
import time
from typing import Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.backoff import BackoffConfig
from repro.core.metrics import FaultStats
from repro.core.verify import verify_and_prefill
from repro.obs import (MetricsRegistry, get_decision_log, get_ledger,
                       get_tracer)
from repro.obs.alerts import (record_compile_gauges, record_device_memory,
                              register_jit_entry)
from repro.obs.ledger import (FRESH, PROMPT, REUSED_PREFIX, SOURCE_NGRAM,
                              categorize_draft_block)
from repro.engine.generate import GenerateConfig, positions_from_mask
from repro.engine.sampling import sample, split_key
from repro.models import model as M
from repro.models.config import ModelConfig

from .faults import EngineKilled, FaultPlan
from .request import (DECODING, FINISH_BUDGET, FINISH_EOS, FINISH_FULL_REUSE,
                      FINISH_QUARANTINE, FINISH_SHED, FINISH_TIMEOUT,
                      Request, Response)
from .scheduler import SlotScheduler

# §10 graceful-degradation ladder for the decode-attention implementation:
# a row that keeps producing non-finite logits steps the engine down one
# rung (recompile on fault — the clean path never pays for it)
_IMPL_LADDER = {"pallas": "blocked", "interpret": "blocked",
                "auto": "blocked", "blocked": "naive", "naive": None}
_IMPL_NAMES = ("auto", "naive", "blocked", "pallas", "interpret")


@functools.partial(jax.jit, static_argnames=("cfg", "gen", "mesh"))
def _admit_vanilla(params, cfg: ModelConfig, gen: GenerateConfig, prompts,
                   mask, keys, mesh=None):
    """Prefill an admission group; mirrors ``generate`` up to the seed token.

    prompts: (R, P) left-padded; keys: (R, 2) per-request decode keys.
    Returns caches sized P + N per row (the exact layout fixed-batch
    ``generate`` builds), the seed token/logprob and the carry keys.
    """
    R, P = prompts.shape
    caches = M.init_cache(cfg, R, P + gen.max_new_tokens)
    if mesh is not None:
        from repro.distributed.mesh import constrain_caches
        caches = constrain_caches(cfg, caches, mesh, batch=False)
    logits, caches = M.prefill(params, cfg, prompts, positions_from_mask(mask),
                               caches)
    keys, sub = split_key(keys)
    tok0, lp0 = sample(sub, logits[:, -1], gen.temperature, gen.top_p)
    # seed_logits ride along for the paged engine's GRPO prompt sharing
    # (§13): a follower re-samples from its leader's prefill logits with its
    # own key instead of re-running the identical prefill
    return {"caches": caches, "tok0": tok0, "lp0": lp0,
            "next_pos": mask.sum(axis=1).astype(jnp.int32), "keys": keys,
            "seed_logits": logits[:, -1]}


@functools.partial(jax.jit, static_argnames=("cfg", "gen", "verify_impl",
                                             "compact_impl", "mesh"))
def _admit_spec(params, cfg: ModelConfig, gen: GenerateConfig, prompts, mask,
                draft_tokens, draft_lp, draft_len, draft_eos, verify_keys,
                decode_keys, log_lenience, *, verify_impl: str,
                compact_impl: str, mesh=None):
    """Speculative-prefix admission: one forward over [prompt | draft].

    Identical device program to the fixed-batch one-pass rollout path
    (verify_and_prefill → realign_decode_cache → seed sample), so a request
    admitted here continues from the same compacted cache, seed logits and
    PRNG stream as ``rollout`` would give it.
    """
    R, P = prompts.shape
    N = draft_tokens.shape[1]
    W = P + N
    ver = verify_and_prefill(params, cfg, prompts, mask, draft_tokens,
                             draft_lp, draft_len, verify_keys, log_lenience,
                             temperature=gen.temperature, top_p=gen.top_p,
                             impl=verify_impl, mesh=mesh)
    n = ver["n"]
    p_len = mask.sum(axis=1).astype(jnp.int32)
    caches = M.realign_decode_cache(cfg, ver["caches"],
                                    (N - n).astype(jnp.int32), p_len + n, W,
                                    impl=compact_impl, mesh=mesh)
    full_reuse = (n == draft_len) & draft_eos
    keys, sub = split_key(decode_keys)
    tok0, lp0 = sample(sub, ver["seed_logits"], gen.temperature, gen.top_p)
    return {"caches": caches, "tok0": tok0, "lp0": lp0, "n": n,
            "lp_curr": ver["lp_curr"], "full_reuse": full_reuse,
            "next_pos": p_len + n, "keys": keys}


@functools.partial(jax.jit, static_argnames=("cfg", "impl", "pad_src",
                                             "mesh"))
def _write_slots(cfg: ModelConfig, dst_caches, src_caches, slots, *,
                 impl: str = "auto", pad_src: int = 0, mesh=None):
    # drafted engines keep draft_k spare slots per row (§9 block headroom);
    # admission caches are padded to the persistent width before the scatter
    if pad_src:
        src_caches = M.pad_cache(cfg, src_caches, pad_src)
    return M.write_cache_slots(cfg, dst_caches, src_caches, slots, impl=impl,
                               mesh=mesh)


@functools.partial(jax.jit, static_argnames=("cfg", "gen", "steps", "mesh"))
def _decode_chunk(params, cfg: ModelConfig, gen: GenerateConfig, caches,
                  cur_tok, cur_lp, done, count, budget, next_pos, write_idx,
                  keys, nan_inject, *, steps: int, mesh=None):
    """``steps`` decode steps over all slots; per-row write offsets/streams.

    Term-for-term the body of ``engine/generate._decode_loop`` (store →
    count/done update → decode_step → split → sample), except the cache
    write lands at the per-row ``write_idx`` instead of a batch-wide offset
    and the loop never stops early — idle/done rows keep stepping with
    position −1 (position-masked attention ignores those writes, and the
    slot is fully rewritten at its next admission).

    §10 non-finite guard: a row whose logits go NaN/inf is *quarantined*
    in-chunk — its garbage sample is forced onto safe (uniform) logits and
    never stored, because quarantine sets ``done`` before the next store.
    Every other row decodes on undisturbed.  ``nan_inject`` (B,) is the
    fault-injection hook: the step index within this chunk at which a row's
    logits are deliberately corrupted, −1 (the clean-path constant) never.
    Both the injection and the guard are ``where``-selects over the same
    traced program, so a clean run is bit-identical to the pre-guard loop.
    """
    def body(carry, step_i):
        caches, cur_tok, cur_lp, done, count, next_pos, write_idx, keys, \
            quar = carry
        tok_store = jnp.where(done, gen.pad_id, cur_tok)
        lp_store = jnp.where(done, 0.0, cur_lp)
        count = count + (~done).astype(jnp.int32)
        done_next = done | (cur_tok == gen.eos_id) | (count >= budget)
        # per-row live extents: each slot sits at its own decode depth, so
        # the flash-decode kernel early-exits per row at write_idx + 1 and
        # skips the dead left padding below write_idx - next_pos (the
        # admitted context is contiguous — prefill or compacted layout)
        logits, caches = M.decode_step(
            params, cfg, tok_store[:, None],
            jnp.where(done[:, None], -1, next_pos[:, None]),
            caches, write_idx, kv_length=write_idx + 1,
            kv_start=write_idx - next_pos, mesh=mesh)
        lg = logits[:, 0]
        lg = jnp.where((nan_inject == step_i)[:, None], jnp.nan, lg)
        bad = ~jnp.all(jnp.isfinite(lg), axis=-1)
        newly = bad & ~done_next        # rows finishing anyway aren't pulled
        quar = quar | newly
        done_next = done_next | newly
        lg = jnp.where(bad[:, None], 0.0, lg)   # sample something finite;
        keys, sub = split_key(keys)             # done_next gates its store
        nxt, nlp = sample(sub, lg, gen.temperature, gen.top_p)
        carry = (caches, nxt, nlp, done_next, count, next_pos + 1,
                 write_idx + 1, keys, quar)
        return carry, (tok_store, lp_store)

    init = (caches, cur_tok, cur_lp, done, count, next_pos, write_idx, keys,
            jnp.zeros_like(done))
    carry, (toks, lps) = jax.lax.scan(body, init, jnp.arange(steps))
    caches, cur_tok, cur_lp, done, count, next_pos, write_idx, keys, \
        quar = carry
    return {"caches": caches, "cur_tok": cur_tok, "cur_lp": cur_lp,
            "done": done, "count": count, "next_pos": next_pos,
            "write_idx": write_idx, "keys": keys, "quarantined": quar,
            "tokens": toks.T, "logprobs": lps.T}      # (B, steps)


# §14 recompile sentinel: these module-level jit wrappers are the engine's
# device programs — their cache sizes are the per-entry compile counts the
# `recompile_steady_state` alert rule watches (obs/alerts.py)
register_jit_entry("admit_vanilla", _admit_vanilla)
register_jit_entry("admit_spec", _admit_spec)
register_jit_entry("write_slots", _write_slots)
register_jit_entry("decode_chunk", _decode_chunk)


class SlotEngine:
    """Continuous-batching generation engine with spec-prefix admission."""

    def __init__(self, params, cfg: ModelConfig, gen: GenerateConfig, *,
                 num_slots: int, prompt_width: int, spec_prefix: bool = False,
                 log_lenience: float = 0.0, chunk_steps: int = 8,
                 verify_impl: str = "auto", compact_impl: str = "auto",
                 slot_write_impl: str = "auto", draft=None, mesh=None,
                 faults: Optional[FaultPlan] = None,
                 deadline_steps: Optional[int] = None,
                 max_queue: Optional[int] = None, overflow: str = "reject",
                 retry_backoff: Optional[BackoffConfig] = None,
                 tracer=None, ledger=None, obs_label: str = ""):
        assert M.supports_slot_serving(cfg), \
            "slot serving needs an attention-only trunk without modality " \
            "extras — use fixed-batch generate otherwise"
        self.params, self.cfg, self.gen = params, cfg, gen
        self.P = int(prompt_width)
        self.N = int(gen.max_new_tokens)
        self.spec_prefix = bool(spec_prefix)
        self.log_lenience = float(log_lenience)
        self.chunk_steps = max(1, int(chunk_steps))
        self.verify_impl, self.compact_impl = verify_impl, compact_impl
        self.slot_write_impl = slot_write_impl
        # §9 continuation draft engine: a DraftConfig switches _run_chunk
        # from `chunk_steps` single-token scans to one draft-verify block
        # per chunk, with per-slot n-gram sources / length controllers
        self.draft = draft if (draft is not None and draft.enabled) else None
        # One engine serves ONE data shard: its decode batch stays whole and
        # only the KV head axis (and the params the caller pre-sharded)
        # spread over the mesh's ``model`` axis.  Data parallelism lives one
        # level up — MeshSlotServer runs one engine per data-shard submesh
        # (DESIGN.md §8).
        self.mesh = mesh
        # context ends at write_base; decode token t lands at write_base + t
        # (vanilla: prefill layout [0, P); spec: compacted layout [0, P+N));
        # drafted engines add draft_k headroom for the block write (§9)
        self.write_base = self.P + (self.N if spec_prefix else 0)
        self.cache_len = self.write_base + self.N + \
            (self.draft.draft_k if self.draft else 0)

        B = int(num_slots)
        if self.draft:
            from repro.core.metrics import DraftStats
            from repro.drafting import DraftController, NGramDraftSource
            self._draft_source = NGramDraftSource(self.draft, B)
            self._draft_ctrl = DraftController(self.draft, B)
            self.draft_stats = DraftStats()
        self.caches = self._make_caches(B)
        if mesh is not None:
            from repro.distributed.mesh import shard_caches
            self.caches = shard_caches(cfg, self.caches, mesh, batch=False)
        self.scheduler = SlotScheduler(B, max_queue=max_queue,
                                       overflow=overflow)
        # §10 hardening state: engine-default deadline (a request's own
        # deadline_steps wins), the injected fault schedule, and the
        # pending targeted faults held until their request is in a slot
        self.deadline_steps = deadline_steps
        self.faults = faults
        self.fault_stats = FaultStats()
        # §12 backoff adoption: with a BackoffConfig, a reclaimed request
        # is NOT resubmitted immediately — it is held until the engine
        # step clock passes its exponential-backoff due step (base/factor
        # measured in engine steps), so repeated failures stop hammering
        # the same slot cycle.  None (the default) keeps the §10 behaviour
        # and existing kill-resume snapshots bit-identical.
        self.retry_backoff = retry_backoff
        self._retry_hold: List[Tuple[int, Request]] = []
        self.slot_age = np.zeros(B, np.int64)   # engine steps spent DECODING
        self._nan_due: set = set()              # request_ids awaiting nan
        self._stall_due: Dict[int, int] = {}    # request_id -> phantom steps
        self._draft_exc_due: set = set()        # request_ids awaiting exc
        self.cur_tok = np.zeros(B, np.int32)
        self.cur_lp = np.zeros(B, np.float32)
        self.done = np.ones(B, bool)
        self.count = np.zeros(B, np.int32)
        self.budget = np.zeros(B, np.int32)
        self.next_pos = np.zeros(B, np.int32)
        self.write_idx = np.full(B, self.write_base, np.int32)
        self.keys = np.zeros((B, 2), np.uint32)
        self._acc_tok: List[List[np.ndarray]] = [[] for _ in range(B)]
        self._acc_lp: List[List[np.ndarray]] = [[] for _ in range(B)]
        # §14: whether a slot's pending carry token is a free bonus sample
        # (previous drafted macro-step fully accepted).  Ledger bookkeeping
        # only — deliberately NOT in state_dict (the ledger isn't either)
        self._carry_bonus = np.zeros(B, bool)
        self._slot_n = np.zeros(B, np.int32)
        self._slot_draft_len = np.zeros(B, np.int32)
        self._slot_full_reuse = np.zeros(B, bool)
        self._slot_prefix_lp: List[Optional[np.ndarray]] = [None] * B
        self.responses: Dict[int, Response] = {}
        self.steps = 0                      # engine decode steps elapsed
        self.time_admit = 0.0
        self.time_slot_write = 0.0
        self.time_decode = 0.0
        # §11 observatory: the tracer draws request/engine lanes, the
        # engine-owned registry holds the latency histograms stats() can't
        # derive from counters (TTFT, queue wait, per-token decode time).
        # Both are inert by default — every recording call early-returns on
        # NULL_TRACER and histograms only fill where observe() runs, so the
        # clean path takes no extra clock reads or syncs (timestamps below
        # reuse the perf_counter values the time_* accounting already takes).
        self.tracer = tracer if tracer is not None else get_tracer()
        # §14 provenance ledger + decision log: host-side sinks, inert by
        # default (NULL_LEDGER / NULL_DECISION_LOG early-return everywhere),
        # and never consulted inside jit'd code — the zero-overhead contract
        # extends to byte-identical lowered HLO with or without them
        self.ledger = ledger if ledger is not None else get_ledger()
        self.decisions = get_decision_log()
        self.obs_label = str(obs_label)     # "shard<i>/" under a mesh server
        self._etrack = f"{self.obs_label}engine"
        self.metrics = MetricsRegistry()
        self._t0 = time.perf_counter()

    # ------------------------------------------------------------- frontend

    def _now(self) -> float:
        return time.perf_counter() - self._t0

    def _abs(self, rel: float) -> float:
        """Engine-relative seconds → the tracer's perf_counter timeline."""
        return self._t0 + rel

    def submit(self, req: Request) -> None:
        assert len(req.prompt) <= self.P, (len(req.prompt), self.P)
        assert 0 <= req.max_new_tokens <= self.N, req.max_new_tokens
        shed = self.scheduler.submit(req, now=self._now())
        if shed is not None:
            # backpressure acted: the shed request resolves immediately with
            # an empty, explicitly-marked response (§10) — callers waiting
            # on it see a terminal state instead of a hang
            self.fault_stats.add(failed=1)
            self.responses[shed.request_id] = Response(
                request_id=shed.request_id, tokens=np.zeros(0, np.int32),
                logprobs=np.zeros(0, np.float32), length=0,
                finish_reason=FINISH_SHED, slot=-1, retries=shed.retries)

    def _release_retries(self) -> None:
        """Re-queue held backoff retries whose due step has passed (§12).
        Bypasses backpressure the same way an immediate resubmit does —
        a retry holds no NEW work."""
        if not self._retry_hold:
            return
        now = self._now()
        due = [r for d, r in self._retry_hold if d <= self.steps]
        self._retry_hold = [(d, r) for d, r in self._retry_hold
                            if d > self.steps]
        for req in due:
            self.scheduler.resubmit(req, now=now)

    def run(self, arrivals: Optional[Iterable[Tuple[int, Request]]] = None,
            max_chunks: Optional[int] = None) -> Dict[int, Response]:
        """Drive the loop until queue + slots drain (and arrivals exhaust).

        arrivals: optional (due_step, Request) stream sorted by due_step —
        requests arriving while the engine runs; the loop idles forward to
        the next due step when it would otherwise drain.
        """
        it = iter(arrivals) if arrivals is not None else None
        nxt = next(it, None) if it is not None else None
        chunks = 0
        while True:
            self._apply_faults()       # may raise EngineKilled (kind 'kill')
            self._release_retries()    # held backoff retries now due
            while nxt is not None and nxt[0] <= self.steps:
                self.submit(nxt[1])
                nxt = next(it, None)
            self._admit()
            if self.scheduler.idle:
                if self._retry_hold:   # backoff holds are pending work:
                    due = min(d for d, _ in self._retry_hold)
                    if nxt is not None:
                        due = min(due, int(nxt[0]))
                    self.steps = max(self.steps, due)      # idle fast-forward
                    continue
                if nxt is None:
                    break
                self.steps = max(self.steps, int(nxt[0]))  # idle fast-forward
                continue
            self._run_chunk()
            self._harvest()
            self._enforce_deadlines()
            chunks += 1
            if max_chunks is not None and chunks >= max_chunks:
                break
        return self.responses

    def metrics_registry(self) -> MetricsRegistry:
        """The engine's full telemetry as ONE typed registry (§11).

        Every scheduler lifecycle counter, §9 draft counter and §10 fault
        counter lands here with its merge semantics attached — counters
        sum, peak gauges max, ratios re-derive from summed parts — so
        ``MeshSlotServer`` gathers shards by a single type-driven
        ``MetricsRegistry.merge`` instead of a hand-listed key walk (the
        schema-drift fix: a new counter can no longer silently vanish from
        the gathered view).  ``stats()`` is just ``as_dict()`` of this.
        """
        from repro.core.metrics import DraftStats
        sch = self.scheduler
        reg = MetricsRegistry()
        # shape/config gauges (sum across shards where extensive)
        reg.set("num_slots", float(sch.num_slots), agg="sum")
        reg.set("num_shards", 1.0, agg="sum")
        reg.set("pending", float(len(sch.queue)), agg="sum")
        reg.set("max_queue", float(sch.max_queue or 0), agg="sum")
        reg.set("engine_steps", float(self.steps), agg="max")
        reg.set("wall_time", self._now(), agg="max")
        # scheduler lifecycle counters
        reg.inc("submitted", sch.submitted)
        reg.inc("admitted", sch.admitted)
        reg.inc("completed", sch.completed)
        reg.inc("busy_slot_steps", sch.busy_slot_steps)
        reg.inc("total_slot_steps", sch.total_slot_steps)
        reg.inc("queue_wait_total", sch.queue_wait_total)
        reg.inc("serve_time_total", sch.serve_time_total)
        reg.inc("timeouts", sch.timeouts)
        reg.inc("quarantined_requests", sch.quarantines)
        reg.inc("retried_requests", sch.retries)
        reg.inc("shed_requests", sch.sheds)
        reg.inc("rejected_requests", sch.rejected)
        reg.ratio("occupancy", "busy_slot_steps", "total_slot_steps")
        reg.ratio("mean_queue_wait", "queue_wait_total", "completed")
        reg.ratio("mean_serve_time", "serve_time_total", "completed")
        # engine throughput counters
        reg.inc("generated_tokens",
                sum(r.length for r in self.responses.values()))
        reg.inc("reused_tokens",
                sum(r.n_accepted for r in self.responses.values()))
        reg.inc("admit_time", self.time_admit)
        reg.inc("slot_write_time", self.time_slot_write)
        reg.inc("decode_time", self.time_decode)
        # §9 draft telemetry (zeros for undrafted engines, so the stats
        # schema is uniform across engine modes and mesh shards); the
        # legacy ratio names stay, re-derived from the summed counters
        ds = self.draft_stats if self.draft else DraftStats()
        reg.inc("draft_proposed", ds.proposed)
        reg.inc("draft_accepted", ds.accepted)
        reg.inc("decode_forwards", ds.forwards)
        reg.inc("decode_emitted", ds.emitted)
        reg.inc("draft_forwards", ds.draft_forwards)
        reg.ratio("accept_rate", "draft_accepted", "draft_proposed")
        reg.ratio("mean_draft_len", "draft_proposed", "draft_forwards")
        reg.ratio("tokens_per_forward", "decode_emitted", "decode_forwards")
        # §10 recovery telemetry under the uniform fault_ schema: the
        # engine-owned counters plus a mirror of the scheduler's lifecycle
        # counters, so one prefix carries the whole failure story
        fs = FaultStats(**{k: getattr(self.fault_stats, k)
                           for k in FaultStats.FIELDS})
        fs.timeouts = sch.timeouts
        fs.retries = sch.retries
        fs.sheds = sch.sheds
        fs.rejected = sch.rejected
        for k, v in fs.as_dict().items():
            reg.inc(k, v)
        # §14 sentinels: per-entry jit compile counts and backend memory
        # stats (the jit caches and device are process-global, so gauges
        # with agg="max" merge shard registries without double-counting;
        # memory gauges simply don't appear on backends that report None)
        record_compile_gauges(reg)
        record_device_memory(reg)
        # §14 provenance tallies — the ledger is process-global too
        if self.ledger.enabled:
            for cname, nv in self.ledger.counts_dict().items():
                reg.set(f"ledger.tokens_{cname}", float(nv), agg="max")
        # §11 latency histograms accumulated by the serving loop itself
        reg.merge(self.metrics)
        return reg

    def stats(self) -> Dict[str, float]:
        return self.metrics_registry().as_dict()

    # ------------------------------------------------------------ admission

    def _pad_group(self, rows: List[np.ndarray]) -> np.ndarray:
        """Stack + pad a group to num_slots rows by duplicating row 0."""
        B = self.scheduler.num_slots
        rows = rows + [rows[0]] * (B - len(rows))
        return np.stack(rows)

    # Layout hooks, overridden by PagedSlotEngine (DESIGN.md §13).  The
    # dense engine's behaviour is the identity on all four.

    def _make_caches(self, B: int):
        """Build the persistent decode caches (dense slabs by default)."""
        return M.init_cache(self.cfg, B, self.cache_len)

    def _admit_cfg(self) -> ModelConfig:
        """Config the admission jits build their throwaway caches with.
        The paged engine admits DENSELY (identical device programs to the
        dense engine) and re-pages at the slot write."""
        return self.cfg

    def _register_groups(self, group, out) -> None:
        """Post-admission hook: the paged engine registers each new GRPO
        group's prompt blocks + seed logits here for CoW sharing."""

    def _on_slot_freed(self, slot: int) -> None:
        """A request left ``slot`` (completed or reclaimed); the paged
        engine releases its block-table row here."""

    def _admit(self) -> None:
        while True:
            group = self.scheduler.reserve(self._now())
            if not group:
                return
            self._admit_group(group)

    def _prep_prompts(self, reqs: List[Request]):
        prom = np.zeros((len(reqs), self.P), np.int32)
        mask = np.zeros((len(reqs), self.P), bool)
        for j, r in enumerate(reqs):
            L = len(r.prompt)
            prom[j, self.P - L:] = np.asarray(r.prompt, np.int32)
            mask[j, self.P - L:] = True
        return prom, mask

    def _admit_group(self, group: List[Tuple[int, Request]]) -> None:
        t0 = time.perf_counter()
        B = self.scheduler.num_slots
        slots = [s for s, _ in group]
        reqs = [r for _, r in group]
        prom, mask = self._prep_prompts(reqs)
        prompts = self._pad_group(list(prom))
        masks = self._pad_group(list(mask))
        keys = self._pad_group([np.asarray(r.key, np.uint32) for r in reqs])

        dn = np.zeros((len(group),), np.int32)
        if self.spec_prefix:
            dt = np.zeros((len(group), self.N), np.int32)
            dl = np.zeros((len(group), self.N), np.float32)
            de = np.zeros((len(group),), bool)
            for j, r in enumerate(reqs):
                if r.has_draft:
                    L = min(len(r.draft_tokens), self.N)
                    dt[j, :L] = r.draft_tokens[:L]
                    dl[j, :L] = r.draft_logprobs[:L]
                    dn[j] = L
                    de[j] = r.draft_eos and L == len(r.draft_tokens)
            vkeys = self._pad_group(
                [np.asarray(r.verify_key, np.uint32) for r in reqs])
            out = _admit_spec(
                self.params, self._admit_cfg(), self.gen,
                jnp.asarray(prompts),
                jnp.asarray(masks), jnp.asarray(self._pad_group(list(dt))),
                jnp.asarray(self._pad_group(list(dl))),
                jnp.asarray(self._pad_group(list(dn))),
                jnp.asarray(self._pad_group(list(de))),
                jnp.asarray(vkeys), jnp.asarray(keys),
                self.log_lenience, verify_impl=self.verify_impl,
                compact_impl=self.compact_impl, mesh=self.mesh)
        else:
            out = _admit_vanilla(self.params, self._admit_cfg(), self.gen,
                                 jnp.asarray(prompts), jnp.asarray(masks),
                                 jnp.asarray(keys), mesh=self.mesh)
        jax.block_until_ready(out["tok0"])
        t1 = time.perf_counter()
        self.time_admit += t1 - t0

        slot_ids = np.array(slots + [slots[0]] * (B - len(slots)),
                            np.int32)
        self.caches = self._write_admitted(out["caches"], slot_ids)
        jax.block_until_ready(jax.tree.leaves(self.caches)[0])
        t2 = time.perf_counter()
        self.time_slot_write += t2 - t1

        # §11: admit/slot-write timings reuse t0/t1/t2 — the clock
        # reads the time_* accounting above already took
        self.metrics.observe("serve.admit_ms", (t1 - t0) * 1e3)
        self.metrics.observe("serve.slot_write_ms", (t2 - t1) * 1e3)
        tr = self.tracer
        if tr.enabled:
            tr.complete("admit", self._etrack, t0, t1, cat="admit",
                        rows=len(group))
            tr.complete("slot_write", self._etrack, t1, t2, cat="admit")

        self._register_groups(group, out)
        tok0 = np.asarray(out["tok0"])
        lp0 = np.asarray(out["lp0"])
        npos = np.asarray(out["next_pos"])
        nkeys = np.asarray(out["keys"])
        n = np.asarray(out["n"]) if self.spec_prefix else \
            np.zeros(B, np.int32)
        fr = np.asarray(out["full_reuse"]) if self.spec_prefix else \
            np.zeros(B, bool)
        lp_curr = np.asarray(out["lp_curr"]) if self.spec_prefix else None
        self._apply_admission(group, tok0, lp0, npos, nkeys, n, fr,
                              lp_curr, dn, t0, t1)
        # full-reuse / zero-budget admissions finish without decoding;
        # harvesting them here lets the loop keep back-filling
        self._harvest()

    def _write_admitted(self, src_caches, slot_ids: np.ndarray):
        """Scatter the admission caches into the persistent batch."""
        return _write_slots(self.cfg, self.caches, src_caches,
                            jnp.asarray(slot_ids),
                            impl=self.slot_write_impl,
                            pad_src=self.draft.draft_k if self.draft else 0,
                            mesh=self.mesh)

    def _apply_admission(self, group, tok0, lp0, npos, nkeys, n, fr,
                         lp_curr, dn, t0: float, t1: float) -> None:
        """Per-request host bookkeeping after an admission (any path):
        state vectors, telemetry, draft-source reset, activation.  Arrays
        are indexed by the request's position ``j`` in ``group``."""
        tr = self.tracer
        led = self.ledger
        for j, (slot, req) in enumerate(group):
            nj = int(n[j])
            budget = max(0, req.max_new_tokens - nj)
            if led.enabled:
                # §14: (re)build the provenance plane.  The accepted prefix
                # splits at the caller's draft boundary: up to base it is
                # SPEC-RL reuse; past it, the request's own re-verified
                # partial output from a previous occupancy (§10 retry)
                base = max(0, int(req.base_draft_len))
                led.begin_row(req.request_id, len(req.prompt),
                              prompt_cat=self._prompt_category(req))
                led.append(req.request_id, REUSED_PREFIX, min(nj, base))
                led.append(req.request_id,
                           led.retry_category(req.request_id),
                           nj - min(nj, base))
            # §11 per-request admission telemetry: queue wait, TTFT
            # (queued → seed token, which admission just produced) and
            # the SPEC-RL reuse length.  Span endpoints are the
            # engine-relative stamps the scheduler already recorded.
            self.metrics.observe("serve.queue_wait_ms",
                                 (req.admitted_at - req.queued_at) * 1e3)
            self.metrics.observe(
                "serve.ttft_ms",
                ((t1 - self._t0) - req.queued_at) * 1e3)
            if self.spec_prefix:
                self.metrics.observe("serve.reuse_len", nj)
            if tr.enabled and tr.sampled(req.request_id):
                lane = f"{self.obs_label}req/{req.request_id}"
                tr.complete("queued", lane, self._abs(req.queued_at),
                            self._abs(req.admitted_at), cat="queue",
                            retries=req.retries)
                tr.complete("admit", lane, t0, t1, cat="admit",
                            slot=slot, n_accepted=nj)
            self.cur_tok[slot] = tok0[j]
            self.cur_lp[slot] = lp0[j]
            self.count[slot] = 0
            self.budget[slot] = budget
            self.next_pos[slot] = npos[j]
            self.write_idx[slot] = self.write_base
            self.keys[slot] = nkeys[j]
            self.slot_age[slot] = 0     # deadline clock is per-occupancy
            self.done[slot] = bool(fr[j]) or budget <= 0
            self._acc_tok[slot] = []
            self._acc_lp[slot] = []
            self._carry_bonus[slot] = False   # seed sample is priced fresh
            self._slot_n[slot] = nj
            self._slot_draft_len[slot] = int(dn[j]) if self.spec_prefix \
                else 0
            self._slot_full_reuse[slot] = bool(fr[j])
            self._slot_prefix_lp[slot] = lp_curr[j] if lp_curr is not None \
                else None
            if self.draft:
                # n-gram index over prompt ⊕ accepted prefix, shadowing
                # the request's sibling corpus (DESIGN.md §9)
                ctx = list(np.asarray(req.prompt, np.int32))
                if self.spec_prefix and req.has_draft:
                    ctx.extend(np.asarray(req.draft_tokens[:nj],
                                          np.int32))
                self._draft_source.reset(slot, ctx, req.ngram_corpus)
                self._draft_ctrl.reset(slot)
            self.scheduler.activate(slot)

    def _prompt_category(self, req: Request) -> int:
        """Provenance of the prompt plane — the paged engine overrides this
        for CoW followers whose prompt blocks are mapped, not prefilled
        (§13 / §14)."""
        return PROMPT

    def _pool_pressure(self) -> float:
        """KV backing-store pressure in [0, 1] — 0 for dense slabs (they
        cannot run dry); the paged engine reports block-pool occupancy."""
        return 0.0

    # ---------------------------------------------------------- decode loop

    def _run_chunk(self, steps: Optional[int] = None) -> None:
        if self.draft:
            return self._run_draft_chunk()
        steps = steps or self.chunk_steps
        live = [s for s in self.scheduler.active if not self.done[s]]
        busy = len(live)
        # §10 fault hook: corrupt the logits of pending nan targets on the
        # first step of this chunk (−1 = never; the clean-path constant)
        inject = np.full(self.scheduler.num_slots, -1, np.int32)
        for slot, req in self.scheduler.active.items():
            if req.request_id in self._nan_due and not self.done[slot]:
                self._nan_due.discard(req.request_id)
                inject[slot] = 0
        t0 = time.perf_counter()
        out = _decode_chunk(
            self.params, self.cfg, self.gen, self.caches,
            jnp.asarray(self.cur_tok), jnp.asarray(self.cur_lp),
            jnp.asarray(self.done), jnp.asarray(self.count),
            jnp.asarray(self.budget), jnp.asarray(self.next_pos),
            jnp.asarray(self.write_idx), jnp.asarray(self.keys),
            jnp.asarray(inject), steps=steps, mesh=self.mesh)
        self.caches = out["caches"]
        toks = np.asarray(out["tokens"])            # (B, steps)
        lps = np.asarray(out["logprobs"])
        count0 = self.count
        t1 = time.perf_counter()
        self.time_decode += t1 - t0
        for name in ("cur_tok", "cur_lp", "done", "count", "next_pos",
                     "write_idx", "keys"):
            # np.array (not asarray): jax arrays view as read-only and the
            # admission path writes these in place
            setattr(self, name, np.array(out[name]))
        # §11 chunk telemetry: t0/t1 are the stamps time_decode already
        # takes; emitted counts come from the np state just harvested
        emitted = int((self.count[live] - count0[live]).sum()) if live else 0
        self.metrics.observe("serve.decode_chunk_ms", (t1 - t0) * 1e3)
        self.metrics.observe("serve.decode_step_ms", (t1 - t0) / steps * 1e3)
        if emitted > 0:
            self.metrics.observe("serve.token_ms", (t1 - t0) / emitted * 1e3)
        tr = self.tracer
        if tr.enabled:
            tr.complete("decode_chunk", self._etrack, t0, t1, cat="decode",
                        steps=steps, busy=busy, emitted=emitted)
            for slot in live:
                req = self.scheduler.active[slot]
                if tr.sampled(req.request_id):
                    tr.complete("decode_chunk",
                                f"{self.obs_label}req/{req.request_id}",
                                t0, t1, cat="decode", slot=slot)
        for slot in self.scheduler.active:
            self._acc_tok[slot].append(toks[slot])
            self._acc_lp[slot].append(lps[slot])
            self.slot_age[slot] += steps
        if self.ledger.enabled:
            # §14: the chunk's valid emission per slot is the count delta
            # (the accumulators above keep full chunk rows and trim at
            # harvest; the ledger must not)
            for slot, req in self.scheduler.active.items():
                self.ledger.append(req.request_id, FRESH,
                                   int(self.count[slot]) - int(count0[slot]))
        self.steps += steps
        self.scheduler.tick(busy, steps)
        # §10 quarantine: rows the in-chunk guard pulled out (their valid
        # prefix is in _acc; the corrupted sample was never stored) leave
        # the decode batch before harvest sees them as completions
        quar = np.asarray(out["quarantined"])
        for slot in [s for s in list(self.scheduler.active) if quar[s]]:
            self.fault_stats.add(nan_events=1)
            self._reclaim(slot, FINISH_QUARANTINE)

    def _run_draft_chunk(self) -> None:
        """One §9 draft-verify macro-step over all slots.

        The device program is the SAME jit'd ``drafting.step.draft_step``
        the fixed-batch drafted loops run — per-row write offsets / budgets
        / PRNG streams are the machinery this engine already carries, so a
        slot absorbs a variable-length accept exactly like a fixed-batch
        row (and greedy output stays token-identical, tested)."""
        from repro.drafting.step import block_width, draft_step
        K = self.draft.draft_k
        B = self.scheduler.num_slots
        busy = sum(1 for s in self.scheduler.active if not self.done[s])
        dt = np.zeros((B, K), np.int32)
        dl = np.zeros((B,), np.int32)
        dec = self.decisions
        feats: Dict[int, Dict[str, float]] = {}
        for slot in self.scheduler.active:
            if self.done[slot]:
                continue
            req = self.scheduler.active[slot]
            if req.draft_off:
                continue                # degraded row: plain (B, 2) decode
            try:
                # §10 fault hook: a targeted draft-source exception, then
                # the same guard any REAL proposal error falls into —
                # drafting dies for this row, the request decodes on
                if req.request_id in self._draft_exc_due:
                    self._draft_exc_due.discard(req.request_id)
                    raise RuntimeError("injected draft-source fault")
                k_s = self._draft_ctrl.draft_len(slot)
                d = self._draft_source.propose(slot, k_s,
                                               pending=int(self.cur_tok[slot]))
            except Exception:
                self.fault_stats.add(draft_errors=1, draft_disabled=1)
                req.draft_off = True
                continue
            dt[slot, :len(d)] = d
            dl[slot] = len(d)
            if dec.enabled:
                # §14 decision record, feature half: everything the length
                # controller could have looked at, captured pre-step from
                # host state the loop already holds.  surprisal is the
                # single-sample entropy estimate -logp of the pending token
                # (full logits never reach the host here, by design)
                feats[slot] = {
                    "surprisal": -float(self.cur_lp[slot]),
                    "position": float(self.next_pos[slot]),
                    "accept_ema": float(self._draft_ctrl.rate[slot]),
                    "draft_k": float(len(d)),
                    "draft_source": SOURCE_NGRAM,
                    "queue_depth": float(len(self.scheduler.queue)),
                    "slot_age": float(self.slot_age[slot]),
                    "pool_pressure": self._pool_pressure(),
                }
        # bucketed block width (drafting/step.py:block_width): the forward
        # narrows with the controller's draft lengths; u_width = draft_k
        # keeps per-request streams independent of co-batched buckets
        K_step = block_width(int(dl.max()), K)
        t0 = time.perf_counter()
        out = draft_step(
            self.params, self.cfg, self.gen, self.caches,
            jnp.asarray(self.cur_tok), jnp.asarray(self.cur_lp),
            jnp.asarray(self.done), jnp.asarray(self.count),
            jnp.asarray(self.budget), jnp.asarray(self.next_pos),
            jnp.asarray(self.write_idx), jnp.asarray(self.keys),
            jnp.asarray(dt[:, :K_step]), jnp.asarray(dl), K=K_step,
            u_width=K, verify_impl=self.verify_impl, mesh=self.mesh)
        self.caches = out["caches"]
        toks = np.asarray(out["tokens"])            # (B, K+1)
        lps = np.asarray(out["logprobs"])
        emitted = np.asarray(out["emitted"])
        t1 = time.perf_counter()
        self.time_decode += t1 - t0
        for name in ("cur_tok", "cur_lp", "done", "count", "next_pos",
                     "write_idx"):
            setattr(self, name, np.array(out[name]))
        self.keys = np.array(out["keys"])
        accepted = np.asarray(out["accepted"])
        proposed = np.asarray(out["proposed"])
        # §11 draft macro-step telemetry (t0/t1 = the time_decode stamps):
        # the acceptance time series lives in the span args
        n_em = int(emitted.sum())
        self.metrics.observe("serve.draft_chunk_ms", (t1 - t0) * 1e3)
        if n_em > 0:
            self.metrics.observe("serve.token_ms", (t1 - t0) / n_em * 1e3)
        tr = self.tracer
        if tr.enabled:
            tr.complete("draft_chunk", self._etrack, t0, t1, cat="draft",
                        busy=busy, proposed=int(proposed.sum()),
                        accepted=int(accepted.sum()), emitted=n_em)
            for slot in self.scheduler.active:
                if self.done[slot] and not emitted[slot]:
                    continue
                req = self.scheduler.active[slot]
                if tr.sampled(req.request_id):
                    tr.complete("draft_chunk",
                                f"{self.obs_label}req/{req.request_id}",
                                t0, t1, cat="draft", slot=slot,
                                proposed=int(proposed[slot]),
                                accepted=int(accepted[slot]),
                                emitted=int(emitted[slot]))
        quarantined: List[int] = []
        led = self.ledger
        for slot in self.scheduler.active:
            req = self.scheduler.active[slot]
            m = int(emitted[slot])
            # §10 non-finite guard, host-side for drafted chunks: scan the
            # block's logprobs; everything from the first bad index on is
            # poisoned and rolled back (injected nan poisons the block at 0)
            poison = m
            if req.request_id in self._nan_due and m > 0:
                self._nan_due.discard(req.request_id)
                poison = 0
            elif m > 0:
                bad = ~np.isfinite(lps[slot, :m])
                if bad.any():
                    poison = int(np.argmax(bad))
            if led.enabled and m:
                # §14: carry (fresh/bonus) + accepted-draft runs for this
                # block, clamped to the kept (un-poisoned) emission
                kept = min(poison, m)
                for cat, nrun in categorize_draft_block(
                        m, bool(self._carry_bonus[slot])):
                    if kept <= 0:
                        break
                    led.append(req.request_id, cat, min(nrun, kept))
                    kept -= nrun
            # a fully-accepted proposal makes the NEXT carry token a free
            # bonus sample (ledger bookkeeping only — never persisted,
            # like the ledger itself)
            self._carry_bonus[slot] = bool(
                proposed[slot] > 0 and accepted[slot] == proposed[slot])
            if poison < m:
                if poison:
                    self._acc_tok[slot].append(toks[slot, :poison])
                    self._acc_lp[slot].append(lps[slot, :poison])
                self.count[slot] -= m - poison      # drop the poisoned tail
                quarantined.append(slot)
                continue
            if m:
                self._acc_tok[slot].append(toks[slot, :m])
                self._acc_lp[slot].append(lps[slot, :m])
                self._draft_source.extend(slot, toks[slot, :m])
            self._draft_ctrl.update(slot, int(proposed[slot]),
                                    int(accepted[slot]))
        if dec.enabled and feats:
            # §14 decision record, outcome half: join the pre-step features
            # to what the verify actually returned (step_ms reuses the
            # t0/t1 stamps time_decode already took)
            step_ms = (t1 - t0) * 1e3
            for slot, f in feats.items():
                req = self.scheduler.active.get(slot)
                if req is None:
                    continue
                prop, acc = int(proposed[slot]), int(accepted[slot])
                m = int(emitted[slot])
                dec.record(req.request_id, self.steps, f, {
                    "proposed": prop, "accepted": acc,
                    "bonus": 1.0 if (prop > 0 and acc == prop and m > acc)
                    else 0.0,
                    "emitted": m, "step_ms": step_ms})
        for slot in self.scheduler.active:
            self.slot_age[slot] += 1
        self.draft_stats.add_step(forwards=busy,
                                  proposed=int(proposed.sum()),
                                  accepted=int(accepted.sum()),
                                  emitted=int(emitted.sum()),
                                  draft_forwards=int((dl > 0).sum()))
        self.steps += 1                     # one forward = one engine step
        self.scheduler.tick(busy, 1)
        for slot in quarantined:
            self.fault_stats.add(nan_events=1)
            self._reclaim(slot, FINISH_QUARANTINE)

    # ------------------------------------------------- §10 fault tolerance

    def _apply_faults(self) -> None:
        """Consume due FaultPlan events at a chunk boundary (the only points
        where host state is consistent).  Targeted events (nan / stall /
        draft_exc) are held pending until their request occupies a slot;
        bursts submit through the normal bounded-queue front door; a kill
        raises out of ``run`` — recovery is load_state_dict."""
        if self.faults is None:
            return
        step = self.steps
        for e in self.faults.due(step, "burst"):
            self.fault_stats.add(injected=1)
            for req in self.faults.next_burst_requests(e.count):
                self.submit(req)
        for e in self.faults.due(step, "nan"):
            self.fault_stats.add(injected=1)
            self._nan_due.add(e.request_id)
        for e in self.faults.due(step, "stall"):
            self.fault_stats.add(injected=1)
            self._stall_due[e.request_id] = e.count
        for e in self.faults.due(step, "draft_exc"):
            self.fault_stats.add(injected=1)
            self._draft_exc_due.add(e.request_id)
        if self.faults.due(step, "kill"):
            self.fault_stats.add(injected=1)
            raise EngineKilled(f"injected kill at engine step {step}")

    def _enforce_deadlines(self) -> None:
        """Reclaim slots whose request outstayed its decode-step deadline."""
        # pending stalls first: phantom aging lands the moment its target
        # is in a slot, deterministically tripping the deadline below
        for slot, req in self.scheduler.active.items():
            if req.request_id in self._stall_due and not self.done[slot]:
                self.slot_age[slot] += self._stall_due.pop(req.request_id)
        for slot in list(self.scheduler.active):
            req = self.scheduler.active[slot]
            if self.done[slot]:
                continue
            ddl = req.deadline_steps if req.deadline_steps is not None \
                else self.deadline_steps
            if ddl is not None and self.slot_age[slot] >= ddl:
                self._reclaim(slot, FINISH_TIMEOUT)

    def _reclaim(self, slot: int, reason: str) -> None:
        """Pull the request out of ``slot`` without finishing it (§10).

        Its valid partial output is preserved: a retry re-enters through
        the queue with that output grown onto its draft, so spec-prefix
        admission re-VERIFIES the tokens instead of regenerating them
        (one forward over [prompt | draft]).  Retries exhausted → a
        failure Response carrying the best-effort partial output.
        Quarantines also walk the degradation ladder: first strike turns
        the request's drafting off, a repeat steps the engine's decode
        impl down one rung.
        """
        req = self.scheduler.active[slot]
        cnt = max(0, int(self.count[slot]))
        toks = (np.concatenate(self._acc_tok[slot])[:cnt]
                if self._acc_tok[slot] else
                np.zeros(0, np.int32)).astype(np.int32)
        lps = (np.concatenate(self._acc_lp[slot])[:cnt]
               if self._acc_lp[slot] else
               np.zeros(0, np.float32)).astype(np.float32)
        n1 = int(self._slot_n[slot])
        plp = self._slot_prefix_lp[slot]
        if reason == FINISH_QUARANTINE:
            req.nan_strikes += 1
            self.fault_stats.add(quarantines=1)
            if not req.draft_off:
                req.draft_off = True        # ladder rung 1: stop speculating
                if self.draft:
                    self.fault_stats.add(draft_disabled=1)
            if req.nan_strikes >= 2:
                self._degrade_impl()        # rung 2: simpler decode kernel
        now = self._now()
        # §14: remember WHY the slot was lost — the partial output that
        # re-enters via spec-prefix verification on retry is attributed
        # RETRY_STITCHED (timeout/stall) or QUARANTINE_CLAMPED, not reuse
        self.ledger.note_retry(req.request_id, reason)
        self.scheduler.reclaim(slot, now=now, reason=reason)
        self._on_slot_freed(slot)
        tr = self.tracer
        _lane = f"{self.obs_label}req/{req.request_id}"
        if tr.enabled and tr.sampled(req.request_id):
            # fault instant on the request lane: quarantine / timeout / shed
            tr.event(reason, _lane, cat="fault", ts=self._abs(now),
                     slot=slot, retries=req.retries)
        if req.retries < req.max_retries:
            if self.spec_prefix:
                # accepted prefix ⊕ partial output becomes the retry draft;
                # lp_curr stands in for behaviour logprobs (both are this
                # policy's own logprobs, so re-verification accepts them)
                prev_t = (np.asarray(req.draft_tokens, np.int32)[:n1]
                          if req.draft_tokens is not None
                          else np.zeros(0, np.int32))
                prev_l = (np.asarray(plp, np.float32)[:n1]
                          if plp is not None else np.zeros(0, np.float32))
                req.draft_tokens = np.concatenate([prev_t,
                                                   toks]).astype(np.int32)
                req.draft_logprobs = np.concatenate(
                    [prev_l, lps]).astype(np.float32)
                req.draft_eos = False
            if self.retry_backoff is not None:
                # §12: hold the retry until its backoff due step — the
                # request re-enters the queue via _release_retries once
                # the engine clock catches up (delay grows per retry)
                delay = self.retry_backoff.delay(req.retries)
                self._retry_hold.append(
                    (self.steps + max(0, math.ceil(delay)), req))
            else:
                self.scheduler.resubmit(req, now=now)
            if tr.enabled and tr.sampled(req.request_id):
                tr.event("retry", _lane, cat="fault", ts=self._abs(now),
                         retry=req.retries)
        else:
            toks2, lps2, orig = self._stitch(req, n1, plp, toks, lps)
            self.fault_stats.add(failed=1)
            if self.ledger.enabled and self.ledger.has_row(req.request_id):
                # conservation holds for failure responses too: the plane
                # covers prompt + caller prefix + best-effort continuation
                self.ledger.finalize(req.request_id,
                                     len(req.prompt) + orig + len(toks2))
            self.responses[req.request_id] = Response(
                request_id=req.request_id, tokens=toks2, logprobs=lps2,
                length=len(toks2), finish_reason=reason, n_accepted=orig,
                prefix_logprobs=plp,
                draft_len=int(self._slot_draft_len[slot]), slot=slot,
                queue_time=req.admitted_at - req.queued_at,
                serve_time=now - req.admitted_at, retries=req.retries)
            self.metrics.observe("serve.serve_ms",
                                 (now - req.admitted_at) * 1e3)
            self.metrics.observe("serve.retries_per_request", req.retries)
            if tr.enabled and tr.sampled(req.request_id):
                # retroactive whole-lifecycle span: queued → failed
                tr.complete("request", _lane, self._abs(req.queued_at),
                            self._abs(now), cat="request", reason=reason,
                            tokens=len(toks2), retries=req.retries)
        self.done[slot] = True
        self._acc_tok[slot] = []
        self._acc_lp[slot] = []
        self._slot_prefix_lp[slot] = None

    def _stitch(self, req: Request, n1: int, plp, toks, lps):
        """Split a serving session's output at the CALLER's draft boundary.

        ``n1`` is the final admission's accepted-prefix length; past
        ``base_draft_len`` it covers the request's own re-verified partial
        output, which belongs in the *continuation* — the Response contract
        (caller-draft prefix vs everything generated here) is retry-blind.
        For never-retried requests n1 <= base and this is the identity.
        """
        base = max(0, int(req.base_draft_len))
        orig = min(n1, base)
        if n1 > orig:
            toks = np.concatenate([np.asarray(req.draft_tokens,
                                              np.int32)[orig:n1], toks])
            lps = np.concatenate([np.asarray(plp,
                                             np.float32)[orig:n1], lps])
        return toks.astype(np.int32), lps.astype(np.float32), orig

    def _degrade_impl(self) -> None:
        """Step the decode-attention impl down one ladder rung (§10).

        Engine-wide by necessity — the impl is a static jit field — so it
        only fires on a *second* quarantine of the same request, after
        per-row degradation (drafting off) was not enough.  Costs one
        recompile of each device program; the clean path never pays it.
        """
        nxt = _IMPL_LADDER.get(self.cfg.decode_impl)
        if nxt is None:
            return
        self.cfg = self.cfg.replace(decode_impl=nxt)
        self.fault_stats.add(impl_fallbacks=1)

    # -------------------------------------------------------------- harvest

    def _harvest(self) -> List[Response]:
        eos = self.gen.eos_id
        finished = []
        # a slot still PREFILLING belongs to a partially-admitted group (the
        # paged engine admits leaders before CoW followers) — its done flag
        # is stale state from the previous occupant, not a finished request
        for slot in [s for s in self.scheduler.active
                     if self.done[s]
                     and self.scheduler.active[s].state == DECODING]:
            req = self.scheduler.active[slot]
            cnt = int(self.count[slot])
            toks = (np.concatenate(self._acc_tok[slot])[:cnt]
                    if self._acc_tok[slot] else np.zeros(0, np.int32))
            lps = (np.concatenate(self._acc_lp[slot])[:cnt]
                   if self._acc_lp[slot] else np.zeros(0, np.float32))
            if self._slot_full_reuse[slot]:
                reason = FINISH_FULL_REUSE
            elif cnt > 0 and toks[-1] == eos:
                reason = FINISH_EOS
            else:
                reason = FINISH_BUDGET
            now = self._now()
            # retry-blind response split (§10): re-verified partial output
            # from earlier attempts moves from the accepted prefix back
            # into the continuation (identity for never-retried requests)
            toks, lps, orig = self._stitch(req, int(self._slot_n[slot]),
                                           self._slot_prefix_lp[slot],
                                           toks, lps)
            if self.ledger.enabled and self.ledger.has_row(req.request_id):
                # §14 conservation invariant: the provenance plane exactly
                # partitions prompt ⊕ caller prefix ⊕ continuation
                self.ledger.finalize(req.request_id,
                                     len(req.prompt) + orig + len(toks))
                self.ledger.clear_retry(req.request_id)
            resp = Response(
                request_id=req.request_id, tokens=toks, logprobs=lps,
                length=len(toks),
                finish_reason=reason, n_accepted=orig,
                prefix_logprobs=self._slot_prefix_lp[slot],
                draft_len=int(self._slot_draft_len[slot]), slot=slot,
                queue_time=req.admitted_at - req.queued_at,
                serve_time=now - req.admitted_at, retries=req.retries)
            self.responses[req.request_id] = resp
            self.scheduler.complete(slot, now=now)
            self._on_slot_freed(slot)
            self.metrics.observe("serve.serve_ms", resp.serve_time * 1e3)
            self.metrics.observe("serve.retries_per_request", req.retries)
            tr = self.tracer
            if tr.enabled and tr.sampled(req.request_id):
                # retroactive whole-lifecycle span: queued → finished
                tr.complete("request",
                            f"{self.obs_label}req/{req.request_id}",
                            self._abs(req.queued_at), self._abs(now),
                            cat="request", reason=reason, tokens=len(toks),
                            n_accepted=orig, slot=slot, retries=req.retries)
            self._acc_tok[slot] = []
            self._acc_lp[slot] = []
            self._slot_prefix_lp[slot] = None
            finished.append(resp)
        return finished

    # ----------------------------------------------- exact kill-and-resume

    _VEC_FIELDS = ("cur_tok", "cur_lp", "done", "count", "budget",
                   "next_pos", "write_idx", "keys", "slot_age", "_slot_n",
                   "_slot_draft_len", "_slot_full_reuse")

    def state_dict(self) -> Dict:
        """Everything the decode loop's future depends on, as an all-array
        pytree (checkpoint/io.save_pytree-compatible).

        Covers the cache slabs, every per-slot state vector, the partial
        token accumulators, the scheduler (queued + in-flight requests,
        bit-exact), finished responses, the §9 draft state (controller
        EMAs, n-gram streams/corpora — the index is rebuilt on load, which
        is order-equivalent to the incremental indexing that built it) and
        all counters.  NOT covered, by design: params/config (the caller
        reconstructs the engine the same way it built it — asserted via
        meta) and the FaultPlan (a restored engine resumes clean).
        ``load_state_dict(state_dict())`` resumes token-identically
        (tests/serving/test_kill_resume.py).
        """
        st: Dict = {
            "meta": {
                "num_slots": np.int64(self.scheduler.num_slots),
                "prompt_width": np.int64(self.P),
                "max_new_tokens": np.int64(self.N),
                "spec_prefix": np.bool_(self.spec_prefix),
                "decode_impl": np.int64(
                    _IMPL_NAMES.index(self.cfg.decode_impl)),
                "steps": np.int64(self.steps),
                "elapsed": np.float64(self._now()),
                "time_admit": np.float64(self.time_admit),
                "time_slot_write": np.float64(self.time_slot_write),
                "time_decode": np.float64(self.time_decode),
            },
            "caches": jax.tree.map(np.asarray, self.caches),
            "vec": {k: np.asarray(getattr(self, k))
                    for k in self._VEC_FIELDS},
            "acc_tok": {str(s): np.concatenate(a).astype(np.int32)
                        for s, a in enumerate(self._acc_tok) if a},
            "acc_lp": {str(s): np.concatenate(a).astype(np.float32)
                       for s, a in enumerate(self._acc_lp) if a},
            "prefix_lp": {str(s): np.asarray(p, np.float32)
                          for s, p in enumerate(self._slot_prefix_lp)
                          if p is not None},
            "scheduler": self.scheduler.state_dict(),
            "responses": {str(rid): r.to_state()
                          for rid, r in self.responses.items()},
            "fault_stats": {k: np.int64(getattr(self.fault_stats, k))
                            for k in FaultStats.FIELDS},
            # §11: the latency histograms resume with the engine, so a
            # kill-and-resume run keeps monotonic counters and percentiles
            "obs": self.metrics.state_dict(),
        }
        if self._retry_hold:
            # §12 backoff holds: requests waiting out their retry delay are
            # in-flight state too — dropping them on resume would lose work.
            # Written only when non-empty, so default-config snapshots stay
            # bit-identical to their pre-backoff layout.
            st["retry_hold"] = {
                str(i): {"due": np.int64(d), "req": r.to_state()}
                for i, (d, r) in enumerate(self._retry_hold)}
        if self.draft:
            st["draft"] = {
                "rate": np.asarray(self._draft_ctrl.rate, np.float64),
                "stream": {str(s): np.asarray(v, np.int64)
                           for s, v in enumerate(self._draft_source._stream)},
                "corpus": {str(s): {str(j): np.asarray(seq, np.int32)
                                    for j, seq in enumerate(v)}
                           for s, v in
                           enumerate(self._draft_source._corpus)},
                "stats": {k: np.int64(getattr(self.draft_stats, k))
                          for k in ("forwards", "draft_forwards", "proposed",
                                    "accepted", "emitted")},
            }
        return st

    def load_state_dict(self, state: Dict) -> None:
        meta = state["meta"]
        assert int(meta["num_slots"]) == self.scheduler.num_slots and \
            int(meta["prompt_width"]) == self.P and \
            int(meta["max_new_tokens"]) == self.N and \
            bool(meta["spec_prefix"]) == self.spec_prefix, \
            "engine was constructed with a different shape than the snapshot"
        impl = _IMPL_NAMES[int(meta["decode_impl"])]
        if impl != self.cfg.decode_impl:   # resume mid-degradation-ladder
            self.cfg = self.cfg.replace(decode_impl=impl)
        caches = jax.tree.map(jnp.asarray, state["caches"])
        if self.mesh is not None:
            from repro.distributed.mesh import shard_caches
            caches = shard_caches(self.cfg, caches, self.mesh, batch=False)
        self.caches = caches
        for k in self._VEC_FIELDS:
            setattr(self, k, np.array(state["vec"][k]))
        self._slot_full_reuse = self._slot_full_reuse.astype(bool)
        self.done = self.done.astype(bool)
        B = self.scheduler.num_slots
        self._acc_tok = [[np.asarray(state["acc_tok"][str(s)], np.int32)]
                         if str(s) in state["acc_tok"] else []
                         for s in range(B)]
        self._acc_lp = [[np.asarray(state["acc_lp"][str(s)], np.float32)]
                        if str(s) in state["acc_lp"] else []
                        for s in range(B)]
        self._slot_prefix_lp = [
            np.asarray(state["prefix_lp"][str(s)], np.float32)
            if str(s) in state["prefix_lp"] else None for s in range(B)]
        self.scheduler.load_state_dict(state["scheduler"])
        self.responses = {int(rid): Response.from_state(rs)
                          for rid, rs in state["responses"].items()}
        for k in FaultStats.FIELDS:
            setattr(self.fault_stats, k, int(state["fault_stats"][k]))
        if "obs" in state:          # absent in pre-§11 snapshots
            self.metrics.load_state_dict(state["obs"])
        hold = state.get("retry_hold", {})   # absent in pre-§12 snapshots
        self._retry_hold = [
            (int(hold[str(i)]["due"]), Request.from_state(hold[str(i)]["req"]))
            for i in range(len(hold))]
        if self.draft and "draft" in state:
            d = state["draft"]
            self._draft_ctrl.rate = np.array(d["rate"], np.float64)
            for s in range(B):
                stream = [int(t) for t in np.asarray(d["stream"][str(s)])]
                corp = d["corpus"].get(str(s), {})
                corpus = [np.asarray(corp[str(j)], np.int32)
                          for j in range(len(corp))]
                # reset() re-registers corpus-then-stream in the same order
                # incremental indexing did, so the rebuilt suffix map is
                # identical and proposals resume bit-exactly
                self._draft_source.reset(s, stream, corpus)
            for k in ("forwards", "draft_forwards", "proposed", "accepted",
                      "emitted"):
                setattr(self.draft_stats, k, int(d["stats"][k]))
        self.steps = int(meta["steps"])
        self.time_admit = float(meta["time_admit"])
        self.time_slot_write = float(meta["time_slot_write"])
        self.time_decode = float(meta["time_decode"])
        self._t0 = time.perf_counter() - float(meta["elapsed"])
