"""Persistent continuous-batching decode loop over slot-replaced dense caches.

The engine keeps ONE decode batch of ``num_slots`` rows alive over dense
``(B, Hkv, S, D)`` caches (DESIGN.md §3 rejects paged KV on TPU — in-place
slot replacement is the idiomatic alternative, §6).  Whenever a row emits
EOS or exhausts its per-slot budget, the next queued request is prefilled —
optionally through ``verify_and_prefill`` so a cached SPEC-RL draft becomes
its speculative prefix — and written into the freed slot by the
``cache_slot_write`` batched-scatter kernel.  No other row notices: the
decode batch never drains to its slowest member.

Three jit'd device programs, all statically shaped:

* ``_admit_vanilla``  — prefill a padded admission group + seed sample;
* ``_admit_spec``     — fused verify+prefill over [prompt | draft], compact
  to the accepted prefix (cache_gather), seed sample at the last accepted
  token — speculative-prefix admission;
* ``_decode_chunk``   — ``chunk_steps`` decode steps for all B slots with
  per-row write offsets (each slot sits at its own depth), per-row PRNG
  streams and per-row budgets.  Its body is term-for-term the body of
  ``engine/generate._decode_loop``, which is what makes slot-scheduled
  output token-identical to fixed-batch ``generate`` (tested).

Host side: numpy state vectors + the SlotScheduler; admission groups are
padded to ``num_slots`` rows by duplicating a real admitted row (duplicate
slot writes carry identical bytes), so every jit sees one shape.
"""
from __future__ import annotations

import functools
import time
from typing import Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.verify import verify_and_prefill
from repro.engine.generate import GenerateConfig, positions_from_mask
from repro.engine.sampling import sample, split_key
from repro.models import model as M
from repro.models.config import ModelConfig

from .request import (FINISH_BUDGET, FINISH_EOS, FINISH_FULL_REUSE, Request,
                      Response)
from .scheduler import SlotScheduler


@functools.partial(jax.jit, static_argnames=("cfg", "gen", "mesh"))
def _admit_vanilla(params, cfg: ModelConfig, gen: GenerateConfig, prompts,
                   mask, keys, mesh=None):
    """Prefill an admission group; mirrors ``generate`` up to the seed token.

    prompts: (R, P) left-padded; keys: (R, 2) per-request decode keys.
    Returns caches sized P + N per row (the exact layout fixed-batch
    ``generate`` builds), the seed token/logprob and the carry keys.
    """
    R, P = prompts.shape
    caches = M.init_cache(cfg, R, P + gen.max_new_tokens)
    if mesh is not None:
        from repro.distributed.mesh import constrain_caches
        caches = constrain_caches(cfg, caches, mesh, batch=False)
    logits, caches = M.prefill(params, cfg, prompts, positions_from_mask(mask),
                               caches)
    keys, sub = split_key(keys)
    tok0, lp0 = sample(sub, logits[:, -1], gen.temperature, gen.top_p)
    return {"caches": caches, "tok0": tok0, "lp0": lp0,
            "next_pos": mask.sum(axis=1).astype(jnp.int32), "keys": keys}


@functools.partial(jax.jit, static_argnames=("cfg", "gen", "verify_impl",
                                             "compact_impl", "mesh"))
def _admit_spec(params, cfg: ModelConfig, gen: GenerateConfig, prompts, mask,
                draft_tokens, draft_lp, draft_len, draft_eos, verify_keys,
                decode_keys, log_lenience, *, verify_impl: str,
                compact_impl: str, mesh=None):
    """Speculative-prefix admission: one forward over [prompt | draft].

    Identical device program to the fixed-batch one-pass rollout path
    (verify_and_prefill → realign_decode_cache → seed sample), so a request
    admitted here continues from the same compacted cache, seed logits and
    PRNG stream as ``rollout`` would give it.
    """
    R, P = prompts.shape
    N = draft_tokens.shape[1]
    W = P + N
    ver = verify_and_prefill(params, cfg, prompts, mask, draft_tokens,
                             draft_lp, draft_len, verify_keys, log_lenience,
                             temperature=gen.temperature, top_p=gen.top_p,
                             impl=verify_impl, mesh=mesh)
    n = ver["n"]
    p_len = mask.sum(axis=1).astype(jnp.int32)
    caches = M.realign_decode_cache(cfg, ver["caches"],
                                    (N - n).astype(jnp.int32), p_len + n, W,
                                    impl=compact_impl, mesh=mesh)
    full_reuse = (n == draft_len) & draft_eos
    keys, sub = split_key(decode_keys)
    tok0, lp0 = sample(sub, ver["seed_logits"], gen.temperature, gen.top_p)
    return {"caches": caches, "tok0": tok0, "lp0": lp0, "n": n,
            "lp_curr": ver["lp_curr"], "full_reuse": full_reuse,
            "next_pos": p_len + n, "keys": keys}


@functools.partial(jax.jit, static_argnames=("cfg", "impl", "pad_src",
                                             "mesh"))
def _write_slots(cfg: ModelConfig, dst_caches, src_caches, slots, *,
                 impl: str = "auto", pad_src: int = 0, mesh=None):
    # drafted engines keep draft_k spare slots per row (§9 block headroom);
    # admission caches are padded to the persistent width before the scatter
    if pad_src:
        src_caches = M.pad_cache(cfg, src_caches, pad_src)
    return M.write_cache_slots(cfg, dst_caches, src_caches, slots, impl=impl,
                               mesh=mesh)


@functools.partial(jax.jit, static_argnames=("cfg", "gen", "steps", "mesh"))
def _decode_chunk(params, cfg: ModelConfig, gen: GenerateConfig, caches,
                  cur_tok, cur_lp, done, count, budget, next_pos, write_idx,
                  keys, *, steps: int, mesh=None):
    """``steps`` decode steps over all slots; per-row write offsets/streams.

    Term-for-term the body of ``engine/generate._decode_loop`` (store →
    count/done update → decode_step → split → sample), except the cache
    write lands at the per-row ``write_idx`` instead of a batch-wide offset
    and the loop never stops early — idle/done rows keep stepping with
    position −1 (position-masked attention ignores those writes, and the
    slot is fully rewritten at its next admission).
    """
    def body(carry, _):
        caches, cur_tok, cur_lp, done, count, next_pos, write_idx, keys = carry
        tok_store = jnp.where(done, gen.pad_id, cur_tok)
        lp_store = jnp.where(done, 0.0, cur_lp)
        count = count + (~done).astype(jnp.int32)
        done_next = done | (cur_tok == gen.eos_id) | (count >= budget)
        # per-row live extents: each slot sits at its own decode depth, so
        # the flash-decode kernel early-exits per row at write_idx + 1 and
        # skips the dead left padding below write_idx - next_pos (the
        # admitted context is contiguous — prefill or compacted layout)
        logits, caches = M.decode_step(
            params, cfg, tok_store[:, None],
            jnp.where(done[:, None], -1, next_pos[:, None]),
            caches, write_idx, kv_length=write_idx + 1,
            kv_start=write_idx - next_pos, mesh=mesh)
        keys, sub = split_key(keys)
        nxt, nlp = sample(sub, logits[:, 0], gen.temperature, gen.top_p)
        carry = (caches, nxt, nlp, done_next, count, next_pos + 1,
                 write_idx + 1, keys)
        return carry, (tok_store, lp_store)

    init = (caches, cur_tok, cur_lp, done, count, next_pos, write_idx, keys)
    carry, (toks, lps) = jax.lax.scan(body, init, None, length=steps)
    caches, cur_tok, cur_lp, done, count, next_pos, write_idx, keys = carry
    return {"caches": caches, "cur_tok": cur_tok, "cur_lp": cur_lp,
            "done": done, "count": count, "next_pos": next_pos,
            "write_idx": write_idx, "keys": keys,
            "tokens": toks.T, "logprobs": lps.T}      # (B, steps)


class SlotEngine:
    """Continuous-batching generation engine with spec-prefix admission."""

    def __init__(self, params, cfg: ModelConfig, gen: GenerateConfig, *,
                 num_slots: int, prompt_width: int, spec_prefix: bool = False,
                 log_lenience: float = 0.0, chunk_steps: int = 8,
                 verify_impl: str = "auto", compact_impl: str = "auto",
                 slot_write_impl: str = "auto", draft=None, mesh=None):
        assert M.supports_slot_serving(cfg), \
            "slot serving needs an attention-only trunk without modality " \
            "extras — use fixed-batch generate otherwise"
        self.params, self.cfg, self.gen = params, cfg, gen
        self.P = int(prompt_width)
        self.N = int(gen.max_new_tokens)
        self.spec_prefix = bool(spec_prefix)
        self.log_lenience = float(log_lenience)
        self.chunk_steps = max(1, int(chunk_steps))
        self.verify_impl, self.compact_impl = verify_impl, compact_impl
        self.slot_write_impl = slot_write_impl
        # §9 continuation draft engine: a DraftConfig switches _run_chunk
        # from `chunk_steps` single-token scans to one draft-verify block
        # per chunk, with per-slot n-gram sources / length controllers
        self.draft = draft if (draft is not None and draft.enabled) else None
        # One engine serves ONE data shard: its decode batch stays whole and
        # only the KV head axis (and the params the caller pre-sharded)
        # spread over the mesh's ``model`` axis.  Data parallelism lives one
        # level up — MeshSlotServer runs one engine per data-shard submesh
        # (DESIGN.md §8).
        self.mesh = mesh
        # context ends at write_base; decode token t lands at write_base + t
        # (vanilla: prefill layout [0, P); spec: compacted layout [0, P+N));
        # drafted engines add draft_k headroom for the block write (§9)
        self.write_base = self.P + (self.N if spec_prefix else 0)
        self.cache_len = self.write_base + self.N + \
            (self.draft.draft_k if self.draft else 0)

        B = int(num_slots)
        if self.draft:
            from repro.core.metrics import DraftStats
            from repro.drafting import DraftController, NGramDraftSource
            self._draft_source = NGramDraftSource(self.draft, B)
            self._draft_ctrl = DraftController(self.draft, B)
            self.draft_stats = DraftStats()
        self.caches = M.init_cache(cfg, B, self.cache_len)
        if mesh is not None:
            from repro.distributed.mesh import shard_caches
            self.caches = shard_caches(cfg, self.caches, mesh, batch=False)
        self.scheduler = SlotScheduler(B)
        self.cur_tok = np.zeros(B, np.int32)
        self.cur_lp = np.zeros(B, np.float32)
        self.done = np.ones(B, bool)
        self.count = np.zeros(B, np.int32)
        self.budget = np.zeros(B, np.int32)
        self.next_pos = np.zeros(B, np.int32)
        self.write_idx = np.full(B, self.write_base, np.int32)
        self.keys = np.zeros((B, 2), np.uint32)
        self._acc_tok: List[List[np.ndarray]] = [[] for _ in range(B)]
        self._acc_lp: List[List[np.ndarray]] = [[] for _ in range(B)]
        self._slot_n = np.zeros(B, np.int32)
        self._slot_draft_len = np.zeros(B, np.int32)
        self._slot_full_reuse = np.zeros(B, bool)
        self._slot_prefix_lp: List[Optional[np.ndarray]] = [None] * B
        self.responses: Dict[int, Response] = {}
        self.steps = 0                      # engine decode steps elapsed
        self.time_admit = 0.0
        self.time_slot_write = 0.0
        self.time_decode = 0.0
        self._t0 = time.perf_counter()

    # ------------------------------------------------------------- frontend

    def _now(self) -> float:
        return time.perf_counter() - self._t0

    def submit(self, req: Request) -> None:
        assert len(req.prompt) <= self.P, (len(req.prompt), self.P)
        assert 0 <= req.max_new_tokens <= self.N, req.max_new_tokens
        self.scheduler.submit(req, now=self._now())

    def run(self, arrivals: Optional[Iterable[Tuple[int, Request]]] = None,
            max_chunks: Optional[int] = None) -> Dict[int, Response]:
        """Drive the loop until queue + slots drain (and arrivals exhaust).

        arrivals: optional (due_step, Request) stream sorted by due_step —
        requests arriving while the engine runs; the loop idles forward to
        the next due step when it would otherwise drain.
        """
        it = iter(arrivals) if arrivals is not None else None
        nxt = next(it, None) if it is not None else None
        chunks = 0
        while True:
            while nxt is not None and nxt[0] <= self.steps:
                self.submit(nxt[1])
                nxt = next(it, None)
            self._admit()
            if self.scheduler.idle:
                if nxt is None:
                    break
                self.steps = max(self.steps, int(nxt[0]))  # idle fast-forward
                continue
            self._run_chunk()
            self._harvest()
            chunks += 1
            if max_chunks is not None and chunks >= max_chunks:
                break
        return self.responses

    def stats(self) -> Dict[str, float]:
        from repro.core.metrics import DraftStats
        out = self.scheduler.stats()
        out.update(engine_steps=float(self.steps),
                   generated_tokens=float(sum(r.length
                                              for r in self.responses.values())),
                   reused_tokens=float(sum(r.n_accepted
                                           for r in self.responses.values())),
                   admit_time=self.time_admit,
                   slot_write_time=self.time_slot_write,
                   decode_time=self.time_decode,
                   wall_time=self._now())
        # §9 draft telemetry (zeros for undrafted engines, so the stats
        # schema is uniform across engine modes and mesh shards)
        out.update((self.draft_stats if self.draft else DraftStats())
                   .as_dict())
        return out

    # ------------------------------------------------------------ admission

    def _pad_group(self, rows: List[np.ndarray]) -> np.ndarray:
        """Stack + pad a group to num_slots rows by duplicating row 0."""
        B = self.scheduler.num_slots
        rows = rows + [rows[0]] * (B - len(rows))
        return np.stack(rows)

    def _admit(self) -> None:
        while True:
            group = self.scheduler.reserve(self._now())
            if not group:
                return
            t0 = time.perf_counter()
            B = self.scheduler.num_slots
            slots = [s for s, _ in group]
            reqs = [r for _, r in group]
            prom = np.zeros((len(group), self.P), np.int32)
            mask = np.zeros((len(group), self.P), bool)
            for j, r in enumerate(reqs):
                L = len(r.prompt)
                prom[j, self.P - L:] = np.asarray(r.prompt, np.int32)
                mask[j, self.P - L:] = True
            prompts = self._pad_group(list(prom))
            masks = self._pad_group(list(mask))
            keys = self._pad_group([np.asarray(r.key, np.uint32) for r in reqs])

            if self.spec_prefix:
                dt = np.zeros((len(group), self.N), np.int32)
                dl = np.zeros((len(group), self.N), np.float32)
                dn = np.zeros((len(group),), np.int32)
                de = np.zeros((len(group),), bool)
                for j, r in enumerate(reqs):
                    if r.has_draft:
                        L = min(len(r.draft_tokens), self.N)
                        dt[j, :L] = r.draft_tokens[:L]
                        dl[j, :L] = r.draft_logprobs[:L]
                        dn[j] = L
                        de[j] = r.draft_eos and L == len(r.draft_tokens)
                vkeys = self._pad_group(
                    [np.asarray(r.verify_key, np.uint32) for r in reqs])
                out = _admit_spec(
                    self.params, self.cfg, self.gen, jnp.asarray(prompts),
                    jnp.asarray(masks), jnp.asarray(self._pad_group(list(dt))),
                    jnp.asarray(self._pad_group(list(dl))),
                    jnp.asarray(self._pad_group(list(dn))),
                    jnp.asarray(self._pad_group(list(de))),
                    jnp.asarray(vkeys), jnp.asarray(keys),
                    self.log_lenience, verify_impl=self.verify_impl,
                    compact_impl=self.compact_impl, mesh=self.mesh)
            else:
                out = _admit_vanilla(self.params, self.cfg, self.gen,
                                     jnp.asarray(prompts), jnp.asarray(masks),
                                     jnp.asarray(keys), mesh=self.mesh)
            jax.block_until_ready(out["tok0"])
            t1 = time.perf_counter()
            self.time_admit += t1 - t0

            slot_ids = np.array(slots + [slots[0]] * (B - len(slots)),
                                np.int32)
            self.caches = _write_slots(self.cfg, self.caches, out["caches"],
                                       jnp.asarray(slot_ids),
                                       impl=self.slot_write_impl,
                                       pad_src=self.draft.draft_k
                                       if self.draft else 0,
                                       mesh=self.mesh)
            jax.block_until_ready(jax.tree.leaves(self.caches)[0])
            self.time_slot_write += time.perf_counter() - t1

            tok0 = np.asarray(out["tok0"])
            lp0 = np.asarray(out["lp0"])
            npos = np.asarray(out["next_pos"])
            nkeys = np.asarray(out["keys"])
            n = np.asarray(out["n"]) if self.spec_prefix else \
                np.zeros(B, np.int32)
            fr = np.asarray(out["full_reuse"]) if self.spec_prefix else \
                np.zeros(B, bool)
            lp_curr = np.asarray(out["lp_curr"]) if self.spec_prefix else None
            for j, (slot, req) in enumerate(group):
                nj = int(n[j])
                budget = max(0, req.max_new_tokens - nj)
                self.cur_tok[slot] = tok0[j]
                self.cur_lp[slot] = lp0[j]
                self.count[slot] = 0
                self.budget[slot] = budget
                self.next_pos[slot] = npos[j]
                self.write_idx[slot] = self.write_base
                self.keys[slot] = nkeys[j]
                self.done[slot] = bool(fr[j]) or budget <= 0
                self._acc_tok[slot] = []
                self._acc_lp[slot] = []
                self._slot_n[slot] = nj
                self._slot_draft_len[slot] = int(dn[j]) if self.spec_prefix \
                    else 0
                self._slot_full_reuse[slot] = bool(fr[j])
                self._slot_prefix_lp[slot] = lp_curr[j] if lp_curr is not None \
                    else None
                if self.draft:
                    # n-gram index over prompt ⊕ accepted prefix, shadowing
                    # the request's sibling corpus (DESIGN.md §9)
                    ctx = list(np.asarray(req.prompt, np.int32))
                    if self.spec_prefix and req.has_draft:
                        ctx.extend(np.asarray(req.draft_tokens[:nj],
                                              np.int32))
                    self._draft_source.reset(slot, ctx, req.ngram_corpus)
                    self._draft_ctrl.reset(slot)
                self.scheduler.activate(slot)
            # full-reuse / zero-budget admissions finish without decoding;
            # harvesting them here lets the loop keep back-filling
            self._harvest()

    # ---------------------------------------------------------- decode loop

    def _run_chunk(self, steps: Optional[int] = None) -> None:
        if self.draft:
            return self._run_draft_chunk()
        steps = steps or self.chunk_steps
        busy = sum(1 for s in self.scheduler.active if not self.done[s])
        t0 = time.perf_counter()
        out = _decode_chunk(
            self.params, self.cfg, self.gen, self.caches,
            jnp.asarray(self.cur_tok), jnp.asarray(self.cur_lp),
            jnp.asarray(self.done), jnp.asarray(self.count),
            jnp.asarray(self.budget), jnp.asarray(self.next_pos),
            jnp.asarray(self.write_idx), jnp.asarray(self.keys), steps=steps,
            mesh=self.mesh)
        self.caches = out["caches"]
        toks = np.asarray(out["tokens"])            # (B, steps)
        lps = np.asarray(out["logprobs"])
        self.time_decode += time.perf_counter() - t0
        for name in ("cur_tok", "cur_lp", "done", "count", "next_pos",
                     "write_idx", "keys"):
            # np.array (not asarray): jax arrays view as read-only and the
            # admission path writes these in place
            setattr(self, name, np.array(out[name]))
        for slot in self.scheduler.active:
            self._acc_tok[slot].append(toks[slot])
            self._acc_lp[slot].append(lps[slot])
        self.steps += steps
        self.scheduler.tick(busy, steps)

    def _run_draft_chunk(self) -> None:
        """One §9 draft-verify macro-step over all slots.

        The device program is the SAME jit'd ``drafting.step.draft_step``
        the fixed-batch drafted loops run — per-row write offsets / budgets
        / PRNG streams are the machinery this engine already carries, so a
        slot absorbs a variable-length accept exactly like a fixed-batch
        row (and greedy output stays token-identical, tested)."""
        from repro.drafting.step import block_width, draft_step
        K = self.draft.draft_k
        B = self.scheduler.num_slots
        busy = sum(1 for s in self.scheduler.active if not self.done[s])
        dt = np.zeros((B, K), np.int32)
        dl = np.zeros((B,), np.int32)
        for slot in self.scheduler.active:
            if self.done[slot]:
                continue
            k_s = self._draft_ctrl.draft_len(slot)
            d = self._draft_source.propose(slot, k_s,
                                           pending=int(self.cur_tok[slot]))
            dt[slot, :len(d)] = d
            dl[slot] = len(d)
        # bucketed block width (drafting/step.py:block_width): the forward
        # narrows with the controller's draft lengths; u_width = draft_k
        # keeps per-request streams independent of co-batched buckets
        K_step = block_width(int(dl.max()), K)
        t0 = time.perf_counter()
        out = draft_step(
            self.params, self.cfg, self.gen, self.caches,
            jnp.asarray(self.cur_tok), jnp.asarray(self.cur_lp),
            jnp.asarray(self.done), jnp.asarray(self.count),
            jnp.asarray(self.budget), jnp.asarray(self.next_pos),
            jnp.asarray(self.write_idx), jnp.asarray(self.keys),
            jnp.asarray(dt[:, :K_step]), jnp.asarray(dl), K=K_step,
            u_width=K, verify_impl=self.verify_impl, mesh=self.mesh)
        self.caches = out["caches"]
        toks = np.asarray(out["tokens"])            # (B, K+1)
        lps = np.asarray(out["logprobs"])
        emitted = np.asarray(out["emitted"])
        self.time_decode += time.perf_counter() - t0
        for name in ("cur_tok", "cur_lp", "done", "count", "next_pos",
                     "write_idx"):
            setattr(self, name, np.array(out[name]))
        self.keys = np.array(out["keys"])
        accepted = np.asarray(out["accepted"])
        proposed = np.asarray(out["proposed"])
        for slot in self.scheduler.active:
            m = int(emitted[slot])
            if m:
                self._acc_tok[slot].append(toks[slot, :m])
                self._acc_lp[slot].append(lps[slot, :m])
                self._draft_source.extend(slot, toks[slot, :m])
            self._draft_ctrl.update(slot, int(proposed[slot]),
                                    int(accepted[slot]))
        self.draft_stats.add_step(forwards=busy,
                                  proposed=int(proposed.sum()),
                                  accepted=int(accepted.sum()),
                                  emitted=int(emitted.sum()),
                                  draft_forwards=int((dl > 0).sum()))
        self.steps += 1                     # one forward = one engine step
        self.scheduler.tick(busy, 1)

    # -------------------------------------------------------------- harvest

    def _harvest(self) -> List[Response]:
        eos = self.gen.eos_id
        finished = []
        for slot in [s for s in self.scheduler.active if self.done[s]]:
            req = self.scheduler.active[slot]
            cnt = int(self.count[slot])
            toks = (np.concatenate(self._acc_tok[slot])[:cnt]
                    if self._acc_tok[slot] else np.zeros(0, np.int32))
            lps = (np.concatenate(self._acc_lp[slot])[:cnt]
                   if self._acc_lp[slot] else np.zeros(0, np.float32))
            if self._slot_full_reuse[slot]:
                reason = FINISH_FULL_REUSE
            elif cnt > 0 and toks[-1] == eos:
                reason = FINISH_EOS
            else:
                reason = FINISH_BUDGET
            now = self._now()
            resp = Response(
                request_id=req.request_id, tokens=toks.astype(np.int32),
                logprobs=lps.astype(np.float32), length=cnt,
                finish_reason=reason, n_accepted=int(self._slot_n[slot]),
                prefix_logprobs=self._slot_prefix_lp[slot],
                draft_len=int(self._slot_draft_len[slot]), slot=slot,
                queue_time=req.admitted_at - req.queued_at,
                serve_time=now - req.admitted_at)
            self.responses[req.request_id] = resp
            self.scheduler.complete(slot, now=now)
            self._acc_tok[slot] = []
            self._acc_lp[slot] = []
            self._slot_prefix_lp[slot] = None
            finished.append(resp)
        return finished
