"""Deterministic fault injection for the slot server (DESIGN.md §10).

Every recovery path in the serving layer is exercised by *injected*
failures, not hoped-for ones: a ``FaultPlan`` is a seeded, reproducible
schedule of fault events that the slot engine consults at chunk boundaries
(the only points where host state is consistent).  Replaying the same plan
against the same requests replays the same failures — which is what lets
tests assert exact recovery behaviour (rows untouched by faults stay
token-identical to a fault-free run) and lets ``benchmarks/fault_bench.py``
price recovery overhead against a clean run.

Event kinds (one dataclass, interpreted per kind):

* ``kill``       — raise ``EngineKilled`` at the chunk boundary, simulating
                   a process death mid-serve; recovery is checkpoint/io
                   ``save_server_state``/``load_server_state`` (exact
                   kill-and-resume, tests/serving/test_kill_resume.py).
* ``nan``        — corrupt the logits of the slot serving ``request_id`` on
                   the first step of the next decode chunk; the in-chunk
                   non-finite guard must quarantine the row.
* ``draft_exc``  — make the row's next draft proposal raise; the engine must
                   disable drafting for that row, never crash.
* ``stall``      — age the slot serving ``request_id`` by ``count`` phantom
                   engine steps, deterministically tripping its deadline
                   (the long-tail straggler failure mode).
* ``burst``      — submit ``count`` requests from the plan's
                   ``request_factory`` at once, overflowing the bounded
                   admission queue so the backpressure policy must act.

Events fire once, at the first chunk boundary at or after ``at_step``
(engine decode steps).  The plan is host-only state and deliberately NOT
part of the engine's ``state_dict`` — a restored engine resumes clean.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence

import numpy as np

KINDS = ("kill", "nan", "draft_exc", "stall", "burst")


class EngineKilled(RuntimeError):
    """Simulated process death at a chunk boundary (fault kind 'kill')."""


@dataclass
class FaultEvent:
    kind: str
    at_step: int = 0            # engine-step boundary at/after which it fires
    request_id: int = -1        # target request (nan / draft_exc / stall)
    count: int = 1              # stall: phantom steps; burst: #requests
    fired: bool = False

    def __post_init__(self):
        assert self.kind in KINDS, self.kind


@dataclass
class FaultPlan:
    """A reproducible schedule of fault events.

    ``request_factory(i)`` builds the i-th burst request (set by the test /
    bench harness that knows prompt shapes); unset plans simply never
    contain burst events.
    """
    events: List[FaultEvent] = field(default_factory=list)
    request_factory: Optional[Callable[[int], object]] = None
    _burst_serial: int = 0

    # -------------------------------------------------------------- queries

    def due(self, step: int, kind: str) -> List[FaultEvent]:
        """Unfired events of ``kind`` due at engine step ``step`` (marks
        them fired — each event is applied exactly once)."""
        out = []
        for e in self.events:
            if not e.fired and e.kind == kind and e.at_step <= step:
                e.fired = True
                out.append(e)
        return out

    def peek(self, kind: str) -> List[FaultEvent]:
        """All events of ``kind`` regardless of firing state (introspection
        for tests: which request_ids were ever targeted)."""
        return [e for e in self.events if e.kind == kind]

    def targeted_requests(self) -> set:
        """Request ids touched by any targeted fault — the complement is the
        set whose output must be token-identical to a fault-free run."""
        return {e.request_id for e in self.events
                if e.kind in ("nan", "draft_exc", "stall")
                and e.request_id >= 0}

    def exhausted(self) -> bool:
        return all(e.fired for e in self.events)

    def next_burst_requests(self, count: int) -> List[object]:
        assert self.request_factory is not None, \
            "burst events need a request_factory"
        out = [self.request_factory(self._burst_serial + i)
               for i in range(count)]
        self._burst_serial += count
        return out


def seeded_plan(seed: int, *, request_ids: Sequence[int], max_step: int,
                n_nan: int = 2, n_stall: int = 1, n_draft_exc: int = 0,
                n_burst: int = 0, burst_size: int = 4, kill_at: int = -1,
                stall_steps: int = 10 ** 6,
                request_factory: Optional[Callable[[int], object]] = None
                ) -> FaultPlan:
    """Build a reproducible mixed fault schedule from one integer seed.

    Draws targets / firing steps from ``np.random.default_rng(seed)`` so a
    (seed, request_ids, max_step) triple always yields the same plan — the
    chaos CI lane and fault bench pin their seeds.
    """
    rng = np.random.default_rng(seed)
    ids = list(request_ids)
    events: List[FaultEvent] = []

    def pick_ids(n):
        n = min(n, len(ids))
        return rng.choice(ids, size=n, replace=False) if n else []

    for rid in pick_ids(n_nan):
        events.append(FaultEvent("nan", at_step=int(rng.integers(0, max_step)),
                                 request_id=int(rid)))
    for rid in pick_ids(n_stall):
        events.append(FaultEvent("stall",
                                 at_step=int(rng.integers(0, max_step)),
                                 request_id=int(rid), count=stall_steps))
    for rid in pick_ids(n_draft_exc):
        events.append(FaultEvent("draft_exc",
                                 at_step=int(rng.integers(0, max_step)),
                                 request_id=int(rid)))
    for _ in range(n_burst):
        events.append(FaultEvent("burst",
                                 at_step=int(rng.integers(0, max_step)),
                                 count=burst_size))
    if kill_at >= 0:
        events.append(FaultEvent("kill", at_step=kill_at))
    events.sort(key=lambda e: (e.at_step, e.kind, e.request_id))
    return FaultPlan(events=events, request_factory=request_factory)
