"""Mesh-parallel slot serving: one scheduler per data shard (DESIGN.md §8).

The slot engine's admission scatter indexes *global* decode-batch rows, so
sharding one engine's batch over the ``data`` axis would turn every
admission into a cross-shard write.  Instead the data axis is handled one
level up: ``MeshSlotServer`` splits the (data, model) mesh into one
model-only submesh per data shard (disjoint devices), runs a full
``SlotEngine`` — scheduler, free-list, persistent caches — on each, and
round-robins incoming requests across them.  Admission is therefore
**shard-local**: a freed slot on shard i is refilled from shard i's queue
with no cross-shard traffic, and each shard's params/caches spread only
over its own ``model`` axis.

Because every request owns its PRNG streams (serving/request.py), output is
independent of which shard a request lands on — the server is
token-identical to a single engine over the same requests, which is the
§6 equivalence contract lifted to the mesh (asserted in
tests/distributed/test_mesh_rollout.py).

``stats()`` returns the gathered metrics view, produced by a type-driven
``MetricsRegistry.merge`` over the shard registries (DESIGN.md §11):
counters sum, peak gauges max, histograms merge bucket-wise, ratios
re-derive from the summed parts — plus a ``per_shard`` breakdown.  The
merge runs over the union of metric names, so a counter added to the
engine can never silently vanish from the gathered view (the pre-§11
hand-listed summation could drop fields).
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Tuple

from repro.distributed.mesh import data_submeshes, shard_params
from repro.engine.generate import GenerateConfig
from repro.obs import MetricsRegistry
from repro.models.config import ModelConfig

from .engine_loop import SlotEngine
from .request import Request, Response


def make_slot_engine(params, cfg: ModelConfig, gen: GenerateConfig, *,
                     mesh=None, num_slots: int, prompt_width: int,
                     spec_prefix: bool = False, log_lenience: float = 0.0,
                     chunk_steps: int = 8, verify_impl: str = "auto",
                     compact_impl: str = "auto",
                     slot_write_impl: str = "auto", draft=None, faults=None,
                     deadline_steps=None, max_queue=None,
                     overflow: str = "reject", tracer=None, ledger=None,
                     kv_pool_blocks: Optional[int] = None):
    """One factory for both mesh regimes (the single dispatch point shared
    by serving/rl_adapter.py and launch/serve.py).

    A mesh with a data axis yields a ``MeshSlotServer`` — ``num_slots`` is
    rounded down to a multiple of the shard count (floored at one slot per
    shard) and params are placed per submesh inside.  Otherwise one
    ``SlotEngine`` (head-sharding its caches when a model-only mesh is
    given); that path expects params already placed by the caller.

    §10 hardening knobs pass straight through: ``deadline_steps`` /
    ``max_queue`` / ``overflow`` apply per engine (per shard on a mesh —
    the bound is shard-local, like admission), ``faults`` is a FaultPlan
    (given to shard 0 on a mesh) or a per-shard sequence of plans.

    ``cfg.cache_layout='paged'`` selects the ``PagedSlotEngine`` (block
    pool + CoW GRPO sharing, DESIGN.md §13); ``kv_pool_blocks`` optionally
    shrinks its pool below the never-runs-dry default (per shard on a
    mesh — each shard engine owns its own allocator).
    """
    from repro.distributed.mesh import data_size
    kw = dict(num_slots=num_slots, prompt_width=prompt_width,
              spec_prefix=spec_prefix, log_lenience=log_lenience,
              chunk_steps=chunk_steps, verify_impl=verify_impl,
              compact_impl=compact_impl, slot_write_impl=slot_write_impl,
              draft=draft, faults=faults, deadline_steps=deadline_steps,
              max_queue=max_queue, overflow=overflow, tracer=tracer,
              ledger=ledger)
    if cfg.cache_layout == "paged":
        kw["kv_pool_blocks"] = kv_pool_blocks
    if mesh is not None and data_size(mesh) > 1:
        D = data_size(mesh)
        kw["num_slots"] = max(D, num_slots - num_slots % D)
        return MeshSlotServer(params, cfg, gen, mesh=mesh, **kw)
    if cfg.cache_layout == "paged":
        from .paged_engine import PagedSlotEngine
        return PagedSlotEngine(params, cfg, gen, mesh=mesh, **kw)
    return SlotEngine(params, cfg, gen, mesh=mesh, **kw)


class MeshSlotServer:
    """Per-data-shard slot engines behind one submit/run/stats frontend.

    params are placed per submesh (replicated over data, ``param_spec``-
    sharded over model); ``num_slots`` is the TOTAL slot count, split evenly
    across shards (it must divide).  The frontend mirrors ``SlotEngine``:
    ``submit`` / ``run(arrivals=...)`` / ``responses`` / ``stats``.
    """

    def __init__(self, params, cfg: ModelConfig, gen: GenerateConfig, *,
                 mesh, num_slots: int, prompt_width: int,
                 spec_prefix: bool = False, log_lenience: float = 0.0,
                 chunk_steps: int = 8, verify_impl: str = "auto",
                 compact_impl: str = "auto", slot_write_impl: str = "auto",
                 draft=None, faults=None, deadline_steps=None,
                 max_queue=None, overflow: str = "reject", tracer=None,
                 ledger=None, kv_pool_blocks: Optional[int] = None):
        self.submeshes = data_submeshes(mesh)
        D = len(self.submeshes)
        assert num_slots % D == 0 and num_slots >= D, \
            (f"num_slots={num_slots} must split evenly over {D} data shards")
        self.cfg, self.gen = cfg, gen
        # a single FaultPlan lands on shard 0; a sequence maps per shard
        plans = list(faults) if isinstance(faults, (list, tuple)) else \
            [faults] + [None] * (D - 1)
        assert len(plans) == D, (len(plans), D)
        if cfg.cache_layout == "paged":
            from .paged_engine import PagedSlotEngine
            mk = lambda *a, **k: PagedSlotEngine(  # noqa: E731
                *a, kv_pool_blocks=kv_pool_blocks, **k)
        else:
            mk = SlotEngine
        self.engines: List[SlotEngine] = [
            mk(shard_params(sm, cfg, params), cfg, gen,
               num_slots=num_slots // D, prompt_width=prompt_width,
               spec_prefix=spec_prefix, log_lenience=log_lenience,
               chunk_steps=chunk_steps, verify_impl=verify_impl,
               compact_impl=compact_impl,
               slot_write_impl=slot_write_impl, draft=draft, mesh=sm,
               faults=plan, deadline_steps=deadline_steps,
               max_queue=max_queue, overflow=overflow,
               tracer=tracer, ledger=ledger, obs_label=f"shard{i}/")
            for i, (sm, plan) in enumerate(zip(self.submeshes, plans))]
        self._rr = 0                       # round-robin submission cursor

    @property
    def num_shards(self) -> int:
        return len(self.engines)

    @property
    def responses(self) -> Dict[int, Response]:
        out: Dict[int, Response] = {}
        for e in self.engines:
            out.update(e.responses)
        return out

    # ------------------------------------------------------------- frontend

    def submit(self, req: Request) -> None:
        """Shard-local admission: the request joins one shard's FIFO queue.

        GRPO siblings (``group_id`` set) route by group so one shard owns
        the whole group — the paged engine's prompt sharing is shard-local
        (§13); everything else round-robins.  Both rules are deterministic,
        keeping kill-and-resume exact.
        """
        if req.group_id is not None:
            self.engines[req.group_id % len(self.engines)].submit(req)
            return
        self.engines[self._rr].submit(req)
        self._rr = (self._rr + 1) % len(self.engines)

    def run(self, arrivals: Optional[Iterable[Tuple[int, Request]]] = None,
            max_chunks: Optional[int] = None) -> Dict[int, Response]:
        """Drive all shard engines, interleaved chunk by chunk.

        Each engine admits from its own queue and decodes its own chunk;
        interleaving keeps the per-shard device programs in flight together
        (disjoint devices — dispatch overlaps until each shard's next
        host sync).  ``arrivals`` are routed round-robin like ``submit``
        and become due against their shard's local step counter.
        """
        subs: List[List[Tuple[int, Request]]] = [[] for _ in self.engines]
        if arrivals is not None:
            for j, (due, req) in enumerate(arrivals):
                i = req.group_id % len(self.engines) \
                    if req.group_id is not None else j % len(self.engines)
                subs[i].append((due, req))
        nxt = [iter(s) for s in subs]
        due = [next(it, None) for it in nxt]
        chunks = 0
        while True:
            moved = False
            for i, e in enumerate(self.engines):
                e._apply_faults()      # may raise EngineKilled (kind 'kill')
                while due[i] is not None and due[i][0] <= e.steps:
                    e.submit(due[i][1])
                    due[i] = next(nxt[i], None)
                e._admit()
                if not e.scheduler.idle:
                    e._run_chunk()
                    e._harvest()
                    e._enforce_deadlines()
                    moved = True
                elif due[i] is not None:
                    e.steps = max(e.steps, int(due[i][0]))  # idle fast-forward
                    moved = True
            chunks += 1
            if max_chunks is not None and chunks >= max_chunks:
                break
            if not moved:
                break
        return self.responses

    # -------------------------------------------------------------- metrics

    def metrics_registry(self) -> MetricsRegistry:
        """Type-driven merge of the shard registries (§11): the one place
        mesh gathering happens, with the merge rule carried by each
        metric's type instead of a hand-maintained key list."""
        return MetricsRegistry.merged([e.metrics_registry()
                                       for e in self.engines])

    def stats(self) -> Dict[str, float]:
        """Gathered view over the shard-local engines + per-shard dumps."""
        out = self.metrics_registry().as_dict()
        out["per_shard"] = [e.stats() for e in self.engines]
        return out

    # ----------------------------------------------- exact kill-and-resume

    def state_dict(self) -> Dict:
        """Per-shard engine snapshots plus the round-robin cursor — the
        full server future (checkpoint/io.save_server_state persists it)."""
        import numpy as np
        return {"engines": {str(i): e.state_dict()
                            for i, e in enumerate(self.engines)},
                "rr": np.int64(self._rr)}

    def load_state_dict(self, state: Dict) -> None:
        assert len(state["engines"]) == len(self.engines), \
            (len(state["engines"]), len(self.engines))
        for i, e in enumerate(self.engines):
            e.load_state_dict(state["engines"][str(i)])
        self._rr = int(state["rr"])
