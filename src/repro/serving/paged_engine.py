"""Paged continuous-batching engine: block-table slots + CoW GRPO sharing.

``PagedSlotEngine`` is the ``SlotEngine`` with its cache layout swapped out
(DESIGN.md §13): instead of one dense ``(B, Hkv, S, D)`` slab per layer, the
persistent decode batch addresses a shared pool of fixed-size KV blocks
through per-slot block tables, managed host-side by a ``BlockAllocator``
(serving/block_table.py).  Everything else — admission programs, the decode
chunk, scheduling, §10 hardening, §11 telemetry — is inherited unchanged;
the subclass only overrides the layout hooks the base class exposes.

Token identity with the dense engine is BY CONSTRUCTION, not by accident:

* Admission runs the *dense* device programs on small throwaway caches
  (``_admit_cfg`` flips ``cache_layout`` back to ``'dense'``), then the
  slot write re-pages each admitted row through its freshly installed
  block table (``models.model._write_cache_slots_paged``).  The prefill /
  verify maths never sees a block table.
* The paged decode step gathers K/V through the table back to the exact
  *logical* width the dense cache would hold (unrounded ``pos``), so the
  chunk scan is term-for-term the dense program.

Copy-on-write GRPO prompt sharing: the G sibling rollouts of a GRPO group
carry the same prompt (``Request.group_id``).  The first sibling admitted
(the *leader*) prefills normally; the engine registers its
``ceil(P/bs)`` prompt blocks plus its seed logits.  Every later sibling
(*follower*) skips prefill entirely — it maps the leader's prompt blocks
read-only (refcounted), allocates fresh blocks for its continuation, and
samples its seed token from the leader's registered prefill logits with its
OWN key (prefill is row-independent, so the leader's last-token logits are
bit-identical to the logits the follower's own prefill would produce).  One
prefill and ONE physical prompt copy per group.

A shared block is forked the moment a row is about to write into it: before
every decode chunk, ``_cow_fork_walk`` scans each live row's write span and
copies any block with refcount > 1 to a private block (device copy + table
scatter).  Only the prompt *boundary* block (when P % block_size != 0) can
ever be both shared and written, so steady-state decode forks at most once
per follower.

Admission pressure: the pool is sized so the default engine never runs dry
(``1 + B·nb`` blocks), but a caller-shrunk pool (``kv_pool_blocks``) turns
allocation failure into load shedding — admission caps itself to the rows
the pool can table (the rest stay QUEUED, in order), a row that cannot fork
mid-decode is reclaimed through the §10 retry machinery, and a request that
cannot even be tabled on an EMPTY batch is shed immediately (FINISH_SHED)
rather than livelocking the queue.
"""
from __future__ import annotations

import functools
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine.generate import GenerateConfig
from repro.engine.sampling import sample, split_key
from repro.models.attention import init_paged_kv_cache
from repro.models.blocks import signature_runs
from repro.models.config import ModelConfig
from repro.obs import MetricsRegistry

from .block_table import BlockAllocator, PoolExhausted
from .engine_loop import SlotEngine
from .request import FINISH_SHED, Request, Response


@functools.partial(jax.jit, static_argnames=("gen",))
def _seed_from_logits(gen: GenerateConfig, seed_logits, keys):
    """Exactly ``_admit_vanilla``'s tail: split each request's decode key
    and sample its seed token — here from the LEADER's prefill logits, which
    row-independent prefill makes bit-identical to the follower's own."""
    keys, sub = split_key(keys)
    tok0, lp0 = sample(sub, seed_logits, gen.temperature, gen.top_p)
    return tok0, lp0, keys


class PagedSlotEngine(SlotEngine):
    """SlotEngine over a paged block pool with CoW GRPO prompt sharing."""

    # §14: transient flag raised around follower admission so the ledger
    # tags those prompt planes SHARED_PROMPT_BLOCK instead of PROMPT
    _admitting_followers = False

    def __init__(self, params, cfg: ModelConfig, gen: GenerateConfig, *,
                 kv_pool_blocks: Optional[int] = None, **kw):
        assert cfg.cache_layout == "paged", \
            "PagedSlotEngine needs cfg.cache_layout='paged'"
        # consumed by _make_caches, which super().__init__ calls
        self._pool_blocks = kv_pool_blocks
        super().__init__(params, cfg, gen, **kw)

    # ------------------------------------------------------- layout hooks

    def _make_caches(self, B: int):
        cfg = self.cfg
        bs = cfg.kv_block_size
        self.nb = -(-self.cache_len // bs)        # blocks per slot row
        self._pb = -(-self.P // bs)               # prompt blocks (CoW share)
        # default pool: the block-0 sink + one full row per slot — sized so
        # the engine can never run dry (sharing only ever FREES blocks, and
        # a fork transiently needs one free block, which sharing guarantees)
        self.NB = self._pool_blocks if self._pool_blocks is not None \
            else 1 + B * self.nb
        self.allocator = BlockAllocator(self.NB, bs)
        # slot -> list of physical block ids (None = slot empty, table=sink)
        self._slot_blocks: List[Optional[List[int]]] = [None] * B
        # group_id -> registered prompt blocks + seed logits (§13 sharing)
        self._groups: Dict[int, Dict] = {}
        # bytes ONE block holds across every layer of the trunk — the unit
        # shared_prompt_bytes_saved counts in
        blk_bytes = 0
        dtype = jnp.dtype(cfg.dtype)
        table = jnp.zeros((B, self.nb), jnp.int32)   # all-sink until admitted
        caches = []
        for sig, run_len in signature_runs(cfg):
            one = {"self": init_paged_kv_cache(cfg, B, self.cache_len, dtype,
                                               num_blocks=self.NB,
                                               table=table)}
            caches.append(jax.tree.map(
                lambda x: jnp.broadcast_to(
                    x[None], (run_len,) + x.shape).copy(), one))
            sc = caches[-1]["self"]
            for name, buf in sc.items():
                if name in ("pos", "table"):
                    continue
                blk_bytes += run_len * int(np.prod(buf.shape[2:])) * \
                    buf.dtype.itemsize
        self._block_bytes = blk_bytes
        return caches

    def _admit_cfg(self) -> ModelConfig:
        # admissions prefill small throwaway caches DENSELY — identical
        # device programs to the dense engine; the slot write re-pages
        return self.cfg.replace(cache_layout="dense")

    # ---------------------------------------------------------- admission

    def _admit(self) -> None:
        while True:
            self._gc_groups()
            cap = self.allocator.free_blocks // self.nb
            if cap == 0:
                if self.scheduler.active or not self.scheduler.queue:
                    # §13 admission pressure: decode completions will free
                    # blocks; queued requests wait their turn in order
                    return
                # empty batch and still no room for a full row: admit ONE
                # request and let allocation failure shed it — guaranteed
                # progress instead of a livelocked queue (a follower may
                # still fit, needing only nb - pb fresh blocks)
                limit: Optional[int] = 1
            else:
                limit = cap
            group = self.scheduler.reserve(self._now(), limit=limit)
            if not group:
                return
            self._admit_group(group)

    def _admit_group(self, group: List[Tuple[int, Request]]) -> None:
        if self.spec_prefix:
            # spec-prefix admissions never share (the compacted prefix is
            # per-request); every row gets a freshly allocated full table
            ok = []
            for slot, req in group:
                if self._try_alloc_row(slot) is None:
                    self._shed_admission(slot, req)
                else:
                    ok.append((slot, req))
            if ok:
                super()._admit_group(ok)
            return
        leaders: List[Tuple[int, Request]] = []
        followers: List[Tuple[int, Request]] = []
        batch_leaders: Dict[int, np.ndarray] = {}   # gid -> leader prompt
        for slot, req in group:
            gid = req.group_id
            prompt = np.asarray(req.prompt, np.int32)
            sharable = gid is not None and (
                (gid in self._groups
                 and np.array_equal(self._groups[gid]["prompt"], prompt))
                or (gid in batch_leaders
                    and np.array_equal(batch_leaders[gid], prompt)))
            if sharable:
                followers.append((slot, req))
                continue
            if self._try_alloc_row(slot) is None:
                self._shed_admission(slot, req)
                continue
            if gid is not None:
                batch_leaders[gid] = prompt
            leaders.append((slot, req))
        if leaders:
            # registers this batch's new gids via _register_groups, so the
            # same-batch followers below share through the registry too
            super()._admit_group(leaders)
        if followers:
            self._admit_followers(followers)

    def _try_alloc_row(self, slot: int) -> Optional[List[int]]:
        try:
            row = self.allocator.alloc(self.nb)
        except PoolExhausted:
            return None
        self._slot_blocks[slot] = row
        return row

    def _shed_admission(self, slot: int, req: Request) -> None:
        """Pool cannot table this request on an empty batch: shed it now
        (no retry — re-queueing what cannot fit would livelock)."""
        now = self._now()
        self.scheduler.reclaim(slot, now=now, reason="shed")
        self._on_slot_freed(slot)
        self.fault_stats.add(failed=1)
        self.responses[req.request_id] = Response(
            request_id=req.request_id, tokens=np.zeros(0, np.int32),
            logprobs=np.zeros(0, np.float32), length=0,
            finish_reason=FINISH_SHED, slot=-1,
            queue_time=now - req.queued_at, serve_time=0.0,
            retries=req.retries)

    def _write_admitted(self, src_caches, slot_ids: np.ndarray):
        # install the freshly allocated tables FIRST — the paged slot write
        # re-pages each dense admission row through dst's table
        rows = np.stack([self._slot_blocks[s] for s in slot_ids])
        self._set_device_tables(slot_ids, rows.astype(np.int32))
        return super()._write_admitted(src_caches, slot_ids)

    def _register_groups(self, group, out) -> None:
        if "seed_logits" not in out:
            return                                  # spec path: no sharing
        seeds = None
        for j, (slot, req) in enumerate(group):
            gid = req.group_id
            if gid is None or gid in self._groups:
                continue
            if seeds is None:
                seeds = np.asarray(out["seed_logits"], np.float32)
            blocks = list(self._slot_blocks[slot][:self._pb])
            for b in blocks:                        # registry's own refs
                self.allocator.share(b)
            L = len(req.prompt)
            pos_row = np.full(self.cache_len, -1, np.int32)
            pos_row[self.P - L:self.P] = np.arange(L, dtype=np.int32)
            self._groups[gid] = {
                "blocks": blocks,
                "prompt": np.asarray(req.prompt, np.int32).copy(),
                "pos_row": pos_row,
                "seed_logits": seeds[j].copy(),
            }

    def _admit_followers(self, fl: List[Tuple[int, Request]]) -> None:
        """Admit GRPO siblings WITHOUT prefill: map the leader's prompt
        blocks CoW, install the admission-time pos row, seed-sample from the
        leader's registered prefill logits with the follower's own key."""
        t0 = time.perf_counter()
        ok: List[Tuple[int, Request]] = []
        for slot, req in fl:
            g = self._groups[req.group_id]
            try:
                fresh = self.allocator.alloc(self.nb - self._pb)
            except PoolExhausted:
                self._shed_admission(slot, req)
                continue
            shared = list(g["blocks"])
            for b in shared:
                self.allocator.share(b)
            self._slot_blocks[slot] = shared + fresh
            self.allocator.shared_prompt_bytes_saved += \
                self._pb * self._block_bytes
            ok.append((slot, req))
        if not ok:
            return
        slots = np.asarray([s for s, _ in ok], np.int32)
        rows = np.stack([self._slot_blocks[s] for s in slots]).astype(np.int32)
        pos_rows = np.stack([self._groups[r.group_id]["pos_row"]
                             for _, r in ok])
        self._set_device_tables(slots, rows, pos_rows=pos_rows)
        seeds = self._pad_group([self._groups[r.group_id]["seed_logits"]
                                 for _, r in ok])
        keys = self._pad_group([np.asarray(r.key, np.uint32) for _, r in ok])
        tok0, lp0, nkeys = _seed_from_logits(self.gen, jnp.asarray(seeds),
                                             jnp.asarray(keys))
        jax.block_until_ready(tok0)
        t1 = time.perf_counter()
        self.time_admit += t1 - t0
        self.metrics.observe("serve.admit_ms", (t1 - t0) * 1e3)
        if self.tracer.enabled:
            self.tracer.complete("admit_shared", self._etrack, t0, t1,
                                 cat="admit", rows=len(ok))
        B = self.scheduler.num_slots
        npos = np.zeros(B, np.int32)
        npos[:len(ok)] = [len(r.prompt) for _, r in ok]
        zi, zb = np.zeros(B, np.int32), np.zeros(B, bool)
        # §14: these rows' prompt planes are SHARED_PROMPT_BLOCK — the
        # tokens exist in the KV pool because the leader prefilled them
        # once, not because this admission paid for them
        self._admitting_followers = True
        try:
            self._apply_admission(ok, np.asarray(tok0), np.asarray(lp0),
                                  npos, np.asarray(nkeys), zi, zb, None, zi,
                                  t0, t1)
        finally:
            self._admitting_followers = False
        self._harvest()

    def _set_device_tables(self, slots, rows, pos_rows=None) -> None:
        """Scatter host block-table rows (and optionally pos rows) into the
        device caches for ``slots``.  Duplicate slots must carry identical
        rows (admission padding), exactly like the slot write itself."""
        sl = jnp.asarray(np.asarray(slots, np.int32))
        tb = jnp.asarray(rows)
        pr = None if pos_rows is None else jnp.asarray(
            np.asarray(pos_rows, np.int32))
        new_caches = []
        for run in self.caches:
            sc = dict(run["self"])
            sc["table"] = sc["table"].at[:, sl].set(tb[None])
            if pr is not None:
                sc["pos"] = sc["pos"].at[:, sl].set(pr[None])
            new_caches.append({"self": sc})
        self.caches = new_caches

    def _gc_groups(self) -> None:
        """Drop group registrations no pending request can still share.

        An entry holds its own refcounts on the prompt blocks, so dropping
        it is what lets a finished group's prompt copy actually free.
        Siblings arriving AFTER their group left the queue simply prefill
        as fresh leaders — sharing is an optimisation, never a dependency.
        """
        if not self._groups:
            return
        pending = {r.group_id for r in self.scheduler.queue
                   if r.group_id is not None}
        pending |= {r.group_id for _, r in self._retry_hold
                    if r.group_id is not None}
        for gid in [g for g in self._groups if g not in pending]:
            self.allocator.free_table(self._groups.pop(gid)["blocks"])

    # --------------------------------------------------------- decode loop

    def _run_chunk(self, steps: Optional[int] = None) -> None:
        span = (self.draft.draft_k + 1) if self.draft \
            else (steps or self.chunk_steps)
        self._cow_fork_walk(span)
        super()._run_chunk(steps)

    def _cow_fork_walk(self, span: int) -> None:
        """Fork every shared block a live row is about to write (§13 CoW).

        The write span of the coming chunk is [w, w + span) clamped to the
        cache (the drafted block write clamps the same way); only the
        prompt boundary block can ever be both shared and in that span, so
        this walk is O(active rows) with at most one fork per follower's
        first chunk.  A fork that finds the pool dry reclaims the row
        through the §10 retry machinery (its blocks free on reclaim, so
        later rows in the same walk may succeed).
        """
        bs = self.cfg.kv_block_size
        srcs: List[int] = []
        dsts: List[int] = []
        upd: List[Tuple[int, int, int]] = []        # (slot, idx, new block)
        for slot in list(self.scheduler.active):
            row = self._slot_blocks[slot]
            if row is None or self.done[slot]:
                continue
            w = min(int(self.write_idx[slot]), self.cache_len - span)
            lo = max(0, w) // bs
            hi = min(w + span - 1, self.cache_len - 1) // bs
            for i in range(lo, hi + 1):
                if self.allocator.refcount[row[i]] <= 1:
                    continue
                try:
                    nb = self.allocator.fork(row[i])
                except PoolExhausted:
                    self._reclaim(slot, FINISH_SHED)
                    break
                srcs.append(row[i])
                dsts.append(nb)
                upd.append((slot, i, nb))
                row[i] = nb
        if srcs:
            self._apply_forks(srcs, dsts, upd)

    def _apply_forks(self, srcs, dsts, upd) -> None:
        s = jnp.asarray(np.asarray(srcs, np.int32))
        d = jnp.asarray(np.asarray(dsts, np.int32))
        sl = jnp.asarray(np.asarray([u[0] for u in upd], np.int32))
        ix = jnp.asarray(np.asarray([u[1] for u in upd], np.int32))
        nv = jnp.asarray(np.asarray([u[2] for u in upd], np.int32))
        new_caches = []
        for run in self.caches:
            sc = dict(run["self"])
            for name, buf in sc.items():
                if name in ("pos", "table"):
                    continue
                sc[name] = buf.at[:, d].set(buf[:, s])
            sc["table"] = sc["table"].at[:, sl, ix].set(nv[None])
            new_caches.append({"self": sc})
        self.caches = new_caches

    # ------------------------------------------------------------- release

    def _on_slot_freed(self, slot: int) -> None:
        row = self._slot_blocks[slot]
        if row is None:
            return
        self.allocator.free_table(row)
        self._slot_blocks[slot] = None
        # point the freed row's table at the sink and blank its pos row, so
        # its (gated, never-stored) idle decode writes land in garbage block
        # 0 instead of blocks the allocator may hand to the next admission
        sl = jnp.asarray(np.asarray([slot], np.int32))
        zrow = jnp.zeros((1, self.nb), jnp.int32)
        nrow = jnp.full((1, self.cache_len), -1, jnp.int32)
        new_caches = []
        for run in self.caches:
            sc = dict(run["self"])
            sc["table"] = sc["table"].at[:, sl].set(zrow[None])
            sc["pos"] = sc["pos"].at[:, sl].set(nrow[None])
            new_caches.append({"self": sc})
        self.caches = new_caches

    # ------------------------------------------------------------- metrics

    def metrics_registry(self) -> MetricsRegistry:
        reg = super().metrics_registry()
        a = self.allocator
        # §11/§13: pool occupancy gauges + sharing counters; extensive
        # across shards (each mesh submesh engine owns its own pool)
        reg.set("paged_num_blocks", float(a.num_blocks), agg="sum")
        reg.set("paged_blocks_in_use", float(a.blocks_in_use), agg="sum")
        reg.set("paged_peak_blocks_in_use", float(a.peak_blocks_in_use),
                agg="sum")
        reg.inc("paged_cow_forks", a.cow_forks)
        reg.inc("paged_alloc_failures", a.alloc_failures)
        reg.inc("paged_shared_prompt_bytes_saved",
                a.shared_prompt_bytes_saved)
        # §14 watermarks: pool pressure for dashboards/alerts, plus the
        # byte view of live/peak pool usage (block bytes are known exactly)
        reg.set("paged_pool_pressure", self._pool_pressure())
        reg.set("paged_bytes_in_use",
                float(a.blocks_in_use) * self._block_bytes, agg="sum")
        reg.set("paged_peak_bytes_in_use",
                float(a.peak_blocks_in_use) * self._block_bytes, agg="sum")
        return reg

    # ------------------------------------------------------ §14 obs hooks

    def _prompt_category(self, req: Request) -> int:
        from repro.obs.ledger import PROMPT, SHARED_PROMPT_BLOCK
        return SHARED_PROMPT_BLOCK if self._admitting_followers else PROMPT

    def _pool_pressure(self) -> float:
        return 1.0 - float(self.allocator.free_blocks) / max(1, self.NB)

    # ------------------------------------------- exact kill-and-resume §10

    def state_dict(self) -> Dict:
        st = super().state_dict()
        st["paged"] = {
            "allocator": self.allocator.state_dict(),
            "slot_blocks": {str(s): np.asarray(row, np.int32)
                            for s, row in enumerate(self._slot_blocks)
                            if row is not None},
            "groups": {str(g): {"blocks": np.asarray(e["blocks"], np.int32),
                                "prompt": e["prompt"],
                                "pos_row": e["pos_row"],
                                "seed_logits": e["seed_logits"]}
                       for g, e in self._groups.items()},
        }
        return st

    def load_state_dict(self, st: Dict) -> None:
        super().load_state_dict(st)
        p = st["paged"]
        self.allocator.load_state_dict(p["allocator"])
        self._slot_blocks = [None] * self.scheduler.num_slots
        for s, row in p["slot_blocks"].items():
            self._slot_blocks[int(s)] = [int(b) for b in np.asarray(row)]
        self._groups = {
            int(g): {"blocks": [int(b) for b in np.asarray(e["blocks"])],
                     "prompt": np.asarray(e["prompt"], np.int32),
                     "pos_row": np.asarray(e["pos_row"], np.int32),
                     "seed_logits": np.asarray(e["seed_logits"], np.float32)}
            for g, e in p["groups"].items()}
