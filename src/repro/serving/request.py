"""Request / response dataclasses for the continuous-batching server.

Lifecycle (DESIGN.md §6, failure arcs §10)::

    QUEUED ──admission──► PREFILLING ──slot write──► DECODING ──eos/budget──► DONE
       │                                                │
       └◄─── bounded retry (re-admission via _admit_spec:│ timeout /
             completed tokens re-verified, not regrown) ─┘ quarantine

A request carries its own PRNG streams (``key`` for decoding, ``verify_key``
for spec-prefix acceptance), so its token output is a pure function of
(prompt, draft, keys, params) — independent of which slot it lands in, what
it is co-batched with, and when it is admitted.  That per-request determinism
is the serving layer's correctness contract: slot-scheduled output is
token-identical to fixed-batch ``generate``/``rollout`` (tested in
tests/serving/test_slot_equivalence.py), and it is also what makes retry
cheap and exact kill-and-resume possible (tests/serving/test_kill_resume.py).

Hardening fields (§10): ``deadline_steps`` bounds how many engine decode
steps a request may sit DECODING before the scheduler reclaims its slot;
``max_retries`` bounds re-admissions after a timeout or quarantine.  On
retry the tokens already generated become the request's *draft* — they
re-enter through speculative-prefix verification instead of being decoded
again — and ``base_draft_len`` remembers where the caller's original draft
ended so the final Response is still split caller-draft-prefix vs
continuation.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np

# request states
QUEUED = "QUEUED"
PREFILLING = "PREFILLING"
DECODING = "DECODING"
DONE = "DONE"
_STATES = (QUEUED, PREFILLING, DECODING, DONE)

# finish reasons
FINISH_EOS = "eos"
FINISH_BUDGET = "budget"
FINISH_FULL_REUSE = "full_reuse"
FINISH_TIMEOUT = "timeout"         # deadline expired, retries exhausted
FINISH_QUARANTINE = "quarantine"   # non-finite logits, retries exhausted
FINISH_SHED = "shed"               # dropped by queue backpressure
_REASONS = (FINISH_EOS, FINISH_BUDGET, FINISH_FULL_REUSE, FINISH_TIMEOUT,
            FINISH_QUARANTINE, FINISH_SHED)
FAILURE_REASONS = (FINISH_TIMEOUT, FINISH_QUARANTINE, FINISH_SHED)


@dataclass
class Request:
    """One generation request.

    prompt: (p,) int32 token ids, unpadded (the engine left-pads to its
    prompt width).  key: the decode PRNG key — the exact key ``generate``
    would be called with for this row.  A draft (tokens + behaviour
    log-probs from a previous rollout) makes the request eligible for
    speculative-prefix admission, which needs ``verify_key`` for the
    acceptance uniforms.
    """
    request_id: int
    prompt: np.ndarray
    key: np.ndarray                       # (2,) uint32 decode stream
    max_new_tokens: int
    verify_key: Optional[np.ndarray] = None
    draft_tokens: Optional[np.ndarray] = None   # (L,) int32, unpadded
    draft_logprobs: Optional[np.ndarray] = None  # (L,) float32
    draft_eos: bool = False
    # n-gram corpus for the §9 continuation draft engine: sibling / prior
    # trajectories indexed alongside the request's own stream (ignored by
    # engines built without a DraftConfig)
    ngram_corpus: Optional[list] = None
    # GRPO group handle (§13): siblings sharing a group_id carry the SAME
    # prompt, and the paged engine prefills it once — followers map the
    # leader's prompt blocks copy-on-write.  None (the default) opts out;
    # dense engines ignore it entirely.
    group_id: Optional[int] = None
    arrival_time: float = 0.0
    state: str = QUEUED
    # lifecycle timestamps (engine-relative seconds), filled by the scheduler
    queued_at: float = 0.0
    admitted_at: float = 0.0
    finished_at: float = 0.0
    # ---- §10 hardening ----
    deadline_steps: Optional[int] = None  # max engine steps DECODING
    max_retries: int = 1                  # timeout/quarantine re-admissions
    retries: int = 0
    # length of the CALLER's draft; retry drafts grow past it with the
    # request's own partial output, and harvest splits the response there
    # (-1 = not yet admitted; set by the scheduler on first submit)
    base_draft_len: int = -1
    nan_strikes: int = 0                  # quarantines suffered (ladder input)
    draft_off: bool = False               # per-request drafting kill switch

    @property
    def has_draft(self) -> bool:
        return self.draft_tokens is not None and len(self.draft_tokens) > 0

    # ---------------------------------------------------- exact serialization

    def to_state(self) -> Dict[str, np.ndarray]:
        """All-array pytree for checkpoint/io (exact kill-and-resume §10).

        Optional fields serialize as absent keys; scalars as 0-d arrays.
        ``from_state(to_state(r))`` reproduces the request bit-for-bit.
        """
        d = {
            "request_id": np.int64(self.request_id),
            "prompt": np.asarray(self.prompt, np.int32),
            "key": np.asarray(self.key, np.uint32),
            "max_new_tokens": np.int64(self.max_new_tokens),
            "draft_eos": np.bool_(self.draft_eos),
            "arrival_time": np.float64(self.arrival_time),
            "state": np.int64(_STATES.index(self.state)),
            "queued_at": np.float64(self.queued_at),
            "admitted_at": np.float64(self.admitted_at),
            "finished_at": np.float64(self.finished_at),
            "deadline_steps": np.int64(-1 if self.deadline_steps is None
                                       else self.deadline_steps),
            "max_retries": np.int64(self.max_retries),
            "retries": np.int64(self.retries),
            "base_draft_len": np.int64(self.base_draft_len),
            "nan_strikes": np.int64(self.nan_strikes),
            "draft_off": np.bool_(self.draft_off),
            "group_id": np.int64(-1 if self.group_id is None
                                 else self.group_id),
        }
        if self.verify_key is not None:
            d["verify_key"] = np.asarray(self.verify_key, np.uint32)
        if self.draft_tokens is not None:
            d["draft_tokens"] = np.asarray(self.draft_tokens, np.int32)
            d["draft_logprobs"] = np.asarray(self.draft_logprobs, np.float32)
        if self.ngram_corpus:
            d["ngram_corpus"] = {str(i): np.asarray(s, np.int32)
                                 for i, s in enumerate(self.ngram_corpus)}
        return d

    @classmethod
    def from_state(cls, d: Dict[str, np.ndarray]) -> "Request":
        def arr(k, dt):
            return np.asarray(d[k], dt) if k in d else None
        ddl = int(d["deadline_steps"])
        corpus = None
        if "ngram_corpus" in d:
            c = d["ngram_corpus"]
            corpus = [np.asarray(c[str(i)], np.int32) for i in range(len(c))]
        return cls(
            request_id=int(d["request_id"]),
            prompt=np.asarray(d["prompt"], np.int32),
            key=np.asarray(d["key"], np.uint32),
            max_new_tokens=int(d["max_new_tokens"]),
            verify_key=arr("verify_key", np.uint32),
            draft_tokens=arr("draft_tokens", np.int32),
            draft_logprobs=arr("draft_logprobs", np.float32),
            draft_eos=bool(d["draft_eos"]),
            ngram_corpus=corpus,
            arrival_time=float(d["arrival_time"]),
            state=_STATES[int(d["state"])],
            queued_at=float(d["queued_at"]),
            admitted_at=float(d["admitted_at"]),
            finished_at=float(d["finished_at"]),
            deadline_steps=None if ddl < 0 else ddl,
            max_retries=int(d["max_retries"]),
            retries=int(d["retries"]),
            base_draft_len=int(d["base_draft_len"]),
            nan_strikes=int(d["nan_strikes"]),
            draft_off=bool(d["draft_off"]),
            # absent in pre-§13 snapshots; -1 encodes None
            group_id=(None if int(d.get("group_id", -1)) < 0
                      else int(d["group_id"])))


@dataclass
class Response:
    """Completed request: reused prefix ⊕ generated continuation.

    ``tokens``/``logprobs`` are the *continuation* only (length ``length``);
    for spec-prefix admissions the accepted draft prefix (``n_accepted``
    tokens, behaviour log-probs in ``prefix_logprobs``) precedes it — the
    rl_adapter assembles the full response exactly like the fixed-batch
    ``assemble``.  For retried requests the continuation already folds in
    the re-verified partial output, so the split stays caller-draft vs
    everything-this-serving-session.  ``retries`` > 0 marks recovered
    requests; failure reasons (timeout / quarantine / shed) mean the tokens
    are best-effort partial output.
    """
    request_id: int
    tokens: np.ndarray                    # (length,) int32 continuation
    logprobs: np.ndarray                  # (length,) float32
    length: int
    finish_reason: str
    n_accepted: int = 0
    prefix_logprobs: Optional[np.ndarray] = None  # (N,) current-policy lp
    draft_len: int = 0
    slot: int = -1
    queue_time: float = 0.0               # seconds spent QUEUED
    serve_time: float = 0.0               # admission -> DONE
    retries: int = 0                      # recoveries before completion
    metrics: dict = field(default_factory=dict)

    # ---------------------------------------------------- exact serialization

    def to_state(self) -> Dict[str, np.ndarray]:
        d = {
            "request_id": np.int64(self.request_id),
            "tokens": np.asarray(self.tokens, np.int32),
            "logprobs": np.asarray(self.logprobs, np.float32),
            "length": np.int64(self.length),
            "finish_reason": np.int64(_REASONS.index(self.finish_reason)),
            "n_accepted": np.int64(self.n_accepted),
            "draft_len": np.int64(self.draft_len),
            "slot": np.int64(self.slot),
            "queue_time": np.float64(self.queue_time),
            "serve_time": np.float64(self.serve_time),
            "retries": np.int64(self.retries),
        }
        if self.prefix_logprobs is not None:
            d["prefix_logprobs"] = np.asarray(self.prefix_logprobs, np.float32)
        return d

    @classmethod
    def from_state(cls, d: Dict[str, np.ndarray]) -> "Response":
        return cls(
            request_id=int(d["request_id"]),
            tokens=np.asarray(d["tokens"], np.int32),
            logprobs=np.asarray(d["logprobs"], np.float32),
            length=int(d["length"]),
            finish_reason=_REASONS[int(d["finish_reason"])],
            n_accepted=int(d["n_accepted"]),
            prefix_logprobs=(np.asarray(d["prefix_logprobs"], np.float32)
                             if "prefix_logprobs" in d else None),
            draft_len=int(d["draft_len"]),
            slot=int(d["slot"]),
            queue_time=float(d["queue_time"]),
            serve_time=float(d["serve_time"]),
            retries=int(d["retries"]))
