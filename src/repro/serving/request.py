"""Request / response dataclasses for the continuous-batching server.

Lifecycle (DESIGN.md §6)::

    QUEUED ──admission──► PREFILLING ──slot write──► DECODING ──eos/budget──► DONE

A request carries its own PRNG streams (``key`` for decoding, ``verify_key``
for spec-prefix acceptance), so its token output is a pure function of
(prompt, draft, keys, params) — independent of which slot it lands in, what
it is co-batched with, and when it is admitted.  That per-request determinism
is the serving layer's correctness contract: slot-scheduled output is
token-identical to fixed-batch ``generate``/``rollout`` (tested in
tests/serving/test_slot_equivalence.py).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

# request states
QUEUED = "QUEUED"
PREFILLING = "PREFILLING"
DECODING = "DECODING"
DONE = "DONE"

# finish reasons
FINISH_EOS = "eos"
FINISH_BUDGET = "budget"
FINISH_FULL_REUSE = "full_reuse"


@dataclass
class Request:
    """One generation request.

    prompt: (p,) int32 token ids, unpadded (the engine left-pads to its
    prompt width).  key: the decode PRNG key — the exact key ``generate``
    would be called with for this row.  A draft (tokens + behaviour
    log-probs from a previous rollout) makes the request eligible for
    speculative-prefix admission, which needs ``verify_key`` for the
    acceptance uniforms.
    """
    request_id: int
    prompt: np.ndarray
    key: np.ndarray                       # (2,) uint32 decode stream
    max_new_tokens: int
    verify_key: Optional[np.ndarray] = None
    draft_tokens: Optional[np.ndarray] = None   # (L,) int32, unpadded
    draft_logprobs: Optional[np.ndarray] = None  # (L,) float32
    draft_eos: bool = False
    # n-gram corpus for the §9 continuation draft engine: sibling / prior
    # trajectories indexed alongside the request's own stream (ignored by
    # engines built without a DraftConfig)
    ngram_corpus: Optional[list] = None
    arrival_time: float = 0.0
    state: str = QUEUED
    # lifecycle timestamps (engine-relative seconds), filled by the scheduler
    queued_at: float = 0.0
    admitted_at: float = 0.0
    finished_at: float = 0.0

    @property
    def has_draft(self) -> bool:
        return self.draft_tokens is not None and len(self.draft_tokens) > 0


@dataclass
class Response:
    """Completed request: reused prefix ⊕ generated continuation.

    ``tokens``/``logprobs`` are the *continuation* only (length ``length``);
    for spec-prefix admissions the accepted draft prefix (``n_accepted``
    tokens, behaviour log-probs in ``prefix_logprobs``) precedes it — the
    rl_adapter assembles the full response exactly like the fixed-batch
    ``assemble``.
    """
    request_id: int
    tokens: np.ndarray                    # (length,) int32 continuation
    logprobs: np.ndarray                  # (length,) float32
    length: int
    finish_reason: str
    n_accepted: int = 0
    prefix_logprobs: Optional[np.ndarray] = None  # (N,) current-policy lp
    draft_len: int = 0
    slot: int = -1
    queue_time: float = 0.0               # seconds spent QUEUED
    serve_time: float = 0.0               # admission -> DONE
    metrics: dict = field(default_factory=dict)
