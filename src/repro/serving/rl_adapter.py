"""RL-side adapter: drain a training prompt batch through the slot engine.

``core/spec_rollout.rollout`` with ``spec.backfill == 'slots'`` lands here:
instead of one fixed decode batch that idles on its long tail, the batch's
prompts become requests on the SlotEngine — a row that finishes immediately
picks up the next pending prompt (straggler backfill), with cached SPEC-RL
drafts entering through speculative-prefix admission.

Correctness contract: with per-request PRNG keys, the slot-scheduled step is
token-identical to the fixed-batch ``rollout`` — per-request key streams are
derived exactly as ``rollout`` splits its (B, 2) key, the admission programs
are the same device code as the one-pass path, and the final assembly reuses
the same jit'd ``assemble``.  A scalar (2,) key is first expanded to
per-request keys with ``fold_in`` (deterministic, but a *different* stream
from fixed-batch scalar-key sampling, which draws batch-coupled noise).
"""
from __future__ import annotations

import time
from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cache import RolloutCache
from repro.core.spec_rollout import (RolloutBatch, SpecConfig, _update_cache,
                                     assemble, use_one_pass)
from repro.engine.generate import GenerateConfig
from repro.engine.sampling import split_key
from repro.models import model as M
from repro.models.config import ModelConfig

from .request import Request


def request_keys(key, batch: int) -> jnp.ndarray:
    """Expand one (2,) key to (B, 2) per-request keys via ``fold_in``."""
    if jnp.ndim(key) == 2:
        return key
    return jax.vmap(lambda i: jax.random.fold_in(key, i))(
        jnp.arange(batch, dtype=jnp.int32))


def rollout_via_slots(params, cfg: ModelConfig, gen: GenerateConfig,
                      spec: SpecConfig, prompts, prompt_mask,
                      prompt_ids: Sequence[int],
                      cache: Optional[RolloutCache], key, step: int,
                      mesh=None, **model_kwargs) -> RolloutBatch:
    """Slot-scheduled equivalent of ``rollout`` (same RolloutBatch contract).

    Under a ``mesh`` with a data axis the batch drains through the
    MeshSlotServer — one scheduler per data shard, shard-local admission
    (DESIGN.md §8); a model-only mesh runs one engine with head-sharded
    caches.  Either way the per-request PRNG streams keep the output
    token-identical to the fixed-batch path.
    """
    if model_kwargs:
        extras = {k: v for k, v in model_kwargs.items() if v is not None}
        if extras:
            raise ValueError(f"backfill='slots' does not support model "
                             f"extras {sorted(extras)}")
    if spec.variant not in ("off", "spec", "delayed"):
        raise ValueError(f"backfill='slots' supports variants off/spec/"
                         f"delayed, not {spec.variant!r}")
    if not M.supports_slot_serving(cfg, model_kwargs):
        raise ValueError("backfill='slots' needs an attention-only trunk")
    if spec.variant != "off" and spec.one_pass == "off":
        raise ValueError("backfill='slots' is a one-pass engine path; "
                         "one_pass='off' contradicts it")

    B, P = prompts.shape
    N = gen.max_new_tokens
    num_slots = spec.backfill_slots or max(1, B // 2)
    t0 = time.perf_counter()
    metrics: Dict[str, float] = {"step": step}

    prompts_np = np.asarray(prompts)
    mask_np = np.asarray(prompt_mask)
    keys = request_keys(key, B)

    use_cache = spec.variant != "off" and cache is not None
    drafts = cache.batch_get(prompt_ids, N, spec.cache_lag) if use_cache \
        else None
    have_drafts = use_cache and int(drafts["draft_len"].sum()) > 0
    if have_drafts:
        assert use_one_pass(cfg, spec, model_kwargs)
        # mirror rollout's one-pass splits: verify stream, then decode stream
        keys, verify_keys = split_key(keys)
        keys, decode_keys = split_key(keys)
        verify_keys = np.asarray(verify_keys)
    else:
        # mirror rollout's vanilla split: one stream for generate
        keys, decode_keys = split_key(keys)
        verify_keys = None
    decode_keys = np.asarray(decode_keys)

    drafting = spec.draft.enabled and M.supports_drafting(cfg, model_kwargs)
    from .mesh_server import make_slot_engine
    engine = make_slot_engine(params, cfg, gen, mesh=mesh,
                              num_slots=num_slots, prompt_width=P,
                              spec_prefix=have_drafts,
                              log_lenience=spec.log_lenience,
                              verify_impl=spec.verify_impl,
                              compact_impl=spec.compact_impl,
                              draft=spec.draft if drafting else None)
    num_slots = int(engine.stats()["num_slots"])    # post-rounding, for metrics
    corpora = cache.batch_siblings(prompt_ids, spec.cache_lag) \
        if (drafting and use_cache) else None
    for i in range(B):
        p_len = int(mask_np[i].sum())
        row = prompts_np[i, P - p_len:] if p_len else prompts_np[i, :0]
        req = Request(request_id=i, prompt=row.astype(np.int32),
                      key=decode_keys[i], max_new_tokens=N)
        if cache is not None and cache.group_size > 1:
            # GRPO sibling handle (§13): the paged engine prefills each
            # group's shared prompt once and CoW-shares its blocks; dense
            # engines ignore the field
            req.group_id = int(prompt_ids[i]) // cache.group_size
        if have_drafts:
            L = int(drafts["draft_len"][i])
            req.verify_key = verify_keys[i]
            req.draft_tokens = drafts["draft_tokens"][i, :L]
            req.draft_logprobs = drafts["draft_logprobs"][i, :L]
            req.draft_eos = bool(drafts["draft_eos"][i])
        if corpora is not None:
            req.ngram_corpus = corpora[i]
        engine.submit(req)
    responses = engine.run()        # merged snapshot (MeshSlotServer's
    # .responses property re-merges per access — don't hit it per row)
    sched = engine.stats()

    # ---- reassemble in training-batch order --------------------------------
    cont_tok = np.zeros((B, N), np.int32)
    cont_lp = np.zeros((B, N), np.float32)
    cont_len = np.zeros((B,), np.int32)
    n = np.zeros((B,), np.int32)
    prefix_lp = np.zeros((B, N), np.float32)
    full_reuse = np.zeros((B,), bool)
    for i in range(B):
        r = responses[i]
        cont_tok[i, :r.length] = r.tokens
        cont_lp[i, :r.length] = r.logprobs
        cont_len[i] = r.length
        n[i] = r.n_accepted
        full_reuse[i] = r.finish_reason == "full_reuse"
        if r.prefix_logprobs is not None:
            prefix_lp[i] = r.prefix_logprobs

    ta0 = time.perf_counter()
    if have_drafts:
        resp, lp, resp_mask, length = assemble(
            jnp.asarray(drafts["draft_tokens"]), jnp.asarray(prefix_lp),
            jnp.asarray(n), jnp.asarray(cont_tok), jnp.asarray(cont_lp),
            jnp.asarray(cont_len), pad_id=gen.pad_id)
        jax.block_until_ready(resp)
        resp, lp = np.asarray(resp), np.asarray(lp)
        resp_mask, length = np.asarray(resp_mask), np.asarray(length)
        draft_len = np.asarray(drafts["draft_len"])
        accept_rate = float(n.sum() / max(int(draft_len.sum()), 1))
        draft_coverage = float((draft_len > 0).mean())
    else:
        resp, lp, length = cont_tok, cont_lp, cont_len
        resp_mask = np.arange(N)[None, :] < length[:, None]
        accept_rate = 0.0
        draft_coverage = 0.0
    assembly_time = time.perf_counter() - ta0

    _update_cache(cache, prompt_ids, resp, lp, length, step, gen.eos_id)

    rollout_time = time.perf_counter() - t0
    metrics.update(
        n_generated=int(cont_len.sum()),
        n_reused=int(n.sum()),
        verified_prefix_mean=float(n.mean()),
        full_reuse_ratio=float(full_reuse.mean()),
        accept_rate=accept_rate,
        draft_coverage=draft_coverage,
        verify_time=sched["admit_time"],
        rollout_time=rollout_time,
        assembly_time=assembly_time,
        compact_time=sched["slot_write_time"],
        decode_time=sched["decode_time"],
        one_pass=1.0 if have_drafts else 0.0,
        prefill_passes=1.0,
        backfill_slots=float(num_slots),
        engine_steps=sched["engine_steps"],
        slot_occupancy=sched["occupancy"],
        admissions=sched["admitted"],
        # §9 draft telemetry, gathered from the engine's DraftStats
        draft_accept_rate=sched["accept_rate"],
        draft_mean_len=sched["mean_draft_len"],
        tokens_per_forward=sched["tokens_per_forward"] if drafting else 1.0,
        decode_forwards=sched["decode_forwards"])
    return RolloutBatch(
        prompt=prompts_np, prompt_mask=mask_np, response=resp,
        response_mask=np.asarray(resp_mask), behaviour_logprobs=lp,
        length=length, metrics=metrics)
