"""Disaggregated rollout service (DESIGN.md §12): the producer half of the
async rollout ↔ train seam.

``RolloutService`` continuously drives the shared ``rl.trainer.Collector``
— same dataset RNG, same PRNG split order, same SPEC-RL cache as the
synchronous trainer — and feeds the bounded ``rl.traj_buffer.TrajBuffer``,
tagging every trajectory with the policy version it was sampled under.
Backpressure is cooperative: at the buffer's high watermark the tick is a
counted no-op (the producer throttles rather than shed).

``WeightSync`` is the versioned weight-publication channel between the
two failure domains.  The trainer publishes (params, version) through
``core.backoff.retry``; a publish that exhausts its retry budget *fails
open* — the service keeps serving the last good version while the
consumer's staleness gauge rises, and past the hard cap the async loop
walks its mode ladder (rl/async_loop.py).  ``fail_next`` is the
deterministic chaos hook the §10 fault lane uses to inject sync failures.

Failure-domain isolation: producer-side faults ride the same seeded
``FaultPlan`` as the slot engine — ``kill`` raises ``EngineKilled`` at a
tick boundary (the consumer catches, counts and restarts the producer;
the trainer never dies with it), ``stall`` makes the service idle for
``count`` ticks (fresh-trajectory starvation, which the §10 watchdog's
service-stall detector is armed against).
"""
from __future__ import annotations

import time
from typing import Dict, Optional

import numpy as np

from repro.core.backoff import BackoffConfig, RetriesExhausted, retry
from repro.rl.traj_buffer import TrajBuffer, Trajectory

from .faults import EngineKilled, FaultPlan


class SyncFailed(RuntimeError):
    """One failed weight-publication attempt (injected or real)."""


class WeightSync:
    """Versioned, retrying weight-publication channel.

    ``publish`` pushes (version, params) through an injectable transport
    with exponential backoff; the service pulls via ``poll``.  Transport
    and sleep are injectable so tests and the deterministic async
    scheduler replay the exact same retry schedule with no wall-clock.
    """

    def __init__(self, backoff: Optional[BackoffConfig] = None,
                 transport=None, sleep=None, copy: bool = False):
        self.backoff = backoff or BackoffConfig(base=0.0, max_attempts=3)
        self._transport = transport          # callable(version, params)
        self._sleep = sleep or (lambda d: None if d == 0.0 else time.sleep(d))
        # copy=True host-fetches the params (distributed.mesh.host_fetch)
        # so the channel carries a self-contained numpy snapshot — needed
        # when the producer lives on another host.  Default off: the live
        # device arrays pass through, preserving sharding and K=0 identity.
        self._copy = bool(copy)
        self._published = None               # (version, params) last good
        self.version = -1                    # last successfully published
        self.publishes = 0
        self.retries = 0
        self.failures = 0
        self._fail_next = 0

    # ---------------------------------------------------------- chaos hook

    def fail_next(self, n: int = 1) -> None:
        """Make the next ``n`` publish *attempts* raise (deterministic
        injected sync failure — the §10 chaos lane's weight-sync fault)."""
        self._fail_next += int(n)

    # ------------------------------------------------------------- publish

    def publish(self, params, version: int) -> bool:
        """Publish ``params`` as ``version`` with retry/backoff.  Returns
        False when the retry budget is exhausted — the caller degrades
        gracefully (last good version keeps serving) instead of crashing."""
        from repro.obs import get_registry
        reg = get_registry()
        if self._copy:
            from repro.distributed.mesh import host_fetch
            params = host_fetch(params)

        def _attempt():
            if self._fail_next > 0:
                self._fail_next -= 1
                raise SyncFailed(f"injected sync failure (v{version})")
            if self._transport is not None:
                self._transport(version, params)
            self._published = (int(version), params)

        def _on_retry(attempt, exc, delay):
            self.retries += 1
            reg.inc("async.sync_retries")

        try:
            retry(_attempt, self.backoff, sleep=self._sleep,
                  retry_on=(SyncFailed,), on_retry=_on_retry,
                  describe=f"weight sync v{version}")
        except RetriesExhausted:
            self.failures += 1
            reg.inc("async.sync_failures")
            return False
        self.version = int(version)
        self.publishes += 1
        return True

    def poll(self):
        """Latest successfully published (version, params), or None."""
        return self._published

    # ------------------------------------------------------------- §10 state

    def state_dict(self) -> Dict:
        return {"version": np.int64(self.version),
                "publishes": np.int64(self.publishes),
                "retries": np.int64(self.retries),
                "failures": np.int64(self.failures),
                "fail_next": np.int64(self._fail_next)}

    def load_state_dict(self, st: Dict) -> None:
        self.version = int(st["version"])
        self.publishes = int(st["publishes"])
        self.retries = int(st["retries"])
        self.failures = int(st["failures"])
        self._fail_next = int(st["fail_next"])


class RolloutService:
    """Continuously-running trajectory producer over the shared Collector.

    One ``tick`` = poll the weight channel, consult the fault plan, then
    (unless throttled/stalled) collect one batch under the current served
    params and push the tagged trajectory into the buffer."""

    def __init__(self, collector, buffer: TrajBuffer, sync: WeightSync,
                 faults: Optional[FaultPlan] = None, producer: int = 0):
        self.collector = collector
        self.buffer = buffer
        self.sync = sync
        self.faults = faults
        self.producer = int(producer)
        self.params = None                   # last good installed weights
        self.version = -1                    # version of self.params
        self.produced = 0                    # completed collect ticks
        self.ticks = 0
        self.stalled_ticks = 0
        self._stall_remaining = 0

    # ------------------------------------------------------------- weights

    def install(self, params, version: int) -> None:
        """Directly install served weights (initial bootstrap / resume)."""
        self.params = params
        self.version = int(version)

    def _maybe_sync(self) -> None:
        pub = self.sync.poll()
        if pub is not None and pub[0] > self.version:
            self.version, self.params = pub[0], pub[1]

    # ---------------------------------------------------------------- tick

    def tick(self) -> bool:
        """One producer step.  Returns True iff a trajectory was produced
        (False: throttled, stalled, or no weights installed yet).

        Raises ``EngineKilled`` on a due 'kill' fault — the producer's
        failure domain; the consumer catches and restarts it."""
        from repro.obs import get_registry
        reg = get_registry()
        self.ticks += 1
        self._maybe_sync()
        if self.faults is not None:
            if self.faults.due(self.ticks - 1, "kill"):
                raise EngineKilled(f"rollout service killed at tick "
                                   f"{self.ticks - 1}")
            for e in self.faults.due(self.ticks - 1, "stall"):
                self._stall_remaining += max(1, int(e.count))
        if self._stall_remaining > 0:
            self._stall_remaining -= 1
            self.stalled_ticks += 1
            reg.inc("async.producer_stalled_ticks")
            return False
        if self.params is None:
            return False
        if self.buffer.should_throttle():
            self.buffer.note_throttled()
            reg.inc("async.producer_throttled_ticks")
            return False
        # the produced-counter IS the collection epoch: under the strict
        # K=0 alternation it equals the consumer's step_idx, so the
        # dataset-RNG and PRNG streams replay the synchronous run exactly
        epoch = self.produced
        batch = self.collector.sample(epoch)
        batch, rb, rewards, times = self.collector.collect(
            self.params, batch, epoch)
        # the stage-times dict (collect_time, reward_time, rollout metrics)
        # travels with the trajectory so the consumer's step metrics match
        # the synchronous trainer's schema key-for-key
        rb.metrics = {k: float(v) for k, v in times.items()
                      if isinstance(v, (int, float))}
        self.buffer.put(Trajectory(batch=batch, rb=rb, rewards=rewards,
                                   version=self.version,
                                   producer=self.producer))
        self.produced += 1
        reg.set("async.produced", float(self.produced))
        return True

    def recover(self) -> None:
        """Post-kill restart: clear transient stall state (the collector,
        cache and buffer live outside the producer's failure domain and
        carry over — mirroring the engine's kill-and-resume contract where
        durable state rides the checkpoint, transient state resets)."""
        self._stall_remaining = 0

    # ------------------------------------------------------------- counters

    def counters(self, prefix: str = "service_") -> Dict[str, float]:
        return {f"{prefix}produced": float(self.produced),
                f"{prefix}ticks": float(self.ticks),
                f"{prefix}stalled_ticks": float(self.stalled_ticks),
                f"{prefix}version": float(self.version)}

    # ------------------------------------------------------------ §10 state

    def state_dict(self) -> Dict:
        st = {"scalars": {"version": np.int64(self.version),
                          "produced": np.int64(self.produced),
                          "ticks": np.int64(self.ticks),
                          "stalled_ticks": np.int64(self.stalled_ticks),
                          "stall_remaining": np.int64(self._stall_remaining),
                          "has_params": np.int64(self.params is not None)}}
        if self.params is not None:
            st["params"] = self.params
        return st

    def load_state_dict(self, st: Dict) -> None:
        sc = st["scalars"]
        self.version = int(sc["version"])
        self.produced = int(sc["produced"])
        self.ticks = int(sc["ticks"])
        self.stalled_ticks = int(sc["stalled_ticks"])
        self._stall_remaining = int(sc["stall_remaining"])
        self.params = st["params"] if int(sc["has_params"]) else None
