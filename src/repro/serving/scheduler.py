"""Slot scheduler: admission queue, slot free-list, occupancy metrics.

Pure host-side bookkeeping — no jax.  The scheduler owns WHICH request runs
WHERE and WHEN; the engine loop (engine_loop.py) owns the device work.  Slots
are the TPU-idiomatic replacement for paged-KV block tables (DESIGN.md §3/§6):
the decode batch has a fixed number of rows over dense caches, and admission
replaces a finished row in place.

Admission is FIFO over the queue; the free-list is LIFO (a freed slot is the
warmest candidate).  Per-slot budgets live in the engine's state vectors;
the scheduler tracks the request lifecycle and aggregates metrics:
queue-wait, slot occupancy (busy slot-steps / total slot-steps), admissions,
completions.
"""
from __future__ import annotations

from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

from .request import DECODING, DONE, PREFILLING, QUEUED, Request


class SlotScheduler:
    def __init__(self, num_slots: int):
        assert num_slots > 0, num_slots
        self.num_slots = num_slots
        self.free: List[int] = list(range(num_slots - 1, -1, -1))
        self.queue: Deque[Request] = deque()
        self.active: Dict[int, Request] = {}          # slot -> request
        # metrics
        self.submitted = 0
        self.admitted = 0
        self.completed = 0
        self.busy_slot_steps = 0
        self.total_slot_steps = 0
        self.queue_wait_total = 0.0
        self.serve_time_total = 0.0

    # ------------------------------------------------------------ lifecycle

    def submit(self, req: Request, now: float = 0.0) -> None:
        req.state = QUEUED
        req.queued_at = now
        self.queue.append(req)
        self.submitted += 1

    @property
    def pending(self) -> int:
        return len(self.queue)

    @property
    def num_active(self) -> int:
        return len(self.active)

    @property
    def idle(self) -> bool:
        return not self.queue and not self.active

    def reserve(self, now: float = 0.0) -> List[Tuple[int, Request]]:
        """Pair queued requests (FIFO) with free slots; mark PREFILLING."""
        group: List[Tuple[int, Request]] = []
        while self.free and self.queue:
            slot = self.free.pop()
            req = self.queue.popleft()
            req.state = PREFILLING
            req.admitted_at = now
            self.queue_wait_total += max(0.0, now - req.queued_at)
            self.active[slot] = req
            self.admitted += 1
            group.append((slot, req))
        return group

    def activate(self, slot: int) -> None:
        self.active[slot].state = DECODING

    def complete(self, slot: int, now: float = 0.0) -> Request:
        """Finish the request in ``slot`` and return the slot to the pool."""
        req = self.active.pop(slot)
        req.state = DONE
        req.finished_at = now
        self.serve_time_total += max(0.0, now - req.admitted_at)
        self.free.append(slot)
        self.completed += 1
        return req

    # -------------------------------------------------------------- metrics

    def tick(self, busy_slots: int, steps: int = 1) -> None:
        """Account ``steps`` decode steps with ``busy_slots`` rows working."""
        self.busy_slot_steps += busy_slots * steps
        self.total_slot_steps += self.num_slots * steps

    def stats(self) -> Dict[str, float]:
        return {
            "num_slots": self.num_slots,
            "submitted": self.submitted,
            "admitted": self.admitted,
            "completed": self.completed,
            "pending": len(self.queue),
            "occupancy": (self.busy_slot_steps / self.total_slot_steps
                          if self.total_slot_steps else 0.0),
            "mean_queue_wait": (self.queue_wait_total / self.completed
                                if self.completed else 0.0),
            "mean_serve_time": (self.serve_time_total / self.completed
                                if self.completed else 0.0),
        }
