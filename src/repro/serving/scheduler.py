"""Slot scheduler: admission queue, slot free-list, occupancy metrics.

Pure host-side bookkeeping — no jax.  The scheduler owns WHICH request runs
WHERE and WHEN; the engine loop (engine_loop.py) owns the device work.  The
decode batch has a fixed number of rows, and admission replaces a finished
row in place — over dense ``(B, S)`` cache slabs (DESIGN.md §3/§6) or, with
``cache_layout='paged'``, over block-table rows whose physical blocks a
``BlockAllocator`` manages one level down (§13, serving/paged_engine.py).

Admission is FIFO over the queue; the free-list is LIFO (a freed slot is the
warmest candidate).  Per-slot budgets live in the engine's state vectors;
the scheduler tracks the request lifecycle and aggregates metrics:
queue-wait, slot occupancy (busy slot-steps / total slot-steps), admissions,
completions.

Hardening (DESIGN.md §10): the queue is optionally *bounded*
(``max_queue``) with an explicit backpressure policy — ``reject`` refuses
the new submission, ``shed-oldest`` drops the head of the queue to make
room — and requests can leave a slot without finishing (``reclaim``: a
deadline expiry or quarantine frees the slot; a bounded number of retries
re-enter through the queue).  Every such event is a counter in ``stats()``.
The whole scheduler state round-trips through ``state_dict`` /
``load_state_dict`` for exact kill-and-resume.
"""
from __future__ import annotations

from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

from .request import DECODING, DONE, PREFILLING, QUEUED, Request

OVERFLOW_POLICIES = ("reject", "shed-oldest")


class SlotScheduler:
    def __init__(self, num_slots: int, max_queue: Optional[int] = None,
                 overflow: str = "reject"):
        assert num_slots > 0, num_slots
        assert max_queue is None or max_queue > 0, max_queue
        assert overflow in OVERFLOW_POLICIES, overflow
        self.num_slots = num_slots
        self.max_queue = max_queue
        self.overflow = overflow
        self.free: List[int] = list(range(num_slots - 1, -1, -1))
        self.queue: Deque[Request] = deque()
        self.active: Dict[int, Request] = {}          # slot -> request
        # metrics
        self.submitted = 0
        self.admitted = 0
        self.completed = 0
        self.busy_slot_steps = 0
        self.total_slot_steps = 0
        self.queue_wait_total = 0.0
        self.serve_time_total = 0.0
        # §10 recovery counters
        self.timeouts = 0
        self.quarantines = 0
        self.retries = 0
        self.sheds = 0
        self.rejected = 0

    # ------------------------------------------------------------ lifecycle

    def submit(self, req: Request, now: float = 0.0) -> Optional[Request]:
        """Queue a request; returns the request SHED by backpressure, if any.

        With an unbounded queue (or room left) the return is None.  At
        capacity, policy ``reject`` refuses and returns ``req`` itself;
        ``shed-oldest`` drops the queue head to admit the newcomer and
        returns the dropped request.  Either way the caller owns emitting
        the shed response — the scheduler only counts it.
        """
        shed: Optional[Request] = None
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            if self.overflow == "reject":
                self.rejected += 1
                self.sheds += 1
                self.submitted += 1
                return req
            shed = self.queue.popleft()                # shed-oldest
            self.sheds += 1
        req.state = QUEUED
        req.queued_at = now
        if req.base_draft_len < 0:
            # remember where the CALLER's draft ends before any retry grows
            # it with the request's own partial output (§10 retry semantics)
            req.base_draft_len = len(req.draft_tokens) \
                if req.draft_tokens is not None else 0
        self.queue.append(req)
        self.submitted += 1
        return shed

    def resubmit(self, req: Request, now: float = 0.0) -> None:
        """Re-queue a reclaimed request (bounded retry).  Bypasses the
        backpressure bound — a retry holds no NEW work, shedding it would
        turn one fault into a dropped request."""
        req.state = QUEUED
        req.queued_at = now
        req.retries += 1
        self.retries += 1
        self.queue.append(req)

    @property
    def pending(self) -> int:
        return len(self.queue)

    @property
    def num_active(self) -> int:
        return len(self.active)

    @property
    def idle(self) -> bool:
        return not self.queue and not self.active

    def reserve(self, now: float = 0.0,
                limit: Optional[int] = None) -> List[Tuple[int, Request]]:
        """Pair queued requests (FIFO) with free slots; mark PREFILLING.

        ``limit`` caps how many pairs this call makes (None = all it can):
        the paged engine admits at most as many rows as its block pool can
        table, leaving the rest QUEUED — in order — until decode completions
        free blocks (DESIGN.md §13 admission pressure).
        """
        group: List[Tuple[int, Request]] = []
        while self.free and self.queue and \
                (limit is None or len(group) < limit):
            slot = self.free.pop()
            req = self.queue.popleft()
            req.state = PREFILLING
            req.admitted_at = now
            self.queue_wait_total += max(0.0, now - req.queued_at)
            self.active[slot] = req
            self.admitted += 1
            group.append((slot, req))
        return group

    def activate(self, slot: int) -> None:
        self.active[slot].state = DECODING

    def complete(self, slot: int, now: float = 0.0) -> Request:
        """Finish the request in ``slot`` and return the slot to the pool."""
        req = self.active.pop(slot)
        req.state = DONE
        req.finished_at = now
        self.serve_time_total += max(0.0, now - req.admitted_at)
        self.free.append(slot)
        self.completed += 1
        return req

    def reclaim(self, slot: int, now: float = 0.0,
                reason: str = "timeout") -> Request:
        """Pull a request OUT of its slot without finishing it (§10).

        The slot returns to the free pool immediately so admission can
        back-fill it; the caller decides whether the request retries
        (``resubmit``) or fails out.  Counted separately from completions.
        """
        req = self.active.pop(slot)
        self.free.append(slot)
        if reason == "quarantine":
            self.quarantines += 1
        elif reason == "shed":
            # §13: a row pulled because the paged block pool ran dry is a
            # load-shedding event, not a straggler timeout
            self.sheds += 1
        else:
            self.timeouts += 1
        return req

    # -------------------------------------------------------------- metrics

    def tick(self, busy_slots: int, steps: int = 1) -> None:
        """Account ``steps`` decode steps with ``busy_slots`` rows working."""
        self.busy_slot_steps += busy_slots * steps
        self.total_slot_steps += self.num_slots * steps

    def stats(self) -> Dict[str, float]:
        return {
            "num_slots": self.num_slots,
            "submitted": self.submitted,
            "admitted": self.admitted,
            "completed": self.completed,
            "pending": len(self.queue),
            "occupancy": (self.busy_slot_steps / self.total_slot_steps
                          if self.total_slot_steps else 0.0),
            "mean_queue_wait": (self.queue_wait_total / self.completed
                                if self.completed else 0.0),
            "mean_serve_time": (self.serve_time_total / self.completed
                                if self.completed else 0.0),
            "timeouts": self.timeouts,
            "quarantined_requests": self.quarantines,
            "retried_requests": self.retries,
            "shed_requests": self.sheds,
            "rejected_requests": self.rejected,
            "max_queue": self.max_queue or 0,
        }

    # ----------------------------------------------------- exact state (§10)

    _COUNTERS = ("submitted", "admitted", "completed", "busy_slot_steps",
                 "total_slot_steps", "queue_wait_total", "serve_time_total",
                 "timeouts", "quarantines", "retries", "sheds", "rejected")

    def state_dict(self) -> Dict:
        import numpy as np
        return {
            "free": np.asarray(self.free, np.int64),
            "queue": {str(i): r.to_state()
                      for i, r in enumerate(self.queue)},
            "active": {str(slot): r.to_state()
                       for slot, r in self.active.items()},
            "counters": {k: np.float64(getattr(self, k))
                         for k in self._COUNTERS},
        }

    def load_state_dict(self, state: Dict) -> None:
        import numpy as np
        self.free = [int(s) for s in np.asarray(state["free"])]
        q = state["queue"]
        self.queue = deque(Request.from_state(q[str(i)])
                           for i in range(len(q)))
        self.active = {int(slot): Request.from_state(st)
                       for slot, st in state["active"].items()}
        for k in self._COUNTERS:
            cast = float if k.endswith("_total") else int
            setattr(self, k, cast(state["counters"][k]))
