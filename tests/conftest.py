"""Shared fixtures.  NOTE: no XLA_FLAGS device-count override here — smoke
tests and benches must see the single real CPU device (the 512-device
override belongs ONLY to repro.launch.dryrun)."""
import os
import sys

os.environ.setdefault("JAX_PLATFORMS", "cpu")

# Make tests/hypothesis_compat.py importable from every test subdirectory
# (the test tree has no __init__.py files, so pytest only puts each test
# module's own directory on sys.path).
sys.path.insert(0, os.path.dirname(__file__))

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.config import ModelConfig


@pytest.fixture(scope="module", autouse=True)
def _bound_compile_maps():
    """Release compiled executables after every test module.

    Each XLA:CPU executable keeps mmap'd JIT code regions alive for the
    life of the process; a full-suite run accumulates enough of them to
    cross the kernel's ``vm.max_map_count`` ceiling (default 65530), at
    which point the NEXT compile segfaults inside LLVM.  Cross-module
    jit reuse is negligible (modules build their own configs/shapes), so
    clearing per module bounds the map count at a few thousand for the
    whole suite."""
    yield
    jax.clear_caches()


@pytest.fixture(scope="session")
def tiny_cfg():
    from repro.data.tokenizer import VOCAB_SIZE
    return ModelConfig(name="tiny", num_layers=2, d_model=64, num_heads=4,
                       num_kv_heads=2, d_ff=128, vocab_size=VOCAB_SIZE,
                       max_seq_len=256)


@pytest.fixture(scope="session")
def tiny_params(tiny_cfg):
    from repro.models import model as M
    return M.init_lm(jax.random.PRNGKey(0), tiny_cfg)
