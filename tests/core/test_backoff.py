"""Shared exponential-backoff policy (DESIGN.md §12): deterministic
schedule, injectable sleep, retry budget semantics."""
import pytest

from repro.core.backoff import BackoffConfig, RetriesExhausted, retry


def test_schedule_is_exponential_and_capped():
    cfg = BackoffConfig(base=0.1, factor=2.0, max_delay=0.5, max_attempts=5)
    assert cfg.schedule() == [0.1, 0.2, 0.4, 0.5]          # capped at max
    assert cfg.delay(10) == 0.5


def test_jitter_is_deterministic_and_bounded():
    cfg = BackoffConfig(base=1.0, factor=1.0, max_delay=10.0,
                        jitter=0.1, seed=42, max_attempts=6)
    a, b = cfg.schedule(), cfg.schedule()
    assert a == b                          # pure function of (config, i)
    for d in a:
        assert 0.9 <= d <= 1.1             # within ±jitter of the base
    # a different seed jitters differently (same bounds)
    assert BackoffConfig(base=1.0, factor=1.0, max_delay=10.0, jitter=0.1,
                         seed=7, max_attempts=6).schedule() != a


def test_retry_succeeds_after_transient_failures():
    calls = {"n": 0}
    slept = []

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise ValueError("transient")
        return "ok"

    cfg = BackoffConfig(base=0.5, factor=2.0, max_delay=8.0, max_attempts=5)
    out = retry(flaky, cfg, sleep=slept.append, retry_on=(ValueError,))
    assert out == "ok" and calls["n"] == 3
    assert slept == [0.5, 1.0]             # one sleep per failed attempt


def test_retry_exhaustion_raises_chained():
    slept = []

    def always():
        raise ValueError("down")

    cfg = BackoffConfig(base=0.1, max_attempts=3)
    with pytest.raises(RetriesExhausted) as ei:
        retry(always, cfg, sleep=slept.append, retry_on=(ValueError,))
    assert isinstance(ei.value.__cause__, ValueError)
    assert len(slept) == 2                 # no sleep after the last attempt


def test_retry_on_filters_exception_types():
    def boom():
        raise KeyError("not retryable here")

    with pytest.raises(KeyError):          # escapes retry immediately
        retry(boom, BackoffConfig(max_attempts=5), sleep=lambda d: None,
              retry_on=(ValueError,))


def test_on_retry_hook_sees_attempt_exc_delay():
    seen = []

    def always():
        raise ValueError("x")

    cfg = BackoffConfig(base=0.25, factor=2.0, max_delay=10.0,
                        max_attempts=3)
    with pytest.raises(RetriesExhausted):
        retry(always, cfg, sleep=lambda d: None, retry_on=(ValueError,),
              on_retry=lambda i, e, d: seen.append((i, type(e).__name__, d)))
    assert seen == [(0, "ValueError", 0.25), (1, "ValueError", 0.5)]
