"""RolloutCache semantics: put/get, lag (Delayed Reuse), batch packing."""
import numpy as np
import pytest

from repro.core.cache import RolloutCache


def test_put_get_roundtrip():
    c = RolloutCache()
    toks = np.arange(5, dtype=np.int32)
    lps = -np.ones(5, np.float32)
    c.put(7, toks, lps, 5, step=3, eos_id=4)
    e = c.get(7)
    np.testing.assert_array_equal(e.tokens, toks)
    assert e.ends_with_eos and e.step == 3


def test_lag_semantics():
    c = RolloutCache(history=3)
    for s in range(3):
        c.put(1, np.array([s], np.int32), np.zeros(1, np.float32), 1, step=s)
    assert c.get(1, lag=1).step == 2      # most recent
    assert c.get(1, lag=2).step == 1      # delayed reuse
    assert c.get(1, lag=3).step == 0
    assert c.get(1, lag=4) is None        # beyond history


def test_miss_on_unknown_prompt():
    c = RolloutCache()
    assert c.get(42) is None
    assert c.stats()["hit_rate"] == 0.0


def test_batch_get_packing():
    c = RolloutCache()
    c.put(0, np.array([5, 6, 2], np.int32), np.array([-1., -2., -3.],
                                                     np.float32), 3, 0)
    out = c.batch_get([0, 99], max_len=6)
    assert out["draft_len"].tolist() == [3, 0]
    np.testing.assert_array_equal(out["draft_tokens"][0, :3], [5, 6, 2])
    assert (out["draft_tokens"][0, 3:] == 0).all()
    assert out["draft_eos"].tolist() == [True, False]
    np.testing.assert_allclose(out["draft_logprobs"][0, :3], [-1, -2, -3])


def test_truncation_drops_eos_flag():
    c = RolloutCache()
    c.put(0, np.array([5, 6, 2], np.int32), np.zeros(3, np.float32), 3, 0)
    out = c.batch_get([0], max_len=2)
    assert out["draft_len"][0] == 2
    assert not out["draft_eos"][0]        # truncated => not a complete response


def test_history_eviction():
    c = RolloutCache(history=2)
    for s in range(5):
        c.put(1, np.array([s], np.int32), np.zeros(1, np.float32), 1, step=s)
    assert c.get(1, lag=1).step == 4
    assert c.get(1, lag=2).step == 3
    assert c.get(1, lag=3) is None


def test_lru_eviction_bounds_size():
    c = RolloutCache(max_prompts=3)
    for pid in range(5):
        c.put(pid, np.array([pid], np.int32), np.zeros(1, np.float32), 1, 0)
    assert len(c) == 3
    assert c.stats()["evictions"] == 2
    assert c.stats()["max_prompts"] == 3
    assert c.get(0) is None and c.get(1) is None      # oldest evicted
    assert c.get(4) is not None


def test_lru_get_refreshes_recency():
    c = RolloutCache(max_prompts=2)
    c.put(0, np.array([0], np.int32), np.zeros(1, np.float32), 1, 0)
    c.put(1, np.array([1], np.int32), np.zeros(1, np.float32), 1, 0)
    assert c.get(0) is not None                       # touch 0 -> 1 is LRU
    c.put(2, np.array([2], np.int32), np.zeros(1, np.float32), 1, 0)
    assert c.get(1) is None                           # 1 evicted, not 0
    assert c.get(0) is not None and c.get(2) is not None


def test_lru_put_existing_refreshes_and_keeps_history():
    c = RolloutCache(history=2, max_prompts=2)
    for s in range(2):
        c.put(0, np.array([s], np.int32), np.zeros(1, np.float32), 1, step=s)
    c.put(1, np.array([9], np.int32), np.zeros(1, np.float32), 1, 0)
    c.put(0, np.array([7], np.int32), np.zeros(1, np.float32), 1, step=2)
    c.put(2, np.array([5], np.int32), np.zeros(1, np.float32), 1, 0)  # evicts 1
    assert c.get(1) is None
    assert c.get(0, lag=1).step == 2                  # history ring intact
    assert c.get(0, lag=2).step == 1
    assert c.stats()["evictions"] == 1


def test_unbounded_by_default():
    c = RolloutCache()
    for pid in range(100):
        c.put(pid, np.array([1], np.int32), np.zeros(1, np.float32), 1, 0)
    assert len(c) == 100
    assert c.stats()["evictions"] == 0
