"""RolloutCache semantics: put/get, lag (Delayed Reuse), batch packing."""
import numpy as np
import pytest

from repro.core.cache import RolloutCache


def test_put_get_roundtrip():
    c = RolloutCache()
    toks = np.arange(5, dtype=np.int32)
    lps = -np.ones(5, np.float32)
    c.put(7, toks, lps, 5, step=3, eos_id=4)
    e = c.get(7)
    np.testing.assert_array_equal(e.tokens, toks)
    assert e.ends_with_eos and e.step == 3


def test_lag_semantics():
    c = RolloutCache(history=3)
    for s in range(3):
        c.put(1, np.array([s], np.int32), np.zeros(1, np.float32), 1, step=s)
    assert c.get(1, lag=1).step == 2      # most recent
    assert c.get(1, lag=2).step == 1      # delayed reuse
    assert c.get(1, lag=3).step == 0
    assert c.get(1, lag=4) is None        # beyond history


def test_miss_on_unknown_prompt():
    c = RolloutCache()
    assert c.get(42) is None
    assert c.stats()["hit_rate"] == 0.0


def test_batch_get_packing():
    c = RolloutCache()
    c.put(0, np.array([5, 6, 2], np.int32), np.array([-1., -2., -3.],
                                                     np.float32), 3, 0)
    out = c.batch_get([0, 99], max_len=6)
    assert out["draft_len"].tolist() == [3, 0]
    np.testing.assert_array_equal(out["draft_tokens"][0, :3], [5, 6, 2])
    assert (out["draft_tokens"][0, 3:] == 0).all()
    assert out["draft_eos"].tolist() == [True, False]
    np.testing.assert_allclose(out["draft_logprobs"][0, :3], [-1, -2, -3])


def test_truncation_drops_eos_flag():
    c = RolloutCache()
    c.put(0, np.array([5, 6, 2], np.int32), np.zeros(3, np.float32), 3, 0)
    out = c.batch_get([0], max_len=2)
    assert out["draft_len"][0] == 2
    assert not out["draft_eos"][0]        # truncated => not a complete response


def test_history_eviction():
    c = RolloutCache(history=2)
    for s in range(5):
        c.put(1, np.array([s], np.int32), np.zeros(1, np.float32), 1, step=s)
    assert c.get(1, lag=1).step == 4
    assert c.get(1, lag=2).step == 3
    assert c.get(1, lag=3) is None


def test_lru_eviction_bounds_size():
    c = RolloutCache(max_prompts=3)
    for pid in range(5):
        c.put(pid, np.array([pid], np.int32), np.zeros(1, np.float32), 1, 0)
    assert len(c) == 3
    assert c.stats()["evictions"] == 2
    assert c.stats()["max_prompts"] == 3
    assert c.get(0) is None and c.get(1) is None      # oldest evicted
    assert c.get(4) is not None


def test_lru_get_refreshes_recency():
    c = RolloutCache(max_prompts=2)
    c.put(0, np.array([0], np.int32), np.zeros(1, np.float32), 1, 0)
    c.put(1, np.array([1], np.int32), np.zeros(1, np.float32), 1, 0)
    assert c.get(0) is not None                       # touch 0 -> 1 is LRU
    c.put(2, np.array([2], np.int32), np.zeros(1, np.float32), 1, 0)
    assert c.get(1) is None                           # 1 evicted, not 0
    assert c.get(0) is not None and c.get(2) is not None


def test_lru_put_existing_refreshes_and_keeps_history():
    c = RolloutCache(history=2, max_prompts=2)
    for s in range(2):
        c.put(0, np.array([s], np.int32), np.zeros(1, np.float32), 1, step=s)
    c.put(1, np.array([9], np.int32), np.zeros(1, np.float32), 1, 0)
    c.put(0, np.array([7], np.int32), np.zeros(1, np.float32), 1, step=2)
    c.put(2, np.array([5], np.int32), np.zeros(1, np.float32), 1, 0)  # evicts 1
    assert c.get(1) is None
    assert c.get(0, lag=1).step == 2                  # history ring intact
    assert c.get(0, lag=2).step == 1
    assert c.stats()["evictions"] == 1


def test_unbounded_by_default():
    c = RolloutCache()
    for pid in range(100):
        c.put(pid, np.array([1], np.int32), np.zeros(1, np.float32), 1, 0)
    assert len(c) == 100
    assert c.stats()["evictions"] == 0


# ---------------------------------------------------- sibling groups (§9)


def _put(c, pid, toks, step=0):
    t = np.asarray(toks, np.int32)
    c.put(pid, t, np.zeros(len(t), np.float32), len(t), step)


def test_siblings_lookup():
    c = RolloutCache(group_size=3)
    for pid in (0, 1, 2, 3):                # group 0: {0,1,2}; group 1: {3}
        _put(c, pid, [10 + pid, 20 + pid])
    sibs = c.siblings(1)
    assert sorted(e.tokens[0] for e in sibs) == [10, 12]
    assert c.siblings(3) == []              # no cached group members
    assert c.stats()["groups"] == 2


def test_siblings_do_not_touch_lru_or_hit_counters():
    c = RolloutCache(group_size=2, max_prompts=2)
    _put(c, 0, [1])
    _put(c, 1, [2])
    h, m = c.hits, c.misses
    c.siblings(0)
    assert (c.hits, c.misses) == (h, m)
    _put(c, 2, [3])                         # evicts pid 0 (LRU, untouched)
    assert c.get(0) is None


def test_lru_eviction_keeps_groups_consistent():
    """No dangling group members after eviction: siblings() is always
    backed by the store, and empty groups disappear."""
    G = 2
    c = RolloutCache(group_size=G, max_prompts=3)
    for pid in range(6):                    # groups {0,1} {2,3} {4,5}
        _put(c, pid, [pid])
    assert len(c) == 3 and c.evictions == 3
    # store now holds {3, 4, 5}; every sibling lookup must be consistent
    for pid in range(6):
        for e in c.siblings(pid):
            assert e is not None
    assert [e.tokens[0] for e in c.siblings(2)] == [3]
    assert c.siblings(0) == []              # group {0,1} fully evicted...
    assert 0 not in c._groups               # ...and unregistered
    stats = c.stats()
    assert stats["groups"] == 2             # {2,3} (partial) and {4,5}


def test_group_reregistration_moves_membership():
    c = RolloutCache(group_size=0)          # explicit groups only
    _put_g = lambda pid, g: c.put(pid, np.asarray([pid], np.int32),
                                  np.zeros(1, np.float32), 1, 0, group=g)
    _put_g(0, 7)
    _put_g(1, 7)
    assert [e.tokens[0] for e in c.siblings(0)] == [1]
    _put_g(1, 8)                            # pid 1 changes group
    assert c.siblings(0) == []
    assert c.stats()["groups"] == 2


def test_batch_siblings_includes_own_rollout():
    c = RolloutCache(group_size=2)
    _put(c, 0, [5, 6])
    _put(c, 1, [7, 8])
    corpora = c.batch_siblings([0, 2])
    assert [t[0] for t in corpora[0]] == [5, 7]   # own first, then sibling
    assert corpora[1] == []
