"""Overlap / diversity metrics (Fig. 2, Fig. 6)."""
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core.metrics import (batch_overlap, distinct_n,
                                prefix_match_fraction, rouge1_overlap,
                                self_bleu)


def test_rouge1_identical_is_one():
    a = [1, 2, 3, 4]
    assert rouge1_overlap(a, a) == pytest.approx(1.0)


def test_rouge1_disjoint_is_zero():
    assert rouge1_overlap([1, 2], [3, 4]) == 0.0


def test_rouge1_empty():
    assert rouge1_overlap([], [1]) == 0.0


@settings(max_examples=30, deadline=None)
@given(a=st.lists(st.integers(0, 9), min_size=1, max_size=30),
       b=st.lists(st.integers(0, 9), min_size=1, max_size=30))
def test_rouge1_symmetric_bounded(a, b):
    v = rouge1_overlap(a, b)
    assert 0.0 <= v <= 1.0
    assert v == pytest.approx(rouge1_overlap(b, a))


def test_prefix_match():
    prev = np.array([1, 2, 3, 4])
    curr = np.array([1, 2, 9, 9])
    assert prefix_match_fraction(prev, curr) == pytest.approx(0.5)
    assert prefix_match_fraction(prev, prev) == pytest.approx(1.0)


def test_distinct1():
    rollouts = [np.array([1, 1, 1]), np.array([1, 1])]
    assert distinct_n(rollouts, 1) == pytest.approx(1 / 5)
    rollouts = [np.array([1, 2, 3])]
    assert distinct_n(rollouts, 1) == pytest.approx(1.0)


def test_self_bleu_extremes():
    same = [np.array([1, 2, 3, 4, 5])] * 4
    distinct = [np.array([1, 2, 3, 4, 5]), np.array([6, 7, 8, 9, 10]),
                np.array([11, 12, 13, 14, 15])]
    assert self_bleu(same) > 0.99
    assert self_bleu(distinct) < 0.05


def test_batch_overlap_mean():
    prev = [np.array([1, 2, 3]), np.array([4, 5])]
    curr = [np.array([1, 2, 3]), np.array([6, 7])]
    assert batch_overlap(prev, curr) == pytest.approx(0.5)
