"""One-pass speculative rollout: equivalence with the two-pass path under a
fixed PRNG key, cache-compaction correctness against an aligned re-prefill,
and the no-second-prefill op-count guarantee."""
import copy

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import RolloutCache, SpecConfig, rollout
from repro.core.verify import verify_and_prefill
from repro.engine.generate import GenerateConfig, positions_from_mask
from repro.models import model as M
from repro.models.config import ModelConfig

B, P, N = 4, 8, 12


@pytest.fixture(scope="module")
def setup():
    cfg = ModelConfig(name="t", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=32)
    # two different policies so verification produces real partial rejections
    params_a = M.init_lm(jax.random.PRNGKey(0), cfg)
    params_b = M.init_lm(jax.random.PRNGKey(42), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (B, P), 3, 32)
    mask = jnp.ones((B, P), bool)
    return cfg, params_a, params_b, prompt, mask


def _seeded_cache(cfg, params, prompt, mask, variant="spec"):
    cache = RolloutCache()
    spec = SpecConfig(variant=variant, verify_impl="ref", one_pass="off")
    gen = GenerateConfig(max_new_tokens=N)
    rollout(params, cfg, gen, spec, prompt, mask, list(range(B)), cache,
            jax.random.PRNGKey(0), 0)
    return cache


@pytest.mark.parametrize("variant", ["spec", "delayed"])
def test_one_pass_matches_two_pass(setup, variant):
    """Same key => same rejection indices, tokens, lengths and logprobs."""
    cfg, params_a, params_b, prompt, mask = setup
    gen = GenerateConfig(max_new_tokens=N)
    ids = list(range(B))
    cache1 = _seeded_cache(cfg, params_a, prompt, mask)
    if variant == "delayed":   # lag=2 needs two cached visits
        spec = SpecConfig(variant="spec", verify_impl="ref", one_pass="off")
        rollout(params_a, cfg, gen, spec, prompt, mask, ids, cache1,
                jax.random.PRNGKey(5), 1)
    cache2 = copy.deepcopy(cache1)

    key = jax.random.PRNGKey(7)
    two = rollout(params_b, cfg, gen,
                  SpecConfig(variant=variant, verify_impl="ref",
                             one_pass="off"),
                  prompt, mask, ids, cache1, key, 2)
    one = rollout(params_b, cfg, gen,
                  SpecConfig(variant=variant, verify_impl="ref", one_pass="on",
                             compact_impl="ref"),
                  prompt, mask, ids, cache2, key, 2)

    assert one.metrics["one_pass"] == 1.0
    assert one.metrics["prefill_passes"] == 1.0
    assert two.metrics["prefill_passes"] == 2.0
    assert one.metrics["n_reused"] == two.metrics["n_reused"]
    np.testing.assert_array_equal(one.length, two.length)
    np.testing.assert_array_equal(one.response, two.response)
    np.testing.assert_allclose(one.behaviour_logprobs, two.behaviour_logprobs,
                               atol=1e-5, rtol=1e-5)
    assert one.metrics["n_reused"] > 0          # the comparison is non-trivial


def test_one_pass_with_pallas_compactor(setup):
    """Interpret-mode cache_gather kernel on the real rollout path."""
    cfg, params_a, params_b, prompt, mask = setup
    gen = GenerateConfig(max_new_tokens=N)
    ids = list(range(B))
    cache1 = _seeded_cache(cfg, params_a, prompt, mask)
    cache2 = copy.deepcopy(cache1)
    key = jax.random.PRNGKey(3)
    ref = rollout(params_b, cfg, gen,
                  SpecConfig(variant="spec", verify_impl="ref", one_pass="on",
                             compact_impl="ref"),
                  prompt, mask, ids, cache1, key, 1)
    ker = rollout(params_b, cfg, gen,
                  SpecConfig(variant="spec", verify_impl="ref", one_pass="on",
                             compact_impl="interpret"),
                  prompt, mask, ids, cache2, key, 1)
    np.testing.assert_array_equal(ker.response, ref.response)
    np.testing.assert_array_equal(ker.length, ref.length)


def test_realigned_cache_matches_aligned_prefill(setup):
    """Compacted verify caches == prefill over the left-aligned tokens:
    identical slot positions everywhere, identical K/V on valid slots."""
    cfg, params_a, params_b, prompt, mask = setup
    from repro.core.spec_rollout import left_align

    draft = jax.random.randint(jax.random.PRNGKey(9), (B, N), 3, 32)
    draft_len = jnp.array([0, 3, 7, N], jnp.int32)
    didx = jnp.arange(N)[None, :]
    draft_mask = didx < draft_len[:, None]
    draft_lp = jnp.where(draft_mask, -1.0, 0.0)

    ver = verify_and_prefill(params_a, cfg, prompt, mask, draft, draft_lp,
                             draft_len, jax.random.PRNGKey(2), 0.0,
                             impl="ref")
    n = ver["n"]
    W = P + N
    p_len = mask.sum(axis=1).astype(jnp.int32)
    got = M.realign_decode_cache(cfg, ver["caches"], (N - n).astype(jnp.int32),
                                 p_len + n, W, impl="ref")

    # reference: left-align prompt ⊕ accepted prefix and prefill from scratch
    prefix_mask = didx < n[:, None]
    combined = jnp.concatenate([prompt, jnp.where(prefix_mask, draft, 0)], axis=1)
    combined_mask = jnp.concatenate([mask, prefix_mask], axis=1)
    al_tok, al_mask = left_align(combined, combined_mask)
    want_caches = M.init_cache(cfg, B, W + N)
    _, want_caches = M.prefill(params_a, cfg, al_tok,
                               positions_from_mask(al_mask), want_caches)

    for run_got, run_want in zip(got, want_caches):
        gsc, wsc = run_got["self"], run_want["self"]
        np.testing.assert_array_equal(np.asarray(gsc["pos"]),
                                      np.asarray(wsc["pos"]))
        valid = np.asarray(wsc["pos"]) >= 0            # (run, B, S)
        for name in ("k", "v", "ckv", "krope"):
            if name not in wsc:
                continue
            gv, wv = np.asarray(gsc[name]), np.asarray(wsc[name])
            vm = valid[:, :, None, :, None] if gv.ndim == 5 else \
                valid[:, :, :, None]
            np.testing.assert_allclose(np.where(vm, gv, 0.0),
                                       np.where(vm, wv, 0.0),
                                       atol=1e-5, rtol=1e-5)


def test_one_pass_forwards_context_exactly_once(setup):
    """Op-count assertion: with jit disabled every executed forward is
    counted — the fused path runs ONE prefill over prompt ⊕ draft and no
    teacher-forced forward; the two-pass path runs one of each."""
    cfg, params_a, params_b, prompt, mask = setup
    small = GenerateConfig(max_new_tokens=4)
    ids = list(range(B))
    cache1 = _seeded_cache(cfg, params_a, prompt, mask)
    cache2 = copy.deepcopy(cache1)

    with jax.disable_jit():
        M.reset_op_counts()
        rollout(params_b, cfg, small,
                SpecConfig(variant="spec", verify_impl="ref", one_pass="on",
                           compact_impl="ref"),
                prompt, mask, ids, cache1, jax.random.PRNGKey(1), 1)
        assert M.OP_COUNTS["prefill"] == 1
        assert M.OP_COUNTS["forward"] == 0

        M.reset_op_counts()
        rollout(params_b, cfg, small,
                SpecConfig(variant="spec", verify_impl="ref", one_pass="off"),
                prompt, mask, ids, cache2, jax.random.PRNGKey(1), 1)
        assert M.OP_COUNTS["prefill"] == 1     # continuation re-prefill
        assert M.OP_COUNTS["forward"] == 1     # scoring pass


def test_one_pass_auto_gating():
    """auto falls back to two-pass for recurrent trunks; 'on' raises."""
    from repro.core.spec_rollout import use_one_pass
    attn = ModelConfig(name="a", num_layers=2, d_model=64, num_heads=4,
                       num_kv_heads=2, d_ff=128, vocab_size=32)
    rec = ModelConfig(name="m", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=32,
                      block_kind="mamba", mamba_d_state=8)
    spec_auto = SpecConfig(variant="spec", one_pass="auto")
    assert use_one_pass(attn, spec_auto, {})
    assert not use_one_pass(rec, spec_auto, {})
    assert not use_one_pass(attn, SpecConfig(variant="full"), {})
    with pytest.raises(ValueError):
        use_one_pass(rec, SpecConfig(variant="spec", one_pass="on"), {})


def test_one_pass_with_encoder_extras(setup):
    """encoder_out flows through the fused verify and the resumed decode."""
    cfg = ModelConfig(name="ed", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=4, d_ff=128, vocab_size=32,
                      encoder_layers=2, encoder_frames=16,
                      cross_attention=True, pos_embed="learned",
                      max_seq_len=64)
    params = M.init_lm(jax.random.PRNGKey(0), cfg)
    bb, pp = 2, 6
    frames = jax.random.normal(jax.random.PRNGKey(1), (bb, 16, cfg.d_model))
    enc, epos = M.encode(params, cfg, frames)
    prompt = jax.random.randint(jax.random.PRNGKey(2), (bb, pp), 3, 32)
    mask = jnp.ones((bb, pp), bool)
    gen = GenerateConfig(max_new_tokens=8)
    kw = dict(encoder_out=enc, encoder_positions=epos)
    cache = RolloutCache()
    spec = SpecConfig(variant="spec", verify_impl="ref", one_pass="on",
                      compact_impl="ref")
    rollout(params, cfg, gen, spec, prompt, mask, [0, 1], cache,
            jax.random.PRNGKey(3), 0, **kw)
    rb = rollout(params, cfg, gen, spec, prompt, mask, [0, 1], cache,
                 jax.random.PRNGKey(4), 1, **kw)
    assert rb.metrics["one_pass"] == 1.0
    assert rb.metrics["accept_rate"] > 0.99      # same policy, l >= 1
    assert (rb.response_mask.sum(1) == rb.length).all()
