"""SPEC-RL orchestration: left_align, assemble, full pipeline invariants,
variant semantics (Table 2), cache freshness."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import RolloutCache, SpecConfig, rollout
from repro.core.spec_rollout import assemble, left_align
from repro.engine.generate import GenerateConfig
from repro.models import model as M
from repro.models.config import ModelConfig


@pytest.fixture(scope="module")
def setup():
    cfg = ModelConfig(name="t", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=32)
    params = M.init_lm(jax.random.PRNGKey(0), cfg)
    B, P = 4, 8
    prompt = jax.random.randint(jax.random.PRNGKey(1), (B, P), 3, 32)
    mask = jnp.ones((B, P), bool)
    return cfg, params, prompt, mask


def test_left_align():
    tokens = jnp.array([[0, 0, 5, 6, 7, 0, 0],
                        [1, 2, 3, 0, 0, 0, 0]])
    mask = tokens > 0
    at, am = left_align(tokens, mask)
    np.testing.assert_array_equal(np.asarray(at[0]), [0, 0, 0, 0, 5, 6, 7])
    np.testing.assert_array_equal(np.asarray(at[1]), [0, 0, 0, 0, 1, 2, 3])
    np.testing.assert_array_equal(np.asarray(am.sum(1)), [3, 3])


def test_assemble():
    draft = jnp.array([[11, 12, 13, 14, 0, 0]], jnp.int32)
    prefix_lp = jnp.full((1, 6), -1.0)
    n = jnp.array([2], jnp.int32)
    cont = jnp.array([[21, 22, 0, 0, 0, 0]], jnp.int32)
    cont_lp = jnp.full((1, 6), -2.0)
    cont_len = jnp.array([2], jnp.int32)
    toks, lp, mask, total = assemble(draft, prefix_lp, n, cont, cont_lp,
                                     cont_len)
    np.testing.assert_array_equal(np.asarray(toks[0]), [11, 12, 21, 22, 0, 0])
    np.testing.assert_allclose(np.asarray(lp[0]), [-1, -1, -2, -2, 0, 0])
    assert int(total[0]) == 4
    np.testing.assert_array_equal(np.asarray(mask[0]),
                                  [True, True, True, True, False, False])


def test_identical_policy_full_acceptance(setup):
    """Same policy + l>=1 => every draft token verified (Eq. 3)."""
    cfg, params, prompt, mask = setup
    ids = list(range(prompt.shape[0]))
    gen = GenerateConfig(max_new_tokens=12)
    cache = RolloutCache()
    spec = SpecConfig(variant="spec", lenience=1.0, verify_impl="ref")
    rollout(params, cfg, gen, spec, prompt, mask, ids, cache,
            jax.random.PRNGKey(0), 0)
    rb = rollout(params, cfg, gen, spec, prompt, mask, ids, cache,
                 jax.random.PRNGKey(1), 1)
    assert rb.metrics["accept_rate"] > 0.999
    assert rb.metrics["n_generated"] == 0 or \
        rb.metrics["n_generated"] < rb.metrics["n_reused"]


def test_cache_refreshed_after_step(setup):
    cfg, params, prompt, mask = setup
    ids = list(range(prompt.shape[0]))
    gen = GenerateConfig(max_new_tokens=8)
    cache = RolloutCache()
    spec = SpecConfig(variant="spec", verify_impl="ref")
    rb0 = rollout(params, cfg, gen, spec, prompt, mask, ids, cache,
                  jax.random.PRNGKey(0), 0)
    for i, pid in enumerate(ids):
        e = cache.get(pid)
        L = int(rb0.length[i])
        np.testing.assert_array_equal(e.tokens, rb0.response[i, :L])
        assert e.step == 0
    rollout(params, cfg, gen, spec, prompt, mask, ids, cache,
            jax.random.PRNGKey(1), 1)
    assert all(cache.get(pid).step == 1 for pid in ids)
    # delayed reuse sees the step-0 rollout
    assert all(cache.get(pid, lag=2).step == 0 for pid in ids)


def test_variants_run_and_report(setup):
    cfg, params, prompt, mask = setup
    ids = list(range(prompt.shape[0]))
    gen = GenerateConfig(max_new_tokens=8)
    cache = RolloutCache()
    rollout(params, cfg, gen, SpecConfig(variant="spec", verify_impl="ref"),
            prompt, mask, ids, cache, jax.random.PRNGKey(0), 0)
    rollout(params, cfg, gen, SpecConfig(variant="spec", verify_impl="ref"),
            prompt, mask, ids, cache, jax.random.PRNGKey(1), 1)
    for variant in ("random", "delayed", "full", "off"):
        spec = SpecConfig(variant=variant, verify_impl="ref")
        rb = rollout(params, cfg, gen, spec, prompt, mask, ids,
                     None if variant == "off" else cache,
                     jax.random.PRNGKey(2), 2)
        assert (rb.response_mask.sum(1) == rb.length).all()
        assert rb.response.shape == (4, 8)
        if variant == "full":
            assert rb.metrics["accept_rate"] == 1.0


def test_lenience_zero_equals_vanilla_token_counts(setup):
    """l -> 0 rejects at position 0: everything regenerated."""
    cfg, params, prompt, mask = setup
    ids = list(range(prompt.shape[0]))
    gen = GenerateConfig(max_new_tokens=8)
    cache = RolloutCache()
    spec0 = SpecConfig(variant="spec", lenience=1e-9, verify_impl="ref")
    rollout(params, cfg, gen, spec0, prompt, mask, ids, cache,
            jax.random.PRNGKey(0), 0)
    rb = rollout(params, cfg, gen, spec0, prompt, mask, ids, cache,
                 jax.random.PRNGKey(1), 1)
    assert rb.metrics["n_reused"] == 0
    assert rb.metrics["n_generated"] > 0


def test_response_tokens_match_behaviour_source(setup):
    """Reused prefix tokens must equal the cached draft tokens."""
    cfg, params, prompt, mask = setup
    ids = list(range(prompt.shape[0]))
    gen = GenerateConfig(max_new_tokens=10)
    cache = RolloutCache()
    spec = SpecConfig(variant="spec", lenience=math.e ** 0.5,
                      verify_impl="ref")
    rb0 = rollout(params, cfg, gen, spec, prompt, mask, ids, cache,
                  jax.random.PRNGKey(0), 0)
    drafts = cache.batch_get(ids, 10)
    rb1 = rollout(params, cfg, gen, spec, prompt, mask, ids, cache,
                  jax.random.PRNGKey(1), 1)
    n_re = rb1.metrics["n_reused"]
    if n_re:
        # per-row: the first reused tokens agree with the old draft
        for i in range(len(ids)):
            L = min(int(rb1.length[i]), int(drafts["draft_len"][i]))
            agree = (rb1.response[i, :L] == drafts["draft_tokens"][i, :L])
            # everything before the first disagreement was the reused prefix
            assert agree[0] or rb1.metrics["verified_prefix_mean"] >= 0


def test_rollout_with_encoder_model_kwargs():
    """SPEC-RL plumbing for enc-dec archs: encoder_out flows through
    verification AND continuation (whisper-style decoder rollouts)."""
    cfg = ModelConfig(name="ed", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=4, d_ff=128, vocab_size=32,
                      encoder_layers=2, encoder_frames=16,
                      cross_attention=True, pos_embed="learned",
                      max_seq_len=64)
    params = M.init_lm(jax.random.PRNGKey(0), cfg)
    B, P = 2, 6
    frames = jax.random.normal(jax.random.PRNGKey(1), (B, 16, cfg.d_model))
    enc, epos = M.encode(params, cfg, frames)
    prompt = jax.random.randint(jax.random.PRNGKey(2), (B, P), 3, 32)
    mask = jnp.ones((B, P), bool)
    gen = GenerateConfig(max_new_tokens=8)
    cache = RolloutCache()
    spec = SpecConfig(variant="spec", verify_impl="ref")
    kw = dict(encoder_out=enc, encoder_positions=epos)
    rb0 = rollout(params, cfg, gen, spec, prompt, mask, [0, 1], cache,
                  jax.random.PRNGKey(3), 0, **kw)
    rb1 = rollout(params, cfg, gen, spec, prompt, mask, [0, 1], cache,
                  jax.random.PRNGKey(4), 1, **kw)
    assert rb1.metrics["accept_rate"] > 0.99     # same policy, l >= 1
    assert (rb1.response_mask.sum(1) == rb1.length).all()
