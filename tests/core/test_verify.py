"""Verification pass (Algorithm 1): acceptance math, statistical behaviour,
consistency between the model scoring pass and the acceptance rule."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.verify import verify_drafts
from repro.kernels.spec_verify.ref import spec_verify_ref
from repro.models import model as M
from repro.models.config import ModelConfig


def test_acceptance_probability_matches_eq3():
    """Monte-carlo: P(reject at 0) == 1 - min(1, l * q/p) for 1-token drafts."""
    trials = 30_000
    lp_curr = jnp.full((trials, 1), math.log(0.2))
    lp_prev = jnp.full((trials, 1), math.log(0.5))
    vl = jnp.ones((trials,), jnp.int32)
    for lenience in (1.0, math.e ** 0.5, 3.0):
        u = jax.random.uniform(jax.random.PRNGKey(int(lenience * 10)),
                               (trials, 1))
        n = np.asarray(spec_verify_ref(lp_curr, lp_prev, u, vl,
                                       math.log(lenience)))
        expect = min(1.0, lenience * 0.2 / 0.5)
        got = (n == 1).mean()
        assert abs(got - expect) < 0.01, (lenience, got, expect)


def test_verify_drafts_identical_policy(tiny_cfg, tiny_params):
    cfg, params = tiny_cfg, tiny_params
    B, P, N = 3, 6, 10
    prompt = jax.random.randint(jax.random.PRNGKey(0), (B, P), 3,
                                cfg.vocab_size)
    pmask = jnp.ones((B, P), bool)
    # draft = greedy continuation; p_prev = exact scoring by the same model
    from repro.engine.generate import GenerateConfig, generate, score
    gen = GenerateConfig(max_new_tokens=N)
    out = generate(params, cfg, gen, prompt, pmask, jax.random.PRNGKey(1))
    res = verify_drafts(params, cfg, prompt, pmask, out["tokens"],
                        out["logprobs"], out["length"], jax.random.PRNGKey(2),
                        0.0, impl="ref")
    # p_curr == p_prev exactly (same model) => full acceptance at l=1
    np.testing.assert_array_equal(np.asarray(res["n"]),
                                  np.asarray(out["length"]))
    assert float(res["accept_rate"]) == 1.0


def test_verify_drafts_prefix_consistency(tiny_cfg, tiny_params):
    """lp_curr from the packed verify == scoring the same tokens directly."""
    cfg, params = tiny_cfg, tiny_params
    B, P, N = 2, 5, 6
    prompt = jax.random.randint(jax.random.PRNGKey(3), (B, P), 3,
                                cfg.vocab_size)
    pmask = jnp.ones((B, P), bool)
    draft = jax.random.randint(jax.random.PRNGKey(4), (B, N), 3,
                               cfg.vocab_size)
    dlen = jnp.array([6, 4], jnp.int32)
    dlp = jnp.full((B, N), -1.0)
    res = verify_drafts(params, cfg, prompt, pmask, draft, dlp, dlen,
                        jax.random.PRNGKey(5), 0.0, impl="ref")
    from repro.engine.generate import score
    didx = jnp.arange(N)[None, :]
    dmask = didx < dlen[:, None]
    full = jnp.concatenate([prompt, jnp.where(dmask, draft, 0)], axis=1)
    fmask = jnp.concatenate([pmask, dmask], axis=1)
    sc = score(params, cfg, full, fmask)
    np.testing.assert_allclose(np.asarray(res["lp_curr"]),
                               np.asarray(sc["logprobs"][:, P:]), atol=1e-5)


def test_perturbed_policy_reduces_acceptance(tiny_cfg, tiny_params):
    """A perturbed current policy must reject more than an identical one."""
    cfg, params = tiny_cfg, tiny_params
    B, P, N = 8, 6, 12
    prompt = jax.random.randint(jax.random.PRNGKey(6), (B, P), 3,
                                cfg.vocab_size)
    pmask = jnp.ones((B, P), bool)
    from repro.engine.generate import GenerateConfig, generate
    gen = GenerateConfig(max_new_tokens=N)
    out = generate(params, cfg, gen, prompt, pmask, jax.random.PRNGKey(7))

    perturbed = jax.tree.map(
        lambda x: x + 0.05 * jax.random.normal(jax.random.PRNGKey(8), x.shape)
        .astype(x.dtype), params)
    same = verify_drafts(params, cfg, prompt, pmask, out["tokens"],
                         out["logprobs"], out["length"],
                         jax.random.PRNGKey(9), 0.0, impl="ref")
    diff = verify_drafts(perturbed, cfg, prompt, pmask, out["tokens"],
                         out["logprobs"], out["length"],
                         jax.random.PRNGKey(9), 0.0, impl="ref")
    assert float(diff["n"].sum()) < float(same["n"].sum())


def test_lenience_recovers_acceptance(tiny_cfg, tiny_params):
    """Higher lenience recovers longer prefixes on a perturbed policy
    (Fig. 4c mechanism), with shared verification randomness."""
    cfg, params = tiny_cfg, tiny_params
    B, P, N = 8, 6, 12
    prompt = jax.random.randint(jax.random.PRNGKey(10), (B, P), 3,
                                cfg.vocab_size)
    pmask = jnp.ones((B, P), bool)
    from repro.engine.generate import GenerateConfig, generate
    gen = GenerateConfig(max_new_tokens=N)
    out = generate(params, cfg, gen, prompt, pmask, jax.random.PRNGKey(11))
    perturbed = jax.tree.map(
        lambda x: x + 0.05 * jax.random.normal(jax.random.PRNGKey(12),
                                               x.shape).astype(x.dtype),
        params)
    ns = []
    for logl in (0.0, 0.5, 2.0):
        r = verify_drafts(perturbed, cfg, prompt, pmask, out["tokens"],
                          out["logprobs"], out["length"],
                          jax.random.PRNGKey(13), logl, impl="ref")
        ns.append(int(r["n"].sum()))
    assert ns[0] <= ns[1] <= ns[2]
