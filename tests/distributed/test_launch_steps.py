"""launch/specs + steps on a degenerate (1,1) mesh: lowering coverage inside
pytest (the 256/512-device paths are covered by dryrun.py and the subprocess
test), plus numerical equivalence of the step-function variants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch.specs import INPUT_SHAPES, input_specs, shape_applicable
from repro.launch.steps import (_ce_chunked, _ce_naive, _score_chunked,
                                make_train_step, make_verify_step)
from repro.models import model as M
from repro.optim import adamw


def _mesh11():
    return jax.make_mesh((1, 1), ("data", "model"))


@pytest.mark.parametrize("shape_name", sorted(INPUT_SHAPES))
def test_input_specs_build_for_all_shapes(shape_name):
    """Spec construction (eval_shape only — no allocation) for a big config."""
    cfg = get_config("mixtral-8x22b")
    mesh = _mesh11()
    spec = input_specs(cfg, shape_name, mesh)
    assert spec["step"] in ("train", "verify", "serve")
    assert spec["tokens_per_step"] > 0
    for leaf in jax.tree.leaves(spec["args"]):
        assert hasattr(leaf, "shape")


def test_skip_logic():
    ok, reason = shape_applicable(get_config("granite-34b"), "long_500k")
    assert not ok and "sub-quadratic" in reason
    for arch in ("rwkv6-3b", "jamba-v0.1-52b", "mixtral-8x22b"):
        ok, _ = shape_applicable(get_config(arch), "long_500k")
        assert ok, arch


def test_ce_chunked_matches_naive(tiny_cfg, tiny_params):
    cfg, params = tiny_cfg, tiny_params
    B, T = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(0), (B, T), 3,
                                cfg.vocab_size)
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    logits, aux = M.forward(params, cfg, tokens, positions,
                            return_hidden=True)
    l_naive = _ce_naive(params, cfg, logits, tokens, positions)
    l_chunk = _ce_chunked(params, cfg, aux["hidden"], tokens, positions,
                          chunk=4)
    np.testing.assert_allclose(float(l_naive), float(l_chunk), rtol=1e-5)


def test_score_chunked_matches_direct(tiny_cfg, tiny_params):
    cfg, params = tiny_cfg, tiny_params
    B, T = 2, 12
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, T), 3,
                                cfg.vocab_size)
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    from repro.engine.sampling import logprobs_of
    logits, aux = M.forward(params, cfg, tokens, positions,
                            return_hidden=True)
    lp_direct = logprobs_of(logits[:, :-1], tokens[:, 1:])
    lp_direct = jnp.concatenate([jnp.zeros_like(lp_direct[:, :1]), lp_direct],
                                axis=1)
    lp_chunk = _score_chunked(params, cfg, aux["hidden"], tokens, chunk=4)
    np.testing.assert_allclose(np.asarray(lp_chunk), np.asarray(lp_direct),
                               atol=1e-5)


def test_microbatch_train_step_matches_full(tiny_cfg):
    """Gradient accumulation over 4 microbatches == one full batch step."""
    cfg = tiny_cfg
    params = M.init_lm(jax.random.PRNGKey(0), cfg)
    opt = adamw.init(params)
    ocfg = adamw.AdamWConfig(lr=1e-3, clip_norm=1e9)
    B, T = 8, 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, T), 3,
                                cfg.vocab_size)
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))

    full = make_train_step(cfg, ocfg, microbatch=1)
    mb = make_train_step(cfg, ocfg, microbatch=4)
    p1, _, loss1, g1 = full(params, opt, tokens, positions)
    p2, _, loss2, g2 = mb(params, opt, tokens, positions)
    np.testing.assert_allclose(float(loss1), float(loss2), rtol=1e-4)
    np.testing.assert_allclose(float(g1), float(g2), rtol=1e-3)
    # accumulation reorders float32 sums; O(5e-5) per-param drift is expected
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-5)


def test_verify_step_variants_agree(tiny_cfg, tiny_params):
    cfg, params = tiny_cfg, tiny_params
    B, T = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, T), 3,
                                cfg.vocab_size)
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    dlp = jnp.full((B, T), -1.5)
    u = jax.random.uniform(jax.random.PRNGKey(3), (B, T))
    dlen = jnp.array([T, T // 2], jnp.int32)
    naive = make_verify_step(cfg)
    chunked = make_verify_step(cfg, score_impl="chunked", score_chunk=4)
    n1, lp1 = naive(params, tokens, positions, dlp, u, dlen, 0.5)
    n2, lp2 = chunked(params, tokens, positions, dlp, u, dlen, 0.5)
    np.testing.assert_array_equal(np.asarray(n1), np.asarray(n2))
    np.testing.assert_allclose(np.asarray(lp1), np.asarray(lp2), atol=1e-5)


def test_blocked_attention_in_model(tiny_cfg):
    """cfg.attn_impl='blocked' is numerically identical to naive."""
    cfg_n = tiny_cfg
    cfg_b = tiny_cfg.replace(attn_impl="blocked")
    params = M.init_lm(jax.random.PRNGKey(0), cfg_n)
    B, T = 2, 48
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, T), 3,
                                cfg_n.vocab_size)
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    ln, _ = M.forward(params, cfg_n, tokens, positions)
    # block_k default 1024 > T would bypass; use a forward with small blocks
    from repro.models.attention import dot_product_attention
    lb, _ = M.forward(params, cfg_b, tokens, positions)
    np.testing.assert_allclose(np.asarray(ln), np.asarray(lb), atol=1e-4)
