"""Sharded vs single-device token identity (DESIGN.md §8).

Every execution strategy added so far carries the same invariant: tokens
are a pure function of (params, prompts, keys), independent of HOW the
computation is laid out.  This file extends it to the mesh: generate,
one-pass SPEC-RL rollout (resume_from_cache), the slot-server backfill path
and a full trainer step each run on a 2×2 (data, model) debug mesh and are
asserted token-identical to the single-device reference in the same
process — including the uneven-head case where ``param_spec`` replicates
KV (3 kv heads on a 2-way model axis).

Device-count setup follows the CI-env pattern: the multi-device lane sets
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` before pytest
starts; under fewer than 4 visible devices everything here skips cleanly
(in-process XLA_FLAGS mutation would silently no-op once jax initialised).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import RolloutCache, SpecConfig, rollout
from repro.data.tokenizer import VOCAB_SIZE
from repro.distributed.mesh import (MeshConfig, data_submeshes, shard_batch,
                                    shard_params)
from repro.distributed.shard_wrap import sharded_decode_attention
from repro.engine.generate import GenerateConfig, generate
from repro.models import model as M
from repro.models.config import ModelConfig

pytestmark = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs >= 4 devices (CI multi-device lane sets "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8)")


def _cfg(**kw):
    base = dict(name="mesh-tiny", num_layers=2, d_model=64, num_heads=4,
                num_kv_heads=2, d_ff=128, vocab_size=VOCAB_SIZE,
                max_seq_len=256)
    base.update(kw)
    return ModelConfig(**base)


def _inputs(B, P, seed=1):
    prompts = jax.random.randint(jax.random.PRNGKey(seed), (B, P), 3,
                                 VOCAB_SIZE - 1)
    mask = jnp.ones((B, P), bool)
    keys = jax.vmap(lambda i: jax.random.fold_in(
        jax.random.PRNGKey(seed + 1), i))(jnp.arange(B))
    return prompts, mask, keys


@pytest.fixture(scope="module")
def mesh22():
    return MeshConfig(data=2, model=2).build()


def assert_rb_equal(a, b):
    np.testing.assert_array_equal(a.response, b.response)
    np.testing.assert_array_equal(a.response_mask, b.response_mask)
    np.testing.assert_array_equal(a.length, b.length)
    np.testing.assert_allclose(a.behaviour_logprobs, b.behaviour_logprobs,
                               atol=1e-4)


# ------------------------------------------------------------------ generate


@pytest.mark.parametrize("kv_heads", [2, 3])
def test_generate_identity(mesh22, kv_heads):
    """Sharded generate == single-device, incl. kv=3 (heads replicated —
    the uneven-head param_spec case on a 2-way model axis)."""
    cfg = _cfg(num_kv_heads=kv_heads, num_heads=6 if kv_heads == 3 else 4,
               head_dim=16)
    params = M.init_lm(jax.random.PRNGKey(0), cfg)
    gen = GenerateConfig(max_new_tokens=10, eos_id=VOCAB_SIZE - 1)
    prompts, mask, keys = _inputs(8, 9)
    ref = generate(params, cfg, gen, prompts, mask, keys)
    sp = shard_params(mesh22, cfg, params)
    out = generate(sp, cfg, gen, *shard_batch(mesh22, (prompts, mask, keys)),
                   mesh=mesh22)
    np.testing.assert_array_equal(np.asarray(ref["tokens"]),
                                  np.asarray(out["tokens"]))
    np.testing.assert_array_equal(np.asarray(ref["length"]),
                                  np.asarray(out["length"]))
    np.testing.assert_allclose(np.asarray(ref["logprobs"]),
                               np.asarray(out["logprobs"]), atol=1e-4)


def test_generate_identity_scalar_key(mesh22):
    """The classic (2,) batched PRNG stream is also layout-invariant."""
    cfg = _cfg()
    params = M.init_lm(jax.random.PRNGKey(0), cfg)
    gen = GenerateConfig(max_new_tokens=8, eos_id=VOCAB_SIZE - 1)
    prompts, mask, _ = _inputs(4, 7)
    key = jax.random.PRNGKey(3)
    ref = generate(params, cfg, gen, prompts, mask, key)
    sp = shard_params(mesh22, cfg, params)
    out = generate(sp, cfg, gen, *shard_batch(mesh22, (prompts, mask)), key,
                   mesh=mesh22)
    np.testing.assert_array_equal(np.asarray(ref["tokens"]),
                                  np.asarray(out["tokens"]))


# ------------------------------------------------- one-pass rollout (resume)


def test_spec_rollout_identity(mesh22):
    """verify_and_prefill → realign (shard_map roll) → resume_from_cache on
    the mesh matches the single-device one-pass rollout step for step."""
    cfg = _cfg()
    params = M.init_lm(jax.random.PRNGKey(0), cfg)
    gen = GenerateConfig(max_new_tokens=12, eos_id=VOCAB_SIZE - 1)
    spec = SpecConfig(variant="spec")
    prompts, mask, keys = _inputs(8, 10)
    ids = list(range(8))
    sp = shard_params(mesh22, cfg, params)

    def steps(p, mesh):
        cache = RolloutCache()
        out = []
        for step in range(3):
            k = jax.vmap(lambda kk: jax.random.fold_in(kk, step))(keys)
            out.append(rollout(p, cfg, gen, spec, prompts, mask, ids, cache,
                               k, step, mesh=mesh))
        return out

    for step, (a, b) in enumerate(zip(steps(params, None), steps(sp, mesh22))):
        assert a.metrics["one_pass"] == b.metrics["one_pass"]
        if step > 0:
            assert b.metrics["one_pass"] == 1.0     # resume path exercised
            assert b.metrics["n_reused"] > 0
        assert_rb_equal(a, b)


# ------------------------------------------------------- slot-server backfill


def test_slot_backfill_identity(mesh22):
    """rollout(spec.backfill='slots') on the mesh — one scheduler per data
    shard, spec-prefix admission — matches the fixed-batch rollout."""
    cfg = _cfg()
    params = M.init_lm(jax.random.PRNGKey(0), cfg)
    gen = GenerateConfig(max_new_tokens=12, eos_id=VOCAB_SIZE - 1)
    prompts, mask, keys = _inputs(8, 10)
    ids = list(range(8))
    sp = shard_params(mesh22, cfg, params)
    fixed = SpecConfig(variant="spec")
    slots = SpecConfig(variant="spec", backfill="slots")

    cache_a, cache_b = RolloutCache(), RolloutCache()
    for step in range(3):
        k = jax.vmap(lambda kk: jax.random.fold_in(kk, step))(keys)
        a = rollout(params, cfg, gen, fixed, prompts, mask, ids, cache_a,
                    k, step)
        b = rollout(sp, cfg, gen, slots, prompts, mask, ids, cache_b,
                    k, step, mesh=mesh22)
        assert_rb_equal(a, b)
    assert b.metrics["backfill_slots"] >= 2          # split over data shards


def test_data_submeshes(mesh22):
    subs = data_submeshes(mesh22)
    assert len(subs) == 2
    devs = [d for sm in subs for d in sm.devices.flat]
    assert len(set(devs)) == 4                       # disjoint devices
    for sm in subs:
        assert sm.axis_names == ("model",)


# ------------------------------------------------------------ trainer step


def test_trainer_step_identity(mesh22):
    """One GRPO step on the mesh: same rollout tokens, same loss, same
    updated params (up to cross-device reduction reordering) as the
    single-device trainer from the same seed."""
    from repro.data.dataset import PromptDataset
    from repro.rewards.mathgen import MathTaskConfig, generate_problems
    from repro.rl.trainer import RLConfig, Trainer

    cfg = _cfg()
    rl = RLConfig(algo="grpo", group_size=2, prompts_per_batch=4,
                  max_new_tokens=8)
    spec = SpecConfig(variant="spec")

    def make(mesh):
        ds = PromptDataset(generate_problems(
            MathTaskConfig(num_problems=8, max_operand=9)),
            max_prompt_len=10)
        return Trainer(cfg, rl, spec, ds, jax.random.PRNGKey(0), mesh=mesh)

    tr_ref = make(None)
    tr_mesh = make(MeshConfig(data=2, model=2))
    assert tr_mesh.mesh is not None
    m_ref = [tr_ref.train_step() for _ in range(2)]
    m_mesh = [tr_mesh.train_step() for _ in range(2)]
    for a, b in zip(m_ref, m_mesh):
        assert a["n_generated"] == b["n_generated"], (a, b)
        assert a["n_reused"] == b["n_reused"]
        np.testing.assert_allclose(a["loss"], b["loss"], atol=1e-4)
        np.testing.assert_allclose(a["reward_mean"], b["reward_mean"],
                                   atol=1e-6)
    for x, y in zip(jax.tree.leaves(tr_ref.params),
                    jax.tree.leaves(tr_mesh.params)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-4)


# --------------------------------------------------- shard_map kernel bound


def test_sharded_decode_attention_matches_op(mesh22):
    """The §8 shard_map boundary returns exactly what the unwrapped op
    does, for divisible and non-divisible (fallback) head counts."""
    from repro.kernels.decode_attention.ops import decode_attention
    B, S, D = 8, 32, 16
    for Hq, Hkv in ((4, 2), (6, 3)):
        q = jax.random.normal(jax.random.PRNGKey(0), (B, Hq, 1, D))
        k = jax.random.normal(jax.random.PRNGKey(1), (B, Hkv, S, D))
        v = jax.random.normal(jax.random.PRNGKey(2), (B, Hkv, S, D))
        q_pos = jnp.full((B,), 9, jnp.int32)
        k_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        k_pos = jnp.where(k_pos <= 9, k_pos, -1)
        lengths = jnp.full((B,), 10, jnp.int32)
        starts = jnp.zeros((B,), jnp.int32)
        ref = decode_attention(q, k, v, q_pos, k_pos, lengths, starts)
        out = sharded_decode_attention(mesh22, q, k, v, q_pos, k_pos,
                                       lengths, starts)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-6)


# ------------------------------------------------------------ draft engine


def test_drafted_rollout_identity(mesh22):
    """§9 drafted rollout on the 2×2 mesh == single-device drafted rollout,
    bit-for-bit at sampling temperature (host-side n-gram proposals are
    deterministic and per-row PRNG streams are layout-independent), across
    cold-start generate AND the one-pass resume step."""
    from repro.drafting import DraftConfig
    cfg = _cfg()
    params = M.init_lm(jax.random.PRNGKey(0), cfg)
    gen = GenerateConfig(max_new_tokens=12, eos_id=VOCAB_SIZE - 1)
    params_b = M.init_lm(jax.random.PRNGKey(42), cfg)
    spec = SpecConfig(variant="spec",
                      draft=DraftConfig(kind="ngram", draft_k=4))
    prompts, mask, keys = _inputs(8, 10)
    ids = list(range(8))
    sp = shard_params(mesh22, cfg, params)
    sp_b = shard_params(mesh22, cfg, params_b)

    def steps(p0, p1, mesh):
        # step 0: cold start (drafted generate) with policy A; step 1:
        # policy B verifies A's cached rollouts -> partial rejections ->
        # drafted one-pass resume over a real continuation
        cache = RolloutCache(group_size=2)
        out = []
        for step, p in enumerate((p0, p1)):
            k = jax.vmap(lambda kk: jax.random.fold_in(kk, step))(keys)
            out.append(rollout(p, cfg, gen, spec, prompts, mask, ids, cache,
                               k, step, mesh=mesh))
        return out

    ref = steps(params, params_b, None)
    for step, (a, b) in enumerate(zip(ref, steps(sp, sp_b, mesh22))):
        assert_rb_equal(a, b)
        assert b.metrics["decode_forwards"] > 0      # drafting exercised
        assert a.metrics["decode_forwards"] == b.metrics["decode_forwards"]
    assert b.metrics["one_pass"] == 1.0              # resume path drafted
    assert 0 < b.metrics["n_reused"] < b.metrics["n_reused"] + \
        b.metrics["n_generated"]                     # partial reuse, real cont

    # ...and through the slot-server backfill (one drafted engine per data
    # shard), still identical to the single-device fixed-batch reference
    slot_spec = SpecConfig(variant="spec", backfill="slots",
                           draft=DraftConfig(kind="ngram", draft_k=4))
    cache = RolloutCache(group_size=2)
    for step, (p, a) in enumerate(zip((sp, sp_b), ref)):
        k = jax.vmap(lambda kk: jax.random.fold_in(kk, step))(keys)
        s = rollout(p, cfg, gen, slot_spec, prompts, mask, ids, cache,
                    k, step, mesh=mesh22)
        assert_rb_equal(a, s)
    assert s.metrics["tokens_per_forward"] > 1.0


def test_drafted_greedy_identity_on_mesh(mesh22):
    """Greedy drafting-on == drafting-off, on the mesh (the §9 contract
    composed with the §8 one)."""
    from repro.drafting import DraftConfig
    cfg = _cfg()
    params = M.init_lm(jax.random.PRNGKey(0), cfg)
    gen = GenerateConfig(max_new_tokens=12, temperature=0.0,
                         eos_id=VOCAB_SIZE - 1)
    prompts, mask, keys = _inputs(8, 10)
    ids = list(range(8))
    sp = shard_params(mesh22, cfg, params)
    off = rollout(sp, cfg, gen, SpecConfig(variant="off"), prompts, mask,
                  ids, None, keys, 0, mesh=mesh22)
    on = rollout(sp, cfg, gen,
                 SpecConfig(variant="off",
                            draft=DraftConfig(kind="ngram", draft_k=4)),
                 prompts, mask, ids, None, keys, 0, mesh=mesh22)
    assert_rb_equal(off, on)
