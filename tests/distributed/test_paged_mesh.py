"""Paged cache layout on the 2×2 (data, model) debug mesh (§8 × §13).

The layout-invariance contract extended to paging: the paged pool's block
axis is GLOBAL (rows of different slots interleave), so it must never shard
like a batch axis — ``decode_cache_pspecs`` replicates paged leaves except
the GQA pool head axis.  On the serving side, ``MeshSlotServer`` routes
whole GRPO groups to shards (``group_id % D``), so CoW prompt sharing stays
shard-local and the mesh server remains token-identical to a single dense
engine over the same requests.

Skips cleanly under < 4 devices (same CI-env pattern as
test_mesh_rollout.py: the multi-device lane sets
``XLA_FLAGS=--xla_force_host_platform_device_count=8``)."""
import copy

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import RolloutCache, SpecConfig, rollout
from repro.data.tokenizer import VOCAB_SIZE
from repro.distributed.mesh import MeshConfig, shard_batch, shard_params
from repro.engine.generate import GenerateConfig, generate
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.serving import MeshSlotServer, Request, make_slot_engine

pytestmark = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs >= 4 devices (CI multi-device lane sets "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8)")

P = 9                                     # P % kv_block_size != 0: CoW forks


def _cfg(**kw):
    base = dict(name="mesh-tiny", num_layers=2, d_model=64, num_heads=4,
                num_kv_heads=2, d_ff=128, vocab_size=VOCAB_SIZE,
                max_seq_len=256)
    base.update(kw)
    return ModelConfig(**base)


def _paged(cfg):
    return cfg.replace(cache_layout="paged", kv_block_size=4)


@pytest.fixture(scope="module")
def mesh22():
    return MeshConfig(data=2, model=2).build()


def test_paged_generate_identity_on_mesh(mesh22):
    """Sharded paged generate == single-device dense generate: the §13
    pspec gating keeps the global block pool whole while the head axis
    still spreads over ``model``."""
    cfg = _cfg()
    params = M.init_lm(jax.random.PRNGKey(0), cfg)
    gen = GenerateConfig(max_new_tokens=10, eos_id=VOCAB_SIZE - 1)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (8, P), 3,
                                 VOCAB_SIZE - 1)
    mask = jnp.ones((8, P), bool)
    keys = jax.vmap(lambda i: jax.random.fold_in(
        jax.random.PRNGKey(2), i))(jnp.arange(8))
    ref = generate(params, cfg, gen, prompts, mask, keys)
    sp = shard_params(mesh22, cfg, params)
    out = generate(sp, _paged(cfg), gen,
                   *shard_batch(mesh22, (prompts, mask, keys)), mesh=mesh22)
    np.testing.assert_array_equal(np.asarray(ref["tokens"]),
                                  np.asarray(out["tokens"]))
    np.testing.assert_array_equal(np.asarray(ref["length"]),
                                  np.asarray(out["length"]))
    np.testing.assert_allclose(np.asarray(ref["logprobs"]),
                               np.asarray(out["logprobs"]), atol=1e-4)


def test_paged_rollout_identity_on_mesh(mesh22):
    """One-pass SPEC-RL steps with a paged cache on the mesh match the
    single-device dense rollout — the resume path re-pages through
    cache_gather compaction under the §13 pspecs."""
    cfg = _cfg()
    params = M.init_lm(jax.random.PRNGKey(0), cfg)
    gen = GenerateConfig(max_new_tokens=12, eos_id=VOCAB_SIZE - 1)
    spec = SpecConfig(variant="spec")
    prompts = jax.random.randint(jax.random.PRNGKey(1), (8, 10), 3,
                                 VOCAB_SIZE - 1)
    mask = jnp.ones((8, 10), bool)
    keys = jax.vmap(lambda i: jax.random.fold_in(
        jax.random.PRNGKey(2), i))(jnp.arange(8))
    ids = list(range(8))
    sp = shard_params(mesh22, cfg, params)

    def steps(p, c, mesh):
        cache = RolloutCache()
        out = []
        for step in range(3):
            k = jax.vmap(lambda kk: jax.random.fold_in(kk, step))(keys)
            out.append(rollout(p, c, gen, spec, prompts, mask, ids, cache,
                               k, step, mesh=mesh))
        return out

    ref = steps(params, cfg, None)
    got = steps(sp, _paged(cfg), mesh22)
    for step, (a, b) in enumerate(zip(ref, got)):
        np.testing.assert_array_equal(a.response, b.response)
        np.testing.assert_array_equal(a.length, b.length)
        np.testing.assert_allclose(a.behaviour_logprobs,
                                   b.behaviour_logprobs, atol=1e-4)
        if step > 0:
            assert b.metrics["n_reused"] > 0


def test_paged_mesh_server_grpo_routing(mesh22):
    """MeshSlotServer over paged shard engines: GRPO groups land whole on
    one shard (group_id % D), CoW sharing fires on BOTH shards, and every
    response is identical to a single dense engine's."""
    cfg = _cfg()
    params = M.init_lm(jax.random.PRNGKey(0), cfg)
    gen = GenerateConfig(max_new_tokens=8, temperature=0.7,
                         eos_id=VOCAB_SIZE - 1)
    rng = np.random.RandomState(3)
    reqs, rid = [], 0
    for g in range(4):                    # groups 0,2 -> shard 0; 1,3 -> 1
        prompt = rng.randint(3, VOCAB_SIZE - 1,
                             size=rng.randint(4, P + 1)).astype(np.int32)
        for _ in range(2):
            key = np.asarray(jax.random.PRNGKey(100 + rid), np.uint32)
            reqs.append(Request(request_id=rid, prompt=prompt.copy(),
                                key=key, max_new_tokens=8, group_id=g))
            rid += 1

    ref_eng = make_slot_engine(params, cfg, gen, num_slots=4, prompt_width=P)
    for r in reqs:
        ref_eng.submit(copy.deepcopy(r))
    ref = ref_eng.run()

    srv = make_slot_engine(params, _paged(cfg), gen, mesh=mesh22,
                           num_slots=4, prompt_width=P)
    assert isinstance(srv, MeshSlotServer)
    for r in reqs:
        srv.submit(copy.deepcopy(r))
    out = srv.run()
    assert sorted(out) == sorted(ref)
    for i in ref:
        assert out[i].finish_reason == ref[i].finish_reason, i
        assert out[i].length == ref[i].length, i
        np.testing.assert_array_equal(out[i].tokens, ref[i].tokens)
        # model-axis reductions reorder fp: tokens exact, logprobs close
        np.testing.assert_allclose(np.asarray(out[i].logprobs),
                                   np.asarray(ref[i].logprobs), atol=1e-4)
    # groups stayed whole per shard and both shards shared prompts
    for eng in srv.engines:
        assert eng.allocator.shared_prompt_bytes_saved > 0
        assert eng.allocator.blocks_in_use == 0
        eng.allocator.check()
    st = srv.stats()
    assert st["paged_cow_forks"] == sum(e.allocator.cow_forks
                                        for e in srv.engines)
    assert st["paged_cow_forks"] > 0
