"""Sharding rules + HLO analysis.

The mesh-requiring test runs in a SUBPROCESS whose *environment* carries
--xla_force_host_platform_device_count=8, so the main pytest process keeps
a single device (per the assignment's conftest rule).  The flag must be in
the env before the subprocess imports jax — an in-process
``os.environ["XLA_FLAGS"] = ...`` mutation silently no-ops once jax has
initialised its backend, which is also why the snippet itself never touches
os.environ.  If the subprocess still comes up with fewer than 8 devices
(e.g. an env that pins XLA_FLAGS without the device-count flag), the test
skips cleanly instead of asserting on a half-built mesh."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.distributed.sharding import (param_spec, params_pspecs,
                                        zero_shard_spec)
from repro.launch.analysis import (analyze_hlo_text, parse_hlo, shape_bytes,
                                   shape_elems)
from repro.models import model as M


def test_param_spec_rules():
    cfg = get_config("qwen1.5-110b")        # kv=8, model=16 -> kv replicated
    assert param_spec("trunk/#0/attn/wq/kernel", (8192, 8192), cfg, 16) == \
        P(None, "model")
    assert param_spec("trunk/#0/attn/wk/kernel", (8192, 1024), cfg, 16) == P()
    assert param_spec("trunk/#0/attn/wo/kernel", (8192, 8192), cfg, 16) == \
        P("model", None)
    assert param_spec("trunk/#0/mlp/w_gate/kernel", (8192, 49152), cfg, 16) \
        == P(None, "model")
    assert param_spec("trunk/#0/mlp/w_down/kernel", (49152, 8192), cfg, 16) \
        == P("model", None)
    assert param_spec("embed", (152064, 8192), cfg, 16) == P("model", None)
    assert param_spec("trunk/#0/norm1/scale", (8192,), cfg, 16) == P()


def test_moe_expert_parallel_vs_tensor_parallel():
    ds = get_config("deepseek-v3-671b")     # 256 experts % 16 == 0 -> EP
    assert param_spec("trunk/#0/moe/w_gate", (256, 7168, 2048), ds, 16) == \
        P("model", None, None)
    mx = get_config("mixtral-8x22b")        # 8 experts, 16-way -> TP on ff
    assert param_spec("trunk/#0/moe/w_gate", (8, 6144, 16384), mx, 16) == \
        P(None, None, "model")
    assert param_spec("trunk/#0/moe/w_down", (8, 16384, 6144), mx, 16) == \
        P(None, "model", None)


def test_params_pspecs_cover_all_leaves():
    cfg = get_config("jamba-v0.1-52b").reduced()
    struct = jax.eval_shape(lambda: M.init_lm(jax.random.PRNGKey(0), cfg))
    specs = params_pspecs(cfg, struct, model_size=2)
    s_leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    p_leaves = jax.tree.leaves(struct)
    assert len(s_leaves) == len(p_leaves)
    for spec, leaf in zip(s_leaves, p_leaves):
        assert len(spec) <= len(leaf.shape)
        # every sharded dim actually divides
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * 8):
            if ax == "model":
                assert dim % 2 == 0


def test_zero_shard_spec():
    sp = zero_shard_spec(P(None, "model"), (4096, 1024), ("data",), 16)
    assert sp == P("data", "model")
    sp = zero_shard_spec(P("model", None), (1024, 4096), ("pod", "data"), 32)
    assert sp == P("model", ("pod", "data"))
    # nothing divisible -> unchanged
    sp = zero_shard_spec(P(), (7,), ("data",), 16)
    assert sp == P()


# ------------------------------------------------------------------ analysis


def test_shape_parsing():
    assert shape_bytes("f32[8,64]{1,0}") == 8 * 64 * 4
    assert shape_bytes("bf16[2,3]") == 12
    assert shape_bytes("(f32[4], s32[2])") == 24
    assert shape_elems("pred[5,5]") == 25


SYNTH_HLO = textwrap.dedent("""\
    HloModule test

    %body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
      %p = (s32[], f32[8,8]) parameter(0)
      %w = f32[8,8]{1,0} get-tuple-element(%p), index=1
      %dot.1 = f32[8,8]{1,0} dot(%w, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ar = f32[8,8]{1,0} all-reduce(%dot.1), channel_id=1, replica_groups=[1,4]<=[4]
      %i = s32[] get-tuple-element(%p), index=0
      ROOT %tup = (s32[], f32[8,8]) tuple(%i, %ar)
    }

    %cond (p2: (s32[], f32[8,8])) -> pred[] {
      %p2 = (s32[], f32[8,8]) parameter(0)
      %i2 = s32[] get-tuple-element(%p2), index=0
      %c = s32[] constant(12)
      ROOT %lt = pred[] compare(%i2, %c), direction=LT
    }

    ENTRY %main (x: f32[8,8]) -> f32[8,8] {
      %x = f32[8,8]{1,0} parameter(0)
      %zero = s32[] constant(0)
      %t0 = (s32[], f32[8,8]) tuple(%zero, %x)
      %wh = (s32[], f32[8,8]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"12"}}
      %gte = f32[8,8]{1,0} get-tuple-element(%wh), index=1
      %ag = f32[8,16]{1,0} all-gather(%gte), channel_id=2, replica_groups=[2,2]<=[4], dimensions={1}
      ROOT %dot.2 = f32[8,8]{1,0} dot(%ag, %ag), lhs_contracting_dims={1}, rhs_contracting_dims={1}
    }
    """)


def test_analyzer_trip_count_multiplication():
    rep = analyze_hlo_text(SYNTH_HLO)
    # body dot: 2*8*8*8 = 1024 flops x 12 trips; entry dot: 2*8*8*16 = 2048
    assert rep["dot_flops_per_device"] == pytest.approx(12 * 1024 + 2048)
    # all-reduce operand 256B x 12 + all-gather operand 256B x 1
    assert rep["collective_bytes_per_device"]["all-reduce"] == \
        pytest.approx(12 * 256)
    assert rep["collective_bytes_per_device"]["all-gather"] == \
        pytest.approx(256)
    assert rep["collective_op_counts"] == {"all-reduce": 1, "all-gather": 1}


SUBPROC_SNIPPET = textwrap.dedent("""\
    import json
    import jax
    if jax.device_count() < 8:           # env did not deliver the devices
        print("SKIP: %d devices" % jax.device_count())
        raise SystemExit(0)
    from repro.configs import get_config
    from repro.launch.specs import input_specs
    from repro.launch.steps import make_train_step, make_serve_step
    from repro.launch import analysis
    from repro.optim import adamw

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = get_config("qwen3-0.6b").reduced(num_layers=2, d_model=128,
                                            vocab_size=256)
    cfg = cfg.replace(dtype="float32", param_dtype="float32")
    spec = input_specs(cfg, "train_4k", mesh)
    fn = make_train_step(cfg, adamw.AdamWConfig())
    with mesh:
        lowered = jax.jit(fn).lower(spec["params"], spec["opt"], *spec["args"])
        compiled = lowered.compile()
        rep = analysis.analyze_compiled(compiled, mesh.size)
    print(json.dumps({
        "flops": rep["dot_flops_per_device"],
        "coll": rep["collective_bytes_total_per_device"],
        "mem": rep["memory"]["resident_bytes"]}))
    """)


def _mesh_subprocess_env() -> dict:
    """Subprocess env with 8 virtual devices: APPEND the device-count flag
    to whatever XLA_FLAGS the CI lane already set (never clobber), and
    prepend src to PYTHONPATH instead of replacing it."""
    env = dict(os.environ)
    flags = env.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        flags = (flags + " --xla_force_host_platform_device_count=8").strip()
    env["XLA_FLAGS"] = flags
    env.setdefault("JAX_PLATFORMS", "cpu")
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    return env


@pytest.mark.slow
def test_small_mesh_lower_compile():
    """A reduced config lowers + compiles on a real 8-device debug mesh and
    yields nonzero flops/collectives (subprocess to isolate device count)."""
    r = subprocess.run([sys.executable, "-c", SUBPROC_SNIPPET],
                       capture_output=True, text=True, timeout=900,
                       env=_mesh_subprocess_env(), cwd=".")
    assert r.returncode == 0, r.stderr[-2000:]
    last = r.stdout.strip().splitlines()[-1]
    if last.startswith("SKIP"):
        pytest.skip(f"subprocess saw too few devices: {last}")
    out = json.loads(last)
    assert out["flops"] > 0
    assert out["coll"] > 0
    assert out["mem"] > 0
