"""Draft-engine correctness (DESIGN.md §9).

* Greedy token-identity: drafting enabled == drafting disabled across
  ``generate``, the one-pass SPEC-RL resume, the slot server and mixed
  left-padded / eos / per-row-budget shapes — acceptance under greedy is
  exactly "draft == argmax", so the emitted stream is the vanilla stream
  whatever the n-gram source proposes.
* Rejection-sampling distribution correctness at temperature > 0: the
  emitted next-token marginal equals the policy distribution exactly
  (chi-squared goodness-of-fit against the true p, same bar vanilla
  sampling is held to).
* draft_step per-row edge cases: zero-length draft, full accept + bonus,
  reject-at-first-token, mid-draft eos truncation, budget truncation.
"""
import copy

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import RolloutCache, SpecConfig, rollout
from repro.drafting import DraftConfig, drafted_generate
from repro.drafting.engine import _prefill_seed
from repro.drafting.step import draft_step
from repro.engine.generate import GenerateConfig, generate
from repro.engine.sampling import adjust_logits
from repro.models import model as M
from repro.models.config import ModelConfig

B, P, N = 4, 8, 14
V = 32


@pytest.fixture(scope="module")
def setup():
    cfg = ModelConfig(name="t", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=V)
    params_a = M.init_lm(jax.random.PRNGKey(0), cfg)
    params_b = M.init_lm(jax.random.PRNGKey(42), cfg)
    prompt = np.zeros((B, P), np.int32)
    mask = np.zeros((B, P), bool)
    rng = np.random.RandomState(3)
    for b in range(B):
        L = int(rng.randint(3, P + 1))
        prompt[b, P - L:] = rng.randint(3, V, L)
        mask[b, P - L:] = True
    return cfg, params_a, params_b, jnp.asarray(prompt), jnp.asarray(mask)


DRAFTS = [DraftConfig(kind="ngram", draft_k=4),
          DraftConfig(kind="ngram", draft_k=6, adaptive=False)]


# ------------------------------------------------------------ greedy identity


@pytest.mark.parametrize("draft", DRAFTS)
def test_generate_greedy_identity(setup, draft):
    cfg, params, _, prompt, mask = setup
    gen = GenerateConfig(max_new_tokens=N, temperature=0.0)
    key = jax.random.PRNGKey(9)
    van = generate(params, cfg, gen, prompt, mask, key)
    dr = drafted_generate(params, cfg, gen, prompt, mask, key, draft)
    np.testing.assert_array_equal(np.asarray(dr["tokens"]),
                                  np.asarray(van["tokens"]))
    np.testing.assert_array_equal(np.asarray(dr["length"]),
                                  np.asarray(van["length"]))
    np.testing.assert_allclose(np.asarray(dr["logprobs"]),
                               np.asarray(van["logprobs"]), atol=1e-5)


def test_generate_greedy_identity_with_eos_and_budget(setup):
    """eos mid-stream + per-row budgets truncate identically."""
    cfg, params, _, prompt, mask = setup
    gen0 = GenerateConfig(max_new_tokens=N, temperature=0.0)
    van0 = np.asarray(generate(params, cfg, gen0, prompt, mask,
                               jax.random.PRNGKey(9))["tokens"])
    # pick an eos id that actually occurs mid-stream in the vanilla output
    eos = int(van0[0, N // 2])
    gen = GenerateConfig(max_new_tokens=N, temperature=0.0, eos_id=eos)
    budget = jnp.asarray([N, 1, 3, N], jnp.int32)
    key = jax.random.PRNGKey(9)
    van = generate(params, cfg, gen, prompt, mask, key, row_budget=budget)
    dr = drafted_generate(params, cfg, gen, prompt, mask, key,
                         DraftConfig(kind="ngram", draft_k=4),
                         row_budget=budget)
    np.testing.assert_array_equal(np.asarray(dr["tokens"]),
                                  np.asarray(van["tokens"]))
    np.testing.assert_array_equal(np.asarray(dr["length"]),
                                  np.asarray(van["length"]))


def test_generate_greedy_identity_with_corpus(setup):
    """A perfectly-predictive corpus changes throughput, never tokens."""
    cfg, params, _, prompt, mask = setup
    gen = GenerateConfig(max_new_tokens=N, temperature=0.0)
    key = jax.random.PRNGKey(9)
    van = generate(params, cfg, gen, prompt, mask, key)
    corpus = [[np.asarray(van["tokens"][b][:van["length"][b]])]
              for b in range(B)]
    dr = drafted_generate(params, cfg, gen, prompt, mask, key,
                         DraftConfig(kind="ngram", draft_k=4), corpus=corpus)
    np.testing.assert_array_equal(np.asarray(dr["tokens"]),
                                  np.asarray(van["tokens"]))
    # ...and the corpus makes speculation actually pay
    assert dr["stats"].tokens_per_forward > 1.5
    assert dr["stats"].accept_rate > 0.5


def test_rollout_resume_greedy_identity(setup):
    """One-pass SPEC-RL with drafting == without, on the continuation past
    a *partially rejected* prefix (cache from policy A, rollout policy B)."""
    cfg, params_a, params_b, prompt, mask = setup
    gen = GenerateConfig(max_new_tokens=N, temperature=0.0)
    ids = list(range(B))
    cache = RolloutCache(group_size=2)
    rollout(params_a, cfg, gen, SpecConfig(variant="spec"), prompt, mask,
            ids, cache, jax.random.PRNGKey(0), 0)
    cache2 = copy.deepcopy(cache)

    key = jax.random.PRNGKey(7)
    base = rollout(params_b, cfg, gen, SpecConfig(variant="spec"),
                   prompt, mask, ids, cache, key, 1)
    dr = rollout(params_b, cfg, gen,
                 SpecConfig(variant="spec",
                            draft=DraftConfig(kind="ngram", draft_k=4)),
                 prompt, mask, ids, cache2, key, 1)
    assert base.metrics["n_reused"] == dr.metrics["n_reused"]
    np.testing.assert_array_equal(dr.response, base.response)
    np.testing.assert_array_equal(dr.length, base.length)
    np.testing.assert_allclose(dr.behaviour_logprobs,
                               base.behaviour_logprobs, atol=1e-5)
    # greedy + a verified-prefix miss means real continuation was drafted
    assert dr.metrics["decode_forwards"] > 0


def test_rollout_slots_greedy_identity(setup):
    """Slot-server backfill with drafting == fixed-batch, per-request keys."""
    cfg, params_a, params_b, prompt, mask = setup
    gen = GenerateConfig(max_new_tokens=N, temperature=0.0)
    ids = list(range(B))
    keys = jax.vmap(lambda i: jax.random.fold_in(jax.random.PRNGKey(11), i))(
        jnp.arange(B))
    caches = [RolloutCache(group_size=2) for _ in range(3)]
    for c in caches:
        rollout(params_a, cfg, gen, SpecConfig(variant="spec"), prompt, mask,
                ids, c, keys, 0)
    key = jax.random.PRNGKey(7)
    draft = DraftConfig(kind="ngram", draft_k=4)
    base = rollout(params_b, cfg, gen, SpecConfig(variant="spec"),
                   prompt, mask, ids, caches[0], keys, 1)
    slots = rollout(params_b, cfg, gen,
                    SpecConfig(variant="spec", draft=draft,
                               backfill="slots", backfill_slots=2),
                    prompt, mask, ids, caches[1], keys, 1)
    fixed = rollout(params_b, cfg, gen,
                    SpecConfig(variant="spec", draft=draft),
                    prompt, mask, ids, caches[2], keys, 1)
    np.testing.assert_array_equal(slots.response, base.response)
    np.testing.assert_array_equal(fixed.response, base.response)
    np.testing.assert_array_equal(slots.length, base.length)
    del key


# ----------------------------------------------------------- step edge cases


def _step_state(cfg, params, prompt, mask, gen, K):
    pre = _prefill_seed(params, cfg, gen, prompt, mask,
                        jax.random.PRNGKey(1), extra=K)
    Bp = prompt.shape[0]
    return dict(
        caches=pre["caches"], cur_tok=pre["tok0"], cur_lp=pre["lp0"],
        done=jnp.zeros((Bp,), bool), count=jnp.zeros((Bp,), jnp.int32),
        budget=jnp.full((Bp,), gen.max_new_tokens, jnp.int32),
        next_pos=pre["next_pos"],
        write_idx=jnp.full((Bp,), prompt.shape[1], jnp.int32),
        keys=pre["key"])


def test_step_edge_cases_greedy(setup):
    """Zero-length draft / full accept / reject-at-first, in one batch.

    eos_id = -1 keeps every greedy stream running the full budget so the
    expected emit counts are exact."""
    cfg, params, _, prompt, mask = setup
    gen = GenerateConfig(max_new_tokens=N, temperature=0.0, eos_id=-1)
    K = 3
    van = np.asarray(generate(params, cfg, gen, prompt, mask,
                              jax.random.PRNGKey(1))["tokens"])
    st = _step_state(cfg, params, prompt, mask, gen, K)
    # row 0: no draft; row 1: the true greedy continuation (full accept);
    # row 2: first token wrong (reject at 0); row 3: first right, second
    # wrong (accept 1, reject at 1)
    dt = np.zeros((B, K), np.int32)
    dl = np.zeros((B,), np.int32)
    dt[1] = van[1, 1:1 + K]
    dl[1] = K
    dt[2, 0] = (van[2, 1] + 1) % V
    dl[2] = 1
    dt[3, :2] = [van[3, 1], (van[3, 2] + 1) % V]
    dl[3] = 2
    out = draft_step(params, cfg, gen, st["caches"], st["cur_tok"],
                     st["cur_lp"], st["done"], st["count"], st["budget"],
                     st["next_pos"], st["write_idx"], st["keys"],
                     jnp.asarray(dt), jnp.asarray(dl), K=K)
    emitted = np.asarray(out["emitted"])
    accepted = np.asarray(out["accepted"])
    np.testing.assert_array_equal(emitted, [1, 1 + K, 1, 2])
    np.testing.assert_array_equal(accepted, [0, K, 0, 1])
    toks = np.asarray(out["tokens"])
    nxt = np.asarray(out["cur_tok"])
    for b in range(B):
        m = emitted[b]
        np.testing.assert_array_equal(toks[b, :m], van[b, :m])
        assert nxt[b] == van[b, m]          # correction == vanilla stream
    # per-row write offsets advanced unevenly, by exactly the kept tokens
    np.testing.assert_array_equal(np.asarray(out["write_idx"]),
                                  P + emitted)


def test_step_mid_draft_eos_truncates(setup):
    cfg, params, _, prompt, mask = setup
    gen0 = GenerateConfig(max_new_tokens=N, temperature=0.0, eos_id=-1)
    van = np.asarray(generate(params, cfg, gen0, prompt, mask,
                              jax.random.PRNGKey(1))["tokens"])
    K = 4
    r = 3                                   # row with a non-repeating head
    eos = int(van[r, 2])                    # third greedy token becomes eos
    assert eos not in (int(van[r, 0]), int(van[r, 1]))
    gen = GenerateConfig(max_new_tokens=N, temperature=0.0, eos_id=eos)
    st = _step_state(cfg, params, prompt, mask, gen, K)
    dt = np.zeros((B, K), np.int32)
    dl = np.zeros((B,), np.int32)
    dt[r] = van[r, 1:1 + K]                 # accepted run contains eos
    dl[r] = K
    out = draft_step(params, cfg, gen, st["caches"], st["cur_tok"],
                     st["cur_lp"], st["done"], st["count"], st["budget"],
                     st["next_pos"], st["write_idx"], st["keys"],
                     jnp.asarray(dt), jnp.asarray(dl), K=K)
    assert bool(np.asarray(out["done"])[r])
    assert int(np.asarray(out["emitted"])[r]) == 3   # ..., eos, stop
    np.testing.assert_array_equal(np.asarray(out["tokens"])[r, :3],
                                  van[r, :3])


def test_step_budget_truncates(setup):
    cfg, params, _, prompt, mask = setup
    gen = GenerateConfig(max_new_tokens=N, temperature=0.0, eos_id=-1)
    K = 4
    van = np.asarray(generate(params, cfg, gen, prompt, mask,
                              jax.random.PRNGKey(1))["tokens"])
    st = _step_state(cfg, params, prompt, mask, gen, K)
    dt = np.zeros((B, K), np.int32)
    dt[1] = van[1, 1:1 + K]
    dl = np.zeros((B,), np.int32)
    dl[1] = K
    budget = np.full((B,), N, np.int32)
    budget[1] = 2                           # room for 2 of the 1+K tokens
    out = draft_step(params, cfg, gen, st["caches"], st["cur_tok"],
                     st["cur_lp"], st["done"], st["count"],
                     jnp.asarray(budget), st["next_pos"], st["write_idx"],
                     st["keys"], jnp.asarray(dt), jnp.asarray(dl), K=K)
    assert int(np.asarray(out["emitted"])[1]) == 2
    assert bool(np.asarray(out["done"])[1])


def test_step_done_rows_are_inert(setup):
    cfg, params, _, prompt, mask = setup
    gen = GenerateConfig(max_new_tokens=N, temperature=0.0)
    K = 3
    st = _step_state(cfg, params, prompt, mask, gen, K)
    done = np.zeros(B, bool)
    done[0] = True
    dt = np.full((B, K), 5, np.int32)
    dl = np.full((B,), K, np.int32)
    out = draft_step(params, cfg, gen, st["caches"], st["cur_tok"],
                     st["cur_lp"], jnp.asarray(done), st["count"],
                     st["budget"], st["next_pos"], st["write_idx"],
                     st["keys"], jnp.asarray(dt), jnp.asarray(dl), K=K)
    assert int(np.asarray(out["emitted"])[0]) == 0
    assert int(np.asarray(out["proposed"])[0]) == 0
    assert int(np.asarray(out["write_idx"])[0]) == P
    assert int(np.asarray(out["cur_tok"])[0]) == int(np.asarray(
        st["cur_tok"])[0])


# ------------------------------------------------- distribution correctness


def _chi2_stat(counts, probs, n):
    """Goodness-of-fit over cells with expectation >= 5 (rest pooled)."""
    exp = probs * n
    big = exp >= 5.0
    stat = float(np.sum((counts[big] - exp[big]) ** 2 / exp[big]))
    rest_c, rest_e = counts[~big].sum(), exp[~big].sum()
    df = int(big.sum()) - 1
    if rest_e > 0:
        stat += float((rest_c - rest_e) ** 2 / rest_e)
        df += 1
    return stat, df


def _chi2_crit(df):
    # generous upper critical value (~p < 1e-4); seeds are fixed so this is
    # a deterministic regression bar, not a flaky statistical test
    return df + 4.0 * np.sqrt(2.0 * df) + 10.0


@pytest.mark.parametrize("temperature,top_p", [(1.0, 1.0), (0.8, 0.9)])
def test_rejection_sampling_distribution(setup, temperature, top_p):
    """The token emitted after a drafted position is distributed exactly as
    vanilla sampling: accept-path (draft token, prob p(g)) plus reject-path
    (residual sample) must reassemble p.  Chi-squared against the TRUE
    adjusted distribution, with vanilla sampling held to the same bar."""
    cfg, params, _, prompt, mask = setup
    gen = GenerateConfig(max_new_tokens=N, temperature=temperature,
                         top_p=top_p)
    R = 512                                  # identical rows, per-row keys
    rows = jnp.broadcast_to(prompt[1], (R, P))
    rmask = jnp.broadcast_to(mask[1], (R, P))
    pre = _prefill_seed(params, cfg, gen, rows, rmask, jax.random.PRNGKey(2),
                        extra=2)
    cur = jnp.full((R,), int(np.asarray(pre["tok0"])[0]), jnp.int32)
    cur_lp = pre["lp0"]

    # the true next-token distribution after [prompt | cur]: one extra
    # decode step with T=1 gives the logits cur conditions
    logits1, _ = M.decode_step(params, cfg, cur[:1, None],
                               pre["next_pos"][:1, None],
                               jax.tree.map(lambda x: x[:, :1],
                                            pre["caches"]),
                               jnp.asarray([P], jnp.int32),
                               kv_length=jnp.asarray([P + 1], jnp.int32))
    p_true = np.asarray(jnp.exp(adjust_logits(logits1[0, 0], temperature,
                                              top_p)))
    g = int(np.argsort(p_true)[-2])          # a plausible (not argmax) draft

    counts = np.zeros(V, np.int64)
    n_total = 0
    for rep in range(4):
        keys = jax.vmap(lambda i: jax.random.fold_in(
            jax.random.PRNGKey(100 + rep), i))(jnp.arange(R))
        dt = jnp.full((R, 1), g, jnp.int32)
        out = draft_step(params, cfg, gen, pre["caches"], cur, cur_lp,
                         jnp.zeros((R,), bool), jnp.zeros((R,), jnp.int32),
                         jnp.full((R,), N, jnp.int32), pre["next_pos"],
                         jnp.full((R,), P, jnp.int32), keys, dt,
                         jnp.full((R,), 1, jnp.int32), K=1)
        acc = np.asarray(out["accepted"])
        nxt = np.asarray(out["cur_tok"])
        emitted_next = np.where(acc > 0, g, nxt)   # token after cur_tok
        np.add.at(counts, emitted_next, 1)
        n_total += R
    stat, df = _chi2_stat(counts.astype(np.float64), p_true, n_total)
    assert stat < _chi2_crit(df), (stat, df)

    # vanilla sampling, same sample size, same bar (test calibration)
    from repro.engine.sampling import sample
    vcounts = np.zeros(V, np.int64)
    for rep in range(4):
        keys = jax.vmap(lambda i: jax.random.fold_in(
            jax.random.PRNGKey(200 + rep), i))(jnp.arange(R))
        tok, _ = sample(keys, jnp.broadcast_to(logits1[0, 0], (R, V)),
                        temperature, top_p)
        np.add.at(vcounts, np.asarray(tok), 1)
    vstat, vdf = _chi2_stat(vcounts.astype(np.float64), p_true, n_total)
    assert vstat < _chi2_crit(vdf), (vstat, vdf)

    # the draft token's accept-path really fires (this is not vacuous)
    assert counts[g] > 0 and p_true[g] > 0.01


def test_behaviour_logprobs_match_score(setup):
    """Drafted rollouts must report log p(token | prefix) for every emitted
    token (accepted OR corrected) — teacher-forced rescoring agrees."""
    cfg, params, _, prompt, mask = setup
    gen = GenerateConfig(max_new_tokens=N, temperature=0.9, top_p=0.95)
    out = drafted_generate(params, cfg, gen, prompt, mask,
                           jax.random.PRNGKey(5),
                           DraftConfig(kind="ngram", draft_k=4))
    from repro.engine.generate import score
    toks = np.asarray(out["tokens"])
    lens = np.asarray(out["length"])
    full = jnp.concatenate([prompt, jnp.asarray(toks)], axis=1)
    fmask = jnp.concatenate(
        [mask, jnp.arange(N)[None, :] < lens[:, None]], axis=1)
    sc = score(params, cfg, full, fmask, temperature=0.9, top_p=0.95)
    lp_ref = np.asarray(sc["logprobs"])[:, P:]
    lp_out = np.asarray(out["logprobs"])
    for b in range(B):
        np.testing.assert_allclose(lp_out[b, :lens[b]], lp_ref[b, :lens[b]],
                                   atol=1e-4)
