"""NGramDraftSource proposal semantics and DraftController adaptation."""
import numpy as np
import pytest

from repro.drafting import DraftConfig, DraftController, NGramDraftSource


def _src(rows=1, **kw):
    kw.setdefault("kind", "ngram")
    return NGramDraftSource(DraftConfig(**kw), rows)


def test_own_history_match():
    """The most recent previous occurrence of the suffix is continued."""
    s = _src(max_ngram=2)
    s.reset(0, [1, 2, 3, 4, 1, 2, 5, 6])
    # suffix (5, 6) never seen; suffix (6,) never seen; no proposal
    assert len(s.propose(0, 4)) == 0
    # pending 1 -> suffix (6, 1) unseen, (1,) seen: latest occurrence of
    # gram (1,) is at index 4, continuation starts with 2, 5, 6
    np.testing.assert_array_equal(s.propose(0, 3, pending=1), [2, 5, 6])
    # two-gram beats one-gram: suffix (1, 2) continues 3 at its first site?
    # no — LATEST registration wins: (1, 2) at index 4..6 continues 5, 6
    s.extend(0, [1, 2])
    np.testing.assert_array_equal(s.propose(0, 2), [5, 6])


def test_longest_gram_preferred():
    s = _src(min_ngram=1, max_ngram=3)
    s.reset(0, [7, 8, 9, 1, 2, 8, 9, 3, 4])
    # suffix ...8, 9 matches the 2-gram (8, 9) -> 3, 4 (latest), while the
    # 1-gram (9,) alone would also say 3; longest match governs
    s.extend(0, [8, 9])
    np.testing.assert_array_equal(s.propose(0, 2), [3, 4])


def test_sibling_corpus_and_self_shadowing():
    s = _src(max_ngram=2)
    sib = np.array([10, 11, 12, 13, 14], np.int32)
    s.reset(0, [1, 10, 11], corpus=[sib])
    # suffix (10, 11) only occurs in the sibling -> continue 12, 13, 14
    np.testing.assert_array_equal(s.propose(0, 3), [12, 13, 14])
    # once the row's own stream contains the gram, it shadows the sibling
    s.extend(0, [10, 11, 99])
    np.testing.assert_array_equal(s.propose(0, 3, pending=11), [99])


def test_use_siblings_off_ignores_corpus():
    s = _src(max_ngram=2, use_siblings=False)
    s.reset(0, [1, 10, 11], corpus=[np.array([10, 11, 12], np.int32)])
    assert len(s.propose(0, 3)) == 0


def test_rows_are_independent():
    s = _src(rows=2, max_ngram=1)
    s.reset(0, [1, 2, 1])
    s.reset(1, [3, 4, 3])
    np.testing.assert_array_equal(s.propose(0, 1, pending=1), [2])
    np.testing.assert_array_equal(s.propose(1, 1, pending=3), [4])
    assert len(s.propose(1, 1, pending=1)) == 0


def test_proposals_are_deterministic():
    """The §9 acceptance math needs q to be a point mass: same context ==
    same proposal, always."""
    s1, s2 = _src(max_ngram=3), _src(max_ngram=3)
    ctx = list(np.random.RandomState(0).randint(0, 8, 64))
    s1.reset(0, ctx)
    s2.reset(0, ctx)
    for pend in range(8):
        np.testing.assert_array_equal(s1.propose(0, 5, pending=pend),
                                      s2.propose(0, 5, pending=pend))


def test_controller_adapts_both_ways():
    cfg = DraftConfig(kind="ngram", draft_k=8, accept_init=0.5, k_min=0)
    c = DraftController(cfg, rows=2)
    k0 = c.draft_len(0)
    for _ in range(30):                      # row 0: everything accepted
        c.update(0, proposed=c.draft_len(0), accepted=c.draft_len(0))
    for _ in range(30):                      # row 1: everything rejected
        c.update(1, proposed=c.draft_len(1) or 1, accepted=0)
    assert c.draft_len(0) == cfg.draft_k > k0
    assert c.draft_len(1) <= 1
    c.reset(1)
    assert c.draft_len(1) == k0              # slot reuse forgets history


def test_controller_fixed_mode():
    c = DraftController(DraftConfig(kind="ngram", draft_k=5, adaptive=False),
                        rows=1)
    c.update(0, proposed=5, accepted=0)
    assert c.draft_len(0) == 5


def test_config_validation():
    with pytest.raises(AssertionError):
        DraftConfig(kind="ngram", min_ngram=3, max_ngram=2).validate()
    with pytest.raises(AssertionError):
        DraftConfig(kind="tree").validate()
