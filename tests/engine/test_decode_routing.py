"""Decode-path routing: generate / resume_from_cache / slot-server outputs
are sample-for-sample identical whichever decode-attention impl serves the
T==1 steps — legacy naive, the length-bounded blocked path, or the split-K
Pallas kernel in interpret mode (ISSUE 3 acceptance criterion)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.engine.generate import (GenerateConfig, generate,
                                   positions_from_mask, resume_from_cache)
from repro.models import model as M
from repro.models.config import ModelConfig


@pytest.fixture(scope="module")
def setup():
    cfg = ModelConfig(name="t", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=32)
    params = M.init_lm(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (3, 8), 3, 32)
    mask = np.ones((3, 8), bool)
    mask[0, :3] = False
    mask[2, :1] = False
    mask = jnp.asarray(mask)
    return cfg, params, jnp.where(mask, prompt, 0), mask


def _assert_same(a, b):
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    np.testing.assert_array_equal(np.asarray(a["length"]),
                                  np.asarray(b["length"]))
    np.testing.assert_allclose(np.asarray(a["logprobs"]),
                               np.asarray(b["logprobs"]), atol=1e-5,
                               rtol=1e-5)


@pytest.mark.parametrize("impl", ["blocked", "interpret"])
def test_generate_token_identity(setup, impl):
    cfg, params, prompt, mask = setup
    gen = GenerateConfig(max_new_tokens=12)
    key = jax.random.PRNGKey(7)
    want = generate(params, cfg.replace(decode_impl="naive"), gen, prompt,
                    mask, key)
    got = generate(params, cfg.replace(decode_impl=impl), gen, prompt, mask,
                   key)
    _assert_same(got, want)


def test_auto_flips_to_blocked_beyond_naive_width(setup):
    """S > NAIVE_MAX_S: 'auto' decode takes the length-bounded blocked path
    and still reproduces the legacy naive samples token for token."""
    from repro.kernels.decode_attention.ops import NAIVE_MAX_S
    cfg, params, prompt, mask = setup
    N = NAIVE_MAX_S + 8 - prompt.shape[1]        # cache width P + N = 136
    gen = GenerateConfig(max_new_tokens=N, eos_id=31)   # rare eos: deep rows
    key = jax.random.PRNGKey(9)
    want = generate(params, cfg.replace(decode_impl="naive"), gen, prompt,
                    mask, key)
    got = generate(params, cfg.replace(decode_impl="auto"), gen, prompt,
                   mask, key)
    _assert_same(got, want)
    assert int(np.asarray(want["length"]).max()) > 64   # genuinely deep


@pytest.mark.parametrize("impl", ["blocked", "interpret"])
def test_resume_from_cache_token_identity(setup, impl):
    cfg, params, prompt, mask = setup
    B, P = prompt.shape
    N = 12
    gen = GenerateConfig(max_new_tokens=N)
    key = jax.random.PRNGKey(11)
    want = generate(params, cfg.replace(decode_impl="naive"), gen, prompt,
                    mask, key)
    cfg_i = cfg.replace(decode_impl=impl)
    caches = M.init_cache(cfg_i, B, P + N)
    logits, caches = M.prefill(params, cfg_i, prompt,
                               positions_from_mask(mask), caches)
    got = resume_from_cache(params, cfg_i, gen, caches, logits[:, -1],
                            mask.sum(axis=1).astype(jnp.int32), P, key)
    _assert_same(got, want)


def test_slot_server_token_identity(setup):
    """Slot-scheduled decode (per-row write depths -> per-row kv_length)
    through the blocked path == fixed-batch naive generate per request."""
    from repro.serving import Request, SlotEngine
    cfg, params, prompt, mask = setup
    B, P = prompt.shape
    N = 12
    gen = GenerateConfig(max_new_tokens=N)
    keys = jax.vmap(lambda i: jax.random.fold_in(jax.random.PRNGKey(19), i)
                    )(jnp.arange(B))
    budget = jnp.array([N, 3, 7], jnp.int32)
    want = generate(params, cfg.replace(decode_impl="naive"), gen, prompt,
                    mask, keys, row_budget=budget)

    eng = SlotEngine(params, cfg.replace(decode_impl="blocked"), gen,
                     num_slots=2, prompt_width=P, chunk_steps=4)
    kn, pn, mn = np.asarray(keys), np.asarray(prompt), np.asarray(mask)
    for i in range(B):
        pl = int(mn[i].sum())
        eng.submit(Request(request_id=i, prompt=pn[i, P - pl:], key=kn[i],
                           max_new_tokens=int(budget[i])))
    resps = eng.run()
    for i in range(B):
        L = int(want["length"][i])
        assert resps[i].length == L
        np.testing.assert_array_equal(resps[i].tokens,
                                      np.asarray(want["tokens"])[i, :L])
        np.testing.assert_allclose(resps[i].logprobs,
                                   np.asarray(want["logprobs"])[i, :L],
                                   atol=1e-5, rtol=1e-5)


def test_mla_decode_routing_identity(setup):
    """apply_mla's decode dispatch (G=1, Dk != Dv): blocked == naive."""
    _, _, prompt, mask = setup
    cfg = ModelConfig(name="mla", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=4, d_ff=128, vocab_size=32,
                      attention_kind="mla", q_lora_rank=32, kv_lora_rank=32,
                      qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16)
    params = M.init_lm(jax.random.PRNGKey(2), cfg)
    gen = GenerateConfig(max_new_tokens=10)
    key = jax.random.PRNGKey(13)
    want = generate(params, cfg.replace(decode_impl="naive"), gen, prompt,
                    mask, key)
    got = generate(params, cfg.replace(decode_impl="blocked"), gen, prompt,
                   mask, key)
    _assert_same(got, want)


def test_sliding_window_decode_routing_identity(setup):
    _, _, prompt, mask = setup
    cfg = ModelConfig(name="swa", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=32,
                      sliding_window=6)
    params = M.init_lm(jax.random.PRNGKey(3), cfg)
    gen = GenerateConfig(max_new_tokens=12)
    key = jax.random.PRNGKey(17)
    want = generate(params, cfg.replace(decode_impl="naive"), gen, prompt,
                    mask, key)
    for impl in ("blocked", "interpret"):
        got = generate(params, cfg.replace(decode_impl=impl), gen, prompt,
                       mask, key)
        _assert_same(got, want)
