"""Generation engine: behaviour-logprob consistency, eos stopping,
row budgets, initial_done skipping, left-padding invariance."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.engine.generate import GenerateConfig, generate, positions_from_mask, score
from repro.models import model as M


@pytest.fixture(scope="module")
def setup(request):
    from repro.models.config import ModelConfig
    cfg = ModelConfig(name="t", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=32)
    params = M.init_lm(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompt(cfg, B=3, P=8, seed=1):
    prompt = jax.random.randint(jax.random.PRNGKey(seed), (B, P), 3,
                                cfg.vocab_size)
    mask = np.ones((B, P), bool)
    mask[0, :3] = False
    mask[2, :1] = False
    mask = jnp.asarray(mask)
    return jnp.where(mask, prompt, 0), mask


def test_logprobs_match_rescoring(setup):
    cfg, params = setup
    prompt, mask = _prompt(cfg)
    gen = GenerateConfig(max_new_tokens=10)
    out = generate(params, cfg, gen, prompt, mask, jax.random.PRNGKey(7))
    N = 10
    full = jnp.concatenate([prompt, out["tokens"]], axis=1)
    gmask = jnp.arange(N)[None, :] < out["length"][:, None]
    fmask = jnp.concatenate([mask, gmask], axis=1)
    sc = score(params, cfg, full, fmask)
    err = jnp.max(jnp.abs(jnp.where(gmask, sc["logprobs"][:, prompt.shape[1]:]
                                    - out["logprobs"], 0.0)))
    assert float(err) < 1e-4


def test_eos_stops_row(setup):
    cfg, params = setup
    prompt, mask = _prompt(cfg)
    gen = GenerateConfig(max_new_tokens=16, eos_id=2)
    out = generate(params, cfg, gen, prompt, mask, jax.random.PRNGKey(3))
    toks = np.asarray(out["tokens"])
    lens = np.asarray(out["length"])
    for i in range(toks.shape[0]):
        row = toks[i, :lens[i]]
        if 2 in row.tolist():
            assert row.tolist().index(2) == lens[i] - 1  # eos is last
        assert (toks[i, lens[i]:] == 0).all()            # pads after


def test_row_budget(setup):
    cfg, params = setup
    prompt, mask = _prompt(cfg)
    gen = GenerateConfig(max_new_tokens=16, eos_id=31)  # unlikely eos
    budget = jnp.array([4, 0, 9], jnp.int32)
    out = generate(params, cfg, gen, prompt, mask, jax.random.PRNGKey(5),
                   row_budget=budget)
    assert (np.asarray(out["length"]) <= np.asarray(budget)).all()
    assert int(out["length"][1]) == 0


def test_initial_done_skips_rows(setup):
    cfg, params = setup
    prompt, mask = _prompt(cfg)
    gen = GenerateConfig(max_new_tokens=8)
    done = jnp.array([True, False, True])
    out = generate(params, cfg, gen, prompt, mask, jax.random.PRNGKey(5),
                   initial_done=done)
    lens = np.asarray(out["length"])
    assert lens[0] == 0 and lens[2] == 0 and lens[1] > 0


def test_left_padding_invariance(setup):
    """Extra left padding must not change greedy generation."""
    cfg, params = setup
    B, P = 1, 6
    prompt = jax.random.randint(jax.random.PRNGKey(9), (B, P), 3,
                                cfg.vocab_size)
    mask = jnp.ones((B, P), bool)
    gen = GenerateConfig(max_new_tokens=6, temperature=0.0)
    out1 = generate(params, cfg, gen, prompt, mask, jax.random.PRNGKey(0))
    pad = jnp.zeros((B, 3), jnp.int32)
    prompt2 = jnp.concatenate([pad, prompt], axis=1)
    mask2 = jnp.concatenate([jnp.zeros((B, 3), bool), mask], axis=1)
    out2 = generate(params, cfg, gen, prompt2, mask2, jax.random.PRNGKey(0))
    np.testing.assert_array_equal(np.asarray(out1["tokens"]),
                                  np.asarray(out2["tokens"]))


def test_resume_from_cache_matches_generate(setup):
    """Decoding from an externally prefilled cache == prefill-inside-generate
    for the same key: the two engine entry points share one decode loop."""
    from repro.engine.generate import resume_from_cache
    cfg, params = setup
    prompt, mask = _prompt(cfg)
    B, P = prompt.shape
    N = 10
    gen = GenerateConfig(max_new_tokens=N)
    key = jax.random.PRNGKey(11)
    want = generate(params, cfg, gen, prompt, mask, key)

    caches = M.init_cache(cfg, B, P + N)
    logits, caches = M.prefill(params, cfg, prompt,
                               positions_from_mask(mask), caches)
    got = resume_from_cache(params, cfg, gen, caches, logits[:, -1],
                            mask.sum(axis=1).astype(jnp.int32), P, key)
    np.testing.assert_array_equal(np.asarray(got["tokens"]),
                                  np.asarray(want["tokens"]))
    np.testing.assert_array_equal(np.asarray(got["length"]),
                                  np.asarray(want["length"]))
    np.testing.assert_allclose(np.asarray(got["logprobs"]),
                               np.asarray(want["logprobs"]), atol=1e-6)


def test_score_first_token_and_pads_zero(setup):
    cfg, params = setup
    prompt, mask = _prompt(cfg)
    sc = score(params, cfg, prompt, mask)
    lp = np.asarray(sc["logprobs"])
    valid = np.asarray(sc["valid"])
    # first valid token of each row has no scored prefix
    for i in range(lp.shape[0]):
        first = int(np.argmax(np.asarray(mask)[i]))
        assert not valid[i, first]
        assert lp[i, first] == 0.0
    assert (lp[~valid] == 0.0).all()


# ------------------------------------------------- resume_from_cache edges


def _verify_resume(cfg, params, prompt, mask, draft_len_rows, log_lenience,
                   key, N=12, draft_eos_rows=None):
    """One-pass verify→compact→resume over crafted drafts; returns
    (n, cont, draft) for comparison against the two-pass reference."""
    from repro.core.spec_rollout import left_align
    from repro.core.verify import verify_and_prefill
    from repro.engine.generate import resume_from_cache
    B, P = prompt.shape
    draft = jax.random.randint(jax.random.PRNGKey(33), (B, N), 3,
                               cfg.vocab_size)
    if draft_eos_rows is not None:
        gen_eos = 2
        for i, dl in enumerate(draft_len_rows):
            if draft_eos_rows[i] and dl > 0:
                draft = draft.at[i, dl - 1].set(gen_eos)
    draft_len = jnp.asarray(draft_len_rows, jnp.int32)
    didx = jnp.arange(N)[None, :]
    # pessimistic behaviour log-probs: random drafts score ~ -log V under
    # the current policy, so -6 keeps the acceptance ratio near 1 and the
    # lenience knob controls rejection
    draft_lp = jnp.where(didx < draft_len[:, None], -6.0, 0.0)
    kv, kd = jax.random.split(key)
    ver = verify_and_prefill(params, cfg, prompt, mask, draft, draft_lp,
                             draft_len, kv, log_lenience, impl="ref")
    n = ver["n"]
    W = P + N
    p_len = mask.sum(axis=1).astype(jnp.int32)
    caches = M.realign_decode_cache(cfg, ver["caches"],
                                    (N - n).astype(jnp.int32), p_len + n, W,
                                    impl="ref")
    eos_at_n = jnp.take_along_axis(
        draft, jnp.maximum(n - 1, 0)[:, None], axis=1)[:, 0] == 2
    full_reuse = (n == draft_len) & (n > 0) & eos_at_n if draft_eos_rows \
        else jnp.zeros((B,), bool)
    gen = GenerateConfig(max_new_tokens=N)
    cont = resume_from_cache(params, cfg, gen, caches, ver["seed_logits"],
                             p_len + n, W, kd, initial_done=full_reuse,
                             row_budget=N - n)
    return n, cont, draft, draft_len, kd


def test_resume_zero_accepted_prefix(setup):
    """n = 0 everywhere (lenience -> 0 rejects all): resuming from the
    compacted verify cache == generating from the bare prompt."""
    from repro.core.spec_rollout import left_align
    cfg, params = setup
    prompt, mask = _prompt(cfg)
    N = 12
    key = jax.random.PRNGKey(21)
    n, cont, _, _, kd = _verify_resume(cfg, params, prompt, mask,
                                       [N, 7, 3], -1e9, key, N=N)
    assert (np.asarray(n) == 0).all()
    # reference: two-pass continuation over the aligned (prompt ⊕ nothing)
    W = prompt.shape[1] + N
    al_tok, al_mask = left_align(
        jnp.concatenate([prompt, jnp.zeros((3, N), jnp.int32)], axis=1),
        jnp.concatenate([mask, jnp.zeros((3, N), bool)], axis=1))
    want = generate(params, cfg, GenerateConfig(max_new_tokens=N), al_tok,
                    al_mask, kd, row_budget=jnp.full((3,), N, jnp.int32))
    np.testing.assert_array_equal(np.asarray(cont["tokens"]),
                                  np.asarray(want["tokens"]))
    np.testing.assert_array_equal(np.asarray(cont["length"]),
                                  np.asarray(want["length"]))


def test_resume_fully_accepted_draft_with_eos(setup):
    """Drafts fully accepted (lenience -> inf) and ending in EOS: the row is
    initially done, resumes zero tokens, and keeps its budget at 0."""
    cfg, params = setup
    prompt, mask = _prompt(cfg)
    N = 12
    n, cont, draft, draft_len, _ = _verify_resume(
        cfg, params, prompt, mask, [5, 8, N], 1e9, jax.random.PRNGKey(23),
        N=N, draft_eos_rows=[True, True, True])
    np.testing.assert_array_equal(np.asarray(n), np.asarray(draft_len))
    assert (np.asarray(cont["length"]) == 0).all()
    assert (np.asarray(cont["tokens"]) == 0).all()
    assert int(cont["n_generated"]) == 0


def test_resume_mixed_per_row_start_positions(setup):
    """Rows with different prompt lengths AND different accepted-prefix
    lengths resume from different cache depths; each row still matches the
    two-pass reference built from its own aligned context."""
    from repro.core.spec_rollout import left_align
    cfg, params = setup
    prompt, mask = _prompt(cfg)                  # mixed p_len already
    N = 12
    n, cont, draft, draft_len, kd = _verify_resume(
        cfg, params, prompt, mask, [0, 6, N], 0.3, jax.random.PRNGKey(25),
        N=N)
    n_np = np.asarray(n)
    assert len(set(n_np.tolist())) > 1           # genuinely mixed starts
    didx = jnp.arange(N)[None, :]
    prefix_mask = didx < n[:, None]
    al_tok, al_mask = left_align(
        jnp.concatenate([prompt, jnp.where(prefix_mask, draft, 0)], axis=1),
        jnp.concatenate([mask, prefix_mask], axis=1))
    want = generate(params, cfg, GenerateConfig(max_new_tokens=N), al_tok,
                    al_mask, kd, row_budget=N - n)
    np.testing.assert_array_equal(np.asarray(cont["tokens"]),
                                  np.asarray(want["tokens"]))
    np.testing.assert_array_equal(np.asarray(cont["length"]),
                                  np.asarray(want["length"]))
    np.testing.assert_allclose(np.asarray(cont["logprobs"]),
                               np.asarray(want["logprobs"]), atol=1e-5)
